"""Train-step factory: loss -> grads -> clip -> AdamW, with microbatch
accumulation, logical-rule sharding, and donated buffers.

Two step flavors:

- ``make_train_step``: the production path. Everything under one jit;
  parallelism comes from in/out shardings (batch over ('pod','data'),
  params FSDP x TP) and GSPMD's collectives -- the 'fused' baseline in
  the paper's vocabulary.
- ``make_ddp_compressed_step``: explicit shard_map data-parallel step
  whose gradient all-reduce is the int8 error-feedback ring
  (optim/compress.py) -- the paper's decomposed-collective idea applied
  to optimizer traffic. Used for small models / the A-B benchmark.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.compat import shard_map

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import sharding as shlib
from repro.models.model import Model
from repro.optim import adamw, compress, schedule


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    step: jax.Array


def init_train_state(model: Model, key, tcfg: TrainConfig) -> Tuple[TrainState, Any]:
    params, specs = model.init(key)
    opt = adamw.init(params, tcfg.opt_state_dtype)
    return TrainState(params=params, opt=opt, step=jnp.zeros((), jnp.int32)), specs


def state_shardings(mesh, specs, abstract_state: Optional[TrainState] = None) -> TrainState:
    """NamedShardings for the TrainState from the param logical specs.
    With ``abstract_state``, resolution is shape-aware (input-safe)."""
    shapes = abstract_state.params if abstract_state is not None else None
    p_sh = shlib.tree_shardings(mesh, specs, shapes)
    scalar = NamedSharding(mesh, P())
    return TrainState(
        params=p_sh,
        opt=adamw.AdamWState(count=scalar, mu=p_sh, nu=p_sh),
        step=scalar,
    )


def _split_micro(batch: Dict[str, jax.Array], n: int):
    def r(x):
        b = x.shape[0]
        return x.reshape((n, b // n) + x.shape[1:])

    return {k: r(v) for k, v in batch.items()}


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        return model.loss(params, batch)

    return loss_fn


def make_train_step(model: Model, tcfg: TrainConfig, mesh=None):
    """Returns step(state, batch) -> (state, metrics), jit-ready."""
    loss_fn = make_loss_fn(model)

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, jax.Array]]:
        lr = schedule.warmup_cosine(
            state.step, peak=tcfg.learning_rate, warmup=tcfg.warmup_steps, total=tcfg.total_steps
        )
        if tcfg.microbatch and tcfg.microbatch > 1:
            micro = _split_micro(batch, tcfg.microbatch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(state.params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, ltot), _ = jax.lax.scan(acc_body, (zeros, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / tcfg.microbatch, grads)
            loss = ltot / tcfg.microbatch
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch
            )
        grads, gnorm = adamw.clip_by_global_norm(grads, tcfg.grad_clip)
        new_params, new_opt = adamw.update(grads, state.opt, state.params, lr=lr, cfg=tcfg)
        metrics = dict(metrics)
        metrics.update({"grad_norm": gnorm, "lr": lr})
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return step


def jit_train_step(model: Model, tcfg: TrainConfig, mesh, specs):
    """jit with explicit in/out shardings + donated state."""
    step = make_train_step(model, tcfg, mesh)
    st_sh = state_shardings(mesh, specs)
    batch_sh = shlib.batch_sharding(mesh, 2)
    return jax.jit(
        step,
        in_shardings=(st_sh, {"tokens": batch_sh, "labels": batch_sh}),
        out_shardings=(st_sh, None),
        donate_argnums=(0,),
    )


# ---------------------------------------------------------------------------
# explicit-DP step with compressed ring all-reduce (paper technique on the
# optimizer's collective)
# ---------------------------------------------------------------------------


class DDPState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    err: Any  # error-feedback residuals (f32, param-shaped)
    step: jax.Array


def init_ddp_state(model: Model, key, tcfg: TrainConfig) -> DDPState:
    params, _ = model.init(key)
    return DDPState(
        params=params,
        opt=adamw.init(params, tcfg.opt_state_dtype),
        err=compress.init_error_state(params),
        step=jnp.zeros((), jnp.int32),
    )


def make_ddp_compressed_step(model: Model, tcfg: TrainConfig, mesh, axis_name: str = "data"):
    """shard_map DP: params replicated, batch sharded over ``axis_name``,
    gradients reduced with the int8 error-feedback all-gather."""
    loss_fn = make_loss_fn(model)

    def inner(state: DDPState, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params, batch)
        if tcfg.grad_compression == "int8":
            grads, new_err = compress.compressed_psum_tree(grads, axis_name, state.err)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis_name), grads)
            new_err = state.err
        loss = jax.lax.pmean(loss, axis_name)
        lr = schedule.warmup_cosine(
            state.step, peak=tcfg.learning_rate, warmup=tcfg.warmup_steps, total=tcfg.total_steps
        )
        grads, gnorm = adamw.clip_by_global_norm(grads, tcfg.grad_clip)
        new_params, new_opt = adamw.update(grads, state.opt, state.params, lr=lr, cfg=tcfg)
        return DDPState(new_params, new_opt, new_err, state.step + 1), {
            "loss": loss,
            "grad_norm": gnorm,
        }

    rep = P()
    bspec = P(axis_name)

    def step(state: DDPState, batch):
        specs_state = jax.tree.map(lambda _: rep, state)
        specs_batch = jax.tree.map(lambda _: bspec, batch)
        return jax.jit(
            shard_map(
                inner,
                mesh=mesh,
                in_specs=(specs_state, specs_batch),
                out_specs=(specs_state, jax.tree.map(lambda _: rep, {"loss": 0, "grad_norm": 0})),
                check_vma=False,
            )
        )(state, batch)

    return step
