from repro.train.step import (
    DDPState,
    TrainState,
    init_ddp_state,
    init_train_state,
    jit_train_step,
    make_ddp_compressed_step,
    make_train_step,
    state_shardings,
)

__all__ = [
    "DDPState", "TrainState", "init_ddp_state", "init_train_state",
    "jit_train_step", "make_ddp_compressed_step", "make_train_step",
    "state_shardings",
]
