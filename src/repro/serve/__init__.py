from repro.serve.engine import Request, ServeEngine
from repro.serve.queue import Admission, CoalescingQueue, PendingQueue
from repro.serve.spectral import (
    PlanPool,
    SpectralEngine,
    SpectralFuture,
    SpectralRequest,
    plan_key,
)

__all__ = [
    "Admission",
    "CoalescingQueue",
    "PendingQueue",
    "PlanPool",
    "Request",
    "ServeEngine",
    "SpectralEngine",
    "SpectralFuture",
    "SpectralRequest",
    "plan_key",
]
