from repro.runtime.faults import CircuitBreaker, FaultPlan, RetryPolicy
from repro.serve.engine import Request, ServeEngine
from repro.serve.queue import Admission, CoalescingQueue, PendingQueue
from repro.serve.spectral import (
    PlanPool,
    SpectralEngine,
    SpectralFuture,
    SpectralRequest,
    plan_key,
)

__all__ = [
    "Admission",
    "CircuitBreaker",
    "CoalescingQueue",
    "FaultPlan",
    "PendingQueue",
    "PlanPool",
    "Request",
    "RetryPolicy",
    "ServeEngine",
    "SpectralEngine",
    "SpectralFuture",
    "SpectralRequest",
    "plan_key",
]
