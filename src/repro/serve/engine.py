"""Batched serving engine: slot-based continuous batching (lite).

A fixed pool of ``max_batch`` slots shares one stacked decode state.
Requests prefill into a free slot (batch=1 prefill, cache rows inserted
at the slot index); every ``step()`` decodes all active slots together;
finished slots are freed for the next request. Greedy or temperature
sampling. This is the standard orchestration shape of production
engines (vLLM-style, minus paging) and is exactly what ``serve_step``
lowers for the decode_* dry-run shapes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.models.model import Model
from repro.serve.queue import PendingQueue


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _tree_set_slot(state, slot_state, idx: int, batch_axis_of=None):
    """Insert a batch=1 sub-state into batch row ``idx`` of the pool state.

    Leaves are (L, B, ...) stacked per layer; slot leaves are (L, 1, ...).
    Scalar leaves (pos counters) are shared across slots and skipped.
    """

    def upd(pool, one):
        if pool.ndim < 2 or pool.shape[:1] != one.shape[:1]:
            return pool
        return jax.lax.dynamic_update_slice_in_dim(pool, one.astype(pool.dtype), idx, axis=1)

    return jax.tree.map(upd, state, slot_state)


class ServeEngine:
    def __init__(self, model: Model, params, scfg: ServeConfig):
        self.model = model
        self.params = params
        self.scfg = scfg
        self.cfg = model.cfg
        b, s = scfg.max_batch, scfg.max_seq
        self.state = model.init_decode_state(b, s)
        # per-slot bookkeeping (host side)
        self.slots: List[Optional[Request]] = [None] * b
        self.slot_pos = np.zeros(b, np.int32)  # valid length per slot
        self._uid = 0
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)

    # ------------------------------------------------------------- requests
    def add_request(self, prompt: np.ndarray, max_new: int = 32) -> Optional[int]:
        try:
            slot = self.slots.index(None)
        except ValueError:
            return None
        req = Request(self._uid, np.asarray(prompt, np.int32), max_new)
        self._uid += 1
        # batch-1 prefill into a scratch state, then insert at slot
        scratch = self.model.init_decode_state(1, self.scfg.max_seq)
        scratch, logits = self._prefill(
            self.params, {"tokens": jnp.asarray(req.prompt[None, :])}, scratch
        )
        self.state = _tree_set_slot(self.state, scratch, slot)
        self.slot_pos[slot] = req.prompt.shape[0] + self.cfg.meta_tokens
        first = int(jnp.argmax(logits[0]))
        req.out.append(first)
        self.slots[slot] = req
        return slot

    # ----------------------------------------------------------------- step
    def step(self) -> List[Request]:
        """One decode step for all active slots; returns finished requests."""
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return []
        tokens = np.zeros((self.scfg.max_batch, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].out[-1]
        # shared pos counter: slots decode in lockstep from the pool's pos;
        # per-slot validity handled by kv_valid_len = slot cache length.
        self.state["pos"] = jnp.asarray(int(self.slot_pos[active].max()), jnp.int32)
        logits, self.state = self._decode(self.params, jnp.asarray(tokens), self.state)
        if self.scfg.temperature > 0:
            key = jax.random.PRNGKey(int(self._uid) + int(self.slot_pos.sum()))
            nxt = jax.random.categorical(key, logits / self.scfg.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = np.asarray(nxt)
        finished = []
        for i in active:
            r = self.slots[i]
            r.out.append(int(nxt[i]))
            self.slot_pos[i] += 1
            if len(r.out) >= r.max_new or self.slot_pos[i] >= self.scfg.max_seq - 1:
                r.done = True
                finished.append(r)
                self.slots[i] = None
        return finished

    def run(self, prompts: List[np.ndarray], max_new: int = 32) -> Dict[int, List[int]]:
        """Convenience driver: serve all prompts to completion."""
        results: Dict[int, List[int]] = {}
        # deque-backed FIFO (shared with the spectral serving queue):
        # the old list.pop(0) was O(n) per admit, O(n^2) per drain
        pending = PendingQueue(prompts)
        submitted = {}
        while pending or any(s is not None for s in self.slots):
            while pending:
                slot = self.add_request(pending.peek(), max_new)
                if slot is None:
                    break
                submitted[self.slots[slot].uid] = True
                pending.pop()
            for r in self.step():
                results[r.uid] = r.out
        return results
