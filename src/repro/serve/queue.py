"""Shared request-queue machinery for the serving engines.

Two pieces, both deque-backed (O(1) at either end -- the LM engine's old
``list.pop(0)`` pending queue was O(n) per admit, O(n^2) per drain):

- :class:`PendingQueue`: a plain FIFO used by
  :meth:`repro.serve.engine.ServeEngine.run` for pending prompts;
- :class:`CoalescingQueue`: the spectral engine's admission queue.
  Items are pushed under a *coalesce key* (same key == same plan + same
  op == batchable into one stacked execution); a key group becomes ready
  when it reaches ``Admission.max_batch`` items or its oldest item has
  waited ``Admission.max_wait_s`` -- the standard batching-server
  admission policy (fill fast under load, bound tail latency when idle).
  ``coalesce=False`` degrades every group to batches of one, which is
  the control arm of the serving benchmark.

The clock is injectable so admission behavior is testable without
sleeping.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, Hashable, List, Optional, Tuple


class PendingQueue:
    """Deque-backed FIFO: O(1) push/pop at both ends."""

    def __init__(self, items=()):
        self._q: collections.deque = collections.deque(items)

    def push(self, item) -> None:
        self._q.append(item)

    def extend(self, items) -> None:
        self._q.extend(items)

    def pop(self):
        """Oldest item (FIFO). Raises IndexError when empty."""
        return self._q.popleft()

    def peek(self):
        return self._q[0]

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)


@dataclasses.dataclass(frozen=True)
class Admission:
    """Batching admission policy: flush a key group at ``max_batch``
    items immediately, or whatever has accumulated once the group's
    oldest item has waited ``max_wait_s``."""

    max_batch: int = 8
    max_wait_s: float = 0.002

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {self.max_wait_s}")


class CoalescingQueue:
    """Same-key request coalescing with a max-batch / max-wait policy."""

    def __init__(
        self,
        admission: Optional[Admission] = None,
        *,
        coalesce: bool = True,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.admission = admission or Admission()
        self.coalesce = coalesce
        self.clock = clock
        # key -> FIFO of (arrival_time, item); dict preserves key arrival
        # order, so ready() drains groups oldest-first
        self._groups: Dict[Hashable, PendingQueue] = {}
        self.pushed = 0

    def push(self, key: Hashable, item, now: Optional[float] = None) -> None:
        now = self.clock() if now is None else now
        group = self._groups.get(key)
        if group is None:
            group = self._groups[key] = PendingQueue()
        group.push((now, item))
        self.pushed += 1

    def depth(self) -> int:
        return sum(len(g) for g in self._groups.values())

    def __len__(self) -> int:
        return self.depth()

    def next_deadline(self, now: Optional[float] = None) -> Optional[float]:
        """Earliest time at which some queued group hits max_wait (i.e.
        when a ``ready()`` poll would flush it); None when empty."""
        arrivals = [g.peek()[0] for g in self._groups.values() if g]
        if not arrivals:
            return None
        return min(arrivals) + self.admission.max_wait_s

    def _pop_batch(self, group: PendingQueue, count: int) -> List:
        return [group.pop()[1] for _ in range(count)]

    def ready(self, now: Optional[float] = None) -> List[Tuple[Hashable, List]]:
        """Pop and return every group the policy says to dispatch now, as
        ``(key, items)`` batches (items in arrival order). Full batches
        flush regardless of age; partial batches flush only once their
        oldest item has waited ``max_wait_s``."""
        now = self.clock() if now is None else now
        batches: List[Tuple[Hashable, List]] = []
        max_batch = self.admission.max_batch if self.coalesce else 1
        for key in list(self._groups):
            group = self._groups[key]
            while len(group) >= max_batch:
                batches.append((key, self._pop_batch(group, max_batch)))
            if group and now - group.peek()[0] >= self.admission.max_wait_s:
                batches.append((key, self._pop_batch(group, len(group))))
            if not group:
                del self._groups[key]
        return batches

    def flush(self) -> List[Tuple[Hashable, List]]:
        """Pop everything immediately (shutdown / drain), still in
        max_batch-sized groups so the executor's compile buckets hold."""
        batches: List[Tuple[Hashable, List]] = []
        max_batch = self.admission.max_batch if self.coalesce else 1
        for key in list(self._groups):
            group = self._groups[key]
            while group:
                batches.append((key, self._pop_batch(group, min(len(group), max_batch))))
            del self._groups[key]
        return batches
