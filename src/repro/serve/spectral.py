"""Spectral serving engine: many concurrent FFT-family requests, one mesh.

The benchmark stack runs one large transform at a time; serving traffic
is the opposite shape -- many small-to-medium fft/rfft/poisson/convolve/
gradient requests arriving concurrently. This module is the slot-based
:class:`~repro.serve.engine.ServeEngine` idea rebuilt for the spectral
workload, on top of the plan front-end:

- **Warm plan-cache pool** (:class:`PlanPool`): plans keyed like planner
  wisdom (shape / ndim / dtype / P / decomp / real), LRU-evicted beyond
  ``capacity``. :meth:`PlanPool.warm_from_wisdom` parses an imported
  wisdom file and pre-plans (and pre-compiles) every entry matching this
  mesh, so a warmed engine's request latency path contains **no**
  ``plan_fft`` call and no jit compile.
- **Request coalescing** (:class:`repro.serve.queue.CoalescingQueue`):
  same-key requests (same op + shape + dtype + real + lengths) batch
  into ONE stacked execution -- the batch axis is a leading dim of the
  plan's ``global_shape``, riding the existing odd-batch support. Batch
  sizes round up to power-of-two buckets (zero-padded, outputs sliced
  back per request) so the compile cache stays O(log max_batch) per
  shape. Admission is max-batch / max-wait.
- **Async dispatch**: a dispatched batch is never blocked on --
  ``jax``'s async dispatch keeps exchanges from different in-flight
  batches overlapping on device; callers get a :class:`SpectralFuture`
  and block only when (and if) they need the value.
- **Telemetry**: p50/p99 request latency, queue-wait and queue-depth
  windows (:class:`repro.runtime.monitor.LatencyWindow`), coalescing
  factor, and plan-pool hit/miss/eviction counters -- the numbers
  ``benchmarks/serve_sweep.py`` turns into the serve section of
  ``BENCH_fft.json``.
- **Fault tolerance**: per-request error isolation (a poisoned request
  in a coalesced batch is split out, retried solo under a
  :class:`repro.runtime.faults.RetryPolicy` budget, and quarantined --
  its siblings still resolve with correct numerics and its
  :meth:`SpectralFuture.result` re-raises the recorded error); a
  per-(backend, plan-key) :class:`repro.runtime.faults.CircuitBreaker`
  that degrades repeatedly-failing plan keys to the ``xla_auto``
  reference schedule and re-probes the fast path after a cool-down; and
  :meth:`SpectralEngine.remesh` for elastic re-scale after device loss
  (invalidate + re-warm the pool on the survivor mesh). Chaos is
  injected with :meth:`SpectralEngine.set_faults` (a seeded
  :class:`repro.runtime.faults.FaultPlan`), and
  ``error/retry/breaker/degraded`` counters ride ``stats()`` and
  ``metrics()``.

Request ops (all flow through any :class:`repro.core.Plan`): ``fft``,
``rfft``, ``ifft`` (c2c spectrum in the plan's own layout), ``poisson``,
``convolve``, ``correlate``, ``gradient``, ``laplacian``.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.apps import convolve as _convolve
from repro.apps import derivatives as _derivatives
from repro.apps import poisson as _poisson
from repro.core import planner as _planner
from repro.core.plan import plan_fft
from repro.runtime.faults import CircuitBreaker, RetryPolicy
from repro.runtime.monitor import LatencyWindow, StepMonitor
from repro.serve.queue import Admission, CoalescingQueue


# ---------------------------------------------------------------------------
# Request ops -- every op takes (plan, stacked operands, lengths)
# ---------------------------------------------------------------------------


def _op_fft(plan, ops, lengths):
    return plan.execute(ops[0])


def _op_ifft(plan, ops, lengths):
    return plan.inverse(ops[0])


def _op_poisson(plan, ops, lengths):
    return _poisson.solve_poisson(ops[0], plan, lengths)


def _op_convolve(plan, ops, lengths):
    return _convolve.fft_convolve(ops[0], ops[1], plan)


def _op_correlate(plan, ops, lengths):
    return _convolve.fft_correlate(ops[0], ops[1], plan)


def _op_gradient(plan, ops, lengths):
    return _derivatives.gradient(ops[0], plan, lengths)


def _op_laplacian(plan, ops, lengths):
    return _derivatives.laplacian(ops[0], plan, lengths)


#: op name -> (fn, arity). "rfft" is "fft" with a real-input check;
#: "ifft" consumes the spectrum in the plan's own forward-output layout
#: (c2c only -- a real plan's spectrum shape is not the request shape).
_OPS: Dict[str, Tuple[Callable, int]] = {
    "fft": (_op_fft, 1),
    "rfft": (_op_fft, 1),
    "ifft": (_op_ifft, 1),
    "poisson": (_op_poisson, 1),
    "convolve": (_op_convolve, 2),
    "correlate": (_op_correlate, 2),
    "gradient": (_op_gradient, 1),
    "laplacian": (_op_laplacian, 1),
}


# ---------------------------------------------------------------------------
# Plan pool
# ---------------------------------------------------------------------------


def plan_key(shape, ndim: int, dtype, p: int, decomp: str, real: bool) -> str:
    """Pool key, the same identity the planner's wisdom keys carry:
    shape (batch bucket included) / ndim / dtype / P / decomp / real."""
    dims = "x".join(str(d) for d in shape)
    return (
        f"shape={dims}|ndim={ndim}|dtype={jnp.dtype(dtype).name}|P={p}"
        f"|decomp={decomp}|real={int(real)}"
    )


class PlanPool:
    """LRU cache of warm (validated, backend-resolved, compiled) plans.

    ``get`` returns a cached plan or builds one through
    :func:`repro.core.plan_fft` (``planner="measure"`` consults/extends
    wisdom); beyond ``capacity`` the least-recently-used plan is evicted
    with its compiled executables. ``warm_from_wisdom`` pre-populates
    the pool from a wisdom file so serving starts hot."""

    def __init__(
        self,
        mesh,
        *,
        capacity: int = 32,
        planner: str = "estimate",
        plan_kwargs: Optional[dict] = None,
        faults=None,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.mesh = mesh
        self.capacity = capacity
        self.planner = planner
        #: optional FaultPlan installed on every plan the pool hands out
        #: (chaos testing); see :meth:`set_faults`
        self.faults = faults
        self.plan_kwargs = dict(plan_kwargs or {})
        self.decomp = self.plan_kwargs.get("decomp", "slab")
        self._plans: "collections.OrderedDict[str, object]" = collections.OrderedDict()
        #: key -> stage-schedule content hash of the cached plan's planned
        #: direction (Plan.schedule_hash()). Pool-side metadata only: the
        #: lookup key format above is frozen (wisdom interop), so the
        #: hash rides next to the entry instead of inside the key. Two
        #: keys with equal hashes execute the identical stage pipeline.
        self._schedule_hashes: Dict[str, str] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.plan_seconds = 0.0  # time spent planning on the request path
        self.warm_seconds = 0.0  # time spent planning/compiling at warm start
        #: decision provenance tally: Plan.selection_channel -> count of
        #: plans that entered the pool via that channel (pinned /
        #: model-argmin / measured-race / wisdom-hit / observed-overlay)
        self.channels: Dict[str, int] = {}

    # -- identity ---------------------------------------------------------
    def shards(self) -> int:
        """Shard count plans from this pool run over (P of the key)."""
        from repro.core.grid import grid_from_mesh
        from repro.core.sharding import fft_axis

        if self.decomp == "pencil":
            grid = grid_from_mesh(
                self.mesh,
                self.plan_kwargs.get("row_axis"),
                self.plan_kwargs.get("col_axis"),
            )
            return grid.size
        ax = self.plan_kwargs.get("axis_name") or fft_axis(self.mesh)
        return self.mesh.shape[ax]

    def key(self, shape, ndim: int, dtype, real: bool) -> str:
        return plan_key(shape, ndim, dtype, self.shards(), self.decomp, real)

    def __len__(self) -> int:
        return len(self._plans)

    def __contains__(self, key: str) -> bool:
        return key in self._plans

    def keys(self):
        return list(self._plans)

    # -- core -------------------------------------------------------------
    def _build(self, shape, ndim, dtype, real, backend: Optional[str] = None):
        kwargs = dict(self.plan_kwargs)
        if backend is not None:
            kwargs["backend"] = backend
            kwargs.pop("planner", None)
        else:
            kwargs.setdefault("planner", self.planner)
        return plan_fft(
            tuple(shape), self.mesh, ndim=ndim, dtype=dtype, real=real, **kwargs
        )

    def _insert(self, key: str, plan) -> None:
        if self.faults is not None:
            plan.faults = self.faults
        self._plans[key] = plan
        self._plans.move_to_end(key)
        self._schedule_hashes[key] = plan.schedule_hash()
        ch = getattr(plan, "selection_channel", "pinned")
        self.channels[ch] = self.channels.get(ch, 0) + 1
        while len(self._plans) > self.capacity:
            evicted, _ = self._plans.popitem(last=False)
            self._schedule_hashes.pop(evicted, None)
            self.evictions += 1

    def set_faults(self, faults) -> None:
        """Install (or clear, with ``None``) a fault plan on the pool
        AND retrofit it onto every already-warm plan -- warm first, then
        arm chaos, so pre-compilation itself is never poisoned."""
        self.faults = faults
        for plan in self._plans.values():
            plan.faults = faults

    def invalidate(self) -> None:
        """Drop every cached plan (and its compiled executables).
        Hit/miss history and provenance tallies are kept -- this is the
        'plans are stale' path, not a telemetry reset."""
        self._plans.clear()
        self._schedule_hashes.clear()

    def remesh(self, mesh) -> None:
        """Point the pool at a new mesh (elastic re-scale after device
        loss): cached plans bake the old mesh's shardings and P, so they
        are all invalidated; re-warm from wisdom at the new P next."""
        self.invalidate()
        self.mesh = mesh

    def schedule_hash(self, key: str) -> Optional[str]:
        """Stage-schedule hash of the pooled plan under ``key`` (None
        when the key is cold/evicted) -- the pipeline identity the pool
        serves for that problem."""
        return self._schedule_hashes.get(key)

    def schedule_hashes(self) -> Dict[str, str]:
        """Snapshot of key -> schedule hash for every warm plan. Equal
        hashes mean the pool would execute the identical stage pipeline
        for those keys (telemetry / cache-dedup analysis)."""
        return dict(self._schedule_hashes)

    def get(self, shape, ndim: int, dtype, real: bool):
        """(plan, hit): the cached plan for this problem, planning (and
        counting a miss) when cold."""
        key = self.key(shape, ndim, dtype, real)
        plan = self._plans.get(key)
        if plan is not None:
            self._plans.move_to_end(key)
            self.hits += 1
            return plan, True
        self.misses += 1
        t0 = time.perf_counter()
        plan = self._build(shape, ndim, dtype, real)
        self.plan_seconds += time.perf_counter() - t0
        self._insert(key, plan)
        return plan, False

    # -- warm start -------------------------------------------------------
    def warm(
        self,
        shape,
        ndim: int,
        dtype,
        real: bool,
        *,
        backend: Optional[str] = None,
        compile: bool = True,
    ):
        """Pre-plan one problem into the pool (pinning ``backend`` when
        given -- e.g. a wisdom entry's recorded winner, variant id
        included) and, with ``compile``, run zeros through both cached
        executables so the first real request pays neither ``plan_fft``
        nor jit."""
        key = self.key(shape, ndim, dtype, real)
        plan = self._plans.get(key)
        t0 = time.perf_counter()
        if plan is None:
            plan = self._build(shape, ndim, dtype, real, backend=backend)
            self._insert(key, plan)
        if compile:
            spec = plan.input_spec()
            x = jax.device_put(jnp.zeros(spec.shape, spec.dtype), spec.sharding)
            y = plan.execute(x)
            if plan.ndim > 1:  # 1-D large has no inverse
                y = plan.inverse(y)
            jax.block_until_ready(y)
        self.warm_seconds += time.perf_counter() - t0
        return plan

    def warm_from_wisdom(
        self, source: Optional[str] = None, *, compile: bool = True
    ) -> int:
        """Import ``source`` (path or JSON text; None = use wisdom
        already in process) and pre-plan every entry matching this
        pool's mesh, decomposition and device kind, pinned to the
        recorded winning backend. Returns the number of plans warmed;
        unparseable or mismatched entries are skipped (wisdom stays
        advisory)."""
        if source is not None:
            _planner.import_wisdom(source)
        dev = _planner.device_kind(self.mesh)
        p = self.shards()
        warmed = 0
        for key, entry in _planner.wisdom_items():
            info = _planner.parse_wisdom_key(key)
            if info is None or info["dev"] != dev or info["p"] != p:
                continue
            if info["decomp"] != self.decomp or info["direction"] != "forward":
                continue
            if info["local_impl"] != self.plan_kwargs.get("local_impl", "jnp"):
                continue
            if info["fuse_dft"] or info["transpose_back"] or info["pipeline"]:
                continue
            if self.key(info["shape"], info["ndim"], info["dtype"], info["real"]) in self:
                continue
            backend = entry.get("backend") if isinstance(entry, dict) else None
            try:
                self.warm(
                    info["shape"],
                    info["ndim"],
                    jnp.dtype(info["dtype"]),
                    info["real"],
                    backend=backend,
                    compile=compile,
                )
            except (ValueError, NotImplementedError, TypeError):
                continue  # foreign entry (other mesh axes, stale backend)
            warmed += 1
        return warmed

    def stats(self) -> Dict[str, float]:
        return {
            "plans": len(self._plans),
            "distinct_schedules": len(set(self._schedule_hashes.values())),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "plan_seconds": self.plan_seconds,
            "warm_seconds": self.warm_seconds,
            "channels": dict(self.channels),
        }


# ---------------------------------------------------------------------------
# Requests / futures
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SpectralRequest:
    op: str
    operands: Tuple
    ndim: int
    real: bool
    lengths: Optional[Tuple[float, ...]]
    submit_t: float

    @property
    def shape(self):
        return tuple(self.operands[0].shape)


class SpectralFuture:
    """Per-request handle. ``result()`` returns the (possibly still
    in-flight) output, forcing dispatch of a still-queued request by
    polling the engine at its admission deadline -- it never waits
    longer than the queue's max-wait. ``block()`` additionally waits for
    the device and records the request's end-to-end latency into the
    engine's telemetry window.

    A request that failed every retry is *quarantined*: its future
    carries the recorded exception in ``error`` and both ``result()``
    and ``block()`` re-raise it -- the failure is isolated to this
    handle; coalesced siblings resolve normally."""

    def __init__(self, engine: "SpectralEngine", request: SpectralRequest):
        self._engine = engine
        self.request = request
        self._value = None
        self._dispatched = False
        self._recorded = False
        self.dispatch_t: Optional[float] = None
        self.batch_size: Optional[int] = None
        self.pool_hit: Optional[bool] = None
        self.backend: Optional[str] = None
        self.degraded: Optional[bool] = None
        self.error: Optional[BaseException] = None

    def _resolve(
        self, value, *, dispatch_t, batch_size, pool_hit, backend, degraded=False
    ) -> None:
        self._value = value
        self._dispatched = True
        self.dispatch_t = dispatch_t
        self.batch_size = batch_size
        self.pool_hit = pool_hit
        self.backend = backend
        self.degraded = degraded

    def _reject(self, error: BaseException, *, dispatch_t) -> None:
        self.error = error
        self._dispatched = True
        self.dispatch_t = dispatch_t
        self.batch_size = 1  # quarantined requests always ran solo last

    def done(self) -> bool:
        """Dispatched (output possibly still in flight on device)."""
        return self._dispatched

    def failed(self) -> bool:
        """Quarantined: every attempt (batch, solo retries) failed."""
        return self.error is not None

    def result(self):
        while not self._dispatched:
            self._engine._force_dispatch()
        if self.error is not None:
            raise self.error
        return self._value

    def block(self):
        while not self._dispatched:
            self._engine._force_dispatch()
        if self.error is not None:
            if not self._recorded:
                self._recorded = True
                self._engine._record_completion(self, failed=True)
            raise self.error
        value = self._value
        jax.block_until_ready(value)
        if not self._recorded:
            self._recorded = True
            self._engine._record_completion(self)
        return value


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class SpectralEngine:
    """Queue -> coalescer -> plan pool -> async dispatch.

    Single-threaded and cooperative, like :class:`ServeEngine`: callers
    ``submit`` (full batches dispatch inline), a driver loop ``poll``\\ s
    to flush partially-filled batches past their max-wait, and
    ``drain()`` flushes + blocks everything. The device-side overlap
    between in-flight batches comes from jax's async dispatch -- the
    engine never blocks on a batch it launched.
    """

    def __init__(
        self,
        mesh,
        *,
        max_batch: int = 8,
        max_wait_s: float = 0.002,
        coalesce: bool = True,
        capacity: int = 32,
        planner: str = "estimate",
        plan_kwargs: Optional[dict] = None,
        wisdom: Optional[str] = None,
        warm_compile: bool = True,
        clock: Callable[[], float] = time.monotonic,
        window: int = 2048,
        faults=None,
        retry: Optional[RetryPolicy] = None,
        breaker: Optional[CircuitBreaker] = None,
    ):
        self.mesh = mesh
        self.max_batch = max_batch
        self.coalesce = coalesce
        self._clock = clock
        self.pool = PlanPool(
            mesh, capacity=capacity, planner=planner, plan_kwargs=plan_kwargs
        )
        self.queue = CoalescingQueue(
            Admission(max_batch=max_batch, max_wait_s=max_wait_s),
            coalesce=coalesce,
            clock=clock,
        )
        self.retry = retry if retry is not None else RetryPolicy()
        self.breaker = breaker if breaker is not None else CircuitBreaker(clock=clock)
        self.faults = None
        #: pool_key -> xla_auto reference plan, the degradation target a
        #: tripped breaker routes that key's traffic through
        self._degraded: Dict[str, object] = {}
        self._window_len = window
        self.reset_stats()
        self._outstanding: List[SpectralFuture] = []
        if wisdom is not None:
            self.warm_start(wisdom, compile=warm_compile)
        if faults is not None:
            # armed AFTER any warm start so pre-compilation is never
            # poisoned; chaos begins with the first real request
            self.set_faults(faults)

    def reset_stats(self) -> None:
        """Zero the telemetry windows and counters (the plan pool and
        its hit/miss history are kept) -- e.g. between benchmark
        measurement windows. This is the ``reset()`` escape hatch for
        the default-on dispatch telemetry."""
        w = self._window_len
        self.latency = LatencyWindow(w)  # submit -> device-done (blocked)
        self.queue_wait = LatencyWindow(w)  # submit -> dispatch
        self.queue_depth = LatencyWindow(w)  # sampled at each submit
        self.batch_sizes = LatencyWindow(w)
        # host-side dispatch breakdown, one window per pipeline stage:
        # plan-pool lookup / operand stack+pad+placement / async launch
        self.stage_windows: Dict[str, LatencyWindow] = {
            name: LatencyWindow(w) for name in ("pool", "stack", "execute")
        }
        # straggler detection over dispatches; flagged dispatches name
        # the slowest stage above as their culprit
        self.dispatch_monitor = StepMonitor(history_limit=w)
        self.requests = 0
        self.batches = 0
        self.padded = 0  # zero-pad rows added to fill buckets
        # fault-tolerance counters (see module docstring)
        self.errors = 0  # failed batch executions, retries included
        self.retries = 0  # solo re-attempts under the retry policy
        self.batch_splits = 0  # poisoned batches split into solo retries
        self.quarantined = 0  # requests that exhausted every attempt
        self.failed_requests = 0  # quarantined futures observed via block()
        self.degraded_dispatches = 0  # dispatches routed to xla_auto

    # -- warm start -------------------------------------------------------
    def warm_start(self, source: Optional[str] = None, *, compile: bool = True) -> int:
        """Pre-plan every wisdom entry matching this mesh (see
        :meth:`PlanPool.warm_from_wisdom`), for each hot shape warming
        all power-of-two batch buckets the coalescer can produce --
        a steady-state request then never sees ``plan_fft`` or jit."""
        warmed = self.pool.warm_from_wisdom(source, compile=compile)
        # wisdom shapes are batched global shapes; extend each to the
        # full bucket ladder so partial batches of the same shape are
        # warm too (a (8, n, n) entry warms (1|2|4, n, n) as well)
        for key in self.pool.keys():
            plan = self.pool._plans[key]
            shape = plan.global_shape
            if len(shape) <= plan.ndim or shape[0] not in self._buckets():
                continue
            for bucket in self._buckets():
                if bucket == shape[0]:
                    continue
                try:
                    self.pool.warm(
                        (bucket,) + shape[1:], plan.ndim, plan.dtype, plan.real,
                        backend=plan.backend, compile=compile,
                    )
                    warmed += 1
                except (ValueError, NotImplementedError):
                    continue
        return warmed

    # -- fault tolerance --------------------------------------------------
    def set_faults(self, faults) -> None:
        """Arm (or, with ``None``, disarm) a
        :class:`repro.runtime.faults.FaultPlan` on every plan the engine
        executes -- pooled, future, and degraded alike. Call after
        :meth:`warm_start` so warm-up itself is never poisoned; which
        stages actually fire is the plan's ``match`` business (the
        ``xla_auto`` degradation path runs under a ``global:<kind>``
        label, so ``match="Exchange"`` chaos leaves it healthy)."""
        self.faults = faults
        self.pool.set_faults(faults)
        for plan in self._degraded.values():
            plan.faults = faults

    def remesh(self, mesh, *, wisdom: Optional[str] = None, warm: bool = True,
               compile: bool = True) -> int:
        """Elastic re-scale: point the engine at a new (typically
        smaller, post-device-loss) mesh. Flushes anything queued against
        the old mesh, invalidates every pooled plan (they bake the old
        shardings and P), drops the degraded-plan cache, resets the
        circuit breaker (its keys embed the old P), and -- with ``warm``
        -- re-warms the pool from wisdom at the new P (``wisdom`` may
        name a file; ``None`` uses wisdom already in process). Returns
        the number of plans warmed."""
        self.flush()
        self.mesh = mesh
        self.pool.remesh(mesh)
        self._degraded.clear()
        self.breaker.reset()
        if warm:
            return self.warm_start(wisdom, compile=compile)
        return 0

    def _buckets(self) -> List[int]:
        out, b = [], 1
        while b < self.max_batch:
            out.append(b)
            b <<= 1
        out.append(self.max_batch)
        return out

    def _bucket(self, k: int) -> int:
        b = 1
        while b < k:
            b <<= 1
        return min(b, self.max_batch)

    # -- submission -------------------------------------------------------
    def submit(
        self,
        op: str,
        x,
        y=None,
        *,
        ndim: int = 2,
        lengths: Optional[Sequence[float]] = None,
    ) -> SpectralFuture:
        """Enqueue one request; returns its future immediately. Any
        coalesced batch the submission completes dispatches inline (no
        blocking); partially-filled batches wait for more same-key
        requests or the admission max-wait (see :meth:`poll`)."""
        if op not in _OPS:
            raise ValueError(f"unknown op {op!r}; serving ops: {sorted(_OPS)}")
        fn, arity = _OPS[op]
        if ndim not in (2, 3):
            raise ValueError(f"serving covers ndim 2 or 3, got {ndim}")
        x = jnp.asarray(x)
        if x.ndim < ndim:
            raise ValueError(f"op {op!r} input rank {x.ndim} < ndim={ndim}")
        real = x.dtype.kind == "f"
        if op == "rfft" and not real:
            raise ValueError(
                f"rfft takes a real input, got dtype {x.dtype.name} (use op='fft')"
            )
        if op == "ifft" and real:
            raise ValueError(
                "ifft consumes a c2c spectrum (complex); real inverse "
                "transforms round-trip through the same future's plan"
            )
        operands = (x,)
        if arity == 2:
            if y is None:
                raise ValueError(f"op {op!r} takes two operands (pass y=)")
            y = jnp.asarray(y)
            if y.shape != x.shape or y.dtype != x.dtype:
                raise ValueError(
                    f"op {op!r} operands must match: {x.shape}/{x.dtype.name} "
                    f"vs {y.shape}/{y.dtype.name}"
                )
            operands = (x, y)
        elif y is not None:
            raise ValueError(f"op {op!r} takes one operand")
        lengths = None if lengths is None else tuple(float(v) for v in lengths)
        now = self._clock()
        req = SpectralRequest(op, operands, ndim, real, lengths, now)
        fut = SpectralFuture(self, req)
        key = (op, tuple(x.shape), x.dtype.name, ndim, real, lengths)
        self.queue.push(key, fut, now=now)
        self.requests += 1
        self._outstanding.append(fut)
        self.queue_depth.record(self.queue.depth())
        self._dispatch_batches(self.queue.ready(now))  # full batches only
        return fut

    # -- pumping ----------------------------------------------------------
    def poll(self, now: Optional[float] = None) -> int:
        """Dispatch every batch the admission policy has made ready
        (full batches plus max-wait-expired partials); returns the
        number of batches dispatched."""
        return self._dispatch_batches(self.queue.ready(now))

    def flush(self) -> int:
        """Dispatch everything queued, policy or not."""
        return self._dispatch_batches(self.queue.flush())

    def drain(self, *, raise_errors: bool = False) -> None:
        """Flush the queue and block until every outstanding request's
        output is on device (recording latencies, in submission order).
        Quarantined futures do not abort the drain: their failures are
        counted (``failed_requests``) and, with ``raise_errors``, the
        first one re-raises after every sibling has been blocked."""
        self.flush()
        first: Optional[BaseException] = None
        for fut in list(self._outstanding):
            try:
                fut.block()
            except Exception as e:  # noqa: BLE001 -- keep draining siblings
                if first is None:
                    first = e
        if first is not None and raise_errors:
            raise first

    def _force_dispatch(self) -> None:
        """A caller is blocked on a queued future: advance the clock to
        the queue's admission deadline (the max-wait flush that would
        happen anyway) instead of sleeping for it."""
        now = self._clock()
        deadline = self.queue.next_deadline(now)
        if deadline is None or not self._dispatch_batches(
            self.queue.ready(max(now, deadline))
        ):
            self.flush()  # defensive: never spin on a stuck queue

    # -- dispatch ---------------------------------------------------------
    def _plan_shape(self, op: str, shape: Tuple[int, ...], ndim: int) -> Tuple[int, ...]:
        """The *planned* (data-side) shape behind a request: identical to
        the request shape except for ``ifft``, whose input is a spectrum
        in the plan's own forward-output layout -- slab fft2 without
        transpose_back is transposed, pencil fft3 without transpose_back
        is axis-reversed -- so the trailing dims map back accordingly.
        (``decomp="auto"`` pools are treated as slab here; pin the
        decomposition when serving non-square inverse traffic.)"""
        if op != "ifft":
            return shape
        trail = shape[-ndim:]
        tb = self.pool.plan_kwargs.get("transpose_back", False)
        if self.pool.decomp == "pencil":
            if ndim == 3 and not tb:
                trail = trail[::-1]
        elif ndim == 2 and not tb:
            trail = (trail[1], trail[0])
        return shape[:-ndim] + trail

    def _dispatch_batches(self, batches) -> int:
        for key, futs in batches:
            self._dispatch(key, futs)
        return len(batches)

    def _dispatch(self, key, futs: List[SpectralFuture]) -> None:
        """Failure-isolation wrapper around :meth:`_execute_batch`: a
        batch that raises is split into solo dispatches (one poisoned
        request must not take its coalesced siblings down); a solo
        request that raises is retried under the engine's
        :class:`RetryPolicy` budget and finally quarantined -- its
        future records the error, nothing propagates to the caller's
        submit/poll path."""
        try:
            self._execute_batch(key, futs)
            return
        except Exception as e:  # noqa: BLE001 -- per-request isolation boundary
            self.errors += 1
            err = e
        if len(futs) > 1:
            self.batch_splits += 1
            for fut in futs:
                self._dispatch(key, [fut])
            return
        t0 = self._clock()
        attempt = 0
        while (
            attempt < self.retry.max_retries
            and self._clock() - t0 <= self.retry.deadline_s
        ):
            attempt += 1
            self.retries += 1
            try:
                self._execute_batch(key, futs)
                return
            except Exception as e:  # noqa: BLE001
                self.errors += 1
                err = e
        self.quarantined += 1
        now = self._clock()
        futs[0]._reject(err, dispatch_t=now)
        self.queue_wait.record(now - futs[0].request.submit_t)

    def _degraded_plan(self, pool_key: str, shape, ndim, dtype, real):
        """The ``xla_auto`` (GSPMD reference schedule) plan a tripped
        breaker degrades ``pool_key``'s traffic to -- cached outside the
        LRU pool so degradation never evicts healthy plans."""
        plan = self._degraded.get(pool_key)
        if plan is None:
            plan = self.pool._build(shape, ndim, dtype, real, backend="xla_auto")
            if self.faults is not None:
                plan.faults = self.faults
            self._degraded[pool_key] = plan
        return plan

    def _execute_batch(self, key, futs: List[SpectralFuture]) -> None:
        op = key[0]
        fn, arity = _OPS[op]
        req0 = futs[0].request
        shape, ndim, real, lengths = req0.shape, req0.ndim, req0.real, req0.lengths
        k = len(futs)
        bucket = self._bucket(k)
        self.dispatch_monitor.start()
        t0 = self._clock()
        plan_shape = (bucket,) + self._plan_shape(op, shape, ndim)
        dtype = req0.operands[0].dtype
        plan, hit = self.pool.get(plan_shape, ndim, dtype, real)
        pool_key = self.pool.key(plan_shape, ndim, dtype, real)
        bkey = (plan.backend, pool_key)
        degraded = False
        if not self.breaker.allow(bkey):
            plan = self._degraded_plan(pool_key, plan_shape, ndim, dtype, real)
            self.degraded_dispatches += 1
            degraded = True
        t_pool = self._clock()
        sharding = plan.input_sharding(opposite=(op == "ifft"))
        stacked = []
        for j in range(arity):
            block = jnp.stack([f.request.operands[j] for f in futs])
            if bucket > k:
                block = jnp.concatenate(
                    [block, jnp.zeros((bucket - k,) + shape, block.dtype)]
                )
            stacked.append(jax.device_put(block, sharding))
        t_stack = self._clock()
        try:
            out = fn(plan, tuple(stacked), lengths)  # async launch, not device time
        except Exception:
            # injected/armed faults surface synchronously here; only the
            # fast path feeds the breaker -- a failing degraded dispatch
            # must not re-open a breaker that already tripped
            if not degraded:
                self.breaker.record_failure(bkey)
            raise
        if not degraded:
            self.breaker.record_success(bkey)
        self.padded += bucket - k
        now = self._clock()
        spans = [
            ("pool", t_pool - t0), ("stack", t_stack - t_pool),
            ("execute", now - t_stack),
        ]
        for name, dt in spans:
            self.stage_windows[name].record(dt)
        self.dispatch_monitor.stop(tokens=k, spans=spans)
        self.batches += 1
        self.batch_sizes.record(k)
        for i, fut in enumerate(futs):
            value = (
                tuple(o[i] for o in out) if isinstance(out, tuple) else out[i]
            )
            fut._resolve(
                value,
                dispatch_t=now,
                batch_size=k,
                pool_hit=hit,
                backend=plan.backend,
                degraded=degraded,
            )
            self.queue_wait.record(now - fut.request.submit_t)

    # -- telemetry --------------------------------------------------------
    def _record_completion(self, fut: SpectralFuture, *, failed: bool = False) -> None:
        if failed:
            self.failed_requests += 1
        else:
            self.latency.record(self._clock() - fut.request.submit_t)
        try:
            self._outstanding.remove(fut)
        except ValueError:
            pass

    def stats(self) -> dict:
        """Serving telemetry snapshot: request latency percentiles (over
        blocked completions), queue wait/depth, coalescing factor, and
        plan-pool counters."""
        dispatched = int(self.batch_sizes.total)
        return {
            "requests": self.requests,
            "completed": self.latency.count,
            "batches": self.batches,
            "mean_batch": (dispatched / self.batches) if self.batches else 0.0,
            "padded": self.padded,
            "latency_s": self.latency.summary((50, 90, 99)),
            "queue_wait_s": self.queue_wait.summary((50, 90, 99)),
            "queue_depth": self.queue_depth.summary((50, 99)),
            "stages_s": {
                name: w.summary((50, 99)) for name, w in self.stage_windows.items()
            },
            "dispatch": self.dispatch_monitor.straggler_report(),
            "pool": self.pool.stats(),
            "faults": {
                "errors": self.errors,
                "retries": self.retries,
                "batch_splits": self.batch_splits,
                "quarantined": self.quarantined,
                "failed_requests": self.failed_requests,
                "degraded_dispatches": self.degraded_dispatches,
                "breaker": self.breaker.stats(),
            },
        }

    def metrics(self) -> dict:
        """Flat scalar gauge/counter mapping for scraping (one number
        per key -- Prometheus-shaped, unlike the nested :meth:`stats`):
        live queue depth, request/batch counters, latency and queue-wait
        percentiles, per-dispatch-stage p50s, plan-pool hit/miss/eviction
        counters, and the dispatch straggler telemetry. Culprit
        attribution rides ``dispatch_culprit_<stage>`` counters; planner
        decision provenance rides ``plan_channel_<channel>`` counters
        (how many pooled plans each selection channel produced) plus a
        ``wisdom_stale`` gauge (entries whose observed timings drifted
        from their recorded race)."""
        pool = self.pool.stats()
        lat = self.latency.percentiles((50, 99))
        wait = self.queue_wait.percentiles((50, 99))
        report = self.dispatch_monitor.straggler_report()
        out = {
            "requests": self.requests,
            "completed": self.latency.count,
            "batches": self.batches,
            "padded": self.padded,
            "queue_depth": self.queue.depth(),
            "queue_depth_p99": self.queue_depth.percentiles((99,))["p99"],
            "latency_p50_s": lat["p50"],
            "latency_p99_s": lat["p99"],
            "queue_wait_p50_s": wait["p50"],
            "queue_wait_p99_s": wait["p99"],
            "pool_hits": pool["hits"],
            "pool_misses": pool["misses"],
            "pool_evictions": pool["evictions"],
            "dispatch_steps": report["steps"],
            "dispatch_flagged": report["flagged"],
        }
        for name, w in self.stage_windows.items():
            out[f"dispatch_{name}_p50_s"] = w.percentiles((50,))["p50"]
        for name, count in report["culprits"].items():
            out[f"dispatch_culprit_{name}"] = count
        for name, count in sorted(pool["channels"].items()):
            out[f"plan_channel_{name.replace('-', '_')}"] = count
        out["wisdom_stale"] = sum(
            1 for row in _planner.wisdom_report() if row["stale"]
        )
        # fault-tolerance counters: errors/retries on dispatch, batch
        # isolation splits, quarantines, degraded (xla_auto) dispatches,
        # and the circuit breaker's state/transition gauges
        out["errors"] = self.errors
        out["retries"] = self.retries
        out["batch_splits"] = self.batch_splits
        out["quarantined"] = self.quarantined
        out["failed_requests"] = self.failed_requests
        out["degraded_dispatches"] = self.degraded_dispatches
        for name, v in self.breaker.stats().items():
            out[f"breaker_{name}"] = v
        return out
