"""Benchmark history ledger + noise-aware regression detection.

The paper's claims are *comparative* -- parcelport timings tracked
across backends, node counts, and runs (Figs. 4-6) -- but a single
``BENCH_fft.json`` snapshot that each run overwrites cannot show a
trajectory. This module makes performance legible over time:

- :func:`snapshot_from_bench` reduces one BENCH document (the
  ``{"schema", "meta", "rows"}`` JSON ``benchmarks/run.py --json``
  writes) to a compact snapshot record: commit, device kind, timestamp,
  the planner-accuracy score, and one scalar metric per
  ``section|config|metric`` key (:func:`row_metrics`);
- :func:`append_snapshot` appends it to an append-only JSONL ledger
  (``BENCH_history.jsonl``); :func:`read_history` loads the ledger,
  skipping malformed lines (the ledger is advisory telemetry -- a
  corrupt line must never brick the gate);
- :func:`detect_regressions` compares a new snapshot to the rolling
  median/MAD of the last K snapshots per key -- noise-aware: a value
  flags only when it exceeds BOTH the median by ``nsig`` robust sigmas
  (1.4826 * MAD) AND a relative floor (``min_ratio`` x median), so
  MAD-level jitter never trips the gate and a genuine 2x slowdown
  always does. A fresh ledger with fewer than ``min_snapshots`` prior
  points per key never false-fails (``benchmarks/regress.py`` is the
  CLI over this).

Keys are stable across runs by construction: they are derived from the
row's identifying fields (bench section, problem size, shard count,
decomposition, backend/variant, transform kind, serve load point), not
from row order.
"""

from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List, Optional, Tuple

HISTORY_SCHEMA = 1

#: Metrics tracked per row kind. Direction "min" = lower is better
#: (regression = value rose); "max" = higher is better (tps).
_METRIC_DIRECTIONS = {
    "measured_us": "min",
    "p50_us": "min",
    "p99_us": "min",
    "warm_first_us": "min",
    "steady_p50_us": "min",
    "tps": "max",
}


def metric_direction(metric: str) -> str:
    return _METRIC_DIRECTIONS.get(metric, "min")


def _config_of(row: dict) -> Optional[str]:
    """Stable config string identifying one row within its section (the
    same identity ``benchmarks/planner_score.py`` groups races by, plus
    the backend/variant and the sweep knobs). None = untracked row."""
    bench = row.get("bench")
    if bench in ("fft2", "fft3_decomp", "real"):
        parts = [f"n{row.get('n')}", f"p{row.get('p')}"]
        if row.get("decomp"):
            parts.append(str(row["decomp"]))
        if row.get("grid"):
            parts.append(str(row["grid"]))
        if row.get("transform"):
            parts.append(str(row["transform"]))
        parts.append(str(row.get("backend")))
        return ",".join(parts)
    if bench == "overlap":
        fused = row.get("fused")
        tag = "fused" if fused else "unfused"
        if fused and row.get("n_chunks"):
            tag = f"fused{row['n_chunks']}"
        return f"{row.get('config')},{row.get('backend')},{tag}"
    if bench == "serve":
        kind = row.get("row")
        if kind == "load_sweep":
            return (
                f"load_sweep,n{row.get('n')},p{row.get('p')},{row.get('op')},"
                f"coalesce={int(bool(row.get('coalesce')))},load{row.get('load')}"
            )
        if kind == "warm_start":
            return f"warm_start,n{row.get('n')},p{row.get('p')},{row.get('op')}"
    return None


def _row_metric_names(row: dict) -> Tuple[str, ...]:
    if row.get("bench") == "serve":
        if row.get("row") == "load_sweep":
            return ("p50_us", "p99_us", "tps")
        return ("warm_first_us", "steady_p50_us")
    return ("measured_us",)


def row_metrics(row: dict) -> List[Tuple[str, float]]:
    """``[(key, value), ...]`` scalars one bench row contributes to the
    trajectory; key format ``section|config|metric``."""
    if not isinstance(row, dict):
        return []
    config = _config_of(row)
    if config is None:
        return []
    out = []
    for metric in _row_metric_names(row):
        v = row.get(metric)
        if isinstance(v, (int, float)) and v > 0:
            out.append((f"{row['bench']}|{config}|{metric}", float(v)))
    return out


def split_key(key: str) -> Tuple[str, str, str]:
    """Inverse of the key format: ``(section, config, metric)``."""
    section, _, rest = key.partition("|")
    config, _, metric = rest.rpartition("|")
    return section, config, metric


def snapshot_from_bench(
    doc: dict,
    *,
    commit: Optional[str] = None,
    device_kind: Optional[str] = None,
    timestamp: Optional[str] = None,
) -> dict:
    """Reduce one BENCH document to a ledger snapshot. ``commit`` /
    ``device_kind`` / ``timestamp`` default to the document's own meta
    fields (``run.py --json`` stamps them); pass explicitly to override."""
    meta = doc.get("meta") if isinstance(doc, dict) else None
    meta = meta if isinstance(meta, dict) else {}
    rows = doc.get("rows") if isinstance(doc, dict) else None
    rows = rows if isinstance(rows, list) else []
    metrics: Dict[str, float] = {}
    sections: Dict[str, int] = {}
    for row in rows:
        for key, value in row_metrics(row):
            metrics[key] = value
        if isinstance(row, dict) and isinstance(row.get("bench"), str):
            sections[row["bench"]] = sections.get(row["bench"], 0) + 1
    snap = {
        "schema": HISTORY_SCHEMA,
        "commit": commit or meta.get("commit") or "unknown",
        "device_kind": device_kind or meta.get("device_kind") or "unknown",
        "timestamp": timestamp or meta.get("timestamp") or "unknown",
        "sections": sections,
        "metrics": metrics,
    }
    score = meta.get("planner_score")
    if isinstance(score, dict):
        snap["planner_score"] = score
    return snap


# ---------------------------------------------------------------------------
# Ledger IO (append-only JSONL)
# ---------------------------------------------------------------------------


def append_snapshot(path: str, snap: dict) -> None:
    """Append one snapshot as a JSONL line. Append-only by design --
    history is immutable; a bad run is diagnosed, not erased."""
    line = json.dumps(snap, sort_keys=True)
    with open(path, "a") as f:
        f.write(line + "\n")


def read_history(path: str) -> List[dict]:
    """Load the ledger, oldest first. Malformed lines are skipped (the
    ledger is advisory -- same contract as the wisdom store); a missing
    file is an empty history, which the min-snapshots guard handles."""
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                snap = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(snap, dict) and isinstance(snap.get("metrics"), dict):
                out.append(snap)
    return out


# ---------------------------------------------------------------------------
# Noise-aware regression detection (rolling median / MAD)
# ---------------------------------------------------------------------------

#: MAD -> sigma for a normal distribution.
MAD_SIGMA = 1.4826


def _median(values: List[float]) -> float:
    vs = sorted(values)
    n = len(vs)
    mid = n // 2
    return vs[mid] if n % 2 else 0.5 * (vs[mid - 1] + vs[mid])


def _mad(values: List[float], med: float) -> float:
    return _median([abs(v - med) for v in values])


def history_values(history: Iterable[dict], key: str, *, k: int = 8) -> List[float]:
    """The last ``k`` recorded values for one metric key, oldest first."""
    vals = []
    for snap in history:
        v = snap.get("metrics", {}).get(key)
        if isinstance(v, (int, float)) and v > 0:
            vals.append(float(v))
    return vals[-k:]


def detect_regressions(
    history: List[dict],
    snap: dict,
    *,
    k: int = 8,
    min_snapshots: int = 3,
    nsig: float = 4.0,
    min_ratio: float = 1.5,
) -> List[dict]:
    """Findings for every metric of ``snap`` that regressed against the
    rolling median/MAD of its last ``k`` historical values.

    A time-like metric (direction "min") flags when
    ``value > median + max(nsig * MAD_SIGMA * mad, (min_ratio-1) * median)``
    -- i.e. it must clear BOTH the robust noise band and a relative
    floor; a throughput metric ("max") mirrors the test downward. Keys
    with fewer than ``min_snapshots`` historical points are skipped (the
    fresh-ledger guard). Returns findings sorted worst-ratio first."""
    findings = []
    for key, value in sorted(snap.get("metrics", {}).items()):
        vals = history_values(history, key, k=k)
        if len(vals) < min_snapshots:
            continue
        med = _median(vals)
        if med <= 0:
            continue
        mad = _mad(vals, med)
        band = max(nsig * MAD_SIGMA * mad, (min_ratio - 1.0) * med)
        section, config, metric = split_key(key)
        direction = metric_direction(metric)
        if direction == "max":
            regressed = value < med - band
            ratio = med / value if value > 0 else float("inf")
        else:
            regressed = value > med + band
            ratio = value / med
        if regressed:
            findings.append(
                {
                    "key": key,
                    "section": section,
                    "config": config,
                    "metric": metric,
                    "value": value,
                    "median": med,
                    "mad": mad,
                    "ratio": ratio,
                    "n": len(vals),
                }
            )
    findings.sort(key=lambda f: -f["ratio"])
    return findings
