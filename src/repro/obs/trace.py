"""Tracing primitives for the stage-schedule executor -- the repo's
APEX analogue.

The paper's breakdown (communication vs local FFT compute, per
parcelport) is a *timeline* result: HPX ships task-level instrumentation
(APEX) that stamps wall-clock spans around every task so cost can be
attributed to the operation that incurred it. Our tasks are the Stage
records of the schedule IR, so the tracer is deliberately tiny: a
:class:`TraceRecorder` collects :class:`Span` records (name + wall-clock
start/duration + free-form ``args``) and counter samples, and exports
them as Chrome-trace JSON (loadable in ``chrome://tracing`` or
https://ui.perfetto.dev) or as one-JSON-object-per-line JSONL for
machine consumption.

Producers:

- ``run_schedule(..., trace=rec)`` stamps one span per schedule stage
  (per-Exchange spans carry backend/role/wire bytes -- see
  :mod:`repro.core.schedule`);
- ``Plan.profile`` aggregates those spans into an observed-vs-predicted
  per-stage table;
- ``benchmarks/run.py --trace out.json`` merges per-section and
  per-subprocess traces into one artifact (:func:`TraceRecorder.adopt`
  re-homes foreign events under their own pid row).

Consumers: ``CommParams.refine_online`` (alpha/beta re-fit from observed
exchange spans), ``planner.record_observed`` (wisdom observed-timings
channel) and ``StepMonitor`` (straggler culprit attribution).

Timestamps come from an injectable monotonic clock (seconds); exports
convert to the microseconds Chrome-trace expects. Span ``ts`` are
relative to the recorder's creation, so merged traces from different
processes line up per-pid rather than pretending to share a clock.
"""

from __future__ import annotations

import dataclasses
import json
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional


@dataclasses.dataclass
class Span:
    """One completed wall-clock interval. ``t0``/``dur`` are seconds
    (``t0`` relative to the recorder's epoch); ``cat`` groups spans for
    filtering (``"exchange"`` marks collective stages); ``args`` is the
    free-form attribute payload shown in the trace viewer."""

    name: str
    t0: float
    dur: float
    cat: str = "stage"
    pid: int = 0
    tid: int = 0
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_chrome(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "ph": "X",
            "ts": self.t0 * 1e6,
            "dur": self.dur * 1e6,
            "pid": self.pid,
            "tid": self.tid,
            "cat": self.cat,
            "args": dict(self.args),
        }


@dataclasses.dataclass
class CounterSample:
    """One counter sample (Chrome-trace ``ph:"C"``): ``values`` maps
    series name -> number, plotted as a stacked area per counter name."""

    name: str
    t: float
    values: Dict[str, float]
    pid: int = 0

    def to_chrome(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "ph": "C",
            "ts": self.t * 1e6,
            "pid": self.pid,
            "tid": 0,
            "args": dict(self.values),
        }


class TraceRecorder:
    """Collects spans + counters; exports Chrome-trace JSON and JSONL.

    The clock is injectable (tests pass a fake); production uses
    ``time.perf_counter``. Recording is append-only and cheap (one
    dataclass per span) so it can stay on in serving paths.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None, *, pid: int = 0):
        self._clock = clock or time.perf_counter
        self._epoch = self._clock()
        self.pid = pid
        self.spans: List[Span] = []
        self.counters: List[CounterSample] = []
        self._process_names: Dict[int, str] = {}
        self._adopted: List[Dict[str, Any]] = []

    # -- recording ---------------------------------------------------------
    def now(self) -> float:
        """Seconds since the recorder was created."""
        return self._clock() - self._epoch

    @contextmanager
    def span(self, name: str, *, cat: str = "stage", tid: int = 0, **args) -> Iterator[Span]:
        """Context manager stamping one span around the enclosed work.
        Extra keyword arguments become the span's ``args``; the yielded
        span may be annotated further before the block exits."""
        sp = Span(name=name, t0=self.now(), dur=0.0, cat=cat, pid=self.pid, tid=tid, args=args)
        try:
            yield sp
        finally:
            sp.dur = self.now() - sp.t0
            self.spans.append(sp)

    def add_span(
        self,
        name: str,
        t0: float,
        dur: float,
        *,
        cat: str = "stage",
        tid: int = 0,
        args: Optional[Dict[str, Any]] = None,
    ) -> Span:
        """Record an already-timed interval (``t0`` in recorder-relative
        seconds, e.g. from :meth:`now`)."""
        sp = Span(name=name, t0=t0, dur=dur, cat=cat, pid=self.pid, tid=tid, args=dict(args or {}))
        self.spans.append(sp)
        return sp

    def counter(self, name: str, **values: float) -> CounterSample:
        c = CounterSample(name=name, t=self.now(), values=dict(values), pid=self.pid)
        self.counters.append(c)
        return c

    # -- queries -----------------------------------------------------------
    def mark(self) -> int:
        """Bookmark for :meth:`spans_since` (e.g. per serve dispatch)."""
        return len(self.spans)

    def spans_since(self, mark: int) -> List[Span]:
        return self.spans[mark:]

    def exchange_spans(self) -> List[Span]:
        """The collective-stage spans (``cat == "exchange"``) -- what
        ``CommParams.refine_online`` fits against."""
        return [s for s in self.spans if s.cat == "exchange"]

    def total_seconds(self) -> float:
        return sum(s.dur for s in self.spans)

    # -- merging -----------------------------------------------------------
    def set_process_name(self, pid: int, name: str) -> None:
        self._process_names[pid] = name

    def adopt(
        self,
        events: Iterable[Dict[str, Any]],
        *,
        pid: Optional[int] = None,
        name: Optional[str] = None,
    ) -> None:
        """Fold pre-exported Chrome events (e.g. printed by a benchmark
        subprocess) into this recorder under their own pid row. Events
        keep their source-relative timestamps -- different processes do
        not share a clock, so rows line up per-pid, not globally."""
        events = list(events)
        if pid is None:
            used = {e.get("pid", 0) for e in self._adopted} | {s.pid for s in self.spans}
            used.add(self.pid)
            pid = max(used) + 1
        for e in events:
            e = dict(e)
            e["pid"] = pid
            self._adopted.append(e)
        if name is not None:
            self.set_process_name(pid, name)

    def merge(self, other: "TraceRecorder", *, pid: Optional[int] = None, name: Optional[str] = None) -> None:
        self.adopt(other._chrome_events(), pid=pid, name=name)

    # -- exports -----------------------------------------------------------
    def _chrome_events(self) -> List[Dict[str, Any]]:
        return [s.to_chrome() for s in self.spans] + [c.to_chrome() for c in self.counters]

    def to_chrome_trace(self) -> Dict[str, Any]:
        """The ``chrome://tracing`` / Perfetto JSON object."""
        events = self._chrome_events() + list(self._adopted)
        for pid, pname in sorted(self._process_names.items()):
            events.append({
                "name": "process_name",
                "ph": "M",
                "pid": pid,
                "tid": 0,
                "args": {"name": pname},
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)

    def to_jsonl(self) -> str:
        """One JSON object per line: spans (``{"kind": "span", ...}``
        with seconds-valued ``t0``/``dur``) then counters."""
        lines = []
        for s in self.spans:
            lines.append(json.dumps({
                "kind": "span", "name": s.name, "cat": s.cat, "t0": s.t0,
                "dur": s.dur, "pid": s.pid, "tid": s.tid, "args": s.args,
            }))
        for c in self.counters:
            lines.append(json.dumps({
                "kind": "counter", "name": c.name, "t": c.t,
                "pid": c.pid, "values": c.values,
            }))
        return "\n".join(lines) + ("\n" if lines else "")

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())

    @classmethod
    def from_jsonl(cls, path: str) -> "TraceRecorder":
        rec = cls()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                d = json.loads(line)
                if d.get("kind") == "span":
                    rec.spans.append(Span(
                        name=d["name"], t0=d["t0"], dur=d["dur"],
                        cat=d.get("cat", "stage"), pid=d.get("pid", 0),
                        tid=d.get("tid", 0), args=d.get("args", {}),
                    ))
                elif d.get("kind") == "counter":
                    rec.counters.append(CounterSample(
                        name=d["name"], t=d["t"], values=d.get("values", {}),
                        pid=d.get("pid", 0),
                    ))
        return rec


def merge_traces(recorders: Iterable[TraceRecorder], names: Optional[Iterable[str]] = None) -> TraceRecorder:
    """Merge recorders into a fresh one, one pid row each."""
    out = TraceRecorder()
    names = list(names) if names is not None else None
    for i, rec in enumerate(recorders):
        label = names[i] if names and i < len(names) else None
        out.merge(rec, pid=i + 1, name=label)
    return out
