from repro.obs.trace import Span, TraceRecorder, merge_traces

__all__ = ["Span", "TraceRecorder", "merge_traces"]
