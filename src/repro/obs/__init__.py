from repro.obs.history import (
    append_snapshot,
    detect_regressions,
    read_history,
    snapshot_from_bench,
)
from repro.obs.trace import Span, TraceRecorder, merge_traces

__all__ = [
    "Span",
    "TraceRecorder",
    "merge_traces",
    "snapshot_from_bench",
    "append_snapshot",
    "read_history",
    "detect_regressions",
]
