"""Losses: sequence-chunked softmax cross-entropy (+ z-loss).

The (B, S, V) logit tensor is the single biggest activation at 256k
vocabs (gemma2/nemotron: 4k x 256 x 256k bf16 = 512 GiB global). We
never materialize it: the unembed matmul + logsumexp + label gather run
per sequence-chunk inside a scan, so peak logit memory drops by
S/chunk. The vocab dim additionally shards over the TP axis via the
'vocab' logical rule.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common


def _chunk_ce(x, labels, mask, unemb_fn, softcap_v: float):
    """x: (B, L, d); labels: (B, L). Returns (sum_nll, sum_z2, count)."""
    logits = unemb_fn(x).astype(jnp.float32)
    logits = common.softcap(logits, softcap_v)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    z2 = (lse * lse) * mask
    return nll.sum(), z2.sum(), mask.sum()


def chunked_xent(
    x: jax.Array,  # (B, S, d) final hidden states
    labels: jax.Array,  # (B, S) int32; -1 = ignore
    unemb_fn,
    *,
    seq_chunk: int = 1024,
    z_loss: float = 0.0,
    final_softcap: float = 0.0,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (mean_nll, mean_z_loss_term). Never materializes (B,S,V)."""
    b, s, d = x.shape
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    seq_chunk = min(seq_chunk, s)
    if s % seq_chunk:
        pad = seq_chunk - s % seq_chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        s = s + pad
    nc = s // seq_chunk
    xs = x.reshape(b, nc, seq_chunk, d).swapaxes(0, 1)
    ls = labels.reshape(b, nc, seq_chunk).swapaxes(0, 1)
    ms = mask.reshape(b, nc, seq_chunk).swapaxes(0, 1)

    def step(carry, inp):
        nll, z2, cnt = carry
        xc, lc, mc = inp
        a, b2, c = _chunk_ce(xc, lc, mc, unemb_fn, final_softcap)
        return (nll + a, z2 + b2, cnt + c), None

    init = (jnp.zeros((), jnp.float32),) * 3
    (nll, z2, cnt), _ = lax.scan(step, init, (xs, ls, ms))
    cnt = jnp.maximum(cnt, 1.0)
    return nll / cnt, z_loss * z2 / cnt


def full_xent(x, labels, unemb_fn, *, z_loss: float = 0.0, final_softcap: float = 0.0):
    """Unchunked oracle for tests."""
    logits = common.softcap(unemb_fn(x).astype(jnp.float32), final_softcap)
    mask = (labels >= 0).astype(jnp.float32)
    lab = jnp.maximum(labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    zl = z_loss * ((lse * lse) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll, zl
