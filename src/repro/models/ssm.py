"""Recurrent sequence mixers: xLSTM's mLSTM (matrix memory, chunkwise-
parallel) and sLSTM (scalar memory, sequential), and Mamba-style selective
SSM (for hymba's parallel attn+mamba heads).

mLSTM chunkwise form (the production formulation -- intra-chunk work is
MXU matmuls, inter-chunk a short scan):

    weight(s->t) = exp(g_t + b_s),  g = cumsum(logsigmoid(f~)),  b = i~ - g
    h_t ~ alpha_t (q_t . C_prev) + sum_{s<=t} exp(b_s - M_t) (q_t.k_s) v_s

with M_t = max(m_prev, cummax b), alpha_t = exp(m_prev - M_t); the carried
(C, n) are stored pre-scaled by exp(-m) for stability. Chunkwise output
is validated against the naive sequential recurrence in tests.

All mixers expose train/prefill (full sequence) and decode (state in,
state out) entry points so the serve engine can thread states uniformly.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from repro.core.compat import shard_map

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import common
from repro.models.common import Params, Specs


# ---------------------------------------------------------------------------
# mLSTM core
# ---------------------------------------------------------------------------


class MLSTMState(NamedTuple):
    c: jax.Array  # (B, H, dk, dv) scaled by exp(-m)
    n: jax.Array  # (B, H, dk)
    m: jax.Array  # (B, H)


def init_mlstm_state(b: int, h: int, dk: int, dv: int, dtype=jnp.float32) -> MLSTMState:
    return MLSTMState(
        c=jnp.zeros((b, h, dk, dv), dtype),
        n=jnp.zeros((b, h, dk), dtype),
        m=jnp.full((b, h), -1e30, dtype),
    )


def mlstm_chunkwise(
    q: jax.Array,  # (B, H, S, dk)
    k: jax.Array,
    v: jax.Array,  # (B, H, S, dv)
    i_pre: jax.Array,  # (B, H, S) input-gate pre-activations
    f_pre: jax.Array,  # (B, H, S) forget-gate pre-activations
    state: Optional[MLSTMState] = None,
    *,
    chunk: int = 64,
) -> Tuple[jax.Array, MLSTMState]:
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    k = k / math.sqrt(dk)
    chunk = min(chunk, s)
    orig_s = s
    if s % chunk:
        # pad with identity steps: i~ = -inf (no write), f~ = +inf (no decay)
        pad = chunk - s % chunk
        zpad = ((0, 0), (0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(a, zpad) for a in (q, k, v))
        i_pre = jnp.pad(i_pre, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        f_pre = jnp.pad(f_pre, ((0, 0), (0, 0), (0, pad)), constant_values=1e30)
        s = s + pad
    nc = s // chunk
    if state is None:
        state = init_mlstm_state(b, h, dk, dv)

    def resh(x):
        return x.reshape(x.shape[:2] + (nc, chunk) + x.shape[3:]).swapaxes(0, 2)[...]

    # (nc, H, B, chunk, ...) scan layout: put chunk index first
    qs = q.reshape(b, h, nc, chunk, dk).transpose(2, 0, 1, 3, 4)
    ks = k.reshape(b, h, nc, chunk, dk).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, h, nc, chunk, dv).transpose(2, 0, 1, 3, 4)
    is_ = i_pre.reshape(b, h, nc, chunk).transpose(2, 0, 1, 3).astype(jnp.float32)
    fs = f_pre.reshape(b, h, nc, chunk).transpose(2, 0, 1, 3).astype(jnp.float32)

    def step(carry: MLSTMState, inp):
        c_prev, n_prev, m_prev = carry
        qc, kc, vc, ic, fc = inp
        logf = jax.nn.log_sigmoid(fc)  # (B,H,L)
        g = jnp.cumsum(logf, axis=-1)  # inclusive
        bvec = ic - g  # (B,H,L)
        mloc = lax.cummax(bvec, axis=2)
        m_t = jnp.maximum(m_prev[..., None], mloc)  # (B,H,L) = M_t
        alpha = jnp.exp(m_prev[..., None] - m_t)  # (B,H,L)

        qf = qc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        scores = jnp.einsum("bhtd,bhsd->bhts", qf, kf)  # (B,H,L,L)
        dmat = jnp.exp(bvec[:, :, None, :] - m_t[..., None])  # w[t,s]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(tri, scores * dmat, 0.0)
        inter_h = jnp.einsum("bhtd,bhde->bhte", qf, c_prev) * alpha[..., None]
        inter_n = jnp.einsum("bhtd,bhd->bht", qf, n_prev) * alpha
        num = w @ vf + inter_h  # (B,H,L,dv)
        den = w.sum(-1) + inter_n  # (B,H,L)
        m_total = g + m_t  # true log-scale at t
        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_total))[..., None]

        # chunk-end state
        g_l = g[..., -1:]  # (B,H,1)
        m_new = jnp.maximum(m_prev + g_l[..., 0], (g_l + bvec).max(-1))
        sc = jnp.exp(g_l + bvec - m_new[..., None])  # (B,H,L)
        c_new = jnp.exp(m_prev + g_l[..., 0] - m_new)[..., None, None] * c_prev + jnp.einsum(
            "bhs,bhsd,bhse->bhde", sc, kf, vf
        )
        n_new = jnp.exp(m_prev + g_l[..., 0] - m_new)[..., None] * n_prev + jnp.einsum(
            "bhs,bhsd->bhd", sc, kf
        )
        return MLSTMState(c_new, n_new, m_new), hout

    final, hs = lax.scan(step, state, (qs, ks, vs, is_, fs))
    out = hs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, dv)[:, :, :orig_s]
    return out.astype(q.dtype), final


def mlstm_decode_step(
    q: jax.Array,  # (B, H, dk)
    k: jax.Array,
    v: jax.Array,  # (B, H, dv)
    i_pre: jax.Array,  # (B, H)
    f_pre: jax.Array,
    state: MLSTMState,
) -> Tuple[jax.Array, MLSTMState]:
    dk = q.shape[-1]
    k = k / math.sqrt(dk)
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    m_new = jnp.maximum(logf + state.m, i_pre.astype(jnp.float32))
    fw = jnp.exp(logf + state.m - m_new)
    iw = jnp.exp(i_pre - m_new)
    kf, vf, qf = (a.astype(jnp.float32) for a in (k, v, q))
    c = fw[..., None, None] * state.c + iw[..., None, None] * (kf[..., :, None] * vf[..., None, :])
    n = fw[..., None] * state.n + iw[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, c)
    den = jnp.einsum("bhd,bhd->bh", qf, n)
    hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return hout.astype(q.dtype), MLSTMState(c, n, m_new)


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------


def init_mlstm_block(key, cfg: ModelConfig) -> Tuple[Params, Specs]:
    d = cfg.d_model
    sc: SSMConfig = cfg.ssm
    di = int(sc.expand * d)
    h = cfg.num_heads
    ks = jax.random.split(key, 8)
    p = {
        "wup": common.dense_init(ks[0], (d, 2 * di)),
        "conv": common.dense_init(ks[1], (4, di)),  # causal depthwise, width 4
        "wq": common.dense_init(ks[2], (di, di)),
        "wk": common.dense_init(ks[3], (di, di)),
        "wv": common.dense_init(ks[4], (di, di)),
        "wif": common.dense_init(ks[5], (di, 2 * h)),
        "gn": {"scale": jnp.zeros((di,), jnp.float32)},
        "wdown": common.dense_init(ks[6], (di, d)),
    }
    s = {
        "wup": ("fsdp", "mlp"),
        "conv": (None, "mlp"),
        "wq": ("mlp", None),
        "wk": ("mlp", None),
        "wv": ("mlp", None),
        "wif": ("mlp", None),
        "gn": {"scale": (None,)},
        "wdown": ("mlp", "fsdp"),
    }
    return p, s


def _causal_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv along S. x: (B,S,D), w: (W,D).
    Returns (out, new_state) with state = last W-1 inputs."""
    wlen = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], wlen - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(wlen))
    new_state = xp[:, -(wlen - 1) :] if wlen > 1 else jnp.zeros_like(pad)
    return out, new_state


class MLSTMBlockState(NamedTuple):
    cell: MLSTMState
    conv: jax.Array  # (B, W-1, di)


def _mlstm_qkvif(p, xm_conv, xm, h):
    dt = xm.dtype
    di = xm.shape[-1]
    dh = di // h
    b, s_len = xm.shape[0], xm.shape[1]
    q = jnp.einsum("bsd,de->bse", xm_conv, p["wq"].astype(dt))
    k = jnp.einsum("bsd,de->bse", xm_conv, p["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", xm, p["wv"].astype(dt))
    gates = jnp.einsum("bsd,dg->bsg", xm_conv.astype(jnp.float32), p["wif"].astype(jnp.float32))
    i_pre, f_pre = gates[..., :h], gates[..., h:]  # (B,S,H)
    to_heads = lambda a: a.reshape(b, s_len, h, dh).transpose(0, 2, 1, 3)
    return to_heads(q), to_heads(k), to_heads(v), i_pre.transpose(0, 2, 1), f_pre.transpose(0, 2, 1)


def apply_mlstm_block(
    p: Params, x: jax.Array, cfg: ModelConfig, state: Optional[MLSTMBlockState] = None
) -> Tuple[jax.Array, Optional[MLSTMBlockState]]:
    """Full-sequence mLSTM block (pre-norm residual handled by caller).
    x: (B, S, d). If ``state`` given, runs statefully and returns new state."""
    sc: SSMConfig = cfg.ssm
    h = cfg.num_heads
    b, s_len, d = x.shape
    di = int(sc.expand * d)
    dt = x.dtype
    up = jnp.einsum("bsd,de->bse", x, p["wup"].astype(dt))
    xm, z = up[..., :di], up[..., di:]
    conv_in_state = state.conv if state is not None else None
    xc, conv_state = _causal_conv(xm, p["conv"], conv_in_state)
    xc = jax.nn.silu(xc)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(p, xc, xm, h)
    cell0 = state.cell if state is not None else None
    hout, cell = mlstm_chunkwise(q, k, v, i_pre, f_pre, cell0, chunk=min(sc.chunk, s_len))
    hout = hout.transpose(0, 2, 1, 3)  # (B,S,H,dh)
    hn = common.apply_groupnorm(p["gn"], hout, h)
    y = hn * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["wdown"].astype(dt))
    return out, (MLSTMBlockState(cell, conv_state) if state is not None else None)


def decode_mlstm_block(
    p: Params, x: jax.Array, cfg: ModelConfig, state: MLSTMBlockState
) -> Tuple[jax.Array, MLSTMBlockState]:
    """Single-token step. x: (B, 1, d)."""
    sc: SSMConfig = cfg.ssm
    h = cfg.num_heads
    b, _, d = x.shape
    di = int(sc.expand * d)
    dt = x.dtype
    up = jnp.einsum("bsd,de->bse", x, p["wup"].astype(dt))
    xm, z = up[..., :di], up[..., di:]
    xc, conv_state = _causal_conv(xm, p["conv"], state.conv)
    xc = jax.nn.silu(xc)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(p, xc, xm, h)
    hout, cell = mlstm_decode_step(
        q[:, :, 0], k[:, :, 0], v[:, :, 0], i_pre[:, :, 0], f_pre[:, :, 0], state.cell
    )
    hn = common.apply_groupnorm(p["gn"], hout[:, :, None, :].transpose(0, 2, 1, 3), h)
    y = hn * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["wdown"].astype(dt))
    return out, MLSTMBlockState(cell, conv_state)


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM)
# ---------------------------------------------------------------------------


class SLSTMState(NamedTuple):
    h: jax.Array  # (B, D)
    c: jax.Array
    n: jax.Array
    m: jax.Array


def init_slstm_state(b: int, d: int) -> SLSTMState:
    z = jnp.zeros((b, d), jnp.float32)
    return SLSTMState(z, z, z, jnp.full((b, d), -1e30, jnp.float32))


def init_slstm_block(key, cfg: ModelConfig) -> Tuple[Params, Specs]:
    d = cfg.d_model
    sc: SSMConfig = cfg.ssm
    hh = sc.slstm_heads
    dh = d // hh
    ks = jax.random.split(key, 4)
    dff = int(d * 4 / 3)
    p = {
        "wx": common.dense_init(ks[0], (d, 4 * d)),  # z,i,f,o pre-acts
        "r": common.dense_init(ks[1], (hh, dh, 4 * dh)) / math.sqrt(dh),  # block-diag recurrent
        "gn": {"scale": jnp.zeros((d,), jnp.float32)},
        "wup": common.dense_init(ks[2], (d, 2 * dff)),
        "wdown": common.dense_init(ks[3], (dff, d)),
    }
    s = {
        "wx": ("fsdp", "mlp"),
        "r": (None, None, None),
        "gn": {"scale": (None,)},
        "wup": ("fsdp", "mlp"),
        "wdown": ("mlp", "fsdp"),
    }
    return p, s


def _slstm_cell(p, xg, st: SLSTMState, hh: int) -> Tuple[jax.Array, SLSTMState]:
    """One step. xg: (B, 4d) input pre-activations."""
    b, d4 = xg.shape
    d = d4 // 4
    dh = d // hh
    hprev = st.h.reshape(b, hh, dh)
    rec = jnp.einsum("bhd,hde->bhe", hprev, p["r"].astype(jnp.float32)).reshape(b, 4 * d)
    # interleaved per-head gate layout: (hh, 4, dh) -> flatten
    rec = rec.reshape(b, hh, 4, dh)
    xg = xg.reshape(b, hh, 4, dh) + rec
    zt, it, ft, ot = xg[:, :, 0], xg[:, :, 1], xg[:, :, 2], xg[:, :, 3]
    zt = jnp.tanh(zt).reshape(b, d)
    ot = jax.nn.sigmoid(ot).reshape(b, d)
    it = it.reshape(b, d)
    ft = ft.reshape(b, d)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + st.m, it)
    fw = jnp.exp(logf + st.m - m_new)
    iw = jnp.exp(it - m_new)
    c = fw * st.c + iw * zt
    n = fw * st.n + iw
    h = ot * c / jnp.maximum(jnp.abs(n), jnp.exp(-m_new))
    return h, SLSTMState(h, c, n, m_new)


def apply_slstm_block(
    p: Params, x: jax.Array, cfg: ModelConfig, state: Optional[SLSTMState] = None,
    mesh=None,
) -> Tuple[jax.Array, Optional[SLSTMState]]:
    sc: SSMConfig = cfg.ssm
    hh = sc.slstm_heads
    b, s_len, d = x.shape
    keep_state = state is not None
    if state is None:
        state = init_slstm_state(b, d)
    xg = jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32), p["wx"].astype(jnp.float32))

    def scan_fn(xg_, st0, r_):
        def step(st, xt):
            h, st2 = _slstm_cell({"r": r_}, xt, st, hh)
            return st2, h

        final, hs = lax.scan(step, st0, xg_.swapaxes(0, 1))
        return final, hs

    if mesh is not None and mesh.size > 1:
        # shard_map island: the 4096-step recurrence must be LOCAL per
        # device (batch-sharded, TP-replicated). Left to GSPMD, the
        # per-step recurrent matmul gets its contraction dim sharded ->
        # one all-reduce per TIME STEP (measured: 393k all-reduces,
        # 12.4 TB/chip at train_4k). Locality by construction instead.
        from jax.sharding import PartitionSpec as P

        ba = tuple(a for a in ("pod", "data") if a in mesh.shape) or None
        bspec = P(ba)
        st_spec = SLSTMState(h=bspec, c=bspec, n=bspec, m=bspec)
        final, hs = shard_map(
            scan_fn,
            mesh=mesh,
            in_specs=(P(ba, None, None), st_spec, P(None, None, None)),
            out_specs=(st_spec, P(None, ba, None)),
            check_vma=False,
        )(xg, state, p["r"])
    else:
        final, hs = scan_fn(xg, state, p["r"])
    hseq = hs.swapaxes(0, 1).astype(x.dtype)  # (B,S,d)
    hn = common.apply_groupnorm(p["gn"], hseq.reshape(b, s_len, hh, d // hh), hh)
    up = jnp.einsum("bsd,de->bse", hn, p["wup"].astype(x.dtype))
    dff = up.shape[-1] // 2
    y = jax.nn.gelu(up[..., :dff]) * up[..., dff:]
    out = jnp.einsum("bse,ed->bsd", y, p["wdown"].astype(x.dtype))
    return out, (final if keep_state else None)


def decode_slstm_block(p, x, cfg, state: SLSTMState):
    out, st = apply_slstm_block(p, x, cfg, state)
    return out, st


# ---------------------------------------------------------------------------
# Mamba (selective SSM) -- hymba's parallel head
# ---------------------------------------------------------------------------


class MambaState(NamedTuple):
    h: jax.Array  # (B, di, N)
    conv: jax.Array  # (B, W-1, di)


def init_mamba_state(b: int, di: int, n: int, w: int) -> MambaState:
    return MambaState(h=jnp.zeros((b, di, n), jnp.float32), conv=jnp.zeros((b, w - 1, di), jnp.float32))


def init_mamba(key, cfg: ModelConfig) -> Tuple[Params, Specs]:
    d = cfg.d_model
    sc: SSMConfig = cfg.ssm
    di = int(sc.expand * d)
    n = sc.state_dim
    ks = jax.random.split(key, 6)
    p = {
        "win": common.dense_init(ks[0], (d, 2 * di)),
        "conv": common.dense_init(ks[1], (sc.conv_dim, di)),
        "wbc": common.dense_init(ks[2], (di, 2 * n)),
        "wdt": common.dense_init(ks[3], (di, di)) * 0.01,
        "dt_bias": jnp.zeros((di,), jnp.float32) + jnp.log(jnp.expm1(0.01)),
        "a_log": jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "dskip": jnp.ones((di,), jnp.float32),
        "wout": common.dense_init(ks[4], (di, d)),
    }
    s = {
        "win": ("fsdp", "mlp"),
        "conv": (None, "mlp"),
        "wbc": ("mlp", None),
        "wdt": ("mlp", "mlp"),
        "dt_bias": ("mlp",),
        "a_log": ("mlp", None),
        "dskip": ("mlp",),
        "wout": ("mlp", "fsdp"),
    }
    return p, s


# ---------------------------------------------------------------------------
# fused selective-scan core with manual VJP
#
# Autodiff of lax.associative_scan explodes into a tree of big slice ops
# (measured: ~50 TB/chip of slice traffic at hymba train_4k before
# channel sharding, ~10 TB after). The backward recurrence is itself a
# reverse scan with analytic per-step gradients:
#     dh[t] = c_t * dy[t]  +  decay[t+1] (.) dh[t+1]
#     ddecay[t] = dh[t] (.) h[t-1];  dinc[t] = dh[t]
# so we recompute h per chunk (transient) and run ONE reverse scan --
# the JAX-level expression of mamba's hardware-aware kernel.
# ---------------------------------------------------------------------------


def _chunk_fwd(decay, inc, h0):
    """Within-chunk scan. decay/inc: (L, B, d, N); h0: (B, d, N)."""

    def combine(a, b):
        (d1, i1), (d2, i2) = a, b
        return d1 * d2, i1 * d2 + i2

    dcum, icum = lax.associative_scan(combine, (decay, inc), axis=0)
    hs = dcum * h0[None] + icum
    return hs


def _mamba_core_fwd_impl(xc, dt, bmat, cmat, a, dskip, h0, chunk: int):
    """Returns (y (B,S,d), h_last, boundary states (nc, B, d, N))."""
    b, s, d = xc.shape
    n = bmat.shape[-1]
    nc = s // chunk

    def to_chunks(v):  # (B, S, ...) -> (nc, L, B, ...)
        return v.reshape(b, nc, chunk, *v.shape[2:]).transpose(1, 2, 0, *range(3, v.ndim + 1))

    xcs, dts, bs_, cs_ = map(to_chunks, (xc, dt, bmat, cmat))

    def step(h, inp):
        xci, dti, bi, ci = inp  # (L, B, d) / (L, B, N)
        decay = jnp.exp(dti[..., None] * a)  # (L,B,d,N)
        inc = (dti * xci)[..., None] * bi[:, :, None, :]
        hs = _chunk_fwd(decay, inc, h)
        y = jnp.einsum("lbdn,lbn->lbd", hs, ci) + dskip * xci
        return hs[-1], (y, h)

    h_last, (ys, bounds) = lax.scan(step, h0, (xcs, dts, bs_, cs_))
    y = ys.transpose(2, 0, 1, 3).reshape(b, s, d)
    return y, h_last, bounds  # bounds: (nc, B, d, N) = h at chunk STARTS


def _mamba_core_bwd_impl(res, cts, chunk: int):
    xc, dt, bmat, cmat, a, dskip, bounds = res
    dy, dh_last = cts
    b, s, d = xc.shape
    n = bmat.shape[-1]
    nc = s // chunk

    def to_chunks(v):
        return v.reshape(b, nc, chunk, *v.shape[2:]).transpose(1, 2, 0, *range(3, v.ndim + 1))

    xcs, dts, bs_, cs_, dys = map(to_chunks, (xc, dt, bmat, cmat, dy))

    def step(carry, inp):
        dh_carry, da_acc, dD_acc = carry  # dh from the FUTURE chunk
        xci, dti, bi, ci, dyi, h_in = inp
        xci, dti, bi, ci, dyi = (v.astype(jnp.float32) for v in (xci, dti, bi, ci, dyi))
        # recompute forward (transient)
        decay = jnp.exp(dti[..., None] * a.astype(jnp.float32))
        inc = (dti * xci)[..., None] * bi[:, :, None, :]
        hs = _chunk_fwd(decay, inc, h_in)
        h_prev = jnp.concatenate([h_in[None], hs[:-1]], axis=0)  # h_{t-1}
        # per-step state cotangent from y, plus the carried one:
        dhs_local = dyi[..., None] * ci[:, :, None, :]  # (L,B,d,N)
        # reverse recurrence dh[t] = dhs_local[t] + decay[t+1] * dh[t+1]
        decay_next = jnp.concatenate([decay[1:], jnp.ones_like(decay[:1])], axis=0)
        dhs_local = dhs_local.at[-1].add(dh_carry)

        def comb(x_, y_):
            (dx, vx), (dy_, vy) = x_, y_
            return dx * dy_, vx * dy_ + vy

        _, dh = lax.associative_scan(comb, (decay_next, dhs_local), axis=0, reverse=True)
        # gradients
        ddecay = dh * h_prev
        dinc = dh
        d_dta = ddecay * decay  # d/d(dt*a)
        da_acc = da_acc + jnp.einsum("lbdn,lbd->dn", d_dta, dti)
        ddt_dec = jnp.einsum("lbdn,dn->lbd", d_dta, a.astype(jnp.float32))
        ddtx = jnp.einsum("lbdn,lbn->lbd", dinc, bi)
        dbi = jnp.einsum("lbdn,lbd->lbn", dinc, dti * xci)
        dci = jnp.einsum("lbdn,lbd->lbn", hs, dyi)
        dxci = ddtx * dti + dskip.astype(jnp.float32) * dyi
        ddti = ddtx * xci + ddt_dec
        dD_acc = dD_acc + jnp.einsum("lbd,lbd->d", dyi, xci)
        dh_prev_chunk = decay[0] * dh[0]  # cotangent into previous chunk's last h
        return (dh_prev_chunk, da_acc, dD_acc), (dxci, ddti, dbi, dci)

    init = (
        dh_last.astype(jnp.float32),
        jnp.zeros(a.shape, jnp.float32),
        jnp.zeros((d,), jnp.float32),
    )
    (dh0, da, dD), (dxcs, ddts, dbs, dcs) = lax.scan(
        step, init, (xcs, dts, bs_, cs_, dys, bounds), reverse=True
    )

    def from_chunks(v):  # (nc, L, B, ...) -> (B, S, ...)
        return v.transpose(2, 0, 1, *range(3, v.ndim)).reshape(b, s, *v.shape[3:])

    # cotangents must match primal dtypes (a/dskip may be bf16 post-cast)
    return (
        from_chunks(dxcs).astype(xc.dtype),
        from_chunks(ddts).astype(dt.dtype),
        from_chunks(dbs).astype(bmat.dtype),
        from_chunks(dcs).astype(cmat.dtype),
        da.astype(a.dtype), dD.astype(dskip.dtype), dh0.astype(jnp.float32),
    )


def _make_mamba_core(chunk: int):
    @jax.custom_vjp
    def core(xc, dt, bmat, cmat, a, dskip, h0):
        y, h_last, _ = _mamba_core_fwd_impl(xc, dt, bmat, cmat, a, dskip, h0, chunk)
        return y, h_last

    def fwd(xc, dt, bmat, cmat, a, dskip, h0):
        y, h_last, bounds = _mamba_core_fwd_impl(xc, dt, bmat, cmat, a, dskip, h0, chunk)
        return (y, h_last), (xc, dt, bmat, cmat, a, dskip, bounds)

    def bwd(res, cts):
        return _mamba_core_bwd_impl(res, cts, chunk)

    core.defvjp(fwd, bwd)
    return core


def mamba_core(xc, dt, bmat, cmat, a, dskip, h0, *, chunk: int):
    """Fused selective scan y = SSM(xc; dt, B, C, A, D), manual VJP.
    xc/dt: (B, S, d) f32; bmat/cmat: (B, S, N); a: (d, N); h0: (B, d, N).
    S must be a multiple of ``chunk`` (caller pads)."""
    return _make_mamba_core(chunk)(xc, dt, bmat, cmat, a, dskip, h0)


def _mamba_scan_chunked(decay, inc, h0, chunk: int):
    """h_t = decay_t * h_{t-1} + inc_t, over axis 1 (time).

    decay/inc: (B, S, di, N). Outer lax.scan over chunks, inner
    associative_scan -- bounded memory at long S (the long_500k path)."""
    b, s, di, n = decay.shape
    chunk = min(chunk, s)
    orig_s = s
    if s % chunk:  # pad with identity elements (decay=1, inc=0)
        pad = chunk - s % chunk
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        inc = jnp.pad(inc, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // chunk

    def combine(a, bpair):
        (d1, i1), (d2, i2) = a, bpair
        return d1 * d2, i1 * d2 + i2

    def step(h, inp):
        dch, ich = inp  # (chunk, B, di, N)
        dcum, icum = lax.associative_scan(combine, (dch, ich), axis=0)
        hs = dcum * h[None] + icum
        return hs[-1], hs

    dr = decay.transpose(1, 0, 2, 3).reshape(nc, chunk, b, di, n)
    ir = inc.transpose(1, 0, 2, 3).reshape(nc, chunk, b, di, n)
    hlast, hs = lax.scan(step, h0, (dr, ir))
    hs = hs.reshape(s, b, di, n).transpose(1, 0, 2, 3)[:, :orig_s]
    return hs, hlast


def apply_mamba(
    p: Params, x: jax.Array, cfg: ModelConfig, state: Optional[MambaState] = None,
    mesh=None,
) -> Tuple[jax.Array, Optional[MambaState]]:
    sc: SSMConfig = cfg.ssm
    b, s_len, d = x.shape
    di = int(sc.expand * d)
    n = sc.state_dim
    dt_ = x.dtype
    keep_state = state is not None
    up = jnp.einsum("bsd,de->bse", x, p["win"].astype(dt_))
    xi, z = up[..., :di], up[..., di:]
    conv_state = state.conv if state is not None else None
    xc, conv_new = _causal_conv(xi, p["conv"], conv_state)
    xc = jax.nn.silu(xc).astype(jnp.float32)
    if mesh is not None and mesh.size > 1:
        # SP->channel transition: the residual carry arrives seq-sharded;
        # the time scan must see the FULL sequence with the channel (d_i)
        # dim sharded instead -- otherwise every scan step gathers its
        # chunk across the mesh (measured 160+ TB/chip at train_4k).
        from repro.core.sharding import constrain

        xc = constrain(xc, mesh, "batch", None, "mlp")
        z = constrain(z, mesh, "batch", None, "mlp")
    bc = jnp.einsum("bse,en->bsn", xc, p["wbc"].astype(jnp.float32))
    bmat, cmat = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(jnp.einsum("bse,ef->bsf", xc, p["wdt"].astype(jnp.float32)) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])  # (di, N)
    h0 = state.h if state is not None else jnp.zeros((b, di, n), jnp.float32)
    chunk = min(sc.chunk, s_len)
    pad = (-s_len) % chunk
    if pad:  # identity steps: dt = 0 -> decay = 1, inc = 0
        zp = ((0, 0), (0, pad), (0, 0))
        xc_p, dt_p = jnp.pad(xc, zp), jnp.pad(dt, zp)
        b_p, c_p = jnp.pad(bmat, zp), jnp.pad(cmat, zp)
    else:
        xc_p, dt_p, b_p, c_p = xc, dt, bmat, cmat
    y, hlast = mamba_core(xc_p, dt_p, b_p, c_p, a, p["dskip"], h0, chunk=chunk)
    y = y[:, :s_len]
    y = y.astype(dt_) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["wout"].astype(dt_))
    return out, (MambaState(hlast, conv_new) if keep_state else None)


def decode_mamba(p, x, cfg, state: MambaState):
    out, st = apply_mamba(p, x, cfg, state)
    return out, st
