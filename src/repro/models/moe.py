"""Mixture-of-Experts with strategy-switchable token dispatch.

The expert all-to-all is the LM-side twin of the paper's FFT pencil
exchange: every device ships (1 - 1/P) of its routed tokens. We provide
the same strategy switch as core/transpose.py:

``dispatch='einsum'`` (gspmd)
    Sort-based capacity dispatch under jit + sharding constraints; XLA
    emits its own (fused, synchronizing) collectives -- the paper's
    all-to-all baseline.
``dispatch='ring'``
    Explicit shard_map island: the dispatch buffer is exchanged in P-1
    direct ppermute hops and each arriving chunk runs its expert FFN
    *immediately*, then returns on the reverse ring -- expert compute
    hidden behind token communication (the paper's N-scatter, applied to
    MoE). Falls back to gspmd when experts % shards != 0 (mixtral).
``dispatch='dense'``
    All experts on all tokens (tiny smoke configs only).

Routing: softmax top-k with renormalization + load-balance aux loss.
Capacity-based with drop (cf * tokens * k / E slots per expert), slot
assignment via stable argsort (production-style; no (T,E,C) one-hots).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from repro.core.compat import axis_size, shard_map

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import common, mlp
from repro.models.common import Params, Specs


def init_moe(key, cfg: ModelConfig) -> Tuple[Params, Specs]:
    mo: MoEConfig = cfg.moe
    d = cfg.d_model
    eff = mo.expert_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": common.dense_init(ks[0], (d, mo.num_experts)),
        "wg": common.dense_init(ks[1], (mo.num_experts, d, eff)),
        "wu": common.dense_init(ks[2], (mo.num_experts, d, eff)),
        "wd": common.dense_init(ks[3], (mo.num_experts, eff, d)),
    }
    s = {
        "router": ("fsdp", None),
        "wg": ("experts", "fsdp", None),
        "wu": ("experts", "fsdp", None),
        "wd": ("experts", None, "fsdp"),
    }
    if mo.num_shared:
        sp, ss = mlp.init_mlp(ks[4], d, eff * mo.num_shared, cfg.mlp_kind)
        p["shared"] = sp
        s["shared"] = ss
    return p, s


def router_topk(
    x: jax.Array, wr: jax.Array, k: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (weights (T,k) f32, indices (T,k) i32, aux load-balance loss)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), wr.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # GShard aux: E * sum_e (fraction routed to e) * (mean prob of e)
    e = wr.shape[1]
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # (T,k,E)
    frac = onehot.sum(1).mean(0)  # (E,)
    aux = e * jnp.sum(frac * probs.mean(0))
    return w, idx, aux


def _expert_ffn(wg, wu, wd, x, kind: str) -> jax.Array:
    """x: (..., C, d) for one expert's weight set."""
    dt = x.dtype
    if kind in mlp.GATED:
        h = mlp._act(jnp.einsum("...cd,df->...cf", x, wg.astype(dt)), kind)
        h = h * jnp.einsum("...cd,df->...cf", x, wu.astype(dt))
    else:
        h = mlp._act(jnp.einsum("...cd,df->...cf", x, wu.astype(dt)), kind)
    return jnp.einsum("...cf,fd->...cd", h, wd.astype(dt))


def _dispatch_indices(idx: jax.Array, e: int, cap: int):
    """Stable-sort capacity assignment.

    idx: (T, k) expert choices. Returns (order (A,), dest (A,), keep (A,))
    where A = T*k; dest = expert*cap + slot for kept assignments.
    """
    t, k = idx.shape
    a = t * k
    flat_e = idx.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)  # token-priority within expert
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = jnp.arange(a) - first[sorted_e]
    keep = rank < cap
    dest = sorted_e * cap + jnp.where(keep, rank, 0)
    return order, dest, keep


def _local_dispatch(x2d: jax.Array, idx: jax.Array, e: int, cap: int) -> Tuple[jax.Array, tuple]:
    """Scatter tokens into the (E, cap, d) buffer; returns routing aux for
    the combine step."""
    t, k = idx.shape
    order, dest, keep = _dispatch_indices(idx, e, cap)
    tok = order // k
    buf = jnp.zeros((e * cap, x2d.shape[-1]), x2d.dtype)
    buf = buf.at[dest].add(x2d[tok] * keep[:, None].astype(x2d.dtype))
    return buf.reshape(e, cap, -1), (order, dest, keep, tok)


def _local_combine(
    buf: jax.Array, w: jax.Array, routing: tuple, t: int
) -> jax.Array:
    order, dest, keep, tok = routing
    k = w.shape[1]
    flat_w = w.reshape(-1)[order]  # (A,) f32
    y = buf.reshape(-1, buf.shape[-1])[dest]  # (A, d)
    y = y * (flat_w * keep).astype(y.dtype)[:, None]
    out = jnp.zeros((t, buf.shape[-1]), y.dtype)
    return out.at[tok].add(y)


def _capacity(tokens: int, k: int, e: int, cf: float) -> int:
    return max(1, math.ceil(tokens * k * cf / e))


# ---------------------------------------------------------------------------
# gspmd (fused-collective) path
# ---------------------------------------------------------------------------


def _apply_moe_gspmd(p, x2d, cfg: ModelConfig, mesh=None) -> Tuple[jax.Array, jax.Array]:
    """Capacity dispatch under jit + GSPMD, batched over DP groups.

    Capacity must be computed from *per-group* token counts: dispatching
    the global token set into one (E, C_global, d) buffer would make the
    buffer (and the argsort) scale with the full batch (hundreds of TB at
    deepseek train_4k). Each DP shard dispatches its own tokens; the
    expert dim sharding then induces the all-to-all, exactly like the
    explicit ring island -- but with XLA choosing the schedule (the
    paper's fused-collective baseline)."""
    mo = cfg.moe
    t = x2d.shape[0]
    g = 1
    if mesh is not None:
        for ax in ("pod", "data"):
            if ax in mesh.shape:
                g *= mesh.shape[ax]
        if t % g:
            g = 1
    tl = t // g
    cap = _capacity(tl, mo.top_k, mo.num_experts, mo.capacity_factor)
    xg = x2d.reshape(g, tl, -1)

    def one_group(xl):
        w, idx, aux = router_topk(xl, p["router"], mo.top_k)
        buf, routing = _local_dispatch(xl, idx, mo.num_experts, cap)
        return w, buf, routing, aux

    w, buf, routing, aux = jax.vmap(one_group)(xg)  # buf: (G, E, C, d)

    def _buf_constrain(v):
        # shape-aware: experts claim the TP axis when they divide it
        # (deepseek 256); otherwise the capacity dim takes it (mixtral's
        # 8 experts would leave the buffer TP-replicated: ~2 TB)
        from jax.sharding import NamedSharding
        from repro.core.sharding import resolve

        spec = resolve(mesh, "batch", "experts", "expert_cap", None, shape=v.shape)
        return jax.lax.with_sharding_constraint(v, NamedSharding(mesh, spec))

    if mesh is not None and mesh.size > 1:
        buf = _buf_constrain(buf)
    dt = x2d.dtype
    if cfg.mlp_kind in mlp.GATED:
        h = mlp._act(jnp.einsum("gecd,edf->gecf", buf, p["wg"].astype(dt)), cfg.mlp_kind)
        h = h * jnp.einsum("gecd,edf->gecf", buf, p["wu"].astype(dt))
    else:
        h = mlp._act(jnp.einsum("gecd,edf->gecf", buf, p["wu"].astype(dt)), cfg.mlp_kind)
    y = jnp.einsum("gecf,efd->gecd", h, p["wd"].astype(dt))
    if mesh is not None and mesh.size > 1:
        y = _buf_constrain(y)
    out = jax.vmap(lambda yb, wb, rt: _local_combine(yb, wb, rt, tl))(y, w, routing)
    return out.reshape(t, -1), aux.mean()


# ---------------------------------------------------------------------------
# dense (smoke) path
# ---------------------------------------------------------------------------


def _apply_moe_dense(p, x2d, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    mo = cfg.moe
    w, idx, aux = router_topk(x2d, p["router"], mo.top_k)
    all_y = jax.vmap(
        lambda wg, wu, wd: _expert_ffn(wg, wu, wd, x2d, cfg.mlp_kind)
    )(p["wg"], p["wu"], p["wd"])  # (E, T, d)
    onehot = jax.nn.one_hot(idx, mo.num_experts, dtype=jnp.float32)  # (T,k,E)
    gate = jnp.einsum("tk,tke->te", w, onehot)  # (T,E)
    out = jnp.einsum("te,etd->td", gate.astype(x2d.dtype), all_y)
    return out, aux


# ---------------------------------------------------------------------------
# explicit ring path (shard_map island) -- the paper's technique
# ---------------------------------------------------------------------------


def _ring_exchange_ffn(
    wg, wu, wd, buf, kind: str, axis_name: str, *, interleave: bool = False
) -> jax.Array:
    """buf: (P, E_loc, C, d) local dispatch buffer grouped by destination
    rank; wg/wu/wd are this rank's local expert weights (E_loc, ...).
    Chunk s ships *directly* to rank me+s (P-1 independent sends -- the
    paper's N-scatter decomposition; XLA overlaps them as async
    collective-permutes), results return on the mirrored ring.

    Default (interleave=False): one batched FFN over all received chunks.
    The per-arrival FFN variant (interleave=True, the paper's literal
    'compute each chunk as it lands') produces P independent weight
    cotangents that XLA keeps live simultaneously in the backward --
    ~40 GB/layer at deepseek scale -- so training uses the batched form
    (identical bytes on the wire, bigger MXU matmuls, one cotangent).
    """
    pn = axis_size(axis_name)
    me = lax.axis_index(axis_name)

    def ffn(chunk):  # (..., E_loc, C, d) with my local experts
        return jax.vmap(lambda g, u, dn, b: _expert_ffn(g, u, dn, b, kind))(wg, wu, wd, chunk)

    if interleave:
        out = jnp.zeros_like(buf)
        own = jnp.take(buf, me, axis=0)
        out = lax.dynamic_update_slice_in_dim(out, ffn(own)[None], me, axis=0)
        for s in range(1, pn):
            fwd = [(i, (i + s) % pn) for i in range(pn)]
            rev = [(i, (i - s) % pn) for i in range(pn)]
            send = jnp.take(buf, (me + s) % pn, axis=0)
            recv = lax.ppermute(send, axis_name, fwd)
            done = ffn(recv)  # compute on arrival
            back = lax.ppermute(done, axis_name, rev)
            out = lax.dynamic_update_slice_in_dim(out, back[None], (me + s) % pn, axis=0)
        return out

    # phase 1: direct-send exchange (independent sends overlap)
    e_loc, cap, d = buf.shape[1:]
    recv_stack = jnp.zeros_like(buf)  # slot s = tokens from rank me-s
    own = jnp.take(buf, me, axis=0)
    recv_stack = lax.dynamic_update_slice_in_dim(recv_stack, own[None], 0, axis=0)
    for s in range(1, pn):
        fwd = [(i, (i + s) % pn) for i in range(pn)]
        send = jnp.take(buf, (me + s) % pn, axis=0)
        recv = lax.ppermute(send, axis_name, fwd)
        recv_stack = lax.dynamic_update_slice_in_dim(recv_stack, recv[None], s, axis=0)
    # phase 2: one batched FFN: (P, E_loc, C, d) -> (E_loc, P*C, d)
    grouped = recv_stack.swapaxes(0, 1).reshape(e_loc, pn * cap, d)
    done = ffn(grouped).reshape(e_loc, pn, cap, d).swapaxes(0, 1)
    # phase 3: direct-send results home
    out = jnp.zeros_like(buf)
    out = lax.dynamic_update_slice_in_dim(out, jnp.take(done, 0, axis=0)[None], me, axis=0)
    for s in range(1, pn):
        rev = [(i, (i - s) % pn) for i in range(pn)]
        back = lax.ppermute(jnp.take(done, s, axis=0), axis_name, rev)
        out = lax.dynamic_update_slice_in_dim(out, back[None], (me + s) % pn, axis=0)
    return out


def _apply_moe_ring(p, x, cfg: ModelConfig, mesh, axis_name: str = "model"):
    """x: (B, S, d) with S sharded over ``axis_name`` inside the island
    (sequence-parallel MoE, DeepSeek-style EP)."""
    from jax.sharding import PartitionSpec as P

    mo = cfg.moe
    b, s, d = x.shape
    pn = mesh.shape[axis_name]
    e_loc = mo.num_experts // pn
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape) or None

    def island(xl, router, wg, wu, wd):
        bl, sl, _ = xl.shape
        t = bl * sl
        x2d = xl.reshape(t, d)
        cap = _capacity(t, mo.top_k, mo.num_experts, mo.capacity_factor)
        w, idx, aux = router_topk(x2d, router, mo.top_k)
        buf, routing = _local_dispatch(x2d, idx, mo.num_experts, cap)
        buf = buf.reshape(pn, e_loc, cap, d)
        y = _ring_exchange_ffn(wg, wu, wd, buf, cfg.mlp_kind, axis_name)
        out = _local_combine(y.reshape(mo.num_experts, cap, d), w, routing, t)
        return out.reshape(bl, sl, d), lax.pmean(aux, axis_name)

    x_spec = P(batch_axes, axis_name, None)
    e_spec = P(axis_name, None, None)
    return shard_map(
        island,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), e_spec, e_spec, e_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"], p["wg"], p["wu"], p["wd"])


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def apply_moe(
    p: Params,
    x: jax.Array,  # (B, S, d)
    cfg: ModelConfig,
    *,
    mesh=None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,S,d), aux loss scalar)."""
    mo = cfg.moe
    b, s, d = x.shape
    dispatch = mo.dispatch
    if dispatch == "ring":
        pn = mesh.shape.get("model", 1) if mesh is not None else 1
        if mesh is None or pn == 1 or mo.num_experts % pn or s % pn:
            dispatch = "einsum"  # divisibility fallback (DESIGN §Arch-applicability)
        else:
            # checkpoint the island: shard_map residuals are opaque to the
            # outer scan remat, so without this every layer would SAVE its
            # (E, C, d) dispatch buffers (~1.4 GB/layer at deepseek scale).
            ring = jax.checkpoint(lambda pp, xx: _apply_moe_ring(pp, xx, cfg, mesh))
            out, aux = ring(p, x)
            if mo.num_shared:
                out = out + mlp.apply_mlp(p["shared"], x, cfg.mlp_kind)
            return out, aux
    x2d = x.reshape(b * s, d)
    if dispatch == "dense":
        out, aux = _apply_moe_dense(p, x2d, cfg)
    else:
        out, aux = _apply_moe_gspmd(p, x2d, cfg, mesh)
    out = out.reshape(b, s, d)
    if mo.num_shared:
        out = out + mlp.apply_mlp(p["shared"], x, cfg.mlp_kind)
    return out, aux
