"""Shared layer primitives: norms, RoPE, embeddings, initializers.

Functional style: ``init_*`` returns ``(params, specs)`` where ``specs``
mirrors the param pytree with tuples of *logical* sharding axis names
(resolved against the mesh by core/sharding.py). ``apply_*`` are pure.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = dict
Specs = dict


def trunc_normal(key, shape, scale: float, dtype=jnp.float32) -> jax.Array:
    """Truncated-normal init with fan-in scaling (MaxText default)."""
    std = scale / math.sqrt(shape[0] if len(shape) > 1 else 1)
    return std * jax.random.truncated_normal(key, -3.0, 3.0, shape, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(d: int, kind: str) -> Tuple[Params, Specs]:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}, {"scale": (None,)}
    if kind == "layernorm":
        return (
            {"scale": jnp.zeros((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)},
            {"scale": (None,), "bias": (None,)},
        )
    raise ValueError(kind)


def apply_norm(p: Params, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"]) + p["bias"]
    return out.astype(x.dtype)


def init_groupnorm(heads: int, d: int) -> Tuple[Params, Specs]:
    """Per-head group norm (xLSTM blocks)."""
    return {"scale": jnp.zeros((d,), jnp.float32)}, {"scale": (None,)}


def apply_groupnorm(p: Params, x: jax.Array, heads: int, eps: float = 1e-6) -> jax.Array:
    """x: (..., H, dh) normalized per head."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out.reshape(out.shape[:-2] + (-1,)) * (1.0 + p["scale"])
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float, fraction: float = 1.0) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D); positions: (..., S) int32.

    ``fraction`` < 1 rotates only the leading dims (nemotron partial rope).
    """
    d = x.shape[-1]
    rot = int(d * fraction) // 2 * 2
    if rot == 0 or theta <= 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # positions (..., S) -> angles (..., S, 1, half), broadcasting over heads
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half], xr[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1) if rot < d else out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int, dtype=jnp.float32) -> jax.Array:
    """Whisper-style absolute sinusoidal embeddings (seq, d)."""
    pos = np.arange(seq)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embed(key, vocab: int, d: int, tie: bool) -> Tuple[Params, Specs]:
    p = {"table": trunc_normal(key, (vocab, d), 1.0)}
    s = {"table": ("vocab", "fsdp")}
    if not tie:
        k2 = jax.random.fold_in(key, 1)
        p["unembed"] = trunc_normal(k2, (d, vocab), 1.0)
        s["unembed"] = ("fsdp", "vocab")
    return p, s


def embed_tokens(p: Params, tokens: jax.Array, dtype) -> jax.Array:
    return p["table"].astype(dtype)[tokens]


def unembed(p: Params, x: jax.Array, tie: bool) -> jax.Array:
    w = p["table"].T if tie else p["unembed"]
    return jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


def dense_init(key, shape, *, scale: float = 1.0) -> jax.Array:
    return trunc_normal(key, shape, scale)
