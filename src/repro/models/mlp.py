"""Feed-forward variants: SwiGLU / GeGLU / squared-ReLU / GELU."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Params, Specs

GATED = ("swiglu", "geglu")


def init_mlp(key, d: int, d_ff: int, kind: str) -> Tuple[Params, Specs]:
    ks = jax.random.split(key, 3)
    if kind in GATED:
        p = {
            "wg": common.dense_init(ks[0], (d, d_ff)),
            "wu": common.dense_init(ks[1], (d, d_ff)),
            "wd": common.dense_init(ks[2], (d_ff, d)),
        }
        s = {"wg": ("fsdp", "mlp"), "wu": ("fsdp", "mlp"), "wd": ("mlp", "fsdp")}
    else:
        p = {
            "wu": common.dense_init(ks[0], (d, d_ff)),
            "wd": common.dense_init(ks[1], (d_ff, d)),
        }
        s = {"wu": ("fsdp", "mlp"), "wd": ("mlp", "fsdp")}
    return p, s


def _act(h: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        return jax.nn.silu(h)
    if kind == "geglu":
        return jax.nn.gelu(h)
    if kind == "relu2":
        r = jax.nn.relu(h)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(h)
    raise ValueError(kind)


def apply_mlp(p: Params, x: jax.Array, kind: str) -> jax.Array:
    dt = x.dtype
    if kind in GATED:
        h = _act(jnp.einsum("...d,df->...f", x, p["wg"].astype(dt)), kind)
        h = h * jnp.einsum("...d,df->...f", x, p["wu"].astype(dt))
    else:
        h = _act(jnp.einsum("...d,df->...f", x, p["wu"].astype(dt)), kind)
    return jnp.einsum("...f,fd->...d", h, p["wd"].astype(dt))
