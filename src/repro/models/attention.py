"""Attention: GQA/MHA with chunked (flash-style) softmax, sliding-window
+ global patterns, logit softcap, MLA (DeepSeek latent attention), and
cache-based decode including the distributed flash-decode combine.

Memory discipline: the chunked impl never materializes (Sq, Skv) scores
-- it scans KV blocks carrying the online-softmax (m, l, acc) state, so
prefill_32k compiles at full scale (the naive impl is kept as the tiny-
shape oracle). This is the pure-JAX formulation of the flash kernel; on
TPU the same blocking is what a Pallas port would use, and the chunk
sizes are MXU/VMEM aligned.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import MLAConfig, ModelConfig
from repro.models import common
from repro.models.common import Params, Specs

NEG_INF = -1e30


def _constrain_heads(x: jax.Array, mesh, kind: str = "heads") -> jax.Array:
    """SP->TP transition at the attention boundary: (B, S, H, D) compute
    must be head-sharded with the sequence whole -- without this, the
    sequence-parallel residual carry propagates seq-sharding INTO the
    flash scan and GSPMD leaves all heads on every device (3 GB/tensor
    at deepseek's 128 MLA heads)."""
    if mesh is None or mesh.size == 1:
        return x
    from repro.core.sharding import constrain

    return constrain(x, mesh, "batch", None, kind, None)


def _use_context_parallel(cfg: ModelConfig, mesh) -> bool:
    """Head-sharded attention needs num_heads % TP == 0; otherwise GSPMD
    pads the head dim and every in-scan dynamic op on the uneven shard
    triggers involuntary full rematerialization (measured: ~46 TB/chip of
    resharding traffic at qwen's 40 heads / 16-way TP, prefill_32k).
    Context-parallel attention instead keeps Q *sequence*-sharded and
    gathers the (small, GQA) KV once per layer -- §Perf iteration 1."""
    if mesh is None or "model" not in mesh.shape:
        return False
    tp = mesh.shape["model"]
    if cfg.attn_partition == "context":
        return True
    if cfg.attn_partition == "heads":
        return False
    return cfg.num_heads % tp != 0  # auto


def _constrain_qkv(q, k, v, mesh, cfg: ModelConfig):
    """Partition q/k/v for the flash scan per the chosen scheme."""
    if mesh is None or mesh.size == 1:
        return q, k, v
    from repro.core.sharding import constrain

    if _use_context_parallel(cfg, mesh):
        q = constrain(q, mesh, "batch", "seq_act", None, None)
        k = constrain(k, mesh, "batch", None, None, None)  # gathered: KV is small
        v = constrain(v, mesh, "batch", None, None, None)
        return q, k, v
    q = constrain(q, mesh, "batch", None, "heads", None)
    k = constrain(k, mesh, "batch", None, "kv_heads", None)
    v = constrain(v, mesh, "batch", None, "kv_heads", None)
    return q, k, v


def _constrain_out(o, mesh, cfg: ModelConfig):
    if mesh is None or mesh.size == 1:
        return o
    from repro.core.sharding import constrain

    if _use_context_parallel(cfg, mesh):
        return constrain(o, mesh, "batch", "seq_act", None, None)
    return constrain(o, mesh, "batch", None, "heads", None)


class AttnSpec(NamedTuple):
    """Static per-call attention behaviour."""

    causal: bool = True
    window: int = 0  # 0 = full
    softcap: float = 0.0
    prefix: int = 0  # keys with idx < prefix always visible (meta tokens)


# ---------------------------------------------------------------------------
# Core softmax attention (naive + chunked)
# ---------------------------------------------------------------------------


def _mask(
    q_idx: jax.Array, k_idx: jax.Array, spec: AttnSpec
) -> jax.Array:
    """(..., Sq, Skv) boolean visibility. q_idx: (Sq,) or (B, Sq) for
    per-row decode positions; k_idx: (Skv,)."""
    ok = k_idx <= q_idx[..., None] if spec.causal else jnp.ones(
        q_idx.shape + k_idx.shape, bool
    )
    if spec.window > 0:
        inwin = k_idx > q_idx[..., None] - spec.window
        if spec.prefix > 0:
            inwin |= k_idx < spec.prefix
        ok &= inwin
    return ok


def attention_naive(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, KVH, D)
    v: jax.Array,  # (B, Skv, KVH, Dv)
    spec: AttnSpec,
    *,
    q_offset: int | jax.Array = 0,
) -> jax.Array:
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(d)
    s = common.softcap(s, spec.softcap)
    q_idx = q_offset + jnp.arange(sq)
    k_idx = jnp.arange(k.shape[1])
    s = jnp.where(_mask(q_idx, k_idx, spec), s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhe->bqhge", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    spec: AttnSpec,
    *,
    q_offset: int | jax.Array = 0,
    kv_chunk: int = 512,
    kv_valid_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Flash-style online softmax over KV chunks (O(Sq) memory).

    ``kv_valid_len``: number of valid cache entries (decode with a
    preallocated ring/linear cache).
    """
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    dv = v.shape[-1]
    kv_chunk = min(kv_chunk, skv)
    n_chunks = (skv + kv_chunk - 1) // kv_chunk
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = (q / math.sqrt(d)).reshape(b, sq, kvh, g, d)  # stay bf16; f32 via dot accum
    q_off = jnp.asarray(q_offset)
    q_idx = q_off[..., None] + jnp.arange(sq) if q_off.ndim else q_off + jnp.arange(sq)

    kc = k.reshape(b, n_chunks, kv_chunk, kvh, d)
    vc = v.reshape(b, n_chunks, kv_chunk, kvh, dv)

    def step(carry, inp):
        m, l, acc = carry
        ci, kb, vb = inp
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg, kb, preferred_element_type=jnp.float32
        )
        s = common.softcap(s, spec.softcap)
        k_idx = ci * kv_chunk + jnp.arange(kv_chunk)
        ok = _mask(q_idx, k_idx, spec)  # (Sq,K) or (B,Sq,K)
        if kv_valid_len is not None:
            valid = jnp.asarray(kv_valid_len)
            ok = ok & (k_idx < valid[..., None, None] if valid.ndim else k_idx < valid)
        ok = ok & (k_idx < skv)  # padding
        if ok.ndim == 2:  # (Sq, K) -> broadcast over (B, KVH, G)
            ok = ok[None, None, None]
        else:  # (B, Sq, K) -> (B, 1, 1, Sq, K)
            ok = ok[:, None, None]
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhe->bhgqe", p.astype(vb.dtype), vb, preferred_element_type=jnp.float32
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, dv), jnp.float32)
    # flash-style backward: recompute per-chunk scores instead of saving
    # them (the inner scan would otherwise stash (Sq, kv_chunk) f32 score/
    # prob tensors per step for autodiff -- exactly what flash avoids).
    step = jax.checkpoint(step)
    (m, l, acc), _ = lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_chunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# flash attention with custom VJP (train path: q_offset=0, no valid_len)
#
# The plain chunked scan saves its (m, l, acc) carry at EVERY kv step for
# autodiff -- O(n_chunks) copies of the (B,H,Sq,Dv) f32 accumulator
# (~17 GB/layer at deepseek MLA train shapes). Flash's backward instead
# recomputes per-chunk probabilities from the final logsumexp stats:
#     p = exp(s - L);  dv += p^T dO;  dp = dO v^T
#     ds = p * (dp - rowsum(dO*O)) [* dsoftcap];  dq += ds k;  dk += ds^T q
# so the residuals are just (q, k, v, out, L).
# ---------------------------------------------------------------------------


def _flash_fwd_scan(qg, kc, vc, spec: AttnSpec, skv: int, kv_chunk: int):
    b, sq, kvh, g, d = qg.shape
    dv = vc.shape[-1]
    n_chunks = kc.shape[1]
    q_idx = jnp.arange(sq)

    def step(carry, inp):
        m, l, acc = carry
        ci, kb, vb = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb, preferred_element_type=jnp.float32)
        s = common.softcap(s, spec.softcap)
        k_idx = ci * kv_chunk + jnp.arange(kv_chunk)
        ok = _mask(q_idx, k_idx, spec) & (k_idx < skv)
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhe->bhgqe", p.astype(vb.dtype), vb, preferred_element_type=jnp.float32
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, dv), jnp.float32)
    (m, l, acc), _ = lax.scan(
        step, (m0, l0, a0),
        (jnp.arange(n_chunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))  # (B,KVH,G,Sq)
    return out, lse


def _make_flash(spec: AttnSpec, kv_chunk: int, skv: int):
    @jax.custom_vjp
    def flash(qg, kc, vc):
        out, _ = _flash_fwd_scan(qg, kc, vc, spec, skv, kv_chunk)
        return out

    def fwd(qg, kc, vc):
        out, lse = _flash_fwd_scan(qg, kc, vc, spec, skv, kv_chunk)
        return out, (qg, kc, vc, out, lse)

    def bwd(res, dout):
        qg, kc, vc, out, lse = res
        b, sq, kvh, g, d = qg.shape
        n_chunks = kc.shape[1]
        kv_ch = kc.shape[2]
        q_idx = jnp.arange(sq)
        dout = dout.astype(jnp.float32)
        dmat = jnp.sum(dout * out, axis=-1)  # (B,KVH,G,Sq)

        def step(dq_acc, inp):
            ci, kb, vb = inp
            s_raw = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb, preferred_element_type=jnp.float32)
            if spec.softcap > 0:
                t = jnp.tanh(s_raw / spec.softcap)
                s = spec.softcap * t
                dcap = 1.0 - t * t
            else:
                s = s_raw
                dcap = None
            k_idx = ci * kv_chunk + jnp.arange(kv_ch)
            ok = _mask(q_idx, k_idx, spec) & (k_idx < skv)
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lse[..., None])  # (B,KVH,G,Sq,K)
            pv = p.astype(vb.dtype)
            dv_c = jnp.einsum("bhgqk,bhgqe->bkhe", pv, dout.astype(vb.dtype),
                              preferred_element_type=jnp.float32)
            dp = jnp.einsum("bhgqe,bkhe->bhgqk", dout, vb, preferred_element_type=jnp.float32)
            ds = p * (dp - dmat[..., None])
            if dcap is not None:
                ds = ds * dcap
            dsv = ds.astype(kb.dtype)
            dq_c = jnp.einsum("bhgqk,bkhd->bqhgd", dsv, kb, preferred_element_type=jnp.float32)
            dk_c = jnp.einsum("bhgqk,bqhgd->bkhd", dsv, qg, preferred_element_type=jnp.float32)
            return dq_acc + dq_c, (dk_c, dv_c)

        dq0 = jnp.zeros(qg.shape, jnp.float32)
        dq, (dks, dvs) = lax.scan(
            jax.checkpoint(step), dq0,
            (jnp.arange(n_chunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
        )
        dk = jnp.moveaxis(dks, 0, 1).astype(kc.dtype)
        dv = jnp.moveaxis(dvs, 0, 1).astype(vc.dtype)
        return dq.astype(qg.dtype), dk, dv

    flash.defvjp(fwd, bwd)
    return flash


def flash_attention_train(q, k, v, spec: AttnSpec, *, kv_chunk: int = 512) -> jax.Array:
    """Memory-optimal flash for the train/prefill path (q_offset=0)."""
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    dvd = v.shape[-1]
    kv_chunk = min(kv_chunk, skv)
    n_chunks = (skv + kv_chunk - 1) // kv_chunk
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = (q / math.sqrt(d)).reshape(b, sq, kvh, g, d)
    kc = k.reshape(b, n_chunks, kv_chunk, kvh, d)
    vc = v.reshape(b, n_chunks, kv_chunk, kvh, dvd)
    flash = _make_flash(spec, kv_chunk, skv)
    out = flash(qg, kc, vc)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, dvd)
    return out.astype(q.dtype)


def attention(
    q, k, v, spec: AttnSpec, *, impl: str = "chunked", q_offset=0, kv_chunk: int = 512,
    kv_valid_len=None,
) -> jax.Array:
    if impl == "naive":
        assert kv_valid_len is None
        return attention_naive(q, k, v, spec, q_offset=q_offset)
    if kv_valid_len is None and isinstance(q_offset, int) and q_offset == 0:
        return flash_attention_train(q, k, v, spec, kv_chunk=kv_chunk)
    return attention_chunked(
        q, k, v, spec, q_offset=q_offset, kv_chunk=kv_chunk, kv_valid_len=kv_valid_len
    )


# ---------------------------------------------------------------------------
# GQA projection layer
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> Tuple[Params, Specs]:
    """Weights stored FLAT -- (d, H*hd) not (d, H, hd) -- so the TP axis
    shards the flattened head dim, which divides 16 even when the head
    count doesn't (qwen 40H, hymba 25H, phi3-medium 10 kv heads)."""
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.dense_init(ks[0], (d, h * hd)),
        "wk": common.dense_init(ks[1], (d, kvh * hd)),
        "wv": common.dense_init(ks[2], (d, kvh * hd)),
        "wo": common.dense_init(ks[3], (h * hd, d)),
    }
    s = {
        "wq": ("fsdp", "heads"),
        "wk": ("fsdp", "kv_heads"),
        "wv": ("fsdp", "kv_heads"),
        "wo": ("heads", "fsdp"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kvh * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kvh * hd,), jnp.float32)
        s["bq"] = ("heads",)
        s["bk"] = ("kv_heads",)
        s["bv"] = ("kv_heads",)
    return p, s


def qkv_proj(p: Params, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    dt = x.dtype
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    if cfg.rope_theta > 0:
        q = common.rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = common.rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    return q, k, v


def out_proj(p: Params, attn_out: jax.Array) -> jax.Array:
    b, s, h, hd = attn_out.shape
    flat = attn_out.reshape(b, s, h * hd)
    return jnp.einsum("bse,ed->bsd", flat, p["wo"].astype(attn_out.dtype))


def apply_attention(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    spec: AttnSpec,
    *,
    positions: Optional[jax.Array] = None,
    impl: str = "chunked",
    mesh=None,
) -> jax.Array:
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = qkv_proj(p, x, cfg, positions)
    q, k, v = _constrain_qkv(q, k, v, mesh, cfg)
    o = attention(q, k, v, spec, impl=impl, kv_chunk=cfg.attn_kv_chunk)
    o = _constrain_out(o, mesh, cfg)
    return out_proj(p, o)


# --- decode with cache -------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, KVH, D)
    v: jax.Array
    length: jax.Array  # (B,) int32 -- valid entries per row (ragged slots)


def init_kv_cache(b: int, s_max: int, kvh: int, hd: int, dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((b, s_max, kvh, hd), dtype),
        v=jnp.zeros((b, s_max, kvh, hd), dtype),
        length=jnp.zeros((b,), jnp.int32),
    )


def decode_attention(
    p: Params,
    x: jax.Array,  # (B, 1, d)
    cache: KVCache,
    cfg: ModelConfig,
    spec: AttnSpec,
    *,
    kv_chunk: int = 512,
) -> Tuple[jax.Array, KVCache]:
    """One decode step: append K/V at each row's cache.length, attend over
    the cache. Rows may be at different positions (serving slots)."""
    pos = cache.length  # (B,)
    b = x.shape[0]
    q, k, v = qkv_proj(p, x, cfg, positions=pos[:, None])
    rows = jnp.arange(b)
    kc = cache.k.at[rows, pos].set(k[:, 0].astype(cache.k.dtype))
    vc = cache.v.at[rows, pos].set(v[:, 0].astype(cache.v.dtype))
    new = KVCache(kc, vc, pos + 1)
    o = attention_chunked(
        q, kc, vc, spec, q_offset=pos, kv_chunk=kv_chunk, kv_valid_len=pos + 1
    )
    return out_proj(p, o), new


def prefill_attention(
    p: Params,
    x: jax.Array,  # (B, S, d)
    cache: KVCache,
    cfg: ModelConfig,
    spec: AttnSpec,
    *,
    impl: str = "chunked",
    mesh=None,
) -> Tuple[jax.Array, KVCache]:
    """Causal full-sequence pass that also populates the KV cache[0:S]."""
    b, s, _ = x.shape
    q, k, v = qkv_proj(p, x, cfg, positions=jnp.arange(s))
    # cache rows written from the pre-gather (cache-layout) K/V
    kc = lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), 0, axis=1)
    vc = lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), 0, axis=1)
    q, k, v = _constrain_qkv(q, k, v, mesh, cfg)
    o = attention(q, k, v, spec, impl=impl, kv_chunk=cfg.attn_kv_chunk)
    o = _constrain_out(o, mesh, cfg)
    length = jnp.full((b,), s, jnp.int32)
    return out_proj(p, o), KVCache(kc, vc, length)


def flash_decode_combine(
    partial_out: jax.Array,  # (B, 1, H, Dv) local
    partial_m: jax.Array,  # (B, H) local max
    partial_l: jax.Array,  # (B, H) local sum
    axis_name: str,
) -> jax.Array:
    """Distributed decode over sequence-sharded KV: each shard computes a
    partial online-softmax; the global combine rescales by the global max
    and sums -- a decomposed collective in the spirit of the paper's
    scatter (the combine is two small psums instead of gathering KV)."""
    m_glob = lax.pmax(partial_m, axis_name)
    scale = jnp.exp(partial_m - m_glob)  # (B, H)
    num = lax.psum(partial_out * scale[:, None, :, None], axis_name)
    den = lax.psum(partial_l * scale, axis_name)
    return num / jnp.maximum(den[:, None, :, None], 1e-30)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> Tuple[Params, Specs]:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qd = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wdq": common.dense_init(ks[0], (d, m.q_lora_rank)),
        "wuq": common.dense_init(ks[1], (m.q_lora_rank, h * qd)),
        "wdkv": common.dense_init(ks[2], (d, m.kv_lora_rank + m.rope_head_dim)),
        "wukv": common.dense_init(ks[3], (m.kv_lora_rank, h * (m.nope_head_dim + m.v_head_dim))),
        "wo": common.dense_init(ks[4], (h * m.v_head_dim, d)),
    }
    nq, _ = common.init_norm(m.q_lora_rank, "rmsnorm")
    nkv, _ = common.init_norm(m.kv_lora_rank, "rmsnorm")
    p["q_norm"], p["kv_norm"] = nq, nkv
    s = {
        "wdq": ("fsdp", None),
        "wuq": (None, "heads"),
        "wdkv": ("fsdp", None),
        "wukv": (None, "heads"),
        "wo": ("heads", "fsdp"),
        "q_norm": {"scale": (None,)},
        "kv_norm": {"scale": (None,)},
    }
    return p, s


def _mla_qkv(p, x, cfg, positions):
    m: MLAConfig = cfg.mla
    h = cfg.num_heads
    dt = x.dtype
    cq = common.apply_norm(p["q_norm"], jnp.einsum("bsd,dr->bsr", x, p["wdq"].astype(dt)), "rmsnorm")
    qd = m.nope_head_dim + m.rope_head_dim
    q = jnp.einsum("bsr,re->bse", cq, p["wuq"].astype(dt))
    q = q.reshape(q.shape[0], q.shape[1], h, qd)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim :]
    q_rope = common.rope(q_rope, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wdkv"].astype(dt))
    ckv = common.apply_norm(p["kv_norm"], ckv_full[..., : m.kv_lora_rank], "rmsnorm")
    k_rope = ckv_full[..., m.kv_lora_rank :][:, :, None, :]  # (B,S,1,rope_d)
    k_rope = common.rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, ckv, k_rope


def apply_mla(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    spec: AttnSpec,
    *,
    positions: Optional[jax.Array] = None,
    impl: str = "chunked",
    mesh=None,
) -> jax.Array:
    """Training/prefill MLA: expand latent to per-head K/V, run GQA=MHA."""
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    if positions is None:
        positions = jnp.arange(s)
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, x, cfg, positions)
    kv = jnp.einsum("bsr,re->bse", ckv, p["wukv"].astype(x.dtype))
    kv = kv.reshape(b, s, h, m.nope_head_dim + m.v_head_dim)
    k_nope, v = kv[..., : m.nope_head_dim], kv[..., m.nope_head_dim :]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.rope_head_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = _constrain_heads(q, mesh, "heads")
    k = _constrain_heads(k, mesh, "heads")
    v = _constrain_heads(v, mesh, "heads")
    o = attention(q, k, v, spec, impl=impl)
    o = _constrain_heads(o, mesh, "heads")
    o = o.reshape(b, s, h * m.v_head_dim)
    return jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))


class MLACache(NamedTuple):
    ckv: jax.Array  # (B, S_max, kv_lora_rank)
    k_rope: jax.Array  # (B, S_max, rope_head_dim)
    length: jax.Array  # (B,)


def init_mla_cache(b: int, s_max: int, m: MLAConfig, dtype=jnp.bfloat16) -> MLACache:
    return MLACache(
        ckv=jnp.zeros((b, s_max, m.kv_lora_rank), dtype),
        k_rope=jnp.zeros((b, s_max, m.rope_head_dim), dtype),
        length=jnp.zeros((b,), jnp.int32),
    )


def prefill_mla(
    p: Params, x: jax.Array, cache: MLACache, cfg: ModelConfig, spec: AttnSpec,
    *, impl: str = "chunked",
) -> Tuple[jax.Array, MLACache]:
    """Full-sequence MLA pass that populates the latent cache[0:S]."""
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, x, cfg, positions)
    new = MLACache(
        ckv=lax.dynamic_update_slice_in_dim(cache.ckv, ckv.astype(cache.ckv.dtype), 0, axis=1),
        k_rope=lax.dynamic_update_slice_in_dim(
            cache.k_rope, k_rope[:, :, 0, :].astype(cache.k_rope.dtype), 0, axis=1
        ),
        length=jnp.full((b,), s, jnp.int32),
    )
    h = cfg.num_heads
    kv = jnp.einsum("bsr,re->bse", ckv, p["wukv"].astype(x.dtype))
    kv = kv.reshape(b, s, h, m.nope_head_dim + m.v_head_dim)
    k_nope, v = kv[..., : m.nope_head_dim], kv[..., m.nope_head_dim :]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.rope_head_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = attention(q, k, v, spec, impl=impl)
    o = o.reshape(b, s, h * m.v_head_dim)
    return jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype)), new


def decode_mla(
    p: Params, x: jax.Array, cache: MLACache, cfg: ModelConfig, spec: AttnSpec
) -> Tuple[jax.Array, MLACache]:
    """Absorbed-matrix MLA decode: scores against the *latent* cache.

    score_h = (W_uk[h]^T q_nope[h]) . ckv + q_rope[h] . k_rope, so the
    cache stays rank-(kv_lora + rope_d) per token -- MLA's raison d'etre.
    """
    m: MLAConfig = cfg.mla
    h = cfg.num_heads
    pos = cache.length  # (B,)
    b = x.shape[0]
    rows = jnp.arange(b)
    q_nope, q_rope, ckv_t, k_rope_t = _mla_qkv(p, x, cfg, positions=pos[:, None])
    ckv_c = cache.ckv.at[rows, pos].set(ckv_t[:, 0].astype(cache.ckv.dtype))
    kr_c = cache.k_rope.at[rows, pos].set(k_rope_t[:, 0, 0, :].astype(cache.k_rope.dtype))
    new = MLACache(ckv_c, kr_c, pos + 1)

    wukv = p["wukv"].reshape(m.kv_lora_rank, h, m.nope_head_dim + m.v_head_dim)
    wuk = wukv[..., : m.nope_head_dim].astype(x.dtype)  # (r, h, nope)
    wuv = wukv[..., m.nope_head_dim :].astype(x.dtype)  # (r, h, v)
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, wuk)  # absorbed query
    s_lat = jnp.einsum("bshr,btr->bhst", q_lat, ckv_c.astype(x.dtype))
    s_rope = jnp.einsum("bshe,bte->bhst", q_rope, kr_c.astype(x.dtype))
    scores = (s_lat + s_rope).astype(jnp.float32) / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    t_idx = jnp.arange(scores.shape[-1])
    scores = jnp.where((t_idx <= pos[:, None])[:, None, None, :], scores, NEG_INF)
    pr = jax.nn.softmax(scores, axis=-1)
    lat_sum = jnp.einsum("bhst,btr->bshr", pr.astype(x.dtype), ckv_c.astype(x.dtype))
    o = jnp.einsum("bshr,rhe->bshe", lat_sum, wuv)
    o = o.reshape(b, o.shape[1], h * m.v_head_dim)
    return jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype)), new
