"""Model zoo: the 10 assigned architectures as composable blocks."""

from repro.models.model import Model, build_groups

__all__ = ["Model", "build_groups"]
