"""Model assembly: embeddings -> scanned layer groups -> head.

Layer-stacking strategy (DESIGN.md §5): params of structurally identical
layers are stacked along a leading axis and executed with ``lax.scan``
(+ configurable remat). This keeps the HLO size O(1) in depth -- the
512-device dry-run compiles 61-layer/671B graphs in seconds-to-minutes
on one CPU core. Mask-only layer differences ride a per-layer flag
vector; structural differences (deepseek dense-prefix vs MoE, xlstm
mLSTM/sLSTM pairs, whisper enc/dec) become separate groups.

Public surface:
    Model(cfg, mesh).init(key) -> (params, specs)
    .loss(params, batch)                      train forward + CE (+MTP)
    .hidden(params, batch)                    trunk only (B,S,d)
    .logits(params, batch)                    full logits (small shapes)
    .init_decode_state(b, s_max) / .prefill / .decode_step
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import blocks, common, losses, ssm
from repro.models.common import Params, Specs


def _is_spec_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def _stack_specs(specs, extra=(None,)):
    return jax.tree.map(lambda t: tuple(extra) + tuple(t), specs, is_leaf=_is_spec_leaf)


@dataclasses.dataclass(frozen=True)
class Group:
    name: str
    kind: str  # dec | dec_moe | hymba | xlstm_pair | enc
    count: int
    flags: Optional[Tuple[bool, ...]]  # per-layer is_global; None -> static
    static_global: bool = True
    cross: bool = False  # whisper decoder


def build_groups(cfg: ModelConfig) -> List[Group]:
    L = cfg.num_layers
    if cfg.family == "ssm":  # xlstm
        every = cfg.ssm.slstm_every
        if every and every != 2:
            raise NotImplementedError("xlstm grouping implemented for slstm_every in (0, 2)")
        if every == 2:
            return [Group("pairs", "xlstm_pair", L // 2, None)]
        return [Group("mlstm", "xlstm_m", L, None)]

    def flags_for(pattern: str) -> Optional[Tuple[bool, ...]]:
        if cfg.window_size <= 0:
            return None  # full attention everywhere -> static global
        if pattern == "alternate":
            return tuple(i % 2 == 1 for i in range(L))
        if pattern == "ends":
            return tuple(i in (0, L // 2, L - 1) for i in range(L))
        return tuple(False for _ in range(L))  # SWA everywhere

    flags = flags_for(cfg.global_pattern)
    static = cfg.window_size <= 0
    groups: List[Group] = []
    if cfg.is_encdec:
        groups.append(Group("encoder", "enc", cfg.encoder_layers, None))
        groups.append(Group("decoder", "dec", L, None, static_global=True, cross=True))
        return groups
    if cfg.family == "hybrid":
        return [Group("hymba", "hymba", L, flags, static_global=static)]
    if cfg.moe is not None:
        fk = cfg.moe.first_k_dense
        if fk:
            d_ff = cfg.moe.dense_d_ff or cfg.d_ff
            groups.append(Group("dense_prefix", "dec", fk, None, static_global=static))
        gflags = None if flags is None else flags[fk:]
        groups.append(Group("moe", "dec_moe", L - fk, gflags, static_global=static))
        return groups
    return [Group("layers", "dec", L, flags, static_global=static)]


def _group_init_fn(g: Group, cfg: ModelConfig):
    if g.kind in ("dec", "dec_moe"):
        return functools.partial(
            blocks.init_decoder_block, cfg=cfg, use_moe=g.kind == "dec_moe", cross=g.cross
        )
    if g.kind == "hymba":
        return functools.partial(blocks.init_hymba_block, cfg=cfg)
    if g.kind == "xlstm_pair":
        return functools.partial(blocks.init_xlstm_pair, cfg=cfg)
    if g.kind == "xlstm_m":
        def init_m(key, cfg=cfg):
            p, s = ssm.init_mlstm_block(key, cfg)
            pn, sn = common.init_norm(cfg.d_model, cfg.norm_kind)
            return {"m": p, "lnm": pn}, {"m": s, "lnm": sn}
        return init_m
    if g.kind == "enc":
        return functools.partial(blocks.init_encoder_block, cfg=cfg)
    raise ValueError(g.kind)


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)  # full


class Model:
    def __init__(self, cfg: ModelConfig, mesh=None, *, attn_impl: str = "chunked"):
        self.cfg = cfg
        self.mesh = mesh
        self.attn_impl = attn_impl
        self.groups = build_groups(cfg)
        self.dtype = jnp.dtype(cfg.dtype)

    def _cast(self, params):
        """Cast float params to compute dtype ONCE at step entry: the cast
        runs on the local FSDP shard, so ZeRO-style weight all-gathers move
        bf16, not f32 (2x collective bytes otherwise -- the convert would
        land *after* the gather)."""
        if self.dtype == jnp.float32:
            return params
        return jax.tree.map(
            lambda a: a.astype(self.dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a,
            params,
        )

    # ------------------------------------------------------------------ init
    def init(self, key) -> Tuple[Params, Specs]:
        cfg = self.cfg
        keys = jax.random.split(key, len(self.groups) + 3)
        pe, se = common.init_embed(keys[0], cfg.vocab_size, cfg.d_model, cfg.tie_embeddings)
        params: Dict[str, Any] = {"embed": pe}
        specs: Dict[str, Any] = {"embed": se}
        pn, sn = common.init_norm(cfg.d_model, cfg.norm_kind)
        params["final_norm"], specs["final_norm"] = pn, sn
        if cfg.meta_tokens:
            params["meta"] = common.trunc_normal(keys[1], (cfg.meta_tokens, cfg.d_model), 1.0)
            specs["meta"] = (None, "fsdp")
        for g, k in zip(self.groups, keys[2:]):
            init_fn = _group_init_fn(g, self.cfg)
            _, gspecs = init_fn(jax.random.PRNGKey(0))
            gparams = jax.vmap(lambda kk: init_fn(kk)[0])(jax.random.split(k, g.count))
            params[g.name] = gparams
            specs[g.name] = _stack_specs(gspecs)
        if cfg.mtp_depth > 0:
            k = keys[-1]
            km, kp = jax.random.split(k)
            use_moe = cfg.moe is not None and cfg.moe.first_k_dense < cfg.num_layers
            pb, sb = blocks.init_decoder_block(km, cfg, use_moe=use_moe)
            params["mtp"] = {
                "proj": common.dense_init(kp, (2 * cfg.d_model, cfg.d_model)),
                "block": pb,
                "norm_h": common.init_norm(cfg.d_model, cfg.norm_kind)[0],
                "norm_e": common.init_norm(cfg.d_model, cfg.norm_kind)[0],
            }
            specs["mtp"] = {
                "proj": ("fsdp", None),
                "block": sb,
                "norm_h": common.init_norm(cfg.d_model, cfg.norm_kind)[1],
                "norm_e": common.init_norm(cfg.d_model, cfg.norm_kind)[1],
            }
        return params, specs

    # ------------------------------------------------------------- embedding
    def _embed_in(self, params, batch) -> jax.Array:
        cfg = self.cfg
        if "embeds" in batch:
            x = batch["embeds"].astype(self.dtype)
        else:
            x = common.embed_tokens(params["embed"], batch["tokens"], self.dtype)
        if cfg.tie_embeddings:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), self.dtype)
        if cfg.is_encdec or cfg.rope_theta <= 0:
            s = x.shape[1]
            x = x + common.sinusoidal_positions(s, cfg.d_model, self.dtype)
        if cfg.meta_tokens:
            m = jnp.broadcast_to(
                params["meta"].astype(self.dtype), (x.shape[0],) + params["meta"].shape
            )
            x = jnp.concatenate([m, x], axis=1)
        return x

    def _unemb_fn(self, params):
        cfg = self.cfg

        def f(x):
            from repro.core.sharding import constrain

            out = common.unembed(params["embed"], x, cfg.tie_embeddings)
            if self.mesh is not None:
                out = constrain(out, self.mesh, "batch", None, "vocab")
            return out

        return f

    # ------------------------------------------------------------ group scan
    def _run_group(self, g: Group, gparams, x, *, positions=None, enc_out=None):
        cfg, mesh, impl = self.cfg, self.mesh, self.attn_impl

        def body_fn(x, p, flag):
            if g.kind == "enc":
                return blocks.apply_encoder_block(p, x, cfg, impl=impl), jnp.zeros((), jnp.float32)
            if g.kind in ("dec", "dec_moe"):
                cross_kv = blocks.cross_kv_proj(p, enc_out, cfg) if g.cross else None
                return blocks.apply_decoder_block(
                    p, x, cfg, is_global=flag, use_moe=g.kind == "dec_moe",
                    positions=positions, impl=impl, mesh=mesh, cross_kv=cross_kv,
                )
            if g.kind == "hymba":
                y, _ = blocks.apply_hymba_block(
                    p, x, cfg, is_global=flag, positions=positions, impl=impl, mesh=mesh
                )
                return y, jnp.zeros((), jnp.float32)
            if g.kind == "xlstm_pair":
                y, _ = blocks.apply_xlstm_pair(p, x, cfg, mesh=mesh)
                return y, jnp.zeros((), jnp.float32)
            if g.kind == "xlstm_m":
                h = common.apply_norm(p["lnm"], x, cfg.norm_kind)
                o, _ = ssm.apply_mlstm_block(p["m"], h, cfg)
                return x + o, jnp.zeros((), jnp.float32)
            raise ValueError(g.kind)

        flags_arr = None if g.flags is None else jnp.asarray(g.flags)

        def scan_body(carry, xs):
            x, aux = carry
            if flags_arr is None:
                p = xs
                y, a = body_fn(x, p, g.static_global)
            else:
                p, flag = xs
                y, a = body_fn(x, p, flag)
            if mesh is not None:
                from repro.core.sharding import constrain

                # Megatron-style sequence parallelism: the scan carry is
                # what remat saves per layer -- sharding its seq dim over
                # the TP axis divides saved-activation HBM by TP width
                # (the all-gather back to full seq happens inside the
                # next layer's attention, where TP compute needs it).
                seq_ax = "seq_act" if cfg.seq_parallel else None
                y = constrain(y, mesh, "batch", seq_ax, None)
            return (y, aux + a), None

        scan_body = _remat(scan_body, cfg.remat)
        xs = gparams if flags_arr is None else (gparams, flags_arr)
        (x, aux), _ = lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)), xs)
        return x, aux

    # ---------------------------------------------------------------- trunk
    def hidden(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        """Returns (final hidden (B, S[, +meta], d) normalized, aux loss)."""
        cfg = self.cfg
        params = self._cast(params)
        aux = jnp.zeros((), jnp.float32)
        if cfg.is_encdec:
            enc = self._embed_in(params, {"embeds": batch["enc_embeds"]})
            enc, a = self._run_group(self.groups[0], params[self.groups[0].name], enc)
            aux += a
            dec = common.embed_tokens(params["embed"], batch["tokens"], self.dtype)
            dec = dec + common.sinusoidal_positions(dec.shape[1], cfg.d_model, self.dtype)
            x, a = self._run_group(self.groups[1], params[self.groups[1].name], dec, enc_out=enc)
            aux += a
        else:
            x = self._embed_in(params, batch)
            positions = jnp.arange(x.shape[1])
            for g in self.groups:
                x, a = self._run_group(g, params[g.name], x, positions=positions)
                aux += a
        x = common.apply_norm(params["final_norm"], x, cfg.norm_kind)
        if cfg.meta_tokens:
            x = x[:, cfg.meta_tokens :]
        return x, aux

    def logits(self, params, batch) -> jax.Array:
        """Full logits -- small shapes only (tests / serving)."""
        x, _ = self.hidden(params, batch)
        out = self._unemb_fn(params)(x)
        return common.softcap(out.astype(jnp.float32), self.cfg.final_logit_softcap)

    # ----------------------------------------------------------------- loss
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x, aux = self.hidden(params, batch)
        nll, zl = losses.chunked_xent(
            x,
            batch["labels"],
            self._unemb_fn(params),
            z_loss=1e-4,
            final_softcap=cfg.final_logit_softcap,
        )
        total = nll + zl
        metrics = {"nll": nll, "z_loss": zl}
        if cfg.moe is not None:
            total = total + cfg.moe.router_aux_weight * aux
            metrics["moe_aux"] = aux
        if cfg.mtp_depth > 0 and "tokens" in batch:
            mtp_nll = jax.checkpoint(self._mtp_loss)(params, x, batch)
            total = total + 0.3 * mtp_nll
            metrics["mtp_nll"] = mtp_nll
        metrics["loss"] = total
        return total, metrics

    def _mtp_loss(self, params, h, batch) -> jax.Array:
        """DeepSeek MTP (depth 1): predict token t+2 from [h_t; emb(t+1)]."""
        cfg = self.cfg
        p = params["mtp"]
        tokens, labels = batch["tokens"], batch["labels"]
        emb_next = common.embed_tokens(params["embed"], tokens[:, 1:], self.dtype)
        hh = common.apply_norm(p["norm_h"], h[:, :-1], cfg.norm_kind)
        ee = common.apply_norm(p["norm_e"], emb_next, cfg.norm_kind)
        z = jnp.concatenate([hh, ee], axis=-1)
        z = jnp.einsum("bsd,de->bse", z, p["proj"].astype(self.dtype))
        use_moe = cfg.moe is not None and cfg.moe.first_k_dense < cfg.num_layers
        z, _ = blocks.apply_decoder_block(
            p["block"], z, cfg, is_global=True, use_moe=use_moe, impl=self.attn_impl,
            mesh=self.mesh,
        )
        mtp_labels = labels[:, 1:]  # label at t+1 predicts token t+2
        nll, _ = losses.chunked_xent(z, mtp_labels, self._unemb_fn(params))
        return nll

    # --------------------------------------------------------------- decode
    def init_decode_state(self, b: int, s_max: int, cache_dtype=jnp.bfloat16):
        cfg = self.cfg
        s_tot = s_max + cfg.meta_tokens
        state: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
        for g in self.groups:
            if g.kind == "enc":
                continue
            if g.kind in ("dec", "dec_moe"):
                one = blocks.init_block_cache(cfg, b, s_tot, cache_dtype)
                state[g.name] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (g.count,) + a.shape), one
                )
            elif g.kind == "hymba":
                di = int(cfg.ssm.expand * cfg.d_model)
                one = blocks.HymbaState(
                    kv=attn_mod.init_kv_cache(b, s_tot, cfg.num_kv_heads, cfg.head_dim_, cache_dtype),
                    mamba=ssm.init_mamba_state(b, di, cfg.ssm.state_dim, cfg.ssm.conv_dim),
                )
                state[g.name] = jax.tree.map(lambda a: jnp.broadcast_to(a, (g.count,) + a.shape), one)
            elif g.kind in ("xlstm_pair", "xlstm_m"):
                di = int(cfg.ssm.expand * cfg.d_model)
                dh = di // cfg.num_heads
                mb = ssm.MLSTMBlockState(
                    cell=ssm.init_mlstm_state(b, cfg.num_heads, dh, dh),
                    conv=jnp.zeros((b, 3, di), jnp.float32),
                )
                if g.kind == "xlstm_pair":
                    one = blocks.XLSTMPairState(m=mb, s=ssm.init_slstm_state(b, cfg.d_model))
                else:
                    one = mb
                state[g.name] = jax.tree.map(lambda a: jnp.broadcast_to(a, (g.count,) + a.shape), one)
        return state

    def prefill(self, params, batch, state) -> Tuple[Dict, jax.Array]:
        """Run the prompt through the model, filling caches. Returns
        (state, last-position logits (B, V))."""
        cfg = self.cfg
        params = self._cast(params)
        if cfg.is_encdec:
            return self._prefill_encdec(params, batch, state)
        x = self._embed_in(params, batch)
        positions = jnp.arange(x.shape[1])
        for g in self.groups:
            x, state[g.name] = self._prefill_group(g, params[g.name], x, state[g.name], positions)
        x = common.apply_norm(params["final_norm"], x, cfg.norm_kind)
        state["pos"] = jnp.asarray(x.shape[1], jnp.int32)
        logits = self._unemb_fn(params)(x[:, -1:])[:, 0]
        return state, common.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)

    def _prefill_group(self, g: Group, gparams, x, gstate, positions):
        cfg, impl, mesh = self.cfg, self.attn_impl, self.mesh
        flags_arr = None if g.flags is None else jnp.asarray(g.flags)

        def body(x, p, st, flag):
            if g.kind in ("dec", "dec_moe"):
                return blocks.prefill_decoder_block(
                    p, x, cfg, st, is_global=flag, use_moe=g.kind == "dec_moe", impl=impl, mesh=mesh
                )
            if g.kind == "hymba":
                return blocks.prefill_hymba_block(p, x, cfg, st, is_global=flag, impl=impl, mesh=mesh)
            if g.kind == "xlstm_pair":
                return blocks.apply_xlstm_pair(p, x, cfg, st)
            if g.kind == "xlstm_m":
                h = common.apply_norm(p["lnm"], x, cfg.norm_kind)
                o, st2 = ssm.apply_mlstm_block(p["m"], h, cfg, st)
                return x + o, st2
            raise ValueError(g.kind)

        def scan_body(x, xs):
            if flags_arr is None:
                p, st = xs
                y, st2 = body(x, p, st, g.static_global)
            else:
                p, st, flag = xs
                y, st2 = body(x, p, st, flag)
            return y, st2

        xs = (gparams, gstate) if flags_arr is None else (gparams, gstate, flags_arr)
        x, new_state = lax.scan(scan_body, x, xs)
        return x, new_state

    def decode_step(self, params, tokens, state) -> Tuple[jax.Array, Dict]:
        """tokens: (B, 1) -> (logits (B, V), new state)."""
        cfg = self.cfg
        params = self._cast(params)
        x = common.embed_tokens(params["embed"], tokens, self.dtype)
        if cfg.tie_embeddings:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), self.dtype)
        if cfg.is_encdec or cfg.rope_theta <= 0:
            x = x + self._abs_pos(state["pos"])
        for g in self.groups:
            if g.kind == "enc":
                continue
            x, state[g.name] = self._decode_group(g, params[g.name], x, state[g.name], state)
        x = common.apply_norm(params["final_norm"], x, cfg.norm_kind)
        state["pos"] = state["pos"] + 1
        logits = self._unemb_fn(params)(x)[:, 0]
        return common.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap), state

    def _abs_pos(self, pos):
        cfg = self.cfg
        half = cfg.d_model // 2
        dim = jnp.arange(half, dtype=jnp.float32)
        ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * dim / cfg.d_model)
        return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :].astype(self.dtype)

    def _decode_group(self, g: Group, gparams, x, gstate, full_state):
        cfg, mesh = self.cfg, self.mesh
        flags_arr = None if g.flags is None else jnp.asarray(g.flags)

        def body(x, p, st, flag, cross_kv=None):
            if g.kind in ("dec", "dec_moe"):
                return blocks.decode_decoder_block(
                    p, x, cfg, st, is_global=flag, use_moe=g.kind == "dec_moe", mesh=mesh,
                    cross_kv=cross_kv,
                )
            if g.kind == "hymba":
                return blocks.decode_hymba_block(p, x, cfg, st, is_global=flag)
            if g.kind == "xlstm_pair":
                return blocks.decode_xlstm_pair(p, x, cfg, st)
            if g.kind == "xlstm_m":
                h = common.apply_norm(p["lnm"], x, cfg.norm_kind)
                o, st2 = ssm.decode_mlstm_block(p["m"], h, cfg, st)
                return x + o, st2
            raise ValueError(g.kind)

        cross = full_state.get("cross") if g.cross else None

        def scan_body(x, xs):
            if cross is not None:
                if flags_arr is None:
                    p, st, ckv = xs
                    y, st2 = body(x, p, st, g.static_global, cross_kv=ckv)
                else:
                    p, st, flag, ckv = xs
                    y, st2 = body(x, p, st, flag, cross_kv=ckv)
            elif flags_arr is None:
                p, st = xs
                y, st2 = body(x, p, st, g.static_global)
            else:
                p, st, flag = xs
                y, st2 = body(x, p, st, flag)
            return y, st2

        if cross is not None:
            xs = (gparams, gstate, cross) if flags_arr is None else (gparams, gstate, flags_arr, cross)
        else:
            xs = (gparams, gstate) if flags_arr is None else (gparams, gstate, flags_arr)
        x, new_state = lax.scan(scan_body, x, xs)
        return x, new_state

    # -------------------------------------------------- whisper prefill path
    def _prefill_encdec(self, params, batch, state):
        cfg = self.cfg
        enc = self._embed_in(params, {"embeds": batch["enc_embeds"]})
        enc, _ = self._run_group(self.groups[0], params[self.groups[0].name], enc)
        gdec = self.groups[1]

        # per-layer cross K/V, precomputed once
        def kv_one(p):
            return blocks.cross_kv_proj(p, enc, self.cfg)

        cross = jax.vmap(kv_one)(params[gdec.name])
        state["cross"] = cross

        dec = common.embed_tokens(params["embed"], batch["tokens"], self.dtype)
        dec = dec + common.sinusoidal_positions(dec.shape[1], cfg.d_model, self.dtype)
        gstate = state[gdec.name]
        flags_arr = None

        def scan_body(x, xs):
            p, st, ckv = xs
            y, st2 = blocks.prefill_decoder_block(
                p, x, cfg, st, is_global=True, use_moe=False, impl=self.attn_impl,
                mesh=self.mesh, cross_kv=ckv,
            )
            return y, st2

        del flags_arr
        x, state[gdec.name] = lax.scan(scan_body, dec, (params[gdec.name], gstate, cross))
        x = common.apply_norm(params["final_norm"], x, cfg.norm_kind)
        state["pos"] = jnp.asarray(dec.shape[1], jnp.int32)
        logits = self._unemb_fn(params)(x[:, -1:])[:, 0]
        return state, common.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
