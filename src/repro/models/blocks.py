"""Per-family transformer blocks, assembled for lax.scan over layers.

Heterogeneity strategy (keeps HLO small -> fast 512-device compiles):

- *mask-only* differences (gemma2 local/global alternation, hymba's
  first/middle/last global layers, mixtral SWA) use a per-layer flag
  vector inside ONE scan -- params stay homogeneous, lax.cond switches
  the attention spec.
- *structural* differences (deepseek dense-vs-MoE FFN, xlstm mLSTM/sLSTM
  alternation) use separate scan groups (see model.py).

Every block returns (x, aux) where aux accumulates MoE load-balance loss.
Decode variants thread per-layer state pytrees (KV caches or SSM states).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import common, mlp, moe, ssm
from repro.models.attention import AttnSpec, KVCache, MLACache
from repro.models.common import Params, Specs


def _attn_spec(cfg: ModelConfig, *, is_global: bool, causal: bool = True) -> AttnSpec:
    window = 0 if is_global else cfg.window_size
    return AttnSpec(
        causal=causal, window=window, softcap=cfg.attn_logit_softcap, prefix=cfg.meta_tokens
    )


def _maybe_post(p, h, cfg):
    return common.apply_norm(p, h, cfg.norm_kind) if cfg.post_norm else h


def _heads(flat: jax.Array, cfg: ModelConfig) -> jax.Array:
    """(B, S, H*hd) -> (B, S, H, hd) (flat TP-friendly weight layout)."""
    b, s, _ = flat.shape
    return flat.reshape(b, s, cfg.num_heads, cfg.head_dim_)


# ---------------------------------------------------------------------------
# dense / moe decoder block (all attention archs)
# ---------------------------------------------------------------------------


def init_decoder_block(key, cfg: ModelConfig, *, use_moe: bool, cross: bool = False):
    ks = jax.random.split(key, 8)
    if cfg.mla is not None:
        pa, sa = attn.init_mla(ks[0], cfg)
    else:
        pa, sa = attn.init_attention(ks[0], cfg)
    p = {"attn": pa, "ln1": init_n(cfg)[0]}
    s = {"attn": sa, "ln1": init_n(cfg)[1]}
    if cross:
        pc, sc = attn.init_attention(ks[3], cfg)
        p["cross"], s["cross"] = pc, sc
        p["lnc"], s["lnc"] = init_n(cfg)
    if use_moe:
        pm, sm = moe.init_moe(ks[1], cfg)
    else:
        pm, sm = mlp.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind)
    p["ffn"], s["ffn"] = pm, sm
    p["ln2"], s["ln2"] = init_n(cfg)
    if cfg.post_norm:
        p["ln1p"], s["ln1p"] = init_n(cfg)
        p["ln2p"], s["ln2p"] = init_n(cfg)
    return p, s


def init_n(cfg):
    return common.init_norm(cfg.d_model, cfg.norm_kind)


def apply_decoder_block(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    is_global,
    use_moe: bool,
    positions=None,
    impl: str = "chunked",
    mesh=None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, jax.Array]:
    h = common.apply_norm(p["ln1"], x, cfg.norm_kind)
    if isinstance(is_global, bool):
        spec = _attn_spec(cfg, is_global=is_global)
        if cfg.mla is not None:
            a = attn.apply_mla(p["attn"], h, cfg, spec, positions=positions, impl=impl, mesh=mesh)
        else:
            a = attn.apply_attention(p["attn"], h, cfg, spec, positions=positions, impl=impl, mesh=mesh)
    else:
        # traced per-layer flag (inside scan): window off/on via cond
        def go(glob):
            spec = _attn_spec(cfg, is_global=glob)
            if cfg.mla is not None:
                return attn.apply_mla(p["attn"], h, cfg, spec, positions=positions, impl=impl, mesh=mesh)
            return attn.apply_attention(p["attn"], h, cfg, spec, positions=positions, impl=impl, mesh=mesh)

        if cfg.window_size > 0:
            a = lax.cond(is_global, lambda: go(True), lambda: go(False))
        else:
            a = go(True)
    x = x + _maybe_post(p.get("ln1p"), a, cfg)

    if cross_kv is not None:
        hc = common.apply_norm(p["lnc"], x, cfg.norm_kind)
        ck, cv = cross_kv
        dtt = x.dtype
        q = _heads(jnp.einsum("bsd,de->bse", hc, p["cross"]["wq"].astype(dtt)), cfg)
        o = attn.attention(q, ck, cv, AttnSpec(causal=False), impl=impl)
        x = x + attn.out_proj(p["cross"], o)

    h2 = common.apply_norm(p["ln2"], x, cfg.norm_kind)
    if use_moe:
        f, aux = moe.apply_moe(p["ffn"], h2, cfg, mesh=mesh)
    else:
        f, aux = mlp.apply_mlp(p["ffn"], h2, cfg.mlp_kind), jnp.zeros((), jnp.float32)
    x = x + _maybe_post(p.get("ln2p"), f, cfg)
    return x, aux


def init_block_cache(cfg: ModelConfig, b: int, s_max: int, dtype=jnp.bfloat16):
    if cfg.mla is not None:
        return attn.init_mla_cache(b, s_max, cfg.mla, dtype)
    return attn.init_kv_cache(b, s_max, cfg.num_kv_heads, cfg.head_dim_, dtype)


def decode_decoder_block(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    cache,
    *,
    is_global,
    use_moe: bool,
    mesh=None,
    cross_kv=None,
):
    h = common.apply_norm(p["ln1"], x, cfg.norm_kind)

    def go(glob):
        spec = _attn_spec(cfg, is_global=glob)
        if cfg.mla is not None:
            return attn.decode_mla(p["attn"], h, cache, cfg, spec)
        return attn.decode_attention(p["attn"], h, cache, cfg, spec)

    if isinstance(is_global, bool):
        a, new_cache = go(is_global)
    elif cfg.window_size > 0:
        a, new_cache = lax.cond(is_global, lambda: go(True), lambda: go(False))
    else:
        a, new_cache = go(True)
    x = x + _maybe_post(p.get("ln1p"), a, cfg)

    if cross_kv is not None:
        hc = common.apply_norm(p["lnc"], x, cfg.norm_kind)
        ck, cv = cross_kv
        q = _heads(jnp.einsum("bsd,de->bse", hc, p["cross"]["wq"].astype(x.dtype)), cfg)
        o = attn.attention(q, ck, cv, AttnSpec(causal=False))
        x = x + attn.out_proj(p["cross"], o)

    h2 = common.apply_norm(p["ln2"], x, cfg.norm_kind)
    if use_moe:
        f, _ = moe.apply_moe(p["ffn"], h2, cfg, mesh=mesh)
    else:
        f = mlp.apply_mlp(p["ffn"], h2, cfg.mlp_kind)
    x = x + _maybe_post(p.get("ln2p"), f, cfg)
    return x, new_cache


def prefill_decoder_block(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    cache,
    *,
    is_global,
    use_moe: bool,
    impl: str = "chunked",
    mesh=None,
    cross_kv=None,
):
    """Full-sequence forward that also fills the per-layer cache."""
    h = common.apply_norm(p["ln1"], x, cfg.norm_kind)

    def go(glob):
        spec = _attn_spec(cfg, is_global=glob)
        if cfg.mla is not None:
            return attn.prefill_mla(p["attn"], h, cache, cfg, spec, impl=impl)
        return attn.prefill_attention(p["attn"], h, cache, cfg, spec, impl=impl, mesh=mesh)

    if isinstance(is_global, bool):
        a, new_cache = go(is_global)
    elif cfg.window_size > 0:
        a, new_cache = lax.cond(is_global, lambda: go(True), lambda: go(False))
    else:
        a, new_cache = go(True)
    x = x + _maybe_post(p.get("ln1p"), a, cfg)

    if cross_kv is not None:
        hc = common.apply_norm(p["lnc"], x, cfg.norm_kind)
        ck, cv = cross_kv
        q = _heads(jnp.einsum("bsd,de->bse", hc, p["cross"]["wq"].astype(x.dtype)), cfg)
        o = attn.attention(q, ck, cv, AttnSpec(causal=False), impl=impl)
        x = x + attn.out_proj(p["cross"], o)

    h2 = common.apply_norm(p["ln2"], x, cfg.norm_kind)
    if use_moe:
        f, _ = moe.apply_moe(p["ffn"], h2, cfg, mesh=mesh)
    else:
        f = mlp.apply_mlp(p["ffn"], h2, cfg.mlp_kind)
    x = x + _maybe_post(p.get("ln2p"), f, cfg)
    return x, new_cache


def cross_kv_proj(p: Params, enc_out: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Precompute cross-attention K/V from encoder states (once per seq)."""
    c = p["cross"]
    b, s, _ = enc_out.shape
    kvh, hd = cfg.num_kv_heads, cfg.head_dim_
    k = jnp.einsum("bsd,de->bse", enc_out, c["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,de->bse", enc_out, c["wv"].astype(enc_out.dtype))
    return k.reshape(b, s, kvh, hd), v.reshape(b, s, kvh, hd)


# ---------------------------------------------------------------------------
# encoder block (whisper)
# ---------------------------------------------------------------------------


def init_encoder_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    pa, sa = attn.init_attention(ks[0], cfg)
    pm, sm = mlp.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind)
    p = {"attn": pa, "ffn": pm, "ln1": init_n(cfg)[0], "ln2": init_n(cfg)[0]}
    s = {"attn": sa, "ffn": sm, "ln1": init_n(cfg)[1], "ln2": init_n(cfg)[1]}
    return p, s


def apply_encoder_block(p, x, cfg: ModelConfig, *, impl="chunked"):
    h = common.apply_norm(p["ln1"], x, cfg.norm_kind)
    a = attn.apply_attention(p["attn"], h, cfg, AttnSpec(causal=False), impl=impl)
    x = x + a
    h2 = common.apply_norm(p["ln2"], x, cfg.norm_kind)
    return x + mlp.apply_mlp(p["ffn"], h2, cfg.mlp_kind)


# ---------------------------------------------------------------------------
# hymba block: parallel attention + mamba heads
# ---------------------------------------------------------------------------


class HymbaState(NamedTuple):
    kv: KVCache
    mamba: ssm.MambaState


def init_hymba_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    pa, sa = attn.init_attention(ks[0], cfg)
    pm, sm = ssm.init_mamba(ks[1], cfg)
    pf, sf = mlp.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_kind)
    p = {
        "attn": pa,
        "mamba": pm,
        "ffn": pf,
        "ln1": init_n(cfg)[0],
        "ln2": init_n(cfg)[0],
        "na": init_n(cfg)[0],
        "nm": init_n(cfg)[0],
        "beta_a": jnp.ones((cfg.d_model,), jnp.float32),
        "beta_m": jnp.ones((cfg.d_model,), jnp.float32),
    }
    s = {
        "attn": sa,
        "mamba": sm,
        "ffn": sf,
        "ln1": init_n(cfg)[1],
        "ln2": init_n(cfg)[1],
        "na": init_n(cfg)[1],
        "nm": init_n(cfg)[1],
        "beta_a": (None,),
        "beta_m": (None,),
    }
    return p, s


def apply_hymba_block(
    p, x, cfg: ModelConfig, *, is_global, positions=None, impl="chunked",
    state: Optional[HymbaState] = None, mesh=None,
):
    h = common.apply_norm(p["ln1"], x, cfg.norm_kind)

    def att(glob):
        spec = _attn_spec(cfg, is_global=glob)
        return attn.apply_attention(p["attn"], h, cfg, spec, positions=positions, impl=impl, mesh=mesh)

    if isinstance(is_global, bool):
        a = att(is_global)
    else:
        a = lax.cond(is_global, lambda: att(True), lambda: att(False))
    mo, mstate = ssm.apply_mamba(p["mamba"], h, cfg, state.mamba if state is not None else None, mesh=mesh)
    mix = 0.5 * (
        common.apply_norm(p["na"], a, cfg.norm_kind) * p["beta_a"].astype(x.dtype)
        + common.apply_norm(p["nm"], mo, cfg.norm_kind) * p["beta_m"].astype(x.dtype)
    )
    x = x + mix
    h2 = common.apply_norm(p["ln2"], x, cfg.norm_kind)
    x = x + mlp.apply_mlp(p["ffn"], h2, cfg.mlp_kind)
    return x, mstate


def prefill_hymba_block(p, x, cfg: ModelConfig, state: HymbaState, *, is_global, impl="chunked", mesh=None):
    h = common.apply_norm(p["ln1"], x, cfg.norm_kind)

    def att(glob):
        spec = _attn_spec(cfg, is_global=glob)
        return attn.prefill_attention(p["attn"], h, state.kv, cfg, spec, impl=impl, mesh=mesh)

    if isinstance(is_global, bool):
        a, kv = att(is_global)
    else:
        a, kv = lax.cond(is_global, lambda: att(True), lambda: att(False))
    mo, mstate = ssm.apply_mamba(p["mamba"], h, cfg, state.mamba, mesh=mesh)
    mix = 0.5 * (
        common.apply_norm(p["na"], a, cfg.norm_kind) * p["beta_a"].astype(x.dtype)
        + common.apply_norm(p["nm"], mo, cfg.norm_kind) * p["beta_m"].astype(x.dtype)
    )
    x = x + mix
    h2 = common.apply_norm(p["ln2"], x, cfg.norm_kind)
    x = x + mlp.apply_mlp(p["ffn"], h2, cfg.mlp_kind)
    return x, HymbaState(kv, mstate)


def decode_hymba_block(p, x, cfg: ModelConfig, state: HymbaState, *, is_global):
    h = common.apply_norm(p["ln1"], x, cfg.norm_kind)

    def att(glob):
        spec = _attn_spec(cfg, is_global=glob)
        return attn.decode_attention(p["attn"], h, state.kv, cfg, spec)

    if isinstance(is_global, bool):
        a, kv = att(is_global)
    else:
        a, kv = lax.cond(is_global, lambda: att(True), lambda: att(False))
    mo, mstate = ssm.decode_mamba(p["mamba"], h, cfg, state.mamba)
    mix = 0.5 * (
        common.apply_norm(p["na"], a, cfg.norm_kind) * p["beta_a"].astype(x.dtype)
        + common.apply_norm(p["nm"], mo, cfg.norm_kind) * p["beta_m"].astype(x.dtype)
    )
    x = x + mix
    h2 = common.apply_norm(p["ln2"], x, cfg.norm_kind)
    x = x + mlp.apply_mlp(p["ffn"], h2, cfg.mlp_kind)
    return x, HymbaState(kv, mstate)


# ---------------------------------------------------------------------------
# xlstm pair block (mLSTM + optional sLSTM)
# ---------------------------------------------------------------------------


class XLSTMPairState(NamedTuple):
    m: ssm.MLSTMBlockState
    s: ssm.SLSTMState


def init_xlstm_pair(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    pm, sm = ssm.init_mlstm_block(k1, cfg)
    ps, ss_ = ssm.init_slstm_block(k2, cfg)
    p = {"m": pm, "s": ps, "lnm": init_n(cfg)[0], "lns": init_n(cfg)[0]}
    s = {"m": sm, "s": ss_, "lnm": init_n(cfg)[1], "lns": init_n(cfg)[1]}
    return p, s


def apply_xlstm_pair(p, x, cfg: ModelConfig, state: Optional[XLSTMPairState] = None, mesh=None):
    if mesh is not None and mesh.size > 1:
        # Time-recurrent blocks must see the FULL sequence locally: a
        # seq-sharded input turns every scan step into a cross-mesh
        # gather (t_coll 64 s at train_4k). The recurrences are tiny
        # (d=2048), so batch-only sharding (replicated over TP) is far
        # cheaper than per-step resharding.
        from repro.core.sharding import constrain

        x = constrain(x, mesh, "batch", None, None)
    hm = common.apply_norm(p["lnm"], x, cfg.norm_kind)
    om, ms = ssm.apply_mlstm_block(p["m"], hm, cfg, state.m if state is not None else None)
    x = x + om
    hs = common.apply_norm(p["lns"], x, cfg.norm_kind)
    os_, ss_ = ssm.apply_slstm_block(p["s"], hs, cfg, state.s if state is not None else None, mesh=mesh)
    x = x + os_
    return x, (XLSTMPairState(ms, ss_) if state is not None else None)


def decode_xlstm_pair(p, x, cfg: ModelConfig, state: XLSTMPairState):
    hm = common.apply_norm(p["lnm"], x, cfg.norm_kind)
    om, ms = ssm.decode_mlstm_block(p["m"], hm, cfg, state.m)
    x = x + om
    hs = common.apply_norm(p["lns"], x, cfg.norm_kind)
    os_, ss_ = ssm.decode_slstm_block(p["s"], hs, cfg, state.s)
    x = x + os_
    return x, XLSTMPairState(ms, ss_)
