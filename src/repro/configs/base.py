"""Config dataclasses: model, shapes, mesh, training, serving.

Every assigned architecture gets a module in this package exposing
``CONFIG`` (the exact full-size numbers from the assignment) and
``reduced()`` (same family, tiny dims -- what the CPU smoke tests run).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    num_shared: int = 0  # deepseek: shared experts always active
    expert_d_ff: int = 0  # 0 -> use model d_ff
    first_k_dense: int = 0  # leading dense layers (deepseek: 3)
    dense_d_ff: int = 0  # d_ff of those dense layers
    capacity_factor: float = 1.25
    dispatch: str = "einsum"  # einsum (gshard) | ring (shard_map a2a) | dense
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mlstm"  # mlstm | mamba
    state_dim: int = 16  # mamba SSM state
    conv_dim: int = 4  # mamba depthwise conv width
    expand: float = 2.0  # inner dim = expand * d_model
    chunk: int = 64  # chunkwise-parallel chunk length
    slstm_every: int = 0  # xLSTM: every k-th block is sLSTM (0 = none)
    slstm_heads: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # --- attention ---
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # partial rotary (nemotron: 0.5)
    qkv_bias: bool = False
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    window_size: int = 0  # sliding-window width (0 = full attention)
    global_pattern: str = "none"  # none | alternate | ends  (which layers go full)
    meta_tokens: int = 0  # hymba: learnable prefix tokens
    # --- mlp / norms ---
    mlp_kind: str = "swiglu"  # swiglu | geglu | relu2 | gelu
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm
    post_norm: bool = False  # gemma2 sandwich norm
    tie_embeddings: bool = False
    # --- submodules ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0  # >0 -> encoder-decoder
    decoder_ratio: int = 4  # decoder_len = seq_len // ratio
    # --- io ---
    input_kind: str = "tokens"  # tokens | embeddings (vlm/audio stub frontends)
    mtp_depth: int = 0  # deepseek multi-token prediction heads
    # --- numerics ---
    dtype: str = "bfloat16"
    remat: str = "full"  # full | dots | none
    seq_parallel: bool = True  # shard saved residual seq dim over TP axis
    attn_partition: str = "auto"  # auto | heads | context (see attention.py)
    attn_kv_chunk: int = 512  # flash KV block (VMEM-bounded on TPU)

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """sub-quadratic sequence handling (SSM state / sliding window)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> float:
        """Analytic parameter count (used for 6ND model-flops)."""
        d, v = self.d_model, self.vocab_size
        hd = self.head_dim_
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        n_dec = self.num_layers
        if self.ssm is not None and self.ssm.kind == "mlstm":
            n_slstm = 0
            if self.ssm.slstm_every:
                n_slstm = self.num_layers // self.ssm.slstm_every
            n_mlstm = self.num_layers - n_slstm
            di = int(self.ssm.expand * d)
            # mLSTM block: up/gate/down proj + qkv + gates + out
            per_m = d * di * 2 + di * d + 3 * di * di // self.num_heads + 3 * di
            per_s = 4 * (d * d + (d // self.ssm.slstm_heads) * d) + 2 * d * (d * 4 // 3)
            total += n_mlstm * per_m + n_slstm * per_s
            return float(total)
        # attention params
        if self.mla is not None:
            m = self.mla
            per_attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.num_heads * (m.nope_head_dim + m.rope_head_dim)
                + d * (m.kv_lora_rank + m.rope_head_dim)
                + m.kv_lora_rank * self.num_heads * (m.nope_head_dim + m.v_head_dim)
                + self.num_heads * m.v_head_dim * d
            )
        else:
            per_attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        # mlp params
        gated = self.mlp_kind in ("swiglu", "geglu")
        def mlp_params(ff: int) -> int:
            return d * ff * (3 if gated else 2)
        if self.moe is not None:
            mo = self.moe
            eff = mo.expert_d_ff or self.d_ff
            dense_ff = mo.dense_d_ff or self.d_ff
            n_moe = n_dec - mo.first_k_dense
            per_moe = (mo.num_experts + mo.num_shared) * mlp_params(eff) + d * mo.num_experts
            total += mo.first_k_dense * (per_attn + mlp_params(dense_ff)) + n_moe * (per_attn + per_moe)
        elif self.ssm is not None and self.ssm.kind == "mamba":  # hybrid (hymba)
            di = int(self.ssm.expand * d)
            per_mamba = d * 2 * di + di * (self.ssm.state_dim * 2 + 1) + di * d
            total += n_dec * (per_attn + per_mamba + mlp_params(self.d_ff))
        else:
            total += n_dec * (per_attn + mlp_params(self.d_ff))
        if self.is_encdec:
            total += self.encoder_layers * (per_attn + mlp_params(self.d_ff))
            total += n_dec * per_attn  # cross attention
        return float(total)

    def active_param_count(self) -> float:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        d = self.d_model
        eff = mo.expert_d_ff or self.d_ff
        gated = self.mlp_kind in ("swiglu", "geglu")
        per_expert = d * eff * (3 if gated else 2)
        inactive = (self.num_layers - mo.first_k_dense) * (
            (mo.num_experts - mo.top_k) * per_expert
        )
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

#: smoke-test shapes (same kinds, tiny)
SMOKE_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 64, 4, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 128, 2, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 128, 4, "decode"),
    "long_500k": ShapeConfig("long_500k", 256, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    microbatch: int = 0  # 0 = no gradient accumulation
    opt_state_dtype: str = "float32"  # float32 | bfloat16 (HBM relief at 671B)
    grad_compression: str = "none"  # none | int8 (error-feedback allreduce)
    seed: int = 0
    checkpoint_every: int = 500
    keep_checkpoints: int = 3
    z_loss: float = 1e-4


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 2048
    prefill_chunk: int = 512
    temperature: float = 0.0  # greedy


def shape_for(name: str, smoke: bool = False) -> ShapeConfig:
    table = SMOKE_SHAPES if smoke else SHAPES
    return table[name]
