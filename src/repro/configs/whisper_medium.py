"""whisper-medium [audio]: enc-dec 24L+24L d_model=1024 16H d_ff=4096
vocab=51865 -- conv frontend is a STUB: input_specs() provides
precomputed frame embeddings (batch, seq, d_model); decoder length is
seq_len // 4 (see DESIGN.md §Arch-applicability) [arXiv:2212.04356]."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,  # decoder layers
    encoder_layers=24,
    decoder_ratio=4,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    mlp_kind="gelu",
    norm_kind="layernorm",
    input_kind="embeddings",  # stub conv frontend emits frame embeddings
    rope_theta=0.0,  # whisper uses absolute (sinusoidal) positions
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        encoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
    )
