"""xlstm-1.3b [ssm]: 48L d_model=2048, sLSTM + mLSTM blocks, vocab=50304
[arXiv:2405.04517]. Attention-free: runs long_500k with O(1) state.

Block layout: every 2nd block is sLSTM (scalar memory, sequential scan,
4 heads); the rest are mLSTM (matrix memory, chunkwise-parallel).
"""

import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,  # blocks carry their own projections
    vocab_size=50304,
    norm_kind="layernorm",
    ssm=SSMConfig(kind="mlstm", expand=2.0, chunk=64, slstm_every=2, slstm_heads=4),
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=2,
        num_kv_heads=2,
        vocab_size=256,
        ssm=SSMConfig(kind="mlstm", expand=2.0, chunk=16, slstm_every=2, slstm_heads=2),
    )
