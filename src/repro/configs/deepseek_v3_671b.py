"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048(expert)
vocab=129280, MoE 256 routed top-8 + 1 shared, MLA, MTP depth 1
[arXiv:2412.19437].

The primary paper-technique target: 256 experts / 16-way TP = 16 experts
per shard, so the token all-to-all dispatch runs through the explicit
shard_map ring with the fused/scatter strategy switch.
"""

import dataclasses

from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,  # MLA: latent cache replaces per-head KV
    d_ff=18432,  # dense-layer d_ff; experts use moe.expert_d_ff
    vocab_size=129280,
    rope_theta=10000.0,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        num_shared=1,
        expert_d_ff=2048,
        first_k_dense=3,
        dense_d_ff=18432,
        dispatch="ring",
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
    ),
    mtp_depth=1,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=3,  # 1 dense + 2 moe
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        moe=MoEConfig(
            num_experts=8, top_k=2, num_shared=1, expert_d_ff=32,
            first_k_dense=1, dense_d_ff=128, dispatch="ring",
        ),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8, nope_head_dim=16, v_head_dim=16),
        mtp_depth=1,
    )
