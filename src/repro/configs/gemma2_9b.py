"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8, head_dim=256)
d_ff=14336 vocab=256000 -- local(4096)+global alternating, logit
softcaps (attn 50, final 30), GeGLU, sandwich norms, tied embeddings
[arXiv:2408.00118]."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    head_dim=256,
    rope_theta=10000.0,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    window_size=4096,
    global_pattern="alternate",  # even layers local SWA, odd layers global
    mlp_kind="geglu",
    norm_kind="rmsnorm",
    post_norm=True,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        window_size=32,
    )
