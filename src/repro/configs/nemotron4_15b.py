"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 -- squared-ReLU MLP (no gating), LayerNorm, partial rotary
[arXiv:2402.16819]."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    rope_theta=10000.0,
    rope_fraction=0.5,
    mlp_kind="relu2",
    norm_kind="layernorm",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256
    )
