"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA [arXiv:2401.04088].

8 experts < 16-way TP: expert dim is GSPMD-padded under the einsum
dispatch (see DESIGN.md §Arch-applicability); the explicit ring dispatch
is exercised on reduced configs where experts % shards == 0.
"""

import dataclasses

from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    rope_theta=1000000.0,
    window_size=4096,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    moe=MoEConfig(num_experts=8, top_k=2, dispatch="einsum"),
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        window_size=32,
        moe=MoEConfig(num_experts=4, top_k=2, dispatch="einsum"),
    )
