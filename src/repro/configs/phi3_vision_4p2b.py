"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP frontend (stub).

32L d_model=3072 32H (kv=32, MHA) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct]. The vision tower is a STUB:
input_specs() provides precomputed patch/text embeddings (batch, seq,
d_model); the LM head and vocab are real.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10000.0,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    input_kind="embeddings",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256
    )
