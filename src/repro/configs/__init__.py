"""Architecture registry: ``--arch <id>`` resolves through ARCHS."""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from repro.configs import (
    deepseek_v3_671b,
    fft_bench,
    gemma2_9b,
    hymba_1p5b,
    mixtral_8x22b,
    nemotron4_15b,
    phi3_medium_14b,
    phi3_vision_4p2b,
    qwen2_5_32b,
    whisper_medium,
    xlstm_1p3b,
)
from repro.configs.base import (
    SHAPES,
    SMOKE_SHAPES,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    ServeConfig,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
    shape_for,
)

_MODULES = {
    "phi-3-vision-4.2b": phi3_vision_4p2b,
    "mixtral-8x22b": mixtral_8x22b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "qwen2.5-32b": qwen2_5_32b,
    "gemma2-9b": gemma2_9b,
    "nemotron-4-15b": nemotron4_15b,
    "phi3-medium-14b": phi3_medium_14b,
    "xlstm-1.3b": xlstm_1p3b,
    "hymba-1.5b": hymba_1p5b,
    "whisper-medium": whisper_medium,
}

ARCHS: Dict[str, ModelConfig] = {k: m.CONFIG for k, m in _MODULES.items()}
REDUCED: Dict[str, Callable[[], ModelConfig]] = {k: m.reduced for k, m in _MODULES.items()}


def get_config(arch: str, *, reduced: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return REDUCED[arch]() if reduced else ARCHS[arch]


def apply_overrides(cfg: ModelConfig, overrides: Dict[str, str]) -> ModelConfig:
    """CLI --override key=value support (ints/floats/bools auto-coerced)."""
    kw = {}
    for k, v in overrides.items():
        field = {f.name: f for f in dataclasses.fields(cfg)}.get(k)
        if field is None:
            raise KeyError(f"no config field {k!r}")
        t = field.type
        if v in ("true", "True", "false", "False"):
            kw[k] = v.lower() == "true"
        else:
            try:
                kw[k] = int(v)
            except ValueError:
                try:
                    kw[k] = float(v)
                except ValueError:
                    kw[k] = v
    return dataclasses.replace(cfg, **kw)


__all__ = [
    "ARCHS", "REDUCED", "SHAPES", "SMOKE_SHAPES", "MLAConfig", "MoEConfig",
    "ModelConfig", "ServeConfig", "ShapeConfig", "SSMConfig", "TrainConfig",
    "apply_overrides", "fft_bench", "get_config", "shape_for",
]
