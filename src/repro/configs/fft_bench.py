"""The paper's own FFT problem configurations.

Figure 4/5 strong scaling uses a 2-D FFT of size 2^14 x 2^14 (c64 = 4
GiB); Figure 3's chunk-size scaling sweeps the per-chunk message size on
two nodes. Full sizes are exercised abstractly by the dry-run; the CPU
benchmark harness uses the scaled sizes below.
"""

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class FFTBenchConfig:
    name: str
    global_shape: Tuple[int, ...]
    ndim_transform: int = 2


#: the paper's production problem (Figs. 4-5)
PAPER_2D = FFTBenchConfig("paper_2d_16k", (16384, 16384), 2)

#: CPU-container scaled problems (same shape family, tractable on 1 core)
BENCH_2D = FFTBenchConfig("bench_2d_1k", (1024, 1024), 2)
BENCH_2D_SMALL = FFTBenchConfig("bench_2d_256", (256, 256), 2)
BENCH_3D = FFTBenchConfig("bench_3d_128", (128, 128, 128), 3)
BENCH_1D = FFTBenchConfig("bench_1d_1m", (1 << 20,), 1)
