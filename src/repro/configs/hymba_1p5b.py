"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504,
ssm_state=16 -- parallel attention + mamba heads in every layer, 128
meta tokens, SWA everywhere except first/middle/last global layers
[arXiv:2411.13676]. Runs long_500k (SWA cache + O(1) SSM state)."""

import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    rope_theta=10000.0,
    window_size=1024,
    global_pattern="ends",  # first / middle / last layers full attention
    meta_tokens=128,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    ssm=SSMConfig(kind="mamba", state_dim=16, conv_dim=4, expand=2.0, chunk=128),
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        window_size=32,
        meta_tokens=8,
        ssm=SSMConfig(kind="mamba", state_dim=8, conv_dim=4, expand=2.0, chunk=16),
    )
