from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM, make_batch_arrays

__all__ = ["DataConfig", "Prefetcher", "SyntheticLM", "make_batch_arrays"]
