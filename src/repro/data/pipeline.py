"""Synthetic LM data pipeline: deterministic, host-sharded, resumable.

Design constraints of a 1000-node deployment baked in:

- *stateless index -> batch map*: batch(step) is a pure function of
  (seed, step, host), so resume-after-failure needs only the step number
  (no iterator state in checkpoints) and any host can recompute any
  shard (elastic re-scale just changes the host slice).
- *host sharding*: each process materializes only its rows of the global
  batch; `jax.make_array_from_process_local_data` would assemble the
  global array on multi-host (single-process here: direct device_put).
- *prefetch*: a daemon thread keeps a bounded queue of ready batches so
  host-side generation overlaps device compute (straggler slack).

The token stream is learnable-but-nontrivial: each sequence is an affine
progression (random start/stride per sequence) XOR low-entropy noise, so
cross-entropy falls quickly from ln(V) -- used by the e2e training tests
to assert optimization actually works.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05


class SyntheticLM:
    """Deterministic synthetic next-token data."""

    def __init__(self, cfg: DataConfig, *, process_index: int = 0, process_count: int = 1):
        self.cfg = cfg
        self.process_index = process_index
        self.process_count = process_count
        if cfg.global_batch % process_count:
            raise ValueError("global_batch must divide across processes")
        self.local_batch = cfg.global_batch // process_count

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, host): the resumability contract."""
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, self.process_index])
        )
        b, s = self.local_batch, c.seq_len
        start = rng.integers(0, c.vocab_size, (b, 1))
        stride = rng.integers(1, 17, (b, 1))
        seq = (start + stride * np.arange(s + 1)) % c.vocab_size
        flips = rng.random((b, s + 1)) < c.noise
        noise_tok = rng.integers(0, c.vocab_size, (b, s + 1))
        seq = np.where(flips, noise_tok, seq).astype(np.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Bounded background prefetch of (step, batch) pairs."""

    def __init__(self, ds: SyntheticLM, start_step: int = 0, depth: int = 2, sharding=None):
        self.ds = ds
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.sharding = sharding
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.ds.batch_at(step)
            try:
                self.q.put((step, batch), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def next(self):
        step, batch = self.q.get()
        if self.sharding is not None:
            batch = {k: jax.device_put(v, self.sharding) for k, v in batch.items()}
        return step, batch

    def stop(self):
        self._stop.set()


def make_batch_arrays(batch: Dict[str, np.ndarray], mesh=None):
    """Device-put a host batch with the standard batch sharding."""
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    from repro.core.sharding import batch_sharding

    return {k: jax.device_put(v, batch_sharding(mesh, v.ndim)) for k, v in batch.items()}
