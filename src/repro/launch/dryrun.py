import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces the proof artifacts required by DESIGN.md:
  - ``compiled.memory_analysis()``  -> bytes/device (fits-HBM check)
  - ``compiled.cost_analysis()``    -> per-device HLO FLOPs / bytes
  - collective bytes parsed from ``compiled.as_text()``
  - the three roofline terms (core/comm_model.py, v5e constants)

The 512 placeholder host devices exist ONLY here (the env var above must
run before any jax import -- this module must be the process entry).
Results are written as JSON under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch deepseek-v3-671b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--jobs 1]
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.core import comm_model, hlo_analysis
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.train import step as train_step_lib

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

#: long_500k runs only for sub-quadratic archs (DESIGN.md §Arch-applicability)
LONG_OK = ("xlstm-1.3b", "hymba-1.5b")


def cells(arch_filter=None, shape_filter=None):
    from repro.configs import _MODULES

    for arch in _MODULES:
        if arch_filter and arch != arch_filter:
            continue
        for sname in SHAPES:
            if shape_filter and sname != shape_filter:
                continue
            if sname == "long_500k" and arch not in LONG_OK:
                continue
            yield arch, sname


def _mem_dict(ma) -> Dict[str, float]:
    return {
        "argument_bytes": float(ma.argument_size_in_bytes),
        "output_bytes": float(ma.output_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        "code_bytes": float(ma.generated_code_size_in_bytes),
        # donated state aliases its output (decode caches, train state):
        # count the aliased bytes once.
        "peak_device_bytes": float(
            ma.argument_size_in_bytes
            + ma.temp_size_in_bytes
            + ma.output_size_in_bytes
            - ma.alias_size_in_bytes
        ),
    }


def lower_cell(arch: str, sname: str, mesh, *, reduced: bool = False):
    """Build the right step program for the cell and lower it abstractly."""
    cfg = get_config(arch, reduced=reduced)
    shape = SHAPES[sname]
    model = Model(cfg, mesh=mesh, attn_impl="chunked")
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        tcfg = TrainConfig(microbatch=4, opt_state_dtype="bfloat16")  # production defaults
        state_abs = jax.eval_shape(
            lambda k: train_step_lib.init_train_state(model, k, tcfg)[0], jax.random.PRNGKey(0)
        )
        param_specs = _static_specs(model)
        st_sh = train_step_lib.state_shardings(mesh, param_specs, state_abs)
        state_in = specs_lib.with_shardings(state_abs, st_sh)
        batch_in = specs_lib.batch_input_specs(cfg, shape, mesh)
        step = train_step_lib.make_train_step(model, tcfg, mesh)
        return jax.jit(step, donate_argnums=(0,)).lower(state_in, batch_in)

    params_abs = _abstract_params(model)
    if shape.kind == "prefill":
        state_abs = specs_lib.abstract_decode_state(model, b, s)
        st_sh = specs_lib.decode_state_shardings(
            state_abs, mesh, replicate_batch=(b == 1), seq_shard=(sname == "long_500k")
        )
        state_in = specs_lib.with_shardings(state_abs, st_sh)
        batch_in = specs_lib.batch_input_specs(cfg, shape, mesh)

        def prefill_step(params, batch, state):
            return model.prefill(params, batch, state)

        return jax.jit(prefill_step, donate_argnums=(2,)).lower(params_abs, batch_in, state_in)

    # decode: one new token against a seq_len cache
    state_abs = specs_lib.abstract_decode_state(model, b, s)
    st_sh = specs_lib.decode_state_shardings(
        state_abs, mesh, replicate_batch=(b == 1), seq_shard=(sname == "long_500k")
    )
    state_in = specs_lib.with_shardings(state_abs, st_sh)
    ba = None if b == 1 else tuple(a for a in ("pod", "data") if a in mesh.shape)
    tok_in = jax.ShapeDtypeStruct((b, 1), jnp.int32, sharding=NamedSharding(mesh, P(ba, None)))

    def serve_step(params, tokens, state):
        return model.decode_step(params, tokens, state)

    return jax.jit(serve_step, donate_argnums=(2,)).lower(params_abs, tok_in, state_in)


def _static_specs(model: Model):
    """Param logical specs without touching device state: the specs tree
    is plain Python built during tracing, so capture it under eval_shape."""
    out = {}

    def capture(key):
        p, s = model.init(key)
        out["specs"] = s
        return p

    jax.eval_shape(capture, jax.random.PRNGKey(0))
    return out["specs"]


def _abstract_params(model: Model):
    params_abs = jax.eval_shape(lambda k: model.init(k)[0], jax.random.PRNGKey(0))
    specs = _static_specs(model)
    sh = _specs_to_shardings(specs, model.mesh, params_abs)
    return specs_lib.with_shardings(params_abs, sh)


def _specs_to_shardings(specs, mesh, abstract_tree):
    from repro.core import sharding as shlib

    return shlib.tree_shardings(mesh, specs, abstract_tree)


def run_cell(arch: str, sname: str, mesh_kind: str, *, reduced=False) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    lowered = lower_cell(arch, sname, mesh, reduced=reduced)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ma = compiled.memory_analysis()
    print(compiled.memory_analysis())  # proves it fits (bytes/device)
    ca = compiled.cost_analysis()
    ca = ca if isinstance(ca, dict) else ca[0]
    print({k: v for k, v in ca.items() if k in ("flops", "bytes accessed")})
    # loop-aware analysis: scan bodies x trip count (cost_analysis counts
    # a while body ONCE -- useless for scanned-layer programs)
    cost = hlo_analysis.analyze_compiled(compiled)
    roof = comm_model.Roofline(
        flops=cost.flops, hbm_bytes=cost.hbm_bytes, coll_bytes=cost.coll_bytes, chips=chips
    )
    cfg = get_config(arch, reduced=reduced)
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    shape = SHAPES[sname]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    # 6ND for train (fwd 2ND + bwd 4ND); forward-only passes are 2ND.
    model_flops = (6.0 if shape.kind == "train" else 2.0) * n_active * tokens
    result = {
        "arch": arch,
        "shape": sname,
        "mesh": mesh_kind,
        "chips": chips,
        "lower_s": t1 - t0,
        "compile_s": t2 - t1,
        "memory": _mem_dict(ma),
        "roofline": roof.as_dict(),
        "collectives": {"counts": cost.coll_counts, "bytes": cost.coll_bytes_by_kind},
        "xla_cost_analysis": {"flops_once": float(ca.get("flops", 0.0)),
                              "bytes_once": float(ca.get("bytes accessed", 0.0))},
        "params": n_params,
        "active_params": n_active,
        "tokens_per_step": tokens,
        "model_flops_global": model_flops,
        "model_flops_per_chip": model_flops / chips,
        "useful_flops_frac": (model_flops / chips) / max(roof.flops, 1.0),
    }
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--reduced", action="store_true", help="reduced configs (CI sanity)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    out_dir = args.out or os.path.abspath(RESULT_DIR)
    os.makedirs(out_dir, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = list(cells(args.arch, args.shape)) if (args.all or not args.arch or not args.shape) else [
        (args.arch, args.shape)
    ]
    failures = 0
    for arch, sname in todo:
        for mk in meshes:
            tag = f"{arch}_{sname}_{mk}" + ("_reduced" if args.reduced else "")
            path = os.path.join(out_dir, tag + ".json")
            try:
                res = run_cell(arch, sname, mk, reduced=args.reduced)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                r = res["roofline"]
                print(
                    f"[OK] {tag}: compile={res['compile_s']:.1f}s "
                    f"mem/dev={res['memory']['peak_device_bytes']/2**30:.2f}GiB "
                    f"bottleneck={r['bottleneck']} "
                    f"t=({r['t_compute_s']:.2e},{r['t_memory_s']:.2e},{r['t_collective_s']:.2e})s"
                )
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
                traceback.print_exc()
                with open(os.path.join(out_dir, tag + ".FAILED"), "w") as f:
                    f.write(traceback.format_exc())
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
