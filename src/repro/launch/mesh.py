"""Production mesh builders (functions, never module-level constants --
importing this module must not touch jax device state)."""

from __future__ import annotations

import jax

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment meshes: 16x16 = 256 chips per pod (v5e),
    2 pods = 512 chips with a leading 'pod' axis for cross-pod DP."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Whatever this host has (tests, benches, CPU runs)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return make_mesh((n // mp, mp), ("data", "model"))
