"""Abstract input/state specs for the dry-run: ShapeDtypeStruct stand-ins
(weak-type-correct, shardable, zero allocation) for every model input.

Three lowered programs, per the shape kind:
  train_*    -> train_step(TrainState, batch)
  prefill_*  -> prefill(params, batch, decode_state)  [cache filled 0:S]
  decode_*   -> serve_step(params, tokens(B,1), decode_state[S])  -- one
                new token against a seq_len cache.

Sharding rules: batch over ('pod','data'); KV/latent caches additionally
over 'model' (heads) -- except long_500k (batch=1), where the batch is
replicated and the *sequence* axis of the caches shards over 'data'
(distributed-cache decode; see EXPERIMENTS.md §Perf for the explicit
flash-decode combine that optimizes it)."""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import Model


def _batch_axes(mesh: Mesh, *, replicate_batch: bool = False):
    if replicate_batch:
        return None
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return axes if axes else None


def batch_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Dict[str, Any]:
    """Abstract train/prefill inputs for one architecture x shape."""
    from repro.core.sharding import sanitize_spec

    b, s = shape.global_batch, shape.seq_len
    ba = _batch_axes(mesh, replicate_batch=(b == 1))
    tok_sh = NamedSharding(mesh, sanitize_spec(mesh, P(ba, None), (b, s)))
    emb_sh = NamedSharding(mesh, sanitize_spec(mesh, P(ba, None, None), (b, s, 1)))
    out: Dict[str, Any] = {}
    if cfg.is_encdec:
        out["enc_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16, sharding=emb_sh)
        dec_len = max(s // cfg.decoder_ratio, 1)
        out["tokens"] = jax.ShapeDtypeStruct((b, dec_len), jnp.int32, sharding=tok_sh)
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, dec_len), jnp.int32, sharding=tok_sh)
        return out
    if cfg.input_kind == "embeddings":
        out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16, sharding=emb_sh)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=tok_sh)
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=tok_sh)
        if cfg.mtp_depth > 0 and "tokens" not in out:
            out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=tok_sh)
    return out


# ---------------------------------------------------------------------------
# decode-state shardings (name-based rules over the state pytree)
# ---------------------------------------------------------------------------


def _leaf_spec(path: str, ndim: int, *, ba, seq_shard: bool, shape=(), tp: int = 1) -> P:
    """Sharding for one stacked decode-state leaf (leading axis = layer)."""
    seq_ax = "data" if seq_shard else None
    if path.endswith("length") and ndim == 2:  # (L, B)
        return P(None, ba)
    if path.endswith("pos"):
        return P()
    if path.endswith((".k", ".v")) and ndim == 5:  # (L,B,S,KVH,D)
        # kv_heads < TP width (GQA): shard head_dim instead -- a replicated
        # 32k cache is 10s of GiB/device otherwise
        if shape and shape[3] % tp and shape[4] % tp == 0:
            return P(None, ba, seq_ax, None, "model")
        return P(None, ba, seq_ax, "model", None)
    if path.endswith("ckv") and ndim == 4:  # (L,B,S,r)
        return P(None, ba, seq_ax, None)
    if path.endswith("k_rope") and ndim == 4:
        return P(None, ba, seq_ax, None)
    if ".cross" in path and ndim == 5:  # (L,B,S_enc,H,D)
        return P(None, ba, None, "model", None)
    if path.endswith(".h") and ndim == 4:  # mamba state (L,B,di,N)
        return P(None, ba, "model", None)
    if path.endswith(".conv") and ndim == 4:  # (L,B,W,di)
        return P(None, ba, None, "model")
    if path.endswith(".c") and ndim == 5:  # mlstm C (L,B,H,dk,dv)
        return P(None, ba, "model", None, None)
    if path.endswith(".n") and ndim == 4:
        return P(None, ba, "model", None)
    if path.endswith(".m") and ndim == 3:
        return P(None, ba, "model")
    if ndim >= 3:  # slstm h/c/n/m (L,B,d) and anything else batched
        return P(None, ba, *([None] * (ndim - 2)))
    return P(*([None] * ndim))


def decode_state_shardings(state, mesh: Mesh, *, replicate_batch: bool, seq_shard: bool):
    from repro.core.sharding import sanitize_spec

    ba = _batch_axes(mesh, replicate_batch=replicate_batch)
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in path)
        # normalize: NamedTuple fields appear as .name attrs in path str
        dotted = name.replace("/", ".")
        spec = _leaf_spec(
            "." + dotted, np.ndim(leaf), ba=ba, seq_shard=seq_shard,
            shape=np.shape(leaf), tp=mesh.shape.get("model", 1),
        )
        spec = sanitize_spec(mesh, spec, np.shape(leaf))  # input shardings must divide
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_decode_state(model: Model, b: int, s_max: int):
    return jax.eval_shape(lambda: model.init_decode_state(b, s_max))


def with_shardings(abstract_tree, sharding_tree):
    return jax.tree.map(
        lambda a, sh: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=sh),
        abstract_tree,
        sharding_tree,
    )
