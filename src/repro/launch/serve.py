"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up the slot-based engine on a reduced (or full) config, feeds it a
stream of synthetic prompts, and reports throughput + per-request
latency percentiles -- the CPU-scale stand-in for the decode_* dry-run
shapes.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ServeConfig, get_config
from repro.models.model import Model
from repro.serve import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.is_encdec:
        raise SystemExit("serve driver targets decoder-only archs (whisper needs audio prompts)")
    model = Model(cfg, attn_impl="chunked")
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        model, params,
        ServeConfig(max_batch=args.max_batch, max_seq=args.max_seq, temperature=args.temperature),
    )
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab_size, rng.integers(4, args.prompt_len + 1)).astype(np.int32)
        for _ in range(args.requests)
    ]
    t0 = time.perf_counter()
    results = engine.run(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    tok = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s aggregate)")
    for uid in sorted(results)[:4]:
        print(f"  req {uid}: {results[uid][:12]}")


if __name__ == "__main__":
    main()
