"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Wires together every substrate: config registry, synthetic data with
prefetch, jit'd train step with logical shardings, checkpoint manager
(atomic/async/keep-N), step monitor (straggler flags), and the failure
recovery loop (auto-resume from latest checkpoint, elastic mesh).
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig, get_config
from repro.data import DataConfig, SyntheticLM, make_batch_arrays
from repro.launch.mesh import make_local_mesh
from repro.models.model import Model
from repro.runtime import (
    FailureInjector,
    Resume,
    StepMonitor,
    elastic_mesh,
    run_with_recovery,
)
from repro.train import init_train_state, make_train_step, state_shardings

log = logging.getLogger("repro.train")


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=None, help="inject a failure (recovery demo)")
    ap.add_argument(
        "--fail-every", type=int, default=None,
        help="repeat the injected failure every N steps after --fail-at",
    )
    ap.add_argument(
        "--fail-times", type=int, default=1,
        help="total injected failures (with --fail-every; default one)",
    )
    ap.add_argument(
        "--elastic", action="store_true",
        help="rebuild the mesh from whatever devices are alive on each "
             "restart (may resume on fewer devices than the failed run)",
    )
    ap.add_argument(
        "--backoff-s", type=float, default=0.0,
        help="base restart backoff; grows exponentially, capped, jittered",
    )
    ap.add_argument("--attn-impl", default="chunked", choices=["chunked", "naive"])
    ap.add_argument(
        "--monitor-window", type=int, default=512,
        help="step-telemetry history bound (StepMonitor history_limit)",
    )
    return ap


def train(args, *, injector: Optional[FailureInjector] = None) -> dict:
    cfg = get_config(args.arch, reduced=args.reduced)
    tcfg = TrainConfig(
        learning_rate=args.lr,
        warmup_steps=max(args.steps // 20, 5),
        total_steps=args.steps,
        microbatch=args.microbatch,
        checkpoint_every=args.ckpt_every,
        seed=args.seed,
    )
    ds = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch, seed=args.seed))
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    monitor = StepMonitor(history_limit=getattr(args, "monitor_window", 512))
    injector = injector or FailureInjector(
        args.fail_at,
        every=getattr(args, "fail_every", None),
        times=getattr(args, "fail_times", 1),
    )
    history = {"loss": [], "restarts": 0}

    def loop(resume: Optional[Resume]):
        # mesh (and everything sharded on it) is rebuilt per attempt:
        # under --elastic a restart re-discovers whatever devices are
        # still alive and may come back at a smaller data-parallel width
        if getattr(args, "elastic", False):
            mesh = elastic_mesh(("data", "model"), model_parallel=args.model_parallel)
        else:
            mesh = make_local_mesh(args.model_parallel)
        model = Model(cfg, mesh=mesh, attn_impl=args.attn_impl)
        state, specs = init_train_state(model, jax.random.PRNGKey(tcfg.seed), tcfg)
        start = 0
        # restore_latest walks back past corrupt/partial checkpoints --
        # a crash mid-save costs one interval, never the run
        latest, restored = ckpt.restore_latest(state)
        if latest is not None:
            state = restored
            start = latest
            if resume is not None:
                log.info(
                    "restart %d (%s): resumed from checkpoint step %d on %d devices",
                    resume.restarts, resume.cause, start, mesh.size,
                )
            else:
                log.info("resumed from checkpoint step %d", start)
            # the pre-failure EMA would flag every post-restart step
            # (recompiles, cold caches) -- start the baseline fresh
            monitor.reset()
        step_fn = jax.jit(make_train_step(model, tcfg, mesh), donate_argnums=(0,))
        for step in range(start, args.steps):
            injector.maybe_fail(step)
            # a step spans input + device work so a slow host pipeline
            # flags (and names itself) like a slow device would
            monitor.start()
            t_in = time.perf_counter()
            batch = make_batch_arrays(ds.batch_at(step), mesh if mesh.size > 1 else None)
            input_s = time.perf_counter() - t_in
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])  # blocks on the step
            st = monitor.stop(
                tokens=args.batch * args.seq,
                spans=[("input", input_s), ("step_fn", time.perf_counter() - t_in - input_s)],
            )
            history["loss"].append(loss)
            if st.flagged:
                log.warning(
                    "straggler step %d: %.3fs (ema %.3fs, slowest stage: %s)",
                    step, st.seconds, monitor.ema, st.culprit,
                )
            if step % args.log_every == 0:
                log.info(
                    "step %d loss %.4f gnorm %.3f %.0f tok/s",
                    step, loss, float(metrics["grad_norm"]), monitor.tokens_per_sec,
                )
            if (step + 1) % tcfg.checkpoint_every == 0 or step + 1 == args.steps:
                ckpt.save(step + 1, state)
        ckpt.wait()

    restarts = run_with_recovery(
        loop,
        max_restarts=2,
        backoff_s=getattr(args, "backoff_s", 0.0),
        seed=args.seed,
    )
    history["restarts"] = restarts
    history["straggler_report"] = monitor.straggler_report()
    return history


def main(argv=None):
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(name)s %(message)s")
    args = build_argparser().parse_args(argv)
    hist = train(args)
    first = np.mean(hist["loss"][:5]) if hist["loss"] else float("nan")
    last = np.mean(hist["loss"][-5:]) if hist["loss"] else float("nan")
    print(f"loss {first:.4f} -> {last:.4f} over {len(hist['loss'])} steps "
          f"(restarts={hist['restarts']})")


if __name__ == "__main__":
    main()
