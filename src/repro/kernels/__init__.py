"""Pallas TPU kernels for the FFT compute hot-spot (DESIGN.md §2).

fft_stage.py: fused complex DFT-matmul + twiddle (pl.pallas_call +
BlockSpec); ops.py: jit'd wrappers; ref.py: pure-jnp oracles.
"""
