"""Pallas TPU kernel: fused complex DFT-matmul + twiddle stage.

This is the compute hot-spot of the matmul-formulated local FFT
(core/local_fft.py): one four-step stage computes

    left  mode:  out = (W @ A) * T        (column DFT + twiddle, fused)
    right mode:  out = A @ W^T            (row DFT; final stage, T = 1)

with complex operands stored as separate (re, im) f32 planes -- the TPU
MXU has no complex type, so the complex product is lowered to the
3-matmul Karatsuba form:

    p1 = Wr@Ar;  p2 = Wi@Ai;  p3 = (Wr+Wi)@(Ar+Ai)
    re = p1 - p2;  im = p3 - p1 - p2

saving 25% of MXU work vs. the naive 4-matmul form. The twiddle multiply
(elementwise complex) runs on the VPU over the same VMEM-resident tile,
so the stage never round-trips the intermediate through HBM -- that
fusion is the kernel's reason to exist.

Blocking: grid (B, M/bm, N/bn); the contraction dim K (the DFT radix,
<= MAX_DFT = 512) stays whole inside a block, so no accumulation loop is
needed and every dot hits the MXU with K >= 128. VMEM per step at the
default bm=bn=128, K=512: 2*(bm*K + K*bn + 2*bm*bn + bm*bn)*4B ~ 1.3 MiB,
far under the ~128 MiB v5e budget; bn can be raised to widen the MXU N
dim when N is large.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU memory-space hint; interpret mode ignores it.
try:  # pragma: no cover - only resolvable with TPU support compiled in
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.MemorySpace.VMEM
except Exception:  # pragma: no cover
    _VMEM = None


def _bs(shape, index_map):
    if _VMEM is None:
        return pl.BlockSpec(shape, index_map)
    return pl.BlockSpec(shape, index_map, memory_space=_VMEM)


def _karatsuba(wr, wi, ar, ai):
    """(wr + i*wi) @ (ar + i*ai) via 3 real matmuls, f32 accumulate."""
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    p1 = dot(wr, ar)
    p2 = dot(wi, ai)
    p3 = dot(wr + wi, ar + ai)
    return p1 - p2, p3 - p1 - p2


def _stage_left_kernel(wr_ref, wi_ref, ar_ref, ai_ref, tr_ref, ti_ref, or_ref, oi_ref):
    """out[b, m, n] = sum_k W[m, k] A[b, k, n] * T[m, n] (complex)."""
    wr, wi = wr_ref[...], wi_ref[...]
    ar, ai = ar_ref[0], ai_ref[0]
    re, im = _karatsuba(wr, wi, ar, ai)
    tr, ti = tr_ref[...], ti_ref[...]
    or_ref[0] = re * tr - im * ti
    oi_ref[0] = re * ti + im * tr


def _stage_right_kernel(wr_ref, wi_ref, ar_ref, ai_ref, or_ref, oi_ref):
    """out[b, m, n] = sum_k A[b, m, k] W[n, k]  (complex, no twiddle)."""
    # A @ W^T == (W @ A^T)^T; keep operands MXU-shaped via dot on transposes.
    wr, wi = wr_ref[...], wi_ref[...]
    ar, ai = ar_ref[0], ai_ref[0]
    re_t, im_t = _karatsuba(wr, wi, ar.T, ai.T)
    or_ref[0] = re_t.T
    oi_ref[0] = im_t.T


def stage_left(
    w: Tuple[jax.Array, jax.Array],
    a: Tuple[jax.Array, jax.Array],
    t: Tuple[jax.Array, jax.Array],
    *,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Fused (W @ A) * T over planar-complex operands.

    w: (M, K) re/im;  a: (B, K, N) re/im;  t: (M, N) re/im -> (B, M, N).
    """
    wr, wi = w
    ar, ai = a
    tr, ti = t
    B, K, N = ar.shape
    M = wr.shape[0]
    bm = min(bm, M)
    bn = min(bn, N)
    if M % bm or N % bn:
        raise ValueError(f"(M={M}, N={N}) must tile by (bm={bm}, bn={bn})")
    grid = (B, M // bm, N // bn)
    out_shape = [jax.ShapeDtypeStruct((B, M, N), jnp.float32)] * 2
    fn = pl.pallas_call(
        _stage_left_kernel,
        grid=grid,
        in_specs=[
            _bs((bm, K), lambda b, i, j: (i, 0)),  # W re
            _bs((bm, K), lambda b, i, j: (i, 0)),  # W im
            _bs((1, K, bn), lambda b, i, j: (b, 0, j)),  # A re
            _bs((1, K, bn), lambda b, i, j: (b, 0, j)),  # A im
            _bs((bm, bn), lambda b, i, j: (i, j)),  # T re
            _bs((bm, bn), lambda b, i, j: (i, j)),  # T im
        ],
        out_specs=[
            _bs((1, bm, bn), lambda b, i, j: (b, i, j)),
            _bs((1, bm, bn), lambda b, i, j: (b, i, j)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )
    return tuple(fn(wr, wi, ar, ai, tr, ti))


def _chunk_twiddle_pack_kernel(cr_ref, ci_ref, mr_ref, mi_ref, or_ref, oi_ref):
    """out[b, j, k, t] = chunk[b, t, j] * m[k, t] (complex, planar).

    One launch fuses the per-arrival work of the pipelined overlap
    executor's chunk callback: the (rows, c) -> (c, rows) relayout of the
    received chunk AND the W_P-column x twiddle broadcast multiply that
    spreads it across the k1 dimension -- previously a transpose copy
    plus a separate elementwise multiply, each round-tripping the chunk
    through memory."""
    cr, ci = cr_ref[0], ci_ref[0]  # (rows, c)
    mr, mi = mr_ref[...], mi_ref[...]  # (p, rows)
    ctr, cti = cr.T, ci.T  # (c, rows) -- the pack, in-register
    a = ctr[:, None, :]  # (c, 1, rows)
    b = cti[:, None, :]
    or_ref[0] = a * mr[None] - b * mi[None]  # (c, p, rows)
    oi_ref[0] = a * mi[None] + b * mr[None]


def chunk_twiddle_pack_c64(chunk: jax.Array, m: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Fused twiddle+pack for one arriving exchange chunk (complex64).

    ``chunk``: (..., rows, c) -- the raw received piece (rows of the
    source block x my column block); ``m``: (p, rows) -- the W_P column
    for this source times the four-step twiddle slice for these rows.
    Returns (..., c, p, rows): the chunk's contribution to the fused
    DFT stage's accumulator (see
    :func:`repro.core.transpose.transpose_then_fft`), computed in a
    single kernel launch instead of a relayout copy + twiddle multiply.
    """
    if chunk.dtype != jnp.complex64 or m.dtype != jnp.complex64:
        raise ValueError(
            f"chunk_twiddle_pack_c64 is a planar-f32 kernel; got "
            f"{chunk.dtype}/{m.dtype} (c128 callers use the jnp path)"
        )
    lead = chunk.shape[:-2]
    rows, c = chunk.shape[-2:]
    p = m.shape[0]
    if m.shape != (p, rows):
        raise ValueError(f"m must be (p, rows)=({p}, {rows}), got {m.shape}")
    flat = chunk.reshape((-1, rows, c))
    B = flat.shape[0]
    cr, ci = jnp.real(flat), jnp.imag(flat)
    mr, mi = jnp.real(m), jnp.imag(m)
    out_shape = [jax.ShapeDtypeStruct((B, c, p, rows), jnp.float32)] * 2
    fn = pl.pallas_call(
        _chunk_twiddle_pack_kernel,
        grid=(B,),
        in_specs=[
            _bs((1, rows, c), lambda b: (b, 0, 0)),
            _bs((1, rows, c), lambda b: (b, 0, 0)),
            _bs((p, rows), lambda b: (0, 0)),
            _bs((p, rows), lambda b: (0, 0)),
        ],
        out_specs=[
            _bs((1, c, p, rows), lambda b: (b, 0, 0, 0)),
            _bs((1, c, p, rows), lambda b: (b, 0, 0, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )
    o_re, o_im = fn(cr, ci, mr, mi)
    out = jax.lax.complex(o_re, o_im)  # complex64 even under x64
    return out.reshape(lead + (c, p, rows))


def stage_right(
    a: Tuple[jax.Array, jax.Array],
    w: Tuple[jax.Array, jax.Array],
    *,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """A @ W^T over planar-complex operands.

    a: (B, M, K) re/im;  w: (N, K) re/im -> (B, M, N).
    """
    ar, ai = a
    wr, wi = w
    B, M, K = ar.shape
    N = wr.shape[0]
    bm = min(bm, M)
    bn = min(bn, N)
    if M % bm or N % bn:
        raise ValueError(f"(M={M}, N={N}) must tile by (bm={bm}, bn={bn})")
    grid = (B, M // bm, N // bn)
    out_shape = [jax.ShapeDtypeStruct((B, M, N), jnp.float32)] * 2
    fn = pl.pallas_call(
        _stage_right_kernel,
        grid=grid,
        in_specs=[
            _bs((bn, K), lambda b, i, j: (j, 0)),  # W re (rows = output cols)
            _bs((bn, K), lambda b, i, j: (j, 0)),  # W im
            _bs((1, bm, K), lambda b, i, j: (b, i, 0)),  # A re
            _bs((1, bm, K), lambda b, i, j: (b, i, 0)),  # A im
        ],
        out_specs=[
            _bs((1, bm, bn), lambda b, i, j: (b, i, j)),
            _bs((1, bm, bn), lambda b, i, j: (b, i, j)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )
    return tuple(fn(wr, wi, ar, ai))
