"""jit'd public wrappers around the Pallas FFT kernels.

``fft_last_axis(x)`` runs the four-step local FFT with both matmul stages
executed by the fused Pallas kernel (fft_stage.py):

    A = x.reshape(-1, n1, n2)
    B = stage_left(W_n1, A, T_n1n2)      # column DFT + twiddle, fused
    D = stage_right(B, W_n2)             # row DFT
    out[k1 + n1*k2] = D[k1, k2]

On non-TPU backends the kernels run in interpret mode (set explicitly or
auto-detected), which executes the kernel body op-by-op -- bitwise the
same math, so tests/benches on CPU validate exactly what the TPU runs.

Factor choice: n1 * n2 = n with both MXU-aligned where possible; the
wrapper falls back to the pure-jnp matmul FFT for shapes the kernel
cannot tile (non-128-multiples on TPU, primes, n < 256).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.fftmath as lf
from repro.kernels import fft_stage


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _split_planar(x: jax.Array):
    return jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)


def _kernel_factors(n: int) -> Optional[tuple[int, int]]:
    """Pick (n1, n2), both multiples of the MXU lane width when possible."""
    n1 = lf.split_factor(n, lf.MAX_DFT)
    if n1 in (0, n):
        return None
    n2 = n // n1
    if n2 > lf.MAX_DFT:
        return None
    return n1, n2


@functools.partial(jax.jit, static_argnames=("inverse", "interpret", "bm", "bn"))
def _fft_last_axis(x, *, inverse: bool, interpret: bool, bm: int, bn: int):
    n = x.shape[-1]
    factors = _kernel_factors(n)
    if factors is None:  # pragma: no cover - guarded by caller
        return lf.fft_matmul(x, inverse=inverse)
    n1, n2 = factors

    v = jnp.conj(x) if inverse else x
    lead = v.shape[:-1]
    a = v.reshape((-1, n1, n2))
    w1 = jnp.asarray(lf._dft_matrix_np(n1))
    tw = jnp.asarray(lf._twiddle_np(n1, n2))
    w2 = jnp.asarray(lf._dft_matrix_np(n2))

    b_re, b_im = fft_stage.stage_left(
        _split_planar(w1), _split_planar(a), _split_planar(tw),
        bm=min(bm, n1), bn=min(bn, n2), interpret=interpret,
    )
    d_re, d_im = fft_stage.stage_right(
        (b_re, b_im), _split_planar(w2),
        bm=min(bm, n1), bn=min(bn, n2), interpret=interpret,
    )
    d = d_re + 1j * d_im  # (B, k1, k2); flat index k1 + n1*k2
    out = jnp.swapaxes(d, -1, -2).reshape(lead + (n,)).astype(jnp.complex64)
    if inverse:
        out = jnp.conj(out) / n
    return out


def fft_last_axis(
    x: jax.Array,
    *,
    inverse: bool = False,
    interpret: Optional[bool] = None,
    bm: int = 128,
    bn: int = 128,
) -> jax.Array:
    """FFT along the last axis via the Pallas fused-stage kernels."""
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    if interpret is None:
        interpret = _default_interpret()
    n = x.shape[-1]
    factors = _kernel_factors(n)
    if factors is None:
        return lf.fft_matmul(x, inverse=inverse)
    n1, n2 = factors
    if not interpret and (n1 % 128 or n2 % 128):
        # TPU tiling wants 128-lane alignment; fall back rather than pad.
        return lf.fft_matmul(x, inverse=inverse)
    return _fft_last_axis(x, inverse=inverse, interpret=interpret, bm=bm, bn=bn)


def stage_left(w, a, t, **kw):
    """Fused complex (W@A)*T -- thin public re-export (planar operands)."""
    kw.setdefault("interpret", _default_interpret())
    return fft_stage.stage_left(w, a, t, **kw)


def stage_right(a, w, **kw):
    """Complex A @ W^T -- thin public re-export (planar operands)."""
    kw.setdefault("interpret", _default_interpret())
    return fft_stage.stage_right(a, w, **kw)
