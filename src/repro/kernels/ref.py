"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _to_c(re: jax.Array, im: jax.Array) -> jax.Array:
    return re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64)


def stage_left_ref(
    w: Tuple[jax.Array, jax.Array],
    a: Tuple[jax.Array, jax.Array],
    t: Tuple[jax.Array, jax.Array],
) -> Tuple[jax.Array, jax.Array]:
    """(W @ A) * T, complex planar: w (M,K), a (B,K,N), t (M,N)."""
    wc, ac, tc = _to_c(*w), _to_c(*a), _to_c(*t)
    out = jnp.einsum("mk,bkn->bmn", wc, ac) * tc
    return jnp.real(out).astype(jnp.float32), jnp.imag(out).astype(jnp.float32)


def stage_right_ref(
    a: Tuple[jax.Array, jax.Array],
    w: Tuple[jax.Array, jax.Array],
) -> Tuple[jax.Array, jax.Array]:
    """A @ W^T, complex planar: a (B,M,K), w (N,K)."""
    ac, wc = _to_c(*a), _to_c(*w)
    out = jnp.einsum("bmk,nk->bmn", ac, wc)
    return jnp.real(out).astype(jnp.float32), jnp.imag(out).astype(jnp.float32)


def fft_last_axis_ref(x: jax.Array, *, inverse: bool = False) -> jax.Array:
    """Oracle for ops.fft_last_axis: XLA's own FFT."""
    x = x.astype(jnp.complex64)
    return jnp.fft.ifft(x) if inverse else jnp.fft.fft(x)
