"""Periodic FFT Poisson solver on a distributed plan.

Solves ``laplacian(u) = f`` on a periodic box by dividing the spectrum
by ``-|k|^2``: the textbook spectral method, but the transform is the
plan's distributed FFT, so the solve inherits the plan's decomposition
(slab/pencil), collective backend(s) and r2c/c2r payload halving --
solving a real-field Poisson problem through a ``plan_fft(real=True)``
plan moves half the wire bytes of the complex path.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.apps.spectral import plan_directions, wavenumbers


def solve_poisson(
    f: jax.Array,
    plan,
    lengths: Optional[Sequence[float]] = None,
) -> jax.Array:
    """Solve ``laplacian(u) = f`` with periodic BCs; returns the
    zero-mean solution ``u`` (the ``k = 0`` mode is gauge freedom and is
    set to zero -- a solution only exists up to a constant, and only for
    zero-mean ``f``; any mean in ``f`` is projected out).

    ``plan`` must cover ``f``'s trailing dims (leading dims are batch);
    ``lengths`` are the domain sizes per transform axis (default
    ``2*pi``). Real plans take (and return) real fields.
    """
    fwd, inv = plan_directions(plan)
    ks = wavenumbers(plan, lengths)
    k2 = sum(k * k for k in ks)
    # -1/|k|^2 with the k=0 (and Hermitian-padding) entries zeroed
    scale = jnp.where(k2 > 0, -1.0 / jnp.where(k2 > 0, k2, 1.0), 0.0)
    return inv(fwd(f) * scale)
