"""Distributed FFT convolution / correlation through a plan.

Circular (periodic) convolution via the convolution theorem: two forward
transforms, a pointwise product, one inverse -- every transform being
the plan's distributed FFT. With a real plan both operands and the
result stay real and every exchange ships the Hermitian-truncated
payload. For linear (non-circular) convolution, zero-pad the operands to
``len(a) + len(b) - 1`` per axis before planning, as usual.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.spectral import plan_directions


def _check_shapes(a: jax.Array, b: jax.Array) -> None:
    if a.shape != b.shape:
        raise ValueError(
            f"fft convolution operands must share a shape (and the plan's "
            f"layout), got {a.shape} vs {b.shape}"
        )


def fft_convolve(a: jax.Array, b: jax.Array, plan) -> jax.Array:
    """Circular convolution ``(a * b)[n] = sum_m a[m] b[n-m]`` over the
    plan's transform axes (leading dims are batch)."""
    _check_shapes(a, b)
    fwd, inv = plan_directions(plan)
    return inv(fwd(a) * fwd(b))


def fft_correlate(a: jax.Array, b: jax.Array, plan) -> jax.Array:
    """Circular cross-correlation ``c[n] = sum_m a[m + n] conj(b[m])``
    over the plan's transform axes."""
    _check_shapes(a, b)
    fwd, inv = plan_directions(plan)
    return inv(jnp.conj(fwd(b)) * fwd(a))
