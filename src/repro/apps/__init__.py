"""repro.apps -- spectral applications on top of the FFT plan front-end.

Each solver takes a :class:`repro.core.Plan`, so every choice the plan
layer offers -- collective backend (pinned / cost-model auto / measured),
slab vs pencil decomposition, r2c vs c2c transforms, calibrated comm
params -- flows through the application unchanged. The apps never look
at the mesh directly: they read the plan's
:meth:`~repro.core.Plan.spectral_axes` layout contract and operate in
whatever frequency-domain layout (transposed, reversed, Hermitian-padded)
the plan produces.

- :mod:`repro.apps.poisson` -- periodic FFT Poisson solver
- :mod:`repro.apps.convolve` -- distributed circular convolution/correlation
- :mod:`repro.apps.derivatives` -- spectral gradient / laplacian
- :mod:`repro.apps.spectral` -- shared wavenumber-grid plumbing
"""

from repro.apps.convolve import fft_convolve, fft_correlate
from repro.apps.derivatives import gradient, laplacian
from repro.apps.poisson import solve_poisson
from repro.apps.spectral import plan_directions, wavenumbers

__all__ = [
    "fft_convolve",
    "fft_correlate",
    "gradient",
    "laplacian",
    "plan_directions",
    "solve_poisson",
    "wavenumbers",
]
