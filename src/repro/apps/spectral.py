"""Shared spectral plumbing: wavenumber grids in the plan's own layout.

A distributed plan's spectrum is rarely the natural ``fftn`` layout --
slab 2-D output is transposed, pencil 3-D output is axis-reversed, real
plans carry a shard-padded Hermitian axis. Anything multiplying in
frequency space (Poisson, derivatives, filters) therefore needs the
frequency of every *output* position, not of the natural layout.
:meth:`repro.core.Plan.spectral_axes` is the layout contract;
:func:`wavenumbers` turns it into broadcast-ready coordinate arrays, so
the solvers in this package are written once and run under every
decomposition x backend x real/complex combination the plan layer
supports.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def plan_directions(plan) -> Tuple:
    """(to_spectrum, from_spectrum) callables of a plan, regardless of
    which direction it was planned in."""
    if plan.direction == "forward":
        return plan.execute, plan.inverse
    return plan.inverse, plan.execute


def wavenumbers(
    plan, lengths: Optional[Sequence[float]] = None
) -> Tuple[jax.Array, ...]:
    """Angular wavenumbers ``k_d`` for each original transform axis,
    shaped to broadcast against the plan's spectrum layout.

    ``lengths[d]`` is the physical domain length of original data axis
    ``d`` (ordered like the trailing ``plan.ndim`` dims of the input;
    default ``2*pi`` each, making ``k`` the integer mode numbers). The
    returned tuple is ordered by *original* axis, each entry an array of
    ones-except-one-dim shape placed at that axis's position in the
    spectrum layout -- ``sum(k*k for k in wavenumbers(plan))`` is
    ``|k|^2`` in the plan's own output layout.

    Padded Hermitian positions get ``k = 0``: the plan guarantees the
    data there is exactly zero, so any multiplicative use is unaffected.
    """
    nd = plan.ndim
    axes = plan.spectral_axes()
    if lengths is None:
        lengths = (2 * np.pi,) * nd
    lengths = tuple(float(L) for L in lengths)
    if len(lengths) != nd:
        raise ValueError(f"lengths must have {nd} entries (one per transform axis), got {len(lengths)}")
    out = [None] * nd
    for pos, ax in enumerate(axes):
        scale = 2 * np.pi / lengths[ax.orig + nd]
        if ax.half:
            k = np.fft.rfftfreq(ax.n) * ax.n * scale
            k = np.pad(k, (0, ax.n_out - k.shape[0]))
        else:
            k = np.fft.fftfreq(ax.n) * ax.n * scale
        shape = [1] * nd
        shape[pos] = ax.n_out
        out[ax.orig + nd] = jnp.asarray(
            k.reshape(shape), dtype=jnp.zeros((), plan.dtype).real.dtype
        )
    return tuple(out)
