"""Spectral derivatives on a distributed plan: gradient and laplacian.

Differentiation is multiplication by ``i*k`` (or ``-|k|^2``) in
frequency space; the wavenumber grids come from the plan's
:meth:`~repro.core.Plan.spectral_axes` contract, so the same code runs
in the slab-transposed, pencil-reversed and Hermitian-padded layouts.
Real plans keep everything real outside the transform: the derivative
of a real field through an r2c plan is computed on the half spectrum
and lands back as a real array.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

from repro.apps.spectral import plan_directions, wavenumbers


def gradient(
    u: jax.Array,
    plan,
    lengths: Optional[Sequence[float]] = None,
) -> Tuple[jax.Array, ...]:
    """``(du/dx_0, ..., du/dx_{ndim-1})``, ordered like the trailing
    transform axes of the input. One forward transform, one inverse per
    component."""
    fwd, inv = plan_directions(plan)
    uh = fwd(u)
    return tuple(inv(uh * (1j * k)) for k in wavenumbers(plan, lengths))


def laplacian(
    u: jax.Array,
    plan,
    lengths: Optional[Sequence[float]] = None,
) -> jax.Array:
    """``sum_d d^2 u / dx_d^2`` via one forward + one inverse transform."""
    fwd, inv = plan_directions(plan)
    ks = wavenumbers(plan, lengths)
    k2 = sum(k * k for k in ks)
    return inv(fwd(u) * (-k2))
