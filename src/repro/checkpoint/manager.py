"""Fault-tolerant checkpointing: atomic, async, keep-N, cross-mesh.

Durability contract for 1000-node runs:

- *atomic*: a checkpoint is staged into a unique same-dir temp directory
  (``step_<N>.tmp*`` via ``tempfile.mkdtemp``, mirroring the planner's
  ``export_wisdom`` same-filesystem discipline) with the manifest
  written LAST, then ``os.replace``d into place only when complete; a
  crash mid-save never corrupts the latest good checkpoint and never
  collides with a concurrent saver.
- *async*: the device->host transfer blocks, the disk write happens on a
  background thread (joined before the next save / on close) so the
  train loop loses ~0 step time.
- *keep-N*: bounded disk usage with the newest N checkpoints retained.
- *corrupt-skip restore*: ``latest_step``/``restore_latest`` consider
  only checkpoints whose manifest parses and whose shard file exists,
  and ``restore_latest`` falls back to the previous step when the
  newest one fails to load (truncated npz, bit rot) instead of raising
  -- a half-written or damaged directory costs one checkpoint interval,
  not the run.
- *mesh-agnostic restore*: leaves are stored as full logical arrays with
  a manifest of shapes/dtypes; ``restore(..., shardings=...)`` re-shards
  onto whatever mesh the restart got (elastic re-scale). On multi-host,
  each process would write its addressable shards under
  ``proc<k>/`` -- the layout already carries the process index.
"""

from __future__ import annotations

import json
import logging
import os
import shutil
import tempfile
import threading
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

log = logging.getLogger("repro.checkpoint")


def _flatten_with_names(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in path
        )
        flat[name] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, process_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.process_index = process_index
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        self.wait()
        flat = _flatten_with_names(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # device -> host now
        manifest = {
            "step": int(step),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in host.items()},
        }

        def write():
            # unique same-dir tempdir: same filesystem (so os.replace is
            # atomic) and no collision if two savers race the same step;
            # the ".tmp" infix keeps it invisible to all_steps()
            tmp = tempfile.mkdtemp(prefix=f"step_{step:010d}.tmp", dir=self.dir)
            final = self._step_dir(step)
            try:
                np.savez(os.path.join(tmp, f"proc{self.process_index}.npz"), **host)
                # manifest last: its presence marks the payload complete
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
            except BaseException:
                shutil.rmtree(tmp, ignore_errors=True)
                raise
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        """Every step directory present on disk, complete or not."""
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp" not in name:
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _is_valid(self, step: int) -> bool:
        """Cheap completeness check: the manifest parses, names this
        step, and this process's shard file exists. (Deeper corruption
        -- a truncated npz -- is caught at load time by
        :meth:`restore_latest`'s fallback.)"""
        d = self._step_dir(step)
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError):
            return False
        if not isinstance(manifest, dict) or manifest.get("step") != step:
            return False
        return os.path.exists(os.path.join(d, f"proc{self.process_index}.npz"))

    def valid_steps(self) -> List[int]:
        """Steps whose checkpoint passes the completeness check."""
        return [s for s in self.all_steps() if self._is_valid(s)]

    def latest_step(self) -> Optional[int]:
        """Newest *complete* checkpoint step (a partial or corrupt
        directory -- missing/unparseable manifest, missing shard -- is
        skipped rather than offered for restore)."""
        steps = self.valid_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, *, shardings: Any = None) -> Any:
        """Restore into the structure of ``target``; ``shardings`` (same
        structure, NamedShardings) re-shards for the current mesh."""
        self.wait()
        path = os.path.join(self._step_dir(step), f"proc{self.process_index}.npz")
        data = np.load(path)
        names = list(_flatten_with_names(target).keys())
        flat_target, treedef = jax.tree.flatten(target)
        flat_sh = treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(
            flat_target
        )
        out = []
        for name, tgt, sh in zip(names, flat_target, flat_sh):
            arr = data[name]
            if tuple(arr.shape) != tuple(jnp.shape(tgt)):
                raise ValueError(f"shape mismatch for {name}: {arr.shape} vs {jnp.shape(tgt)}")
            arr = arr.astype(np.dtype(jnp.result_type(tgt)) if hasattr(tgt, "dtype") else arr.dtype)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return treedef.unflatten(out)

    def restore_latest(
        self, target: Any, *, shardings: Any = None
    ) -> Tuple[Optional[int], Any]:
        """Restore the newest checkpoint that actually loads, walking
        back past corrupt/partial ones (one warning each) -- the
        recovery loop's entry point. Returns ``(None, None)`` when no
        checkpoint survives."""
        for step in reversed(self.valid_steps()):
            try:
                return step, self.restore(step, target, shardings=shardings)
            except Exception as e:  # noqa: BLE001 -- fall back to the previous step
                log.warning(
                    "checkpoint step %d unreadable (%s: %s); falling back",
                    step, type(e).__name__, e,
                )
        return None, None
