"""Fault-tolerant checkpointing: atomic, async, keep-N, cross-mesh.

Durability contract for 1000-node runs:

- *atomic*: a checkpoint is written into ``step_<N>.tmp`` and
  ``os.replace``d into place only when complete; a crash mid-save never
  corrupts the latest good checkpoint.
- *async*: the device->host transfer blocks, the disk write happens on a
  background thread (joined before the next save / on close) so the
  train loop loses ~0 step time.
- *keep-N*: bounded disk usage with the newest N checkpoints retained.
- *mesh-agnostic restore*: leaves are stored as full logical arrays with
  a manifest of shapes/dtypes; ``restore(..., shardings=...)`` re-shards
  onto whatever mesh the restart got (elastic re-scale). On multi-host,
  each process would write its addressable shards under
  ``proc<k>/`` -- the layout already carries the process index.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in path
        )
        flat[name] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, process_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.process_index = process_index
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        self.wait()
        flat = _flatten_with_names(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # device -> host now
        manifest = {
            "step": int(step),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in host.items()},
        }

        def write():
            tmp = os.path.join(self.dir, f"step_{step:010d}.tmp")
            final = os.path.join(self.dir, f"step_{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"proc{self.process_index}.npz"), **host)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, *, shardings: Any = None) -> Any:
        """Restore into the structure of ``target``; ``shardings`` (same
        structure, NamedShardings) re-shards for the current mesh."""
        self.wait()
        path = os.path.join(self.dir, f"step_{step:010d}", f"proc{self.process_index}.npz")
        data = np.load(path)
        names = list(_flatten_with_names(target).keys())
        flat_target, treedef = jax.tree.flatten(target)
        flat_sh = treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(
            flat_target
        )
        out = []
        for name, tgt, sh in zip(names, flat_target, flat_sh):
            arr = data[name]
            if tuple(arr.shape) != tuple(jnp.shape(tgt)):
                raise ValueError(f"shape mismatch for {name}: {arr.shape} vs {jnp.shape(tgt)}")
            arr = arr.astype(np.dtype(jnp.result_type(tgt)) if hasattr(tgt, "dtype") else arr.dtype)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return treedef.unflatten(out)

    def restore_latest(self, target: Any, *, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target, shardings=shardings)
