"""Loop-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers program under-reports FLOPs/bytes/collectives by the
layer count -- useless for a roofline. This module re-derives the three
roofline inputs directly from the scheduled HLO:

- **flops**: every ``dot`` op's 2*prod(result)*prod(contracting) from
  the operand symbol table (elementwise/transcendental flops are noise
  next to the matmuls at LM shapes).
- **hbm bytes**: matmul-boundary traffic -- ``dot`` operands + results,
  slice/gather/scatter results, dynamic-update-slice update payloads --
  which is what a well-fused TPU executable actually moves per layer.
  Inside loop bodies, elementwise/convert/broadcast/copy results are
  assumed fused into their producers (counting them would inflate the
  term ~10x with CPU-HLO's unfused soup); at the entry level they ARE
  counted (that's where param/optimizer update traffic lives).
- **collective bytes**: per-kind ring-factor accounting (comm_model.py)
  of every collective op.

All three roll up through the call graph: ``while`` bodies multiply by
``known_trip_count`` (from backend_config), fusions/calls add once,
conditional branches contribute their max. Validated against analytic
6ND counts in tests/test_roofline.py.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

from repro.core.comm_model import (
    COLLECTIVE_KINDS,
    _DTYPE_BYTES,
    collective_payload_bytes,
    collective_scaled_bytes,
    shape_bytes as _shape_bytes,
    split_op_line,
)

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->\s*(.+?)\s*\{")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPNAME = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r"known_trip_count[\"':\s{]+n[\"':\s]+(\d+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_SKIP_OPS = frozenset(
    "tuple get-tuple-element parameter constant bitcast copy-start copy-done "
    "after-all add-dependency partition-id replica-id".split()
)


def _shape_dims(text: str) -> List[int]:
    m = _SHAPE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class OpLine:
    name: str
    kind: str
    result_type: str
    operands: List[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]  # param name -> type string
    ops: List[OpLine]


def _parse_operand_names(text: str, start: int) -> List[str]:
    """Operand %names from the balanced-paren argument list whose opening
    parenthesis is at ``text[start]``."""
    depth = 0
    end = start
    for i in range(start, len(text)):
        if text[i] == "(":
            depth += 1
        elif text[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%([\w\.\-]+)", text[start + 1 : end])


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and ("%" in line.split("(")[0] or line.strip().startswith("ENTRY")):
                name = m.group(1)
                params = {}
                for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\)|[^,)]+))", m.group(2)):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(name, params, [])
                if line.strip().startswith("ENTRY"):
                    entry = name
            continue
        s = line.strip()
        if s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        om = _OPNAME.match(s)
        if not om or "=" not in s:
            continue
        rhs = s.split("=", 1)[1].lstrip()
        # result type / op name split (layout-annotation safe)
        split = split_op_line(rhs)
        if split is None:
            continue
        result_type, kind = split
        args_start = rhs.find("(", rhs.find(kind, len(result_type)))
        cur.ops.append(
            OpLine(
                name=om.group(1),
                kind=kind,
                result_type=result_type.strip(),
                operands=_parse_operand_names(rhs, args_start),
                raw=s,
            )
        )
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.hbm_bytes += mult * other.hbm_bytes
        self.coll_bytes += mult * other.coll_bytes
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + mult * v
        for k, v in other.coll_bytes_by_kind.items():
            self.coll_bytes_by_kind[k] = self.coll_bytes_by_kind.get(k, 0.0) + mult * v


_MEMORY_OPS = frozenset(
    "gather scatter dynamic-slice slice concatenate reduce sort".split()
)


class HloAnalyzer:
    def __init__(self, text: str, *, default_group: int = 1):
        self.comps, self.entry = parse_hlo(text)
        self.default_group = default_group
        self._memo: Dict[Tuple[str, bool, bool], Cost] = {}

    # -- symbol table ---------------------------------------------------------
    def _type_of(self, comp: Computation, name: str) -> str:
        for op in comp.ops:
            if op.name == name:
                return op.result_type
        if name in comp.params:
            return comp.params[name]
        # e.g. %param.3 inside header with different dotting
        base = name.split("/")[-1]
        return comp.params.get(base, "")

    # -- per-op costs ----------------------------------------------------------
    def _dot_flops(self, comp: Computation, op: OpLine) -> float:
        res_dims = _shape_dims(op.result_type)
        cm = _CONTRACT.search(op.raw)
        if not cm or not op.operands:
            return 0.0
        lhs_type = self._type_of(comp, op.operands[0])
        lhs_dims = _shape_dims(lhs_type)
        cdims = [int(x) for x in cm.group(1).split(",") if x]
        k = 1
        for c in cdims:
            if c < len(lhs_dims):
                k *= lhs_dims[c]
        out = 1
        for d in res_dims:
            out *= d
        return 2.0 * out * k

    def _collective_bytes(self, op: OpLine) -> Tuple[str, float]:
        base = op.kind
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base not in COLLECTIVE_KINDS:
            return "", 0.0
        if op.kind.endswith("-done"):
            return base, 0.0  # payload already counted at the -start
        # payload extraction and ring factors are both shared with
        # comm_model.parse_collectives -- the two parsers cannot drift
        size = collective_payload_bytes(
            op.result_type, is_start=op.kind.endswith("-start"), kind=base
        )
        if base == "collective-permute":
            # point-to-point (source_target_pairs, no replica_groups)
            p = 1
        else:
            gm = _GROUPS_IOTA.search(op.raw)
            if gm:
                p = int(gm.group(2))
            else:
                gm2 = _GROUPS_LIST.search(op.raw)
                p = len(gm2.group(1).split(",")) if gm2 else self.default_group
        return base, collective_scaled_bytes(base, size, p)

    # -- roll-up ----------------------------------------------------------------
    def cost_of(
        self, comp_name: str, *, inside_fusion: bool = False, in_loop: bool = False
    ) -> Cost:
        key = (comp_name, inside_fusion, in_loop)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(comp_name)
        total = Cost()
        if comp is None:
            return total
        self._memo[key] = total  # break cycles
        for op in comp.ops:
            if op.kind in _SKIP_OPS:
                continue
            if op.kind == "while":
                tm = _TRIP.search(op.raw)
                trips = int(tm.group(1)) if tm else 1
                body = _CALLS.search(op.raw)
                if body:
                    total.add(self.cost_of(body.group(1), in_loop=True), trips)
                continue
            if op.kind == "conditional":
                bm = _BRANCHES.search(op.raw)
                if bm:
                    branches = re.findall(r"%?([\w\.\-]+)", bm.group(1))
                    costs = [self.cost_of(b, in_loop=in_loop) for b in branches]
                    if costs:
                        best = max(costs, key=lambda c: c.flops + c.hbm_bytes)
                        total.add(best)
                continue
            if op.kind == "fusion":
                fm = _CALLS.search(op.raw)
                if fm:
                    total.add(self.cost_of(fm.group(1), inside_fusion=True, in_loop=in_loop))
                if not in_loop:
                    total.hbm_bytes += _shape_bytes(op.result_type)  # e.g. optimizer writes
                continue
            if op.kind in ("call", "custom-call", "async-start"):
                fm = _CALLS.search(op.raw)
                if fm:
                    total.add(
                        self.cost_of(fm.group(1), inside_fusion=inside_fusion, in_loop=in_loop)
                    )
                if op.kind == "custom-call":
                    total.hbm_bytes += _shape_bytes(op.result_type)
                continue
            ckind, cbytes = self._collective_bytes(op)
            if ckind:
                if op.kind.endswith("-done"):
                    continue
                total.coll_bytes += cbytes
                total.coll_counts[ckind] = total.coll_counts.get(ckind, 0.0) + 1
                total.coll_bytes_by_kind[ckind] = (
                    total.coll_bytes_by_kind.get(ckind, 0.0) + cbytes
                )
                continue
            if op.kind == "dot":
                total.flops += self._dot_flops(comp, op)
                total.hbm_bytes += _shape_bytes(op.result_type) + sum(
                    _shape_bytes(self._type_of(comp, o)) for o in op.operands
                )
            elif op.kind == "fft":
                # XLA FFT op: standard 5 N log2 N per transform (c2c)
                import math as _m

                dims = _shape_dims(op.result_type)
                if dims:
                    n = dims[-1]
                    batch = 1
                    for d in dims[:-1]:
                        batch *= d
                    total.flops += 5.0 * batch * n * max(_m.log2(max(n, 2)), 1.0)
                total.hbm_bytes += _shape_bytes(op.result_type) * 2
            elif op.kind == "dynamic-update-slice":
                # writes only the update payload (operand 1)
                if len(op.operands) > 1:
                    total.hbm_bytes += _shape_bytes(self._type_of(comp, op.operands[1]))
            elif op.kind in _MEMORY_OPS:
                total.hbm_bytes += _shape_bytes(op.result_type)
            elif not in_loop:
                total.hbm_bytes += _shape_bytes(op.result_type)
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.cost_of(self.entry)


def analyze_compiled(compiled, *, default_group: int = 1) -> Cost:
    return HloAnalyzer(compiled.as_text(), default_group=default_group).entry_cost()
