"""Version compatibility shims for the jax APIs the core layer leans on.

The repo targets the modern ``jax.shard_map`` / ``jax.sharding.AxisType``
surface; older jaxlibs (e.g. the 0.4.3x line) ship the same machinery
under ``jax.experimental.shard_map`` and have no axis types at all.
Everything below resolves to the native API when it exists, so on a
current jax these wrappers are zero-cost aliases.
"""

from __future__ import annotations

from typing import Sequence

import jax
from jax import lax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` with a fallback to the experimental module.

    ``check_vma`` maps to the native kwarg when given; the experimental
    fallback always runs with its (equivalent) ``check_rep`` disabled --
    the replication checker predates several collective patterns used
    here."""
    if hasattr(jax, "shard_map"):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def axis_size(axis_name: str) -> int:
    """Static size of a shard_map axis (``lax.axis_size`` when available)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """1-to-1 ``jax.make_mesh`` with auto axis types when the version has
    typed axes (shard_map + jit sharding propagation both work)."""
    try:
        from jax.sharding import AxisType

        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names), axis_types=(AxisType.Auto,) * len(axis_names)
        )
    except ImportError:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def make_mesh_1d(p: int, axis_name: str = "model"):
    """The FFT benchmarks' standard 1-D mesh over the first ``p`` devices."""
    return make_mesh((p,), (axis_name,))
