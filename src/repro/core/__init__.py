"""repro.core -- the paper's contribution: distributed FFT over
strategy-switchable collectives, plus the generalized decomposed-collective
overlap layer reused across the LM stack.

The collective strategies are pluggable backends (repro.core.backends --
the HPX parcelport analogue); the user-facing entry point is the
FFTW-style plan/executor (``plan_fft`` -> ``Plan``)."""

from repro.core import backends
from repro.core.backends import CollectiveBackend
from repro.core.distributed_fft import FFTConfig, fft2, ifft2, fft3, fft1d_large, reference_fft2
from repro.core.fftmath import local_fft, local_fft2, fft_matmul, dft_matrix, MAX_DFT
from repro.core.grid import ProcessGrid, auto_grid_shape, grid_from_mesh, grid_shapes, make_grid
from repro.core.overlap import (
    collective_matmul_ag,
    ring_all_gather,
    ring_reduce_scatter,
    ring_scatter_reduce,
)
from repro.core.comm_model import CommParams
from repro.core.pencil import PencilConfig, pencil_fft2, pencil_fft3
from repro.core.plan import FFTPlan, Plan, SpectralAxis, make_plan, plan_fft
from repro.core.planner import export_wisdom, forget_wisdom, import_wisdom, wisdom_size
from repro.core.real import (
    irfft2,
    irfft3,
    pencil_irfft2,
    pencil_irfft3,
    pencil_rfft2,
    pencil_rfft3,
    rfft2,
    rfft3,
    rfft_len,
)
from repro.core.transpose import distributed_transpose, transpose_then_fft

__all__ = [
    "CollectiveBackend", "CommParams", "FFTConfig", "FFTPlan", "MAX_DFT",
    "PencilConfig", "Plan", "ProcessGrid", "SpectralAxis", "auto_grid_shape",
    "backends", "collective_matmul_ag", "dft_matrix", "distributed_transpose",
    "export_wisdom", "fft1d_large", "fft2", "fft3", "fft_matmul",
    "forget_wisdom", "grid_from_mesh", "grid_shapes", "ifft2", "import_wisdom",
    "irfft2", "irfft3", "local_fft", "local_fft2", "make_grid", "make_plan",
    "pencil_fft2", "pencil_fft3", "pencil_irfft2", "pencil_irfft3",
    "pencil_rfft2", "pencil_rfft3", "plan_fft", "reference_fft2", "rfft2",
    "rfft3", "rfft_len", "ring_all_gather", "ring_reduce_scatter",
    "ring_scatter_reduce", "transpose_then_fft", "wisdom_size",
]
