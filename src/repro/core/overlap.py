"""Decomposed collectives with interleaved compute -- the paper's technique
as a reusable layer.

The paper's contribution generalizes past FFT: *replace one synchronized
collective with a sequence of smaller direct sends so per-chunk compute
can hide behind the remaining communication*. This module provides that
pattern for the three collective shapes the rest of the framework needs:

- ``ring_scatter_reduce``  : all-to-all whose received chunks are folded
  into an accumulator (used by the fused scatter-FFT and MoE combine).
- ``ring_all_gather``      : all-gather decomposed into P-1 ppermutes with
  an optional per-chunk consumer (ring attention / collective matmul).
- ``collective_matmul_ag`` : y = all_gather(x) @ w without materializing
  the gather -- each arriving x-chunk is multiplied into the accumulator
  while the next chunk is in flight (Wang et al.-style overlap; here it
  is the direct LM-side analogue of the paper's scatter-FFT).
- ``ring_reduce_scatter``  : psum_scatter decomposed into a ring with the
  running partial folded at each hop.

All functions must run inside ``shard_map`` over ``axis_name``.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import axis_size


def ring_scatter_reduce(
    x: jax.Array,
    axis_name: str,
    chunk_fn: Callable[[jax.Array, jax.Array], jax.Array],
    *,
    split_axis: int = -1,
) -> jax.Array:
    """All-to-all + reduce: chunk j of every rank's ``x`` is sent to rank j,
    and each rank folds arriving chunks with ``sum(chunk_fn(chunk, src))``.

    ``x`` local shape (..., P*c) along ``split_axis``; chunk_fn receives the
    (..., c) chunk and the (traced) source rank, returning the partial to
    accumulate. The own-chunk partial is computed first (step 0), then each
    ppermute hop delivers the next partial's input while the previous
    partial is being computed.
    """
    p = axis_size(axis_name)
    me = lax.axis_index(axis_name)
    split_axis = split_axis % x.ndim
    if x.shape[split_axis] % p:
        raise ValueError(f"axis {split_axis} ({x.shape[split_axis]}) not divisible by {p}")
    c = x.shape[split_axis] // p

    def chunk(i: jax.Array) -> jax.Array:
        return lax.dynamic_slice_in_dim(x, i * c, c, axis=split_axis)

    if p == 1:
        return chunk_fn(chunk(jnp.asarray(0)), jnp.asarray(0))

    acc = chunk_fn(chunk(me), me)
    for s in range(1, p):
        perm = [(i, (i + s) % p) for i in range(p)]
        recv = lax.ppermute(chunk((me + s) % p), axis_name, perm)
        src = (me - s) % p
        acc = acc + chunk_fn(recv, src)
    return acc


def ring_all_gather(
    x: jax.Array,
    axis_name: str,
    chunk_fn: Optional[Callable[[jax.Array, jax.Array], jax.Array]] = None,
    *,
    axis: int = 0,
) -> jax.Array:
    """All-gather decomposed into a P-1 step neighbour ring.

    Without ``chunk_fn`` returns the gathered array (shards concatenated in
    rank order along ``axis``). With ``chunk_fn(chunk, src)`` returns the
    *sum* of per-chunk results instead, never materializing the gather.
    """
    p = axis_size(axis_name)
    me = lax.axis_index(axis_name)
    axis = axis % x.ndim
    if p == 1:
        return chunk_fn(x, jnp.asarray(0)) if chunk_fn is not None else x

    perm = [(i, (i + 1) % p) for i in range(p)]  # pass left-to-right
    if chunk_fn is None:
        out_shape = x.shape[:axis] + (p * x.shape[axis],) + x.shape[axis + 1 :]
        out = jnp.zeros(out_shape, x.dtype)
        buf = x
        src = me
        out = lax.dynamic_update_slice_in_dim(out, buf, src * x.shape[axis], axis=axis)
        for _ in range(p - 1):
            buf = lax.ppermute(buf, axis_name, perm)
            src = (src - 1) % p
            out = lax.dynamic_update_slice_in_dim(out, buf, src * x.shape[axis], axis=axis)
        return out

    buf = x
    acc = chunk_fn(buf, me)
    src = me
    for _ in range(p - 1):
        buf = lax.ppermute(buf, axis_name, perm)
        src = (src - 1) % p
        acc = acc + chunk_fn(buf, src)
    return acc


def collective_matmul_ag(
    x: jax.Array,
    w: jax.Array,
    axis_name: str,
    *,
    contract_chunks_of: str = "w",
) -> jax.Array:
    """y = all_gather(x, axis=-1) @ w  without the materialized gather.

    ``x`` local (..., k/P); ``w`` local (k, n) when chunks index rows of the
    *full* weight (``contract_chunks_of='w'`` means each rank holds the full
    w and consumes row-block src*k/P of it per arriving chunk), so
    y = sum_src x_src @ w[src*kc:(src+1)*kc].  This is the LM-side
    instantiation of the paper's scatter-FFT: a reduction whose terms are
    computed as their operands arrive.
    """
    del contract_chunks_of
    kc = x.shape[-1]

    def chunk_fn(chunk: jax.Array, src: jax.Array) -> jax.Array:
        w_slice = lax.dynamic_slice_in_dim(w, src * kc, kc, axis=0)
        return jnp.einsum("...k,kn->...n", chunk, w_slice)

    return ring_all_gather(x, axis_name, chunk_fn, axis=-1)


def ring_reduce_scatter(x: jax.Array, axis_name: str, *, axis: int = -1) -> jax.Array:
    """psum_scatter decomposed into a P-1 step ring with the running partial
    added at each hop (result shard s = sum over ranks of their chunk s).
    """
    p = axis_size(axis_name)
    me = lax.axis_index(axis_name)
    axis = axis % x.ndim
    if x.shape[axis] % p:
        raise ValueError(f"axis {axis} ({x.shape[axis]}) not divisible by {p}")
    c = x.shape[axis] // p
    if p == 1:
        return x

    def chunk(i: jax.Array) -> jax.Array:
        return lax.dynamic_slice_in_dim(x, i * c, c, axis=axis)

    perm = [(i, (i + 1) % p) for i in range(p)]
    # The partial destined to rank c starts at rank c+1 and travels P-1
    # forward hops, absorbing each visited rank's chunk c; so rank ``me``
    # seeds chunk (me-1), and after hop t receives the partial for chunk
    # (me-1-t), finishing with its own fully-reduced chunk ``me``.
    acc = chunk((me - 1) % p)
    for t in range(1, p):
        acc = lax.ppermute(acc, axis_name, perm)
        acc = acc + chunk((me - 1 - t) % p)
    return acc
