"""Distributed transpose strategies -- the paper's experimental axis.

The FFT pencil exchange moves chunk *i* of every node's local block to
node *i* (each node keeps 1/P and ships (1-1/P) of its data). The paper
realizes this with either one synchronized ``all-to-all`` or with N
``scatter`` collectives that let arriving chunks be transposed while the
rest of the communication is still in flight.

TPU adaptation (see DESIGN.md #2): the switchable "parcelport" becomes a
switchable *collective lowering strategy* over the fixed ICI fabric:

``alltoall``
    One fused ``jax.lax.all_to_all`` -- the paper's synchronized baseline.
``scatter``
    P-1 direct ``ppermute`` sends (a ring walk over distances 1..P-1).
    The per-chunk callback runs as soon as chunk *k* lands, so XLA's
    async collective-permute overlaps step k+1's communication with
    chunk k's compute -- the paper's N-scatter overlap, as dataflow.
``bisection``
    Bruck / hypercube exchange: ceil(log2 P) rounds of half-the-buffer
    messages. Fewer, larger messages -- wins when per-message latency
    (the paper's TCP-overhead regime, Fig. 3) dominates. Beyond-paper.

All strategies are SPMD-uniform (masks/permutations do not branch on the
device id except through ``lax.axis_index`` arithmetic) and are validated
against each other and a numpy routing simulation in tests.

Inside ``shard_map`` the local block is ``(..., r, C)`` where the global
rows ``R = P*r`` are sharded over ``axis_name``; the transposed result is
``(..., c, R)`` with the global columns ``C = P*c`` now sharded.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import axis_size as _axis_size

#: A registered backend name (see ``repro.core.backends.available()``).
#: Plain ``str`` on purpose: the registry, not a hand-kept enumeration,
#: defines the valid set.
Strategy = str

#: chunk_fn(chunk, src_index) -> processed chunk. ``chunk`` is the
#: (..., r, c) block received from shard ``src_index``, already transposed
#: to (..., c, r) when ``pre_transposed`` -- see _scatter below.
ChunkFn = Callable[[jax.Array, jax.Array], jax.Array]


def _split_chunks(x: jax.Array, p: int) -> jax.Array:
    """(..., r, C) -> (p, ..., r, c): chunk j holds columns [j*c, (j+1)*c)."""
    *lead, r, C = x.shape
    c = C // p
    x = x.reshape(*lead, r, p, c)
    return jnp.moveaxis(x, -2, 0)


def _merge_rows(chunks: jax.Array) -> jax.Array:
    """(p, ..., r, c) -> (..., p*r, c): stack chunk j as rows [j*r, (j+1)*r)."""
    p = chunks.shape[0]
    chunks = jnp.moveaxis(chunks, 0, -3)  # (..., p, r, c)
    *lead, _, r, c = chunks.shape
    return chunks.reshape(*lead, p * r, c)


def _transpose_local(x: jax.Array) -> jax.Array:
    return jnp.swapaxes(x, -1, -2)


# ---------------------------------------------------------------------------
# Strategy: fused all-to-all (the paper's synchronized collective)
# ---------------------------------------------------------------------------


def _alltoall(x: jax.Array, axis_name: str) -> jax.Array:
    # (..., r, C) --split cols/concat rows--> (..., R, c) --local T--> (..., c, R)
    y = lax.all_to_all(x, axis_name, split_axis=x.ndim - 1, concat_axis=x.ndim - 2, tiled=True)
    return _transpose_local(y)


# ---------------------------------------------------------------------------
# Strategy: N-scatter ring (the paper's proposed decomposition)
# ---------------------------------------------------------------------------


def _chunked_exchange(
    x: jax.Array,
    axis_name: str,
    chunk_fn: Optional[ChunkFn],
    schedule,
) -> jax.Array:
    """Shared P-1-round chunk-streaming exchange.

    ``schedule(me, s, p)`` defines round s: the static ppermute ``perm``,
    the chunk slot this rank ships, and the source rank of the chunk it
    receives. Each received chunk is transposed (and optionally further
    processed by ``chunk_fn``) immediately -- 'the arriving data chunks
    can be transposed as soon as they are received' (paper, §3).

    Dataflow note: every send uses a *pre-existing* chunk of the input, so
    no ppermute depends on any chunk_fn result. XLA is free to issue the
    next round while the previous chunk's transpose/compute runs; on TPU
    the sends lower to async collective-permute-start/done pairs.
    """
    p = _axis_size(axis_name)
    me = lax.axis_index(axis_name)
    chunks = _split_chunks(x, p)  # (p, ..., r, c)
    r, c = x.shape[-2], x.shape[-1] // p

    def process(chunk: jax.Array, src: jax.Array) -> jax.Array:
        out = _transpose_local(chunk)  # (..., c, r)
        if chunk_fn is not None:
            out = chunk_fn(out, src)
        return out

    # Own chunk (round 0) -- compute immediately, no communication.
    own = jnp.take(chunks, me, axis=0)
    parts = [(me, process(own, me))]
    for s in range(1, p):
        perm, send_slot, src = schedule(me, s, p)
        send = jnp.take(chunks, send_slot, axis=0)
        recv = lax.ppermute(send, axis_name, perm)
        parts.append((src, process(recv, src)))

    # Assemble (..., c, R): chunk from src j supplies columns [j*r, (j+1)*r).
    out_shape = x.shape[:-2] + (c, p * r)
    out = jnp.zeros(out_shape, x.dtype)
    for src, part in parts:
        out = lax.dynamic_update_slice_in_dim(out, part, src * r, axis=out.ndim - 1)
    return out


def _scatter(
    x: jax.Array,
    axis_name: str,
    chunk_fn: Optional[ChunkFn] = None,
) -> jax.Array:
    """P-1 direct sends, a one-directional ring walk over distances
    1..P-1 -- the paper's N-scatter decomposition."""

    def ring(me, s, p):
        # round s: ship the chunk destined to me+s; receive from me-s
        return [(i, (i + s) % p) for i in range(p)], (me + s) % p, (me - s) % p

    return _chunked_exchange(x, axis_name, chunk_fn, ring)


# ---------------------------------------------------------------------------
# Strategy: Bruck / bisection exchange (beyond-paper)
# ---------------------------------------------------------------------------


def _bisection(x: jax.Array, axis_name: str) -> jax.Array:
    """Bruck all-to-all: ceil(log2 P) rounds, each shipping the slots whose
    round-bit is set. Message count log P (vs P-1), bytes P/2 slots per
    round (vs 1 slot per step) -- the latency/bandwidth trade the paper
    probes with its chunk-size benchmark.

    Slot invariant: after the initial rotation, slot j at rank i holds the
    chunk destined to (i + j) mod P; slot j travels a total distance j by
    moving +2^t on each set bit t; the final flip+rotation orders the
    received chunks by source rank.
    """
    p = _axis_size(axis_name)
    me = lax.axis_index(axis_name)
    chunks = _split_chunks(x, p)  # (p, ..., r, c), slot d = chunk destined to d
    r = x.shape[-2]

    # Phase 1: rotate so slot j holds destination (me + j) mod p.
    buf = jnp.roll(chunks, -me, axis=0)

    # Phase 2: log rounds of exchange with rank (me + 2^t). The travelling
    # slot set {j : bit t of j set} is static and identical on every rank,
    # so we ship exactly those slots (half the buffer), not a masked copy.
    t = 0
    while (1 << t) < p:
        step = 1 << t
        idx = tuple(j for j in range(p) if (j >> t) & 1)
        perm = [(i, (i + step) % p) for i in range(p)]
        recv = lax.ppermute(buf[idx, ...], axis_name, perm)
        buf = buf.at[idx, ...].set(recv)
        t += 1

    # Phase 3: slot j now holds the chunk from source (me - j) mod p.
    by_src = jnp.flip(jnp.roll(buf, -(me + 1), axis=0), axis=0)  # slot s = from rank s
    stacked = _merge_rows(by_src)  # (..., R, c)
    return _transpose_local(stacked)  # (..., c, R)


# ---------------------------------------------------------------------------
# Strategy: pairwise XOR exchange (beyond-paper)
# ---------------------------------------------------------------------------


def _pairwise_xor(
    x: jax.Array,
    axis_name: str,
    chunk_fn: Optional[ChunkFn] = None,
) -> jax.Array:
    """Pairwise exchange: round s swaps one chunk with partner (me XOR s).

    XOR with a fixed s is an involution, so every round is a symmetric
    bidirectional swap (both halves of each link busy), unlike the ring's
    one-directional walk. Requires power-of-two P (XOR must stay a
    permutation of the ranks). Chunks arrive incrementally, so per-chunk
    ``chunk_fn`` processing overlaps the next round exactly as in
    ``scatter``.
    """

    def swap(me, s, p):
        # round s: both ship to and receive from the same partner me^s
        return [(i, i ^ s) for i in range(p)], me ^ s, me ^ s

    return _chunked_exchange(x, axis_name, chunk_fn, swap)


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------


def distributed_transpose(
    x: jax.Array,
    axis_name: str,
    *,
    strategy: str = "alltoall",
    chunk_fn: Optional[ChunkFn] = None,
) -> jax.Array:
    """Transpose a (..., R, C) array whose R axis is sharded over
    ``axis_name`` into a (..., C, R) array with C sharded. Must be called
    inside ``shard_map``; local in (..., r, C), local out (..., c, R).

    ``strategy`` names a registered :mod:`repro.core.backends` backend;
    ``chunk_fn`` is only honoured by chunk-streaming backends
    (``backend.supports_chunk_fn`` -- the monolithic collectives have
    nothing to interleave, exactly the paper's point).
    """
    from repro.core import backends  # late import: backends registers over us

    backend = backends.get(strategy)
    if backend.kind != "shard_map":
        raise ValueError(
            f"backend {strategy!r} is a whole-transform backend with no "
            f"shard_map transpose; use it through fft2/fft3/plan_fft"
        )
    p = _axis_size(axis_name)
    if x.shape[-1] % p:
        raise ValueError(
            f"column count {x.shape[-1]} not divisible by the {p} shards of "
            f"mesh axis {axis_name!r} (plan-level shapes are validated by "
            f"plan_fft; direct callers must pre-chunk)"
        )
    if chunk_fn is not None and not backend.supports_chunk_fn:
        raise ValueError(
            f"chunk_fn requires a chunk-streaming backend "
            f"(got {strategy!r}; streaming: "
            f"{[b for b in backends.available() if backends.get(b).supports_chunk_fn]})"
        )
    if p == 1:
        y = _transpose_local(x)
        if chunk_fn is not None:
            y = chunk_fn(y, jnp.asarray(0))
        return y
    if not backend.supports(p):
        raise ValueError(f"backend {strategy!r} does not support P={p}")
    return backend.transpose(x, axis_name, chunk_fn)
