"""Distributed transpose strategies -- the paper's experimental axis.

The FFT pencil exchange moves chunk *i* of every node's local block to
node *i* (each node keeps 1/P and ships (1-1/P) of its data). The paper
realizes this with either one synchronized ``all-to-all`` or with N
``scatter`` collectives that let arriving chunks be transposed while the
rest of the communication is still in flight.

TPU adaptation (see DESIGN.md #2): the switchable "parcelport" becomes a
switchable *collective lowering strategy* over the fixed ICI fabric:

``alltoall``
    One fused ``jax.lax.all_to_all`` -- the paper's synchronized baseline.
``scatter``
    P-1 direct ``ppermute`` sends (a ring walk over distances 1..P-1).
    The per-chunk callback runs as soon as chunk *k* lands, so XLA's
    async collective-permute overlaps step k+1's communication with
    chunk k's compute -- the paper's N-scatter overlap, as dataflow.
``bisection``
    Bruck / hypercube exchange: ceil(log2 P) rounds of half-the-buffer
    messages. Fewer, larger messages -- wins when per-message latency
    (the paper's TCP-overhead regime, Fig. 3) dominates. Beyond-paper.

**Pipelining (``n_chunks``).** The streaming exchanges decouple the chunk
count from P: each peer block can be sub-chunked into ``q`` pieces so the
exchange ships ``(P-1)*q`` smaller messages. Every send still uses a
pre-existing slice of the input (double buffering as dataflow: no send
depends on any chunk_fn result), so sub-chunk t's compute hides behind
sub-chunk t+1's flight -- even at P=2, where the classic per-peer
streaming has a single round and nothing to overlap.

**Compute fusion.** :func:`transpose_then_fft` folds the *next FFT
pass* into the exchange on streaming backends: the length-R DFT after a
transpose decomposes over source ranks (decimation in time, j = src*r +
j2), so each arriving chunk contributes a rank-1 outer product with one
DFT-matrix column -- cheap, and fully overlapped with the remaining
sends. Monolithic backends fall back to transpose + local FFT.

All strategies are SPMD-uniform (masks/permutations do not branch on the
device id except through ``lax.axis_index`` arithmetic) and are validated
against each other and a numpy routing simulation in tests.

Inside ``shard_map`` the local block is ``(..., r, C)`` where the global
rows ``R = P*r`` are sharded over ``axis_name``; the transposed result is
``(..., c, R)`` with the global columns ``C = P*c`` now sharded.
"""

from __future__ import annotations

import inspect
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import axis_size as _axis_size

#: A registered backend name (see ``repro.core.backends.available()``).
#: Plain ``str`` on purpose: the registry, not a hand-kept enumeration,
#: defines the valid set.
Strategy = str

#: chunk_fn(chunk, src) -> processed chunk. ``chunk`` is the
#: (..., r, c) block received from shard ``src_index``, already transposed
#: to (..., c, r) when ``pre_transposed`` -- see _scatter below. A
#: chunk_fn may instead take (chunk, src, offset): under sub-chunked
#: pipelining it then receives each (..., c, r/q) piece as it arrives,
#: with ``offset`` the (static) starting index within the source block's
#: r rows -- position-dependent fusions (twiddles, DFT columns) stay
#: correct per sub-chunk. Two-argument chunk_fns are only ever handed
#: whole peer blocks (sub-chunking then pipelines the transport alone).
ChunkFn = Callable[..., jax.Array]


def _split_chunks(x: jax.Array, p: int) -> jax.Array:
    """(..., r, C) -> (p, ..., r, c): chunk j holds columns [j*c, (j+1)*c)."""
    *lead, r, C = x.shape
    c = C // p
    x = x.reshape(*lead, r, p, c)
    return jnp.moveaxis(x, -2, 0)


def _merge_rows(chunks: jax.Array) -> jax.Array:
    """(p, ..., r, c) -> (..., p*r, c): stack chunk j as rows [j*r, (j+1)*r)."""
    p = chunks.shape[0]
    chunks = jnp.moveaxis(chunks, 0, -3)  # (..., p, r, c)
    *lead, _, r, c = chunks.shape
    return chunks.reshape(*lead, p * r, c)


def _transpose_local(x: jax.Array) -> jax.Array:
    return jnp.swapaxes(x, -1, -2)


# ---------------------------------------------------------------------------
# Pipelining helpers
# ---------------------------------------------------------------------------


def subchunks_per_peer(r: int, p: int, n_chunks: Optional[int]) -> int:
    """Sub-chunks q per peer block for an ``n_chunks`` total-chunk target:
    the largest divisor of ``r`` (the peer block's row count) not above
    ceil(n_chunks / p). ``None`` or ``n_chunks <= p`` keeps the classic
    one-chunk-per-peer schedule. Shared by the exchanges and the cost
    model (:func:`repro.core.comm_model.effective_chunks`) so the modeled
    message count is the executed one."""
    if not n_chunks or n_chunks <= p:
        return 1
    q = min(max(1, -(-int(n_chunks) // p)), r)
    while r % q:
        q -= 1
    return q


def _chunk_fn_arity(fn: ChunkFn) -> int:
    """2 when ``fn`` takes (chunk, src), 3 when it also takes the
    sub-chunk row offset (see :data:`ChunkFn`)."""
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):  # builtins / exotic callables
        return 2
    n = 0
    for prm in sig.parameters.values():
        if prm.kind == inspect.Parameter.VAR_POSITIONAL:
            return 3
        if prm.kind in (
            inspect.Parameter.POSITIONAL_ONLY,
            inspect.Parameter.POSITIONAL_OR_KEYWORD,
        ):
            n += 1
    return 3 if n >= 3 else 2


def _call_chunk_fn(fn: ChunkFn, arity: int, chunk, src, offset: int):
    if arity >= 3:
        return fn(chunk, src, offset)
    return fn(chunk, src)


# ---------------------------------------------------------------------------
# Strategy: fused all-to-all (the paper's synchronized collective)
# ---------------------------------------------------------------------------


def _alltoall(x: jax.Array, axis_name: str) -> jax.Array:
    # (..., r, C) --split cols/concat rows--> (..., R, c) --local T--> (..., c, R)
    y = lax.all_to_all(x, axis_name, split_axis=x.ndim - 1, concat_axis=x.ndim - 2, tiled=True)
    return _transpose_local(y)


# ---------------------------------------------------------------------------
# Strategy: N-scatter ring (the paper's proposed decomposition)
# ---------------------------------------------------------------------------


def _chunked_exchange(
    x: jax.Array,
    axis_name: str,
    chunk_fn: Optional[ChunkFn],
    schedule,
    n_chunks: Optional[int] = None,
) -> jax.Array:
    """Shared chunk-streaming exchange: P-1 peer rounds, each shipped as
    ``q`` sub-chunk messages (``q`` from :func:`subchunks_per_peer`).

    ``schedule(me, s, p)`` defines round s: the static ppermute ``perm``,
    the chunk slot this rank ships, and the source rank of the chunk it
    receives. Each received piece is transposed (and optionally further
    processed by ``chunk_fn``) immediately -- 'the arriving data chunks
    can be transposed as soon as they are received' (paper, §3).

    Dataflow note (the double buffer): every send uses a *pre-existing*
    slice of the input, so no ppermute depends on any chunk_fn result.
    XLA is free to issue the next message while the previous piece's
    transpose/compute runs; on TPU the sends lower to async
    collective-permute-start/done pairs, giving the overlapped pipeline
    without explicit buffer management.
    """
    p = _axis_size(axis_name)
    me = lax.axis_index(axis_name)
    chunks = _split_chunks(x, p)  # (p, ..., r, c)
    r, c = x.shape[-2], x.shape[-1] // p
    q = subchunks_per_peer(r, p, n_chunks)
    rq = r // q
    arity = _chunk_fn_arity(chunk_fn) if chunk_fn is not None else 3
    per_sub = chunk_fn is None or arity >= 3

    def sub(block: jax.Array, t: int) -> jax.Array:
        return lax.slice_in_dim(block, t * rq, (t + 1) * rq, axis=-2)

    def process(piece: jax.Array, src: jax.Array, offset: int) -> jax.Array:
        out = _transpose_local(piece)  # (..., c, rows)
        if chunk_fn is not None:
            out = _call_chunk_fn(chunk_fn, arity, out, src, offset)
        return out

    # parts: (src, col_offset, processed (..., c, rows)) in arrival order.
    parts = []

    def rounds(block: jax.Array, src, perm=None):
        if per_sub:
            for t in range(q):
                piece = sub(block, t)
                if perm is not None:
                    piece = lax.ppermute(piece, axis_name, perm)
                parts.append((src, t * rq, process(piece, src, t * rq)))
        else:
            # 2-arg chunk_fn: stream the transport, process the whole
            # reassembled peer block (position-blind fusions only)
            pieces = []
            for t in range(q):
                piece = sub(block, t)
                if perm is not None:
                    piece = lax.ppermute(piece, axis_name, perm)
                pieces.append(_transpose_local(piece))
            whole = pieces[0] if q == 1 else jnp.concatenate(pieces, axis=-1)
            parts.append((src, 0, chunk_fn(whole, src)))

    # Own chunk (round 0) -- compute immediately, no communication.
    rounds(jnp.take(chunks, me, axis=0), me)
    for s in range(1, p):
        perm, send_slot, src = schedule(me, s, p)
        rounds(jnp.take(chunks, send_slot, axis=0), src, perm)

    # Assemble (..., c, R): the piece from src j at row offset o supplies
    # columns [j*r + o, j*r + o + rows).
    out_shape = x.shape[:-2] + (c, p * r)
    out = jnp.zeros(out_shape, parts[0][2].dtype)
    for src, off, part in parts:
        out = lax.dynamic_update_slice_in_dim(out, part, src * r + off, axis=out.ndim - 1)
    return out


def _chunked_reduce(
    x: jax.Array,
    axis_name: str,
    chunk_fn: ChunkFn,
    schedule,
    n_chunks: Optional[int] = None,
) -> jax.Array:
    """Streaming exchange-and-accumulate: like :func:`_chunked_exchange`
    but the per-source results are *summed*, not concatenated -- the
    shape the fused DFT stage needs (each arriving chunk contributes to
    every output frequency of the cross-rank dimension).

    ``chunk_fn(chunk, src, offset)`` receives the RAW (untransposed)
    received piece (..., rows, c) -- rows ``[offset, offset + rows)`` of
    source ``src``'s block -- and returns an array whose LAST axis is
    that source-row axis. Results sum over sources at equal offsets and
    concatenate along the last axis across offsets. Sub-chunking via
    ``n_chunks`` splits each peer block so compute streams into flight
    time even at small P.
    """
    p = _axis_size(axis_name)
    me = lax.axis_index(axis_name)
    chunks = _split_chunks(x, p)  # (p, ..., r, c)
    r = x.shape[-2]
    q = subchunks_per_peer(r, p, n_chunks)
    rq = r // q

    def sub(block: jax.Array, t: int) -> jax.Array:
        return lax.slice_in_dim(block, t * rq, (t + 1) * rq, axis=-2)

    own = jnp.take(chunks, me, axis=0)
    parts = [chunk_fn(sub(own, t), me, t * rq) for t in range(q)]
    for s in range(1, p):
        perm, send_slot, src = schedule(me, s, p)
        send = jnp.take(chunks, send_slot, axis=0)
        for t in range(q):
            recv = lax.ppermute(sub(send, t), axis_name, perm)
            parts[t] = parts[t] + chunk_fn(recv, src, t * rq)
    return parts[0] if q == 1 else jnp.concatenate(parts, axis=-1)


def _ring_schedule(me, s, p):
    # round s: ship the chunk destined to me+s; receive from me-s
    return [(i, (i + s) % p) for i in range(p)], (me + s) % p, (me - s) % p


def _swap_schedule(me, s, p):
    # round s: both ship to and receive from the same partner me^s
    return [(i, i ^ s) for i in range(p)], me ^ s, me ^ s


def _scatter(
    x: jax.Array,
    axis_name: str,
    chunk_fn: Optional[ChunkFn] = None,
    n_chunks: Optional[int] = None,
) -> jax.Array:
    """P-1 direct sends, a one-directional ring walk over distances
    1..P-1 -- the paper's N-scatter decomposition."""
    return _chunked_exchange(x, axis_name, chunk_fn, _ring_schedule, n_chunks)


# ---------------------------------------------------------------------------
# Strategy: Bruck / bisection exchange (beyond-paper)
# ---------------------------------------------------------------------------


def _bisection(x: jax.Array, axis_name: str) -> jax.Array:
    """Bruck all-to-all: ceil(log2 P) rounds, each shipping the slots whose
    round-bit is set. Message count log P (vs P-1), bytes P/2 slots per
    round (vs 1 slot per step) -- the latency/bandwidth trade the paper
    probes with its chunk-size benchmark.

    Slot invariant: after the initial rotation, slot j at rank i holds the
    chunk destined to (i + j) mod P; slot j travels a total distance j by
    moving +2^t on each set bit t; the final flip+rotation orders the
    received chunks by source rank.
    """
    p = _axis_size(axis_name)
    me = lax.axis_index(axis_name)
    chunks = _split_chunks(x, p)  # (p, ..., r, c), slot d = chunk destined to d
    r = x.shape[-2]

    # Phase 1: rotate so slot j holds destination (me + j) mod p.
    buf = jnp.roll(chunks, -me, axis=0)

    # Phase 2: log rounds of exchange with rank (me + 2^t). The travelling
    # slot set {j : bit t of j set} is static and identical on every rank,
    # so we ship exactly those slots (half the buffer), not a masked copy.
    t = 0
    while (1 << t) < p:
        step = 1 << t
        idx = tuple(j for j in range(p) if (j >> t) & 1)
        perm = [(i, (i + step) % p) for i in range(p)]
        recv = lax.ppermute(buf[idx, ...], axis_name, perm)
        buf = buf.at[idx, ...].set(recv)
        t += 1

    # Phase 3: slot j now holds the chunk from source (me - j) mod p.
    by_src = jnp.flip(jnp.roll(buf, -(me + 1), axis=0), axis=0)  # slot s = from rank s
    stacked = _merge_rows(by_src)  # (..., R, c)
    return _transpose_local(stacked)  # (..., c, R)


# ---------------------------------------------------------------------------
# Strategy: pairwise XOR exchange (beyond-paper)
# ---------------------------------------------------------------------------


def _pairwise_xor(
    x: jax.Array,
    axis_name: str,
    chunk_fn: Optional[ChunkFn] = None,
    n_chunks: Optional[int] = None,
) -> jax.Array:
    """Pairwise exchange: round s swaps one chunk with partner (me XOR s).

    XOR with a fixed s is an involution, so every round is a symmetric
    bidirectional swap (both halves of each link busy), unlike the ring's
    one-directional walk. Requires power-of-two P (XOR must stay a
    permutation of the ranks). Chunks arrive incrementally, so per-chunk
    ``chunk_fn`` processing overlaps the next round exactly as in
    ``scatter``.
    """
    return _chunked_exchange(x, axis_name, chunk_fn, _swap_schedule, n_chunks)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def distributed_transpose(
    x: jax.Array,
    axis_name: str,
    *,
    strategy: str = "alltoall",
    chunk_fn: Optional[ChunkFn] = None,
    n_chunks: Optional[int] = None,
) -> jax.Array:
    """Transpose a (..., R, C) array whose R axis is sharded over
    ``axis_name`` into a (..., C, R) array with C sharded. Must be called
    inside ``shard_map``; local in (..., r, C), local out (..., c, R).

    ``strategy`` names a registered :mod:`repro.core.backends` backend;
    ``chunk_fn`` is only honoured by chunk-streaming backends
    (``backend.supports_chunk_fn`` -- the monolithic collectives have
    nothing to interleave, exactly the paper's point). ``n_chunks``
    (streaming backends, a performance hint elsewhere ignored) decouples
    the message count from P: each peer block is shipped as
    ~``n_chunks/P`` sub-messages so per-chunk compute pipelines into
    flight time even on short rings.
    """
    from repro.core import backends  # late import: backends registers over us

    backend = backends.get(strategy)
    if backend.kind != "shard_map":
        raise ValueError(
            f"backend {strategy!r} is a whole-transform backend with no "
            f"shard_map transpose; use it through fft2/fft3/plan_fft"
        )
    p = _axis_size(axis_name)
    if x.shape[-1] % p:
        raise ValueError(
            f"column count {x.shape[-1]} not divisible by the {p} shards of "
            f"mesh axis {axis_name!r} (plan-level shapes are validated by "
            f"plan_fft; direct callers must pre-chunk)"
        )
    if chunk_fn is not None and not backend.supports_chunk_fn:
        raise ValueError(
            f"chunk_fn requires a chunk-streaming backend "
            f"(got {strategy!r}; streaming: "
            f"{[b for b in backends.available() if backends.get(b).supports_chunk_fn]})"
        )
    if p == 1:
        y = _transpose_local(x)
        if chunk_fn is not None:
            y = _call_chunk_fn(chunk_fn, _chunk_fn_arity(chunk_fn), y, jnp.asarray(0), 0)
        return y
    if not backend.supports(p):
        raise ValueError(f"backend {strategy!r} does not support P={p}")
    return backend.transpose(x, axis_name, chunk_fn, n_chunks=n_chunks)


def transpose_then_fft(
    x: jax.Array,
    axis_name: str,
    *,
    strategy: str,
    impl: str = "jnp",
    fused: bool = False,
    n_chunks: Optional[int] = None,
    inverse: bool = False,
) -> jax.Array:
    """The pipelined overlap executor's unit step: transpose
    (..., r, C) -> (..., c, R) and FFT the result along its last (R)
    axis -- with the cross-rank stage of that FFT folded into the
    arriving chunks when ``fused`` and the backend streams.

    Decimation in time over source ranks (global row j = src*r + j2,
    output frequency k = k1 + P*k2):

        F[k1 + P*k2] = DFT_r over j2 [ T[k1, j2] * sum_src W_P[k1, src] * chunk_src[j2] ]

    The inner sum streams through :func:`_chunked_reduce`: each arriving
    chunk's contribution is a rank-1 outer product with one W_P column
    (times the elementwise twiddle) -- cheap VPU work hidden behind the
    remaining sends. After the exchange only a *local* length-r FFT and
    the k-order relayout remain. The same identity conjugated gives the
    inverse transform (tables conjugate; the trailing local FFT carries
    1/r and the stage adds the remaining 1/P).

    Unfused (or monolithic-backend, or P=1) calls lower to the plain
    transpose followed by a whole-axis local FFT -- numerically the same
    transform, nothing overlapped.
    """
    import repro.core.fftmath as lf
    from repro.core import backends  # late import: backends registers over us

    backend = backends.get(strategy)
    p = _axis_size(axis_name)
    if not (fused and backend.supports_chunk_fn and p > 1):
        y = distributed_transpose(x, axis_name, strategy=strategy, n_chunks=n_chunks)
        return lf.local_fft(y, axis=-1, inverse=inverse, impl=impl)
    # same guards the plain transpose enforces -- the fused path must not
    # trade its friendly errors for a reshape blow-up in _split_chunks
    if x.shape[-1] % p:
        raise ValueError(
            f"column count {x.shape[-1]} not divisible by the {p} shards of "
            f"mesh axis {axis_name!r} (plan-level shapes are validated by "
            f"plan_fft; direct callers must pre-chunk)"
        )
    if not backend.supports(p):
        raise ValueError(f"backend {strategy!r} does not support P={p}")

    r = x.shape[-2]
    cdtype = jnp.result_type(x.dtype, jnp.complex64)
    w_p = jnp.asarray(lf.dft_matrix(p, cdtype))  # (k1, src)
    tw = jnp.asarray(lf.twiddle(p, r, cdtype))  # (k1, j2)
    if inverse:
        w_p, tw = jnp.conj(w_p), jnp.conj(tw)

    use_pallas = impl == "pallas" and jnp.dtype(cdtype) == jnp.complex64

    def chunk_fn(chunk: jax.Array, src: jax.Array, offset: int) -> jax.Array:
        # chunk (..., rows, c) = rows [offset, offset+rows) of src's block.
        rows = chunk.shape[-2]
        col = lax.dynamic_slice_in_dim(w_p, src, 1, axis=1)[:, 0]  # (k1=p,)
        tws = lax.slice_in_dim(tw, offset, offset + rows, axis=1)  # (p, rows)
        m = col[:, None] * tws  # (k1, j2) for this piece
        if use_pallas:
            from repro.kernels import fft_stage

            return fft_stage.chunk_twiddle_pack_c64(chunk, m)
        ct = _transpose_local(chunk)  # (..., c, rows)
        return ct[..., None, :] * m  # (..., c, k1=p, j2=rows)

    acc = backend.stream_reduce(x.astype(cdtype), axis_name, chunk_fn, n_chunks=n_chunks)
    acc = lf.local_fft(acc, axis=-1, inverse=inverse, impl=impl)  # j2 -> k2 (1/r if inverse)
    # F index k = k1 + P*k2 -> order (k2 major, k1 minor).
    out = _transpose_local(acc)  # (..., c, k2=r, k1=p)
    out = out.reshape(out.shape[:-2] + (p * r,))
    if inverse:
        out = out / p  # completes the 1/(p*r) = 1/R factor
    return out
