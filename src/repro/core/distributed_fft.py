"""Slab-decomposed distributed FFT entry points over the stage-schedule
IR (the paper's application, §2).

Global data model for ``fft2``: x has shape (..., R, C) with R sharded
over ``axis_name`` (P shards); leading axes are batch. The paper's four
steps per dimension map to:

    1. local FFT along the contiguous axis (C)
    2/3. chunk + communicate: ``distributed_transpose`` (strategy-switchable)
    4. chunk re-transpose -- folded into the strategy (the ``scatter``
       strategy transposes each chunk as it arrives; the fused collectives
       transpose after assembly)

then the second dimension's local FFT. Output is the transposed spectrum
F^T (C sharded) by default -- standard for pencil FFT libraries -- or the
natural layout with ``transpose_back=True`` (one more exchange).

``fused=True`` (beyond-paper, any chunk-streaming strategy) goes further
than the paper's "transpose chunks on arrival": it folds the *next
dimension's DFT itself* into the exchange via decimation across source
ranks (R = P*r, DFT_R = DFT_P across ranks x twiddle x DFT_r within
chunks). Each arriving (sub-)chunk contributes W_P[:, src] (x) chunk to
the accumulator, so the post-communication serial FFT_R disappears into
the flight time -- the pipelined overlap executor
(:func:`repro.core.transpose.transpose_then_fft`), shared by the 3-D
slab chain, both pencil legs and the r2c subsystem. ``fuse_dft`` is the
legacy fft2-only spelling and is honoured as an alias; ``n_chunks``
decouples the streamed chunk count from P (see ``plan_fft(pipeline=)``).

Every transform here is a thin builder over
:mod:`repro.core.schedule`: the entry point lowers its arguments to a
declarative stage schedule and hands it to the one interpreter
(:func:`repro.core.schedule.run_schedule`), which is also what the cost
model and the byte accounting walk.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

import repro.core.fftmath as lf
import repro.core.schedule as sch
from repro.core import backends


@dataclasses.dataclass(frozen=True)
class FFTConfig:
    """Transform config carrier. New code should use ``plan_fft`` (see
    :mod:`repro.core.plan`, which resolves ``pipeline=`` into the
    ``fused``/``n_chunks`` fields here); kept as a thin carrier so
    existing call sites keep working. ``strategy`` names any backend
    registered in :mod:`repro.core.backends`.

    ``fused`` folds each exchange's following FFT stage into the arriving
    chunks on streaming backends (the pipelined overlap executor);
    ``n_chunks`` decouples the streamed chunk count from P (sub-chunked
    transport + finer compute grain). ``fuse_dft`` is the legacy
    fft2-only spelling of ``fused`` and is honoured as an alias."""

    strategy: str = "alltoall"
    local_impl: lf.LocalImpl = "jnp"
    fuse_dft: bool = False  # legacy alias: fold 2nd-dim DFT into the ring
    transpose_back: bool = False  # return natural (row-sharded) layout
    fused: bool = False  # streaming backends: fuse the next FFT stage
    n_chunks: Optional[int] = None  # total-chunk target (None = P)


def _wants_fused(cfg: FFTConfig) -> bool:
    return cfg.fused or cfg.fuse_dft


def _check(cfg: FFTConfig) -> backends.CollectiveBackend:
    backend = backends.get(cfg.strategy)  # raises listing the registry
    if _wants_fused(cfg) and not (backend.kind == "shard_map" and backend.supports_chunk_fn):
        raise ValueError(
            f"fuse_dft/fused requires a chunk-streaming backend "
            f"(got {cfg.strategy!r}; streaming: "
            f"{[b for b in backends.available() if backends.get(b).supports_chunk_fn]})"
        )
    return backend


def _build(x: jax.Array, mesh: Mesh, axis_name: str, cfg: FFTConfig, *,
           ndim: int, inverse: bool, rows: Optional[int] = None) -> sch.Schedule:
    return sch.build_schedule(
        x.shape, ndim=ndim, inverse=inverse, decomp="slab",
        axis_name=axis_name, p=mesh.shape[axis_name], backend=cfg.strategy,
        fused=_wants_fused(cfg), n_chunks=cfg.n_chunks,
        transpose_back=cfg.transpose_back, rows=rows,
    )


def fft2(
    x: jax.Array,
    mesh: Mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    *,
    inverse: bool = False,
) -> jax.Array:
    """Distributed 2-D FFT of (..., R, C), R sharded over ``axis_name``.

    Returns F^T (= fft2(x).swapaxes(-1,-2)) with C sharded, unless
    ``cfg.transpose_back`` -- mirroring the paper's pencil layout. With
    ``inverse``, computes the unitary-unnormalized ifft2 (1/(R*C) factor),
    same layout conventions.
    """
    _check(cfg)
    plan = _build(x, mesh, axis_name, cfg, ndim=2, inverse=inverse)
    return sch.run_schedule(x, plan, mesh, impl=cfg.local_impl)


def ifft2(x: jax.Array, mesh: Mesh, axis_name: str, cfg: FFTConfig = FFTConfig()) -> jax.Array:
    return fft2(x, mesh, axis_name, cfg, inverse=True)


def fft3(
    x: jax.Array,
    mesh: Mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    *,
    inverse: bool = False,
) -> jax.Array:
    """Slab-decomposed 3-D FFT of (..., D0, D1, D2), D0 sharded.

    Local batched 2-D FFT over (D1, D2), then one strategy-switched
    exchange to localize D0, FFT, and the exchange back (natural layout is
    always restored: 3-D users expect it)."""
    _check(cfg)
    plan = _build(x, mesh, axis_name, cfg, ndim=3, inverse=inverse)
    return sch.run_schedule(x, plan, mesh, impl=cfg.local_impl)


def fft1d_large(
    x: jax.Array,
    mesh: Mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    *,
    rows: Optional[int] = None,
) -> jax.Array:
    """Distributed 1-D FFT of a signal too large for one device.

    x: (..., N) viewed as (R, C) row-major with R = rows (default: R = P *
    ceil-balanced) sharded. Six-step algorithm: transpose, FFT_R, twiddle
    (fused into the second exchange's chunks under ``scatter``), transpose,
    FFT_C, transpose. Returns the standard-ordered spectrum, R-sharded.
    """
    _check(cfg)
    plan = _build(x, mesh, axis_name, cfg, ndim=1, inverse=False, rows=rows)
    return sch.run_schedule(x, plan, mesh, impl=cfg.local_impl)


def reference_fft2(x: jax.Array, *, inverse: bool = False) -> jax.Array:
    """Single-device oracle (numpy semantics) for tests/benchmarks."""
    return jnp.fft.ifft2(x) if inverse else jnp.fft.fft2(x)
