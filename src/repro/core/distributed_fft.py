"""Pencil-decomposed distributed FFT composed from local FFTs + the
collective-strategy transpose (the paper's application, §2).

Global data model for ``fft2``: x has shape (..., R, C) with R sharded
over ``axis_name`` (P shards); leading axes are batch. The paper's four
steps per dimension map to:

    1. local FFT along the contiguous axis (C)
    2/3. chunk + communicate: ``distributed_transpose`` (strategy-switchable)
    4. chunk re-transpose -- folded into the strategy (the ``scatter``
       strategy transposes each chunk as it arrives; the fused collectives
       transpose after assembly)

then the second dimension's local FFT. Output is the transposed spectrum
F^T (C sharded) by default -- standard for pencil FFT libraries -- or the
natural layout with ``transpose_back=True`` (one more exchange).

``fused=True`` (beyond-paper, any chunk-streaming strategy) goes further
than the paper's "transpose chunks on arrival": it folds the *next
dimension's DFT itself* into the exchange via decimation across source
ranks (R = P*r, DFT_R = DFT_P across ranks x twiddle x DFT_r within
chunks). Each arriving (sub-)chunk contributes W_P[:, src] (x) chunk to
the accumulator, so the post-communication serial FFT_R disappears into
the flight time -- the pipelined overlap executor
(:func:`repro.core.transpose.transpose_then_fft`), shared by the 3-D
slab chain, both pencil legs and the r2c subsystem. ``fuse_dft`` is the
legacy fft2-only spelling and is honoured as an alias; ``n_chunks``
decouples the streamed chunk count from P (see ``plan_fft(pipeline=)``).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.core.fftmath as lf
import repro.core.transpose as tr
from repro.core import backends
from repro.core.compat import shard_map


# ---------------------------------------------------------------------------
# shard_map-local building blocks
# ---------------------------------------------------------------------------


def _fft_local_then_transpose(
    x: jax.Array,
    axis_name: str,
    *,
    strategy: tr.Strategy,
    impl: lf.LocalImpl,
    n_chunks: Optional[int] = None,
) -> jax.Array:
    """Steps 1-4 for one dimension: local FFT along the contiguous axis,
    then the strategy-switched pencil exchange."""
    y = lf.local_fft(x, axis=-1, impl=impl)
    return tr.distributed_transpose(y, axis_name, strategy=strategy, n_chunks=n_chunks)


def _fft2_fused_scatter(
    x: jax.Array,
    axis_name: str,
    *,
    impl: lf.LocalImpl,
    strategy: tr.Strategy = "scatter",
    n_chunks: Optional[int] = None,
) -> jax.Array:
    """fft2 second dimension folded into the exchange (fused execution).

    After the row FFT, the column DFT of length R = P*r decomposes across
    source ranks (decimation in time with n1 = P, n2 = r):

        F[k1 + P*k2] = DFT_r over j2 [ T[k1, j2] * sum_src W_P[k1, src] * chunk_src[j2] ]

    The inner sum streams through the backend's own chunk schedule with a
    cheap rank-1 outer product per arriving (sub-)chunk -- fully
    overlapped with the in-flight sends. The shared implementation is
    :func:`repro.core.transpose.transpose_then_fft`, which the 3-D slab,
    pencil and r2c chains ride too.
    """
    y = lf.local_fft(x, axis=-1, impl=impl)
    return tr.transpose_then_fft(
        y, axis_name, strategy=strategy, impl=impl, fused=True, n_chunks=n_chunks
    )


# ---------------------------------------------------------------------------
# Public distributed transforms
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FFTConfig:
    """Transform config carrier. New code should use ``plan_fft`` (see
    :mod:`repro.core.plan`, which resolves ``pipeline=`` into the
    ``fused``/``n_chunks`` fields here); kept as a thin carrier so
    existing call sites keep working. ``strategy`` names any backend
    registered in :mod:`repro.core.backends`.

    ``fused`` folds each exchange's following FFT stage into the arriving
    chunks on streaming backends (the pipelined overlap executor);
    ``n_chunks`` decouples the streamed chunk count from P (sub-chunked
    transport + finer compute grain). ``fuse_dft`` is the legacy
    fft2-only spelling of ``fused`` and is honoured as an alias."""

    strategy: str = "alltoall"
    local_impl: lf.LocalImpl = "jnp"
    fuse_dft: bool = False  # legacy alias: fold 2nd-dim DFT into the ring
    transpose_back: bool = False  # return natural (row-sharded) layout
    fused: bool = False  # streaming backends: fuse the next FFT stage
    n_chunks: Optional[int] = None  # total-chunk target (None = P)


def _wants_fused(cfg: FFTConfig) -> bool:
    return cfg.fused or cfg.fuse_dft


def _check(cfg: FFTConfig) -> backends.CollectiveBackend:
    backend = backends.get(cfg.strategy)  # raises listing the registry
    if _wants_fused(cfg) and not (backend.kind == "shard_map" and backend.supports_chunk_fn):
        raise ValueError(
            f"fuse_dft/fused requires a chunk-streaming backend "
            f"(got {cfg.strategy!r}; streaming: "
            f"{[b for b in backends.available() if backends.get(b).supports_chunk_fn]})"
        )
    return backend


def fft2(
    x: jax.Array,
    mesh: Mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    *,
    inverse: bool = False,
) -> jax.Array:
    """Distributed 2-D FFT of (..., R, C), R sharded over ``axis_name``.

    Returns F^T (= fft2(x).swapaxes(-1,-2)) with C sharded, unless
    ``cfg.transpose_back`` -- mirroring the paper's pencil layout. With
    ``inverse``, computes the unitary-unnormalized ifft2 (1/(R*C) factor),
    same layout conventions.
    """
    backend = _check(cfg)
    if backend.kind == "global":
        return _fft2_xla_auto(x, mesh, axis_name, inverse=inverse, transpose_back=cfg.transpose_back)

    def fn(xl: jax.Array) -> jax.Array:
        v = jnp.conj(xl) if inverse else xl
        if _wants_fused(cfg):
            out = _fft2_fused_scatter(
                v, axis_name, impl=cfg.local_impl, strategy=cfg.strategy,
                n_chunks=cfg.n_chunks,
            )
        else:
            out = _fft_local_then_transpose(
                v, axis_name, strategy=cfg.strategy, impl=cfg.local_impl,
                n_chunks=cfg.n_chunks,
            )
            out = lf.local_fft(out, axis=-1, impl=cfg.local_impl)
        if cfg.transpose_back:
            out = tr.distributed_transpose(
                out, axis_name, strategy=cfg.strategy, n_chunks=cfg.n_chunks
            )
        if inverse:
            out = jnp.conj(out) / (x.shape[-1] * x.shape[-2])
        return out

    ndim = x.ndim
    spec = P(*([None] * (ndim - 2) + [axis_name, None]))
    return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)(x)


def ifft2(x: jax.Array, mesh: Mesh, axis_name: str, cfg: FFTConfig = FFTConfig()) -> jax.Array:
    return fft2(x, mesh, axis_name, cfg, inverse=True)


def _fft2_xla_auto(
    x: jax.Array, mesh: Mesh, axis_name: str, *, inverse: bool, transpose_back: bool
) -> jax.Array:
    """The 'FFTW3 reference' analogue: hand the sharded array to XLA's own
    FFT op under jit and let GSPMD choose the communication schedule."""
    ndim = x.ndim
    spec = P(*([None] * (ndim - 2) + [axis_name, None]))
    sh = NamedSharding(mesh, spec)

    def fn(v: jax.Array) -> jax.Array:
        out = jnp.fft.ifft2(v) if inverse else jnp.fft.fft2(v)
        if not transpose_back:
            out = jnp.swapaxes(out, -1, -2)
        return out

    return jax.jit(fn, in_shardings=sh, out_shardings=sh)(x)


def fft3(
    x: jax.Array,
    mesh: Mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    *,
    inverse: bool = False,
) -> jax.Array:
    """Slab-decomposed 3-D FFT of (..., D0, D1, D2), D0 sharded.

    Local batched 2-D FFT over (D1, D2), then one strategy-switched
    exchange to localize D0, FFT, and the exchange back (natural layout is
    always restored: 3-D users expect it)."""
    backend = _check(cfg)
    if backend.kind == "global":
        ndim = x.ndim
        spec = P(*([None] * (ndim - 3) + [axis_name, None, None]))
        sh = NamedSharding(mesh, spec)
        f = jnp.fft.ifftn if inverse else jnp.fft.fftn
        return jax.jit(lambda v: f(v, axes=(-3, -2, -1)), in_shardings=sh, out_shardings=sh)(x)

    d0, d1, d2 = x.shape[-3:]

    def fn(xl: jax.Array) -> jax.Array:
        v = jnp.conj(xl) if inverse else xl
        v = lf.local_fft2(v, impl=cfg.local_impl)  # over (D1, D2), both local
        flat = v.reshape(v.shape[:-2] + (d1 * d2,))  # (..., d0_local, D1*D2)
        # D0 pass: exchange + FFT, fused into the arriving chunks on
        # streaming backends (the pipelined overlap executor)
        t = tr.transpose_then_fft(
            flat, axis_name, strategy=cfg.strategy, impl=cfg.local_impl,
            fused=_wants_fused(cfg), n_chunks=cfg.n_chunks,
        )
        back = tr.distributed_transpose(
            t, axis_name, strategy=cfg.strategy, n_chunks=cfg.n_chunks
        )
        out = back.reshape(v.shape)
        if inverse:
            out = jnp.conj(out) / (d0 * d1 * d2)
        return out

    ndim = x.ndim
    spec = P(*([None] * (ndim - 3) + [axis_name, None, None]))
    return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)(x)


def fft1d_large(
    x: jax.Array,
    mesh: Mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    *,
    rows: Optional[int] = None,
) -> jax.Array:
    """Distributed 1-D FFT of a signal too large for one device.

    x: (..., N) viewed as (R, C) row-major with R = rows (default: R = P *
    ceil-balanced) sharded. Six-step algorithm: transpose, FFT_R, twiddle
    (fused into the second exchange's chunks under ``scatter``), transpose,
    FFT_C, transpose. Returns the standard-ordered spectrum, R-sharded.
    """
    backend = _check(cfg)
    if backend.kind == "global":
        ndim = x.ndim
        sh = NamedSharding(mesh, P(*([None] * (ndim - 1) + [axis_name])))
        return jax.jit(jnp.fft.fft, in_shardings=sh, out_shardings=sh)(x)

    n = x.shape[-1]
    p = mesh.shape[axis_name]
    r = rows or p
    if n % r or (n // r) % p or r % p:
        raise ValueError(f"N={n} must factor as rows({r}) x cols with both divisible by P={p}")
    c = n // r

    def fn(xl: jax.Array) -> jax.Array:
        me = lax.axis_index(axis_name)
        # local rows block of A = x.reshape(R, C): (..., R/p, C)
        a = xl.reshape(xl.shape[:-1] + (r // p, c))
        # exchange 1: localize columns j2; FFT_R over j1 -> k1 -- fused
        # into the arriving chunks on streaming backends
        g = tr.transpose_then_fft(
            a, axis_name, strategy=cfg.strategy, impl=cfg.local_impl,
            fused=_wants_fused(cfg), n_chunks=cfg.n_chunks,
        )  # (..., C/p, R)

        # Twiddle w_n^(j2*k1). Under a chunk-streaming backend it is fused
        # into exchange 2's per-chunk compute (applied to each sub-chunk
        # as it arrives -- the paper's 'hide computation behind
        # communication'); otherwise applied up-front to the whole block.
        if backend.supports_chunk_fn:

            def tw_chunk(chunk: jax.Array, src: jax.Array, offset: int) -> jax.Array:
                # chunk (..., R/p, rows): my k1 block x src's j2 rows
                # [offset, offset+rows) of its C/p block.
                k1 = me * (r // p) + jnp.arange(r // p)
                j2 = src * (c // p) + offset + jnp.arange(chunk.shape[-1])
                tw = jnp.exp(-2j * jnp.pi * (k1[:, None] * j2[None, :]) / n)
                return chunk * tw.astype(chunk.dtype)

            t2 = tr.distributed_transpose(
                g, axis_name, strategy=cfg.strategy, chunk_fn=tw_chunk,
                n_chunks=cfg.n_chunks,
            )
        else:
            j2 = me * (c // p) + jnp.arange(c // p)
            k1 = jnp.arange(r)
            tw = jnp.exp(-2j * jnp.pi * (j2[:, None] * k1[None, :]) / n).astype(g.dtype)
            t2 = tr.distributed_transpose(g * tw, axis_name, strategy=cfg.strategy)
        f = lf.local_fft(t2, axis=-1, impl=cfg.local_impl)  # (..., R/p, C): F[k1, k2]
        # X[k2*R + k1] = F[k1, k2]  =>  natural order is F^T flattened; one
        # final exchange re-shards k2 and emits X contiguously.
        t3 = tr.distributed_transpose(
            f, axis_name, strategy=cfg.strategy, n_chunks=cfg.n_chunks
        )
        return t3.reshape(xl.shape[:-1] + (c // p * r,))

    ndim = x.ndim
    spec = P(*([None] * (ndim - 1) + [axis_name]))
    return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)(x)


def reference_fft2(x: jax.Array, *, inverse: bool = False) -> jax.Array:
    """Single-device oracle (numpy semantics) for tests/benchmarks."""
    return jnp.fft.ifft2(x) if inverse else jnp.fft.fft2(x)
