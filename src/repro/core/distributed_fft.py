"""Pencil-decomposed distributed FFT composed from local FFTs + the
collective-strategy transpose (the paper's application, §2).

Global data model for ``fft2``: x has shape (..., R, C) with R sharded
over ``axis_name`` (P shards); leading axes are batch. The paper's four
steps per dimension map to:

    1. local FFT along the contiguous axis (C)
    2/3. chunk + communicate: ``distributed_transpose`` (strategy-switchable)
    4. chunk re-transpose -- folded into the strategy (the ``scatter``
       strategy transposes each chunk as it arrives; the fused collectives
       transpose after assembly)

then the second dimension's local FFT. Output is the transposed spectrum
F^T (C sharded) by default -- standard for pencil FFT libraries -- or the
natural layout with ``transpose_back=True`` (one more exchange).

``fuse_dft=True`` (beyond-paper, scatter strategy only) goes further than
the paper's "transpose chunks on arrival": it folds the *second
dimension's DFT itself* into the ring via decimation across source ranks
(R = P*r, DFT_R = DFT_P across ranks x twiddle x DFT_r within chunks).
Each arriving chunk contributes W_P[:, src] (x) chunk to the accumulator,
so the post-communication serial FFT_R disappears into the ring. See
EXPERIMENTS.md §Perf for the roofline accounting.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.core.fftmath as lf
import repro.core.transpose as tr
from repro.core import backends
from repro.core.compat import axis_size, shard_map
from repro.core.overlap import ring_scatter_reduce


# ---------------------------------------------------------------------------
# shard_map-local building blocks
# ---------------------------------------------------------------------------


def _fft_local_then_transpose(
    x: jax.Array,
    axis_name: str,
    *,
    strategy: tr.Strategy,
    impl: lf.LocalImpl,
) -> jax.Array:
    """Steps 1-4 for one dimension: local FFT along the contiguous axis,
    then the strategy-switched pencil exchange."""
    y = lf.local_fft(x, axis=-1, impl=impl)
    return tr.distributed_transpose(y, axis_name, strategy=strategy)


def _fft2_fused_scatter(x: jax.Array, axis_name: str, *, impl: lf.LocalImpl) -> jax.Array:
    """fft2 second dimension folded into the ring (fuse_dft=True).

    After the row FFT, the column DFT of length R = P*r decomposes across
    source ranks (decimation in time with n1 = P, n2 = r):

        F[k1 + P*k2] = DFT_r over j2 [ T[k1, j2] * sum_src W_P[k1, src] * chunk_src[j2] ]

    The inner sum is exactly a ring_scatter_reduce whose per-chunk compute
    is a cheap rank-1 outer product -- fully overlapped with the sends.
    """
    y = lf.local_fft(x, axis=-1, impl=impl)
    p = axis_size(axis_name)
    r = y.shape[-2]
    w_p = jnp.asarray(lf._dft_matrix_np(p))  # (k1, src)

    def chunk_fn(chunk: jax.Array, src: jax.Array) -> jax.Array:
        # chunk (..., r, c) = rows [src*r,...) x my column block; transpose
        # to (..., c, r) then expand across the k1 dimension.
        ct = jnp.swapaxes(chunk, -1, -2)  # (..., c, j2=r)
        col = lax.dynamic_slice_in_dim(w_p, src, 1, axis=1)[:, 0]  # (k1=p,)
        return ct[..., None, :] * col[:, None]  # (..., c, k1=p, j2=r)

    acc = ring_scatter_reduce(y, axis_name, chunk_fn, split_axis=-1)
    # Twiddle T[k1, j2] = w_n^(k1*j2), then DFT over j2 -> k2.
    tw = jnp.asarray(lf._twiddle_np(p, r))
    acc = acc * tw
    acc = lf.local_fft(acc, axis=-1, impl=impl)  # (..., c, k1=p, k2=r)
    # F index k = k1 + P*k2 -> order (k2 major, k1 minor).
    out = jnp.swapaxes(acc, -1, -2)  # (..., c, k2, k1)
    return out.reshape(out.shape[:-2] + (p * r,))


# ---------------------------------------------------------------------------
# Public distributed transforms
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FFTConfig:
    """Legacy transform config. New code should use ``plan_fft`` (see
    :mod:`repro.core.plan`); kept as a thin carrier for one release so
    existing call sites keep working. ``strategy`` names any backend
    registered in :mod:`repro.core.backends`."""

    strategy: str = "alltoall"
    local_impl: lf.LocalImpl = "jnp"
    fuse_dft: bool = False  # scatter-only: fold 2nd-dim DFT into the ring
    transpose_back: bool = False  # return natural (row-sharded) layout


def _check(cfg: FFTConfig) -> backends.CollectiveBackend:
    backend = backends.get(cfg.strategy)  # raises listing the registry
    if cfg.fuse_dft and cfg.strategy != "scatter":
        raise ValueError("fuse_dft requires strategy='scatter'")
    return backend


def fft2(
    x: jax.Array,
    mesh: Mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    *,
    inverse: bool = False,
) -> jax.Array:
    """Distributed 2-D FFT of (..., R, C), R sharded over ``axis_name``.

    Returns F^T (= fft2(x).swapaxes(-1,-2)) with C sharded, unless
    ``cfg.transpose_back`` -- mirroring the paper's pencil layout. With
    ``inverse``, computes the unitary-unnormalized ifft2 (1/(R*C) factor),
    same layout conventions.
    """
    backend = _check(cfg)
    if backend.kind == "global":
        return _fft2_xla_auto(x, mesh, axis_name, inverse=inverse, transpose_back=cfg.transpose_back)

    def fn(xl: jax.Array) -> jax.Array:
        v = jnp.conj(xl) if inverse else xl
        if cfg.fuse_dft:
            out = _fft2_fused_scatter(v, axis_name, impl=cfg.local_impl)
        else:
            out = _fft_local_then_transpose(v, axis_name, strategy=cfg.strategy, impl=cfg.local_impl)
            out = lf.local_fft(out, axis=-1, impl=cfg.local_impl)
        if cfg.transpose_back:
            out = tr.distributed_transpose(out, axis_name, strategy=cfg.strategy)
        if inverse:
            out = jnp.conj(out) / (x.shape[-1] * x.shape[-2])
        return out

    ndim = x.ndim
    spec = P(*([None] * (ndim - 2) + [axis_name, None]))
    return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)(x)


def ifft2(x: jax.Array, mesh: Mesh, axis_name: str, cfg: FFTConfig = FFTConfig()) -> jax.Array:
    return fft2(x, mesh, axis_name, cfg, inverse=True)


def _fft2_xla_auto(
    x: jax.Array, mesh: Mesh, axis_name: str, *, inverse: bool, transpose_back: bool
) -> jax.Array:
    """The 'FFTW3 reference' analogue: hand the sharded array to XLA's own
    FFT op under jit and let GSPMD choose the communication schedule."""
    ndim = x.ndim
    spec = P(*([None] * (ndim - 2) + [axis_name, None]))
    sh = NamedSharding(mesh, spec)

    def fn(v: jax.Array) -> jax.Array:
        out = jnp.fft.ifft2(v) if inverse else jnp.fft.fft2(v)
        if not transpose_back:
            out = jnp.swapaxes(out, -1, -2)
        return out

    return jax.jit(fn, in_shardings=sh, out_shardings=sh)(x)


def fft3(
    x: jax.Array,
    mesh: Mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    *,
    inverse: bool = False,
) -> jax.Array:
    """Slab-decomposed 3-D FFT of (..., D0, D1, D2), D0 sharded.

    Local batched 2-D FFT over (D1, D2), then one strategy-switched
    exchange to localize D0, FFT, and the exchange back (natural layout is
    always restored: 3-D users expect it)."""
    backend = _check(cfg)
    if backend.kind == "global":
        ndim = x.ndim
        spec = P(*([None] * (ndim - 3) + [axis_name, None, None]))
        sh = NamedSharding(mesh, spec)
        f = jnp.fft.ifftn if inverse else jnp.fft.fftn
        return jax.jit(lambda v: f(v, axes=(-3, -2, -1)), in_shardings=sh, out_shardings=sh)(x)

    d0, d1, d2 = x.shape[-3:]

    def fn(xl: jax.Array) -> jax.Array:
        v = jnp.conj(xl) if inverse else xl
        v = lf.local_fft2(v, impl=cfg.local_impl)  # over (D1, D2), both local
        flat = v.reshape(v.shape[:-2] + (d1 * d2,))  # (..., d0_local, D1*D2)
        t = tr.distributed_transpose(flat, axis_name, strategy=cfg.strategy)
        t = lf.local_fft(t, axis=-1, impl=cfg.local_impl)  # along D0
        back = tr.distributed_transpose(t, axis_name, strategy=cfg.strategy)
        out = back.reshape(v.shape)
        if inverse:
            out = jnp.conj(out) / (d0 * d1 * d2)
        return out

    ndim = x.ndim
    spec = P(*([None] * (ndim - 3) + [axis_name, None, None]))
    return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)(x)


def fft1d_large(
    x: jax.Array,
    mesh: Mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    *,
    rows: Optional[int] = None,
) -> jax.Array:
    """Distributed 1-D FFT of a signal too large for one device.

    x: (..., N) viewed as (R, C) row-major with R = rows (default: R = P *
    ceil-balanced) sharded. Six-step algorithm: transpose, FFT_R, twiddle
    (fused into the second exchange's chunks under ``scatter``), transpose,
    FFT_C, transpose. Returns the standard-ordered spectrum, R-sharded.
    """
    backend = _check(cfg)
    if backend.kind == "global":
        ndim = x.ndim
        sh = NamedSharding(mesh, P(*([None] * (ndim - 1) + [axis_name])))
        return jax.jit(jnp.fft.fft, in_shardings=sh, out_shardings=sh)(x)

    n = x.shape[-1]
    p = mesh.shape[axis_name]
    r = rows or p
    if n % r or (n // r) % p or r % p:
        raise ValueError(f"N={n} must factor as rows({r}) x cols with both divisible by P={p}")
    c = n // r

    def fn(xl: jax.Array) -> jax.Array:
        me = lax.axis_index(axis_name)
        # local rows block of A = x.reshape(R, C): (..., R/p, C)
        a = xl.reshape(xl.shape[:-1] + (r // p, c))
        # exchange 1: localize columns j2; FFT_R over j1 -> k1
        t1 = tr.distributed_transpose(a, axis_name, strategy=cfg.strategy)
        g = lf.local_fft(t1, axis=-1, impl=cfg.local_impl)  # (..., C/p, R)

        # Twiddle w_n^(j2*k1). Under a chunk-streaming backend it is fused
        # into exchange 2's per-chunk compute (applied to each chunk as it
        # arrives -- the paper's 'hide computation behind communication');
        # otherwise applied up-front to the whole block.
        if backend.supports_chunk_fn:

            def tw_chunk(chunk: jax.Array, src: jax.Array) -> jax.Array:
                # chunk (..., R/p, C/p): my k1 block x src's j2 block.
                k1 = me * (r // p) + jnp.arange(r // p)
                j2 = src * (c // p) + jnp.arange(c // p)
                tw = jnp.exp(-2j * jnp.pi * (k1[:, None] * j2[None, :]) / n)
                return chunk * tw.astype(chunk.dtype)

            t2 = tr.distributed_transpose(g, axis_name, strategy=cfg.strategy, chunk_fn=tw_chunk)
        else:
            j2 = me * (c // p) + jnp.arange(c // p)
            k1 = jnp.arange(r)
            tw = jnp.exp(-2j * jnp.pi * (j2[:, None] * k1[None, :]) / n).astype(g.dtype)
            t2 = tr.distributed_transpose(g * tw, axis_name, strategy=cfg.strategy)
        f = lf.local_fft(t2, axis=-1, impl=cfg.local_impl)  # (..., R/p, C): F[k1, k2]
        # X[k2*R + k1] = F[k1, k2]  =>  natural order is F^T flattened; one
        # final exchange re-shards k2 and emits X contiguously.
        t3 = tr.distributed_transpose(f, axis_name, strategy=cfg.strategy)
        return t3.reshape(xl.shape[:-1] + (c // p * r,))

    ndim = x.ndim
    spec = P(*([None] * (ndim - 1) + [axis_name]))
    return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)(x)


def reference_fft2(x: jax.Array, *, inverse: bool = False) -> jax.Array:
    """Single-device oracle (numpy semantics) for tests/benchmarks."""
    return jnp.fft.ifft2(x) if inverse else jnp.fft.fft2(x)
