"""Pluggable collective-backend registry -- the paper's parcelport axis.

HPX swaps its network layer (TCP / MPI / LCI parcelports) underneath one
collective interface, which is the paper's whole experimental axis. This
module is the TPU-side analogue: every pencil-exchange strategy is a
registered :class:`CollectiveBackend` and the rest of the stack (the
distributed FFTs, the plan front-end, the benchmarks) dispatches through
the registry instead of enumerating strategy strings.

A backend bundles the two things that previously lived in different
files and could drift apart:

- ``transpose(x, axis_name, chunk_fn)`` -- the shard_map-local pencil
  exchange (implementations in :mod:`repro.core.transpose`);
- ``cost(m_bytes, p, prm, chunk_compute_s)`` -- the alpha-beta napkin
  model of that same schedule (:mod:`repro.core.comm_model`), which is
  what lets ``Plan.predict()`` rank backends *before* running anything
  (the paper's Fig. 3 hypothesis step) and powers ``backend="auto"``.

Registering a new backend is all that is needed for it to show up in
``available()``, in ``backend="auto"`` selection, and in the
oracle-equivalence test sweep::

    @register
    class MyExchange(CollectiveBackend):
        name = "my_exchange"
        def transpose(self, x, axis_name, chunk_fn=None, *, n_chunks=None): ...
        def cost(self, m_bytes, p, prm=CommParams(), chunk_compute_s=0.0,
                 *, n_chunks=None, fused=True): ...

(the keyword-only ``n_chunks``/``fused`` parameters are part of the
extension contract since the pipelined overlap executor: every call
site passes them, so a backend must at least accept-and-ignore them --
monolithic backends do exactly that).
"""

from __future__ import annotations

from typing import Callable, Dict, Iterable, Optional, Tuple, Type

import jax

from repro.core import comm_model as cm
from repro.core import transpose as tr
from repro.core.comm_model import CommParams

ChunkFn = Callable[[jax.Array, jax.Array], jax.Array]


class CollectiveBackend:
    """One pencil-exchange strategy: implementation + cost model.

    Class attributes:

    ``name``
        Registry key (the user-facing ``backend=``/``strategy=`` string).
    ``kind``
        ``"shard_map"`` -- the backend implements the per-shard exchange
        and composes with the explicit local-FFT pipeline; ``"global"``
        -- the backend takes over the *whole* transform at the jit level
        (the ``xla_auto`` reference) and has no ``transpose``.
    ``supports_chunk_fn``
        Whether ``transpose`` streams chunks through a per-arrival
        callback (the paper's overlap hook).
    """

    name: str = ""
    kind: str = "shard_map"
    supports_chunk_fn: bool = False

    def supports(self, p: int) -> bool:
        """Whether the schedule is defined for ``p`` shards."""
        return True

    def transpose(
        self,
        x: jax.Array,
        axis_name: str,
        chunk_fn: Optional[ChunkFn] = None,
        *,
        n_chunks: Optional[int] = None,
    ) -> jax.Array:
        """shard_map-local (..., r, C) -> (..., c, R) pencil exchange.
        ``n_chunks`` (streaming backends; a hint elsewhere) sub-chunks
        each peer block so compute pipelines into flight time."""
        raise NotImplementedError(f"backend {self.name!r} has no shard_map transpose")

    def stream_reduce(
        self,
        x: jax.Array,
        axis_name: str,
        chunk_fn: ChunkFn,
        *,
        n_chunks: Optional[int] = None,
    ) -> jax.Array:
        """Streaming exchange-and-accumulate over this backend's own
        schedule (see :func:`repro.core.transpose._chunked_reduce`) --
        the hook the fused transpose+FFT stage rides. Only
        chunk-streaming backends implement it; the monolithic
        collectives have no per-arrival moment to fold compute into."""
        raise NotImplementedError(
            f"backend {self.name!r} is not chunk-streaming; fused stages "
            f"need a backend with supports_chunk_fn"
        )

    def cost(
        self,
        m_bytes: float,
        p: int,
        prm: CommParams = CommParams(),
        chunk_compute_s: float = 0.0,
        *,
        n_chunks: Optional[int] = None,
        fused: bool = True,
    ) -> float:
        """Predicted seconds for one exchange of a local block of
        ``m_bytes`` over ``p`` shards (alpha-beta model).

        ``chunk_compute_s`` is *per-chunk* compute (there are ``p``
        chunks) in every backend's model: streaming backends overlap it
        with later rounds (``fused=True``, the pipelined default) or --
        ``fused=False``, the monolithic discipline -- serialize all
        ``p`` chunk computes after the exchange, exactly like the
        monolithic collectives always do. ``n_chunks`` models the
        sub-chunked pipeline (more, smaller messages; finer overlap
        grain) on streaming backends and is ignored by monolithic ones.
        Same units everywhere, so ``cheapest()`` comparisons are
        apples-to-apples."""
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<CollectiveBackend {self.name!r} kind={self.kind}>"


_REGISTRY: Dict[str, CollectiveBackend] = {}


def register(cls: Type[CollectiveBackend]) -> Type[CollectiveBackend]:
    """Class decorator: instantiate and add to the registry by ``name``."""
    if not cls.name:
        raise ValueError(f"backend class {cls.__name__} must set a name")
    if cls.name in _REGISTRY:
        raise ValueError(f"backend {cls.name!r} already registered")
    _REGISTRY[cls.name] = cls()
    return cls


def get(name: str) -> CollectiveBackend:
    """Look up a backend; unknown names list what *is* registered."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown collective backend {name!r}; registered backends: {list(available())}"
        ) from None


def available(kind: Optional[str] = None) -> Tuple[str, ...]:
    """Sorted names of every registered backend; ``kind="shard_map"``
    restricts to backends with a per-shard transpose (the only ones a
    pencil grid can route per-axis)."""
    return tuple(sorted(n for n, b in _REGISTRY.items() if kind is None or b.kind == kind))


def supporting(p: int, kind: Optional[str] = None) -> Tuple[str, ...]:
    """Sorted names of registered backends (of ``kind``, when given)
    whose schedule is defined for ``p`` shards -- THE eligibility filter:
    auto selection, ``Plan.predict_axes`` and the measured planner's
    candidate sets all go through here, so they cover the same field."""
    return tuple(n for n in available(kind) if _REGISTRY[n].supports(p))


def cheapest(
    m_bytes: float,
    p: int,
    prm: CommParams = CommParams(),
    *,
    names: Optional[Iterable[str]] = None,
    chunk_compute_s: float = 0.0,
    n_chunks: Optional[int] = None,
    fused: bool = True,
) -> str:
    """Cost-model argmin over (by default) every registered backend that
    supports ``p`` -- the ``backend="auto"`` selection rule, and by
    construction the argmin of ``Plan.predict()``'s ranking. Ties break
    toward the lexicographically first name, so selection is
    deterministic. ``n_chunks``/``fused`` rank with the pipelined
    overlap model (see :meth:`CollectiveBackend.cost`)."""
    if names is None:
        names = supporting(p)
    costs = {}
    for n in sorted(names):
        b = get(n)
        if b.supports(p):
            costs[n] = b.cost(m_bytes, p, prm, chunk_compute_s, n_chunks=n_chunks, fused=fused)
    if not costs:
        raise ValueError(f"no registered backend supports P={p}")
    return min(costs, key=costs.__getitem__)


def cheapest_pair(
    m_bytes: float,
    p_rows: int,
    p_cols: int,
    prm: CommParams = CommParams(),
    *,
    names: Optional[Iterable[str]] = None,
    chunk_compute_s: float = 0.0,
    n_chunks: Optional[int] = None,
    fused: bool = True,
) -> Tuple[str, str]:
    """Per-axis cost-model argmin for a pencil grid: (backend_row,
    backend_col), each the :func:`cheapest` shard_map backend for its
    own sub-ring size. The two selections are independent -- each
    sub-exchange moves the local block over only its own axis, so the
    ranking decomposes (the 2-D ``backend="auto"`` rule).

    ``m_bytes`` is the per-device local block -- the whole block
    participates in each sub-exchange (each ships (1-1/P_axis) of it).
    """
    if names is None:
        row_names = supporting(p_rows, kind="shard_map")
        col_names = supporting(p_cols, kind="shard_map")
    else:
        names = [n for n in names if get(n).kind == "shard_map"]
        row_names = col_names = names
    row = cheapest(m_bytes, p_rows, prm, names=row_names,
                   chunk_compute_s=chunk_compute_s, n_chunks=n_chunks, fused=fused)
    col = cheapest(m_bytes, p_cols, prm, names=col_names,
                   chunk_compute_s=chunk_compute_s, n_chunks=n_chunks, fused=fused)
    return row, col


# ---------------------------------------------------------------------------
# Built-in backends (the paper's strategies + beyond-paper additions)
# ---------------------------------------------------------------------------


@register
class AllToAllBackend(CollectiveBackend):
    """One fused ``lax.all_to_all`` -- the paper's synchronized baseline."""

    name = "alltoall"

    def transpose(self, x, axis_name, chunk_fn=None, *, n_chunks=None):
        return tr._alltoall(x, axis_name)

    def cost(self, m_bytes, p, prm=CommParams(), chunk_compute_s=0.0, *,
             n_chunks=None, fused=True):
        # monolithic: all p chunk computes serialize after the collective
        return cm.t_alltoall(m_bytes, p, prm) + max(p, 1) * chunk_compute_s


@register
class ScatterBackend(CollectiveBackend):
    """P-1 direct sends (ring walk); arriving chunks stream through
    ``chunk_fn`` while later sends are in flight -- the paper's N-scatter
    decomposition."""

    name = "scatter"
    supports_chunk_fn = True

    def transpose(self, x, axis_name, chunk_fn=None, *, n_chunks=None):
        return tr._scatter(x, axis_name, chunk_fn, n_chunks)

    def stream_reduce(self, x, axis_name, chunk_fn, *, n_chunks=None):
        return tr._chunked_reduce(x, axis_name, chunk_fn, tr._ring_schedule, n_chunks)

    def cost(self, m_bytes, p, prm=CommParams(), chunk_compute_s=0.0, *,
             n_chunks=None, fused=True):
        if not fused:
            # streaming transport, but compute serialized after it (the
            # unfused discipline the pipelined executor replaces)
            return cm.t_scatter_ring(m_bytes, p, prm, 0.0, n_chunks=n_chunks) + (
                max(p, 1) * chunk_compute_s
            )
        return cm.t_scatter_ring(m_bytes, p, prm, chunk_compute_s, n_chunks=n_chunks)


@register
class BisectionBackend(CollectiveBackend):
    """Bruck / hypercube exchange: ceil(log2 P) rounds of half-buffer
    messages -- wins when per-message latency dominates (the paper's
    TCP-overhead regime)."""

    name = "bisection"

    def transpose(self, x, axis_name, chunk_fn=None, *, n_chunks=None):
        return tr._bisection(x, axis_name)

    def cost(self, m_bytes, p, prm=CommParams(), chunk_compute_s=0.0, *,
             n_chunks=None, fused=True):
        # monolithic: all p chunk computes serialize after the collective
        return cm.t_bisection(m_bytes, p, prm) + max(p, 1) * chunk_compute_s


@register
class PairwiseXorBackend(CollectiveBackend):
    """Pairwise XOR exchange (beyond-paper): P-1 symmetric swap rounds,
    round s pairing rank i with i XOR s. Power-of-two P only. Streams
    chunks like the ring, so the full overlap accounting applies."""

    name = "pairwise_xor"
    supports_chunk_fn = True

    def supports(self, p: int) -> bool:
        return p >= 1 and (p & (p - 1)) == 0

    def transpose(self, x, axis_name, chunk_fn=None, *, n_chunks=None):
        return tr._pairwise_xor(x, axis_name, chunk_fn, n_chunks)

    def stream_reduce(self, x, axis_name, chunk_fn, *, n_chunks=None):
        return tr._chunked_reduce(x, axis_name, chunk_fn, tr._swap_schedule, n_chunks)

    def cost(self, m_bytes, p, prm=CommParams(), chunk_compute_s=0.0, *,
             n_chunks=None, fused=True):
        if not fused:
            return cm.t_pairwise(m_bytes, p, prm, 0.0, n_chunks=n_chunks) + (
                max(p, 1) * chunk_compute_s
            )
        return cm.t_pairwise(m_bytes, p, prm, chunk_compute_s, n_chunks=n_chunks)


@register
class XlaAutoBackend(CollectiveBackend):
    """The 'FFTW3 reference' analogue: hand the sharded array to XLA's
    own FFT under jit and let GSPMD schedule the communication. Whole-
    transform backend -- no shard_map transpose; modeled as one fused
    all-to-all (what GSPMD lowers the resharding to)."""

    name = "xla_auto"
    kind = "global"

    def cost(self, m_bytes, p, prm=CommParams(), chunk_compute_s=0.0, *,
             n_chunks=None, fused=True):
        # monolithic: all p chunk computes serialize after the collective
        return cm.t_alltoall(m_bytes, p, prm) + max(p, 1) * chunk_compute_s
