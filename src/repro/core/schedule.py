"""Stage-schedule IR: one declarative pipeline compiler behind every
distributed transform, cost model, and planner candidate.

The paper realizes every distributed FFT as the same composable pattern
-- local FFT passes stitched together by collective exchanges, expressed
as HPX futures over scatter/all-to-all -- and its task-graph predecessor
makes that dataflow *explicit* rather than hand-coding each transform.
This module is that idea for our stack: every pipeline (slab
``fft2``/``fft3``/``fft1d_large``, pencil ``fft2``/``fft3`` and the
eight r2c/c2r chains) lowers to a declarative tuple of **Stage**
records, and a single interpreter (:func:`execute_schedule`) compiles
any schedule into the shard_map body, reusing the existing
:func:`repro.core.transpose.transpose_then_fft` /
``distributed_transpose`` machinery.

Stage vocabulary (the paper's futures/collectives, as data):

``LocalFFT(axis, inverse)``
    One local c2c FFT pass -- the compute future between exchanges.
``LocalR2C()`` / ``LocalC2R(n_last)``
    The real-to-complex truncation pass and its inverse (the only
    passes whose input/output is real).
``Exchange(axis, role, backend, p, elems, payload, fft, ...)``
    One collective transpose over a mesh axis, dispatched through the
    backend registry -- the parcelport switch. ``fft=True`` folds the
    *following* FFT pass into the arriving chunks when the backend
    streams (the pipelined overlap executor); ``elems``/``payload``
    record the per-device wire payload so the cost model and the HLO
    byte accounting walk the very object that executes.
``Twiddle(n, r, c)``
    The six-step 1-D twiddle; fused into the next Exchange's per-chunk
    compute on streaming backends, applied up-front otherwise.
``HermitianPack(h, hp)`` / ``Trim(h)``
    Zero-pad the half spectrum to the shard-divisible length / trim the
    pad where the axis lands local again.
``Relayout(op, dims)``
    Free local data movement (swaps/reshapes) between stages.

Because the builders are *pure* (shapes + names + ring sizes in,
Schedule out -- no mesh, no devices), schedules hash stably
(:meth:`Schedule.schedule_hash`), snapshot into golden tests, and
rewrite mechanically: planner candidate variants (``name@u``,
``name@f2P``, pencil pairs) are :func:`with_backends` /
:func:`with_pipeline` rewrites of the same schedule the plan executes,
so ``predict()`` can never drift from execution.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.core.fftmath as lf
import repro.core.transpose as tr
from repro.core.compat import shard_map


# ---------------------------------------------------------------------------
# Hermitian-length helpers (shared by the validator and the builders;
# re-exported by repro.core.real for its public API)
# ---------------------------------------------------------------------------


def rfft_len(n: int) -> int:
    """Length of the Hermitian-non-redundant rfft output for a real
    length-``n`` axis (numpy's ``n//2 + 1``)."""
    return int(n) // 2 + 1


def padded_rfft_len(n: int, multiple: int, weight: int = 1) -> int:
    """Smallest ``hp >= rfft_len(n)`` with ``(weight * hp) % multiple == 0``.

    ``weight`` covers the slab fft3 case where the *flattened* axis
    ``D1 * Hp`` (not ``Hp`` itself) must divide the shard count."""
    hp = rfft_len(n)
    while (weight * hp) % multiple:
        hp += 1
    return hp


def _pad_disabled_hint(n: int, multiple: int, weight: int = 1) -> str:
    return (
        f"pass pad=True (pads the half spectrum to "
        f"{padded_rfft_len(n, multiple, weight)}, plan-recorded trim)"
    )


# ---------------------------------------------------------------------------
# The one shard-divisibility validator (slab/pencil x c2c/r2c)
# ---------------------------------------------------------------------------


def check_divisible(
    global_shape,
    ndim: int,
    *,
    p: Optional[int] = None,
    axis_name=None,
    p_rows: Optional[int] = None,
    p_cols: Optional[int] = None,
    row_axis=None,
    col_axis=None,
    real: bool = False,
    pad: bool = True,
):
    """Validate that ``global_shape`` can be sharded for this transform;
    the single schedule-level copy of what used to live in three places
    (``pencil.check_divisible``, ``real.check_divisible_slab``,
    ``real.check_divisible_pencil`` -- all now delegating wrappers) plus
    the slab c2c checks inlined in ``Plan``. Raises a ``ValueError``
    naming the offending data axis and mesh/grid dimension -- the
    plan-time guard, so the failure never surfaces as an opaque chunking
    error deep inside :mod:`repro.core.transpose`.

    Returns ``(h, hp)`` for real problems (the Hermitian and
    shard-padded Hermitian lengths), ``None`` for c2c."""
    shape = tuple(global_shape)
    pencil = p_rows is not None

    if not real:
        if pencil:
            pr, pc = p_rows, p_cols

            def need(axis_from_end: int, divisor: int, why: str) -> None:
                size = shape[len(shape) - axis_from_end]
                if size % divisor:
                    raise ValueError(
                        f"pencil fft{ndim}: data axis -{axis_from_end} (global size "
                        f"{size}) is not divisible by {why} -- shape "
                        f"{shape} on grid {pr}x{pc} "
                        f"(row_axis={row_axis!r}, col_axis={col_axis!r})"
                    )

            if ndim == 3:
                need(3, pr, f"P_row={pr} ({row_axis!r})")
                need(2, pc, f"P_col={pc} ({col_axis!r})")
                need(2, pr, f"P_row={pr} ({row_axis!r}; the rows exchange re-shards it)")
                need(1, pc, f"P_col={pc} ({col_axis!r}; the cols exchange re-shards it)")
            elif ndim == 2:
                need(2, pr * pc, f"P_row*P_col={pr * pc} (both sub-rings re-shard it)")
                need(1, pr * pc, f"P_row*P_col={pr * pc} (both sub-rings re-shard it)")
            else:
                raise ValueError(f"pencil decomposition supports ndim 2 or 3, got {ndim}")
            return None
        ax = axis_name
        if ndim == 2:
            r, c = shape[-2:]
            for off, size in ((2, r), (1, c)):
                if size % p:
                    raise ValueError(
                        f"slab fft2: data axis -{off} (global size {size}) is not "
                        f"divisible by mesh axis {ax!r} (P={p}) -- shape {shape}"
                    )
        elif ndim == 3:
            d0, d1, d2 = shape[-3:]
            if d0 % p:
                raise ValueError(
                    f"slab fft3: data axis -3 (global size {d0}) is not divisible "
                    f"by mesh axis {ax!r} (P={p}) -- shape {shape}"
                )
            if (d1 * d2) % p:
                raise ValueError(
                    f"slab fft3: flattened axes (-2,-1) (size {d1}*{d2}={d1 * d2}) "
                    f"not divisible by mesh axis {ax!r} (P={p}) -- shape {shape}"
                )
        else:
            n = shape[-1]
            if n % (p * p):
                raise ValueError(
                    f"fft1d_large: data axis -1 (size {n}) must be divisible by "
                    f"P^2={p * p} of mesh axis {ax!r} -- shape {shape}"
                )
        return None

    if not pencil:
        if ndim == 2:
            r, c = shape[-2:]
            if r % p:
                raise ValueError(
                    f"real slab rfft2: data axis -2 (global size {r}) is not "
                    f"divisible by mesh axis {axis_name!r} (P={p}) -- shape {shape}"
                )
            h = rfft_len(c)
            if not pad and h % p:
                raise ValueError(
                    f"real slab rfft2: Hermitian axis -1 (N={c} -> N//2+1={h}) is "
                    f"not divisible by mesh axis {axis_name!r} (P={p}) and "
                    f"pad=False -- shape {shape}; {_pad_disabled_hint(c, p)}"
                )
            return h, (padded_rfft_len(c, p) if pad else h)
        if ndim == 3:
            d0, d1, d2 = shape[-3:]
            if d0 % p:
                raise ValueError(
                    f"real slab rfft3: data axis -3 (global size {d0}) is not "
                    f"divisible by mesh axis {axis_name!r} (P={p}) -- shape {shape}"
                )
            h = rfft_len(d2)
            if not pad and (d1 * h) % p:
                raise ValueError(
                    f"real slab rfft3: flattened axes (-2,-1) (size {d1}*{h}={d1 * h} "
                    f"after the Hermitian truncation of N={d2}) not divisible by "
                    f"mesh axis {axis_name!r} (P={p}) and pad=False -- shape "
                    f"{shape}; {_pad_disabled_hint(d2, p, d1)}"
                )
            return h, (padded_rfft_len(d2, p, weight=d1) if pad else h)
        raise NotImplementedError(
            f"real transforms support ndim 2 or 3, got ndim={ndim} "
            f"(1-D real: run the c2c fft1d_large on a complexified signal)"
        )

    pr, pc = p_rows, p_cols
    where = (
        f"shape {shape} on grid {pr}x{pc} "
        f"(row_axis={row_axis!r}, col_axis={col_axis!r})"
    )
    if ndim == 3:
        d0, d1, d2 = shape[-3:]
        if d0 % pr:
            raise ValueError(
                f"real pencil rfft3: data axis -3 (global size {d0}) is not "
                f"divisible by P_row={pr} ({row_axis!r}) -- {where}"
            )
        for divisor, why in ((pc, f"P_col={pc} ({col_axis!r})"),
                             (pr, f"P_row={pr} ({row_axis!r}; the rows "
                                  f"exchange re-shards it)")):
            if d1 % divisor:
                raise ValueError(
                    f"real pencil rfft3: data axis -2 (global size {d1}) is "
                    f"not divisible by {why} -- {where}"
                )
        h = rfft_len(d2)
        if not pad and h % pc:
            raise ValueError(
                f"real pencil rfft3: Hermitian axis -1 (N={d2} -> N//2+1={h}) "
                f"is not divisible by P_col={pc} ({col_axis!r}) and "
                f"pad=False -- {where}; {_pad_disabled_hint(d2, pc)}"
            )
        return h, (padded_rfft_len(d2, pc) if pad else h)
    if ndim == 2:
        r, c = shape[-2:]
        if r % (pr * pc):
            raise ValueError(
                f"real pencil rfft2: data axis -2 (global size {r}) is not "
                f"divisible by P_row*P_col={pr * pc} (both sub-rings re-shard "
                f"it) -- {where}"
            )
        if c % pc:
            raise ValueError(
                f"real pencil rfft2: data axis -1 (global size {c}) is not "
                f"divisible by P_col={pc} ({col_axis!r}) -- {where}"
            )
        h = rfft_len(c)
        if not pad and h % (pr * pc):
            raise ValueError(
                f"real pencil rfft2: Hermitian axis -1 (N={c} -> N//2+1={h}) "
                f"is not divisible by P_row*P_col={pr * pc} (both sub-rings "
                f"re-shard it) and pad=False -- {where}; "
                f"{_pad_disabled_hint(c, pr * pc)}"
            )
        return h, (padded_rfft_len(c, pr * pc) if pad else h)
    raise NotImplementedError(f"real pencil transforms support ndim 2 or 3, got {ndim}")


# ---------------------------------------------------------------------------
# Stage records
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LocalFFT:
    """One local c2c FFT pass along ``axis`` (1/n factor when inverse)."""

    axis: int = -1
    inverse: bool = False


@dataclasses.dataclass(frozen=True)
class LocalR2C:
    """Local real-to-complex pass along the last axis (keeps H = N//2+1)."""


@dataclasses.dataclass(frozen=True)
class LocalC2R:
    """Local complex-to-real pass: half spectrum (length ``n_last//2+1``)
    to a real length-``n_last`` signal, carrying the 1/n factor."""

    n_last: int


@dataclasses.dataclass(frozen=True)
class HermitianPack:
    """Zero-pad the Hermitian axis from ``h`` to the shard-divisible
    ``hp`` (the pad is exactly zero, so downstream FFTs stay exact)."""

    h: int
    hp: int


@dataclasses.dataclass(frozen=True)
class Trim:
    """Keep the first ``h`` entries of the last axis (drop the shard pad
    where the Hermitian axis lands local again)."""

    h: int


@dataclasses.dataclass(frozen=True)
class Relayout:
    """Free local data movement: ``swap_last2`` / ``swap_outer``
    (axes -3,-2) / ``flatten2`` (merge the last two axes) /
    ``unflatten2`` (split the last axis into ``dims``)."""

    op: str
    dims: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class Twiddle:
    """Six-step twiddle w_n^(j2*k1) of the 1-D large transform (N = r*c
    viewed row-major). Always immediately precedes an Exchange: on a
    chunk-streaming backend the executor folds it into that exchange's
    per-chunk compute (the paper's 'hide computation behind
    communication'); otherwise it is applied up-front to the block."""

    n: int
    r: int
    c: int


@dataclasses.dataclass(frozen=True)
class Exchange:
    """One collective transpose over mesh axis ``axis`` (ring size
    ``p``), dispatched through the backend registry. ``fft=True`` runs
    :func:`repro.core.transpose.transpose_then_fft` -- the following FFT
    pass folded into the arriving chunks when ``fused`` and the backend
    streams (conjugated tables when ``inverse``). ``elems`` is the
    per-device payload element count and ``payload`` its wire dtype
    class (``"complex"`` or ``"real"``) -- the byte truth the cost model
    and HLO accounting walk."""

    axis: str
    role: str  # 'slab' | 'row' | 'col'
    backend: str
    p: int
    elems: float
    payload: str = "complex"
    fft: bool = False
    inverse: bool = False
    fused: bool = False
    n_chunks: Optional[int] = None


_STAGE_TYPES = (LocalFFT, LocalR2C, LocalC2R, HermitianPack, Trim, Relayout, Twiddle, Exchange)


# ---------------------------------------------------------------------------
# Schedule container
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A lowered transform: stage tuple + the metadata the runner and
    the analyzers need. ``global_shape`` is the full *data-side* shape
    (the real array's shape for r2c/c2r chains, batch dims included);
    ``in_tail``/``out_tail`` are the trailing PartitionSpec entries of
    the transform's input/output (leading batch dims are replicated).
    ``conj``/``scale`` implement the c2c inverse as the conjugate-wrap
    of the forward schedule; real chains instead carry per-stage
    ``inverse`` flags (structurally reversed schedule, conjugated
    tables). ``global_backend`` marks whole-transform (GSPMD reference)
    backends: the stage list still carries the abstract exchange
    structure for cost/byte accounting, but execution routes through the
    one :func:`_xla_reference` path instead of the interpreter."""

    kind: str
    global_shape: Tuple[int, ...]
    ndim: int
    decomp: str
    real: bool
    inverse: bool
    transpose_back: bool
    stages: Tuple[object, ...]
    in_tail: Tuple[Optional[str], ...]
    out_tail: Tuple[Optional[str], ...]
    conj: bool = False
    scale: Optional[float] = None
    n_last: Optional[int] = None
    h: Optional[int] = None
    hp: Optional[int] = None
    global_backend: Optional[str] = None

    # -- identity ----------------------------------------------------------
    def canonical(self) -> str:
        """Stable text form: header + one dataclass repr per stage. This
        is what hashes, and what the golden snapshots diff."""
        head = (
            f"kind={self.kind}|shape={self.global_shape}|ndim={self.ndim}|"
            f"decomp={self.decomp}|real={self.real}|inverse={self.inverse}|"
            f"tb={self.transpose_back}|conj={self.conj}|scale={self.scale}|"
            f"n_last={self.n_last}|h={self.h}|hp={self.hp}|"
            f"in={self.in_tail}|out={self.out_tail}|gb={self.global_backend}"
        )
        return "\n".join([head] + [repr(st) for st in self.stages])

    def schedule_hash(self) -> str:
        """12-hex content hash of :meth:`canonical` -- two plans with the
        same hash execute the same pipeline."""
        return hashlib.sha256(self.canonical().encode()).hexdigest()[:12]

    # -- queries -----------------------------------------------------------
    def exchanges(self, role: Optional[str] = None) -> Tuple[Exchange, ...]:
        return tuple(
            st for st in self.stages
            if isinstance(st, Exchange) and (role is None or st.role == role)
        )

    def describe(
        self,
        *,
        params=None,
        chunk_compute_s: float = 0.0,
        real_itemsize: int = 8,
        complex_itemsize: int = 8,
    ) -> str:
        return describe_schedule(
            self, params=params, chunk_compute_s=chunk_compute_s,
            real_itemsize=real_itemsize, complex_itemsize=complex_itemsize,
        )


# ---------------------------------------------------------------------------
# Cost / byte walks (the SAME object that executes)
# ---------------------------------------------------------------------------


def exchange_block_bytes(st: Exchange, real_itemsize: int, complex_itemsize: int) -> float:
    """Full per-device block bytes one Exchange re-shards (the alpha-beta
    ``m_bytes``); the wire ships ``(1 - 1/p)`` of it."""
    item = complex_itemsize if st.payload == "complex" else real_itemsize
    return st.elems * item


def exchange_wire_bytes(st: Exchange, real_itemsize: int, complex_itemsize: int) -> float:
    return exchange_block_bytes(st, real_itemsize, complex_itemsize) * (1 - 1 / st.p)


def schedule_comm_bytes(sched: Schedule, real_itemsize: int, complex_itemsize: int) -> float:
    """Total bytes each device ships per transform -- the sum of every
    Exchange stage's wire payload. ``Plan.comm_bytes`` and the HLO-parser
    cross-checks both consume this walk."""
    return sum(
        exchange_wire_bytes(st, real_itemsize, complex_itemsize)
        for st in sched.exchanges()
    )


def stage_seconds(
    st: Exchange,
    params,
    chunk_compute_s: float,
    real_itemsize: int,
    complex_itemsize: int,
) -> float:
    """Alpha-beta predicted seconds of one Exchange stage, costed by its
    own backend at its own ring size with its own pipeline fields."""
    from repro.core import backends

    b = backends.get(st.backend)
    return b.cost(
        exchange_block_bytes(st, real_itemsize, complex_itemsize),
        st.p, params, chunk_compute_s,
        n_chunks=st.n_chunks, fused=st.fused,
    )


def predict_seconds(
    sched: Schedule,
    params,
    chunk_compute_s: float,
    real_itemsize: int,
    complex_itemsize: int,
    role: Optional[str] = None,
) -> float:
    """Whole-schedule (or one grid axis's) predicted seconds: the sum of
    :func:`stage_seconds` over its Exchange stages. ``Plan.predict`` is
    this walk over backend/pipeline rewrites of the plan's own schedule,
    so prediction and execution cannot drift."""
    return sum(
        stage_seconds(st, params, chunk_compute_s, real_itemsize, complex_itemsize)
        for st in sched.exchanges(role)
    )


# ---------------------------------------------------------------------------
# Rewrites (planner candidates as schedule transformations)
# ---------------------------------------------------------------------------


def with_pipeline(sched: Schedule, fused: bool, n_chunks: Optional[int]) -> Schedule:
    """Rewrite every Exchange's pipeline fields -- the ``@u`` (unfused)
    and ``@f<k>`` (sub-chunked) planner variants as schedule rewrites."""
    stages = tuple(
        dataclasses.replace(st, fused=bool(fused), n_chunks=n_chunks)
        if isinstance(st, Exchange) else st
        for st in sched.stages
    )
    return dataclasses.replace(sched, stages=stages)


def with_backends(
    sched: Schedule,
    *,
    slab: Optional[str] = None,
    row: Optional[str] = None,
    col: Optional[str] = None,
) -> Schedule:
    """Rewrite Exchange backends by role -- backend candidates (and
    pencil ``"row+col"`` pairs) as schedule rewrites."""
    sub = {"slab": slab, "row": row, "col": col}

    def rw(st):
        if not isinstance(st, Exchange):
            return st
        nm = sub.get(st.role)
        return st if nm is None else dataclasses.replace(st, backend=nm)

    return dataclasses.replace(sched, stages=tuple(rw(st) for st in sched.stages))


def apply_variant(sched: Schedule, candidate: str, *, pipeline="auto") -> Schedule:
    """Measured-planner candidate id (``name``, ``name@u``,
    ``name@f<k>``, ``"row+col"`` pair key, with or without variant
    suffix) -> the rewritten schedule that candidate would execute."""
    from repro.core.plan import pipeline_is_default, split_pair
    from repro.core.planner import parse_variant

    base, pipe = parse_variant(candidate)
    if pipe is None and not pipeline_is_default(pipeline):
        pipe = pipeline
    fused = True if pipe is None else pipe not in (False, 0)
    n_chunks = (
        pipe if isinstance(pipe, int) and not isinstance(pipe, bool) and pipe > 0 else None
    )
    if sched.decomp == "pencil":
        br, bc = split_pair(base)
        out = with_backends(sched, row=br, col=bc)
    else:
        out = with_backends(sched, slab=base)
    return with_pipeline(out, fused, n_chunks)


# ---------------------------------------------------------------------------
# Builders (pure: shapes + names + ring sizes in, Schedule out)
# ---------------------------------------------------------------------------


def build_schedule(
    global_shape,
    *,
    ndim: int,
    inverse: bool = False,
    real: bool = False,
    decomp: str = "slab",
    axis_name=None,
    p: int = 1,
    row_axis=None,
    col_axis=None,
    p_rows: int = 1,
    p_cols: int = 1,
    backend: str = "alltoall",
    backend_row: str = "alltoall",
    backend_col: str = "alltoall",
    fused: bool = False,
    n_chunks: Optional[int] = None,
    transpose_back: bool = False,
    pad: bool = True,
    rows: Optional[int] = None,
) -> Schedule:
    """Lower one distributed transform to its stage schedule.

    ``global_shape`` is the full data-side shape (real-side for r2c/c2r,
    batch dims included); for a pencil schedule pass the grid axes/sizes,
    for slab the mesh axis and its size. Real problems are validated
    here (the builder needs ``h``/``hp`` anyway); slab c2c divisibility
    stays with the plan layer so direct entry-point callers keep the
    transpose-level errors they always had."""
    shape = tuple(global_shape)
    if decomp == "pencil":
        if real:
            return _pencil_real(
                shape, ndim, inverse, row_axis, col_axis, p_rows, p_cols,
                backend_row, backend_col, fused, n_chunks, transpose_back, pad,
            )
        return _pencil_c2c(
            shape, ndim, inverse, row_axis, col_axis, p_rows, p_cols,
            backend_row, backend_col, fused, n_chunks, transpose_back,
        )
    if real:
        return _slab_real(
            shape, ndim, inverse, axis_name, p, backend, fused, n_chunks,
            transpose_back, pad,
        )
    return _slab_c2c(
        shape, ndim, inverse, axis_name, p, backend, fused, n_chunks,
        transpose_back, rows,
    )


def _global_kind(backend: str) -> Optional[str]:
    from repro.core import backends

    try:
        b = backends.get(backend)
    except (KeyError, ValueError):
        return None
    return backend if b.kind == "global" else None


def _slab_c2c(shape, ndim, inverse, ax, p, backend, fused, n_chunks, tb, rows):
    gb = _global_kind(backend)
    m = float(np.prod(shape)) / p

    def ex(fft=False, fuse=False):
        return Exchange(
            axis=ax, role="slab", backend=backend, p=p, elems=m,
            fft=fft, fused=fuse, n_chunks=n_chunks,
        )

    meta = dict(
        global_shape=shape, ndim=ndim, decomp="slab", real=False,
        inverse=inverse, transpose_back=tb, global_backend=gb,
    )
    if ndim == 2:
        stages = [LocalFFT(axis=-1), ex(fft=True, fuse=fused)]
        if tb:
            stages.append(ex())
        return Schedule(
            kind="fft2", stages=tuple(stages), in_tail=(ax, None),
            out_tail=(ax, None), conj=inverse,
            scale=float(shape[-1] * shape[-2]) if inverse else None, **meta,
        )
    if ndim == 3:
        d0, d1, d2 = shape[-3:]
        stages = (
            LocalFFT(axis=-1), LocalFFT(axis=-2), Relayout("flatten2"),
            ex(fft=True, fuse=fused), ex(), Relayout("unflatten2", (d1, d2)),
        )
        return Schedule(
            kind="fft3", stages=stages, in_tail=(ax, None, None),
            out_tail=(ax, None, None), conj=inverse,
            scale=float(d0 * d1 * d2) if inverse else None, **meta,
        )
    # ndim == 1: the six-step large transform (forward only)
    if inverse:
        raise NotImplementedError("1-D large inverse: conjugate externally")
    n = shape[-1]
    r = rows or p
    if n % r or (n // r) % p or r % p:
        if gb is not None:
            # the GSPMD reference FFTs any length -- keep the legacy
            # behavior of not imposing the six-step factorization on it
            # (no abstract exchange structure to record in that case)
            return Schedule(
                kind="fft1d", stages=(), in_tail=(ax,), out_tail=(ax,), **meta
            )
        raise ValueError(f"N={n} must factor as rows({r}) x cols with both divisible by P={p}")
    c = n // r
    stages = (
        Relayout("unflatten2", (r // p, c)),
        ex(fft=True, fuse=fused),
        Twiddle(n=n, r=r, c=c),
        ex(),
        LocalFFT(axis=-1),
        ex(),
        Relayout("flatten2"),
    )
    return Schedule(kind="fft1d", stages=stages, in_tail=(ax,), out_tail=(ax,), **meta)


def _slab_real(shape, ndim, inverse, ax, p, backend, fused, n_chunks, tb, pad):
    gb = _global_kind(backend)
    h, hp = check_divisible(shape, ndim, p=p, axis_name=ax, real=True, pad=pad)
    he = float(np.prod(shape[:-1])) * hp / p
    n_last = shape[-1]

    def ex(fft=False, fuse=False, inv=False):
        return Exchange(
            axis=ax, role="slab", backend=backend, p=p, elems=he,
            fft=fft, inverse=inv, fused=fuse, n_chunks=n_chunks,
        )

    meta = dict(
        global_shape=shape, ndim=ndim, decomp="slab", real=True,
        inverse=inverse, transpose_back=tb, n_last=n_last, h=h, hp=hp,
        global_backend=gb,
    )
    if ndim == 2:
        if not inverse:
            stages = [LocalR2C(), HermitianPack(h, hp), ex(fft=True, fuse=fused)]
            if tb:
                stages += [ex(), Trim(h)]
            return Schedule(
                kind="rfft2", stages=tuple(stages), in_tail=(ax, None),
                out_tail=(ax, None), **meta,
            )
        if tb:
            stages = [HermitianPack(h, hp), ex(fft=True, fuse=fused, inv=True)]
        else:
            stages = [LocalFFT(axis=-1, inverse=True)]
        stages += [ex(), Trim(h), LocalC2R(n_last)]
        return Schedule(
            kind="irfft2", stages=tuple(stages), in_tail=(ax, None),
            out_tail=(ax, None), **meta,
        )
    d1 = shape[-2]
    if not inverse:
        stages = (
            LocalR2C(), HermitianPack(h, hp), LocalFFT(axis=-2),
            Relayout("flatten2"), ex(fft=True, fuse=fused), ex(),
            Relayout("unflatten2", (d1, hp)), Trim(h),
        )
        return Schedule(
            kind="rfft3", stages=stages, in_tail=(ax, None, None),
            out_tail=(ax, None, None), **meta,
        )
    stages = (
        HermitianPack(h, hp), Relayout("flatten2"),
        ex(fft=True, fuse=fused, inv=True), ex(),
        Relayout("unflatten2", (d1, hp)), LocalFFT(axis=-2, inverse=True),
        Trim(h), LocalC2R(n_last),
    )
    return Schedule(
        kind="irfft3", stages=stages, in_tail=(ax, None, None),
        out_tail=(ax, None, None), **meta,
    )


def _pencil_c2c(shape, ndim, inverse, row, col, pr, pc, br, bc, fused, n_chunks, tb):
    check_divisible(shape, ndim, p_rows=pr, p_cols=pc, row_axis=row, col_axis=col)
    m = float(np.prod(shape)) / (pr * pc)

    def exr(fft=False, fuse=False):
        return Exchange(axis=row, role="row", backend=br, p=pr, elems=m,
                        fft=fft, fused=fuse, n_chunks=n_chunks)

    def exc(fft=False, fuse=False):
        return Exchange(axis=col, role="col", backend=bc, p=pc, elems=m,
                        fft=fft, fused=fuse, n_chunks=n_chunks)

    meta = dict(
        global_shape=shape, ndim=ndim, decomp="pencil", real=False,
        inverse=inverse, transpose_back=tb,
    )
    if ndim == 3:
        d0, d1, d2 = shape[-3:]
        stages = [
            LocalFFT(axis=-1), exc(fft=True, fuse=fused),
            Relayout("swap_outer"), exr(fft=True, fuse=fused),
        ]
        if tb:
            stages += [exr(), Relayout("swap_outer"), exc()]
        in_tail = (row, col, None)
        return Schedule(
            kind="fft3", stages=tuple(stages), in_tail=in_tail,
            out_tail=in_tail if tb else (col, row, None), conj=inverse,
            scale=float(d0 * d1 * d2) if inverse else None, **meta,
        )
    if tb:
        raise ValueError(
            "pencil fft2 already returns the natural layout; "
            "transpose_back applies to slab transforms and pencil fft3 only"
        )
    r_glob, c_glob = shape[-2:]
    stages = (
        Relayout("swap_last2"), exc(fft=True, fuse=fused), exc(),
        Relayout("swap_last2"), exr(fft=True, fuse=fused), exr(),
    )
    return Schedule(
        kind="fft2", stages=stages, in_tail=(row, col), out_tail=(row, col),
        conj=inverse, scale=float(r_glob * c_glob) if inverse else None, **meta,
    )


def _pencil_real(shape, ndim, inverse, row, col, pr, pc, br, bc, fused, n_chunks, tb, pad):
    h, hp = check_divisible(
        shape, ndim, p_rows=pr, p_cols=pc, row_axis=row, col_axis=col,
        real=True, pad=pad,
    )
    shards = pr * pc
    he = float(np.prod(shape[:-1])) * hp / shards
    n_last = shape[-1]

    def exr(fft=False, fuse=False, inv=False):
        return Exchange(axis=row, role="row", backend=br, p=pr, elems=he,
                        fft=fft, inverse=inv, fused=fuse, n_chunks=n_chunks)

    def exc(fft=False, fuse=False, inv=False, payload="complex", elems=None):
        return Exchange(axis=col, role="col", backend=bc, p=pc,
                        elems=he if elems is None else elems, payload=payload,
                        fft=fft, inverse=inv, fused=fuse, n_chunks=n_chunks)

    meta = dict(
        global_shape=shape, ndim=ndim, decomp="pencil", real=True,
        inverse=inverse, transpose_back=tb, n_last=n_last, h=h, hp=hp,
    )
    if ndim == 3:
        if not inverse:
            stages = [
                LocalR2C(), HermitianPack(h, hp), exc(fft=True, fuse=fused),
                Relayout("swap_outer"), exr(fft=True, fuse=fused),
            ]
            if tb:
                stages += [exr(), Relayout("swap_outer"), exc(), Trim(h)]
            in_tail = (row, col, None)
            return Schedule(
                kind="rfft3", stages=tuple(stages), in_tail=in_tail,
                out_tail=in_tail if tb else (col, row, None), **meta,
            )
        if tb:
            stages = [
                HermitianPack(h, hp), exc(), Relayout("swap_outer"),
                exr(fft=True, fuse=fused, inv=True),
            ]
        else:
            stages = [LocalFFT(axis=-1, inverse=True)]
        stages += [
            exr(fft=True, fuse=fused, inv=True), Relayout("swap_outer"),
            exc(), Trim(h), LocalC2R(n_last),
        ]
        return Schedule(
            kind="irfft3", stages=tuple(stages),
            in_tail=(row, col, None) if tb else (col, row, None),
            out_tail=(row, col, None), **meta,
        )
    if tb:
        raise ValueError(
            "pencil rfft2 already returns the natural layout; "
            "transpose_back applies to slab transforms and pencil rfft3 only"
        )
    real_elems = float(np.prod(shape)) / shards
    if not inverse:
        stages = (
            Relayout("swap_last2"), exc(payload="real", elems=real_elems),
            LocalR2C(), HermitianPack(h, hp), exc(), Relayout("swap_last2"),
            exr(fft=True, fuse=fused), exr(),
        )
        return Schedule(
            kind="rfft2", stages=stages, in_tail=(row, col),
            out_tail=(row, col), **meta,
        )
    stages = (
        exr(fft=True, fuse=fused, inv=True), exr(), Relayout("swap_last2"),
        exc(), Trim(h), LocalC2R(n_last),
        exc(payload="real", elems=real_elems), Relayout("swap_last2"),
    )
    return Schedule(
        kind="irfft2", stages=stages, in_tail=(row, col), out_tail=(row, col), **meta
    )


# ---------------------------------------------------------------------------
# Local r2c/c2r building blocks (shared with repro.core.real, which
# re-exports them; they live here so the executor has no real.py import)
# ---------------------------------------------------------------------------


def local_rfft(x: jax.Array, impl) -> jax.Array:
    """r2c along the last axis. ``jnp`` uses the native rfft; the matmul
    and pallas impls have no r2c codelet, so they transform the
    complexified axis and keep the non-redundant half."""
    if impl == "jnp":
        return jnp.fft.rfft(x, axis=-1)
    return lf.local_fft(x, axis=-1, impl=impl)[..., : rfft_len(x.shape[-1])]


def local_irfft(x: jax.Array, n: int, impl) -> jax.Array:
    """c2r along the last axis: half spectrum (length ``n//2+1``) to a
    real length-``n`` signal, carrying the 1/n factor."""
    if impl == "jnp":
        return jnp.fft.irfft(x, n=n, axis=-1)
    h = x.shape[-1]
    # rebuild the redundant half (X[n-k] = conj(X[k]), k = 1..n-h) and
    # run the impl's c2c inverse; the result is real up to roundoff
    tail = jnp.conj(x[..., 1 : n - h + 1])[..., ::-1]
    full = jnp.concatenate([x, tail], axis=-1)
    return jnp.real(lf.local_fft(full, axis=-1, inverse=True, impl=impl))


def pad_last(v: jax.Array, count: int) -> jax.Array:
    if count == 0:
        return v
    return jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, count)])


# ---------------------------------------------------------------------------
# The executor
# ---------------------------------------------------------------------------


def _relayout(v: jax.Array, st: Relayout) -> jax.Array:
    if st.op == "swap_last2":
        return jnp.swapaxes(v, -1, -2)
    if st.op == "swap_outer":
        return jnp.swapaxes(v, -3, -2)
    if st.op == "flatten2":
        return v.reshape(v.shape[:-2] + (v.shape[-2] * v.shape[-1],))
    if st.op == "unflatten2":
        a, b = st.dims
        return v.reshape(v.shape[:-1] + (a, b))
    raise ValueError(f"unknown relayout op {st.op!r}")


def _twiddled_exchange(v: jax.Array, tw: Twiddle, ex: Exchange) -> jax.Array:
    """Twiddle + the exchange it rides: fused into the per-chunk compute
    on streaming backends (applied to each sub-chunk as it arrives),
    up-front to the whole block otherwise."""
    from repro.core import backends

    n, r, c, p = tw.n, tw.r, tw.c, ex.p
    me = lax.axis_index(ex.axis)
    if backends.get(ex.backend).supports_chunk_fn:

        def tw_chunk(chunk: jax.Array, src: jax.Array, offset: int) -> jax.Array:
            # chunk (..., R/p, rows): my k1 block x src's j2 rows
            # [offset, offset+rows) of its C/p block.
            k1 = me * (r // p) + jnp.arange(r // p)
            j2 = src * (c // p) + offset + jnp.arange(chunk.shape[-1])
            t = jnp.exp(-2j * jnp.pi * (k1[:, None] * j2[None, :]) / n)
            return chunk * t.astype(chunk.dtype)

        return tr.distributed_transpose(
            v, ex.axis, strategy=ex.backend, chunk_fn=tw_chunk, n_chunks=ex.n_chunks
        )
    j2 = me * (c // p) + jnp.arange(c // p)
    k1 = jnp.arange(r)
    t = jnp.exp(-2j * jnp.pi * (j2[:, None] * k1[None, :]) / n).astype(v.dtype)
    return tr.distributed_transpose(v * t, ex.axis, strategy=ex.backend)


def _execute_stages(v: jax.Array, stages: Tuple[object, ...], *, impl="jnp") -> jax.Array:
    """Interpret a run of stages over one device's local block. The
    whole-schedule executor and the trace-mode segment runner both call
    this, so traced segments execute exactly the ops the untraced body
    would."""
    i = 0
    while i < len(stages):
        st = stages[i]
        if isinstance(st, LocalFFT):
            v = lf.local_fft(v, axis=st.axis, inverse=st.inverse, impl=impl)
        elif isinstance(st, LocalR2C):
            v = local_rfft(v, impl)
        elif isinstance(st, LocalC2R):
            v = local_irfft(v, st.n_last, impl)
        elif isinstance(st, HermitianPack):
            v = pad_last(v, st.hp - st.h)
        elif isinstance(st, Trim):
            v = v[..., : st.h]
        elif isinstance(st, Relayout):
            v = _relayout(v, st)
        elif isinstance(st, Twiddle):
            nxt = stages[i + 1] if i + 1 < len(stages) else None
            if not isinstance(nxt, Exchange):
                raise ValueError("Twiddle must immediately precede an Exchange")
            v = _twiddled_exchange(v, st, nxt)
            i += 2
            continue
        elif isinstance(st, Exchange):
            if st.fft:
                v = tr.transpose_then_fft(
                    v, st.axis, strategy=st.backend, impl=impl,
                    fused=st.fused, n_chunks=st.n_chunks, inverse=st.inverse,
                )
            else:
                v = tr.distributed_transpose(
                    v, st.axis, strategy=st.backend, n_chunks=st.n_chunks
                )
        else:
            raise TypeError(f"unknown stage {st!r}")
        i += 1
    return v


def execute_schedule(xl: jax.Array, sched: Schedule, *, impl="jnp") -> jax.Array:
    """Interpret a schedule over one device's local block -- the single
    shard_map body behind every distributed transform. Must be called
    inside ``shard_map`` (use :func:`run_schedule` from outside)."""
    v = jnp.conj(xl) if sched.conj else xl
    v = _execute_stages(v, sched.stages, impl=impl)
    if sched.conj:
        v = jnp.conj(v)
    if sched.scale is not None:
        v = v / sched.scale
    return v


def _specs(sched: Schedule, ndim: int) -> Tuple[P, P]:
    i = P(*([None] * (ndim - len(sched.in_tail))), *sched.in_tail)
    o = P(*([None] * (ndim - len(sched.out_tail))), *sched.out_tail)
    return i, o


def simulate_specs(sched: Schedule, ndim: int) -> Tuple[Tuple[Optional[str], ...], ...]:
    """Walk the stage list symbolically and return the full-length
    partition spec at every stage boundary: ``specs[0]`` is the input
    spec, ``specs[i + 1]`` the spec after stage ``i``. This is what lets
    the trace-mode executor cut the schedule into per-stage shard_map
    segments without any resharding between them.

    The rules mirror the executor's data movement:

    - an :class:`Exchange` transposes the *data* of the last two local
      dims but keeps the same spec positions sharded -- the local block
      goes ``(..., r, C)`` with R sharded to ``(..., c, R)`` with C
      sharded over the same mesh axis (see
      :mod:`repro.core.transpose`), so the tail spec is unchanged;
    - a :class:`Relayout` permutes/merges/splits spec entries exactly as
      it moves the local dims;
    - local stages (FFT/r2c/c2r/pad/trim) never touch sharding.

    The final spec must land on the schedule's own ``out_tail`` -- a
    mismatch means the simulation rules and a builder disagree, so we
    fail loudly rather than emit a silently-resharding trace."""
    spec = [None] * (ndim - len(sched.in_tail)) + list(sched.in_tail)
    out = [tuple(spec)]
    for st in sched.stages:
        if isinstance(st, Relayout):
            if st.op == "swap_last2":
                spec[-1], spec[-2] = spec[-2], spec[-1]
            elif st.op == "swap_outer":
                spec[-3], spec[-2] = spec[-2], spec[-3]
            elif st.op == "flatten2":
                if spec[-1] is not None:
                    raise ValueError(
                        "flatten2 with the minor axis sharded has no "
                        "block-contiguous partition spec"
                    )
                spec = spec[:-2] + [spec[-2]]
            elif st.op == "unflatten2":
                spec = spec[:-1] + [spec[-1], None]
            else:  # pragma: no cover - _relayout already rejects these
                raise ValueError(f"unknown relayout op {st.op!r}")
        elif isinstance(st, (Twiddle, Exchange)):
            ex = st if isinstance(st, Exchange) else None
            if ex is not None and ex.p > 1 and spec[-2] != ex.axis:
                raise ValueError(
                    f"exchange over mesh axis {ex.axis!r} but simulated "
                    f"spec has {spec[-2]!r} sharded at position -2"
                )
        out.append(tuple(spec))
    expected = [None] * (len(out[-1]) - len(sched.out_tail)) + list(sched.out_tail)
    if list(out[-1]) != expected:
        raise ValueError(
            f"spec simulation of {sched.kind} schedule landed on "
            f"{out[-1]} but the schedule declares out_tail={sched.out_tail}"
        )
    return tuple(out)


def _xla_reference(x: jax.Array, sched: Schedule, mesh: Mesh) -> jax.Array:
    """The one GSPMD reference path (the 'FFTW3 reference' analogue):
    hand the sharded array to XLA's own FFT op under jit and let GSPMD
    choose the communication schedule. Replaces the per-transform
    ``_fft2_xla_auto`` / ``_rfft2_xla_auto`` / ``_irfft2_xla_auto``
    one-offs -- every whole-transform backend now routes through the
    same schedule object as the shard_map executor."""
    return _reference_executable(sched, mesh, x.ndim)(x)


@functools.lru_cache(maxsize=128)
def _reference_executable(sched: Schedule, mesh: Mesh, ndim: int):
    """Jitted GSPMD reference, cached on the (hashable, frozen) schedule
    so repeated traced executions (``Plan.profile`` reps) hit the compile
    cache instead of re-jitting a fresh closure every call."""
    in_spec, out_spec = _specs(sched, ndim)
    sh_in = NamedSharding(mesh, in_spec)
    sh_out = NamedSharding(mesh, out_spec)
    k, inv, tb = sched.kind, sched.inverse, sched.transpose_back
    if k == "fft2":

        def fn(v):
            out = jnp.fft.ifft2(v) if inv else jnp.fft.fft2(v)
            if not tb:
                out = jnp.swapaxes(out, -1, -2)
            return out

    elif k == "fft3":
        f3 = jnp.fft.ifftn if inv else jnp.fft.fftn
        fn = lambda v: f3(v, axes=(-3, -2, -1))  # noqa: E731
    elif k == "fft1d":
        fn = jnp.fft.fft
    elif k == "rfft2":
        hp = sched.hp

        def fn(v):
            y = jnp.fft.rfft2(v)
            if tb:
                return y
            y = jnp.swapaxes(y, -1, -2)
            return jnp.pad(y, [(0, 0)] * (y.ndim - 2) + [(0, hp - y.shape[-2]), (0, 0)])

    elif k == "irfft2":
        h, n_last = sched.h, sched.n_last
        r_glob = sched.global_shape[-2]

        def fn(v):
            if not tb:
                v = jnp.swapaxes(v[..., :h, :], -1, -2)
            return jnp.fft.irfft2(v, s=(r_glob, n_last))

    elif k == "rfft3":
        fn = lambda v: jnp.fft.rfftn(v, axes=(-3, -2, -1))  # noqa: E731
    elif k == "irfft3":
        s = sched.global_shape[-3:]
        fn = lambda v: jnp.fft.irfftn(v, s=s, axes=(-3, -2, -1))  # noqa: E731
    else:  # pragma: no cover - builders only emit the kinds above
        raise ValueError(f"no whole-transform reference for schedule kind {k!r}")
    return jax.jit(fn, in_shardings=sh_in, out_shardings=sh_out)


def run_schedule(
    x: jax.Array, sched: Schedule, mesh: Mesh, *, impl="jnp", trace=None, faults=None
) -> jax.Array:
    """Run a schedule on a globally-sharded array: shard_map the
    interpreter with the schedule's own partition specs, or dispatch the
    whole transform to the GSPMD reference for ``kind="global"``
    backends.

    With ``trace`` (a :class:`repro.obs.trace.TraceRecorder`) the
    schedule instead executes *segmented*: one shard_map per stage with
    ``jax.block_until_ready`` between them, stamping a wall-clock span
    per stage -- Exchange spans carry backend/role/wire-bytes/pipeline
    attributes (the paper's comm-vs-compute breakdown, per stage). The
    default ``trace=None`` path is byte-identical to the untraced
    executor and stays jittable.

    With ``faults`` (an *armed* :class:`repro.runtime.faults.FaultPlan`)
    the schedule also executes segmented, consulting the fault plan
    before every Exchange segment (and before a ``global:`` reference
    dispatch) so a matching spec can raise, stall, or report device loss
    at exactly the stage it names -- deterministic chaos on the IR. An
    exhausted (``active() == False``) or absent fault plan costs
    nothing: the fast path runs unchanged."""
    if faults is not None and faults.active():
        if trace is not None:
            return _run_schedule_traced(
                x, sched, mesh, impl=impl, trace=trace, faults=faults
            )
        return _run_schedule_faulted(x, sched, mesh, impl=impl, faults=faults)
    if trace is not None:
        return _run_schedule_traced(x, sched, mesh, impl=impl, trace=trace)
    if sched.global_backend is not None:
        return _xla_reference(x, sched, mesh)
    in_spec, out_spec = _specs(sched, x.ndim)

    def fn(xl: jax.Array) -> jax.Array:
        return execute_schedule(xl, sched, impl=impl)

    return shard_map(fn, mesh=mesh, in_specs=in_spec, out_specs=out_spec)(x)


def _run_schedule_faulted(
    x: jax.Array, sched: Schedule, mesh: Mesh, *, impl, faults
) -> jax.Array:
    """Chaos-mode executor: the trace-mode segment walk without spans or
    fences, calling ``faults.on_stage(label, index=...)`` before every
    Exchange segment (Twiddles ride their Exchange, as in tracing).
    Injected faults therefore surface as *host* exceptions at dispatch
    time -- synchronously and deterministically -- while the segments
    themselves still launch async; numerics of a non-firing run match
    the untraced executor (same per-segment shard_maps over the same
    simulated boundary specs)."""
    if sched.global_backend is not None:
        faults.on_stage(f"global:{sched.kind}", index=0)
        return _xla_reference(x, sched, mesh)
    bounds = simulate_specs(sched, x.ndim)
    v = jnp.conj(x) if sched.conj else x
    for start, seg in _segments(sched):
        report = seg[-1]
        if isinstance(report, Exchange):
            faults.on_stage(_stage_label(report), index=start + len(seg) - 1)
        fn = _segment_executable(
            sched, start, len(seg), impl, mesh,
            P(*bounds[start]), P(*bounds[start + len(seg)]),
        )
        v = fn(v)
    if sched.conj:
        v = jnp.conj(v)
    if sched.scale is not None:
        v = v / sched.scale
    return v


def _segments(sched: Schedule) -> Tuple[Tuple[int, Tuple[object, ...]], ...]:
    """Cut the stage list into trace segments: every stage is its own
    segment except a Twiddle, which rides its following Exchange (the
    executor fuses them; the merged span reports on the Exchange)."""
    segs = []
    stages = sched.stages
    i = 0
    while i < len(stages):
        if isinstance(stages[i], Twiddle):
            segs.append((i, stages[i : i + 2]))
            i += 2
        else:
            segs.append((i, stages[i : i + 1]))
            i += 1
    return tuple(segs)


def _itemsizes(x: jax.Array) -> Tuple[int, int]:
    """(real, complex) itemsizes implied by the runtime dtype."""
    if jnp.iscomplexobj(x):
        return x.dtype.itemsize // 2, x.dtype.itemsize
    return x.dtype.itemsize, 2 * x.dtype.itemsize


def exchange_span_args(st: Exchange, real_itemsize: int, complex_itemsize: int) -> Dict[str, object]:
    """The attribute payload every Exchange span carries -- the same
    byte walk the cost model uses, so observed spans and
    ``schedule_comm_bytes`` can never disagree."""
    return {
        "stage": "Exchange",
        "backend": st.backend,
        "role": st.role,
        "axis": st.axis,
        "p": st.p,
        "payload": st.payload,
        "fft": st.fft,
        "inverse": st.inverse,
        "fused": st.fused,
        "n_chunks": st.n_chunks,
        "block_bytes": exchange_block_bytes(st, real_itemsize, complex_itemsize),
        "wire_bytes": exchange_wire_bytes(st, real_itemsize, complex_itemsize),
    }


@functools.lru_cache(maxsize=512)
def _segment_executable(
    sched: Schedule, start: int, seg_len: int, impl: str, mesh: Mesh,
    in_spec: P, out_spec: P,
):
    """One jitted shard_map per trace segment, cached on the frozen
    schedule + boundary specs. Without this every traced execution
    rebuilds fresh closures, so jit's cache never hits and each
    ``Plan.profile`` rep re-pays tracing + compilation -- the observed
    spans would time the compiler, not the stage."""
    seg = sched.stages[start : start + seg_len]
    return jax.jit(shard_map(
        lambda xl: _execute_stages(xl, seg, impl=impl),
        mesh=mesh, in_specs=in_spec, out_specs=out_spec,
    ))


def _run_schedule_traced(
    x: jax.Array, sched: Schedule, mesh: Mesh, *, impl, trace, faults=None
) -> jax.Array:
    """Trace-mode executor: host-side segmentation with a wall-clock
    span per stage. Each segment is its own shard_map over the
    spec-simulated boundary shardings (no resharding between segments --
    :func:`simulate_specs` guarantees consecutive segments agree on the
    layout), and ``block_until_ready`` fences each span so durations
    measure that stage's work rather than dispatch latency. First
    execution of a segment pays its compile; profile with warmup reps
    (``Plan.profile`` does) for steady-state numbers."""
    r_item, c_item = _itemsizes(x)
    if sched.global_backend is not None:
        if faults is not None:
            faults.on_stage(f"global:{sched.kind}", index=0)
        with trace.span(
            f"global:{sched.kind}",
            cat="stage",
            stage="Global",
            backend=sched.global_backend,
            schedule=sched.schedule_hash(),
        ):
            out = _xla_reference(x, sched, mesh)
            jax.block_until_ready(out)
        return out
    bounds = simulate_specs(sched, x.ndim)
    v = x
    jax.block_until_ready(v)
    if sched.conj:
        with trace.span("Conj(in)", cat="stage", stage="Conj"):
            v = jnp.conj(v)
            jax.block_until_ready(v)
    for start, seg in _segments(sched):
        in_spec = P(*bounds[start])
        out_spec = P(*bounds[start + len(seg)])
        report = seg[-1]  # the Exchange of a Twiddle+Exchange pair
        if isinstance(report, Exchange):
            cat = "exchange"
            args = exchange_span_args(report, r_item, c_item)
            if len(seg) > 1:
                args["twiddle"] = True
        else:
            cat = "stage"
            args = {"stage": type(report).__name__}
        args["index"] = start + len(seg) - 1
        if faults is not None and isinstance(report, Exchange):
            # consult the chaos hook OUTSIDE the span: an injected raise
            # must not leave a half-open span in the recorder
            faults.on_stage(_stage_label(report), index=start + len(seg) - 1)
        fn = _segment_executable(sched, start, len(seg), impl, mesh, in_spec, out_spec)
        with trace.span(_stage_label(report), cat=cat, **args):
            v = fn(v)
            jax.block_until_ready(v)
    if sched.conj or sched.scale is not None:
        with trace.span("Epilogue(conj/scale)", cat="stage", stage="Epilogue"):
            if sched.conj:
                v = jnp.conj(v)
            if sched.scale is not None:
                v = v / sched.scale
            jax.block_until_ready(v)
    return v


# ---------------------------------------------------------------------------
# Pretty-printing (Plan.describe / benchmarks --explain)
# ---------------------------------------------------------------------------


def _stage_label(st) -> str:
    if isinstance(st, Exchange):
        bits = [f"{st.role}:{st.axis}", st.backend, f"p={st.p}"]
        if st.fft:
            bits.append("ifft" if st.inverse else "fft")
        if st.fused:
            bits.append("fused" + (f"@{st.n_chunks}" if st.n_chunks else ""))
        if st.payload != "complex":
            bits.append(st.payload)
        return f"Exchange({', '.join(bits)})"
    if isinstance(st, LocalFFT):
        return f"LocalFFT(axis={st.axis}{', inverse' if st.inverse else ''})"
    if isinstance(st, LocalR2C):
        return "LocalR2C()"
    if isinstance(st, LocalC2R):
        return f"LocalC2R(n={st.n_last})"
    if isinstance(st, HermitianPack):
        return f"HermitianPack(h={st.h}, hp={st.hp})"
    if isinstance(st, Trim):
        return f"Trim(h={st.h})"
    if isinstance(st, Relayout):
        d = f", dims={st.dims}" if st.dims else ""
        return f"Relayout({st.op}{d})"
    if isinstance(st, Twiddle):
        return f"Twiddle(n={st.n}, r={st.r}, c={st.c})"
    return repr(st)


def describe_schedule(
    sched: Schedule,
    *,
    params=None,
    chunk_compute_s: float = 0.0,
    real_itemsize: int = 8,
    complex_itemsize: int = 8,
) -> str:
    """Human-readable stage dump with per-stage predicted microseconds
    and wire bytes -- the per-stage observability hook. Local stages
    show '-' in the modeled columns (the alpha-beta model prices
    exchanges; local compute rides ``chunk_compute_s``)."""
    from repro.core import comm_model as cm

    prm = params or cm.CommParams()
    head = (
        f"schedule {sched.kind} [{sched.decomp}"
        f"{', r2c' if sched.real else ''}"
        f"{', inverse' if sched.inverse else ''}"
        f"{', transpose_back' if sched.transpose_back else ''}] "
        f"shape={sched.global_shape} hash={sched.schedule_hash()}"
    )
    lines = [head]
    if sched.global_backend is not None:
        lines.append(f"  (whole-transform reference backend: {sched.global_backend})")
    lines.append(f"  {'#':>2}  {'stage':<52} {'model us':>10} {'wire bytes':>12}")
    t_total = 0.0
    b_total = 0.0
    for i, st in enumerate(sched.stages):
        if isinstance(st, Exchange):
            t = stage_seconds(st, prm, chunk_compute_s, real_itemsize, complex_itemsize)
            b = exchange_wire_bytes(st, real_itemsize, complex_itemsize)
            t_total += t
            b_total += b
            lines.append(
                f"  {i:>2}  {_stage_label(st):<52} {t * 1e6:>10.2f} {b:>12.0f}"
            )
        else:
            lines.append(f"  {i:>2}  {_stage_label(st):<52} {'-':>10} {'-':>12}")
    lines.append(
        f"  total modeled exchange time {t_total * 1e6:.2f} us, "
        f"wire bytes/device {b_total:.0f}"
    )
    return "\n".join(lines)
