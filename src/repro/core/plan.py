"""FFTW-style plan objects for the distributed FFT.

The paper's FFTW3 reference works through plans; we mirror that UX: a
plan captures (global shape, mesh, shard axis, strategy, local impl),
pre-jits the transform, and exposes ``execute`` / ``inverse``. Plans are
also where the benchmark harness hangs its per-strategy measurements.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import distributed_fft as dfft
from repro.core.distributed_fft import FFTConfig


@dataclasses.dataclass(frozen=True)
class FFTPlan:
    global_shape: Tuple[int, ...]  # (..., R, C) for 2-D, (..., D0, D1, D2) for 3-D
    mesh: Mesh
    axis_name: str
    cfg: FFTConfig = FFTConfig()
    ndim_transform: int = 2  # 1, 2 or 3

    def __post_init__(self):
        p = self.mesh.shape[self.axis_name]
        if self.ndim_transform == 2:
            r, c = self.global_shape[-2:]
            if r % p or c % p:
                raise ValueError(f"2-D shape {(r, c)} not divisible by shards {p}")
        elif self.ndim_transform == 3:
            d0, d1, d2 = self.global_shape[-3:]
            if d0 % p or (d1 * d2) % p:
                raise ValueError(f"3-D shape {(d0, d1, d2)} not shardable by {p}")
        elif self.ndim_transform == 1:
            n = self.global_shape[-1]
            if n % (p * p):
                raise ValueError(f"1-D size {n} must be divisible by P^2={p*p}")
        else:
            raise ValueError("ndim_transform must be 1, 2 or 3")

    # -- sharding specs ------------------------------------------------------
    def input_sharding(self) -> NamedSharding:
        nd = len(self.global_shape)
        k = {1: 1, 2: 2, 3: 3}[self.ndim_transform]
        spec = [None] * nd
        spec[nd - k] = self.axis_name  # shard the leading transform dim
        return NamedSharding(self.mesh, P(*spec))

    def input_spec(self, dtype=jnp.complex64) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.global_shape, dtype, sharding=self.input_sharding())

    # -- execution -----------------------------------------------------------
    def _fn(self, inverse: bool):
        if self.ndim_transform == 2:
            return lambda x: dfft.fft2(x, self.mesh, self.axis_name, self.cfg, inverse=inverse)
        if self.ndim_transform == 3:
            return lambda x: dfft.fft3(x, self.mesh, self.axis_name, self.cfg, inverse=inverse)
        if inverse:
            raise NotImplementedError("1-D large inverse: conjugate externally")
        return lambda x: dfft.fft1d_large(x, self.mesh, self.axis_name, self.cfg)

    def execute(self, x: jax.Array) -> jax.Array:
        return self._fn(False)(x)

    def inverse(self, x: jax.Array) -> jax.Array:
        return self._fn(True)(x)

    def lower(self, inverse: bool = False):
        """Abstract lowering for dry-run / roofline (no allocation)."""
        return jax.jit(self._fn(inverse)).lower(self.input_spec())

    # -- napkin model ---------------------------------------------------------
    def comm_bytes(self) -> float:
        """Bytes each device ships per pencil exchange ((1-1/P) of local)."""
        import numpy as np

        p = self.mesh.shape[self.axis_name]
        local = np.prod(self.global_shape) * 8 / p  # c64
        return float(local * (1 - 1 / p))


def make_plan(
    global_shape: Tuple[int, ...],
    mesh: Mesh,
    *,
    axis_name: Optional[str] = None,
    strategy: str = "alltoall",
    local_impl: str = "jnp",
    fuse_dft: bool = False,
    transpose_back: bool = False,
    ndim_transform: int = 2,
) -> FFTPlan:
    from repro.core.sharding import fft_axis

    return FFTPlan(
        global_shape=tuple(global_shape),
        mesh=mesh,
        axis_name=axis_name or fft_axis(mesh),
        cfg=FFTConfig(
            strategy=strategy,
            local_impl=local_impl,  # type: ignore[arg-type]
            fuse_dft=fuse_dft,
            transpose_back=transpose_back,
        ),
        ndim_transform=ndim_transform,
    )
