"""FFTW-style plan/executor front-end over the collective-backend registry.

The paper's FFTW3 reference works through plans; this is the same UX for
the distributed transforms, rebuilt on :mod:`repro.core.backends`:

    plan = plan_fft((n, n), mesh, ndim=2, backend="auto")
    y = plan.execute(x)          # cached jitted executable
    x2 = plan.inverse(y)

A :class:`Plan`:

- validates the (global shape, mesh, shard axes, decomposition, backend)
  combination **once**, at construction -- including shard-divisibility,
  so a bad shape fails here naming the offending data axis and mesh/grid
  dimension instead of deep inside the transpose chunking;
- resolves the decomposition: ``decomp="slab"`` (one mesh axis, the
  paper's layout), ``"pencil"`` (a 2-D
  :class:`~repro.core.grid.ProcessGrid`, sub-axis exchanges with
  independently selected per-axis backends), or ``"auto"`` (pencil
  whenever the mesh offers a valid 2-D grid, else slab);
- resolves ``backend="auto"`` to the alpha-beta cost-model argmin --
  over every registered backend supporting the shard count (slab), or
  per grid axis via :func:`repro.core.backends.cheapest_pair` (pencil;
  pass a ``(backend_row, backend_col)`` tuple to pin the pair);
- caches one jitted executable per (direction, dtype), so repeated
  ``execute`` calls never re-trace or re-compile;
- exposes ``lower``/``roofline`` for dry-run analysis of the compiled
  communication schedule.

``FFTPlan``/``make_plan`` remain as deprecation shims for one release.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.core.schedule as sch
from repro.core import backends
from repro.core import comm_model as cm
from repro.core.distributed_fft import FFTConfig

#: Pair-key separator for pencil backend pairs ("scatter+bisection") --
#: registry names are identifiers, so '+' cannot appear inside one.
PAIR_SEP = "+"

_DTYPE_PARTNERS = {
    "float32": "complex64", "complex64": "float32",
    "float64": "complex128", "complex128": "float64",
}


def real_complex_pair(dtype) -> Tuple[jnp.dtype, jnp.dtype]:
    """The (real, complex) dtype pair containing ``dtype`` -- the single
    copy of the r2c dtype mapping (plan validation and byte accounting
    must agree on it). Raises for dtypes with no real/complex partner."""
    d = jnp.dtype(dtype)
    partner = _DTYPE_PARTNERS.get(d.name)
    if partner is None:
        raise ValueError(
            f"no real/complex dtype pair for {d.name}; real plans support "
            f"{sorted(n for n in _DTYPE_PARTNERS if not n.startswith('c'))}"
        )
    return (jnp.dtype(partner), d) if d.kind == "c" else (d, jnp.dtype(partner))


def pair_key(backend_row: str, backend_col: str) -> str:
    return f"{backend_row}{PAIR_SEP}{backend_col}"


def pipeline_is_default(pipeline) -> bool:
    """Whether a ``pipeline=`` value is the default ("auto") setting.
    Identity-checked for True/None: ``1 == True`` in Python, but
    ``pipeline=1`` is an explicit one-chunk request, not the default."""
    return pipeline == "auto" or pipeline is True or pipeline is None


def _warn_real_fuse_dft() -> bool:
    """The old hard error ("real transforms have no fused path") is dead:
    the pipelined overlap executor IS that path, and it is on by default
    wherever a streaming backend is selected. One warning, attributed to
    the caller of whichever entry point (plan_fft / Plan) saw the flag
    (stacklevel: helper -> entry point -> caller). Returns the
    replacement fuse_dft value."""
    warnings.warn(
        "fuse_dft on real plans is deprecated and ignored: r2c/c2r "
        "chains fuse streaming exchanges by default -- control it "
        "with plan_fft(..., pipeline=...)",
        DeprecationWarning,
        stacklevel=3,
    )
    return False


class SpectralAxis(NamedTuple):
    """One output axis of a plan's frequency-domain (spectrum) layout.

    ``orig`` is the original data axis it carries (negative index into
    the trailing transform dims), ``n`` that axis's real/complex global
    length, ``n_out`` the length in the spectrum layout (``rfft_len(n)``
    or its shard-padded version for the Hermitian axis of a real plan,
    ``n`` otherwise), and ``half`` whether the axis is
    Hermitian-truncated. The apps layer builds wavenumber grids from
    this -- see :func:`repro.apps.spectral.wavenumbers`."""

    orig: int
    n: int
    n_out: int
    half: bool


def split_pair(key) -> Tuple[str, str]:
    """(row, col) from a pair key, a 2-tuple/list, or a single name
    (applied to both axes)."""
    if isinstance(key, (tuple, list)):
        if len(key) != 2:
            raise ValueError(f"pencil backend pair must have 2 entries, got {key!r}")
        return str(key[0]), str(key[1])
    if PAIR_SEP in key:
        row, _, col = key.partition(PAIR_SEP)
        return row, col
    return key, key


class Plan:
    """A validated, backend-resolved, executable-caching FFT plan.

    Construct through :func:`plan_fft`. ``direction`` fixes what
    ``execute`` computes ("forward" or "inverse"); ``inverse`` always
    computes the opposite of ``execute``.

    Slab plans (``decomp="slab"``) expose ``backend`` (one registry
    name); pencil plans expose ``backend_row``/``backend_col`` plus
    ``backend`` as the combined ``"row+col"`` pair key, and ``grid``
    (the resolved :class:`~repro.core.grid.ProcessGrid`).

    Partial surface: the 1-D large transform has no inverse -- planning
    ``ndim=1, direction="inverse"`` is rejected at construction, and
    calling ``inverse()`` on a forward 1-D plan raises
    ``NotImplementedError`` before anything executes (conjugate
    externally instead). Pencil supports ndim 2 and 3.
    """

    def __init__(
        self,
        global_shape: Tuple[int, ...],
        mesh: Mesh,
        *,
        ndim: int = 2,
        direction: str = "forward",
        backend: str = "auto",
        axis_name: Optional[str] = None,
        local_impl: str = "jnp",
        fuse_dft: bool = False,
        transpose_back: bool = False,
        dtype=jnp.complex64,
        params: Optional[cm.CommParams] = None,
        chunk_compute_s: float = 0.0,
        decomp: str = "slab",
        row_axis: Optional[str] = None,
        col_axis: Optional[str] = None,
        real: bool = False,
        pad: bool = True,
        pipeline="auto",
    ):
        from repro.core.sharding import fft_axis

        if ndim not in (1, 2, 3):
            raise ValueError("ndim must be 1, 2 or 3")
        if direction not in ("forward", "inverse"):
            raise ValueError(f"direction must be 'forward' or 'inverse', got {direction!r}")
        if decomp not in ("slab", "pencil", "auto"):
            raise ValueError(f"decomp must be 'slab', 'pencil' or 'auto', got {decomp!r}")
        if real and ndim == 1:
            raise NotImplementedError(
                "1-D real transform is not implemented: complexify and use ndim=1 c2c"
            )
        if real and fuse_dft:
            fuse_dft = _warn_real_fuse_dft()
        if isinstance(backend, str) and "@" in backend:
            # measured-planner candidate ids ("scatter@u", "scatter@f16",
            # Plan.backend of a variant winner) are valid backend specs:
            # the suffix is a pipeline override, so backend=plan.backend
            # always round-trips
            from repro.core.planner import parse_variant

            backend, pipe_override = parse_variant(backend)
            if not pipeline_is_default(pipeline):
                raise ValueError(
                    f"backend variant suffix and pipeline={pipeline!r} "
                    f"both specify the pipeline; pass one or the other"
                )
            pipeline = pipe_override
        if not (
            pipeline in ("auto", True, False, None)
            or (isinstance(pipeline, int) and not isinstance(pipeline, bool) and pipeline >= 0)
        ):
            raise ValueError(
                f"pipeline must be 'auto', True/False, or a chunk-count int "
                f">= 0, got {pipeline!r}"
            )
        if ndim == 1 and direction == "inverse":
            # fail at plan time, not first execute (validate-once contract)
            raise NotImplementedError(
                "1-D large inverse is not implemented: plan forward and conjugate externally"
            )
        if (row_axis is None) != (col_axis is None):
            raise ValueError("pass both row_axis and col_axis, or neither")
        self.global_shape = tuple(global_shape)
        self.mesh = mesh
        self.axis_name = axis_name or fft_axis(mesh)
        self.ndim = ndim
        self.direction = direction
        self.real = bool(real)
        self.pad = bool(pad)
        self.dtype = jnp.dtype(dtype)
        if self.real:
            # a real plan's dtype is the REAL input dtype; the matching
            # complex dtype (the spectrum side) is derived. Passing the
            # complex default through plan_fft maps to its real partner.
            try:
                self.dtype, self.cdtype = real_complex_pair(self.dtype)
            except ValueError:
                raise ValueError(
                    f"real plans take a real input dtype (float32/float64), "
                    f"got {self.dtype.name}"
                ) from None
        else:
            self.cdtype = self.dtype
        self.hermitian_len: Optional[int] = None
        self.padded_hermitian_len: Optional[int] = None
        self.local_impl = local_impl
        self.fuse_dft = fuse_dft
        self.transpose_back = transpose_back
        if params is None:
            # default-on persisted calibration: when this fabric's
            # alpha/beta have been fitted (CommParams.calibrate via
            # planner.ensure_calibrated, refine_online, or an imported
            # wisdom file's calibration section), every default-params
            # plan prices with the measured constants
            from repro.core import planner as _planner

            params = _planner.calibration_for(_planner.device_kind(mesh))
        self.params = params or cm.CommParams()
        self.chunk_compute_s = chunk_compute_s
        self.pipeline = "auto" if (pipeline is True or pipeline is None) else pipeline
        #: resolved by _resolve_pipeline once the backend(s) are known
        self.fused: bool = False
        self.n_chunks: Optional[int] = None
        # set by the measured planner (repro.core.planner.plan_measured)
        self.planner = "estimate"
        self.measured: Optional[Dict[str, float]] = None
        #: candidate id -> "ExcType: msg" for candidates that raised
        #: mid-race (recorded as inf, excluded from the argmin)
        self.race_failures: Dict[str, str] = {}
        self.wisdom_hit = False
        self.wisdom_key: Optional[str] = None
        #: chaos hook (repro.runtime.faults.FaultPlan). While armed,
        #: execute/inverse run the segmented chaos executor so the plan
        #: consults it before every Exchange; once exhausted (or None)
        #: the cached jitted executables run untouched.
        self.faults = None
        #: decision provenance: which channel picked this plan's backend
        #: -- "pinned" (caller named it), "model-argmin" (alpha-beta
        #: auto), or -- overwritten by plan_measured -- "measured-race" /
        #: "wisdom-hit" / "observed-overlay". Rendered by :meth:`why`.
        self.selection_channel = "pinned"
        #: direction -> lowered stage schedule (the single pipeline truth
        #: that execution, the cost model and the byte accounting share);
        #: cleared whenever the decomposition/backends are (re)resolved
        self._schedules: Dict[bool, sch.Schedule] = {}

        self.grid = None
        if decomp == "slab":
            if row_axis is not None or col_axis is not None:
                raise ValueError("row_axis/col_axis apply to decomp='pencil' (or 'auto') only")
            self.decomp = "slab"
            self._init_slab(backend)
        elif decomp == "pencil":
            self.decomp = "pencil"
            self._init_pencil(backend, row_axis, col_axis)
        else:
            # auto: pencil when the WHOLE pencil plan validates (grid,
            # divisibility, per-axis backends), else slab -- a pinned
            # backend that only works under one decomposition steers the
            # choice instead of erroring
            if row_axis is not None:
                # explicitly configured grid axes are a user argument,
                # not an infeasibility signal: bad names must raise, not
                # silently fall back to slab
                from repro.core import grid as _grid

                _grid.grid_from_mesh(mesh, row_axis, col_axis)
            pencil_err: Optional[ValueError] = None
            if ndim in (2, 3) and not fuse_dft and not (ndim == 2 and transpose_back):
                try:
                    self.decomp = "pencil"
                    self._init_pencil(backend, row_axis, col_axis)
                except ValueError as e:
                    pencil_err = e
                    self.grid = None
                    self.decomp = None
            else:
                self.decomp = None
            if self.decomp == "pencil":
                # cost-aware tie-break: a structurally-valid pencil grid
                # can still lose to slab (a degenerate (P,1) grid doubles
                # the fft2 exchanges over the same ring). Adopt slab when
                # it keeps at least the same parallelism and its resolved
                # backend predicts cheaper than the pencil pair. The
                # trial shards over the larger of fft_axis and the grid
                # axes -- fft_axis's last-axis fallback would otherwise
                # pick a size-1 axis on e.g. a (P,1) ("rows","cols") mesh
                # and lose the comparison to a phantom parallelism gap
                trial_ax = axis_name
                if trial_ax is None:
                    candidates = (fft_axis(mesh), self.grid.row_axis, self.grid.col_axis)
                    trial_ax = max(candidates, key=lambda a: mesh.shape[a])
                try:
                    trial = Plan(
                        global_shape, mesh, ndim=ndim, direction=direction,
                        backend=backend, axis_name=trial_ax, local_impl=local_impl,
                        fuse_dft=fuse_dft, transpose_back=transpose_back, dtype=dtype,
                        params=params, chunk_compute_s=chunk_compute_s, decomp="slab",
                        real=real, pad=pad, pipeline=self.pipeline,
                    )
                except (ValueError, NotImplementedError):
                    trial = None
                if (
                    trial is not None
                    and trial.shards >= self.shards
                    and trial.predict()[trial.backend] < self.predict()[self.backend]
                ):
                    self.grid = None
                    self.axis_name = trial_ax
                    self.decomp = "slab"
                    self._init_slab(backend)
            if self.decomp is None:
                self.decomp = "slab"
                try:
                    self._init_slab(backend)
                except ValueError as e:
                    if pencil_err is not None:
                        raise ValueError(
                            f"decomp='auto': neither decomposition fits this "
                            f"problem -- pencil: {pencil_err} -- slab: {e}"
                        ) from e
                    raise
        self._cache: Dict[Tuple[str, str], jax.stages.Wrapped] = {}
        self.compiles = 0  # jit wrappers created (not per-shape recompiles)

    # -- pipelined overlap resolution -------------------------------------------
    def _pipeline_enabled(self) -> bool:
        """Whether ``pipeline=`` allows fusing at all (off only when the
        caller passed False/0)."""
        return self.pipeline not in (False, 0)

    def _pipeline_n_chunks(self) -> Optional[int]:
        if isinstance(self.pipeline, int) and not isinstance(self.pipeline, bool):
            return int(self.pipeline) if self.pipeline > 0 else None
        return None

    def _resolve_pipeline(self) -> None:
        """Resolve ``pipeline=`` against the selected backend(s): fused
        execution wherever a chunk-streaming backend rides a >1-shard
        ring (pencil legs resolve independently inside the transforms --
        ``fused`` here records whether ANY leg fuses, which is what the
        cost model overlaps)."""
        self.n_chunks = self._pipeline_n_chunks()
        if not self._pipeline_enabled():
            # explicit pipeline=False wins over the legacy fuse_dft alias
            # too (the config layer gets fuse_dft=False below), so one
            # knob disables fusion everywhere
            self.fused = False
            return
        if self.decomp == "pencil":
            legs = (
                (self.backend_row, self.grid.p_rows),
                (self.backend_col, self.grid.p_cols),
            )
            self.fused = any(
                backends.get(b).supports_chunk_fn and p > 1 for b, p in legs
            )
        else:
            b = self.backend_obj
            self.fused = bool(
                b.kind == "shard_map" and b.supports_chunk_fn and self.shards > 1
            )

    def _auto_chunk_compute_s(self, dtype=None) -> float:
        """Per-peer-chunk seconds of the fused stage's compute: the
        caller's ``chunk_compute_s`` when given, else a memory-bound
        napkin -- each arriving chunk's outer-product contribution
        writes one local block's worth of accumulator
        (``_cost_bytes / HBM_BW``). This is what lets ``predict()`` and
        ``backend='auto'`` price fused (overlapped) against unfused
        (serialized) stage compute without the user measuring anything.
        Zero when no exchange ring exceeds one shard -- there is no
        exchange to fuse into, and charging phantom per-chunk compute
        would skew degenerate-grid decomp='auto' comparisons."""
        if self.chunk_compute_s:
            return self.chunk_compute_s
        rings = (
            max(self.grid.p_rows, self.grid.p_cols)
            if self.decomp == "pencil"
            else self.shards
        )
        if rings <= 1:
            return 0.0
        return self._cost_bytes(dtype) / cm.HBM_BW

    def _init_slab(self, backend: str) -> None:
        self._schedules.clear()
        p = self.shards
        shape, ax = self.global_shape, self.axis_name
        if self.real:
            self.hermitian_len, self.padded_hermitian_len = sch.check_divisible(
                shape, self.ndim, p=p, axis_name=ax, real=True, pad=self.pad
            )
        else:
            sch.check_divisible(shape, self.ndim, p=p, axis_name=ax)

        if not isinstance(backend, str) or PAIR_SEP in backend:
            raise ValueError(
                f"slab plans take one backend name, got {backend!r} "
                f"(per-axis pairs are decomp='pencil')"
            )
        if backend == "auto":
            self.selection_channel = "model-argmin"
            backend = "scatter" if self.fuse_dft else backends.cheapest(
                self._cost_bytes(), p, self.params,
                chunk_compute_s=self._auto_chunk_compute_s(),
                n_chunks=self._pipeline_n_chunks(),
                fused=self._pipeline_enabled(),
            )
        self.backend_obj = backends.get(backend)  # raises listing the registry
        self.backend = backend
        self.backend_row = self.backend_col = None
        if not self.backend_obj.supports(p):
            raise ValueError(f"backend {backend!r} does not support P={p}")
        if self.fuse_dft and not self.backend_obj.supports_chunk_fn:
            raise ValueError(
                f"fuse_dft requires a chunk-streaming backend (got "
                f"{backend!r}; streaming: "
                f"{[b for b in backends.available() if backends.get(b).supports_chunk_fn]})"
            )
        self._resolve_pipeline()

        self._cfg = FFTConfig(
            strategy=backend,
            local_impl=self.local_impl,  # type: ignore[arg-type]
            # pipeline=False disables the legacy alias at the config
            # layer too, so the plan's fused flag IS the execution truth
            fuse_dft=self.fuse_dft and self._pipeline_enabled(),
            transpose_back=self.transpose_back,
            fused=self.fused,
            n_chunks=self.n_chunks,
        )

    def _init_pencil(self, backend, row_axis: Optional[str], col_axis: Optional[str]) -> None:
        from repro.core import grid as _grid
        from repro.core import pencil as _pencil

        if self.ndim == 1:
            raise ValueError("pencil decomposition supports ndim 2 or 3 (1-D is slab-only)")
        if self.fuse_dft:
            raise ValueError("fuse_dft is a slab scatter-only feature; use decomp='slab'")
        if self.ndim == 2 and self.transpose_back:
            raise ValueError(
                "pencil fft2 already returns the natural layout; "
                "transpose_back applies to slab plans and pencil fft3 only"
            )
        self._schedules.clear()
        self.grid = _grid.grid_from_mesh(self.mesh, row_axis, col_axis)
        g = self.grid
        if self.real:
            self.hermitian_len, self.padded_hermitian_len = sch.check_divisible(
                self.global_shape, self.ndim, p_rows=g.p_rows, p_cols=g.p_cols,
                row_axis=g.row_axis, col_axis=g.col_axis, real=True, pad=self.pad,
            )
        else:
            sch.check_divisible(
                self.global_shape, self.ndim, p_rows=g.p_rows, p_cols=g.p_cols,
                row_axis=g.row_axis, col_axis=g.col_axis,
            )

        if backend == "auto":
            self.selection_channel = "model-argmin"
            br, bc = backends.cheapest_pair(
                self._cost_bytes(),
                self.grid.p_rows,
                self.grid.p_cols,
                self.params,
                chunk_compute_s=self._auto_chunk_compute_s(),
                n_chunks=self._pipeline_n_chunks(),
                fused=self._pipeline_enabled(),
            )
        else:
            br, bc = split_pair(backend)
        self.backend_row, self.backend_col = br, bc
        self.backend = pair_key(br, bc)
        self.backend_obj = None  # per-axis backends; see backend_row/col
        self._resolve_pipeline()
        self._cfg = _pencil.PencilConfig(
            backend_row=br,
            backend_col=bc,
            local_impl=self.local_impl,  # type: ignore[arg-type]
            transpose_back=self.transpose_back,
            fused=self.fused,
            n_chunks=self.n_chunks,
        )
        _pencil._check_backends(self._cfg, self.grid)  # raises naming the axis

    # -- geometry --------------------------------------------------------------
    @property
    def shards(self) -> int:
        if self.decomp == "pencil":
            return self.grid.size
        return self.mesh.shape[self.axis_name]

    def local_bytes(self, dtype=None) -> float:
        """Bytes of one device's local block of the input (the real
        block, for a real plan)."""
        itemsize = self._dtype_pair(dtype)[0].itemsize if self.real else jnp.dtype(
            dtype or self.dtype
        ).itemsize
        return float(np.prod(self.global_shape)) * itemsize / self.shards

    def _dtype_pair(self, dtype=None) -> Tuple[jnp.dtype, jnp.dtype]:
        """(real, complex) dtype pair for a byte query: either side of
        the pair may be passed; None means the plan's own."""
        if dtype is None:
            return self.dtype, self.cdtype
        return real_complex_pair(dtype)

    def _cost_bytes(self, dtype=None) -> float:
        """Per-device block bytes the exchanges actually move -- the
        input block for c2c plans, the Hermitian-truncated (shard-padded)
        complex block for real plans. This is what feeds the alpha-beta
        costs and ``backend='auto'``."""
        if not self.real:
            return self.local_bytes(dtype)
        citem = self._dtype_pair(dtype)[1].itemsize
        elems = float(np.prod(self.global_shape[:-1])) * self.padded_hermitian_len
        return elems * citem / self.shards

    def _byte_sizes(self, dtype=None) -> Tuple[int, int]:
        """(real_itemsize, complex_itemsize) a byte/cost query prices the
        schedule's Exchange payloads with; either side of the r2c pair
        may be passed, None means the plan's own dtypes."""
        if self.real:
            r, c = self._dtype_pair(dtype)
            return r.itemsize, c.itemsize
        item = jnp.dtype(dtype or self.dtype).itemsize
        return item, item

    def comm_bytes(self, dtype=None) -> float:
        """Total bytes each device ships over the fabric per transform,
        summed over every Exchange stage of the plan's own schedule --
        each exchange re-shards its block over its ring (P for slab,
        P_row/P_col per sub-exchange for pencil), shipping (1-1/P_ring)
        of it. Same units under both decompositions, so slab-vs-pencil
        comparisons are direct.

        Real plans count the Hermitian payload: every complex exchange
        moves the truncated ``Hp`` block (~half the c2c bytes at the
        same shape); the pencil rfft2's first cols exchange moves the
        full-width block at the *real* dtype (also half). The c2r
        inverse mirrors the chain, so the total is direction-agnostic."""
        r_item, c_item = self._byte_sizes(dtype)
        return sch.schedule_comm_bytes(self.schedule(), r_item, c_item)

    # -- cost model ------------------------------------------------------------
    def predict(
        self,
        dtype=None,
        chunk_compute_s: Optional[float] = None,
        *,
        fused: Optional[bool] = None,
        n_chunks: Optional[int] = None,
    ) -> Dict[str, float]:
        """Alpha-beta predicted seconds per backend for this problem.

        Slab: ``n_exchanges * backend.cost(local_bytes, P, params,
        chunk_compute_s)`` for every registered backend that supports
        this shard count. Pencil: one entry per ``"row+col"`` pair of
        shard_map backends, each axis costed at its own sub-ring size
        (P_row / P_col) by :func:`repro.core.comm_model.t_pencil` --
        see :meth:`predict_axes` for the per-axis decomposition.

        ``chunk_compute_s`` (default: the plan's own, else the
        memory-bound stage estimate) is per-chunk compute;
        ``fused``/``n_chunks`` (default: the plan's own resolution)
        report the fused vs unfused variants of the same problem:
        ``fused=True`` overlaps the stage compute on streaming backends,
        ``fused=False`` serializes it everywhere (the monolithic
        discipline), so ``predict(fused=True)`` vs ``predict(fused=False)``
        is the modeled overlap win. Uses the plan's ``params`` -- pass a
        calibrated :meth:`~repro.core.comm_model.CommParams.calibrate`
        result at plan time for measured (rather than v5e napkin)
        constants."""
        fused = self.fused if fused is None else fused
        n_chunks = self.n_chunks if n_chunks is None else n_chunks
        if self.decomp == "pencil":
            row_costs, col_costs = self.predict_axes(
                dtype, chunk_compute_s, fused=fused, n_chunks=n_chunks
            )
            return {
                pair_key(r, c): row_costs[r] + col_costs[c]
                for r in row_costs
                for c in col_costs
            }
        cc = self._auto_chunk_compute_s(dtype) if chunk_compute_s is None else chunk_compute_s
        r_item, c_item = self._byte_sizes(dtype)
        base = sch.with_pipeline(self.schedule(), fused, n_chunks)
        p = self.shards
        out = {}
        for name in backends.available():
            if backends.get(name).supports(p):
                out[name] = sch.predict_seconds(
                    sch.with_backends(base, slab=name),
                    self.params, cc, r_item, c_item,
                )
        return out

    def predict_axes(
        self,
        dtype=None,
        chunk_compute_s: Optional[float] = None,
        *,
        fused: Optional[bool] = None,
        n_chunks: Optional[int] = None,
    ) -> Tuple[Dict[str, float], Dict[str, float]]:
        """Pencil only: (row_costs, col_costs) -- per-backend predicted
        seconds of all of this transform's exchanges over that grid axis,
        each at its own sub-ring size. ``predict()[f"{r}+{c}"] ==
        row_costs[r] + col_costs[c]`` by construction. ``fused`` /
        ``n_chunks`` as in :meth:`predict` (per-leg: a streaming backend
        overlaps its own axis's stage compute independently)."""
        if self.decomp != "pencil":
            raise ValueError("predict_axes is a pencil-plan method; use predict()")
        fused = self.fused if fused is None else fused
        n_chunks = self.n_chunks if n_chunks is None else n_chunks
        cc = self._auto_chunk_compute_s(dtype) if chunk_compute_s is None else chunk_compute_s
        r_item, c_item = self._byte_sizes(dtype)
        base = sch.with_pipeline(self.schedule(), fused, n_chunks)
        out = []
        for role, p_axis in (("row", self.grid.p_rows), ("col", self.grid.p_cols)):
            out.append({
                name: sch.predict_seconds(
                    sch.with_backends(base, **{role: name}),
                    self.params, cc, r_item, c_item, role,
                )
                for name in backends.supporting(p_axis, kind="shard_map")
            })
        return out[0], out[1]

    # -- sharding specs --------------------------------------------------------
    def _opposite_reverses_layout(self) -> bool:
        """Whether the opposite direction consumes the reversed-axes
        pencil layout (3-D pencil without transpose_back: the forward
        output is fftn reversed, sharded (cols, rows))."""
        return self.decomp == "pencil" and self.ndim == 3 and not self.transpose_back

    def _spectrum_side(self, opposite: bool) -> bool:
        """Real plans only: whether the (possibly opposite) direction's
        input is the half spectrum (the c2r side) rather than the real
        array."""
        return (self.direction == "inverse") != opposite

    def spectral_axes(self) -> Tuple[SpectralAxis, ...]:
        """The plan's frequency-domain layout: one :class:`SpectralAxis`
        per trailing output dim of the forward transform (equivalently,
        per trailing input dim of the inverse), in output order. Works
        for c2c and real plans -- the apps layer keys off it."""
        nd = self.ndim
        dims = self.global_shape[-nd:]
        natural = list(range(-nd, 0))
        if self.decomp == "pencil":
            order = natural if (nd == 2 or self.transpose_back) else natural[::-1]
        else:
            order = [-1, -2] if (nd == 2 and not self.transpose_back) else natural
        # output dims the decomposition keeps sharded: the Hermitian axis
        # must stay padded there (trimming would break divisibility)
        sharded = {0, 1} if self.decomp == "pencil" else ({0} if nd > 1 else set())
        axes = []
        for pos, orig in enumerate(order):
            n = dims[orig]
            half = self.real and orig == -1
            if half:
                n_out = self.padded_hermitian_len if pos in sharded else self.hermitian_len
            else:
                n_out = n
            axes.append(SpectralAxis(orig, n, n_out, half))
        return tuple(axes)

    def spectrum_shape(self) -> Tuple[int, ...]:
        """Global shape of the frequency-domain array (forward output /
        inverse input), batch dims included."""
        return self.global_shape[: -self.ndim] + tuple(a.n_out for a in self.spectral_axes())

    def input_sharding(self, opposite: bool = False) -> NamedSharding:
        """Sharding of the planned direction's input; ``opposite=True``
        gives the opposite direction's input (differs only when that
        direction consumes the reversed-axes pencil layout)."""
        nd = len(self.global_shape)
        spec = [None] * nd
        if self.decomp == "pencil":
            # shard the two leading transform dims over the grid; the
            # reversed layout arrives sharded (cols, rows)
            row, col = self.grid.row_axis, self.grid.col_axis
            if self.real:
                reversed_spectrum = self.ndim == 3 and not self.transpose_back
                if self._spectrum_side(opposite) and reversed_spectrum:
                    row, col = col, row
            elif opposite and self._opposite_reverses_layout():
                row, col = col, row
            spec[nd - self.ndim] = row
            spec[nd - self.ndim + 1] = col
        else:
            spec[nd - self.ndim] = self.axis_name  # shard the leading transform dim
        return NamedSharding(self.mesh, P(*spec))

    def input_spec(self, dtype=None, opposite: bool = False) -> jax.ShapeDtypeStruct:
        shape = self.global_shape
        if self.real:
            if self._spectrum_side(opposite):
                shape = self.spectrum_shape()
                dt = dtype or self.cdtype
            else:
                dt = dtype or self.dtype
            return jax.ShapeDtypeStruct(shape, dt, sharding=self.input_sharding(opposite))
        if opposite and self._opposite_reverses_layout():
            shape = shape[:-3] + tuple(reversed(shape[-3:]))
        return jax.ShapeDtypeStruct(
            shape, dtype or self.dtype, sharding=self.input_sharding(opposite)
        )

    # -- the stage schedule (the single pipeline truth) ------------------------
    def schedule(self, inverse: Optional[bool] = None) -> sch.Schedule:
        """The stage schedule the given direction executes (None: the
        planned direction) -- the declarative pipeline IR
        (:class:`repro.core.schedule.Schedule`) that ``execute`` runs,
        :meth:`predict`/:meth:`comm_bytes` walk, and the planner
        rewrites. Built once per direction and cached."""
        inv = (self.direction == "inverse") if inverse is None else bool(inverse)
        cached = self._schedules.get(inv)
        if cached is not None:
            return cached
        if self.ndim == 1 and inv:
            raise NotImplementedError("1-D large inverse: conjugate externally")
        if self.decomp == "pencil":
            grid, shape = self.grid, self.global_shape
            row, col = grid.row_axis, grid.col_axis
            pr, pc = grid.p_rows, grid.p_cols
            br, bc = self.backend_row, self.backend_col
            opposite = inv != (self.direction == "inverse")
            if not self.real and opposite and self._opposite_reverses_layout():
                # the opposite direction consumes the reversed-axes
                # output, sharded (cols, rows): swap the grid roles (and
                # the per-axis backends with them) so the transform
                # reads that sharding directly -- no hidden reshard, and
                # the forward divisibility constraints already imply the
                # reversed ones, so round trips always plan. (Real plans
                # never swap: each irfft consumes exactly the layout its
                # rfft produces -- an explicit reverse chain.)
                shape = shape[:-3] + tuple(reversed(shape[-3:]))
                row, col, pr, pc, br, bc = col, row, pc, pr, bc, br
            built = sch.build_schedule(
                shape, ndim=self.ndim, inverse=inv, real=self.real,
                decomp="pencil", row_axis=row, col_axis=col,
                p_rows=pr, p_cols=pc, backend_row=br, backend_col=bc,
                fused=self.fused, n_chunks=self.n_chunks,
                transpose_back=self.transpose_back, pad=self.pad,
            )
        else:
            built = sch.build_schedule(
                self.global_shape, ndim=self.ndim, inverse=inv,
                real=self.real, decomp="slab", axis_name=self.axis_name,
                # _cfg.strategy, not self.backend: a measured variant
                # winner reports its candidate id ("scatter@u") on
                # .backend, but the schedule carries the base name
                p=self.shards, backend=self._cfg.strategy,
                fused=self.fused or self._cfg.fuse_dft,
                n_chunks=self.n_chunks,
                transpose_back=self.transpose_back, pad=self.pad,
            )
        self._schedules[inv] = built
        return built

    def schedule_hash(self, inverse: Optional[bool] = None) -> str:
        """Content hash of the direction's stage schedule: two plans with
        equal hashes execute the same pipeline (serve pools record it)."""
        return self.schedule(inverse).schedule_hash()

    def predict_stages(self, inverse: Optional[bool] = None, dtype=None):
        """Per-stage cost decomposition: ``[(Exchange, predicted seconds,
        wire bytes), ...]`` over the direction's schedule at the plan's
        own backends and pipeline. The seconds sum to
        ``predict()[self.backend]`` and the bytes to :meth:`comm_bytes`
        -- the invariant the schedule tests pin."""
        r_item, c_item = self._byte_sizes(dtype)
        cc = self._auto_chunk_compute_s(dtype)
        base = sch.with_pipeline(self.schedule(inverse), self.fused, self.n_chunks)
        return [
            (
                st,
                sch.stage_seconds(st, self.params, cc, r_item, c_item),
                sch.exchange_wire_bytes(st, r_item, c_item),
            )
            for st in base.exchanges()
        ]

    def describe(self, inverse: Optional[bool] = None, dtype=None) -> str:
        """Human-readable stage dump of the direction's schedule with
        per-stage predicted microseconds and wire bytes (the
        observability hook; also ``benchmarks/run.py --explain``)."""
        r_item, c_item = self._byte_sizes(dtype)
        return self.schedule(inverse).describe(
            params=self.params,
            chunk_compute_s=self._auto_chunk_compute_s(dtype),
            real_itemsize=r_item,
            complex_itemsize=c_item,
        )

    def why(self) -> dict:
        """Decision provenance: *why this backend won* -- the selection
        channel (``pinned`` / ``model-argmin`` / ``measured-race`` /
        ``wisdom-hit`` / ``observed-overlay``), the timing table the
        decision argmin'd over (measured seconds for a measured plan,
        alpha-beta model seconds otherwise), the wisdom key consulted,
        and the calibration constants in force (with whether they are
        fitted fabric constants or the module defaults). Rendered by
        :meth:`why_text`; dumped by ``benchmarks/run.py --explain``;
        aggregated as gauges in serve ``metrics()``."""
        from repro.core import planner as _planner

        if self.planner == "measure" and self.measured:
            # failed candidates carry timing inf -- keep them out of the
            # table and the argmin; they are reported under "failed"
            timings = {
                k: float(v)
                for k, v in self.measured.items()
                if isinstance(v, (int, float)) and math.isfinite(v)
            }
            timings_kind = "measured"
        else:
            timings = {k: float(v) for k, v in self.predict().items()}
            timings_kind = "model"
        argmin = min(sorted(timings), key=timings.__getitem__) if timings else None
        dev = _planner.device_kind(self.mesh)
        cell = _planner.calibration_cell(dev)
        return {
            "channel": self.selection_channel,
            "backend": self.backend,
            "decomp": self.decomp,
            "planner": self.planner,
            "fused": self.fused,
            "n_chunks": self.n_chunks,
            "timings_kind": timings_kind,
            "timings": timings,
            "argmin": argmin,
            "failed": dict(self.race_failures),
            "wisdom_key": self.wisdom_key,
            "wisdom_hit": self.wisdom_hit,
            "calibration": {
                "device_kind": dev,
                "alpha_s": float(self.params.alpha_s),
                "beta_bytes_s": float(self.params.beta_bytes_s),
                "source": (cell or {}).get("source", "default"),
                "calibrated": cell is not None,
            },
        }

    def why_text(self) -> str:
        """One-paragraph rendering of :meth:`why` (the ``--explain``
        format): channel, winner, the top of the timing table, and the
        calibration constants in force."""
        w = self.why()
        cal = w["calibration"]
        unit = 1e6  # report microseconds either way
        table = sorted(w["timings"].items(), key=lambda kv: kv[1])
        shown = ", ".join(f"{k}={v * unit:.1f}us" for k, v in table[:4])
        if len(table) > 4:
            shown += f", ... ({len(table) - 4} more)"
        lines = [
            f"why: backend={w['backend']} via {w['channel']} "
            f"(decomp={w['decomp']}, planner={w['planner']})",
            f"  {w['timings_kind']} table argmin={w['argmin']}: {shown}"
            if table
            else "  (no timing table)",
            f"  calibration[{cal['device_kind']}]: alpha={cal['alpha_s'] * 1e6:.2f}us "
            f"beta={cal['beta_bytes_s'] / 1e9:.1f}GB/s "
            f"({cal['source'] if cal['calibrated'] else 'default'})",
        ]
        if w["failed"]:
            lines.append(
                "  failed candidates (excluded from argmin): "
                + ", ".join(f"{k} ({v})" for k, v in sorted(w["failed"].items()))
            )
        if w["wisdom_key"]:
            lines.append(f"  wisdom_key: {w['wisdom_key']}")
        return "\n".join(lines)

    def profile(
        self,
        x: Optional[jax.Array] = None,
        *,
        reps: int = 3,
        warmup: int = 1,
        inverse: Optional[bool] = None,
        trace=None,
        record: bool = True,
    ) -> "ProfileResult":
        """Execute the direction through the trace-mode (segmented)
        executor and return one *observed* row per schedule stage next
        to :meth:`predict_stages`' model -- the paper's comm-vs-compute
        breakdown, measured on this plan.

        ``x=None`` profiles a zeros input built from :meth:`input_spec`.
        Spans land in ``trace`` (a fresh
        :class:`repro.obs.trace.TraceRecorder` if None; the returned
        result keeps it for export). ``warmup`` untimed traced runs pay
        the per-segment compiles first, then ``reps`` timed runs are
        aggregated by median. ``record=True`` folds the total observed
        seconds into the planner's wisdom observed channel
        (:func:`repro.core.planner.record_observed`; a no-op unless this
        plan came from ``planner="measure"``).

        Profiling never touches the plan's cached untraced executables
        -- the jitted hot path compiles to exactly the same HLO before
        and after (pinned by a regression test). Segmented wall-clock
        time exceeds the fused execution (per-stage host fences defeat
        inter-stage overlap), so treat observed sums as an attribution
        of cost, not a throughput measurement."""
        from repro.obs.trace import TraceRecorder

        inv = (self.direction == "inverse") if inverse is None else bool(inverse)
        opposite = inv != (self.direction == "inverse")
        if x is None:
            spec = self.input_spec(opposite=opposite)
            x = jax.device_put(jnp.zeros(spec.shape, spec.dtype), spec.sharding)
        else:
            x = jnp.asarray(x)
        built = self.schedule(inv)
        rec = trace if trace is not None else TraceRecorder()
        for _ in range(max(0, warmup)):
            sch.run_schedule(
                x, built, self.mesh, impl=self.local_impl, trace=TraceRecorder()
            )
        per_rep = []
        for _ in range(max(1, reps)):
            m = rec.mark()
            sch.run_schedule(x, built, self.mesh, impl=self.local_impl, trace=rec)
            per_rep.append(rec.spans_since(m))
        preds = self.predict_stages(inv, x.dtype)
        rows = []
        k_ex = 0
        for pos, sp in enumerate(per_rep[0]):
            durs = sorted(spans[pos].dur for spans in per_rep)
            obs = durs[len(durs) // 2]
            pred_s = wire = None
            if sp.cat == "exchange":
                pred_s = preds[k_ex][1]
                wire = sp.args.get("wire_bytes")
                k_ex += 1
            rows.append(ProfileRow(
                index=int(sp.args.get("index", pos)),
                stage=sp.name,
                kind=str(sp.args.get("stage", type(sp).__name__)),
                observed_s=obs,
                predicted_s=pred_s,
                wire_bytes=wire,
                args=dict(sp.args),
            ))
        result = ProfileResult(
            rows=tuple(rows), schedule=built, trace=rec, reps=len(per_rep)
        )
        if record:
            from repro.core import planner

            planner.record_observed(self, result.observed_s)
        return result

    # -- execution -------------------------------------------------------------
    def _fn(self, inverse: bool):
        built = self.schedule(inverse)  # ndim=1 inverse raises here
        mesh, impl = self.mesh, self.local_impl
        return lambda x: sch.run_schedule(x, built, mesh, impl=impl)

    def _executable(self, inverse: bool, dtype) -> jax.stages.Wrapped:
        key = ("inverse" if inverse else "forward", jnp.dtype(dtype).name)
        fn = self._cache.get(key)
        if fn is None:
            fn = jax.jit(self._fn(inverse))
            self._cache[key] = fn
            self.compiles += 1
        return fn

    def _faults_armed(self) -> bool:
        return self.faults is not None and self.faults.active()

    def execute(self, x: jax.Array) -> jax.Array:
        """Run the planned direction through the cached executable (or,
        while a :attr:`faults` plan is armed, through the segmented
        chaos executor so injected failures fire deterministically)."""
        x = jnp.asarray(x)
        inv = self.direction == "inverse"
        if self._faults_armed():
            return sch.run_schedule(
                x, self.schedule(inv), self.mesh,
                impl=self.local_impl, faults=self.faults,
            )
        return self._executable(inv, x.dtype)(x)

    def inverse(self, x: jax.Array) -> jax.Array:
        """Run the opposite of the planned direction. Not available for
        ``ndim=1`` (raises before executing anything -- see class doc)."""
        x = jnp.asarray(x)
        inv = self.direction != "inverse"
        if self._faults_armed():
            return sch.run_schedule(
                x, self.schedule(inv), self.mesh,
                impl=self.local_impl, faults=self.faults,
            )
        return self._executable(inv, x.dtype)(x)

    def executable_stats(self) -> Dict[Tuple[str, str], int]:
        """(direction, dtype) -> number of compiled specializations held
        by that cached executable (1 == no recompilation happened)."""
        stats = {}
        for key, fn in self._cache.items():
            try:
                stats[key] = fn._cache_size()
            except AttributeError:  # pragma: no cover - future jax
                stats[key] = 1
        return stats

    # -- analysis --------------------------------------------------------------
    def lower(self, inverse: Optional[bool] = None, dtype=None):
        """Abstract lowering for dry-run / roofline (no allocation).

        Goes through the same cached jit wrapper ``execute`` uses, so a
        later ``execute`` at this (direction, dtype) reuses the wrapper
        (and ``compiles`` counts it exactly once). Lowering the opposite
        direction uses that direction's actual input layout (the
        reversed-axes pencil output where applicable)."""
        inv = (self.direction == "inverse") if inverse is None else inverse
        opposite = inv != (self.direction == "inverse")
        spec = self.input_spec(dtype, opposite=opposite)
        # key the cache with the direction's ACTUAL input dtype (a real
        # plan's c2r side consumes the complex spectrum, not self.dtype),
        # so a later execute/inverse reuses this wrapper
        return self._executable(inv, spec.dtype).lower(spec)

    def roofline(self, inverse: Optional[bool] = None) -> cm.Roofline:
        """Compile abstractly and derive the three roofline terms from
        the scheduled HLO (loop-aware collective accounting)."""
        from repro.core import hlo_analysis

        compiled = self.lower(inverse).compile()
        cost = hlo_analysis.analyze_compiled(compiled, default_group=self.shards)
        return cm.Roofline(
            flops=cost.flops,
            hbm_bytes=cost.hbm_bytes,
            coll_bytes=cost.coll_bytes,
            chips=int(self.mesh.size),
        )

    def __repr__(self) -> str:
        where = (
            f"grid={self.grid.p_rows}x{self.grid.p_cols}"
            if self.decomp == "pencil"
            else f"P={self.shards}"
        )
        kind = "r2c" if self.real else "c2c"
        return (
            f"Plan({kind}, shape={self.global_shape}, ndim={self.ndim}, "
            f"decomp={self.decomp!r}, {where}, "
            f"backend={self.backend!r}, direction={self.direction!r}, "
            f"dtype={self.dtype.name})"
        )


@dataclasses.dataclass(frozen=True)
class ProfileRow:
    """One schedule stage's observed wall-clock vs model prediction.
    ``predicted_s``/``wire_bytes`` are None for non-Exchange stages (the
    alpha-beta model prices exchanges; local compute has no model row).
    ``args`` is the span's full attribute payload (backend, role, p,
    fused, n_chunks, ... for exchanges)."""

    index: int
    stage: str
    kind: str
    observed_s: float
    predicted_s: Optional[float] = None
    wire_bytes: Optional[float] = None
    args: Dict[str, object] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class ProfileResult:
    """``Plan.profile`` output: per-stage rows + the recorder holding
    the raw spans (exportable via ``result.trace.write_chrome_trace``)."""

    rows: Tuple[ProfileRow, ...]
    schedule: sch.Schedule
    trace: object
    reps: int

    @property
    def observed_s(self) -> float:
        return sum(r.observed_s for r in self.rows)

    @property
    def exchange_observed_s(self) -> float:
        return sum(r.observed_s for r in self.rows if r.kind == "Exchange")

    @property
    def predicted_s(self) -> float:
        return sum(r.predicted_s or 0.0 for r in self.rows)

    def exchange_rows(self) -> Tuple[ProfileRow, ...]:
        return tuple(r for r in self.rows if r.kind == "Exchange")

    def table(self) -> str:
        """The observed-vs-predicted stage table (README's worked
        example renders this)."""
        s = self.schedule
        head = (
            f"profile {s.kind} [{s.decomp}"
            f"{', r2c' if s.real else ''}{', inverse' if s.inverse else ''}] "
            f"shape={s.global_shape} hash={s.schedule_hash()} reps={self.reps}"
        )
        lines = [head]
        lines.append(
            f"  {'#':>2}  {'stage':<52} {'observed us':>12} {'model us':>10} "
            f"{'wire bytes':>12}"
        )
        for r in self.rows:
            pred = f"{r.predicted_s * 1e6:.2f}" if r.predicted_s is not None else "-"
            wire = f"{r.wire_bytes:.0f}" if r.wire_bytes is not None else "-"
            lines.append(
                f"  {r.index:>2}  {r.stage:<52} {r.observed_s * 1e6:>12.2f} "
                f"{pred:>10} {wire:>12}"
            )
        lines.append(
            f"  total observed {self.observed_s * 1e6:.2f} us "
            f"(exchanges {self.exchange_observed_s * 1e6:.2f} us, "
            f"model {self.predicted_s * 1e6:.2f} us)"
        )
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.table()


def plan_fft(
    global_shape: Tuple[int, ...],
    mesh: Mesh,
    *,
    ndim: int = 2,
    direction: str = "forward",
    backend: str = "auto",
    axis_name: Optional[str] = None,
    local_impl: str = "jnp",
    fuse_dft: bool = False,
    transpose_back: bool = False,
    dtype=jnp.complex64,
    params: Optional[cm.CommParams] = None,
    chunk_compute_s: float = 0.0,
    planner: str = "estimate",
    timer=None,
    use_wisdom: bool = True,
    decomp: str = "slab",
    row_axis: Optional[str] = None,
    col_axis: Optional[str] = None,
    real: bool = False,
    pad: bool = True,
    pipeline="auto",
    faults=None,
) -> Plan:
    """Plan a distributed FFT (the FFTW ``plan`` analogue).

    ``pipeline`` controls the pipelined overlap executor -- whether each
    exchange streams its chunks and fuses the following FFT stage into
    their flight time (the paper's HPX-futures overlap, as dataflow):

    ``"auto"`` (default)
        Chunk-streamed, compute-fused exchanges wherever the selected
        backend streams (``supports_chunk_fn``) over a >1-shard ring;
        one chunk per peer. Monolithic backends are unaffected.
    ``int n``
        Fused, with the streamed chunk count decoupled from P: each
        peer block is sub-chunked toward ``n`` total chunks per
        exchange, so flight time amortizes even at small P (and the
        per-arrival compute grain shrinks). ``Plan.n_chunks`` records
        it; the executed sub-chunk count additionally snaps to a
        divisor of the peer block rows.
    ``False`` (or ``0``)
        Disable: plain transpose + whole-axis local FFT, the
        pre-pipeline behavior (what the ``overlap`` benchmark calls the
        unfused monolithic run).

    ``Plan.predict(fused=..., n_chunks=...)`` reports the model's fused
    vs unfused cost for the same problem.

    ``real=True`` plans the r2c/c2r pair (:mod:`repro.core.real`):
    ``execute`` computes the distributed ``rfftn`` of a real array (and
    ``inverse`` the matching ``irfftn``; ``direction="inverse"`` swaps
    the two), every exchange after the local r2c pass shipping only the
    Hermitian-truncated ``N//2+1`` payload -- ~half the c2c wire bytes
    at the same shape. ``dtype`` is then the real input dtype
    (float32/float64; the complex default maps to its real partner).
    The ``N//2+1`` axis rarely divides the shard count: ``pad=True``
    (default) zero-pads it to the next divisible length (recorded as
    ``Plan.padded_hermitian_len``, trimmed wherever the axis lands
    local -- see the module docs for the per-layout contract);
    ``pad=False`` raises at plan time naming the offending axis.

    ``decomp`` picks the process decomposition:

    ``"slab"`` (default)
        One sharded data dim over one mesh axis (``axis_name``, the
        paper's layout): parallelism caps at P <= N, one global exchange
        over all P ranks per transpose.
    ``"pencil"``
        Two sharded data dims over a 2-D process grid (``row_axis`` /
        ``col_axis``, conventionally ``("rows", "cols")`` -- see
        :mod:`repro.core.grid`): each transpose is a sub-axis exchange
        over only P_row or P_col ranks, and each axis gets its own
        backend -- pass ``backend=("scatter", "bisection")`` (or the
        ``"scatter+bisection"`` pair key) to pin, ``backend="auto"``
        for the per-axis cost-model argmin. ndim 2 or 3.
    ``"auto"``
        Pencil whenever the mesh offers a valid 2-D grid for this
        shape/ndim AND the cost model does not predict a slab plan of at
        least equal parallelism to be strictly cheaper (a degenerate
        (P,1) grid, for example, doubles the fft2 exchanges over the
        same ring, so slab wins it); else slab.

    ``planner`` picks the selection discipline (FFTW's ESTIMATE/MEASURE):

    ``"estimate"`` (default)
        ``backend="auto"`` = alpha-beta cost-model argmin over every
        registered backend supporting this shard count (per grid axis at
        its own sub-ring size under pencil) -- the same set (and costs)
        ``Plan.predict()`` ranks. Pass a
        :meth:`CommParams.calibrate <repro.core.comm_model.CommParams.calibrate>`
        result as ``params`` to estimate with measured constants.
    ``"measure"``
        Times every candidate backend (every per-axis pair, under
        pencil) on the real mesh (warmup + median) and pins the measured
        argmin; per-candidate timings land on ``Plan.measured``.
        Consults the wisdom store first (:mod:`repro.core.planner`) --
        keys carry the decomposition, grid shape and per-axis backend
        pair -- so a second identical plan never re-measures;
        ``use_wisdom=False`` forces re-measurement and
        ``timer(plan) -> seconds`` replaces the real clock (tests).

    Pass any name from ``repro.core.backends.available()`` as
    ``backend=`` to pin the backend under either planner. ``faults=``
    installs a chaos hook (:class:`repro.runtime.faults.FaultPlan`) on
    the returned plan: while armed, execute/inverse consult it before
    every Exchange stage (see :attr:`Plan.faults`).
    """
    if real and fuse_dft:
        fuse_dft = _warn_real_fuse_dft()
    if planner not in ("estimate", "measure"):
        raise ValueError(f"planner must be 'estimate' or 'measure', got {planner!r}")
    if planner == "estimate" and (timer is not None or use_wisdom is not True):
        # a forgotten planner="measure" would otherwise silently fall back
        # to model-based selection with the injected timer never called
        raise ValueError("timer= and use_wisdom= require planner='measure'")
    if planner == "measure":
        from repro.core import planner as _planner

        plan = _planner.plan_measured(
            global_shape,
            mesh,
            ndim=ndim,
            direction=direction,
            backend=backend,
            axis_name=axis_name,
            local_impl=local_impl,
            fuse_dft=fuse_dft,
            transpose_back=transpose_back,
            dtype=dtype,
            params=params,
            chunk_compute_s=chunk_compute_s,
            timer=timer,
            use_wisdom=use_wisdom,
            decomp=decomp,
            row_axis=row_axis,
            col_axis=col_axis,
            real=real,
            pad=pad,
            pipeline=pipeline,
        )
        plan.faults = faults
        return plan
    plan = Plan(
        global_shape,
        mesh,
        ndim=ndim,
        direction=direction,
        backend=backend,
        axis_name=axis_name,
        local_impl=local_impl,
        fuse_dft=fuse_dft,
        transpose_back=transpose_back,
        dtype=dtype,
        params=params,
        chunk_compute_s=chunk_compute_s,
        decomp=decomp,
        row_axis=row_axis,
        col_axis=col_axis,
        real=real,
        pad=pad,
        pipeline=pipeline,
    )
    plan.faults = faults
    return plan


# ---------------------------------------------------------------------------
# Legacy shims (one release): FFTPlan dataclass + make_plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FFTPlan:
    """Deprecated: thin shim over :class:`Plan` preserving the old field
    layout. Use :func:`plan_fft` instead."""

    global_shape: Tuple[int, ...]
    mesh: Mesh
    axis_name: str
    cfg: FFTConfig = FFTConfig()
    ndim_transform: int = 2

    def __post_init__(self):
        plan = Plan(
            self.global_shape,
            self.mesh,
            ndim=self.ndim_transform,
            backend=self.cfg.strategy,
            axis_name=self.axis_name,
            local_impl=self.cfg.local_impl,
            fuse_dft=self.cfg.fuse_dft,
            transpose_back=self.cfg.transpose_back,
        )
        object.__setattr__(self, "_plan", plan)

    def input_sharding(self) -> NamedSharding:
        return self._plan.input_sharding()

    def input_spec(self, dtype=jnp.complex64) -> jax.ShapeDtypeStruct:
        return self._plan.input_spec(dtype)

    def execute(self, x: jax.Array) -> jax.Array:
        return self._plan.execute(x)

    def inverse(self, x: jax.Array) -> jax.Array:
        return self._plan.inverse(x)

    def lower(self, inverse: bool = False):
        return self._plan.lower(inverse)

    def comm_bytes(self, dtype=jnp.complex64) -> float:
        return self._plan.comm_bytes(dtype)


def make_plan(
    global_shape: Tuple[int, ...],
    mesh: Mesh,
    *,
    axis_name: Optional[str] = None,
    strategy: str = "alltoall",
    local_impl: str = "jnp",
    fuse_dft: bool = False,
    transpose_back: bool = False,
    ndim_transform: int = 2,
) -> FFTPlan:
    """Deprecated: use :func:`plan_fft` (``strategy`` -> ``backend``,
    ``ndim_transform`` -> ``ndim``)."""
    from repro.core.sharding import fft_axis

    warnings.warn(
        "make_plan is deprecated; use repro.core.plan_fft(shape, mesh, "
        "ndim=..., backend=...) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    return FFTPlan(
        global_shape=tuple(global_shape),
        mesh=mesh,
        axis_name=axis_name or fft_axis(mesh),
        cfg=FFTConfig(
            strategy=strategy,
            local_impl=local_impl,  # type: ignore[arg-type]
            fuse_dft=fuse_dft,
            transpose_back=transpose_back,
        ),
        ndim_transform=ndim_transform,
    )
