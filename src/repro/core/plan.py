"""FFTW-style plan/executor front-end over the collective-backend registry.

The paper's FFTW3 reference works through plans; this is the same UX for
the distributed transforms, rebuilt on :mod:`repro.core.backends`:

    plan = plan_fft((n, n), mesh, ndim=2, backend="auto")
    y = plan.execute(x)          # cached jitted executable
    x2 = plan.inverse(y)

A :class:`Plan`:

- validates the (global shape, mesh, shard axis, backend) combination
  **once**, at construction;
- resolves ``backend="auto"`` to the alpha-beta cost-model argmin over
  every registered backend supporting the shard count
  (``Plan.predict()`` exposes the full ranking -- the paper's Fig. 3
  hypothesis step as an API);
- caches one jitted executable per (direction, dtype), so repeated
  ``execute`` calls never re-trace or re-compile;
- exposes ``lower``/``roofline`` for dry-run analysis of the compiled
  communication schedule.

``FFTPlan``/``make_plan`` remain as deprecation shims for one release.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import backends
from repro.core import comm_model as cm
from repro.core import distributed_fft as dfft
from repro.core.distributed_fft import FFTConfig

_EXCHANGES = {1: 3, 2: 1, 3: 2}  # pencil exchanges per forward transform


class Plan:
    """A validated, backend-resolved, executable-caching FFT plan.

    Construct through :func:`plan_fft`. ``direction`` fixes what
    ``execute`` computes ("forward" or "inverse"); ``inverse`` always
    computes the opposite of ``execute``.

    Partial surface: the 1-D large transform has no inverse -- planning
    ``ndim=1, direction="inverse"`` is rejected at construction, and
    calling ``inverse()`` on a forward 1-D plan raises
    ``NotImplementedError`` before anything executes (conjugate
    externally instead).
    """

    def __init__(
        self,
        global_shape: Tuple[int, ...],
        mesh: Mesh,
        *,
        ndim: int = 2,
        direction: str = "forward",
        backend: str = "auto",
        axis_name: Optional[str] = None,
        local_impl: str = "jnp",
        fuse_dft: bool = False,
        transpose_back: bool = False,
        dtype=jnp.complex64,
        params: Optional[cm.CommParams] = None,
        chunk_compute_s: float = 0.0,
    ):
        from repro.core.sharding import fft_axis

        if ndim not in (1, 2, 3):
            raise ValueError("ndim must be 1, 2 or 3")
        if direction not in ("forward", "inverse"):
            raise ValueError(f"direction must be 'forward' or 'inverse', got {direction!r}")
        if ndim == 1 and direction == "inverse":
            # fail at plan time, not first execute (validate-once contract)
            raise NotImplementedError(
                "1-D large inverse is not implemented: plan forward and conjugate externally"
            )
        self.global_shape = tuple(global_shape)
        self.mesh = mesh
        self.axis_name = axis_name or fft_axis(mesh)
        self.ndim = ndim
        self.direction = direction
        self.dtype = jnp.dtype(dtype)
        self.local_impl = local_impl
        self.fuse_dft = fuse_dft
        self.transpose_back = transpose_back
        self.params = params or cm.CommParams()
        self.chunk_compute_s = chunk_compute_s
        # set by the measured planner (repro.core.planner.plan_measured)
        self.planner = "estimate"
        self.measured: Optional[Dict[str, float]] = None
        self.wisdom_hit = False

        p = self.shards
        if ndim == 2:
            r, c = self.global_shape[-2:]
            if r % p or c % p:
                raise ValueError(f"2-D shape {(r, c)} not divisible by shards {p}")
        elif ndim == 3:
            d0, d1, d2 = self.global_shape[-3:]
            if d0 % p or (d1 * d2) % p:
                raise ValueError(f"3-D shape {(d0, d1, d2)} not shardable by {p}")
        else:
            n = self.global_shape[-1]
            if n % (p * p):
                raise ValueError(f"1-D size {n} must be divisible by P^2={p * p}")

        if backend == "auto":
            backend = "scatter" if fuse_dft else backends.cheapest(
                self.local_bytes(), p, self.params, chunk_compute_s=chunk_compute_s
            )
        self.backend_obj = backends.get(backend)  # raises listing the registry
        self.backend = backend
        if not self.backend_obj.supports(p):
            raise ValueError(f"backend {backend!r} does not support P={p}")
        if fuse_dft and backend != "scatter":
            raise ValueError("fuse_dft requires backend='scatter'")

        self._cfg = FFTConfig(
            strategy=backend,
            local_impl=local_impl,  # type: ignore[arg-type]
            fuse_dft=fuse_dft,
            transpose_back=transpose_back,
        )
        self._cache: Dict[Tuple[str, str], jax.stages.Wrapped] = {}
        self.compiles = 0  # jit wrappers created (not per-shape recompiles)

    # -- geometry --------------------------------------------------------------
    @property
    def shards(self) -> int:
        return self.mesh.shape[self.axis_name]

    def local_bytes(self, dtype=None) -> float:
        """Bytes of one device's local block of the input."""
        itemsize = jnp.dtype(dtype or self.dtype).itemsize
        return float(np.prod(self.global_shape)) * itemsize / self.shards

    def comm_bytes(self, dtype=None) -> float:
        """Bytes each device ships per pencil exchange ((1-1/P) of local)."""
        p = self.shards
        return self.local_bytes(dtype) * (1 - 1 / p)

    # -- cost model ------------------------------------------------------------
    def predict(self, dtype=None, chunk_compute_s: Optional[float] = None) -> Dict[str, float]:
        """Alpha-beta predicted seconds per backend for this problem --
        ``n_exchanges * backend.cost(local_bytes, P, params, chunk_compute_s)``
        for every registered backend that supports this shard count.
        ``chunk_compute_s`` (default: the plan's own) is per-chunk compute:
        streaming backends overlap it with later rounds, monolithic ones
        serialize it, so the overlap advantage shows up in the ranking.
        Uses the plan's ``params`` -- pass a calibrated
        :meth:`~repro.core.comm_model.CommParams.calibrate` result at plan
        time for measured (rather than v5e napkin) constants."""
        p = self.shards
        m = self.local_bytes(dtype)
        cc = self.chunk_compute_s if chunk_compute_s is None else chunk_compute_s
        n_ex = _EXCHANGES[self.ndim] + (1 if self.ndim == 2 and self.transpose_back else 0)
        out = {}
        for name in backends.available():
            b = backends.get(name)
            if b.supports(p):
                out[name] = n_ex * b.cost(m, p, self.params, cc)
        return out

    # -- sharding specs --------------------------------------------------------
    def input_sharding(self) -> NamedSharding:
        nd = len(self.global_shape)
        spec = [None] * nd
        spec[nd - self.ndim] = self.axis_name  # shard the leading transform dim
        return NamedSharding(self.mesh, P(*spec))

    def input_spec(self, dtype=None) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(
            self.global_shape, dtype or self.dtype, sharding=self.input_sharding()
        )

    # -- execution -------------------------------------------------------------
    def _fn(self, inverse: bool):
        if self.ndim == 2:
            return lambda x: dfft.fft2(x, self.mesh, self.axis_name, self._cfg, inverse=inverse)
        if self.ndim == 3:
            return lambda x: dfft.fft3(x, self.mesh, self.axis_name, self._cfg, inverse=inverse)
        if inverse:
            raise NotImplementedError("1-D large inverse: conjugate externally")
        return lambda x: dfft.fft1d_large(x, self.mesh, self.axis_name, self._cfg)

    def _executable(self, inverse: bool, dtype) -> jax.stages.Wrapped:
        key = ("inverse" if inverse else "forward", jnp.dtype(dtype).name)
        fn = self._cache.get(key)
        if fn is None:
            fn = jax.jit(self._fn(inverse))
            self._cache[key] = fn
            self.compiles += 1
        return fn

    def execute(self, x: jax.Array) -> jax.Array:
        """Run the planned direction through the cached executable."""
        x = jnp.asarray(x)
        return self._executable(self.direction == "inverse", x.dtype)(x)

    def inverse(self, x: jax.Array) -> jax.Array:
        """Run the opposite of the planned direction. Not available for
        ``ndim=1`` (raises before executing anything -- see class doc)."""
        x = jnp.asarray(x)
        return self._executable(self.direction != "inverse", x.dtype)(x)

    def executable_stats(self) -> Dict[Tuple[str, str], int]:
        """(direction, dtype) -> number of compiled specializations held
        by that cached executable (1 == no recompilation happened)."""
        stats = {}
        for key, fn in self._cache.items():
            try:
                stats[key] = fn._cache_size()
            except AttributeError:  # pragma: no cover - future jax
                stats[key] = 1
        return stats

    # -- analysis --------------------------------------------------------------
    def lower(self, inverse: Optional[bool] = None, dtype=None):
        """Abstract lowering for dry-run / roofline (no allocation).

        Goes through the same cached jit wrapper ``execute`` uses, so a
        later ``execute`` at this (direction, dtype) reuses the wrapper
        (and ``compiles`` counts it exactly once)."""
        inv = (self.direction == "inverse") if inverse is None else inverse
        return self._executable(inv, dtype or self.dtype).lower(self.input_spec(dtype))

    def roofline(self, inverse: Optional[bool] = None) -> cm.Roofline:
        """Compile abstractly and derive the three roofline terms from
        the scheduled HLO (loop-aware collective accounting)."""
        from repro.core import hlo_analysis

        compiled = self.lower(inverse).compile()
        cost = hlo_analysis.analyze_compiled(compiled, default_group=self.shards)
        return cm.Roofline(
            flops=cost.flops,
            hbm_bytes=cost.hbm_bytes,
            coll_bytes=cost.coll_bytes,
            chips=int(self.mesh.size),
        )

    def __repr__(self) -> str:
        return (
            f"Plan(shape={self.global_shape}, ndim={self.ndim}, P={self.shards}, "
            f"backend={self.backend!r}, direction={self.direction!r}, "
            f"dtype={self.dtype.name})"
        )


def plan_fft(
    global_shape: Tuple[int, ...],
    mesh: Mesh,
    *,
    ndim: int = 2,
    direction: str = "forward",
    backend: str = "auto",
    axis_name: Optional[str] = None,
    local_impl: str = "jnp",
    fuse_dft: bool = False,
    transpose_back: bool = False,
    dtype=jnp.complex64,
    params: Optional[cm.CommParams] = None,
    chunk_compute_s: float = 0.0,
    planner: str = "estimate",
    timer=None,
    use_wisdom: bool = True,
) -> Plan:
    """Plan a distributed FFT (the FFTW ``plan`` analogue).

    ``planner`` picks the selection discipline (FFTW's ESTIMATE/MEASURE):

    ``"estimate"`` (default)
        ``backend="auto"`` = alpha-beta cost-model argmin over every
        registered backend supporting this shard count -- the same set
        (and costs) ``Plan.predict()`` ranks. Pass a
        :meth:`CommParams.calibrate <repro.core.comm_model.CommParams.calibrate>`
        result as ``params`` to estimate with measured constants.
    ``"measure"``
        Times every candidate backend on the real mesh (warmup + median)
        and pins the measured argmin; per-backend timings land on
        ``Plan.measured``. Consults the wisdom store first
        (:mod:`repro.core.planner`), so a second identical plan never
        re-measures; ``use_wisdom=False`` forces re-measurement and
        ``timer(plan) -> seconds`` replaces the real clock (tests).

    Pass any name from ``repro.core.backends.available()`` as
    ``backend=`` to pin the backend under either planner.
    """
    if planner not in ("estimate", "measure"):
        raise ValueError(f"planner must be 'estimate' or 'measure', got {planner!r}")
    if planner == "estimate" and (timer is not None or use_wisdom is not True):
        # a forgotten planner="measure" would otherwise silently fall back
        # to model-based selection with the injected timer never called
        raise ValueError("timer= and use_wisdom= require planner='measure'")
    if planner == "measure":
        from repro.core import planner as _planner

        return _planner.plan_measured(
            global_shape,
            mesh,
            ndim=ndim,
            direction=direction,
            backend=backend,
            axis_name=axis_name,
            local_impl=local_impl,
            fuse_dft=fuse_dft,
            transpose_back=transpose_back,
            dtype=dtype,
            params=params,
            chunk_compute_s=chunk_compute_s,
            timer=timer,
            use_wisdom=use_wisdom,
        )
    return Plan(
        global_shape,
        mesh,
        ndim=ndim,
        direction=direction,
        backend=backend,
        axis_name=axis_name,
        local_impl=local_impl,
        fuse_dft=fuse_dft,
        transpose_back=transpose_back,
        dtype=dtype,
        params=params,
        chunk_compute_s=chunk_compute_s,
    )


# ---------------------------------------------------------------------------
# Legacy shims (one release): FFTPlan dataclass + make_plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FFTPlan:
    """Deprecated: thin shim over :class:`Plan` preserving the old field
    layout. Use :func:`plan_fft` instead."""

    global_shape: Tuple[int, ...]
    mesh: Mesh
    axis_name: str
    cfg: FFTConfig = FFTConfig()
    ndim_transform: int = 2

    def __post_init__(self):
        plan = Plan(
            self.global_shape,
            self.mesh,
            ndim=self.ndim_transform,
            backend=self.cfg.strategy,
            axis_name=self.axis_name,
            local_impl=self.cfg.local_impl,
            fuse_dft=self.cfg.fuse_dft,
            transpose_back=self.cfg.transpose_back,
        )
        object.__setattr__(self, "_plan", plan)

    def input_sharding(self) -> NamedSharding:
        return self._plan.input_sharding()

    def input_spec(self, dtype=jnp.complex64) -> jax.ShapeDtypeStruct:
        return self._plan.input_spec(dtype)

    def execute(self, x: jax.Array) -> jax.Array:
        return self._plan.execute(x)

    def inverse(self, x: jax.Array) -> jax.Array:
        return self._plan.inverse(x)

    def lower(self, inverse: bool = False):
        return self._plan.lower(inverse)

    def comm_bytes(self, dtype=jnp.complex64) -> float:
        return self._plan.comm_bytes(dtype)


def make_plan(
    global_shape: Tuple[int, ...],
    mesh: Mesh,
    *,
    axis_name: Optional[str] = None,
    strategy: str = "alltoall",
    local_impl: str = "jnp",
    fuse_dft: bool = False,
    transpose_back: bool = False,
    ndim_transform: int = 2,
) -> FFTPlan:
    """Deprecated: use :func:`plan_fft` (``strategy`` -> ``backend``,
    ``ndim_transform`` -> ``ndim``)."""
    from repro.core.sharding import fft_axis

    warnings.warn(
        "make_plan is deprecated; use repro.core.plan_fft(shape, mesh, "
        "ndim=..., backend=...) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    return FFTPlan(
        global_shape=tuple(global_shape),
        mesh=mesh,
        axis_name=axis_name or fft_axis(mesh),
        cfg=FFTConfig(
            strategy=strategy,
            local_impl=local_impl,  # type: ignore[arg-type]
            fuse_dft=fuse_dft,
            transpose_back=transpose_back,
        ),
        ndim_transform=ndim_transform,
    )
