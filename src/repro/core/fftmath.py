"""Local (on-chip) FFT in MXU-friendly matmul form.

TPU adaptation of the paper's per-node FFTW stage: TPUs have no scalar
FFT codelets -- the efficient formulation is the Cooley-Tukey / Bailey
four-step factorization expressed as DFT-*matrix* matmuls, which map
directly onto the 128x128 MXU systolic array.

For a length-``n`` transform with ``n = n1 * n2``::

    A           = x.reshape(n1, n2)                    # j = j1*n2 + j2
    B[k1, j2]   = sum_j1 W_n1[k1, j1] * A[j1, j2]      # DFT over j1  (matmul)
    C[k1, j2]   = B[k1, j2] * exp(-2*pi*i*k1*j2 / n)   # twiddle
    D[k1, k2]   = sum_j2 C[k1, j2] * W_n2[k2, j2]      # DFT over j2  (matmul)
    X[k1+n1*k2] = D[k1, k2]                            # transposed read-out

The recursion bottoms out at a direct DFT matmul of size <= ``max_dft``.
``jnp.fft`` is kept as the oracle path (it is also what the ``xla_auto``
distributed reference uses, mirroring the paper's FFTW3 baseline).

All twiddle/DFT tables are computed host-side in float64 (numpy) and cast
to complex64, which keeps the matmul-FFT error ~1e-5 relative even for
n = 2^14 (validated in tests/test_fft_local.py).
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

LocalImpl = Literal["jnp", "matmul", "pallas"]

#: Largest direct DFT-matrix applied as a single matmul. 512 keeps the
#: operand (512x512 c64 = 2 MiB as 4 real f32 matmuls of 1 MiB) well within
#: one VMEM-resident tile set while giving the MXU K-dims >= 128.
MAX_DFT = 512


@functools.lru_cache(maxsize=64)
def _dft_matrix_np(n: int, dtype: str = "complex64") -> np.ndarray:
    """DFT matrix W[k, j] = exp(-2*pi*i*k*j/n), computed in float64 and
    cast to ``dtype`` (the fused exchange stages keep complex128 tables
    so c128 transforms stay at double precision)."""
    k = np.arange(n, dtype=np.float64)
    return np.exp(-2j * np.pi * np.outer(k, k) / n).astype(dtype)


@functools.lru_cache(maxsize=64)
def _twiddle_np(n1: int, n2: int, dtype: str = "complex64") -> np.ndarray:
    """Four-step twiddle T[k1, j2] = exp(-2*pi*i*k1*j2/(n1*n2)), float64."""
    k1 = np.arange(n1, dtype=np.float64)
    j2 = np.arange(n2, dtype=np.float64)
    return np.exp(-2j * np.pi * np.outer(k1, j2) / (n1 * n2)).astype(dtype)


def dft_matrix(n: int, dtype="complex64") -> jax.Array:
    return jnp.asarray(_dft_matrix_np(n, np.dtype(dtype).name))


def twiddle(n1: int, n2: int, dtype="complex64") -> jax.Array:
    return jnp.asarray(_twiddle_np(n1, n2, np.dtype(dtype).name))


def split_factor(n: int, max_dft: int = MAX_DFT) -> int:
    """Pick n1 | n, n1 <= max_dft, as close to sqrt(n) as possible.

    Returns 0 if ``n`` has no factor in [2, max_dft] (prime beyond the
    direct-DFT limit) -- the caller falls back to a direct O(n^2) DFT.
    """
    if n <= max_dft:
        return n
    best = 0
    f = 2
    while f * f <= n:
        if n % f == 0:
            for cand in (n // f, f):
                if cand <= max_dft and cand > best:
                    best = cand
        f += 1
    return best


def _fft_matmul_c64(x: jax.Array, max_dft: int) -> jax.Array:
    """Forward FFT along the last axis via recursive four-step matmuls."""
    n = x.shape[-1]
    if n == 1:
        return x
    n1 = split_factor(n, max_dft)
    if n1 in (0, n):
        # Direct DFT: either small enough, or prime beyond the limit.
        return jnp.einsum("...j,kj->...k", x, dft_matrix(n))
    n2 = n // n1
    a = x.reshape(x.shape[:-1] + (n1, n2))
    b = jnp.einsum("kj,...jl->...kl", dft_matrix(n1), a)
    b = b * twiddle(n1, n2)
    c = _fft_matmul_c64(b, max_dft)  # transform along last (j2 -> k2) axis
    d = jnp.swapaxes(c, -1, -2)  # (..., k2, k1): index k1 + n1*k2
    return d.reshape(x.shape[:-1] + (n,))


def fft_matmul(x: jax.Array, *, inverse: bool = False, max_dft: int = MAX_DFT) -> jax.Array:
    """FFT along the last axis, MXU matmul formulation. Unnormalized
    forward; inverse carries the 1/n factor (matches jnp.fft)."""
    x = x.astype(jnp.complex64)
    if inverse:
        n = x.shape[-1]
        return jnp.conj(_fft_matmul_c64(jnp.conj(x), max_dft)) / n
    return _fft_matmul_c64(x, max_dft)


def _fft_pallas(x: jax.Array, *, inverse: bool = False) -> jax.Array:
    # Imported lazily: kernels are optional at import time.
    from repro.kernels import ops as kops

    return kops.fft_last_axis(x, inverse=inverse)


def local_fft(
    x: jax.Array,
    *,
    axis: int = -1,
    inverse: bool = False,
    impl: LocalImpl = "jnp",
    max_dft: int = MAX_DFT,
) -> jax.Array:
    """1-D FFT along ``axis`` with a selectable implementation.

    ``jnp``    -- oracle / reference (XLA's own FFT op).
    ``matmul`` -- four-step DFT matmuls (MXU formulation, pure jnp).
    ``pallas`` -- the fused Pallas kernel (kernels/fft_stage.py).
    """
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    if axis != -1 and axis != x.ndim - 1:
        x = jnp.moveaxis(x, axis, -1)
        y = local_fft(x, axis=-1, inverse=inverse, impl=impl, max_dft=max_dft)
        return jnp.moveaxis(y, -1, axis)
    if impl == "jnp":
        return jnp.fft.ifft(x, norm="backward") if inverse else jnp.fft.fft(x)
    if impl == "matmul":
        return fft_matmul(x, inverse=inverse, max_dft=max_dft)
    if impl == "pallas":
        return _fft_pallas(x, inverse=inverse)
    raise ValueError(f"unknown local FFT impl: {impl!r}")


def local_fft2(x: jax.Array, *, inverse: bool = False, impl: LocalImpl = "jnp") -> jax.Array:
    """2-D FFT over the last two axes (single-device reference)."""
    y = local_fft(x, axis=-1, inverse=inverse, impl=impl)
    return local_fft(y, axis=-2, inverse=inverse, impl=impl)
