"""Distributed real-to-complex FFTs (rfftn / irfftn) over the backend
registry -- half the wire bytes for real-input workloads.

The paper's FFTW3+MPI reference is what scientific users drive with
*real* data: an r2c transform keeps only the Hermitian-non-redundant
half of the last axis (``H = N//2 + 1`` complex values instead of ``N``),
so every pencil exchange after the first local pass ships ~half the
bytes of the complex-to-complex path. The structure mirrors
:mod:`repro.core.distributed_fft` / :mod:`repro.core.pencil`:

- the r2c pass runs **locally on the contiguous last axis** (it is the
  only pass whose input is real);
- every remaining pass is an ordinary c2c FFT fed through the same
  strategy-switched :func:`repro.core.transpose.distributed_transpose`,
  so the whole parcelport axis (backend registry, per-axis pencil
  backends, measured planner) applies unchanged -- just on the truncated
  payload;
- c2r mirrors the chain in reverse and restores the real layout.

**The N//2+1 divisibility problem.** ``H`` is almost never divisible by
the shard count (it is odd whenever ``N`` is even), so the Hermitian
axis cannot be re-sharded as-is. With ``pad=True`` (default) the half
spectrum is zero-padded to the next divisible length ``Hp`` before the
exchange and the pad is trimmed wherever the axis ends up local again
(the plan records ``hermitian_len``/``padded_hermitian_len``); the
padded tail is exactly zero (FFTs of zeros), so layouts that keep it
are still numerically exact. With ``pad=False`` a non-divisible ``H``
raises a plan-time ``ValueError`` naming the offending data axis and
mesh/grid dimension, in the same style as the c2c validators.

Spectrum layouts (global values; ``H``/``Hp`` along the original last
axis):

====================  =====================================================
slab ``rfft2``        ``(..., Hp, R)`` transposed, Hp-sharded (the slab
                      c2c convention); ``transpose_back`` -> exact
                      natural ``(..., R, H)``
slab ``rfft3``        natural ``(..., D0, D1, H)``, D0-sharded (exact)
pencil ``rfft2``      natural ``(..., R, Hp)``, (rows, cols)-sharded
pencil ``rfft3``      reversed ``(..., Hp, D1, D0)``, (cols, rows)-sharded;
                      ``transpose_back`` -> exact natural
====================  =====================================================

Each ``irfft*`` consumes exactly the layout its ``rfft*`` produces.
``n_last`` (the original real length) is explicit on every inverse --
``H`` alone cannot distinguish even ``2*(H-1)`` from odd ``2*H-1``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.core.fftmath as lf
import repro.core.transpose as tr
from repro.core import backends
from repro.core.compat import shard_map
from repro.core.distributed_fft import FFTConfig
from repro.core.grid import ProcessGrid
from repro.core.pencil import PencilConfig, _check_backends


# ---------------------------------------------------------------------------
# Hermitian-length helpers
# ---------------------------------------------------------------------------


def rfft_len(n: int) -> int:
    """Length of the Hermitian-non-redundant rfft output for a real
    length-``n`` axis (numpy's ``n//2 + 1``)."""
    return int(n) // 2 + 1


def padded_rfft_len(n: int, multiple: int, weight: int = 1) -> int:
    """Smallest ``hp >= rfft_len(n)`` with ``(weight * hp) % multiple == 0``.

    ``weight`` covers the slab fft3 case where the *flattened* axis
    ``D1 * Hp`` (not ``Hp`` itself) must divide the shard count."""
    hp = rfft_len(n)
    while (weight * hp) % multiple:
        hp += 1
    return hp


def _pad_disabled_hint(n: int, multiple: int, weight: int = 1) -> str:
    return (
        f"pass pad=True (pads the half spectrum to "
        f"{padded_rfft_len(n, multiple, weight)}, plan-recorded trim)"
    )


def check_divisible_slab(global_shape, p: int, ndim: int, axis_name, *, pad: bool = True):
    """Validate a slab r2c problem; returns ``(h, hp)`` for the Hermitian
    axis. Raises a ValueError naming the offending data axis and mesh
    axis -- the plan-time guard, mirroring the c2c validators."""
    shape = tuple(global_shape)
    if ndim == 2:
        r, c = shape[-2:]
        if r % p:
            raise ValueError(
                f"real slab rfft2: data axis -2 (global size {r}) is not "
                f"divisible by mesh axis {axis_name!r} (P={p}) -- shape {shape}"
            )
        h = rfft_len(c)
        if not pad and h % p:
            raise ValueError(
                f"real slab rfft2: Hermitian axis -1 (N={c} -> N//2+1={h}) is "
                f"not divisible by mesh axis {axis_name!r} (P={p}) and "
                f"pad=False -- shape {shape}; {_pad_disabled_hint(c, p)}"
            )
        return h, (padded_rfft_len(c, p) if pad else h)
    if ndim == 3:
        d0, d1, d2 = shape[-3:]
        if d0 % p:
            raise ValueError(
                f"real slab rfft3: data axis -3 (global size {d0}) is not "
                f"divisible by mesh axis {axis_name!r} (P={p}) -- shape {shape}"
            )
        h = rfft_len(d2)
        if not pad and (d1 * h) % p:
            raise ValueError(
                f"real slab rfft3: flattened axes (-2,-1) (size {d1}*{h}={d1 * h} "
                f"after the Hermitian truncation of N={d2}) not divisible by "
                f"mesh axis {axis_name!r} (P={p}) and pad=False -- shape "
                f"{shape}; {_pad_disabled_hint(d2, p, d1)}"
            )
        return h, (padded_rfft_len(d2, p, weight=d1) if pad else h)
    raise NotImplementedError(
        f"real transforms support ndim 2 or 3, got ndim={ndim} "
        f"(1-D real: run the c2c fft1d_large on a complexified signal)"
    )


def check_divisible_pencil(global_shape, grid: ProcessGrid, ndim: int, *, pad: bool = True):
    """Validate a pencil r2c problem; returns ``(h, hp)``. Errors name
    the data axis and grid dimension, like the c2c pencil validator."""
    shape = tuple(global_shape)
    pr, pc = grid.p_rows, grid.p_cols
    where = (
        f"shape {shape} on grid {pr}x{pc} "
        f"(row_axis={grid.row_axis!r}, col_axis={grid.col_axis!r})"
    )
    if ndim == 3:
        d0, d1, d2 = shape[-3:]
        if d0 % pr:
            raise ValueError(
                f"real pencil rfft3: data axis -3 (global size {d0}) is not "
                f"divisible by P_row={pr} ({grid.row_axis!r}) -- {where}"
            )
        for divisor, why in ((pc, f"P_col={pc} ({grid.col_axis!r})"),
                             (pr, f"P_row={pr} ({grid.row_axis!r}; the rows "
                                  f"exchange re-shards it)")):
            if d1 % divisor:
                raise ValueError(
                    f"real pencil rfft3: data axis -2 (global size {d1}) is "
                    f"not divisible by {why} -- {where}"
                )
        h = rfft_len(d2)
        if not pad and h % pc:
            raise ValueError(
                f"real pencil rfft3: Hermitian axis -1 (N={d2} -> N//2+1={h}) "
                f"is not divisible by P_col={pc} ({grid.col_axis!r}) and "
                f"pad=False -- {where}; {_pad_disabled_hint(d2, pc)}"
            )
        return h, (padded_rfft_len(d2, pc) if pad else h)
    if ndim == 2:
        r, c = shape[-2:]
        if r % (pr * pc):
            raise ValueError(
                f"real pencil rfft2: data axis -2 (global size {r}) is not "
                f"divisible by P_row*P_col={pr * pc} (both sub-rings re-shard "
                f"it) -- {where}"
            )
        if c % pc:
            raise ValueError(
                f"real pencil rfft2: data axis -1 (global size {c}) is not "
                f"divisible by P_col={pc} ({grid.col_axis!r}) -- {where}"
            )
        h = rfft_len(c)
        if not pad and h % (pr * pc):
            raise ValueError(
                f"real pencil rfft2: Hermitian axis -1 (N={c} -> N//2+1={h}) "
                f"is not divisible by P_row*P_col={pr * pc} (both sub-rings "
                f"re-shard it) and pad=False -- {where}; "
                f"{_pad_disabled_hint(c, pr * pc)}"
            )
        return h, (padded_rfft_len(c, pr * pc) if pad else h)
    raise NotImplementedError(f"real pencil transforms support ndim 2 or 3, got {ndim}")


# ---------------------------------------------------------------------------
# Local r2c / c2r building blocks (impl-switched like lf.local_fft)
# ---------------------------------------------------------------------------


def _local_rfft(x: jax.Array, impl: lf.LocalImpl) -> jax.Array:
    """r2c along the last axis. ``jnp`` uses the native rfft; the matmul
    and pallas impls have no r2c codelet, so they transform the
    complexified axis and keep the non-redundant half."""
    if impl == "jnp":
        return jnp.fft.rfft(x, axis=-1)
    return lf.local_fft(x, axis=-1, impl=impl)[..., : rfft_len(x.shape[-1])]


def _local_irfft(x: jax.Array, n: int, impl: lf.LocalImpl) -> jax.Array:
    """c2r along the last axis: half spectrum (length ``n//2+1``) to a
    real length-``n`` signal, carrying the 1/n factor."""
    if impl == "jnp":
        return jnp.fft.irfft(x, n=n, axis=-1)
    h = x.shape[-1]
    # rebuild the redundant half (X[n-k] = conj(X[k]), k = 1..n-h) and
    # run the impl's c2c inverse; the result is real up to roundoff
    tail = jnp.conj(x[..., 1 : n - h + 1])[..., ::-1]
    full = jnp.concatenate([x, tail], axis=-1)
    return jnp.real(lf.local_fft(full, axis=-1, inverse=True, impl=impl))


def _pad_last(v: jax.Array, count: int) -> jax.Array:
    if count == 0:
        return v
    return jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, count)])


def _real_fused(cfg) -> bool:
    """Whether this config asks for fused (chunk-streamed) exchanges.

    ``fuse_dft`` used to hard-error here ("the real transforms have no
    fused path"); the pipelined overlap executor IS that path now, so
    the flag is honoured as a deprecated alias of ``fused`` -- new code
    spells it ``plan_fft(..., pipeline=...)``."""
    if getattr(cfg, "fuse_dft", False):
        import warnings

        warnings.warn(
            "fuse_dft on real transforms is deprecated; the r2c/c2r chains "
            "fuse streaming exchanges via the fused/n_chunks fields (or "
            "plan_fft(..., pipeline=...)) -- treating it as fused=True",
            DeprecationWarning,
            stacklevel=3,
        )
        return True
    return cfg.fused


def _check_real_cfg(cfg) -> backends.CollectiveBackend:
    return backends.get(cfg.strategy)


# ---------------------------------------------------------------------------
# Slab r2c / c2r
# ---------------------------------------------------------------------------


def rfft2(
    x: jax.Array,
    mesh: Mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    *,
    pad: bool = True,
) -> jax.Array:
    """Slab-decomposed 2-D r2c FFT of real (..., R, C), R sharded.

    Returns the transposed half spectrum ``(..., Hp, C->R)`` (global
    value ``rfftn(x).swapaxes(-1, -2)`` with ``Hp - H`` zero rows
    appended), Hp-sharded -- the one exchange ships only the Hermitian
    payload. ``cfg.transpose_back`` restores the exact natural
    ``(..., R, H)`` layout with a second (equally truncated) exchange.
    """
    backend = _check_real_cfg(cfg)
    fused = _real_fused(cfg)
    p = mesh.shape[axis_name]
    h, hp = check_divisible_slab(x.shape, p, 2, axis_name, pad=pad)
    if backend.kind == "global":
        return _rfft2_xla_auto(x, mesh, axis_name, hp=hp, transpose_back=cfg.transpose_back)

    def fn(xl: jax.Array) -> jax.Array:
        v = _local_rfft(xl, cfg.local_impl)  # (..., r, H)
        v = _pad_last(v, hp - h)
        # exchange + R-axis FFT, fused into the Hermitian-truncated
        # chunks in flight when the backend streams: (..., hp/P, R)
        v = tr.transpose_then_fft(
            v, axis_name, strategy=cfg.strategy, impl=cfg.local_impl,
            fused=fused, n_chunks=cfg.n_chunks,
        )
        if cfg.transpose_back:
            v = tr.distributed_transpose(
                v, axis_name, strategy=cfg.strategy, n_chunks=cfg.n_chunks
            )
            v = v[..., :h]  # (..., r, H) exact
        return v

    spec = P(*([None] * (x.ndim - 2)), axis_name, None)
    return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)(x)


def irfft2(
    y: jax.Array,
    mesh: Mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    n_last: int = 0,
    *,
    pad: bool = True,
) -> jax.Array:
    """Inverse of :func:`rfft2`: consumes exactly its layout (transposed
    padded half spectrum, or natural when ``cfg.transpose_back``) and
    returns the real (..., R, C=``n_last``), R sharded."""
    backend = _check_real_cfg(cfg)
    if n_last <= 0:
        raise ValueError("irfft2 needs n_last (the original real length of axis -1)")
    p = mesh.shape[axis_name]
    r_glob = y.shape[-2] if cfg.transpose_back else y.shape[-1]
    h, hp = check_divisible_slab(
        y.shape[:-2] + (r_glob, n_last), p, 2, axis_name, pad=pad
    )
    expect = (r_glob, h) if cfg.transpose_back else (hp, r_glob)
    if y.shape[-2:] != expect:
        raise ValueError(
            f"irfft2: spectrum axes {y.shape[-2:]} do not match the rfft2 "
            f"layout {expect} for n_last={n_last} "
            f"(transpose_back={cfg.transpose_back}, pad={pad})"
        )
    if backend.kind == "global":
        return _irfft2_xla_auto(
            y, mesh, axis_name, n_last=n_last, h=h, transpose_back=cfg.transpose_back
        )

    fused = _real_fused(cfg)

    def fn(yl: jax.Array) -> jax.Array:
        v = yl
        if cfg.transpose_back:  # natural (..., r, H): re-enter the spectral layout
            v = _pad_last(v, hp - h)
            # the re-entry exchange + inverse R FFT fuse (conjugated
            # decimation; the trailing transpose stays monolithic)
            v = tr.transpose_then_fft(
                v, axis_name, strategy=cfg.strategy, impl=cfg.local_impl,
                fused=fused, n_chunks=cfg.n_chunks, inverse=True,
            )
        else:
            v = lf.local_fft(v, axis=-1, inverse=True, impl=cfg.local_impl)  # 1/R
        v = tr.distributed_transpose(
            v, axis_name, strategy=cfg.strategy, n_chunks=cfg.n_chunks
        )  # (..., r, Hp)
        return _local_irfft(v[..., :h], n_last, cfg.local_impl)  # (..., r, C), 1/C

    spec = P(*([None] * (y.ndim - 2)), axis_name, None)
    return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)(y)


def rfft3(
    x: jax.Array,
    mesh: Mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    *,
    pad: bool = True,
) -> jax.Array:
    """Slab-decomposed 3-D r2c FFT of real (..., D0, D1, D2), D0 sharded.

    Exact natural output ``(..., D0, D1, H)`` = ``numpy.fft.rfftn`` over
    the last three axes (the internal ``Hp`` padding rides the two
    exchanges flattened with D1 and is trimmed before returning -- the
    trim is free because the Hermitian axis ends up local)."""
    backend = _check_real_cfg(cfg)
    p = mesh.shape[axis_name]
    h, hp = check_divisible_slab(x.shape, p, 3, axis_name, pad=pad)
    d1 = x.shape[-2]
    spec = P(*([None] * (x.ndim - 3)), axis_name, None, None)
    if backend.kind == "global":
        sh = NamedSharding(mesh, spec)
        out_sh = NamedSharding(mesh, spec)
        return jax.jit(
            lambda v: jnp.fft.rfftn(v, axes=(-3, -2, -1)),
            in_shardings=sh, out_shardings=out_sh,
        )(x)

    fused = _real_fused(cfg)

    def fn(xl: jax.Array) -> jax.Array:
        v = _local_rfft(xl, cfg.local_impl)  # (..., d0, D1, H)
        v = _pad_last(v, hp - h)
        v = lf.local_fft(v, axis=-2, impl=cfg.local_impl)  # c2c along D1
        flat = v.reshape(v.shape[:-2] + (d1 * hp,))
        # exchange + D0 FFT fused into the truncated chunks in flight
        t = tr.transpose_then_fft(
            flat, axis_name, strategy=cfg.strategy, impl=cfg.local_impl,
            fused=fused, n_chunks=cfg.n_chunks,
        )
        back = tr.distributed_transpose(
            t, axis_name, strategy=cfg.strategy, n_chunks=cfg.n_chunks
        )
        return back.reshape(v.shape)[..., :h]

    return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)(x)


def irfft3(
    y: jax.Array,
    mesh: Mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    n_last: int = 0,
    *,
    pad: bool = True,
) -> jax.Array:
    """Inverse of :func:`rfft3`: natural half spectrum (..., D0, D1, H)
    to the real (..., D0, D1, ``n_last``), D0 sharded."""
    backend = _check_real_cfg(cfg)
    if n_last <= 0:
        raise ValueError("irfft3 needs n_last (the original real length of axis -1)")
    p = mesh.shape[axis_name]
    h, hp = check_divisible_slab(y.shape[:-1] + (n_last,), p, 3, axis_name, pad=pad)
    if y.shape[-1] != h:
        raise ValueError(
            f"irfft3: Hermitian axis has length {y.shape[-1]}, expected "
            f"{n_last}//2+1={h} for n_last={n_last}"
        )
    d1 = y.shape[-2]
    spec = P(*([None] * (y.ndim - 3)), axis_name, None, None)
    if backend.kind == "global":
        sh = NamedSharding(mesh, spec)
        return jax.jit(
            lambda v: jnp.fft.irfftn(v, s=y.shape[-3:-1] + (n_last,), axes=(-3, -2, -1)),
            in_shardings=sh, out_shardings=sh,
        )(y)

    fused = _real_fused(cfg)

    def fn(yl: jax.Array) -> jax.Array:
        v = _pad_last(yl, hp - h)
        flat = v.reshape(v.shape[:-2] + (d1 * hp,))
        # exchange + inverse D0 FFT fused (conjugated decimation): 1/D0
        t = tr.transpose_then_fft(
            flat, axis_name, strategy=cfg.strategy, impl=cfg.local_impl,
            fused=fused, n_chunks=cfg.n_chunks, inverse=True,
        )
        back = tr.distributed_transpose(
            t, axis_name, strategy=cfg.strategy, n_chunks=cfg.n_chunks
        )
        v = back.reshape(v.shape)
        v = lf.local_fft(v, axis=-2, inverse=True, impl=cfg.local_impl)  # 1/D1
        return _local_irfft(v[..., :h], n_last, cfg.local_impl)  # 1/D2

    return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)(y)


def _rfft2_xla_auto(x, mesh, axis_name, *, hp: int, transpose_back: bool):
    """GSPMD reference for the slab r2c: same layout contract as the
    shard_map path (padded transposed spectrum / exact natural)."""
    spec = P(*([None] * (x.ndim - 2)), axis_name, None)
    sh = NamedSharding(mesh, spec)

    def fn(v):
        y = jnp.fft.rfft2(v)
        if transpose_back:
            return y
        y = jnp.swapaxes(y, -1, -2)
        return jnp.pad(y, [(0, 0)] * (y.ndim - 2) + [(0, hp - y.shape[-2]), (0, 0)])

    return jax.jit(fn, in_shardings=sh, out_shardings=sh)(x)


def _irfft2_xla_auto(y, mesh, axis_name, *, n_last: int, h: int, transpose_back: bool):
    spec = P(*([None] * (y.ndim - 2)), axis_name, None)
    sh = NamedSharding(mesh, spec)
    r_glob = y.shape[-2] if transpose_back else y.shape[-1]

    def fn(v):
        if not transpose_back:
            v = jnp.swapaxes(v[..., :h, :], -1, -2)
        return jnp.fft.irfft2(v, s=(r_glob, n_last))

    return jax.jit(fn, in_shardings=sh, out_shardings=sh)(y)


# ---------------------------------------------------------------------------
# Pencil r2c / c2r
# ---------------------------------------------------------------------------


def pencil_rfft3(
    x: jax.Array,
    grid: ProcessGrid,
    cfg: PencilConfig = PencilConfig(),
    *,
    pad: bool = True,
) -> jax.Array:
    """Pencil-decomposed 3-D r2c FFT of real (..., D0, D1, D2) with D0
    sharded over ``grid.row_axis`` and D1 over ``grid.col_axis``.

    Returns the reversed-axes half spectrum ``(..., Hp, D1, D0)``
    (global value ``rfftn(x).transpose(..., -1, -2, -3)`` with zero rows
    appended) sharded (Hp over cols, D1 over rows) -- the c2c pencil
    convention on the truncated payload. ``cfg.transpose_back`` restores
    the exact natural ``(..., D0, D1, H)`` with two more sub-exchanges.
    """
    _check_backends(cfg, grid)
    fused = _real_fused(cfg)
    h, hp = check_divisible_pencil(x.shape, grid, 3, pad=pad)
    row, col = grid.row_axis, grid.col_axis

    def fn(xl: jax.Array) -> jax.Array:
        v = _local_rfft(xl, cfg.local_impl)  # (..., d0r, d1c, H)
        v = _pad_last(v, hp - h)
        # cols sub-exchange swaps (D1, Hp) with the D1 FFT fused into
        # the truncated chunks: (d0r, d1c, Hp) -> (d0r, hp_c, D1)
        v = tr.transpose_then_fft(
            v, col, strategy=cfg.backend_col, impl=cfg.local_impl,
            fused=fused, n_chunks=cfg.n_chunks,
        )
        v = jnp.swapaxes(v, -3, -2)  # (hp_c, d0r, D1)
        # rows sub-exchange + D0 FFT, fused independently per leg
        v = tr.transpose_then_fft(
            v, row, strategy=cfg.backend_row, impl=cfg.local_impl,
            fused=fused, n_chunks=cfg.n_chunks,
        )  # (hp_c, d1r, D0)
        if cfg.transpose_back:
            v = tr.distributed_transpose(
                v, row, strategy=cfg.backend_row, n_chunks=cfg.n_chunks
            )
            v = jnp.swapaxes(v, -3, -2)  # (d0r, hp_c, D1)
            v = tr.distributed_transpose(
                v, col, strategy=cfg.backend_col, n_chunks=cfg.n_chunks
            )
            v = v[..., :h]  # (d0r, d1c, H) exact
        return v

    lead = [None] * (x.ndim - 3)
    in_spec = P(*lead, row, col, None)
    out_spec = in_spec if cfg.transpose_back else P(*lead, col, row, None)
    return shard_map(fn, mesh=grid.mesh, in_specs=in_spec, out_specs=out_spec)(x)


def pencil_irfft3(
    y: jax.Array,
    grid: ProcessGrid,
    cfg: PencilConfig = PencilConfig(),
    n_last: int = 0,
    *,
    pad: bool = True,
) -> jax.Array:
    """Inverse of :func:`pencil_rfft3`: consumes exactly its layout
    (reversed padded half spectrum, or exact natural when
    ``cfg.transpose_back``) and returns the real
    (..., D0, D1, ``n_last``) sharded (rows, cols)."""
    _check_backends(cfg, grid)
    if n_last <= 0:
        raise ValueError("pencil_irfft3 needs n_last (the original real length of axis -1)")
    if cfg.transpose_back:
        d0, d1 = y.shape[-3], y.shape[-2]
    else:
        d0, d1 = y.shape[-1], y.shape[-2]
    h, hp = check_divisible_pencil(y.shape[:-3] + (d0, d1, n_last), grid, 3, pad=pad)
    expect = (d0, d1, h) if cfg.transpose_back else (hp, d1, d0)
    if y.shape[-3:] != expect:
        raise ValueError(
            f"pencil_irfft3: spectrum axes {y.shape[-3:]} do not match the "
            f"pencil_rfft3 layout {expect} for n_last={n_last} "
            f"(transpose_back={cfg.transpose_back}, pad={pad})"
        )
    row, col = grid.row_axis, grid.col_axis
    fused = _real_fused(cfg)

    def fn(yl: jax.Array) -> jax.Array:
        v = yl
        if cfg.transpose_back:  # natural (d0r, d1c, H): re-enter the spectral layout
            v = _pad_last(v, hp - h)
            v = tr.distributed_transpose(
                v, col, strategy=cfg.backend_col, n_chunks=cfg.n_chunks
            )  # (d0r, hp_c, D1)
            v = jnp.swapaxes(v, -3, -2)  # (hp_c, d0r, D1)
            # re-entry rows exchange + inverse D0 FFT fuse: (hp_c, d1r, D0)
            v = tr.transpose_then_fft(
                v, row, strategy=cfg.backend_row, impl=cfg.local_impl,
                fused=fused, n_chunks=cfg.n_chunks, inverse=True,
            )  # 1/D0
        else:
            v = lf.local_fft(v, axis=-1, inverse=True, impl=cfg.local_impl)  # 1/D0
        # rows exchange + inverse D1 FFT fuse: (hp_c, d0r, D1), 1/D1
        v = tr.transpose_then_fft(
            v, row, strategy=cfg.backend_row, impl=cfg.local_impl,
            fused=fused, n_chunks=cfg.n_chunks, inverse=True,
        )
        v = jnp.swapaxes(v, -3, -2)  # (d0r, hp_c, D1)
        v = tr.distributed_transpose(
            v, col, strategy=cfg.backend_col, n_chunks=cfg.n_chunks
        )  # (d0r, d1c, Hp)
        return _local_irfft(v[..., :h], n_last, cfg.local_impl)  # 1/D2

    lead = [None] * (y.ndim - 3)
    in_spec = P(*lead, row, col, None) if cfg.transpose_back else P(*lead, col, row, None)
    out_spec = P(*lead, row, col, None)
    return shard_map(fn, mesh=grid.mesh, in_specs=in_spec, out_specs=out_spec)(y)


def pencil_rfft2(
    x: jax.Array,
    grid: ProcessGrid,
    cfg: PencilConfig = PencilConfig(),
    *,
    pad: bool = True,
) -> jax.Array:
    """Pencil-decomposed 2-D r2c FFT of real (..., R, C) with R sharded
    over ``grid.row_axis`` and C over ``grid.col_axis``.

    Natural-layout output ``(..., R, Hp)`` sharded (rows, cols), zero
    columns beyond ``H``. Like the c2c :func:`~repro.core.pencil.pencil_fft2`
    this is four sub-exchanges -- but only the first (which localizes the
    real axis for the r2c pass) ships full-width data, and it ships it at
    the *real* dtype: every complex exchange carries the truncated
    payload. ``transpose_back`` is rejected (already natural)."""
    if cfg.transpose_back:
        raise ValueError(
            "pencil rfft2 already returns the natural layout; "
            "transpose_back applies to slab transforms and pencil rfft3 only"
        )
    _check_backends(cfg, grid)
    h, hp = check_divisible_pencil(x.shape, grid, 2, pad=pad)
    row, col = grid.row_axis, grid.col_axis

    fused = _real_fused(cfg)

    def fn(xl: jax.Array) -> jax.Array:
        # pass A -- localize C over the cols sub-ring (real payload),
        # r2c it, and re-shard the truncated half spectrum back (the r2c
        # pass itself stays local -- its input is real, not a c2c stage)
        v = jnp.swapaxes(xl, -1, -2)  # (c_c, r_r)
        v = tr.distributed_transpose(
            v, col, strategy=cfg.backend_col, n_chunks=cfg.n_chunks
        )  # (r_rc, C)
        v = _local_rfft(v, cfg.local_impl)  # (r_rc, H)
        v = _pad_last(v, hp - h)
        v = tr.distributed_transpose(
            v, col, strategy=cfg.backend_col, n_chunks=cfg.n_chunks
        )  # (hp_c, r_r)
        v = jnp.swapaxes(v, -1, -2)  # (r_r, hp_c)
        # pass B -- c2c transform R over the rows sub-ring (half
        # payload), the R FFT fused into the arriving chunks
        v = tr.transpose_then_fft(
            v, row, strategy=cfg.backend_row, impl=cfg.local_impl,
            fused=fused, n_chunks=cfg.n_chunks,
        )  # (hp_rc, R)
        v = tr.distributed_transpose(
            v, row, strategy=cfg.backend_row, n_chunks=cfg.n_chunks
        )  # (r_r, hp_c)
        return v

    spec = P(*([None] * (x.ndim - 2)), row, col)
    return shard_map(fn, mesh=grid.mesh, in_specs=spec, out_specs=spec)(x)


def pencil_irfft2(
    y: jax.Array,
    grid: ProcessGrid,
    cfg: PencilConfig = PencilConfig(),
    n_last: int = 0,
    *,
    pad: bool = True,
) -> jax.Array:
    """Inverse of :func:`pencil_rfft2`: padded natural half spectrum
    (..., R, Hp) to the real (..., R, ``n_last``), both (rows, cols)
    sharded. The final (real-payload) exchange restores the real layout."""
    if cfg.transpose_back:
        raise ValueError(
            "pencil irfft2 consumes the natural layout; transpose_back "
            "applies to slab transforms and pencil rfft3 only"
        )
    _check_backends(cfg, grid)
    if n_last <= 0:
        raise ValueError("pencil_irfft2 needs n_last (the original real length of axis -1)")
    h, hp = check_divisible_pencil(y.shape[:-1] + (n_last,), grid, 2, pad=pad)
    if y.shape[-1] != hp:
        raise ValueError(
            f"pencil_irfft2: Hermitian axis has length {y.shape[-1]}, expected "
            f"the padded {hp} (H={h}) for n_last={n_last} on grid "
            f"{grid.p_rows}x{grid.p_cols} (pad={pad})"
        )
    row, col = grid.row_axis, grid.col_axis

    fused = _real_fused(cfg)

    def fn(yl: jax.Array) -> jax.Array:
        # rows exchange + inverse R FFT fuse: (hp_rc, R), 1/R
        v = tr.transpose_then_fft(
            yl, row, strategy=cfg.backend_row, impl=cfg.local_impl,
            fused=fused, n_chunks=cfg.n_chunks, inverse=True,
        )
        v = tr.distributed_transpose(
            v, row, strategy=cfg.backend_row, n_chunks=cfg.n_chunks
        )  # (r_r, hp_c)
        v = jnp.swapaxes(v, -1, -2)  # (hp_c, r_r)
        v = tr.distributed_transpose(
            v, col, strategy=cfg.backend_col, n_chunks=cfg.n_chunks
        )  # (r_rc, Hp)
        v = _local_irfft(v[..., :h], n_last, cfg.local_impl)  # (r_rc, C), 1/C
        v = tr.distributed_transpose(
            v, col, strategy=cfg.backend_col, n_chunks=cfg.n_chunks
        )  # (c_c, r_r)
        return jnp.swapaxes(v, -1, -2)  # (r_r, c_c)

    spec = P(*([None] * (y.ndim - 2)), row, col)
    return shard_map(fn, mesh=grid.mesh, in_specs=spec, out_specs=spec)(y)
