"""Distributed real-to-complex FFTs (rfftn / irfftn) over the backend
registry -- half the wire bytes for real-input workloads.

The paper's FFTW3+MPI reference is what scientific users drive with
*real* data: an r2c transform keeps only the Hermitian-non-redundant
half of the last axis (``H = N//2 + 1`` complex values instead of ``N``),
so every pencil exchange after the first local pass ships ~half the
bytes of the complex-to-complex path. The structure mirrors
:mod:`repro.core.distributed_fft` / :mod:`repro.core.pencil`:

- the r2c pass runs **locally on the contiguous last axis** (it is the
  only pass whose input is real);
- every remaining pass is an ordinary c2c FFT fed through the same
  strategy-switched :func:`repro.core.transpose.distributed_transpose`,
  so the whole parcelport axis (backend registry, per-axis pencil
  backends, measured planner) applies unchanged -- just on the truncated
  payload;
- c2r mirrors the chain in reverse and restores the real layout.

**The N//2+1 divisibility problem.** ``H`` is almost never divisible by
the shard count (it is odd whenever ``N`` is even), so the Hermitian
axis cannot be re-sharded as-is. With ``pad=True`` (default) the half
spectrum is zero-padded to the next divisible length ``Hp`` before the
exchange and the pad is trimmed wherever the axis ends up local again
(the plan records ``hermitian_len``/``padded_hermitian_len``); the
padded tail is exactly zero (FFTs of zeros), so layouts that keep it
are still numerically exact. With ``pad=False`` a non-divisible ``H``
raises a plan-time ``ValueError`` naming the offending data axis and
mesh/grid dimension, in the same style as the c2c validators.

Spectrum layouts (global values; ``H``/``Hp`` along the original last
axis):

====================  =====================================================
slab ``rfft2``        ``(..., Hp, R)`` transposed, Hp-sharded (the slab
                      c2c convention); ``transpose_back`` -> exact
                      natural ``(..., R, H)``
slab ``rfft3``        natural ``(..., D0, D1, H)``, D0-sharded (exact)
pencil ``rfft2``      natural ``(..., R, Hp)``, (rows, cols)-sharded
pencil ``rfft3``      reversed ``(..., Hp, D1, D0)``, (cols, rows)-sharded;
                      ``transpose_back`` -> exact natural
====================  =====================================================

Each ``irfft*`` consumes exactly the layout its ``rfft*`` produces.
``n_last`` (the original real length) is explicit on every inverse --
``H`` alone cannot distinguish even ``2*(H-1)`` from odd ``2*H-1``.

Every transform is a thin builder over :mod:`repro.core.schedule`: the
r2c/c2r chains lower to declarative stage schedules (the inverse chains
are structurally reversed schedules with conjugated tables) and run
through the one interpreter, the same object the cost model and the
byte accounting walk. The Hermitian helpers and the shard-divisibility
validators live there too; this module re-exports them under their
historical names.
"""

from __future__ import annotations

import jax

import repro.core.schedule as sch
from repro.core import backends
from repro.core.distributed_fft import FFTConfig
from repro.core.grid import ProcessGrid
from repro.core.pencil import PencilConfig, _check_backends
from repro.core.schedule import (  # noqa: F401  (re-exported API)
    _pad_disabled_hint,
    padded_rfft_len,
    rfft_len,
)

# historical private names, re-exported for the impl-switched local passes
_local_rfft = sch.local_rfft
_local_irfft = sch.local_irfft
_pad_last = sch.pad_last


def check_divisible_slab(global_shape, p: int, ndim: int, axis_name, *, pad: bool = True):
    """Validate a slab r2c problem; returns ``(h, hp)`` for the Hermitian
    axis. Raises a ValueError naming the offending data axis and mesh
    axis -- delegates to the one schedule-level validator
    (:func:`repro.core.schedule.check_divisible`)."""
    return sch.check_divisible(
        global_shape, ndim, p=p, axis_name=axis_name, real=True, pad=pad
    )


def check_divisible_pencil(global_shape, grid: ProcessGrid, ndim: int, *, pad: bool = True):
    """Validate a pencil r2c problem; returns ``(h, hp)``. Errors name
    the data axis and grid dimension -- delegates to the one
    schedule-level validator."""
    return sch.check_divisible(
        global_shape, ndim, p_rows=grid.p_rows, p_cols=grid.p_cols,
        row_axis=grid.row_axis, col_axis=grid.col_axis, real=True, pad=pad,
    )


def _real_fused(cfg) -> bool:
    """Whether this config asks for fused (chunk-streamed) exchanges.

    ``fuse_dft`` used to hard-error here ("the real transforms have no
    fused path"); the pipelined overlap executor IS that path now, so
    the flag is honoured as a deprecated alias of ``fused`` -- new code
    spells it ``plan_fft(..., pipeline=...)``."""
    if getattr(cfg, "fuse_dft", False):
        import warnings

        warnings.warn(
            "fuse_dft on real transforms is deprecated; the r2c/c2r chains "
            "fuse streaming exchanges via the fused/n_chunks fields (or "
            "plan_fft(..., pipeline=...)) -- treating it as fused=True",
            DeprecationWarning,
            stacklevel=3,
        )
        return True
    return cfg.fused


def _check_real_cfg(cfg) -> backends.CollectiveBackend:
    return backends.get(cfg.strategy)


def _build_slab(shape, mesh, axis_name, cfg, *, ndim, inverse, pad) -> sch.Schedule:
    return sch.build_schedule(
        shape, ndim=ndim, inverse=inverse, real=True, decomp="slab",
        axis_name=axis_name, p=mesh.shape[axis_name], backend=cfg.strategy,
        fused=_real_fused(cfg), n_chunks=cfg.n_chunks,
        transpose_back=cfg.transpose_back, pad=pad,
    )


def _build_pencil(shape, grid, cfg, *, ndim, inverse, pad) -> sch.Schedule:
    return sch.build_schedule(
        shape, ndim=ndim, inverse=inverse, real=True, decomp="pencil",
        row_axis=grid.row_axis, col_axis=grid.col_axis,
        p_rows=grid.p_rows, p_cols=grid.p_cols,
        backend_row=cfg.backend_row, backend_col=cfg.backend_col,
        fused=_real_fused(cfg), n_chunks=cfg.n_chunks,
        transpose_back=cfg.transpose_back, pad=pad,
    )


# ---------------------------------------------------------------------------
# Slab r2c / c2r
# ---------------------------------------------------------------------------


def rfft2(
    x: jax.Array,
    mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    *,
    pad: bool = True,
) -> jax.Array:
    """Slab-decomposed 2-D r2c FFT of real (..., R, C), R sharded.

    Returns the transposed half spectrum ``(..., Hp, C->R)`` (global
    value ``rfftn(x).swapaxes(-1, -2)`` with ``Hp - H`` zero rows
    appended), Hp-sharded -- the one exchange ships only the Hermitian
    payload. ``cfg.transpose_back`` restores the exact natural
    ``(..., R, H)`` layout with a second (equally truncated) exchange.
    """
    _check_real_cfg(cfg)
    plan = _build_slab(x.shape, mesh, axis_name, cfg, ndim=2, inverse=False, pad=pad)
    return sch.run_schedule(x, plan, mesh, impl=cfg.local_impl)


def irfft2(
    y: jax.Array,
    mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    n_last: int = 0,
    *,
    pad: bool = True,
) -> jax.Array:
    """Inverse of :func:`rfft2`: consumes exactly its layout (transposed
    padded half spectrum, or natural when ``cfg.transpose_back``) and
    returns the real (..., R, C=``n_last``), R sharded."""
    _check_real_cfg(cfg)
    if n_last <= 0:
        raise ValueError("irfft2 needs n_last (the original real length of axis -1)")
    r_glob = y.shape[-2] if cfg.transpose_back else y.shape[-1]
    shape = y.shape[:-2] + (r_glob, n_last)
    plan = _build_slab(shape, mesh, axis_name, cfg, ndim=2, inverse=True, pad=pad)
    h, hp = plan.h, plan.hp
    expect = (r_glob, h) if cfg.transpose_back else (hp, r_glob)
    if y.shape[-2:] != expect:
        raise ValueError(
            f"irfft2: spectrum axes {y.shape[-2:]} do not match the rfft2 "
            f"layout {expect} for n_last={n_last} "
            f"(transpose_back={cfg.transpose_back}, pad={pad})"
        )
    return sch.run_schedule(y, plan, mesh, impl=cfg.local_impl)


def rfft3(
    x: jax.Array,
    mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    *,
    pad: bool = True,
) -> jax.Array:
    """Slab-decomposed 3-D r2c FFT of real (..., D0, D1, D2), D0 sharded.

    Exact natural output ``(..., D0, D1, H)`` = ``numpy.fft.rfftn`` over
    the last three axes (the internal ``Hp`` padding rides the two
    exchanges flattened with D1 and is trimmed before returning -- the
    trim is free because the Hermitian axis ends up local)."""
    _check_real_cfg(cfg)
    plan = _build_slab(x.shape, mesh, axis_name, cfg, ndim=3, inverse=False, pad=pad)
    return sch.run_schedule(x, plan, mesh, impl=cfg.local_impl)


def irfft3(
    y: jax.Array,
    mesh,
    axis_name: str,
    cfg: FFTConfig = FFTConfig(),
    n_last: int = 0,
    *,
    pad: bool = True,
) -> jax.Array:
    """Inverse of :func:`rfft3`: natural half spectrum (..., D0, D1, H)
    to the real (..., D0, D1, ``n_last``), D0 sharded."""
    _check_real_cfg(cfg)
    if n_last <= 0:
        raise ValueError("irfft3 needs n_last (the original real length of axis -1)")
    shape = y.shape[:-1] + (n_last,)
    plan = _build_slab(shape, mesh, axis_name, cfg, ndim=3, inverse=True, pad=pad)
    h = plan.h
    if y.shape[-1] != h:
        raise ValueError(
            f"irfft3: Hermitian axis has length {y.shape[-1]}, expected "
            f"{n_last}//2+1={h} for n_last={n_last}"
        )
    return sch.run_schedule(y, plan, mesh, impl=cfg.local_impl)


# ---------------------------------------------------------------------------
# Pencil r2c / c2r
# ---------------------------------------------------------------------------


def pencil_rfft3(
    x: jax.Array,
    grid: ProcessGrid,
    cfg: PencilConfig = PencilConfig(),
    *,
    pad: bool = True,
) -> jax.Array:
    """Pencil-decomposed 3-D r2c FFT of real (..., D0, D1, D2) with D0
    sharded over ``grid.row_axis`` and D1 over ``grid.col_axis``.

    Returns the reversed-axes half spectrum ``(..., Hp, D1, D0)``
    (global value ``rfftn(x).transpose(..., -1, -2, -3)`` with zero rows
    appended) sharded (Hp over cols, D1 over rows) -- the c2c pencil
    convention on the truncated payload. ``cfg.transpose_back`` restores
    the exact natural ``(..., D0, D1, H)`` with two more sub-exchanges.
    """
    _check_backends(cfg, grid)
    plan = _build_pencil(x.shape, grid, cfg, ndim=3, inverse=False, pad=pad)
    return sch.run_schedule(x, plan, grid.mesh, impl=cfg.local_impl)


def pencil_irfft3(
    y: jax.Array,
    grid: ProcessGrid,
    cfg: PencilConfig = PencilConfig(),
    n_last: int = 0,
    *,
    pad: bool = True,
) -> jax.Array:
    """Inverse of :func:`pencil_rfft3`: consumes exactly its layout
    (reversed padded half spectrum, or exact natural when
    ``cfg.transpose_back``) and returns the real
    (..., D0, D1, ``n_last``) sharded (rows, cols)."""
    _check_backends(cfg, grid)
    if n_last <= 0:
        raise ValueError("pencil_irfft3 needs n_last (the original real length of axis -1)")
    if cfg.transpose_back:
        d0, d1 = y.shape[-3], y.shape[-2]
    else:
        d0, d1 = y.shape[-1], y.shape[-2]
    shape = y.shape[:-3] + (d0, d1, n_last)
    plan = _build_pencil(shape, grid, cfg, ndim=3, inverse=True, pad=pad)
    h, hp = plan.h, plan.hp
    expect = (d0, d1, h) if cfg.transpose_back else (hp, d1, d0)
    if y.shape[-3:] != expect:
        raise ValueError(
            f"pencil_irfft3: spectrum axes {y.shape[-3:]} do not match the "
            f"pencil_rfft3 layout {expect} for n_last={n_last} "
            f"(transpose_back={cfg.transpose_back}, pad={pad})"
        )
    return sch.run_schedule(y, plan, grid.mesh, impl=cfg.local_impl)


def pencil_rfft2(
    x: jax.Array,
    grid: ProcessGrid,
    cfg: PencilConfig = PencilConfig(),
    *,
    pad: bool = True,
) -> jax.Array:
    """Pencil-decomposed 2-D r2c FFT of real (..., R, C) with R sharded
    over ``grid.row_axis`` and C over ``grid.col_axis``.

    Natural-layout output ``(..., R, Hp)`` sharded (rows, cols), zero
    columns beyond ``H``. Like the c2c :func:`~repro.core.pencil.pencil_fft2`
    this is four sub-exchanges -- but only the first (which localizes the
    real axis for the r2c pass) ships full-width data, and it ships it at
    the *real* dtype: every complex exchange carries the truncated
    payload. ``transpose_back`` is rejected (already natural)."""
    if cfg.transpose_back:
        raise ValueError(
            "pencil rfft2 already returns the natural layout; "
            "transpose_back applies to slab transforms and pencil rfft3 only"
        )
    _check_backends(cfg, grid)
    plan = _build_pencil(x.shape, grid, cfg, ndim=2, inverse=False, pad=pad)
    return sch.run_schedule(x, plan, grid.mesh, impl=cfg.local_impl)


def pencil_irfft2(
    y: jax.Array,
    grid: ProcessGrid,
    cfg: PencilConfig = PencilConfig(),
    n_last: int = 0,
    *,
    pad: bool = True,
) -> jax.Array:
    """Inverse of :func:`pencil_rfft2`: padded natural half spectrum
    (..., R, Hp) to the real (..., R, ``n_last``), both (rows, cols)
    sharded. The final (real-payload) exchange restores the real layout."""
    if cfg.transpose_back:
        raise ValueError(
            "pencil irfft2 consumes the natural layout; transpose_back "
            "applies to slab transforms and pencil rfft3 only"
        )
    _check_backends(cfg, grid)
    if n_last <= 0:
        raise ValueError("pencil_irfft2 needs n_last (the original real length of axis -1)")
    shape = y.shape[:-1] + (n_last,)
    plan = _build_pencil(shape, grid, cfg, ndim=2, inverse=True, pad=pad)
    h, hp = plan.h, plan.hp
    if y.shape[-1] != hp:
        raise ValueError(
            f"pencil_irfft2: Hermitian axis has length {y.shape[-1]}, expected "
            f"the padded {hp} (H={h}) for n_last={n_last} on grid "
            f"{grid.p_rows}x{grid.p_cols} (pad={pad})"
        )
    return sch.run_schedule(y, plan, grid.mesh, impl=cfg.local_impl)
