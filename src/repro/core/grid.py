"""2-D process grids for pencil decomposition.

The paper's FFT benchmark shards over a *single* mesh axis (slab
decomposition), which caps parallelism at P <= N and forces one global
exchange over all P ranks. The companion FFT case-study points at richer
decompositions: arrange the P processes as a (P_row x P_col) **pencil
grid** so each transpose becomes a *sub-axis* exchange over only P_row
or P_col ranks -- smaller rings, more parallelism, and (because each
sub-exchange goes through the backend registry independently) a 2-D
analogue of the paper's parcelport switch.

:class:`ProcessGrid` is the thin, validated handle the rest of the stack
passes around: a jax :class:`~jax.sharding.Mesh` plus which two of its
axes play the row/column roles. It deliberately does NOT own the mesh's
device placement -- build the mesh however you like (``make_grid`` is
the convenience path) and wrap it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from jax.sharding import Mesh

#: Preferred mesh-axis names for the pencil grid, in (row, col) order.
#: ``grid_from_mesh`` looks for these first; any 2-axis mesh works via
#: explicit ``row_axis=`` / ``col_axis=``.
GRID_AXES: Tuple[str, str] = ("rows", "cols")


@dataclasses.dataclass(frozen=True)
class ProcessGrid:
    """A (P_row x P_col) view of two axes of a mesh.

    ``row_axis`` shards the leading transform dimension; ``col_axis``
    shards the next one. The pencil transforms exchange over each axis
    independently (one sub-ring of size ``p_rows``, one of ``p_cols``),
    which is what lets ``backend_row`` / ``backend_col`` differ.
    """

    mesh: Mesh
    row_axis: str = GRID_AXES[0]
    col_axis: str = GRID_AXES[1]

    def __post_init__(self):
        if self.row_axis == self.col_axis:
            raise ValueError(
                f"pencil grid needs two distinct mesh axes, got "
                f"row_axis == col_axis == {self.row_axis!r}"
            )
        for role, ax in (("row_axis", self.row_axis), ("col_axis", self.col_axis)):
            if ax not in self.mesh.shape:
                raise ValueError(
                    f"{role}={ax!r} is not an axis of the mesh "
                    f"(mesh axes: {list(self.mesh.shape)})"
                )

    @property
    def p_rows(self) -> int:
        return int(self.mesh.shape[self.row_axis])

    @property
    def p_cols(self) -> int:
        return int(self.mesh.shape[self.col_axis])

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.p_rows, self.p_cols)

    @property
    def size(self) -> int:
        """Total shards participating in the pencil decomposition."""
        return self.p_rows * self.p_cols

    def axis_of(self, role: str) -> str:
        """Mesh axis name for ``"row"`` or ``"col"``."""
        if role == "row":
            return self.row_axis
        if role == "col":
            return self.col_axis
        raise ValueError(f"role must be 'row' or 'col', got {role!r}")

    def __repr__(self) -> str:
        return (
            f"ProcessGrid({self.p_rows}x{self.p_cols}, "
            f"row_axis={self.row_axis!r}, col_axis={self.col_axis!r})"
        )


def make_grid(
    shape: Tuple[int, int],
    axis_names: Tuple[str, str] = GRID_AXES,
    devices: Optional[Sequence] = None,
) -> ProcessGrid:
    """Build a fresh (P_row x P_col) mesh and wrap it as a ProcessGrid.

    Uses the first ``P_row * P_col`` local devices unless ``devices`` is
    given (then reshaped row-major, rows varying slowest -- adjacent
    devices end up in the same row sub-ring, the locality a torus wants).
    """
    import numpy as np

    from repro.core.compat import make_mesh

    pr, pc = int(shape[0]), int(shape[1])
    if pr < 1 or pc < 1:
        raise ValueError(f"grid shape must be positive, got {(pr, pc)}")
    if devices is None:
        return ProcessGrid(make_mesh((pr, pc), tuple(axis_names)), *axis_names)
    devs = np.asarray(devices)
    if devs.size != pr * pc:
        raise ValueError(f"grid {pr}x{pc} needs {pr * pc} devices, got {devs.size}")
    return ProcessGrid(Mesh(devs.reshape(pr, pc), tuple(axis_names)), *axis_names)


def grid_from_mesh(
    mesh: Mesh,
    row_axis: Optional[str] = None,
    col_axis: Optional[str] = None,
) -> ProcessGrid:
    """Resolve the pencil grid on an existing mesh.

    Explicit ``row_axis``/``col_axis`` always win. Otherwise the
    conventional :data:`GRID_AXES` names are used when both exist, else
    the mesh's last two axes (mirroring ``fft_axis``'s last-axis
    fallback for slab). A 1-axis mesh has no pencil grid -- that is a
    ``ValueError`` here, which ``plan_fft(decomp="auto")`` catches to
    fall back to slab.
    """
    axes = list(mesh.shape)
    if row_axis is not None or col_axis is not None:
        if row_axis is None or col_axis is None:
            raise ValueError("pass both row_axis and col_axis, or neither")
        return ProcessGrid(mesh, row_axis, col_axis)
    if all(a in mesh.shape for a in GRID_AXES):
        return ProcessGrid(mesh, *GRID_AXES)
    if len(axes) < 2:
        raise ValueError(
            f"pencil decomposition needs a mesh with >= 2 axes "
            f"(got axes {axes}); build one with repro.core.grid.make_grid"
        )
    return ProcessGrid(mesh, axes[-2], axes[-1])


def grid_shapes(p: int) -> List[Tuple[int, int]]:
    """Every (P_row, P_col) factorization of ``p``, rows ascending --
    the sweep set for the slab-vs-pencil benchmarks."""
    if p < 1:
        raise ValueError(f"process count must be positive, got {p}")
    return [(d, p // d) for d in range(1, p + 1) if p % d == 0]


def auto_grid_shape(p: int) -> Tuple[int, int]:
    """Most-square (P_row, P_col) factorization with P_row <= P_col.

    Squarer grids minimize the larger sub-ring, hence the larger of the
    two exchange costs -- the default the ROADMAP's 'scale further'
    direction wants when nothing is pinned."""
    if p < 1:
        raise ValueError(f"process count must be positive, got {p}")
    pr = 1
    for d in range(1, int(math.isqrt(p)) + 1):
        if p % d == 0:
            pr = d
    return (pr, p // pr)
