"""Logical-axis sharding rules (MaxText-style) + mesh helpers.

Model code annotates arrays with *logical* axis names; the rules below
map them onto whatever mesh axes exist. Missing mesh axes resolve to
replication, so the same model code runs on the 1-device test mesh, the
single-pod (16,16) mesh and the multi-pod (2,16,16) mesh unchanged.

Scheme (see DESIGN.md §5): DP over ('pod','data') for activations; FSDP
(weight d_model/embed dim) over ('pod','data'); TP (heads / d_ff / vocab
/ experts) over 'model'. GSPMD pads non-divisible dims (e.g. qwen2.5's
40 heads on 16-way TP), keeping every assigned arch runnable.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, None]

# logical name -> tuple of preferred mesh axes (first existing ones kept)
DEFAULT_RULES: dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),  # weight sharding along d_model/embed dim
    "tp": ("model",),  # heads / d_ff / experts / vocab
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "expert_cap": ("model",),  # fallback when expert count < TP width
    "mlp": ("model",),
    "seq": (),  # sequence kept unsharded by default
    "seq_shard": ("data",),  # explicit sequence parallelism (long-context)
    "seq_act": ("model",),  # Megatron-style SP: saved residual stream seq dim
    "embed": (),  # activation d_model dim: replicated
    "fft_rows": ("model",),  # FFT pencil decomposition
}


def resolve(mesh: Mesh, *logical: Axis, shape: Optional[Sequence[int]] = None) -> P:
    """Map logical axis names to a PartitionSpec valid for ``mesh``.

    With ``shape`` given, the resolution is *shape-aware*: a mesh axis is
    only claimed by a dim it evenly divides, and unclaimed axes remain
    available for later dims. Input shardings (unlike internal
    constraints) must divide exactly, and this rule is also what routes
    the TP axis to d_ff when an arch's expert/head count doesn't divide
    it (mixtral's 8 experts, qwen's 40 heads -> flattened head dims).
    """
    used: set[str] = set()
    out = []
    for i, name in enumerate(logical):
        if name is None:
            out.append(None)
            continue
        axes = [a for a in DEFAULT_RULES.get(name, ()) if a in mesh.shape and a not in used]
        if shape is not None:
            # greedily keep the longest prefix whose product divides the dim
            dim = shape[i]
            kept = []
            prod = 1
            for a in axes:
                if dim % (prod * mesh.shape[a]) == 0:
                    kept.append(a)
                    prod *= mesh.shape[a]
            axes = kept
        used.update(axes)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def sanitize_spec(mesh: Mesh, spec: P, shape: Sequence[int]) -> P:
    """Drop mesh axes from a PartitionSpec that don't divide the dim
    (required for input shardings; constraints tolerate padding)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        prod = 1
        for a in axes:
            if a not in mesh.shape:
                continue
            if shape[i] % (prod * mesh.shape[a]) == 0:
                kept.append(a)
                prod *= mesh.shape[a]
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


def named(mesh: Mesh, *logical: Axis) -> NamedSharding:
    return NamedSharding(mesh, resolve(mesh, *logical))


def constrain(x: jax.Array, mesh: Mesh, *logical: Axis) -> jax.Array:
    """with_sharding_constraint via logical names (no-op on 1-device)."""
    if mesh.size == 1:
        return x
    return jax.lax.with_sharding_constraint(x, named(mesh, *logical))


def tree_shardings(mesh: Mesh, logical_tree, shape_tree=None):
    """Map a pytree of logical-name tuples to NamedShardings. With
    ``shape_tree`` (matching abstract arrays), resolution is shape-aware
    (input-sharding safe)."""
    is_names = lambda t: isinstance(t, tuple) and all(isinstance(x, (str, type(None))) for x in t)
    if shape_tree is None:
        return jax.tree.map(lambda names: named(mesh, *names), logical_tree, is_leaf=is_names)
    return jax.tree.map(
        lambda names, a: NamedSharding(mesh, resolve(mesh, *names, shape=a.shape)),
        logical_tree,
        shape_tree,
        is_leaf=is_names,
    )


def batch_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    """Standard data-batch sharding: leading axis over ('pod','data')."""
    return named(mesh, *(["batch"] + [None] * (ndim - 1)))


def make_test_mesh(shape: Sequence[int] = (1, 1), axes: Sequence[str] = ("data", "model")) -> Mesh:
    """Small mesh over however many real devices exist (tests/benches)."""
    n = int(np.prod(shape))
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, tuple(axes))


def fft_axis(mesh: Mesh) -> str:
    """Mesh axis the FFT pencil decomposition shards over."""
    for a in DEFAULT_RULES["fft_rows"]:
        if a in mesh.shape:
            return a
    return list(mesh.shape)[-1]
