"""ICI communication cost model + compiled-HLO collective accounting.

Two roles:

1. **alpha-beta napkin model** of the collective strategies (DESIGN.md §2)
   -- drives the hypothesis step of every perf iteration and the
   chunk-size benchmark's derived columns (the paper's Fig. 3 regime:
   per-message overhead alpha vs bandwidth beta).

2. **HLO collective parser** for the roofline's collective term: walks
   ``compiled.as_text()``, sums the shipped bytes of every collective op
   (with the standard (P-1)/P ring factors), since ``cost_analysis()``
   does not report communication.

v5e constants are module-level so benchmarks and the dry-run agree.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, Optional

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW_PER_LINK = 50e9  # bytes/s (per direction, per link)
ICI_LINKS = 4  # torus links usable by a well-mapped collective
ICI_LATENCY_S = 1e-6  # per-hop software+switch latency (alpha)
VMEM_BYTES = 128 * 1024 * 1024

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)


# ---------------------------------------------------------------------------
# alpha-beta strategy model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CommParams:
    alpha_s: float = ICI_LATENCY_S  # per message
    beta_bytes_s: float = ICI_BW_PER_LINK * ICI_LINKS  # per device
    compute_overlap: float = 0.0  # fraction of per-chunk compute hidden


def t_alltoall(m_bytes: float, p: int, prm: CommParams = CommParams()) -> float:
    """One fused all-to-all: every device ships (1-1/P)*M once; the fabric
    moves it in a single synchronized phase."""
    if p <= 1:
        return 0.0
    return prm.alpha_s + (1 - 1 / p) * m_bytes / prm.beta_bytes_s


def t_scatter_ring(m_bytes: float, p: int, prm: CommParams = CommParams(),
                   chunk_compute_s: float = 0.0) -> float:
    """P-1 direct sends of M/P each; per-chunk compute overlaps the next
    send (fully, if chunk_compute <= chunk_comm). When per-chunk compute
    exceeds per-chunk comm, the difference is exposed on every step, and
    the last chunk's compute is always exposed (nothing left to overlap)."""
    if p <= 1:
        return max(chunk_compute_s, 0.0)
    per_chunk = prm.alpha_s + (m_bytes / p) / prm.beta_bytes_s
    exposed = max(0.0, chunk_compute_s - per_chunk) * (p - 1)
    return (p - 1) * per_chunk + chunk_compute_s + exposed


def t_bisection(m_bytes: float, p: int, prm: CommParams = CommParams()) -> float:
    """ceil(log2 P) rounds of M/2 each (Bruck): fewest messages, most
    bytes -- wins in the alpha-dominated small-chunk regime."""
    import math

    if p <= 1:
        return 0.0
    rounds = math.ceil(math.log2(p))
    return rounds * (prm.alpha_s + (m_bytes / 2) / prm.beta_bytes_s)


def t_pairwise(m_bytes: float, p: int, prm: CommParams = CommParams(),
               chunk_compute_s: float = 0.0) -> float:
    """Pairwise XOR exchange: P-1 rounds, round s swapping the M/P chunk
    with partner (rank XOR s) -- the classic MPI_Alltoall fallback, for
    power-of-two P. Same bytes and chunk streaming as the scatter ring
    (chunks arrive incrementally, so per-chunk compute overlaps the next
    round identically); it differs in schedule, not overlap: symmetric
    bidirectional swaps instead of a one-directional ring walk."""
    return t_scatter_ring(m_bytes, p, prm, chunk_compute_s)


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_ITOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(line: str) -> int:
    """Bytes of the op's result (first shape after '=', incl. tuples)."""
    rhs = line.split("=", 1)[1]
    # take shapes up to the op name's '(' -- i.e. the result type only
    head = rhs.split("(", 1)[0]
    total = 0
    for m in _SHAPE_RE.finditer(head):
        dtype, dims = m.group(1), m.group(2)
        if dtype in _DTYPE_BYTES:
            total += _shape_bytes(dtype, dims)
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ITOTA.search(line)
    if m:  # iota format [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_moved: Dict[str, float]  # per-device bytes shipped over ICI

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_moved.values())

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())


def parse_collectives(hlo_text: str, *, default_group: int = 1) -> CollectiveStats:
    """Sum per-device ICI bytes for every collective in compiled HLO.

    Ring-factor accounting (result size S, group size P):
      all-gather:          each device receives (P-1)/P * S
      reduce-scatter:      ships (P-1)/P * (P*S) /P ... = (P-1)/P * operand = (P-1)*S
      all-reduce:          ring RS+AG = 2 (P-1)/P * S
      all-to-all:          (P-1)/P * S
      collective-permute:  S (point-to-point)
    '-start' async forms counted once; '-done' skipped.
    """
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    bytes_moved: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lowered = s.split("=", 1)[1].lstrip()
        kind = None
        for k in COLLECTIVE_KINDS:
            # op name appears right after the result type, e.g.
            # "%ag = f32[8,4]{1,0} all-gather-start(...)"
            if re.search(rf"\b{k}(-start)?\(", lowered):
                kind = k
                break
        if kind is None or f"{kind}-done" in lowered:
            continue
        size = _result_bytes(s)
        if kind == "collective-permute":
            counts[kind] += 1
            bytes_moved[kind] += size
            continue
        # collective-permute was handled (and ``continue``d) above, so only
        # the group-sized collectives reach the factor table.
        p = _group_size(s, default_group)
        if p <= 1:
            factor = 0.0
        elif kind == "all-reduce":
            factor = 2 * (p - 1) / p
        elif kind == "reduce-scatter":
            factor = (p - 1)  # result is 1/P of operand; ships (P-1)/P*operand
        else:  # all-gather, all-to-all
            factor = (p - 1) / p
        counts[kind] += 1
        bytes_moved[kind] += size * factor
    return CollectiveStats(counts=counts, bytes_moved=bytes_moved)


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    flops: float  # HLO flops, whole program, per device
    hbm_bytes: float  # HLO bytes accessed, per device
    coll_bytes: float  # ICI bytes shipped, per device
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (ICI_BW_PER_LINK * ICI_LINKS)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def roofline_from_compiled(compiled, *, chips: int, default_group: int = 1) -> Roofline:
    ca = compiled.cost_analysis()
    if not isinstance(ca, dict):  # older jax returned [dict]
        ca = ca[0]
    stats = parse_collectives(compiled.as_text(), default_group=default_group)
    return Roofline(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=stats.total_bytes,
        chips=chips,
    )
