"""ICI communication cost model + compiled-HLO collective accounting.

Two roles:

1. **alpha-beta napkin model** of the collective strategies (DESIGN.md §2)
   -- drives the hypothesis step of every perf iteration and the
   chunk-size benchmark's derived columns (the paper's Fig. 3 regime:
   per-message overhead alpha vs bandwidth beta).

2. **HLO collective parser** for the roofline's collective term: walks
   ``compiled.as_text()``, sums the shipped bytes of every collective op
   (with the standard (P-1)/P ring factors), since ``cost_analysis()``
   does not report communication.

v5e constants are module-level so benchmarks and the dry-run agree --
they are *defaults*, not truths: ``CommParams.calibrate(mesh)`` fits
alpha/beta to the actual fabric (ppermute ping-pong sweep + least
squares), and every cost function takes the params explicitly.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, Iterable, Optional, Tuple

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW_PER_LINK = 50e9  # bytes/s (per direction, per link)
ICI_LINKS = 4  # torus links usable by a well-mapped collective
ICI_LATENCY_S = 1e-6  # per-hop software+switch latency (alpha)
VMEM_BYTES = 128 * 1024 * 1024

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)


# ---------------------------------------------------------------------------
# alpha-beta strategy model
# ---------------------------------------------------------------------------


#: Message sizes (bytes) swept by :meth:`CommParams.calibrate` -- wide
#: enough to pin both the latency intercept and the bandwidth slope.
CALIBRATE_SIZES = (4096, 16384, 65536, 262144, 1048576, 4194304)

#: Largest physically-plausible fitted bandwidth (1 PB/s; the fastest
#: real fabrics are ~1 TB/s). Above this the fit's slope is float noise.
_BETA_FIT_MAX = 1e15


@dataclasses.dataclass(frozen=True)
class CommParams:
    alpha_s: float = ICI_LATENCY_S  # per message
    beta_bytes_s: float = ICI_BW_PER_LINK * ICI_LINKS  # per device
    compute_overlap: float = 0.0  # fraction of per-chunk compute hidden

    @classmethod
    def calibrate(
        cls,
        mesh=None,
        axis_name: Optional[str] = None,
        *,
        sizes: Iterable[int] = CALIBRATE_SIZES,
        warmup: int = 1,
        iters: int = 5,
        timer: Optional["Callable[[int], float]"] = None,
    ) -> "CommParams":
        """Fit alpha/beta to *this* fabric by measurement (the paper's
        Fig. 3 per-parcelport fit, as an API).

        Runs a ppermute ping-pong (one round trip = 2 hops) for each
        message size in ``sizes`` on the real mesh and least-squares fits
        ``t_roundtrip = 2*alpha + 2*m/beta``, so ``backend="auto"`` /
        ``Plan.predict()`` rank with measured constants instead of the
        module-level v5e numbers (which are wrong on any other fabric).

        ``timer(m_bytes) -> roundtrip seconds`` overrides the real
        measurement (tests inject synthetic timings; no mesh needed).
        """
        import numpy as np

        sizes = [int(m) for m in sizes]
        if len(sizes) < 2:
            raise ValueError("calibrate needs >= 2 message sizes to fit alpha and beta")
        if timer is None:
            if mesh is None:
                raise ValueError("calibrate needs a mesh (or an injected timer)")
            timer = _pingpong_timer(mesh, axis_name, warmup=warmup, iters=iters)
        ts = np.asarray([float(timer(m)) for m in sizes])
        # least squares t = a + b*m; round trip = 2 hops
        slope, intercept = np.polyfit(np.asarray(sizes, dtype=float), ts, 1)
        alpha = max(float(intercept) / 2.0, 0.0)
        beta = 2.0 / float(slope) if slope > 0 else float("inf")
        # a non-positive or numerically-zero slope means the sweep never
        # left the latency-dominated regime (or was pure noise): an
        # "infinite bandwidth" fit would silently zero the beta term, so
        # fall back to the default constant and say so
        if not (0 < beta <= _BETA_FIT_MAX):
            import warnings

            warnings.warn(
                f"calibrate: bandwidth not identifiable from this sweep "
                f"(fitted slope {float(slope):.3e} s/byte); keeping the "
                f"default beta -- extend `sizes` upward to fix",
                RuntimeWarning,
                stacklevel=2,
            )
            beta = ICI_BW_PER_LINK * ICI_LINKS
        return cls(alpha_s=alpha, beta_bytes_s=beta)

    def refine_online(self, trace, *, min_spans: int = 2):
        """Re-fit alpha/beta from *observed* Exchange spans -- ROADMAP's
        online refinement from execution telemetry.

        ``trace`` is a :class:`repro.obs.trace.TraceRecorder` (its
        ``exchange_spans()`` are consumed), or any iterable of spans
        (``Span`` objects or their JSONL dicts). Each span contributes
        one point ``t = alpha * n_msgs + fit_bytes / beta`` where
        ``n_msgs``/``fit_bytes`` come from the span's backend structure
        (:func:`exchange_fit_terms`: ring backends send ``(P-1)*q``
        messages of the wire payload, bisection ``ceil(log2 P)`` rounds
        of half the block, all-to-all one fused phase).

        Returns a dict mapping ``(backend, payload_class)`` to a new
        frozen :class:`CommParams` (``self`` is never mutated), plus the
        pooled fit under ``("*", "*")``. Groups with fewer than
        ``min_spans`` points or a degenerate/negative fit keep this
        instance's constants for the unidentifiable coefficient -- same
        contract as :meth:`calibrate`'s bandwidth guard."""
        import numpy as np

        if hasattr(trace, "exchange_spans"):
            spans = trace.exchange_spans()
        else:
            spans = [s for s in trace if _span_field(s, "cat") == "exchange"]
        groups: Dict[Tuple[str, str], list] = {}
        pooled: list = []
        for sp in spans:
            args = _span_field(sp, "args") or {}
            dur = _span_field(sp, "dur")
            backend = args.get("backend")
            p = args.get("p")
            block = args.get("block_bytes")
            if not (isinstance(backend, str) and isinstance(p, (int, float)) and p
                    and isinstance(block, (int, float)) and isinstance(dur, (int, float))
                    and dur > 0):
                continue
            msgs, fit_bytes = exchange_fit_terms(
                backend, int(p), float(block), args.get("n_chunks")
            )
            wire = args.get("wire_bytes", fit_bytes)
            row = (float(msgs), float(fit_bytes), float(dur))
            groups.setdefault((backend, payload_class(float(wire))), []).append(row)
            pooled.append(row)
        fits = dict(groups)
        fits[("*", "*")] = pooled
        out = {}
        for key, rows in fits.items():
            out[key] = self._fit_spans(rows, min_spans, np)
        return out

    def _fit_spans(self, rows, min_spans: int, np) -> "CommParams":
        if len(rows) < max(2, min_spans):
            return self
        a = np.asarray([[r[0], r[1]] for r in rows], dtype=float)
        y = np.asarray([r[2] for r in rows], dtype=float)
        if np.linalg.matrix_rank(a) < 2:
            return self
        (alpha, inv_beta), *_ = np.linalg.lstsq(a, y, rcond=None)
        new_alpha = float(alpha) if alpha > 0 else self.alpha_s
        beta = 1.0 / float(inv_beta) if inv_beta > 0 else float("inf")
        new_beta = beta if 0 < beta <= _BETA_FIT_MAX else self.beta_bytes_s
        return dataclasses.replace(self, alpha_s=new_alpha, beta_bytes_s=new_beta)


def _span_field(sp, name: str):
    """Span attribute access across Span objects and their JSONL dicts."""
    if isinstance(sp, dict):
        return sp.get(name)
    return getattr(sp, name, None)


#: (exclusive) upper edges of the observed-payload size classes the
#: online refinement groups spans by -- wire payloads below 64 KiB are
#: latency-shaped, above 8 MiB bandwidth-shaped.
PAYLOAD_CLASS_EDGES = ((64 * 1024, "small"), (8 * 1024 * 1024, "medium"))


def payload_class(wire_bytes: float) -> str:
    for edge, name in PAYLOAD_CLASS_EDGES:
        if wire_bytes < edge:
            return name
    return "large"


def exchange_fit_terms(
    backend: str, p: int, block_bytes: float, n_chunks: Optional[int] = None
) -> Tuple[float, float]:
    """(n_msgs, bytes-on-the-wire) one Exchange contributes to the
    alpha/beta regression -- the message/byte structure of each cost
    function above, inverted for fitting. Unknown backends fall back to
    the one-phase all-to-all shape."""
    import math

    if p <= 1:
        return 0.0, 0.0
    wire = block_bytes * (1 - 1 / p)
    if backend in ("scatter", "pairwise_xor"):
        q = effective_chunks(p, n_chunks) // p
        return float((p - 1) * q), wire
    if backend == "bisection":
        rounds = math.ceil(math.log2(p))
        return float(rounds), rounds * block_bytes / 2
    return 1.0, wire


def _pingpong_timer(mesh, axis_name: Optional[str], *, warmup: int, iters: int):
    """Real-mesh round-trip timer: each device ships an m-byte f32 block
    one hop forward and one hop back under jit (lowers to the same
    collective-permute pairs the scatter backends use)."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.core.compat import shard_map

    if axis_name is None:
        from repro.core.sharding import fft_axis

        axis_name = fft_axis(mesh)  # the axis the pencil exchanges ship over
    p = mesh.shape[axis_name]
    fwd = [(i, (i + 1) % p) for i in range(p)]
    bwd = [(i, (i - 1) % p) for i in range(p)]

    def timer(m_bytes: int) -> float:
        from repro.core.planner import time_fn

        n = max(int(m_bytes) // 4, 1)

        def pingpong(x):
            y = lax.ppermute(x, axis_name, fwd)
            return lax.ppermute(y, axis_name, bwd)

        f = jax.jit(
            shard_map(
                pingpong,
                mesh=mesh,
                in_specs=PartitionSpec(axis_name),
                out_specs=PartitionSpec(axis_name),
            )
        )
        x = jax.device_put(
            jnp.zeros((p * n,), jnp.float32),
            NamedSharding(mesh, PartitionSpec(axis_name)),
        )
        return time_fn(f, x, warmup=warmup, iters=iters)

    timer.axis_name = axis_name  # resolved axis, inspectable by callers/tests
    return timer


def t_alltoall(m_bytes: float, p: int, prm: CommParams = CommParams()) -> float:
    """One fused all-to-all: every device ships (1-1/P)*M once; the fabric
    moves it in a single synchronized phase."""
    if p <= 1:
        return 0.0
    return prm.alpha_s + (1 - 1 / p) * m_bytes / prm.beta_bytes_s


def effective_chunks(p: int, n_chunks: Optional[int] = None) -> int:
    """Total chunks of a streaming exchange under an ``n_chunks`` target:
    ``q * p`` where ``q = ceil(n_chunks / p)`` sub-chunks per peer block
    (``None``/``<= p`` keeps the classic one-per-peer schedule). The
    model-side twin of :func:`repro.core.transpose.subchunks_per_peer` --
    the executed q additionally snaps to a divisor of the peer block's
    row count, which the byte-level model ignores."""
    if not n_chunks or n_chunks <= p:
        return max(p, 1)
    return max(1, -(-int(n_chunks) // p)) * max(p, 1)


def t_scatter_ring(m_bytes: float, p: int, prm: CommParams = CommParams(),
                   chunk_compute_s: float = 0.0,
                   n_chunks: Optional[int] = None) -> float:
    """Streaming ring: (P-1)*q direct sends of M/(P*q) each (q sub-chunks
    per peer block, q=1 classically); per-sub-chunk compute overlaps the
    next send (fully, if sub-chunk compute <= sub-chunk comm). When
    compute exceeds comm, the difference is exposed on every step, and
    the last sub-chunk's compute is always exposed (nothing left to
    overlap). ``chunk_compute_s`` stays *per peer chunk* (there are P),
    so costs stay comparable across n_chunks: sub-chunking splits the
    same compute into q finer, better-hiding pieces while paying q-1
    extra message latencies per peer."""
    if p <= 1:
        return max(chunk_compute_s, 0.0)
    n = effective_chunks(p, n_chunks)
    q = n // p
    msgs = (p - 1) * q
    per_msg = prm.alpha_s + (m_bytes / n) / prm.beta_bytes_s
    sub_compute = chunk_compute_s / q
    exposed = max(0.0, sub_compute - per_msg) * msgs
    return msgs * per_msg + sub_compute + exposed


def t_bisection(m_bytes: float, p: int, prm: CommParams = CommParams()) -> float:
    """ceil(log2 P) rounds of M/2 each (Bruck): fewest messages, most
    bytes -- wins in the alpha-dominated small-chunk regime."""
    import math

    if p <= 1:
        return 0.0
    rounds = math.ceil(math.log2(p))
    return rounds * (prm.alpha_s + (m_bytes / 2) / prm.beta_bytes_s)


def t_pairwise(m_bytes: float, p: int, prm: CommParams = CommParams(),
               chunk_compute_s: float = 0.0,
               n_chunks: Optional[int] = None) -> float:
    """Pairwise XOR exchange: P-1 rounds, round s swapping the M/P chunk
    with partner (rank XOR s) -- the classic MPI_Alltoall fallback, for
    power-of-two P. Same bytes and chunk streaming as the scatter ring
    (chunks arrive incrementally, so per-chunk compute overlaps the next
    round identically, and sub-chunked pipelining applies identically);
    it differs in schedule, not overlap: symmetric bidirectional swaps
    instead of a one-directional ring walk."""
    return t_scatter_ring(m_bytes, p, prm, chunk_compute_s, n_chunks)


#: Sub-axis exchanges per pencil transform, (n_row, n_col): fft3 is one
#: transpose per grid axis (+1 each under transpose_back); fft2
#: transforms each data dim over its own sub-ring with a transpose /
#: FFT / transpose-back pass, i.e. two exchanges per axis.
PENCIL_EXCHANGES = {2: (2, 2), 3: (1, 1)}


def pencil_exchanges(ndim: int, transpose_back: bool = False):
    """(n_row, n_col) sub-axis exchanges of one pencil transform -- the
    single copy shared by :func:`t_pencil` and ``Plan`` (predict /
    comm_bytes), so the model and the plan cannot desynchronize."""
    try:
        n_row, n_col = PENCIL_EXCHANGES[ndim]
    except KeyError:
        raise ValueError(f"pencil decomposition supports ndim 2 or 3, got {ndim}") from None
    if transpose_back and ndim == 3:
        n_row, n_col = n_row + 1, n_col + 1
    return n_row, n_col


def t_pencil_axis(
    m_bytes: float,
    p_axis: int,
    backend: str,
    n_exchanges: int,
    prm: CommParams = CommParams(),
    chunk_compute_s: float = 0.0,
    *,
    first_m_bytes: Optional[float] = None,
    n_chunks: Optional[int] = None,
    fused: bool = True,
) -> float:
    """Predicted seconds of all of one grid axis's sub-exchanges: the
    axis's backend costed at the axis's own sub-ring size. The single
    per-axis formula shared by :func:`t_pencil` and
    ``Plan.predict_axes`` -- the model and the plan cannot drift.

    ``first_m_bytes`` sizes the axis's *first* exchange separately --
    the real pencil rfft2's first cols exchange ships the untransformed
    real block while every later exchange carries the Hermitian-truncated
    complex payload (see :mod:`repro.core.real`). ``n_chunks``/``fused``
    thread the pipelined overlap model through to the backend cost."""
    from repro.core import backends  # late: backends imports this module

    b = backends.get(backend)
    if first_m_bytes is None:
        return n_exchanges * b.cost(
            m_bytes, p_axis, prm, chunk_compute_s, n_chunks=n_chunks, fused=fused
        )
    return b.cost(
        first_m_bytes, p_axis, prm, chunk_compute_s, n_chunks=n_chunks, fused=fused
    ) + (
        (n_exchanges - 1) * b.cost(
            m_bytes, p_axis, prm, chunk_compute_s, n_chunks=n_chunks, fused=fused
        )
    )


def t_pencil(
    m_bytes: float,
    p_rows: int,
    p_cols: int,
    backend_row: str,
    backend_col: str,
    prm: CommParams = CommParams(),
    *,
    ndim: int = 3,
    transpose_back: bool = False,
    chunk_compute_s: float = 0.0,
    first_col_m_bytes: Optional[float] = None,
    n_chunks: Optional[int] = None,
    fused: bool = True,
) -> float:
    """Predicted seconds of one pencil transform's communication: each
    sub-axis exchange costed by its *own* backend at its *own* sub-ring
    size (P_row or P_col) -- the 2-D extension of the per-backend
    alpha-beta model. ``m_bytes`` is the per-device local block; every
    sub-exchange re-shards the whole block over one grid axis, so the
    per-axis cost is ``backend.cost(m_bytes, p_axis)`` and the axes sum
    (the exchanges are sequentialized by the FFT passes between them).

    For real (Hermitian-truncated) transforms pass the half-spectrum
    block as ``m_bytes``; ``first_col_m_bytes`` sizes the rfft2 pencil
    path's first cols exchange, which still ships the full-width real
    block (the r2c pass needs the axis local first).
    """
    n_row, n_col = pencil_exchanges(ndim, transpose_back)
    return t_pencil_axis(
        m_bytes, p_rows, backend_row, n_row, prm, chunk_compute_s,
        n_chunks=n_chunks, fused=fused,
    ) + (
        t_pencil_axis(
            m_bytes, p_cols, backend_col, n_col, prm, chunk_compute_s,
            first_m_bytes=first_col_m_bytes, n_chunks=n_chunks, fused=fused,
        )
    )


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_ITOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OP_NAME_RE = re.compile(r" *([\w\-]+)\(")


def split_op_line(rhs: str):
    """Split ``"<result-type> <op-name>(..."`` -- the text after ``=`` of
    a scheduled-HLO op line -- into ``(result_type, op_name)``.

    The op name is the token after the *end of the result type* (first
    space at bracket depth 0), NOT the first ``word(`` in the line:
    post-layout TPU types carry parenthesized layout annotations
    (``{0:T(1024)}`` tiles, ``S(1)`` memory spaces) whose ``T(``/``S(``
    would win an eager search and make every op line unrecognizable.
    Returns None when the text is not an op application.
    """
    depth = 0
    for i, ch in enumerate(rhs):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == " " and depth == 0:
            m = _OP_NAME_RE.match(rhs, i)
            if m is None:
                return None
            return rhs[:i], m.group(1)
    return None


def shape_bytes(type_text: str) -> int:
    """Total bytes of every array shape in an HLO type string (tuples
    sum their elements; layout annotations and unknown tokens ignored)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_text):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _tuple_elements(type_text: str) -> list:
    """Top-level elements of a tuple type string ``(a, b, ...)`` --
    commas inside dims ``[8,4]``, layouts ``{1,0}`` and nested tuples do
    not split."""
    inner = type_text.strip()
    inner = inner[1 : inner.rfind(")")]
    elems, depth, start = [], 0, 0
    for i, ch in enumerate(inner):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            elems.append(inner[start:i])
            start = i + 1
    elems.append(inner[start:])
    return [e.strip() for e in elems if e.strip()]


#: '-start' kinds whose tuple result is (operand-alias, receive-buffer,
#: context-scalars...). all-reduce-start is NOT here: its (possibly
#: variadic) tuple is the reduced result(s) themselves, all payload.
_START_ALIAS_KINDS = ("all-gather", "collective-permute")


def collective_payload_bytes(
    result_type: str, *, is_start: bool = False, kind: Optional[str] = None
) -> int:
    """Shipped payload bytes of a collective op's result type.

    Sync forms: the result array(s) -- tuples (variadic collectives) sum
    every element. Async ``-start`` forms of the alias-style kinds
    (:data:`_START_ALIAS_KINDS`) return
    ``(operand-alias, receive-buffer, context-scalars...)``: counting the
    whole tuple double-counts the aliased input and adds the u32[]
    context words, so only the receive-buffer element (the second)
    counts. ``all-reduce-start`` tuples are results only -- every element
    is payload. Shared by :func:`parse_collectives` and
    :mod:`repro.core.hlo_analysis` so the two parsers cannot drift.
    """
    t = result_type.strip()
    if not t.startswith("("):
        return shape_bytes(t)
    if is_start and (kind is None or kind in _START_ALIAS_KINDS):
        elems = _tuple_elements(t)
        if len(elems) > 1:
            return shape_bytes(elems[1])
    return shape_bytes(t)


def collective_scaled_bytes(kind: str, payload_bytes: float, p: int) -> float:
    """Per-device ICI bytes shipped = payload * the kind's ring factor at
    group size ``p`` (the table in :func:`parse_collectives`'s docstring).
    The single copy both HLO parsers use -- editing a factor here cannot
    make them disagree."""
    if kind == "collective-permute":
        return float(payload_bytes)  # point-to-point, no group factor
    if p <= 1:
        return 0.0
    if kind == "all-reduce":
        return payload_bytes * 2 * (p - 1) / p
    if kind == "reduce-scatter":
        return payload_bytes * (p - 1)  # result is 1/P of the operand
    return payload_bytes * (p - 1) / p  # all-gather, all-to-all


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ITOTA.search(line)
    if m:  # iota format [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_moved: Dict[str, float]  # per-device bytes shipped over ICI

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_moved.values())

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())


def parse_collectives(hlo_text: str, *, default_group: int = 1) -> CollectiveStats:
    """Sum per-device ICI bytes for every collective in compiled HLO.

    Ring-factor accounting (result size S, group size P):
      all-gather:          each device receives (P-1)/P * S
      reduce-scatter:      ships (P-1)/P * (P*S) /P ... = (P-1)/P * operand = (P-1)*S
      all-reduce:          ring RS+AG = 2 (P-1)/P * S
      all-to-all:          (P-1)/P * S
      collective-permute:  S (point-to-point)
    '-start' async forms counted once (receive-buffer element of the
    tuple result only -- see :func:`collective_payload_bytes`); '-done'
    skipped.
    """
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    bytes_moved: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1].lstrip()
        # op name appears right after the result type, e.g.
        # "%ag = f32[8,4]{1,0} all-gather-start(...)" or, async tuple,
        # "%cp = (f32[1024], f32[1024], u32[], u32[]) collective-permute-start(...)"
        split = split_op_line(rhs)
        if split is None:
            continue
        result_type, opname = split
        kind = opname
        for suffix in ("-start", "-done"):
            if kind.endswith(suffix):
                kind = kind[: -len(suffix)]
        if kind not in COLLECTIVE_KINDS or opname.endswith("-done"):
            continue
        size = collective_payload_bytes(
            result_type, is_start=opname.endswith("-start"), kind=kind
        )
        p = 1 if kind == "collective-permute" else _group_size(s, default_group)
        counts[kind] += 1
        bytes_moved[kind] += collective_scaled_bytes(kind, size, p)
    return CollectiveStats(counts=counts, bytes_moved=bytes_moved)


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    flops: float  # HLO flops, whole program, per device
    hbm_bytes: float  # HLO bytes accessed, per device
    coll_bytes: float  # ICI bytes shipped, per device
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (ICI_BW_PER_LINK * ICI_LINKS)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def roofline_from_compiled(compiled, *, chips: int, default_group: int = 1) -> Roofline:
    ca = compiled.cost_analysis()
    if not isinstance(ca, dict):  # older jax returned [dict]
        ca = ca[0]
    stats = parse_collectives(compiled.as_text(), default_group=default_group)
    return Roofline(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=stats.total_bytes,
        chips=chips,
    )
