"""Pencil-decomposed distributed FFTs over a 2-D process grid.

Slab decomposition (:mod:`repro.core.distributed_fft`) shards one data
dimension over one mesh axis: parallelism caps at P <= N and every
transpose is one global exchange over all P ranks. The pencil
decomposition shards TWO data dimensions over a
(:class:`~repro.core.grid.ProcessGrid`) of P_row x P_col processes, so

- parallelism scales to P_row * P_col <= N0 * N1, and
- each transpose is a **sub-axis** exchange over only P_row or P_col
  ranks -- smaller rings, independently strategy-switched. ``scatter``
  over the rows axis and ``bisection`` over cols is a legal (and, per
  the alpha-beta model, often optimal) combination: the 2-D analogue of
  the paper's parcelport switch.

``pencil_fft3`` is the canonical shape (the companion case-study's
algorithm): three local FFT passes separated by two sub-axis transposes,

    (X/Pr, Y/Pc, Z)  --fft Z-->  --T_cols-->  (X/Pr, Z/Pc, Y)
                     --fft Y-->  --T_rows-->  (Z/Pc, Y/Pr, X)  --fft X-->

returning the reversed-axes spectrum ``fftn(x).transpose(..., -1,-2,-3)``
(standard pencil output; ``transpose_back=True`` restores the natural
layout with two more sub-exchanges).

``pencil_fft2`` transforms each data dimension over its own grid axis
(transpose / FFT / transpose-back per axis -- four sub-exchanges, two
per sub-ring) and returns the **natural-layout** ``fft2(x)``: with both
data dims sharded there is no cheaper transposed-output shortcut. Its
point is the mesh, not the shape: on a 2-D mesh it exchanges over each
sub-ring separately (per-axis backends, per-fabric tuning) instead of
flattening everything onto one P-wide ring. Both data dims must divide
P_row*P_col, so its parallelism cap matches slab's P <= N.

Every sub-exchange dispatches through :mod:`repro.core.backends` by
name, exactly like the slab path -- whole-transform (``kind="global"``)
backends have no shard_map transpose and are rejected per-axis.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro.core.fftmath as lf
import repro.core.transpose as tr
from repro.core import backends
from repro.core.compat import shard_map
from repro.core.grid import ProcessGrid


@dataclasses.dataclass(frozen=True)
class PencilConfig:
    """Per-axis exchange strategy + local-FFT settings for the pencil
    transforms. ``backend_row``/``backend_col`` name registered
    shard_map backends; they are resolved and validated independently
    (the 2-D parcelport switch). ``transpose_back`` applies to
    ``pencil_fft3`` only -- ``pencil_fft2`` is already natural-layout.

    ``fused`` folds each sub-exchange's following FFT pass into the
    arriving chunks *independently per leg*: the row and col exchanges
    each fuse exactly when their own backend streams, so a mixed pair
    like ``("scatter", "bisection")`` pipelines the rows leg and runs
    the cols leg monolithically. ``n_chunks`` is the per-exchange
    total-chunk target (sub-chunked transport; see
    :func:`repro.core.transpose.subchunks_per_peer`)."""

    backend_row: str = "alltoall"
    backend_col: str = "alltoall"
    local_impl: lf.LocalImpl = "jnp"
    transpose_back: bool = False
    fused: bool = False
    n_chunks: "int | None" = None


def _check_backends(cfg: PencilConfig, grid: ProcessGrid) -> None:
    for role, name, p in (
        ("row", cfg.backend_row, grid.p_rows),
        ("col", cfg.backend_col, grid.p_cols),
    ):
        b = backends.get(name)  # raises listing the registry
        if b.kind != "shard_map":
            raise ValueError(
                f"backend_{role}={name!r} is a whole-transform backend; "
                f"pencil sub-axis exchanges need shard_map backends "
                f"({list(backends.available(kind='shard_map'))})"
            )
        if not b.supports(p):
            raise ValueError(
                f"backend_{role}={name!r} does not support "
                f"P_{role}={p} (grid {grid.p_rows}x{grid.p_cols})"
            )


def check_divisible(global_shape, grid: ProcessGrid, ndim: int) -> None:
    """Raise a ValueError naming the offending data axis and grid
    dimension when ``global_shape`` cannot be pencil-sharded -- the
    plan-time guard, so the failure never surfaces as an opaque chunking
    error deep inside :mod:`repro.core.transpose`."""
    pr, pc = grid.p_rows, grid.p_cols

    def need(axis_from_end: int, divisor: int, why: str) -> None:
        size = global_shape[len(global_shape) - axis_from_end]
        if size % divisor:
            raise ValueError(
                f"pencil fft{ndim}: data axis -{axis_from_end} (global size "
                f"{size}) is not divisible by {why} -- shape "
                f"{tuple(global_shape)} on grid {pr}x{pc} "
                f"(row_axis={grid.row_axis!r}, col_axis={grid.col_axis!r})"
            )

    if ndim == 3:
        need(3, pr, f"P_row={pr} ({grid.row_axis!r})")
        need(2, pc, f"P_col={pc} ({grid.col_axis!r})")
        need(2, pr, f"P_row={pr} ({grid.row_axis!r}; the rows exchange re-shards it)")
        need(1, pc, f"P_col={pc} ({grid.col_axis!r}; the cols exchange re-shards it)")
    elif ndim == 2:
        need(2, pr * pc, f"P_row*P_col={pr * pc} (both sub-rings re-shard it)")
        need(1, pr * pc, f"P_row*P_col={pr * pc} (both sub-rings re-shard it)")
    else:
        raise ValueError(f"pencil decomposition supports ndim 2 or 3, got {ndim}")


def pencil_fft3(
    x: jax.Array,
    grid: ProcessGrid,
    cfg: PencilConfig = PencilConfig(),
    *,
    inverse: bool = False,
) -> jax.Array:
    """Pencil-decomposed 3-D FFT of (..., D0, D1, D2) with D0 sharded
    over ``grid.row_axis`` and D1 over ``grid.col_axis``.

    Returns the reversed-axes spectrum (global value
    ``fftn(x).transpose(..., -1, -2, -3)``) sharded (D2 over cols, D1
    over rows), or the natural layout with ``cfg.transpose_back`` (two
    extra sub-exchanges). ``inverse`` computes the matching ifftn
    (1/(D0*D1*D2) normalization), same layout conventions.
    """
    _check_backends(cfg, grid)
    check_divisible(x.shape, grid, 3)
    d0, d1, d2 = x.shape[-3:]
    row, col = grid.row_axis, grid.col_axis

    def fn(xl: jax.Array) -> jax.Array:
        v = jnp.conj(xl) if inverse else xl
        # pass 1: D2 is local -- FFT it, then the cols sub-exchange
        # swaps (D1, D2): (x_r, y_c, D2) -> (x_r, z_c, D1) with the D1
        # FFT (pass 2) fused into the arriving chunks when backend_col
        # streams -- each leg pipelines independently
        v = lf.local_fft(v, axis=-1, impl=cfg.local_impl)
        v = tr.transpose_then_fft(
            v, col, strategy=cfg.backend_col, impl=cfg.local_impl,
            fused=cfg.fused, n_chunks=cfg.n_chunks,
        )
        # pass 3 prep: the rows sub-exchange needs the rows-sharded D0
        # at position -2: (x_r, z_c, D1) -> (z_c, x_r, D1); the D0 FFT
        # fuses into the rows exchange when backend_row streams
        v = jnp.swapaxes(v, -3, -2)
        v = tr.transpose_then_fft(
            v, row, strategy=cfg.backend_row, impl=cfg.local_impl,
            fused=cfg.fused, n_chunks=cfg.n_chunks,
        )  # (z_c, y_r, D0), D0 transformed
        if cfg.transpose_back:
            v = tr.distributed_transpose(
                v, row, strategy=cfg.backend_row, n_chunks=cfg.n_chunks
            )
            v = jnp.swapaxes(v, -3, -2)
            v = tr.distributed_transpose(
                v, col, strategy=cfg.backend_col, n_chunks=cfg.n_chunks
            )
        if inverse:
            v = jnp.conj(v) / (d0 * d1 * d2)
        return v

    lead = [None] * (x.ndim - 3)
    in_spec = P(*lead, row, col, None)
    out_spec = in_spec if cfg.transpose_back else P(*lead, col, row, None)
    return shard_map(fn, mesh=grid.mesh, in_specs=in_spec, out_specs=out_spec)(x)


def pencil_fft2(
    x: jax.Array,
    grid: ProcessGrid,
    cfg: PencilConfig = PencilConfig(),
    *,
    inverse: bool = False,
) -> jax.Array:
    """Pencil-decomposed 2-D FFT of (..., R, C) with R sharded over
    ``grid.row_axis`` and C over ``grid.col_axis``.

    Each data dimension is transformed over its own grid axis
    (transpose / local FFT / transpose-back, i.e. two exchanges per
    sub-ring), so the output is the **natural-layout** ``fft2(x)`` --
    unlike the slab path's transposed spectrum. ``cfg.transpose_back``
    must be False (there is nothing to transpose back). Both R and C
    must divide P_row*P_col (every sub-ring re-shards both dims).
    """
    if cfg.transpose_back:
        raise ValueError(
            "pencil fft2 already returns the natural layout; "
            "transpose_back applies to slab transforms and pencil fft3 only"
        )
    _check_backends(cfg, grid)
    check_divisible(x.shape, grid, 2)
    r_glob, c_glob = x.shape[-2:]
    row, col = grid.row_axis, grid.col_axis

    def fn(xl: jax.Array) -> jax.Array:
        v = jnp.conj(xl) if inverse else xl
        # pass A -- transform C over the cols sub-ring. The cols
        # exchange wants the cols-sharded dim at -2 and a fully-local
        # dim at -1: (r_r, c_c) -> (c_c, r_r) -> T_col -> (r_rc, C),
        # with the C FFT fused into the arriving chunks when
        # backend_col streams (the transpose-back stays monolithic --
        # nothing follows it to fuse)
        v = jnp.swapaxes(v, -1, -2)
        v = tr.transpose_then_fft(
            v, col, strategy=cfg.backend_col, impl=cfg.local_impl,
            fused=cfg.fused, n_chunks=cfg.n_chunks,
        )
        v = tr.distributed_transpose(
            v, col, strategy=cfg.backend_col, n_chunks=cfg.n_chunks
        )
        v = jnp.swapaxes(v, -1, -2)  # back to (r_r, c_c), C-dim done
        # pass B -- transform R over the rows sub-ring: (r_r, c_c) is
        # already (rows-sharded, local): T_row -> (c_cr, R).
        v = tr.transpose_then_fft(
            v, row, strategy=cfg.backend_row, impl=cfg.local_impl,
            fused=cfg.fused, n_chunks=cfg.n_chunks,
        )
        v = tr.distributed_transpose(
            v, row, strategy=cfg.backend_row, n_chunks=cfg.n_chunks
        )
        if inverse:
            v = jnp.conj(v) / (r_glob * c_glob)
        return v

    spec = P(*([None] * (x.ndim - 2)), row, col)
    return shard_map(fn, mesh=grid.mesh, in_specs=spec, out_specs=spec)(x)
