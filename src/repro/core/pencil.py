"""Pencil-decomposed distributed FFTs over a 2-D process grid.

Slab decomposition (:mod:`repro.core.distributed_fft`) shards one data
dimension over one mesh axis: parallelism caps at P <= N and every
transpose is one global exchange over all P ranks. The pencil
decomposition shards TWO data dimensions over a
(:class:`~repro.core.grid.ProcessGrid`) of P_row x P_col processes, so

- parallelism scales to P_row * P_col <= N0 * N1, and
- each transpose is a **sub-axis** exchange over only P_row or P_col
  ranks -- smaller rings, independently strategy-switched. ``scatter``
  over the rows axis and ``bisection`` over cols is a legal (and, per
  the alpha-beta model, often optimal) combination: the 2-D analogue of
  the paper's parcelport switch.

``pencil_fft3`` is the canonical shape (the companion case-study's
algorithm): three local FFT passes separated by two sub-axis transposes,

    (X/Pr, Y/Pc, Z)  --fft Z-->  --T_cols-->  (X/Pr, Z/Pc, Y)
                     --fft Y-->  --T_rows-->  (Z/Pc, Y/Pr, X)  --fft X-->

returning the reversed-axes spectrum ``fftn(x).transpose(..., -1,-2,-3)``
(standard pencil output; ``transpose_back=True`` restores the natural
layout with two more sub-exchanges).

``pencil_fft2`` transforms each data dimension over its own grid axis
(transpose / FFT / transpose-back per axis -- four sub-exchanges, two
per sub-ring) and returns the **natural-layout** ``fft2(x)``: with both
data dims sharded there is no cheaper transposed-output shortcut. Its
point is the mesh, not the shape: on a 2-D mesh it exchanges over each
sub-ring separately (per-axis backends, per-fabric tuning) instead of
flattening everything onto one P-wide ring. Both data dims must divide
P_row*P_col, so its parallelism cap matches slab's P <= N.

Every sub-exchange dispatches through :mod:`repro.core.backends` by
name, exactly like the slab path -- whole-transform (``kind="global"``)
backends have no shard_map transpose and are rejected per-axis. Both
transforms are thin builders over :mod:`repro.core.schedule`: they
lower to a declarative stage schedule and run through the one
interpreter, the same object the cost model walks.
"""

from __future__ import annotations

import dataclasses

import jax

import repro.core.fftmath as lf
import repro.core.schedule as sch
from repro.core import backends
from repro.core.grid import ProcessGrid


@dataclasses.dataclass(frozen=True)
class PencilConfig:
    """Per-axis exchange strategy + local-FFT settings for the pencil
    transforms. ``backend_row``/``backend_col`` name registered
    shard_map backends; they are resolved and validated independently
    (the 2-D parcelport switch). ``transpose_back`` applies to
    ``pencil_fft3`` only -- ``pencil_fft2`` is already natural-layout.

    ``fused`` folds each sub-exchange's following FFT pass into the
    arriving chunks *independently per leg*: the row and col exchanges
    each fuse exactly when their own backend streams, so a mixed pair
    like ``("scatter", "bisection")`` pipelines the rows leg and runs
    the cols leg monolithically. ``n_chunks`` is the per-exchange
    total-chunk target (sub-chunked transport; see
    :func:`repro.core.transpose.subchunks_per_peer`)."""

    backend_row: str = "alltoall"
    backend_col: str = "alltoall"
    local_impl: lf.LocalImpl = "jnp"
    transpose_back: bool = False
    fused: bool = False
    n_chunks: "int | None" = None


def _check_backends(cfg: PencilConfig, grid: ProcessGrid) -> None:
    for role, name, p in (
        ("row", cfg.backend_row, grid.p_rows),
        ("col", cfg.backend_col, grid.p_cols),
    ):
        b = backends.get(name)  # raises listing the registry
        if b.kind != "shard_map":
            raise ValueError(
                f"backend_{role}={name!r} is a whole-transform backend; "
                f"pencil sub-axis exchanges need shard_map backends "
                f"({list(backends.available(kind='shard_map'))})"
            )
        if not b.supports(p):
            raise ValueError(
                f"backend_{role}={name!r} does not support "
                f"P_{role}={p} (grid {grid.p_rows}x{grid.p_cols})"
            )


def check_divisible(global_shape, grid: ProcessGrid, ndim: int) -> None:
    """Raise a ValueError naming the offending data axis and grid
    dimension when ``global_shape`` cannot be pencil-sharded.
    Delegates to the one schedule-level validator
    (:func:`repro.core.schedule.check_divisible`); kept as the
    grid-flavored public spelling."""
    sch.check_divisible(
        global_shape, ndim, p_rows=grid.p_rows, p_cols=grid.p_cols,
        row_axis=grid.row_axis, col_axis=grid.col_axis,
    )


def _build(x: jax.Array, grid: ProcessGrid, cfg: PencilConfig, *,
           ndim: int, inverse: bool) -> sch.Schedule:
    return sch.build_schedule(
        x.shape, ndim=ndim, inverse=inverse, decomp="pencil",
        row_axis=grid.row_axis, col_axis=grid.col_axis,
        p_rows=grid.p_rows, p_cols=grid.p_cols,
        backend_row=cfg.backend_row, backend_col=cfg.backend_col,
        fused=cfg.fused, n_chunks=cfg.n_chunks,
        transpose_back=cfg.transpose_back,
    )


def pencil_fft3(
    x: jax.Array,
    grid: ProcessGrid,
    cfg: PencilConfig = PencilConfig(),
    *,
    inverse: bool = False,
) -> jax.Array:
    """Pencil-decomposed 3-D FFT of (..., D0, D1, D2) with D0 sharded
    over ``grid.row_axis`` and D1 over ``grid.col_axis``.

    Returns the reversed-axes spectrum (global value
    ``fftn(x).transpose(..., -1, -2, -3)``) sharded (D2 over cols, D1
    over rows), or the natural layout with ``cfg.transpose_back`` (two
    extra sub-exchanges). ``inverse`` computes the matching ifftn
    (1/(D0*D1*D2) normalization), same layout conventions.
    """
    _check_backends(cfg, grid)
    plan = _build(x, grid, cfg, ndim=3, inverse=inverse)
    return sch.run_schedule(x, plan, grid.mesh, impl=cfg.local_impl)


def pencil_fft2(
    x: jax.Array,
    grid: ProcessGrid,
    cfg: PencilConfig = PencilConfig(),
    *,
    inverse: bool = False,
) -> jax.Array:
    """Pencil-decomposed 2-D FFT of (..., R, C) with R sharded over
    ``grid.row_axis`` and C sharded over ``grid.col_axis``.

    Each data dimension is transformed over its own grid axis
    (transpose / local FFT / transpose-back, i.e. two exchanges per
    sub-ring), so the output is the **natural-layout** ``fft2(x)`` --
    unlike the slab path's transposed spectrum. ``cfg.transpose_back``
    must be False (there is nothing to transpose back). Both R and C
    must divide P_row*P_col (every sub-ring re-shards both dims).
    """
    if cfg.transpose_back:
        raise ValueError(
            "pencil fft2 already returns the natural layout; "
            "transpose_back applies to slab transforms and pencil fft3 only"
        )
    _check_backends(cfg, grid)
    plan = _build(x, grid, cfg, ndim=2, inverse=inverse)
    return sch.run_schedule(x, plan, grid.mesh, impl=cfg.local_impl)
