"""Measurement-driven FFT planning -- the FFTW_MEASURE analogue.

The paper's FFTW3 reference does not *model* which schedule is fastest,
it **measures**: ``FFTW_MEASURE`` times candidate plans on the actual
machine and caches the winner as *wisdom*. This module is the same
discipline for the distributed transforms:

- :func:`plan_fft(..., planner="measure") <repro.core.plan.plan_fft>`
  times every registered backend that supports the shard count **on the
  real mesh** (warmup + median wall-clock, the same ``time_fn`` the
  benchmarks use) -- expanded to (backend, n_chunks, fused) variant
  triples where the pipelined overlap executor applies (see
  :func:`candidate_variants`) -- and pins the plan to the measured
  argmin, recording the full per-candidate timing table on
  ``Plan.measured``;
- an FFTW-style **wisdom store** -- JSON, keyed by
  (shape, ndim, dtype, P, candidate backend set, device kind, and the
  decomposition: slab axis, or pencil grid shape + axes + per-axis
  backend pairs) -- is consulted before measuring, so a repeated
  identical plan is free.
  :func:`export_wisdom` / :func:`import_wisdom` round-trip it to disk
  exactly like ``fftw_export_wisdom_to_filename``;
- the alpha-beta constants feeding ``planner="estimate"`` can themselves
  be measured: :meth:`repro.core.comm_model.CommParams.calibrate` fits
  alpha/beta to a ppermute ping-pong sweep (the paper's Fig. 3
  per-parcelport fit) and plugs into ``plan_fft(..., params=...)``.

``timer`` is injectable everywhere (``timer(plan) -> seconds``), so the
selection logic is testable without a fabric.
"""

from __future__ import annotations

import json
import math
import os
import tempfile
import time
from typing import Callable, Dict, List, Optional, Tuple

WISDOM_VERSION = 1

#: In-process wisdom: key -> {"backend": name, "timings": {name: s}, ...}
_WISDOM: Dict[str, dict] = {}


# ---------------------------------------------------------------------------
# Timing (shared with benchmarks/common.py, which re-exports this)
# ---------------------------------------------------------------------------


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time (s) of a jitted call (blocks on result)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def default_timer(warmup: int = 1, iters: int = 5) -> Callable:
    """``timer(plan) -> seconds``: run the plan's cached executable on a
    zeros input with the plan's own input layout (``Plan.input_spec``
    carries the shape/dtype/sharding, so real c2r plans -- whose input
    is the half spectrum, not ``global_shape`` -- time correctly)."""

    def timer(plan) -> float:
        import jax
        import jax.numpy as jnp

        spec = plan.input_spec()
        x = jax.device_put(jnp.zeros(spec.shape, spec.dtype), spec.sharding)
        return time_fn(plan.execute, x, warmup=warmup, iters=iters)

    return timer


# ---------------------------------------------------------------------------
# Wisdom store
# ---------------------------------------------------------------------------


def device_kind(mesh) -> str:
    """Hardware identity of the mesh's devices (wisdom must not cross
    device kinds -- a v5e winner says nothing about CPU or v4)."""
    try:
        return str(next(iter(mesh.devices.flat)).device_kind)
    except (AttributeError, StopIteration):  # pragma: no cover - exotic mesh
        return "unknown"


def wisdom_key(
    global_shape: Tuple[int, ...],
    ndim: int,
    dtype: str,
    p: int,
    backend_names: Tuple[str, ...],
    dev_kind: str,
    opts: str = "",
) -> str:
    """Stable string key for one measured problem."""
    shape = "x".join(str(d) for d in global_shape)
    names = "+".join(sorted(backend_names))
    key = f"v{WISDOM_VERSION}|shape={shape}|ndim={ndim}|dtype={dtype}|P={p}|backends={names}|dev={dev_kind}"
    if opts:
        key += f"|{opts}"
    return key


def _valid_observed_cell(cell) -> bool:
    return (
        isinstance(cell, dict)
        and isinstance(cell.get("n"), (int, float))
        and cell.get("n", 0) > 0
        and isinstance(cell.get("s"), (int, float))
        and cell.get("s", -1.0) >= 0
    )


def _merge_observed(a, b) -> Dict[str, dict]:
    """Union two observed-timings channels: per-candidate sample counts
    add and means combine count-weighted. Malformed cells are dropped
    (same advisory contract as the rest of the wisdom store)."""
    out: Dict[str, dict] = {}
    for side in (a, b):
        if not isinstance(side, dict):
            continue
        for name, cell in side.items():
            if not _valid_observed_cell(cell):
                continue
            prev = out.get(name)
            if prev is None:
                out[name] = {"n": cell["n"], "s": float(cell["s"])}
            else:
                n = prev["n"] + cell["n"]
                out[name] = {
                    "n": n,
                    "s": (prev["s"] * prev["n"] + float(cell["s"]) * cell["n"]) / n,
                }
    return out


def effective_timings(entry) -> Dict[str, float]:
    """The timing table the planner's argmin consults: plan-time race
    medians overlaid by the *observed* channel where real executions
    have been recorded (``record_observed`` / ``Plan.profile``) -- a
    candidate's observed mean from production runs outranks its one-off
    race time. Returns {} for malformed entries."""
    if not isinstance(entry, dict):
        return {}
    timings = entry.get("timings")
    eff = {
        k: float(v)
        for k, v in (timings.items() if isinstance(timings, dict) else ())
        if isinstance(v, (int, float))
    }
    obs = entry.get("observed")
    if isinstance(obs, dict):
        for name, cell in obs.items():
            if _valid_observed_cell(cell):
                eff[name] = float(cell["s"])
    return eff


def merge_wisdom_entry(old, new) -> dict:
    """Combine two wisdom entries for the same key: the per-candidate
    timing tables union (both measurements were real; a candidate timed
    by either run stays known), the observed-timings channels union
    count-weighted, and the pinned backend becomes the argmin of the
    combined :func:`effective_timings`. A malformed side loses to a
    well-formed one outright -- wisdom is advisory, so the merge can
    never raise."""
    old_t = old.get("timings") if isinstance(old, dict) else None
    new_t = new.get("timings") if isinstance(new, dict) else None
    if not isinstance(new_t, dict) or not new_t:
        return old if isinstance(old_t, dict) and old_t else new
    if not isinstance(old_t, dict) or not old_t:
        return new
    timings = dict(old_t)
    timings.update(new_t)
    merged = dict(new)
    merged["timings"] = timings
    observed = _merge_observed(old.get("observed"), new.get("observed"))
    if observed:
        merged["observed"] = observed
    eff = effective_timings(merged)
    merged["backend"] = min(sorted(eff), key=eff.__getitem__)
    return merged


def _atomic_write(path: str, text: str) -> None:
    """Write ``text`` to ``path`` via a same-directory temp file +
    ``os.replace``, so a concurrent reader (another serving pool, a
    benchmark run) never sees a half-written wisdom file."""
    directory = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=directory, prefix=".wisdom.", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def export_wisdom(path: Optional[str] = None, *, merge: bool = True) -> str:
    """Serialize accumulated wisdom to JSON; write it to ``path`` when
    given. Returns the JSON text either way.

    The write is atomic (temp file + ``os.replace``) and, with ``merge``
    (default), folds any wisdom already at ``path`` into the output via
    :func:`merge_wisdom_entry` -- so two concurrent writers (a serving
    pool exporting its warm pool, a benchmark run exporting its sweep)
    interleave instead of clobbering each other's entries.
    ``merge=False`` writes exactly this process's wisdom.

    The output carries a top-level ``calibration`` section alongside
    ``entries``: the per-device-kind fabric constants from the
    calibration store, merged against the file's the same way (count-
    weighted, :func:`record_calibration`'s contract) -- so one wisdom
    file ships both *which backend won* and *the alpha/beta it won
    under*."""
    entries: Dict[str, dict] = dict(_WISDOM)
    calibration: Dict[str, dict] = {k: dict(c) for k, c in _CALIBRATION.items()}
    if path is not None and merge and os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            data = None  # unreadable existing file: overwrite it
        if isinstance(data, dict) and data.get("version") == WISDOM_VERSION:
            disk = data.get("entries")
            if isinstance(disk, dict):
                for key, entry in disk.items():
                    if key in entries:
                        entries[key] = merge_wisdom_entry(entry, entries[key])
                    else:
                        entries[key] = entry
            disk_cal = data.get("calibration")
            if isinstance(disk_cal, dict):
                for dev, cell in disk_cal.items():
                    if dev in calibration:
                        calibration[dev] = _merge_calibration_cell(cell, calibration[dev])
                    elif _valid_calibration_cell(cell):
                        calibration[dev] = cell
    doc = {"version": WISDOM_VERSION, "entries": entries}
    if calibration:
        doc["calibration"] = calibration
    text = json.dumps(doc, indent=2, sort_keys=True)
    if path is not None:
        _atomic_write(path, text)
    return text


def import_wisdom(source: str) -> int:
    """Merge wisdom from a JSON string or a path to a JSON file.
    Returns the number of entries merged; wrong-version files merge 0
    (wisdom is advisory -- stale formats are dropped, never an error).
    Keys already known in-process merge via :func:`merge_wisdom_entry`
    (timing tables union, backend re-argmins) rather than being
    overwritten, so importing an older file can't undo newer
    measurements of candidates it never timed."""
    text = source
    if not source.lstrip().startswith(("{", "[")):
        # not JSON text -> must be a path; surface a missing file as such
        # rather than a baffling JSONDecodeError on the path string
        with open(source) as f:
            text = f.read()
    data = json.loads(text)
    if not isinstance(data, dict) or data.get("version") != WISDOM_VERSION:
        return 0
    calibration = data.get("calibration")
    if isinstance(calibration, dict):
        # the calibration section merges even when the entry table is
        # empty/absent -- a calibration-only wisdom file is valid
        for dev, cell in calibration.items():
            if _valid_calibration_cell(cell):
                _CALIBRATION[dev] = _merge_calibration_cell(_CALIBRATION.get(dev), cell)
    entries = data.get("entries")
    if not isinstance(entries, dict):
        return 0
    for key, entry in entries.items():
        if key in _WISDOM:
            _WISDOM[key] = merge_wisdom_entry(_WISDOM[key], entry)
        else:
            _WISDOM[key] = entry
    return len(entries)


def parse_wisdom_key(key: str) -> Optional[dict]:
    """Decode a wisdom key back into the problem it describes, or None
    when the key is unparseable (foreign/stale formats are skipped, not
    errors -- same advisory contract as :func:`import_wisdom`).

    Returns a dict with ``shape`` (tuple), ``ndim``, ``dtype``, ``p``,
    ``dev``, ``decomp``, ``direction``, ``real``, ``pad``,
    ``transpose_back``, ``local_impl`` and -- pencil keys -- ``grid``
    ((rows, cols)) and ``axes`` ((row_axis, col_axis)). The serving
    plan pool uses this to pre-plan every shape a wisdom file knows."""
    fields: Dict[str, str] = {}
    parts = key.split("|")
    if not parts or parts[0] != f"v{WISDOM_VERSION}":
        return None
    for part in parts[1:]:
        name, sep, value = part.partition("=")
        if sep and name not in fields:  # opts fields never shadow base ones
            fields[name] = value
    try:
        shape = tuple(int(d) for d in fields["shape"].split("x"))
        out = {
            "shape": shape,
            "ndim": int(fields["ndim"]),
            "dtype": fields["dtype"],
            "p": int(fields["P"]),
            "dev": fields["dev"],
        }
    except (KeyError, ValueError):
        return None
    # the last |-field is the opts blob: comma-separated name=value pairs
    # (see plan_measured's wisdom_key call); on an opts-less key it is
    # the dev field, which parses to nothing relevant and defaults apply
    opts: Dict[str, str] = {}
    for part in parts[-1].split(","):
        name, sep, value = part.partition("=")
        if sep:
            opts[name] = value
    out["decomp"] = opts.get("decomp", "slab")
    out["direction"] = opts.get("dir", "forward")
    out["local_impl"] = opts.get("impl", "jnp")
    out["real"] = opts.get("real") == "1"
    out["pad"] = opts.get("pad", "1") == "1"
    out["transpose_back"] = opts.get("tb") == "1"
    out["fuse_dft"] = opts.get("fuse") == "1"
    out["pipeline"] = opts.get("pipe")  # None unless pinned at measure time
    if out["decomp"] == "pencil":
        try:
            rows, _, cols = opts["grid"].partition("x")
            row_ax, _, col_ax = opts["axes"].partition("+")
            out["grid"] = (int(rows), int(cols))
            out["axes"] = (row_ax, col_ax)
        except (KeyError, ValueError):
            return None
    return out


def wisdom_items():
    """Snapshot of the in-process wisdom store as (key, entry) pairs --
    the read-only view the serving pool's warm start iterates."""
    return list(_WISDOM.items())


def record_observed(plan, seconds, *, backend: Optional[str] = None) -> bool:
    """Fold one *observed* whole-transform execution time (seconds of
    wall clock from real telemetry -- ``Plan.profile``, a trace span, a
    serving window) into the wisdom observed channel for the plan's
    problem key.

    The entry's ``observed`` table keeps a count-weighted running mean
    per candidate, and the entry's pinned ``backend`` re-argmins over
    :func:`effective_timings` -- so the measured planner consults real
    executions, not just its plan-time races, and ``export_wisdom``
    ships what production actually saw. Only plans produced by
    ``planner="measure"`` carry a ``wisdom_key``; anything else (or a
    forgotten key, or a non-positive/NaN duration) is a no-op returning
    False."""
    key = getattr(plan, "wisdom_key", None)
    if key is None or not (seconds > 0):
        return False
    entry = _WISDOM.get(key)
    if not isinstance(entry, dict):
        return False
    name = backend if backend is not None else getattr(plan, "backend", None)
    if not isinstance(name, str):
        return False
    obs = entry.get("observed")
    if not isinstance(obs, dict):
        obs = entry["observed"] = {}
    cell = obs.get(name)
    if _valid_observed_cell(cell):
        n = cell["n"] + 1
        cell = {"n": n, "s": (cell["s"] * cell["n"] + float(seconds)) / n}
    else:
        cell = {"n": 1, "s": float(seconds)}
    obs[name] = cell
    eff = effective_timings(entry)
    if eff:
        entry["backend"] = min(sorted(eff), key=eff.__getitem__)
    return True


def forget_wisdom() -> None:
    """Drop all accumulated wisdom (``fftw_forget_wisdom``)."""
    _WISDOM.clear()


def wisdom_size() -> int:
    return len(_WISDOM)


def wisdom_report(*, stale_ratio: float = 2.0) -> List[dict]:
    """Decision-health report over the in-process wisdom store: one row
    per entry with the per-candidate drift of the *observed* channel
    (production executions folded in by :func:`record_observed` /
    ``Plan.profile``) against the plan-time race median. An entry whose
    observed mean drifts more than ``stale_ratio`` x (either way) from
    its race time is flagged ``stale`` -- the fabric has moved since the
    race and the plan deserves re-measuring. Fleet operators read this;
    serve ``metrics()`` exports the stale count as a gauge."""
    rows = []
    for key, entry in wisdom_items():
        if not isinstance(entry, dict):
            continue
        timings = entry.get("timings")
        timings = timings if isinstance(timings, dict) else {}
        obs = entry.get("observed")
        obs = obs if isinstance(obs, dict) else {}
        drifts: Dict[str, float] = {}
        observed_n = 0
        for name, cell in obs.items():
            if not _valid_observed_cell(cell):
                continue
            observed_n += int(cell["n"])
            race = timings.get(name)
            if isinstance(race, (int, float)) and race > 0:
                drifts[name] = float(cell["s"]) / float(race)
        stale = any(d > stale_ratio or d < 1.0 / stale_ratio for d in drifts.values())
        rows.append(
            {
                "key": key,
                "backend": entry.get("backend"),
                "candidates": len(timings),
                "observed_n": observed_n,
                "drifts": drifts,
                "max_drift": max(drifts.values()) if drifts else None,
                "stale": stale,
            }
        )
    return rows


# ---------------------------------------------------------------------------
# Calibration store (persisted per-fabric alpha/beta, default-on)
# ---------------------------------------------------------------------------

#: device_kind -> {"alpha_s", "beta_bytes_s", "n", "source", "backends"?}
#: -- the fitted fabric constants every default-params Plan prices with.
_CALIBRATION: Dict[str, dict] = {}

#: Module override for the auto-calibrate switch; None = consult the
#: ``REPRO_AUTO_CALIBRATE`` env var (default on).
_AUTO_CALIBRATE: Optional[bool] = None

#: Device kinds whose auto-calibration already failed this process --
#: retrying on every plan would turn one broken sweep into a tax.
_AUTO_CALIBRATE_FAILED: set = set()


def auto_calibrate_enabled() -> bool:
    """Whether ``plan_measured`` may run the ppermute calibration sweep
    on a fresh race. Default on; ``REPRO_AUTO_CALIBRATE=0`` (the test
    suite sets it -- subprocesses inherit) or
    :func:`set_auto_calibrate` ``(False)`` disables."""
    if _AUTO_CALIBRATE is not None:
        return _AUTO_CALIBRATE
    return os.environ.get("REPRO_AUTO_CALIBRATE", "1") != "0"


def set_auto_calibrate(enabled: Optional[bool]) -> None:
    """Override the auto-calibrate switch (None = back to the env var)."""
    global _AUTO_CALIBRATE
    _AUTO_CALIBRATE = enabled


def _valid_calibration_cell(cell) -> bool:
    return (
        isinstance(cell, dict)
        and isinstance(cell.get("alpha_s"), (int, float))
        and cell["alpha_s"] >= 0
        and isinstance(cell.get("beta_bytes_s"), (int, float))
        and cell["beta_bytes_s"] > 0
    )


def _merge_calibration_cell(old, new) -> dict:
    """Count-weighted merge of two calibration cells for one device
    kind (``merge_wisdom_entry``'s contract: malformed sides lose
    outright, the merge never raises). Per-backend sub-cells union the
    same way."""
    if not _valid_calibration_cell(new):
        return old if _valid_calibration_cell(old) else new
    if not _valid_calibration_cell(old):
        return new
    n_old = old.get("n") if isinstance(old.get("n"), (int, float)) and old.get("n", 0) > 0 else 1
    n_new = new.get("n") if isinstance(new.get("n"), (int, float)) and new.get("n", 0) > 0 else 1
    n = n_old + n_new
    merged = dict(new)
    merged["alpha_s"] = (old["alpha_s"] * n_old + new["alpha_s"] * n_new) / n
    merged["beta_bytes_s"] = (old["beta_bytes_s"] * n_old + new["beta_bytes_s"] * n_new) / n
    merged["n"] = n
    backends = {}
    for side in (old.get("backends"), new.get("backends")):
        if not isinstance(side, dict):
            continue
        for name, sub in side.items():
            backends[name] = _merge_calibration_cell(backends.get(name), sub)
    if backends:
        merged["backends"] = backends
    return merged


def record_calibration(
    dev_kind: str,
    params,
    *,
    source: str = "calibrate",
    n: int = 1,
    backends: Optional[Dict[str, object]] = None,
) -> dict:
    """Fold fitted fabric constants into the in-process calibration
    store (count-weighted against what is already known, exactly like
    the wisdom observed channel). ``params`` is a
    :class:`repro.core.comm_model.CommParams`; ``backends`` optionally
    maps backend names to their own fitted CommParams (the per-backend-
    class fingerprint ``benchmarks/planner_score.py`` stamps into meta).
    Returns the merged cell."""
    cell = {
        "alpha_s": float(params.alpha_s),
        "beta_bytes_s": float(params.beta_bytes_s),
        "n": int(n),
        "source": source,
    }
    if backends:
        cell["backends"] = {
            name: {
                "alpha_s": float(p.alpha_s),
                "beta_bytes_s": float(p.beta_bytes_s),
                "n": int(n),
            }
            for name, p in backends.items()
        }
    merged = _merge_calibration_cell(_CALIBRATION.get(dev_kind), cell)
    _CALIBRATION[dev_kind] = merged
    return merged


def calibration_cell(dev_kind: str) -> Optional[dict]:
    """The raw stored cell for a device kind (None when uncalibrated)."""
    cell = _CALIBRATION.get(dev_kind)
    return cell if _valid_calibration_cell(cell) else None


def calibration_for(dev_kind: str, backend: Optional[str] = None):
    """Fabric constants for a device kind as a ``CommParams`` (None when
    uncalibrated -- callers fall back to the module defaults). With
    ``backend``, the per-backend-class fit when one is stored, else the
    pooled fit."""
    cell = calibration_cell(dev_kind)
    if cell is None:
        return None
    from repro.core import comm_model as cm

    if backend is not None:
        sub = (cell.get("backends") or {}).get(backend)
        if _valid_calibration_cell(sub):
            return cm.CommParams(
                alpha_s=float(sub["alpha_s"]), beta_bytes_s=float(sub["beta_bytes_s"])
            )
    return cm.CommParams(
        alpha_s=float(cell["alpha_s"]), beta_bytes_s=float(cell["beta_bytes_s"])
    )


def calibration_items():
    """Snapshot of the calibration store as (device_kind, cell) pairs."""
    return list(_CALIBRATION.items())


def forget_calibration() -> None:
    """Drop all stored fabric constants (tests; paired with
    :func:`forget_wisdom`)."""
    _CALIBRATION.clear()
    _AUTO_CALIBRATE_FAILED.clear()


def ensure_calibrated(
    mesh,
    axis_name: Optional[str] = None,
    *,
    timer: Optional[Callable] = None,
    sizes=None,
    force: bool = False,
):
    """Run :meth:`CommParams.calibrate` once per device kind and record
    the fit in the calibration store -- the default-on path
    ``plan_measured`` takes on a fresh race, and the API a host calls
    explicitly at startup. Already-calibrated device kinds return the
    stored constants without re-measuring (``force=True`` re-sweeps).
    ``timer(m_bytes) -> seconds`` injects a synthetic sweep (tests)."""
    from repro.core import comm_model as cm

    dev = device_kind(mesh)
    if not force:
        known = calibration_for(dev)
        if known is not None:
            return known
    kwargs = {} if sizes is None else {"sizes": sizes}
    params = cm.CommParams.calibrate(mesh, axis_name, timer=timer, **kwargs)
    record_calibration(dev, params, source="calibrate")
    return params


def _auto_calibrate(mesh) -> None:
    """Best-effort once-per-device-kind calibration on the fresh-race
    path. A failed sweep (exotic mesh, collective error) warns once and
    disables itself for that device kind -- planning must never break
    because calibration did."""
    dev = device_kind(mesh)
    if dev in _CALIBRATION or dev in _AUTO_CALIBRATE_FAILED:
        return
    try:
        ensure_calibrated(mesh)
    except Exception as e:  # noqa: BLE001 - advisory, never fatal
        import warnings

        _AUTO_CALIBRATE_FAILED.add(dev)
        warnings.warn(
            f"auto-calibration failed on {dev!r} ({e}); planning continues "
            f"with default CommParams (set REPRO_AUTO_CALIBRATE=0 to silence)",
            RuntimeWarning,
            stacklevel=3,
        )


# ---------------------------------------------------------------------------
# Measured planning
# ---------------------------------------------------------------------------


def candidate_backends(p: int, *, fuse_dft: bool = False) -> List[str]:
    """Backends eligible for measurement at this shard count. ``fuse_dft``
    is a scatter-only feature, so it collapses the field."""
    from repro.core import backends

    if fuse_dft:
        return ["scatter"] if backends.get("scatter").supports(p) else []
    return list(backends.supporting(p))


#: Candidate-variant separator. A plain name is the backend at the
#: caller's own pipeline setting (fused by default where streaming);
#: ``name@u`` is the unfused monolithic run of the same backend, and
#: ``name@f<k>`` the fused run with an n_chunks=k sub-chunked pipeline --
#: the measured planner races these (backend, n_chunks, fused) triples.
VARIANT_SEP = "@"


def parse_variant(candidate: str):
    """(base_backend, pipeline_override) of a measured-candidate id;
    ``None`` override means 'the caller's own pipeline setting'."""
    if VARIANT_SEP not in candidate:
        return candidate, None
    base, _, tag = candidate.rpartition(VARIANT_SEP)
    if tag == "u":
        return base, False
    if tag.startswith("f") and tag[1:].isdigit():
        return base, int(tag[1:])
    raise ValueError(
        f"unknown measured-candidate variant {candidate!r} "
        f"(expected 'name', 'name@u' or 'name@f<n_chunks>')"
    )


def variant_id(base: str, pipeline_override) -> str:
    """Inverse of :func:`parse_variant`: re-attach a pipeline override to
    a (possibly pair-key) base backend name."""
    if pipeline_override is None:
        return base
    if pipeline_override in (False, 0):
        return f"{base}{VARIANT_SEP}u"
    return f"{base}{VARIANT_SEP}f{int(pipeline_override)}"


def predict_candidate(plan, candidate: str, pipeline="auto") -> float:
    """Model prediction matching one measured candidate id: ``@u`` is
    unfused, ``@f<k>`` fused with n_chunks=k, and a plain name resolves
    to ``pipeline`` -- the setting the candidates were raced under
    (default "auto" = fused wherever the backend streams) -- so benches
    can print measured and model columns for the same
    (backend, n_chunks, fused) triple.

    Implemented as a schedule rewrite: the candidate id is applied to the
    plan's own stage schedule (:func:`repro.core.schedule.apply_variant`)
    and the rewritten schedule is costed stage by stage -- the exact
    pipeline the candidate would execute is the one being priced."""
    import repro.core.schedule as sch

    rewritten = sch.apply_variant(plan.schedule(), candidate, pipeline=pipeline)
    r_item, c_item = plan._byte_sizes()
    return sch.predict_seconds(
        rewritten, plan.params, plan._auto_chunk_compute_s(), r_item, c_item
    )


def candidate_variants(
    names: List[str], *, decomp: str, p: int, p_rows: int = 1, p_cols: int = 1
) -> List[str]:
    """Expand plain backend candidates into (backend, n_chunks, fused)
    triples: every streaming candidate additionally races its unfused
    monolithic twin (``@u``) and -- slab only, to keep the pencil pair
    field bounded -- a 2P-chunk sub-chunked pipeline (``@f2P``). Plain
    names keep their default (fused) resolution, so an all-monolithic
    field is byte-identical to the pre-pipeline candidate set (and its
    wisdom keys): old wisdom never aliases a fused entry because any
    field containing one has variant ids in its key."""
    from repro.core import backends
    from repro.core.plan import split_pair

    out = list(names)
    for nm in names:
        if decomp == "pencil":
            br, bc = split_pair(nm)
            streams = (backends.get(br).supports_chunk_fn and p_rows > 1) or (
                backends.get(bc).supports_chunk_fn and p_cols > 1
            )
        else:
            streams = backends.get(nm).supports_chunk_fn and p > 1
        if streams:
            out.append(f"{nm}{VARIANT_SEP}u")
            if decomp != "pencil":
                out.append(f"{nm}{VARIANT_SEP}f{2 * p}")
    return out


def candidate_pairs(p_rows: int, p_cols: int) -> List[str]:
    """Every measurable ``"row+col"`` pair for a pencil grid: the cross
    product of shard_map backends supporting each sub-ring size (the
    same eligibility filter ``Plan.predict_axes`` ranks)."""
    from repro.core import backends
    from repro.core.plan import pair_key

    rows = backends.supporting(p_rows, kind="shard_map")
    cols = backends.supporting(p_cols, kind="shard_map")
    return [pair_key(r, c) for r in rows for c in cols]


def plan_measured(
    global_shape,
    mesh,
    *,
    ndim: int = 2,
    direction: str = "forward",
    backend: str = "auto",
    axis_name: Optional[str] = None,
    local_impl: str = "jnp",
    fuse_dft: bool = False,
    transpose_back: bool = False,
    dtype=None,
    params=None,
    chunk_compute_s: float = 0.0,
    timer: Optional[Callable] = None,
    use_wisdom: bool = True,
    warmup: int = 1,
    iters: int = 5,
    decomp: str = "slab",
    row_axis: Optional[str] = None,
    col_axis: Optional[str] = None,
    real: bool = False,
    pad: bool = True,
    pipeline="auto",
):
    """FFTW_MEASURE: time every candidate backend on the real mesh, pin
    the plan to the measured argmin, and remember the answer as wisdom.

    ``backend="auto"`` measures every registered backend supporting P --
    under ``decomp="pencil"``, every ``"row+col"`` pair of shard_map
    backends supporting the sub-ring sizes. With the default
    ``pipeline="auto"`` the field expands to (backend, n_chunks, fused)
    triples (see :func:`candidate_variants`): each streaming candidate
    additionally races its unfused monolithic twin and -- slab -- a
    sub-chunked pipeline, so the measured winner settles the overlap
    question per problem, not per model. A pinned ``backend=`` name
    (or pair) restricts the base field to that one (its variants still
    race; the timings land on ``Plan.measured``). ``timer(plan) ->
    seconds`` replaces the real measurement when injected. Wisdom keys
    carry the decomposition, grid shape/axes, and the candidate-variant
    set -- pre-pipeline wisdom (plain-name fields) imports cleanly and
    can never alias a fused entry, whose field necessarily contains
    variant ids.
    """
    import jax.numpy as jnp

    from repro.core.plan import Plan, pair_key, split_pair

    if dtype is None:
        dtype = jnp.float32 if real else jnp.complex64

    def build(candidate: str) -> Plan:
        base, pipe_override = parse_variant(candidate) if isinstance(
            candidate, str
        ) else (candidate, None)
        plan = Plan(
            global_shape,
            mesh,
            ndim=ndim,
            direction=direction,
            backend=base,
            axis_name=axis_name,
            local_impl=local_impl,
            fuse_dft=fuse_dft,
            transpose_back=transpose_back,
            dtype=dtype,
            params=params,
            chunk_compute_s=chunk_compute_s,
            decomp=decomp,
            row_axis=row_axis,
            col_axis=col_axis,
            real=real,
            pad=pad,
            pipeline=pipeline if pipe_override is None else pipe_override,
        )
        if pipe_override is not None:
            plan.backend = candidate  # report the variant it actually is
        return plan

    from repro.core.sharding import fft_axis

    # one probe plan resolves decomp="auto", the grid, and validates the
    # shape once; candidates then rebuild with the resolved decomposition.
    # The probe uses the caller's backend so a pinned backend that only
    # works under one decomposition steers auto the same way estimate does
    probe = build(backend)
    p = probe.shards
    # a variant-suffixed pinned backend ("scatter@u", Plan.backend of a
    # measured winner) pins the pipeline too: race that one candidate
    from repro.core.plan import pipeline_is_default

    pinned_pipe = None
    if isinstance(backend, str) and backend != "auto":
        backend, pinned_pipe = parse_variant(backend)
    if pinned_pipe is not None and not pipeline_is_default(pipeline):
        raise ValueError(
            f"backend variant suffix and pipeline={pipeline!r} both specify "
            f"the pipeline; pass one or the other"
        )
    race_variants = pipeline_is_default(pipeline) and pinned_pipe is None
    if probe.decomp == "pencil":
        grid = probe.grid
        if backend == "auto":
            names = candidate_pairs(grid.p_rows, grid.p_cols)
        else:
            names = [variant_id(pair_key(*split_pair(backend)), pinned_pipe)]
        if race_variants:
            names = candidate_variants(
                names, decomp="pencil", p=p, p_rows=grid.p_rows, p_cols=grid.p_cols
            )
        placement = (
            f"decomp=pencil,grid={grid.p_rows}x{grid.p_cols},"
            f"axes={grid.row_axis}+{grid.col_axis}"
        )
    else:
        ax = axis_name or fft_axis(mesh)
        if backend == "auto":
            names = candidate_backends(p, fuse_dft=fuse_dft)
        else:
            names = [variant_id(backend, pinned_pipe)]
        if race_variants:
            names = candidate_variants(names, decomp="slab", p=p)
        placement = f"decomp=slab,ax={ax}"
    if not names:
        raise ValueError(f"no measurable backend supports P={p}")
    decomp = probe.decomp  # pin for the candidate builds
    if decomp == "slab":
        row_axis = col_axis = None  # auto may have fallen back from pencil

    key = wisdom_key(
        tuple(global_shape),
        ndim,
        probe.dtype.name,  # the resolved dtype (real plans: the real side)
        p,
        tuple(names),
        device_kind(mesh),
        opts=(
            f"mesh={'x'.join(f'{k}{v}' for k, v in mesh.shape.items())},"
            f"{placement},dir={direction},impl={local_impl},"
            f"fuse={int(fuse_dft)},tb={int(transpose_back)}"
            # r2c winners must never alias c2c ones (nor padded vs
            # strict); c2c keys stay byte-identical to the pre-real
            # format -- pad is a no-op there, and appending it would
            # both re-measure on a spurious pad= argument and orphan
            # every previously exported c2c wisdom entry
            + (f",real=1,pad={int(pad)}" if real else "")
            # a pinned pipeline changes every candidate's execution, so
            # it keys separately; the default ("auto") keeps the
            # pre-pipeline byte format -- any field that can fuse
            # already carries variant ids in its candidate set, so old
            # wisdom can never alias a fused entry
            + ("" if race_variants else f",pipe={pipeline}")
        ),
    )
    if use_wisdom and key in _WISDOM:
        entry = _WISDOM[key]
        best = entry.get("backend") if isinstance(entry, dict) else None
        timings = entry.get("timings") if isinstance(entry, dict) else None
        if best in names and isinstance(timings, dict) and timings:
            plan = build(best)  # still validates shape/mesh/backend
            plan.planner = "measure"
            plan.measured = dict(timings)
            failed = entry.get("failed")
            plan.race_failures = dict(failed) if isinstance(failed, dict) else {}
            plan.wisdom_hit = True
            plan.wisdom_key = key
            # provenance: did the observed channel (production
            # executions) overrule the plan-time race argmin?
            raw = {
                k: float(v) for k, v in timings.items() if isinstance(v, (int, float))
            }
            race_best = min(sorted(raw), key=raw.__getitem__) if raw else None
            obs = entry.get("observed")
            observed = isinstance(obs, dict) and any(
                _valid_observed_cell(c) for c in obs.values()
            )
            plan.selection_channel = (
                "observed-overlay" if observed and best != race_best else "wisdom-hit"
            )
            return plan
        # wisdom is advisory: a malformed/stale entry (e.g. a hand-edited
        # or foreign wisdom file, or one without usable timings) is
        # dropped and we re-measure
        _WISDOM.pop(key, None)

    # fresh race on the real fabric: fit this device kind's alpha/beta
    # first (once per process; REPRO_AUTO_CALIBRATE=0 disables), so the
    # candidate plans built below -- and every model_us column derived
    # from them -- price with measured constants, not the v5e defaults.
    # An injected timer means no real fabric is being measured, so there
    # is nothing to calibrate against.
    if timer is None and auto_calibrate_enabled():
        _auto_calibrate(mesh)

    timer = timer or default_timer(warmup=warmup, iters=iters)
    plans: Dict[str, Plan] = {}
    timings: Dict[str, float] = {}
    failures: Dict[str, str] = {}
    for name in names:
        # a candidate that raises mid-race (backend bug, injected fault,
        # a collective that lost its ring) is recorded as failed --
        # timing inf, excluded from the argmin, noted in Plan.why() --
        # instead of aborting the whole measured race
        try:
            plans[name] = build(name)
            timings[name] = float(timer(plans[name]))
        except Exception as e:  # noqa: BLE001 -- race isolation boundary
            timings[name] = float("inf")
            failures[name] = f"{type(e).__name__}: {e}"
    finite = {k: v for k, v in timings.items() if math.isfinite(v)}
    if not finite:
        raise RuntimeError(
            f"measured race: every candidate failed for {key}: {failures}"
        )
    best = min(sorted(finite), key=finite.__getitem__)

    _WISDOM[key] = {
        "backend": best,
        # finite timings only: inf is not JSON, and a failed candidate
        # must never win a later wisdom-hit argmin
        "timings": dict(finite),  # own copy: Plan.measured stays mutable
        "device_kind": device_kind(mesh),
        **({"failed": dict(failures)} if failures else {}),
    }
    plan = plans[best]
    plan.planner = "measure"
    plan.measured = timings
    plan.race_failures = failures
    plan.wisdom_hit = False
    plan.wisdom_key = key
    plan.selection_channel = "measured-race"
    return plan
