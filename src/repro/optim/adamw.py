"""AdamW with dtype-configurable state (HBM relief at 671B scale).

State is sharded exactly like the params (same logical specs), so FSDP
keeps optimizer memory per-device at (2 * state_bytes / chips). With
``opt_state_dtype='bfloat16'`` the m/v moments halve again -- the knob
that lets deepseek-v3-671b train on a 512-chip v5e slice (DESIGN.md §5,
EXPERIMENTS.md §Dry-run memory table).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    count: jax.Array
    mu: Any
    nu: Any


def init(params, dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        count=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(z, params),
        nu=jax.tree.map(z, params),
    )


def state_specs(param_specs) -> AdamWState:
    """Optimizer-state sharding mirrors the params."""
    return AdamWState(count=((),), mu=param_specs, nu=param_specs)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: jax.Array,
    cfg: TrainConfig,
) -> Tuple[Any, AdamWState]:
    c = state.count + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** c.astype(jnp.float32)
    bc2 = 1 - b2 ** c.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m2 / bc1
        vhat = v2 / bc2
        step = mhat / (jnp.sqrt(vhat) + 1e-8) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(count=c, mu=new_m, nu=new_v)
