"""Gradient compression for cross-pod all-reduce: int8 quantization with
error feedback.

At 512+ chips the data-parallel gradient reduce-scatter is the largest
recurring collective. Within a pod the ICI is fast; *between* pods the
per-link budget is the bottleneck, so we compress the cross-pod leg:

    q = round(g / scale) in int8, scale = max|g| / 127 (per tensor)
    residual e <- g - q * scale carried to the next step (error feedback,
    keeps SGD convergence despite biased rounding)

``compressed_psum`` is the shard_map building block; the decomposed-ring
variant reuses core/overlap.py's reduce-scatter/all-gather rings over the
int8 payload -- the paper's decomposed-collective idea applied to the
optimizer's communication (4x fewer bytes x overlappable hops).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from repro.core.compat import axis_size


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    g: jax.Array, axis_name: str, err: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce (mean) over ``axis_name``.

    Returns (reduced mean gradient f32, new error residual). Must run
    inside shard_map. The int8 payload is psum'd as int32 (exact), the
    per-device scales are gathered so dequantization is exact per source.
    """
    gf = g.astype(jnp.float32) + err
    q, scale = quantize_int8(gf)
    new_err = gf - dequantize_int8(q, scale)
    # Ship int8 on the wire: all-gather the quantized payload + per-device
    # scales ((P-1)/P * 1 byte/elem vs 2(P-1)/P * 4 for a f32 ring
    # all-reduce = 8x fewer ICI bytes), dequantize-and-sum locally.
    n = axis_size(axis_name)
    q_all = lax.all_gather(q, axis_name)  # (P, ...) int8 on the wire
    s_all = lax.all_gather(scale, axis_name)  # (P,) f32 (negligible)
    total = jnp.tensordot(
        s_all, q_all.astype(jnp.float32).reshape(n, -1), axes=1
    ).reshape(g.shape)
    return total / n, new_err


def compressed_psum_tree(grads, axis_name: str, errs):
    """Tree version; errs mirrors grads (f32 residuals)."""
    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(errs)
    out, new_e = [], []
    for g, e in zip(flat_g, flat_e):
        r, e2 = compressed_psum(g, axis_name, e)
        out.append(r.astype(g.dtype))
        new_e.append(e2)
    return td.unflatten(out), td.unflatten(new_e)


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
