from repro.optim import adamw, compress, schedule

__all__ = ["adamw", "compress", "schedule"]
