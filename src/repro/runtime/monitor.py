"""Step-time monitoring + straggler detection.

On an SPMD TPU fleet every chip executes the same program, so classic
work-stealing does not apply; the operable levers are (a) detecting that
steps are slower than the fleet baseline (failing HBM, thermal throttle,
a slow host input pipeline), (b) flagging the offender for the scheduler
to cordon, and (c) keeping the input pipeline ahead of the device so a
slow host never blocks the collective. This module implements the
detection half; launch/train.py wires it to logging + the recovery loop.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Dict, Iterable, List, Optional, Sequence


@dataclasses.dataclass
class StepStats:
    step: int
    seconds: float
    tokens: int
    flagged: bool


def percentiles(
    samples: Iterable[float], qs: Sequence[float] = (50, 90, 99)
) -> Dict[str, float]:
    """Nearest-rank percentiles of ``samples``: ``{"p50": ..., ...}``.
    Empty input returns 0.0 for every quantile (a serving dashboard
    wants numbers, not exceptions, before traffic arrives)."""
    data = sorted(samples)
    out = {}
    for q in qs:
        if not 0 <= q <= 100:
            raise ValueError(f"percentile must be in [0, 100], got {q}")
        label = f"{q:g}".replace(".", "_")
        if not data:
            out[f"p{label}"] = 0.0
            continue
        # nearest-rank: ceil(q/100 * n), 1-indexed; p0 -> first sample
        rank = max(1, math.ceil(q / 100 * len(data)))
        out[f"p{label}"] = float(data[min(rank, len(data)) - 1])
    return out


class LatencyWindow:
    """Rolling window of recent scalar samples (latencies, queue depths)
    with O(1) record and on-demand percentile summaries -- the telemetry
    primitive behind the spectral serving engine's p50/p99 stats."""

    def __init__(self, maxlen: int = 2048):
        self._window: collections.deque = collections.deque(maxlen=maxlen)
        self.count = 0  # lifetime samples, not just the retained window
        self.total = 0.0

    def record(self, value: float) -> None:
        self._window.append(float(value))
        self.count += 1
        self.total += float(value)

    def __len__(self) -> int:
        return len(self._window)

    def percentiles(self, qs: Sequence[float] = (50, 90, 99)) -> Dict[str, float]:
        return percentiles(self._window, qs)

    def summary(self, qs: Sequence[float] = (50, 90, 99)) -> Dict[str, float]:
        out = self.percentiles(qs)
        out["count"] = self.count
        out["mean"] = (self.total / self.count) if self.count else 0.0
        out["max"] = max(self._window) if self._window else 0.0
        return out


class StepMonitor:
    def __init__(self, *, ema_alpha: float = 0.1, straggler_factor: float = 2.0, warmup: int = 3):
        self.ema: Optional[float] = None
        self.alpha = ema_alpha
        self.factor = straggler_factor
        self.warmup = warmup
        self.history: List[StepStats] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, *, tokens: int = 0) -> StepStats:
        dt = time.perf_counter() - self._t0
        flagged = False
        if len(self.history) >= self.warmup and self.ema is not None:
            flagged = dt > self.factor * self.ema
        if self.ema is None:
            self.ema = dt
        elif not flagged:  # don't let outliers poison the baseline
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        st = StepStats(self._step, dt, tokens, flagged)
        self.history.append(st)
        self._step += 1
        return st

    def percentiles(
        self, qs: Sequence[float] = (50, 90, 99), window: Optional[int] = None
    ) -> Dict[str, float]:
        """Step-time percentiles over the most recent ``window`` steps
        (default: all history) -- the p50/p99 view of the same samples
        the EMA smooths."""
        recent = self.history if window is None else self.history[-window:]
        return percentiles((s.seconds for s in recent), qs)

    @property
    def tokens_per_sec(self) -> float:
        recent = self.history[-10:]
        tok = sum(s.tokens for s in recent)
        sec = sum(s.seconds for s in recent)
        return tok / sec if sec else 0.0

    def straggler_report(self) -> dict:
        flags = [s for s in self.history if s.flagged]
        return {
            "steps": len(self.history),
            "flagged": len(flags),
            "ema_s": self.ema,
            "worst": max((s.seconds for s in self.history), default=0.0),
        }
