"""Step-time monitoring + straggler detection.

On an SPMD TPU fleet every chip executes the same program, so classic
work-stealing does not apply; the operable levers are (a) detecting that
steps are slower than the fleet baseline (failing HBM, thermal throttle,
a slow host input pipeline), (b) flagging the offender for the scheduler
to cordon, and (c) keeping the input pipeline ahead of the device so a
slow host never blocks the collective. This module implements the
detection half; launch/train.py wires it to logging + the recovery loop.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional


@dataclasses.dataclass
class StepStats:
    step: int
    seconds: float
    tokens: int
    flagged: bool


class StepMonitor:
    def __init__(self, *, ema_alpha: float = 0.1, straggler_factor: float = 2.0, warmup: int = 3):
        self.ema: Optional[float] = None
        self.alpha = ema_alpha
        self.factor = straggler_factor
        self.warmup = warmup
        self.history: List[StepStats] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, *, tokens: int = 0) -> StepStats:
        dt = time.perf_counter() - self._t0
        flagged = False
        if len(self.history) >= self.warmup and self.ema is not None:
            flagged = dt > self.factor * self.ema
        if self.ema is None:
            self.ema = dt
        elif not flagged:  # don't let outliers poison the baseline
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        st = StepStats(self._step, dt, tokens, flagged)
        self.history.append(st)
        self._step += 1
        return st

    @property
    def tokens_per_sec(self) -> float:
        recent = self.history[-10:]
        tok = sum(s.tokens for s in recent)
        sec = sum(s.seconds for s in recent)
        return tok / sec if sec else 0.0

    def straggler_report(self) -> dict:
        flags = [s for s in self.history if s.flagged]
        return {
            "steps": len(self.history),
            "flagged": len(flags),
            "ema_s": self.ema,
            "worst": max((s.seconds for s in self.history), default=0.0),
        }
