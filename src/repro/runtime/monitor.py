"""Step-time monitoring + straggler detection.

On an SPMD TPU fleet every chip executes the same program, so classic
work-stealing does not apply; the operable levers are (a) detecting that
steps are slower than the fleet baseline (failing HBM, thermal throttle,
a slow host input pipeline), (b) flagging the offender for the scheduler
to cordon, and (c) keeping the input pipeline ahead of the device so a
slow host never blocks the collective. This module implements the
detection half; launch/train.py wires it to logging + the recovery loop,
and the spectral serving engine wraps its dispatch in one so queue and
straggler telemetry are on by default.

Telemetry is window-bounded (a ``deque`` per monitor/window) so
always-on recording cannot grow without bound; ``reset()`` is the
escape hatch that drops accumulated state. A step may carry *spans*
(``repro.obs.trace`` spans, or plain ``(name, seconds)`` pairs) so a
straggler flag names the offending stage -- the culprit -- instead of
just "the step was slow".
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class StepStats:
    step: int
    seconds: float
    tokens: int
    flagged: bool
    #: name of the slowest span within the step (None when no spans
    #: were attached) -- what a straggler flag attributes the time to
    culprit: Optional[str] = None


def percentiles(
    samples: Iterable[float], qs: Sequence[float] = (50, 90, 99)
) -> Dict[str, float]:
    """Nearest-rank percentiles of ``samples``: ``{"p50": ..., ...}``.

    Convention (asserted by tests): rank = ``max(1, ceil(q/100 * n))``,
    1-indexed into the sorted samples -- so ``q=0`` returns the minimum,
    ``q=100`` the maximum, and a single sample is every percentile of
    itself. Empty input returns 0.0 for every quantile (a serving
    dashboard wants numbers, not exceptions, before traffic arrives).

    Labels encode the quantile with ``.`` -> ``_`` (``99.9`` ->
    ``"p99_9"``). Two *distinct* quantiles whose labels would collide
    (e.g. ``99.9`` and ``99.90000000000001`` both format to ``99.9`` at
    ``%g`` precision) raise instead of silently collapsing into one
    dict key; passing the same quantile twice (``50`` and ``50.0``) is
    fine -- they are the same percentile."""
    data = sorted(samples)
    out: Dict[str, float] = {}
    label_q: Dict[str, float] = {}
    for q in qs:
        if not 0 <= q <= 100:
            raise ValueError(f"percentile must be in [0, 100], got {q}")
        label = "p" + f"{q:g}".replace(".", "_")
        prev = label_q.get(label)
        if prev is not None and prev != q:
            raise ValueError(
                f"percentile labels collide: q={prev!r} and q={q!r} both "
                f"format to {label!r}; pass distinguishable quantiles"
            )
        label_q[label] = q
        if not data:
            out[label] = 0.0
            continue
        # nearest-rank: ceil(q/100 * n), 1-indexed; p0 -> first sample
        rank = max(1, math.ceil(q / 100 * len(data)))
        out[label] = float(data[min(rank, len(data)) - 1])
    return out


class LatencyWindow:
    """Rolling window of recent scalar samples (latencies, queue depths)
    with O(1) record and on-demand percentile summaries -- the telemetry
    primitive behind the spectral serving engine's p50/p99 stats."""

    def __init__(self, maxlen: int = 2048):
        self._window: collections.deque = collections.deque(maxlen=maxlen)
        self.count = 0  # lifetime samples, not just the retained window
        self.total = 0.0

    def record(self, value: float) -> None:
        self._window.append(float(value))
        self.count += 1
        self.total += float(value)

    def __len__(self) -> int:
        return len(self._window)

    def percentiles(self, qs: Sequence[float] = (50, 90, 99)) -> Dict[str, float]:
        return percentiles(self._window, qs)

    def summary(self, qs: Sequence[float] = (50, 90, 99)) -> Dict[str, float]:
        out = self.percentiles(qs)
        out["count"] = self.count
        out["mean"] = (self.total / self.count) if self.count else 0.0
        out["max"] = max(self._window) if self._window else 0.0
        return out


def _span_name_seconds(span) -> Optional[Tuple[str, float]]:
    """(name, seconds) from a trace span, a JSONL span dict, or a plain
    (name, seconds) pair; None for anything unusable."""
    if isinstance(span, dict):
        name, dur = span.get("name"), span.get("dur")
    elif isinstance(span, (tuple, list)) and len(span) == 2:
        name, dur = span
    else:
        name, dur = getattr(span, "name", None), getattr(span, "dur", None)
    if isinstance(name, str) and isinstance(dur, (int, float)):
        return name, float(dur)
    return None


class StepMonitor:
    """EMA-baselined straggler detector over a bounded step history.

    ``history`` keeps the most recent ``history_limit`` steps (the EMA
    and lifetime counters survive trimming), so leaving a monitor
    recording forever -- the train loop and the serving dispatch both do
    -- costs O(history_limit) memory. ``reset()`` drops everything."""

    def __init__(
        self,
        *,
        ema_alpha: float = 0.1,
        straggler_factor: float = 2.0,
        warmup: int = 3,
        history_limit: int = 512,
    ):
        self.alpha = ema_alpha
        self.factor = straggler_factor
        self.warmup = warmup
        self.history_limit = history_limit
        self.reset()

    def reset(self) -> None:
        """Drop all recorded telemetry (history, EMA baseline, step and
        flag counters) -- the escape hatch for always-on monitors."""
        self.ema: Optional[float] = None
        self.history: collections.deque = collections.deque(maxlen=self.history_limit)
        self._t0: Optional[float] = None
        self._step = 0
        self.flag_count = 0  # lifetime, survives history trimming

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, *, tokens: int = 0, spans: Optional[Iterable] = None) -> StepStats:
        """Close the step opened by :meth:`start`. ``spans`` optionally
        attributes the step's time to its stages (trace spans or
        ``(name, seconds)`` pairs): the slowest becomes the step's
        ``culprit``, so a straggler flag names the offending stage."""
        dt = time.perf_counter() - self._t0
        flagged = False
        if self._step >= self.warmup and self.ema is not None:
            flagged = dt > self.factor * self.ema
        if self.ema is None:
            self.ema = dt
        elif not flagged:  # don't let outliers poison the baseline
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        culprit = None
        if spans is not None:
            parsed = [p for p in map(_span_name_seconds, spans) if p is not None]
            if parsed:
                culprit = max(parsed, key=lambda p: p[1])[0]
        st = StepStats(self._step, dt, tokens, flagged, culprit)
        self.history.append(st)
        self._step += 1
        if flagged:
            self.flag_count += 1
        return st

    def percentiles(
        self, qs: Sequence[float] = (50, 90, 99), window: Optional[int] = None
    ) -> Dict[str, float]:
        """Step-time percentiles over the most recent ``window`` steps
        (default: the whole retained history) -- the p50/p99 view of the
        same samples the EMA smooths."""
        recent: Iterable[StepStats] = self.history
        if window is not None:
            recent = list(self.history)[-window:]
        return percentiles((s.seconds for s in recent), qs)

    @property
    def tokens_per_sec(self) -> float:
        recent = list(self.history)[-10:]
        tok = sum(s.tokens for s in recent)
        sec = sum(s.seconds for s in recent)
        return tok / sec if sec else 0.0

    def straggler_report(self) -> dict:
        """Summary incl. per-culprit flag attribution: ``culprits`` maps
        stage name -> number of *flagged* steps it was slowest in."""
        flags = [s for s in self.history if s.flagged]
        culprits: Dict[str, int] = {}
        for s in flags:
            if s.culprit is not None:
                culprits[s.culprit] = culprits.get(s.culprit, 0) + 1
        return {
            "steps": self._step,
            "flagged": self.flag_count,
            "ema_s": self.ema,
            "worst": max((s.seconds for s in self.history), default=0.0),
            "culprits": culprits,
        }
