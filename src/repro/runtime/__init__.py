from repro.runtime.elastic import FailureInjector, SimulatedFailure, elastic_mesh, run_with_recovery
from repro.runtime.monitor import LatencyWindow, StepMonitor, StepStats, percentiles

__all__ = [
    "FailureInjector", "LatencyWindow", "SimulatedFailure", "StepMonitor",
    "StepStats", "elastic_mesh", "percentiles", "run_with_recovery",
]
