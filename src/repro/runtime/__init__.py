from repro.runtime.elastic import FailureInjector, SimulatedFailure, elastic_mesh, run_with_recovery
from repro.runtime.monitor import StepMonitor, StepStats

__all__ = [
    "FailureInjector", "SimulatedFailure", "StepMonitor", "StepStats",
    "elastic_mesh", "run_with_recovery",
]
