from repro.runtime.elastic import (
    FailureInjector,
    Resume,
    SimulatedFailure,
    backoff_delay,
    elastic_mesh,
    run_with_recovery,
)
from repro.runtime.faults import (
    CircuitBreaker,
    DeviceLossFault,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    RetryPolicy,
)
from repro.runtime.monitor import LatencyWindow, StepMonitor, StepStats, percentiles

__all__ = [
    "CircuitBreaker",
    "DeviceLossFault",
    "FailureInjector",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "LatencyWindow",
    "Resume",
    "RetryPolicy",
    "SimulatedFailure",
    "StepMonitor",
    "StepStats",
    "backoff_delay",
    "elastic_mesh",
    "percentiles",
    "run_with_recovery",
]
