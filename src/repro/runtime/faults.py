"""Deterministic chaos injection + recovery primitives (the fault layer).

The paper's cluster runs (and the HPX+LCI study it builds on) live on a
fabric that can drop, stall, or lose ranks; everything in this repo --
planner races, coalesced serving, the train loop -- used to assume every
Exchange succeeds. This module supplies the failure *contract*:

- :class:`FaultPlan` -- a seeded, fully deterministic chaos hook
  installed via ``run_schedule(..., faults=)`` (and through
  ``Plan.faults`` / ``SpectralEngine(faults=)`` / the train driver).
  The executor consults it before every Exchange segment (and before a
  ``global:`` reference dispatch); a matching spec can **raise**
  (:class:`InjectedFault`), **stall** past a deadline (injectable
  ``sleep``), or report **device loss** (:class:`DeviceLossFault`
  carrying the surviving device count -- the signal
  ``run_with_recovery`` + ``elastic_mesh`` turn into a remesh).
  Like the planner's injectable timers, every decision comes from
  explicit counters plus a seeded RNG, so each failure mode is
  reproducible in tests and CI.
- :class:`RetryPolicy` -- the dispatch retry budget (attempts + wall
  deadline) the serving engine applies before quarantining a request.
- :class:`CircuitBreaker` -- per-key closed/open/half-open breaker with
  an injectable clock; the serving engine keys it by
  ``(backend, plan-key)`` and degrades open keys to the ``xla_auto``
  reference schedule until a probe succeeds.

Nothing here imports the core/serve layers -- the executor and engine
duck-type against ``FaultPlan.active()`` / ``on_stage()`` -- so the
module stays a dependency leaf the whole stack can share.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Dict, Hashable, List, Optional, Tuple


class InjectedFault(RuntimeError):
    """Raised by a :class:`FaultPlan` ``error`` spec at the stage it names."""


class DeviceLossFault(InjectedFault):
    """A collective 'returned' on a shrunken device set: the exchange's
    ring lost ranks. ``alive`` is the surviving device count the
    recovery layer should remesh to (None = unknown, re-probe)."""

    def __init__(self, message: str, *, alive: Optional[int] = None):
        super().__init__(message)
        self.alive = alive


@dataclasses.dataclass
class FaultSpec:
    """One armed fault. ``match`` is a substring of the stage label the
    executor reports (``Exchange(slab:model, alltoall, p=8, fft)`` /
    ``global:fft2`` -- see ``repro.core.schedule._stage_label``), so a
    spec can name one Exchange ("rows"), a backend ("scatter"), every
    collective ("Exchange"), or anything (""). Firing is decided per
    *matching execution*: matches ``{at, at+every, at+2*every, ...}``
    fire (``every=None`` = every match from ``at`` on), capped at
    ``times`` total firings (None = unlimited) -- so the default
    ``at=0, times=1`` fires exactly once, on the first match, and
    ``times=3`` poisons the next three matching executions; a ``rate``
    spec instead fires each match with probability ``rate`` drawn from
    the plan's seeded RNG."""

    mode: str  # "error" | "stall" | "device_loss"
    match: str = "Exchange"
    at: int = 0
    every: Optional[int] = None
    times: Optional[int] = 1
    rate: Optional[float] = None
    stall_s: float = 0.0
    alive: Optional[int] = None  # device_loss: surviving device count

    def __post_init__(self):
        if self.mode not in ("error", "stall", "device_loss"):
            raise ValueError(f"unknown fault mode {self.mode!r}")
        if self.rate is not None and not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")


class FaultPlan:
    """A deterministic, seeded set of :class:`FaultSpec`\\ s.

    The executor calls :meth:`on_stage` with each Exchange's label just
    before launching the segment; the plan counts matches per spec and
    applies whichever armed spec is scheduled to fire -- raising,
    sleeping (``sleep`` is injectable), or raising device loss. Every
    firing is appended to :attr:`events` (and stamped as a ``cat="fault"``
    span when a :class:`repro.obs.trace.TraceRecorder` is attached via
    ``recorder=``), so chaos runs leave an auditable trail.

    :meth:`active` is False once every spec is exhausted -- callers
    (``Plan.execute``) then return to the fast jitted path, which is
    what lets a circuit-breaker probe observe recovery."""

    def __init__(
        self,
        specs: Tuple[FaultSpec, ...] = (),
        *,
        seed: int = 0,
        sleep: Callable[[float], None] = time.sleep,
        recorder=None,
    ):
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self.seed = seed
        self.sleep = sleep
        self.recorder = recorder
        self._rng = random.Random(seed)
        self._seen: Dict[int, int] = {}  # spec index -> matching executions
        self._fired: Dict[int, int] = {}  # spec index -> firings
        self.injected = 0
        self.stalled_s = 0.0
        self.events: List[dict] = []

    # -- constructors ------------------------------------------------------
    @classmethod
    def error(cls, match: str = "Exchange", **kw) -> "FaultPlan":
        """Raise :class:`InjectedFault` at the named stage."""
        plan_kw = {k: kw.pop(k) for k in ("seed", "sleep", "recorder") if k in kw}
        return cls((FaultSpec("error", match=match, **kw),), **plan_kw)

    @classmethod
    def stall(cls, stall_s: float, match: str = "Exchange", **kw) -> "FaultPlan":
        """Stall the named stage by ``stall_s`` (via the injectable
        sleep) -- the 'slow parcelport' mode retry deadlines catch."""
        plan_kw = {k: kw.pop(k) for k in ("seed", "sleep", "recorder") if k in kw}
        return cls((FaultSpec("stall", match=match, stall_s=stall_s, **kw),), **plan_kw)

    @classmethod
    def device_loss(
        cls, alive: Optional[int] = None, match: str = "Exchange", **kw
    ) -> "FaultPlan":
        """Raise :class:`DeviceLossFault` (ring lost ranks; ``alive``
        survivors) at the named stage."""
        plan_kw = {k: kw.pop(k) for k in ("seed", "sleep", "recorder") if k in kw}
        return cls(
            (FaultSpec("device_loss", match=match, alive=alive, **kw),), **plan_kw
        )

    @classmethod
    def rate(
        cls, rate: float, mode: str = "error", match: str = "Exchange", *, seed: int = 0, **kw
    ) -> "FaultPlan":
        """Fire each matching execution with probability ``rate`` from
        the seeded RNG (the benchmark's fixed injected-fault rate)."""
        plan_kw = {k: kw.pop(k) for k in ("sleep", "recorder") if k in kw}
        return cls(
            (FaultSpec(mode, match=match, rate=rate, times=None, **kw),),
            seed=seed,
            **plan_kw,
        )

    # -- state -------------------------------------------------------------
    def active(self) -> bool:
        """Whether any spec can still fire (executors skip the chaos
        path entirely -- staying byte-identical -- when False)."""
        return any(
            s.times is None or self._fired.get(i, 0) < s.times
            for i, s in enumerate(self.specs)
        )

    def reset(self) -> None:
        """Re-arm: zero all counters and reseed the RNG, so a reset plan
        replays the identical fault sequence."""
        self._rng = random.Random(self.seed)
        self._seen.clear()
        self._fired.clear()
        self.injected = 0
        self.stalled_s = 0.0
        self.events.clear()

    # -- the executor hook -------------------------------------------------
    def _scheduled(self, spec: FaultSpec, k: int) -> bool:
        if spec.rate is not None:
            return self._rng.random() < spec.rate
        if k < spec.at:
            return False
        if spec.every is None:
            return True  # every match from `at` on; `times` caps firings
        return (k - spec.at) % spec.every == 0

    def on_stage(self, label: str, *, index: int = 0) -> None:
        """Called by the executor before launching the stage named
        ``label``; raises / stalls when an armed spec fires."""
        for i, spec in enumerate(self.specs):
            if spec.times is not None and self._fired.get(i, 0) >= spec.times:
                continue
            if spec.match not in label:
                continue
            k = self._seen.get(i, 0)
            self._seen[i] = k + 1
            if not self._scheduled(spec, k):
                continue
            self._fired[i] = self._fired.get(i, 0) + 1
            self.injected += 1
            self._record(spec, label, index, k)
            if spec.mode == "stall":
                self.stalled_s += spec.stall_s
                self.sleep(spec.stall_s)
            elif spec.mode == "device_loss":
                raise DeviceLossFault(
                    f"injected device loss at {label} (match {k}"
                    f"{'' if spec.alive is None else f', {spec.alive} alive'})",
                    alive=spec.alive,
                )
            else:
                raise InjectedFault(f"injected fault at {label} (match {k})")

    def _record(self, spec: FaultSpec, label: str, index: int, k: int) -> None:
        event = {
            "mode": spec.mode,
            "stage": label,
            "index": index,
            "match_count": k,
            "injected": self.injected,
        }
        self.events.append(event)
        if self.recorder is not None:
            with self.recorder.span(f"fault:{spec.mode}", cat="fault", **event):
                pass  # instant marker span: the fault fired here


# ---------------------------------------------------------------------------
# Dispatch retry budget + circuit breaker (serving-side recovery)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Per-dispatch retry budget: up to ``max_retries`` re-executions of
    a failed solo request, abandoned once ``deadline_s`` of wall clock
    (the engine's injectable clock) has elapsed since the first attempt."""

    max_retries: int = 1
    deadline_s: float = float("inf")


class CircuitBreaker:
    """Per-key three-state breaker with an injectable clock.

    ``closed`` keys dispatch normally; ``failure_threshold`` consecutive
    failures open a key (``allow`` returns False -- callers degrade);
    after ``reset_after_s`` the next ``allow`` admits ONE half-open
    probe, whose success re-closes the key (failure re-opens it and
    restarts the timeout). Counters (``opened``/``reclosed``/``probes``)
    feed the serving engine's ``metrics()``."""

    def __init__(
        self,
        *,
        failure_threshold: int = 3,
        reset_after_s: float = 30.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        if failure_threshold < 1:
            raise ValueError(f"failure_threshold must be >= 1, got {failure_threshold}")
        self.failure_threshold = failure_threshold
        self.reset_after_s = reset_after_s
        self._clock = clock
        self._state: Dict[Hashable, str] = {}
        self._failures: Dict[Hashable, int] = {}
        self._opened_at: Dict[Hashable, float] = {}
        self.opened = 0  # transitions into "open" (first open + re-opens)
        self.reclosed = 0  # half-open probes that healed the key
        self.probes = 0  # half-open probes admitted

    def state(self, key: Hashable) -> str:
        return self._state.get(key, "closed")

    def states(self) -> Dict[Hashable, str]:
        return dict(self._state)

    def allow(self, key: Hashable) -> bool:
        """Whether the next dispatch for ``key`` may use the primary
        plan (False: degrade). Transitions open -> half-open when the
        reset timeout has elapsed, admitting exactly one probe."""
        st = self.state(key)
        if st == "closed":
            return True
        if st == "open" and self._clock() - self._opened_at[key] >= self.reset_after_s:
            self._state[key] = "half-open"
            self.probes += 1
            return True
        return False  # open (cooling down) or half-open (probe in flight)

    def record_success(self, key: Hashable) -> None:
        if self.state(key) != "closed":
            self.reclosed += 1
        self._state[key] = "closed"
        self._failures[key] = 0

    def record_failure(self, key: Hashable) -> None:
        n = self._failures.get(key, 0) + 1
        self._failures[key] = n
        st = self.state(key)
        if st == "half-open" or (st == "closed" and n >= self.failure_threshold):
            self._state[key] = "open"
            self._opened_at[key] = self._clock()
            self._failures[key] = 0
            self.opened += 1

    def reset(self) -> None:
        """Forget every key (e.g. after an elastic remesh -- the old
        mesh's failures say nothing about the new fabric)."""
        self._state.clear()
        self._failures.clear()
        self._opened_at.clear()

    def stats(self) -> Dict[str, int]:
        states = list(self._state.values())
        return {
            "open": states.count("open"),
            "half_open": states.count("half-open"),
            "opened": self.opened,
            "reclosed": self.reclosed,
            "probes": self.probes,
        }
