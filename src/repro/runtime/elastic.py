"""Failure recovery + elastic re-scale orchestration.

``run_with_recovery`` wraps a training loop in the restart contract:
on any failure (device loss, preemption, injected fault) it restores the
latest checkpoint and resumes, up to ``max_restarts``. Because the data
pipeline is a pure function of step (data/pipeline.py) and checkpoints
are mesh-agnostic (checkpoint/manager.py), the resumed run is bitwise
consistent with an uninterrupted one (asserted by tests), and a restart
may come back on a *different* device count -- ``elastic_mesh`` picks
the largest valid mesh for whatever is alive (shrink it explicitly with
``max_devices``/``devices`` when chaos tests simulate rank loss).

Restart pacing is capped exponential backoff with deterministic jitter:
``backoff_s * 2**(restart-1)`` up to ``backoff_cap_s``, scaled by a
``seed``-ed jitter factor so a thundering herd of restarts de-correlates
*reproducibly*. ``sleep`` is injectable, so tests assert the exact delay
sequence without waiting for it. The loop function receives ``None`` on
the first run and an explicit :class:`Resume` value afterwards (which
replaced an old ``resume_step = -1`` sentinel): the restart ordinal, the
failure that caused it, and the step to resume from (``None`` = restore
the latest checkpoint, the usual contract).
"""

from __future__ import annotations

import dataclasses
import logging
import random
import time
from typing import Callable, List, Optional

import jax
import numpy as np

log = logging.getLogger("repro.runtime")


class SimulatedFailure(RuntimeError):
    """Raised by tests / chaos hooks to simulate node loss."""


class FailureInjector:
    """Raises :class:`SimulatedFailure` on a repeatable step schedule.

    ``FailureInjector(k)`` fires once at step ``k`` (the historical
    contract); ``every=n`` extends the schedule to ``{k, k+n, k+2n,
    ...}``, capped at ``times`` total firings (None = unlimited). The
    schedule is pure arithmetic on the step counter, so a chaos run
    replays identically; :attr:`fired_steps` records each firing."""

    def __init__(
        self,
        at_step: Optional[int] = None,
        *,
        every: Optional[int] = None,
        times: Optional[int] = 1,
    ):
        self.at_step = at_step
        self.every = every
        self.times = times
        self.fired_steps: List[int] = []

    @property
    def fired(self) -> bool:
        return bool(self.fired_steps)

    def scheduled(self, step: int) -> bool:
        """Whether ``maybe_fail(step)`` would raise."""
        if self.at_step is None or step < self.at_step:
            return False
        if self.times is not None and len(self.fired_steps) >= self.times:
            return False
        if step == self.at_step:
            return True
        return self.every is not None and (step - self.at_step) % self.every == 0

    def maybe_fail(self, step: int):
        if self.scheduled(step):
            self.fired_steps.append(step)
            raise SimulatedFailure(f"injected failure at step {step}")


def elastic_mesh(
    axis_names=("data", "model"),
    *,
    model_parallel: int = 1,
    devices=None,
    max_devices: Optional[int] = None,
):
    """Build the largest mesh available right now (restart may see fewer
    hosts). model_parallel is fixed by the checkpointed layout; the data
    axis absorbs whatever devices remain (devices that do not fill a
    whole model-parallel group are dropped). ``devices`` pins an explicit
    alive list and ``max_devices`` truncates it -- the knobs chaos tests
    use to simulate rank loss on a forced-device host."""
    devs = list(jax.devices()) if devices is None else list(devices)
    if max_devices is not None:
        devs = devs[:max_devices]
    n = len(devs) - (len(devs) % model_parallel)
    if n < model_parallel:
        raise ValueError(
            f"{len(devs)} alive devices cannot fill one "
            f"model_parallel={model_parallel} group"
        )
    devs = devs[:n]
    from jax.sharding import Mesh

    if len(axis_names) == 1:
        if model_parallel != 1:
            raise ValueError("model_parallel needs a second mesh axis")
        return Mesh(np.asarray(devs), axis_names)
    return Mesh(np.asarray(devs).reshape(n // model_parallel, model_parallel), axis_names)


@dataclasses.dataclass(frozen=True)
class Resume:
    """Explicit restart token handed to the recovery loop's ``loop_fn``
    (first run gets ``None``). ``step=None`` means 'restore the latest
    checkpoint' -- the contract the old ``-1`` sentinel spelled
    implicitly."""

    restarts: int
    cause: str = ""
    step: Optional[int] = None


def backoff_delay(
    restart: int,
    base_s: float,
    *,
    cap_s: float = 30.0,
    jitter: float = 0.25,
    rng: Optional[random.Random] = None,
) -> float:
    """Capped exponential backoff for the ``restart``-th retry (1-based):
    ``base_s * 2**(restart-1)`` clamped to ``cap_s``, scaled by a
    uniform ``1 +- jitter`` factor drawn from ``rng`` (deterministic for
    a seeded Random; no jitter when rng is None)."""
    if base_s <= 0:
        return 0.0
    delay = min(cap_s, base_s * (2.0 ** max(0, restart - 1)))
    if jitter and rng is not None:
        delay *= 1.0 + jitter * (2.0 * rng.random() - 1.0)
    return min(delay, cap_s)


def run_with_recovery(
    loop_fn: Callable[[Optional[Resume]], None],
    *,
    max_restarts: int = 3,
    backoff_s: float = 0.0,
    backoff_cap_s: float = 30.0,
    jitter: float = 0.25,
    seed: int = 0,
    sleep: Callable[[float], None] = time.sleep,
    on_restart: Optional[Callable[[int, Exception], None]] = None,
) -> int:
    """``loop_fn(resume)`` runs until completion or raises; returns the
    number of restarts consumed. ``resume`` is ``None`` on the first
    attempt and a :class:`Resume` afterwards. ``on_restart(restarts,
    exc)`` runs before the backoff sleep -- the hook elastic callers use
    to shrink the device pool / rebuild state for the next attempt."""
    rng = random.Random(seed)
    restarts = 0
    resume: Optional[Resume] = None
    while True:
        try:
            loop_fn(resume)
            return restarts
        except Exception as e:  # noqa: BLE001 -- recovery boundary
            restarts += 1
            if restarts > max_restarts:
                raise
            log.warning("run failed (%s); restart %d/%d", e, restarts, max_restarts)
            if on_restart is not None:
                on_restart(restarts, e)
            delay = backoff_delay(
                restarts, backoff_s, cap_s=backoff_cap_s, jitter=jitter, rng=rng
            )
            if delay > 0:
                sleep(delay)
            resume = Resume(restarts=restarts, cause=f"{type(e).__name__}: {e}")
