"""Failure recovery + elastic re-scale orchestration.

``run_with_recovery`` wraps a training loop in the restart contract:
on any failure (device loss, preemption, injected fault) it restores the
latest checkpoint and resumes, up to ``max_restarts``. Because the data
pipeline is a pure function of step (data/pipeline.py) and checkpoints
are mesh-agnostic (checkpoint/manager.py), the resumed run is bitwise
consistent with an uninterrupted one (asserted by tests), and a restart
may come back on a *different* device count -- ``elastic_mesh`` picks
the largest valid mesh for whatever is alive.
"""

from __future__ import annotations

import logging
import time
from typing import Callable, Optional

import jax
import numpy as np

log = logging.getLogger("repro.runtime")


class SimulatedFailure(RuntimeError):
    """Raised by tests / chaos hooks to simulate node loss."""


class FailureInjector:
    """Raises SimulatedFailure the first time ``step == at_step``."""

    def __init__(self, at_step: Optional[int] = None):
        self.at_step = at_step
        self.fired = False

    def maybe_fail(self, step: int):
        if self.at_step is not None and step == self.at_step and not self.fired:
            self.fired = True
            raise SimulatedFailure(f"injected failure at step {step}")


def elastic_mesh(axis_names=("data", "model"), *, model_parallel: int = 1):
    """Build the largest mesh available right now (restart may see fewer
    hosts). model_parallel is fixed by the checkpointed layout; the data
    axis absorbs whatever devices remain."""
    n = len(jax.devices())
    if n % model_parallel:
        raise ValueError(f"{n} devices not divisible by model_parallel={model_parallel}")
    from jax.sharding import Mesh

    devs = np.asarray(jax.devices()).reshape(n // model_parallel, model_parallel)
    return Mesh(devs, axis_names)


def run_with_recovery(
    loop_fn: Callable[[Optional[int]], None],
    *,
    max_restarts: int = 3,
    backoff_s: float = 0.0,
    on_restart: Optional[Callable[[int, Exception], None]] = None,
):
    """loop_fn(resume_step) runs until completion or raises. Returns the
    number of restarts consumed."""
    restarts = 0
    resume_step = None
    while True:
        try:
            loop_fn(resume_step)
            return restarts
        except Exception as e:  # noqa: BLE001 -- recovery boundary
            restarts += 1
            if restarts > max_restarts:
                raise
            log.warning("run failed (%s); restart %d/%d", e, restarts, max_restarts)
            if on_restart is not None:
                on_restart(restarts, e)
            if backoff_s:
                time.sleep(backoff_s)
            resume_step = -1  # sentinel: loop_fn restores latest checkpoint
