"""Measured-vs-model FFT backend rows -- the perf-trajectory seed.

One subprocess per device count runs ``plan_fft(..., planner="measure")``
on P host devices: the measured planner times every registered backend
through the plan front-end (warmup + median), and ``Plan.predict()``
supplies each backend's own alpha-beta prediction next to it -- the
paper's measured-parcelport vs napkin-model comparison, as data.

``run_json()`` returns machine-readable dict rows (written to
``BENCH_fft.json`` by ``benchmarks/run.py --json``); ``to_csv()`` renders
the same rows in the harness's ``name,us_per_call,derived`` format.

With ``trace=`` (a :class:`repro.obs.trace.TraceRecorder`), each
subprocess additionally profiles the winning plan through the trace-mode
executor (``Plan.profile``) and ships its per-stage spans back as Chrome
events, adopted into the recorder under one pid row per device count --
``benchmarks/run.py --trace`` merges these into the benchmark trace
artifact.
"""

from __future__ import annotations

import json
from typing import Iterable, List

from benchmarks.common import run_devices_subprocess

_CODE = r"""
import json
from repro.core import plan_fft, planner
from repro.core.compat import make_mesh

n, p = __N__, __P__
mesh = make_mesh((p,), ("model",))
plan = plan_fft((n, n), mesh, planner="measure")
dev = planner.device_kind(mesh)
for name in sorted(plan.measured):
    # candidates are (backend, n_chunks, fused) variants: model each with
    # its own pipeline resolution so measured and model stay comparable
    model = planner.predict_candidate(plan, name)
    row = {"bench": "fft2", "n": n, "p": p, "backend": name,
           "measured_us": round(plan.measured[name] * 1e6, 1),
           "model_us": round(model * 1e6, 2),
           "picked": plan.backend, "device_kind": dev}
    print("ROW " + json.dumps(row))
if __TRACE__:
    # per-stage observed timeline of the winning plan (trace-mode
    # executor); spans ship back to the parent as Chrome events
    result = plan.profile(reps=3, warmup=1)
    print("TRACE " + json.dumps(result.trace.to_chrome_trace()["traceEvents"]))
"""


def run_json(
    n: int = 256, device_counts: Iterable[int] = (1, 2, 4, 8), trace=None
) -> List[dict]:
    """Measured + model-predicted rows per backend per device count."""
    rows: List[dict] = []
    for p in device_counts:
        code = (
            _CODE.replace("__N__", str(n))
            .replace("__P__", str(p))
            .replace("__TRACE__", repr(trace is not None))
        )
        out = run_devices_subprocess(code, devices=p)
        for line in out.splitlines():
            if line.startswith("ROW "):
                rows.append(json.loads(line[4:]))
            elif line.startswith("TRACE ") and trace is not None:
                trace.adopt(json.loads(line[6:]), name=f"fft_measure n={n} p={p}")
    return rows


def to_csv(rows: List[dict]) -> List[str]:
    return [
        f"fft_measure/{r['backend']}/p{r['p']},{r['measured_us']},"
        f"model_us={r['model_us']};picked={r['picked']}"
        for r in rows
    ]


def run(n: int = 256) -> List[str]:
    return to_csv(run_json(n))


if __name__ == "__main__":
    print("\n".join(run()))
