"""Measured-vs-model FFT backend rows -- the perf-trajectory seed.

One subprocess per device count runs ``plan_fft(..., planner="measure")``
on P host devices: the measured planner times every registered backend
through the plan front-end (warmup + median), and ``Plan.predict()``
supplies each backend's own alpha-beta prediction next to it -- the
paper's measured-parcelport vs napkin-model comparison, as data.

``run_json()`` returns machine-readable dict rows (written to
``BENCH_fft.json`` by ``benchmarks/run.py --json``); ``to_csv()`` renders
the same rows in the harness's ``name,us_per_call,derived`` format.
"""

from __future__ import annotations

import json
from typing import Iterable, List

from benchmarks.common import run_devices_subprocess

_CODE = r"""
import json
from repro.core import plan_fft, planner
from repro.core.compat import make_mesh

n, p = __N__, __P__
mesh = make_mesh((p,), ("model",))
plan = plan_fft((n, n), mesh, planner="measure")
dev = planner.device_kind(mesh)
for name in sorted(plan.measured):
    # candidates are (backend, n_chunks, fused) variants: model each with
    # its own pipeline resolution so measured and model stay comparable
    model = planner.predict_candidate(plan, name)
    row = {"bench": "fft2", "n": n, "p": p, "backend": name,
           "measured_us": round(plan.measured[name] * 1e6, 1),
           "model_us": round(model * 1e6, 2),
           "picked": plan.backend, "device_kind": dev}
    print("ROW " + json.dumps(row))
"""


def run_json(n: int = 256, device_counts: Iterable[int] = (1, 2, 4, 8)) -> List[dict]:
    """Measured + model-predicted rows per backend per device count."""
    rows: List[dict] = []
    for p in device_counts:
        out = run_devices_subprocess(
            _CODE.replace("__N__", str(n)).replace("__P__", str(p)), devices=p
        )
        for line in out.splitlines():
            if line.startswith("ROW "):
                rows.append(json.loads(line[4:]))
    return rows


def to_csv(rows: List[dict]) -> List[str]:
    return [
        f"fft_measure/{r['backend']}/p{r['p']},{r['measured_us']},"
        f"model_us={r['model_us']};picked={r['picked']}"
        for r in rows
    ]


def run(n: int = 256) -> List[str]:
    return to_csv(run_json(n))


if __name__ == "__main__":
    print("\n".join(run()))
