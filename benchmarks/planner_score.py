"""Planner-accuracy score over a committed BENCH_fft.json baseline.

Every backend-race row in the baseline carries both ``measured_us`` (what
the measured planner timed) and ``model_us`` (what that backend's
alpha-beta cost model predicted), plus ``picked`` (the backend the
planner shipped). Grouping the rows back into their races -- one group
per (bench, n, p, decomp, grid, transform) -- yields two hit rates and a
calibration ratio:

  picked_hit_rate   fraction of races where ``picked`` equals the
                    measured argmin. The measured planner picks the
                    measured argmin *by construction*, so anything below
                    1.0 means the race rows and the shipped decision
                    drifted apart (a merge bug, a stale section, or a
                    planner regression) -- this is the CI tripwire.
  model_hit_rate    fraction of races where the alpha-beta model's
                    argmin agrees with the measured argmin -- would the
                    napkin model alone have picked the same backend?
                    (The paper's model-vs-measured question, as a score.)
  model_ratio_geo   geometric mean of model_us / measured_us across all
                    rows -- absolute calibration. Far from 1.0 on CPU
                    hosts (the model is parameterised for TPU ICI), so
                    it is reported but not gated by default.

Run:  PYTHONPATH=src python -m benchmarks.planner_score
          [--path BENCH_fft.json] [--min-picked 0.9] [--min-model 0.1]
          [--wisdom auto] [--ratio-band 0.2:5.0] [--write-meta]

When persisted calibration is available (``--wisdom`` names a wisdom
file with a ``calibration`` section; the default ``auto`` looks for
``WISDOM.json`` next to ``--path``), a second *calibrated* score is
computed: every race row's ``model_us`` is re-priced offline
(:mod:`benchmarks.row_model` rebuilds the row's schedule) under the
fabric's fitted alpha/beta -- per backend class where fitted, pooled
otherwise -- so the score reflects this fabric's constants, not the
TPU-ICI defaults. ``--ratio-band LO:HI`` gates the calibrated
``model_ratio_geo`` inside [LO, HI].

Exits 1 when a gate fails. ``--write-meta`` records both scores into
the baseline's top-level ``meta`` section (which ``benchmarks/run.py
--json`` merges preserve) plus the calibration fingerprint (alpha/beta
per device kind and backend class), so the committed artifact carries
its own accuracy stamp.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Dict, List, Tuple

#: race identity: one group per planner decision in the baseline
GROUP_KEYS = ("bench", "n", "p", "decomp", "grid", "transform")


def _race_rows(rows: List[dict]) -> List[dict]:
    """Rows that describe one backend inside a planner race: must carry
    the backend, both timings, and the planner's decision. (overlap and
    serve rows are sweeps, not races -- no ``picked`` -- and drop out.)"""
    out = []
    for r in rows:
        if not isinstance(r, dict):
            continue
        if not isinstance(r.get("backend"), str) or not isinstance(r.get("picked"), str):
            continue
        m, mo = r.get("measured_us"), r.get("model_us")
        if isinstance(m, (int, float)) and isinstance(mo, (int, float)) and m > 0 and mo > 0:
            out.append(r)
    return out


def group_races(rows: List[dict]) -> Dict[Tuple, List[dict]]:
    groups: Dict[Tuple, List[dict]] = {}
    for r in _race_rows(rows):
        key = tuple(r.get(k) for k in GROUP_KEYS)
        groups.setdefault(key, []).append(r)
    return groups


def score(rows: List[dict]) -> dict:
    """Planner-accuracy score dict for a baseline's rows (see module
    docstring for the metric definitions)."""
    groups = group_races(rows)
    picked_hits = model_hits = 0
    log_ratios: List[float] = []
    for rs in groups.values():
        measured_best = min(rs, key=lambda r: r["measured_us"])["backend"]
        model_best = min(rs, key=lambda r: r["model_us"])["backend"]
        # every row in a race carries the same `picked`; trust the first
        if rs[0]["picked"] == measured_best:
            picked_hits += 1
        if model_best == measured_best:
            model_hits += 1
        log_ratios.extend(math.log(r["model_us"] / r["measured_us"]) for r in rs)
    n = len(groups)
    return {
        "groups": n,
        "rows": sum(len(rs) for rs in groups.values()),
        "picked_hits": picked_hits,
        "picked_hit_rate": picked_hits / n if n else 0.0,
        "model_hits": model_hits,
        "model_hit_rate": model_hits / n if n else 0.0,
        "model_ratio_geo": math.exp(sum(log_ratios) / len(log_ratios))
        if log_ratios
        else 0.0,
    }


def calibrated_rows(rows: List[dict]) -> List[dict]:
    """Race rows with ``model_us`` re-priced under the planner
    calibration store's fitted constants (per backend class when fitted,
    pooled otherwise). Empty when no calibration is known for any row's
    device kind -- the caller falls back to the raw score only."""
    from benchmarks import row_model
    from repro.core import planner

    out = []
    for r in _race_rows(rows):
        dev = r.get("device_kind") or "unknown"
        params = planner.calibration_for(dev, row_model.backend_class(r["backend"]))
        if params is None:
            continue
        s = row_model.row_model_seconds(r, params)
        if s is None:
            continue
        r2 = dict(r)
        r2["model_us"] = round(s * 1e6, 2)
        out.append(r2)
    return out


def calibration_fingerprint() -> dict:
    """The alpha/beta constants the calibrated score was computed under,
    per device kind and backend class -- stamped into meta so the
    committed artifact records what it was scored against."""
    from repro.core import planner

    return {dev: cell for dev, cell in planner.calibration_items()}


def _parse_band(text: str):
    lo, _, hi = text.partition(":")
    return float(lo), float(hi)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--path", default="BENCH_fft.json")
    ap.add_argument(
        "--min-picked", type=float, default=0.9,
        help="gate: minimum picked-vs-measured-argmin hit rate",
    )
    ap.add_argument(
        "--min-model", type=float, default=0.0,
        help="gate: minimum model-argmin hit rate (default TPU-ICI params)",
    )
    ap.add_argument(
        "--wisdom", default="auto", metavar="PATH",
        help="wisdom file whose calibration section prices the "
        "calibrated score ('auto': WISDOM.json next to --path; '' : off)",
    )
    ap.add_argument(
        "--ratio-band", default=None, metavar="LO:HI",
        help="gate: calibrated model_ratio_geo must land inside [LO, HI]",
    )
    ap.add_argument(
        "--write-meta", action="store_true",
        help="record the scores + calibration fingerprint into the "
        "baseline's top-level meta section",
    )
    args = ap.parse_args(argv)
    try:
        with open(args.path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"planner_score: cannot read {args.path}: {e}", file=sys.stderr)
        return 1
    rows = doc.get("rows", []) if isinstance(doc, dict) else []
    s = score(rows)
    print(
        f"planner_score {args.path}: {s['groups']} races / {s['rows']} rows\n"
        f"  picked_hit_rate  {s['picked_hit_rate']:.3f} "
        f"({s['picked_hits']}/{s['groups']})  [gate >= {args.min_picked}]\n"
        f"  model_hit_rate   {s['model_hit_rate']:.3f} "
        f"({s['model_hits']}/{s['groups']})  [gate >= {args.min_model}]\n"
        f"  model_ratio_geo  {s['model_ratio_geo']:.4g}  (1.0 = calibrated)"
    )

    sc = None
    wisdom = args.wisdom
    if wisdom == "auto":
        wisdom = os.path.join(os.path.dirname(os.path.abspath(args.path)), "WISDOM.json")
        if not os.path.exists(wisdom):
            wisdom = ""
    if wisdom:
        from repro.core import planner

        try:
            planner.import_wisdom(wisdom)
        except (OSError, json.JSONDecodeError) as e:
            print(f"planner_score: cannot read wisdom {wisdom}: {e}", file=sys.stderr)
            return 1
        crows = calibrated_rows(rows)
        if crows:
            sc = score(crows)
            print(
                f"  calibrated ({wisdom}):\n"
                f"  model_hit_rate   {sc['model_hit_rate']:.3f} "
                f"({sc['model_hits']}/{sc['groups']})\n"
                f"  model_ratio_geo  {sc['model_ratio_geo']:.4g}"
                + (f"  [gate in {args.ratio_band}]" if args.ratio_band else "")
            )
        else:
            print(f"  (no calibration for these rows' device kinds in {wisdom})")

    if args.write_meta and isinstance(doc, dict):
        meta = doc.get("meta")
        if not isinstance(meta, dict):
            meta = {}
        meta["planner_score"] = s
        if sc is not None:
            meta["planner_score_calibrated"] = dict(
                sc, calibration=calibration_fingerprint()
            )
        doc["meta"] = meta
        out = {k: doc[k] for k in ("schema", "meta") if k in doc}
        out["rows"] = rows
        with open(args.path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"  wrote meta.planner_score into {args.path}")
    failed = []
    if s["groups"] == 0:
        failed.append("no planner races found in baseline")
    if s["picked_hit_rate"] < args.min_picked:
        failed.append(
            f"picked_hit_rate {s['picked_hit_rate']:.3f} < {args.min_picked}"
        )
    if s["model_hit_rate"] < args.min_model:
        failed.append(f"model_hit_rate {s['model_hit_rate']:.3f} < {args.min_model}")
    if args.ratio_band:
        lo, hi = _parse_band(args.ratio_band)
        if sc is None:
            failed.append("--ratio-band set but no calibrated score (missing wisdom?)")
        elif not (lo <= sc["model_ratio_geo"] <= hi):
            failed.append(
                f"calibrated model_ratio_geo {sc['model_ratio_geo']:.4g} "
                f"outside [{lo}, {hi}]"
            )
    if failed:
        print("planner_score FAIL: " + "; ".join(failed), file=sys.stderr)
        return 1
    print("planner_score OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
