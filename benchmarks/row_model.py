"""Offline re-pricing of committed BENCH race rows + per-fabric fits.

Every race row in ``BENCH_fft.json`` (fft2 / fft3_decomp / real
sections) names a pure schedule: problem shape, shard count,
decomposition/grid, transform kind, and the candidate variant id. Since
the stage-schedule IR is rebuildable without a mesh
(:func:`repro.core.schedule.build_schedule` + ``apply_variant``), each
row's ``model_us`` can be recomputed offline under ANY CommParams --
which is what lets persisted calibration re-score the committed
baseline without re-running the sweeps:

- :func:`row_model_seconds` rebuilds the row's schedule and prices it
  exactly the way ``planner.predict_candidate`` priced it at bench time
  (same chunk-compute napkin, same itemsizes) -- with default params it
  reproduces the committed ``model_us`` columns bit-for-rounding;
- :func:`row_fit_terms` inverts the row into its alpha/beta regression
  terms (total messages, total fit bytes over its exchanges);
- :func:`fit_calibration` least-squares fits fabric constants from the
  measured rows -- pooled per device_kind plus one fit per backend
  class (the paper's Fig. 3 per-parcelport fit, from the committed
  artifact instead of a live sweep).

Run:  PYTHONPATH=src python -m benchmarks.row_model
          [--path BENCH_fft.json] [--write-wisdom WISDOM.json] [--verify]

``--write-wisdom`` records the fits into the planner calibration store
and exports them as the wisdom file's ``calibration`` section (merged
atomically); ``--verify`` recomputes every race row under default
CommParams and fails on any mismatch with the committed ``model_us``.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import comm_model as cm
from repro.core import planner
from repro.core import schedule as sch
from repro.core.plan import split_pair


def row_problem(row: dict) -> Optional[dict]:
    """Decode one race row into pure schedule-builder arguments (None
    for rows that are not rebuildable races -- overlap/serve sweeps)."""
    bench = row.get("bench")
    n, p = row.get("n"), row.get("p")
    if not isinstance(n, int) or not isinstance(p, int) or p < 1:
        return None
    if bench == "fft2":
        return {"shape": (n, n), "ndim": 2, "decomp": "slab", "p": p, "real": False}
    if bench == "fft3_decomp":
        if row.get("decomp") == "pencil":
            pr, _, pc = str(row.get("grid")).partition("x")
            try:
                pr, pc = int(pr), int(pc)
            except ValueError:
                return None
            return {
                "shape": (n, n, n), "ndim": 3, "decomp": "pencil",
                "p_rows": pr, "p_cols": pc, "real": False,
            }
        return {"shape": (n, n, n), "ndim": 3, "decomp": "slab", "p": p, "real": False}
    if bench == "real":
        return {
            "shape": (n, n), "ndim": 2, "decomp": "slab", "p": p,
            "real": row.get("transform") == "r2c",
        }
    return None


def row_schedule(row: dict, candidate: Optional[str] = None):
    """``(schedule, r_item, c_item, chunk_compute_s)`` for one row's
    candidate (default: the row's own backend id), rebuilt offline --
    the same rewritten schedule ``planner.predict_candidate`` priced on
    the live plan. None when the row is not a rebuildable race."""
    prob = row_problem(row)
    if prob is None:
        return None
    candidate = candidate if candidate is not None else row.get("backend")
    if not isinstance(candidate, str):
        return None
    if prob["decomp"] == "pencil":
        base = sch.build_schedule(
            prob["shape"], ndim=prob["ndim"], decomp="pencil",
            row_axis="rows", col_axis="cols",
            p_rows=prob["p_rows"], p_cols=prob["p_cols"],
            backend_row="alltoall", backend_col="alltoall", real=prob["real"],
        )
        rings = max(prob["p_rows"], prob["p_cols"])
        p = prob["p_rows"] * prob["p_cols"]
    else:
        base = sch.build_schedule(
            prob["shape"], ndim=prob["ndim"], decomp="slab", axis_name="model",
            p=prob["p"], backend="alltoall", real=prob["real"],
        )
        rings = p = prob["p"]
    try:
        applied = sch.apply_variant(base, candidate)
    except (ValueError, KeyError):
        return None
    r_item, c_item = (4, 8) if prob["real"] else (8, 8)
    # Plan._auto_chunk_compute_s's memory-bound napkin: the per-device
    # exchanged block (_cost_bytes) over HBM_BW; zero when no ring > 1
    if prob["real"]:
        elems = float(np.prod(prob["shape"][:-1])) * float(base.hp)
        cost_bytes = elems * c_item / p
    else:
        cost_bytes = float(np.prod(prob["shape"])) * c_item / p
    chunk_compute_s = 0.0 if rings <= 1 else cost_bytes / cm.HBM_BW
    return applied, r_item, c_item, chunk_compute_s


def row_model_seconds(
    row: dict, params: Optional[cm.CommParams] = None, candidate: Optional[str] = None
) -> Optional[float]:
    """The row's alpha-beta model seconds under ``params`` (default
    CommParams reproduces the committed ``model_us``)."""
    built = row_schedule(row, candidate)
    if built is None:
        return None
    applied, r_item, c_item, cc = built
    return sch.predict_seconds(applied, params or cm.CommParams(), cc, r_item, c_item)


def row_fit_terms(row: dict, candidate: Optional[str] = None) -> Optional[Tuple[float, float]]:
    """``(n_msgs, fit_bytes)`` the row contributes to an alpha/beta
    regression -- :func:`repro.core.comm_model.exchange_fit_terms`
    summed over its rebuilt schedule's exchanges."""
    built = row_schedule(row, candidate)
    if built is None:
        return None
    applied, r_item, c_item, _ = built
    msgs = fit_bytes = 0.0
    for st in applied.exchanges():
        block = sch.exchange_block_bytes(st, r_item, c_item)
        m, b = cm.exchange_fit_terms(st.backend, st.p, block, st.n_chunks)
        msgs += m
        fit_bytes += b
    return msgs, fit_bytes


def backend_class(candidate: str) -> Optional[str]:
    """The backend class one candidate's measurement calibrates: the
    base backend name (variant suffix stripped); a mixed pencil pair
    spreads its time over two collectives and fits no single class
    (None -- it still feeds the pooled fit)."""
    base, _ = planner.parse_variant(candidate)
    if "+" in base:
        br, bc = split_pair(base)
        return br if br == bc else None
    return base


def fit_calibration(
    rows: List[dict], *, base: Optional[cm.CommParams] = None, min_rows: int = 3
) -> Dict[str, dict]:
    """Least-squares alpha/beta per device_kind from measured race rows:
    ``{dev: {"pooled": CommParams, "backends": {class: CommParams},
    "rows": n}}``. Groups too small or rank-deficient to fit keep no
    entry (same guard as ``CommParams.refine_online``)."""
    base = base or cm.CommParams()
    per_dev: Dict[str, dict] = {}
    for row in rows:
        if not isinstance(row, dict):
            continue
        m = row.get("measured_us")
        if not (isinstance(m, (int, float)) and m > 0 and isinstance(row.get("backend"), str)):
            continue
        terms = row_fit_terms(row)
        if terms is None or terms[0] <= 0:
            continue  # p=1 rows carry no exchange signal
        point = (terms[0], terms[1], float(m) * 1e-6)
        dev = row.get("device_kind") or "unknown"
        d = per_dev.setdefault(dev, {"pooled": [], "classes": {}})
        d["pooled"].append(point)
        cls = backend_class(row["backend"])
        if cls is not None:
            d["classes"].setdefault(cls, []).append(point)
    out: Dict[str, dict] = {}
    for dev, d in per_dev.items():
        pooled = base._fit_spans(d["pooled"], min_rows, np)
        if pooled is base:
            continue  # unfittable: no calibration for this device kind
        fits = {}
        for cls, points in sorted(d["classes"].items()):
            fit = base._fit_spans(points, min_rows, np)
            if fit is not base:
                fits[cls] = fit
        out[dev] = {"pooled": pooled, "backends": fits, "rows": len(d["pooled"])}
    return out


def record_fits(fits: Dict[str, dict], *, source: str = "bench_fit") -> None:
    """Fold :func:`fit_calibration`'s output into the planner
    calibration store (count-weighted by contributing rows)."""
    for dev, fit in fits.items():
        planner.record_calibration(
            dev, fit["pooled"], source=source, n=fit["rows"], backends=fit["backends"]
        )


def verify_rows(rows: List[dict], *, tol_us: float = 0.02) -> List[dict]:
    """Race rows whose recomputed default-params model_us disagrees with
    the committed column beyond rounding -- the offline-rebuild
    correctness check (empty = the pure rebuild matches the live plans)."""
    bad = []
    for row in rows:
        if not isinstance(row, dict) or not isinstance(row.get("model_us"), (int, float)):
            continue
        s = row_model_seconds(row)
        if s is None:
            continue
        got = round(s * 1e6, 2)
        if abs(got - row["model_us"]) > tol_us:
            bad.append({**row, "recomputed_model_us": got})
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--path", default="BENCH_fft.json")
    ap.add_argument(
        "--write-wisdom", default=None, metavar="PATH",
        help="fit per-fabric constants from the baseline's measured rows "
        "and export them as the wisdom file's calibration section",
    )
    ap.add_argument(
        "--verify", action="store_true",
        help="recompute every race row's model_us under default params "
        "and fail on mismatch with the committed column",
    )
    args = ap.parse_args(argv)
    try:
        with open(args.path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"row_model: cannot read {args.path}: {e}", file=sys.stderr)
        return 1
    rows = doc.get("rows", []) if isinstance(doc, dict) else []
    if args.verify:
        bad = verify_rows(rows)
        if bad:
            for r in bad[:10]:
                print(
                    f"row_model MISMATCH: {r.get('bench')}/{r.get('backend')} "
                    f"p={r.get('p')} committed {r.get('model_us')} != "
                    f"recomputed {r['recomputed_model_us']}",
                    file=sys.stderr,
                )
            print(f"row_model FAIL: {len(bad)} mismatching rows", file=sys.stderr)
            return 1
        print("row_model verify OK: recomputed model_us matches committed rows")
    fits = fit_calibration(rows)
    for dev, fit in sorted(fits.items()):
        p = fit["pooled"]
        print(
            f"row_model fit[{dev}]: pooled alpha={p.alpha_s * 1e6:.1f}us "
            f"beta={p.beta_bytes_s / 1e9:.2f}GB/s ({fit['rows']} rows; "
            f"classes: {', '.join(fit['backends']) or 'none'})"
        )
    if args.write_wisdom:
        if not fits:
            print("row_model: nothing fittable; wisdom not written", file=sys.stderr)
            return 1
        record_fits(fits)
        planner.export_wisdom(args.write_wisdom)
        print(f"row_model: wrote calibration for {sorted(fits)} -> {args.write_wisdom}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
