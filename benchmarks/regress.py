"""Perf-trajectory viewer + noise-aware regression gate over the
benchmark history ledger (``BENCH_history.jsonl``).

Each ``benchmarks/run.py --json`` run appends one snapshot record
(commit, device_kind, timestamp, planner score, one scalar per
``section|config|metric`` key) to the append-only ledger -- see
:mod:`repro.obs.history`. This CLI reads it back:

  default       render the trajectory table: one line per tracked key,
                the last K values oldest->newest, rolling median, and
                the current baseline's value/ratio
  --check       gate mode: reduce the baseline BENCH json to a candidate
                snapshot and exit 1 when any metric regressed against
                the rolling median/MAD of the ledger (naming the
                (section, config) row); a ledger with fewer than
                --min-snapshots points per key never false-fails
  --append      append the baseline's snapshot to the ledger (what the
                CI slow-sweeps job runs after regenerating + re-scoring
                the baseline, so the artifact trajectory grows one
                point per run)

Run:  PYTHONPATH=src python -m benchmarks.regress
          [--history BENCH_history.jsonl] [--baseline BENCH_fft.json]
          [--check] [--append] [--k 8] [--min-snapshots 3]
          [--nsig 4.0] [--min-ratio 1.5] [--last 30]
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs import history as h


def _load_baseline(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"regress: cannot read baseline {path}: {e}", file=sys.stderr)
        return None


def render_table(hist, snap, *, k: int, last: int) -> str:
    """Trajectory table: per key, the last values, median, and the
    candidate snapshot's value/ratio against that median."""
    lines = [
        f"{'section|config|metric':<72} {'history (old->new)':<28} "
        f"{'median':>10} {'now':>10} {'ratio':>7}"
    ]
    keys = sorted(snap.get("metrics", {}))[: max(0, last) or None]
    for key in keys:
        vals = h.history_values(hist, key, k=k)
        value = snap["metrics"][key]
        med = vals and h._median(vals)
        hist_s = " ".join(f"{v:.0f}" for v in vals) or "-"
        med_s = f"{med:.1f}" if med else "-"
        ratio_s = f"{value / med:.2f}" if med else "-"
        lines.append(f"{key:<72} {hist_s:<28} {med_s:>10} {value:>10.1f} {ratio_s:>7}")
    if len(snap.get("metrics", {})) > len(keys):
        lines.append(f"... {len(snap['metrics']) - len(keys)} more keys (--last N)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--history", default="BENCH_history.jsonl")
    ap.add_argument("--baseline", default="BENCH_fft.json")
    ap.add_argument("--check", action="store_true", help="exit 1 on a confirmed regression")
    ap.add_argument(
        "--append", action="store_true",
        help="append the baseline's snapshot to the ledger",
    )
    ap.add_argument("--k", type=int, default=8, help="rolling window (snapshots per key)")
    ap.add_argument(
        "--min-snapshots", type=int, default=3,
        help="min historical points per key before the gate can fire",
    )
    ap.add_argument("--nsig", type=float, default=4.0, help="robust sigmas (MAD-based)")
    ap.add_argument(
        "--min-ratio", type=float, default=1.5,
        help="relative floor: a regression must also exceed ratio x median",
    )
    ap.add_argument("--last", type=int, default=30, help="table rows to print (0 = all)")
    args = ap.parse_args(argv)

    doc = _load_baseline(args.baseline)
    if doc is None:
        return 1
    snap = h.snapshot_from_bench(doc)
    hist = h.read_history(args.history)
    print(
        f"regress: ledger {args.history}: {len(hist)} snapshot(s); "
        f"baseline {args.baseline}: {len(snap['metrics'])} tracked metrics "
        f"(commit={snap['commit']}, dev={snap['device_kind']})"
    )

    if args.append:
        h.append_snapshot(args.history, snap)
        print(f"regress: appended snapshot -> {args.history} ({len(hist) + 1} total)")
        return 0

    if not args.check:
        print(render_table(hist, snap, k=args.k, last=args.last))
        return 0

    findings = h.detect_regressions(
        hist, snap, k=args.k, min_snapshots=args.min_snapshots,
        nsig=args.nsig, min_ratio=args.min_ratio,
    )
    if not findings:
        checked = sum(
            1 for key in snap["metrics"]
            if len(h.history_values(hist, key, k=args.k)) >= args.min_snapshots
        )
        guarded = len(snap["metrics"]) - checked
        print(
            f"regress OK: {checked} metric(s) within the noise band"
            + (f" ({guarded} below the {args.min_snapshots}-snapshot guard)" if guarded else "")
        )
        return 0
    for f in findings:
        print(
            f"regress REGRESSION: ({f['section']}, {f['config']}) {f['metric']} "
            f"= {f['value']:.1f} vs median {f['median']:.1f} "
            f"(ratio {f['ratio']:.2f}x, mad {f['mad']:.1f}, n={f['n']})",
            file=sys.stderr,
        )
    print(f"regress FAIL: {len(findings)} regression(s)", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
