"""Paper Figs. 4-5 analogue: FFT strong scaling over every registered
backend, vs the compiler-auto reference (the FFTW3 stand-in).

The paper: 2-D FFT of 2^14 x 2^14 over 1..16 nodes, one figure per
collective formulation, FFTW3 MPI+pthreads as the reference line. Here:
2^10 x 2^10 (CPU-tractable; same shape family) over 1/2/4/8 host
devices x ``backends.available()``; derived columns give each backend's
alpha-beta v5e projection for the paper's full 2^14 problem.
"""

from __future__ import annotations

from repro.core import backends

from benchmarks.common import run_devices_subprocess

_CODE = r"""
import time, numpy as np, jax, jax.numpy as jnp
from repro.core import backends, fft2, FFTConfig
from repro.core.compat import make_mesh

n = __N__
devs = __DEVS__
mesh = make_mesh((devs,), ("model",))
rng = np.random.default_rng(0)
x = jnp.asarray((rng.standard_normal((n, n)) + 1j*rng.standard_normal((n, n))).astype(np.complex64))
for strat in backends.available():
    if not backends.get(strat).supports(devs):
        continue
    cfgs = [("jnp", strat)]
    if strat == "scatter":
        cfgs.append(("jnp+fuse", strat))
    for impl, s in cfgs:
        cfg = FFTConfig(strategy=s, fuse_dft=(impl == "jnp+fuse"))
        fn = jax.jit(lambda v, c=cfg: fft2(v, mesh, "model", c))
        jax.block_until_ready(fn(x))
        ts = []
        for _ in range(8):
            t0 = time.perf_counter(); jax.block_until_ready(fn(x)); ts.append(time.perf_counter()-t0)
        ts.sort()
        print(f"ROW,{devs},{s},{impl},{ts[len(ts)//2]*1e6:.1f}")
"""


def run(n: int = 1024) -> list[str]:
    rows = []
    for devs in (1, 2, 4, 8):
        out = run_devices_subprocess(_CODE.replace("__N__", str(n)).replace("__DEVS__", str(devs)), devices=devs)
        for line in out.splitlines():
            if not line.startswith("ROW,"):
                continue
            _, d, strat, impl, us = line.split(",")
            d = int(d)
            # v5e projection for the PAPER's 2^14 problem at this device count
            m_local = (16384 * 16384 * 8) / max(d, 1)
            proj = backends.get(strat).cost(m_local, d)
            tag = strat if impl != "jnp+fuse" else strat + "+fusedft"
            rows.append(
                f"fig45_strong/{tag}/p{d},{us},v5e_comm_2e14_us={proj*1e6:.0f}"
            )
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
