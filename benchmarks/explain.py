"""``--explain``: dump the stage schedules the benchmarks execute.

For each representative plan (the shapes the fft/pencil/real sweeps
measure), print ``Plan.describe()`` -- the declarative stage pipeline
(:mod:`repro.core.schedule`) with per-stage model-predicted microseconds
and wire bytes per device -- followed by ``Plan.why_text()``, the
decision provenance: which channel chose the backend (pinned /
model-argmin / measured-race / wisdom-hit / observed-overlay), the
timing table that decision argmin'd over, and the calibration constants
it was priced under. This is the observability companion to the timing
sweeps: the same schedule object that executes is the one being priced,
so a surprising measured row can be read stage by stage and decision by
decision.

Runs in a subprocess with 8 forced host devices (like every sweep), so
the dumps reflect real 8-shard / 4x2-grid pipelines.
"""

from __future__ import annotations

from benchmarks.common import run_devices_subprocess

_CODE = r"""
from repro.core import plan_fft
from repro.core.compat import make_mesh

n = __N__
mesh = make_mesh((8,), ("model",))
gmesh = make_mesh((4, 2), ("rows", "cols"))

cases = [
    ("slab c2c fft2 (fused streaming)",
     dict(shape=(n, n), mesh=mesh, ndim=2, backend="scatter")),
    ("slab c2c fft2 (unfused monolithic)",
     dict(shape=(n, n), mesh=mesh, ndim=2, backend="scatter", pipeline=False)),
    ("slab c2c fft3",
     dict(shape=(64, 64, 64), mesh=mesh, ndim=3, backend="alltoall")),
    ("slab c2c fft1d_large",
     dict(shape=(n * n,), mesh=mesh, ndim=1, backend="scatter")),
    ("slab r2c rfft2",
     dict(shape=(n, n), mesh=mesh, ndim=2, backend="scatter", real=True)),
    ("slab c2r irfft2",
     dict(shape=(n, n), mesh=mesh, ndim=2, backend="scatter", real=True,
          direction="inverse")),
    ("pencil c2c fft3 (4x2 grid)",
     dict(shape=(64, 64, 64), mesh=gmesh, ndim=3, decomp="pencil")),
    ("pencil r2c rfft3 (4x2 grid)",
     dict(shape=(64, 64, 64), mesh=gmesh, ndim=3, decomp="pencil", real=True)),
    ("slab c2c fft2 (auto backend: model-argmin provenance)",
     dict(shape=(n, n), mesh=mesh, ndim=2, backend="auto")),
]
for title, kw in cases:
    shape, m = kw.pop("shape"), kw.pop("mesh")
    plan = plan_fft(shape, m, **kw)
    print(f"== {title}: {plan!r}")
    print(plan.describe())
    print(plan.why_text())
    print()
"""


def run(n: int = 256) -> str:
    """The full explain dump (also printed by ``run.py --explain``)."""
    return run_devices_subprocess(_CODE.replace("__N__", str(n)), devices=8)


if __name__ == "__main__":
    print(run(), end="")
