"""Local FFT implementation bench: XLA FFT vs MXU-matmul vs Pallas stage
(interpret mode -- correctness-path timing; TPU timing is the target)."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import fftmath
from repro.kernels import ops

from benchmarks.common import time_fn


def run() -> list[str]:
    rng = np.random.default_rng(0)
    rows = []
    for n in (1024, 4096):
        x = jnp.asarray(
            (rng.standard_normal((8, n)) + 1j * rng.standard_normal((8, n))).astype(np.complex64)
        )
        f_jnp = jax.jit(lambda v: fftmath.local_fft(v, impl="jnp"))
        f_mm = jax.jit(lambda v: fftmath.local_fft(v, impl="matmul"))
        rows.append(f"local_fft/jnp/n{n},{time_fn(f_jnp, x)*1e6:.1f},batch8")
        rows.append(f"local_fft/matmul/n{n},{time_fn(f_mm, x)*1e6:.1f},batch8")
        # pallas interpret mode is python-speed; time one call only
        t = time_fn(lambda v: ops.fft_last_axis(v), x, warmup=1, iters=2)
        rows.append(f"local_fft/pallas_interp/n{n},{t*1e6:.1f},batch8;interpret")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
