"""Spectral serving sweep -- p50/p99 latency and throughput vs offered load.

The paper (and every other section of this harness) measures one big
transform at a time; this section measures the serving workload the
ROADMAP's north star describes: many small transforms arriving
concurrently. Two arms per offered load:

- ``coalesce=True``: same-shape requests batch into one stacked plan
  execution (power-of-two buckets) behind the max-batch/max-wait
  admission policy;
- ``coalesce=False``: every request dispatches alone -- the control.

Each row carries the request-latency p50/p99 (from the engine's
telemetry window), transforms/sec, the realized mean batch size, and
queue-depth stats. A separate ``warm_start`` row demonstrates the warm
plan-cache pool: first-request latency on a cold engine (``plan_fft`` +
jit compile in the latency path) vs a wisdom-warmed engine (plan pool
misses == 0) vs the steady-state p50.

A ``chaos`` row measures the same workload under a fixed injected-fault
rate (seeded :class:`repro.runtime.faults.FaultPlan`, 5% of Exchange
executions poisoned): p50/p99 over the requests that still complete,
throughput, and the engine's error/retry/quarantine/degraded counters --
the cost of graceful degradation, as a number. ``--chaos`` runs just
that row from the CLI.

``run_json()`` rows merge into ``BENCH_fft.json`` as the ``serve``
section via ``benchmarks/run.py --json``; ``to_csv()`` renders the
harness's ``name,us_per_call,derived`` format.
"""

from __future__ import annotations

import json
from typing import Iterable, List

from benchmarks.common import run_devices_subprocess

_CODE = r"""
import json, time
import numpy as np, jax
from repro.core import plan_fft, planner
from repro.core.compat import make_mesh
from repro.serve import SpectralEngine

n, p = __N__, __P__
mesh = make_mesh((p,), ("model",))
dev = planner.device_kind(mesh)
rng = np.random.default_rng(0)
MAX_BATCH = 8

def mk():
    return (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))
            ).astype(np.complex64)

inputs = [mk() for _ in range(MAX_BATCH)]

# ---- load sweep: coalescing on vs off --------------------------------
for coalesce in (True, False):
    eng = SpectralEngine(mesh, max_batch=MAX_BATCH, max_wait_s=0.005,
                         coalesce=coalesce)
    # warm every batch bucket so the timed windows never compile
    for b in (1, 2, 4, MAX_BATCH):
        for i in range(b):
            eng.submit("fft", inputs[i])
        eng.drain()
    for load in (1, 4, 16, 32):
        waves = max(2, 128 // load)
        # one untimed wave absorbs residual allocation/dispatch jitter
        for i in range(load):
            eng.submit("fft", inputs[i % MAX_BATCH])
        eng.drain()
        eng.reset_stats()
        t0 = time.perf_counter()
        for _ in range(waves):
            futs = [eng.submit("fft", inputs[i % MAX_BATCH]) for i in range(load)]
            eng.flush()
            for f in futs:
                f.block()
        elapsed = time.perf_counter() - t0
        s = eng.stats()
        print("ROW " + json.dumps({
            "bench": "serve", "row": "load_sweep", "n": n, "p": p, "op": "fft",
            "coalesce": coalesce, "load": load, "requests": s["requests"],
            "p50_us": round(s["latency_s"]["p50"] * 1e6, 1),
            "p99_us": round(s["latency_s"]["p99"] * 1e6, 1),
            "tps": round(s["requests"] / elapsed, 1),
            "mean_batch": round(s["mean_batch"], 2),
            "queue_depth_p99": s["queue_depth"]["p99"],
            "pool_misses_timed": s["pool"]["misses"],
            "device_kind": dev,
        }))

# ---- warm plan-cache pool: cold vs wisdom-warmed first request -------
x = inputs[0]
cold = SpectralEngine(mesh, max_batch=MAX_BATCH)
t0 = time.perf_counter()
cold.submit("fft", x).block()
cold_first = time.perf_counter() - t0

# steady state on the now-hot engine
steady = []
for _ in range(32):
    t0 = time.perf_counter()
    cold.submit("fft", x).block()
    steady.append(time.perf_counter() - t0)
steady.sort()
steady_p50 = steady[len(steady) // 2]

# measure once (writes wisdom), export atomically, warm a fresh engine
planner.forget_wisdom()
plan_fft((1, n, n), mesh, planner="measure")
wisdom_path = "/tmp/serve_wisdom.json"
planner.export_wisdom(wisdom_path)
warm = SpectralEngine(mesh, max_batch=MAX_BATCH, wisdom=wisdom_path)
t0 = time.perf_counter()
fut = warm.submit("fft", x)
fut.block()
warm_first = time.perf_counter() - t0
print("ROW " + json.dumps({
    "bench": "serve", "row": "warm_start", "n": n, "p": p, "op": "fft",
    "cold_first_us": round(cold_first * 1e6, 1),
    "steady_p50_us": round(steady_p50 * 1e6, 1),
    "warm_first_us": round(warm_first * 1e6, 1),
    "warm_pool_misses": warm.pool.misses,  # 0 == no plan_fft in the path
    "warm_pool_plans": len(warm.pool),
    "picked": fut.backend,
    "device_kind": dev,
}))

# ---- chaos: latency under a fixed injected-fault rate ----------------
if __CHAOS__:
    from repro.runtime import FaultPlan, RetryPolicy
    RATE = 0.05
    ch = SpectralEngine(mesh, max_batch=MAX_BATCH, max_wait_s=0.005,
                        retry=RetryPolicy(max_retries=1))
    for b in (1, 2, 4, MAX_BATCH):  # warm every bucket BEFORE arming chaos
        for i in range(b):
            ch.submit("fft", inputs[i])
        ch.drain()
    ch.reset_stats()
    ch.set_faults(FaultPlan.rate(RATE, seed=7))
    t0 = time.perf_counter()
    done = failed = 0
    for _ in range(16):
        futs = [ch.submit("fft", inputs[i % MAX_BATCH]) for i in range(16)]
        ch.flush()
        for f in futs:
            try:
                f.block()
                done += 1
            except Exception:
                failed += 1  # quarantined: isolated to its own future
    elapsed = time.perf_counter() - t0
    s = ch.stats()
    fl = s["faults"]
    print("ROW " + json.dumps({
        "bench": "serve", "row": "chaos", "n": n, "p": p, "op": "fft",
        "fault_rate": RATE, "requests": s["requests"], "completed": done,
        "failed": failed,
        "p50_us": round(s["latency_s"]["p50"] * 1e6, 1),
        "p99_us": round(s["latency_s"]["p99"] * 1e6, 1),
        "tps": round(done / elapsed, 1),
        "errors": fl["errors"], "retries": fl["retries"],
        "batch_splits": fl["batch_splits"],
        "quarantined": fl["quarantined"],
        "degraded_dispatches": fl["degraded_dispatches"],
        "breaker_opened": fl["breaker"]["opened"],
        "device_kind": dev,
    }))
"""


def run_json(
    n: int = 64, device_counts: Iterable[int] = (8,), *, chaos: bool = True
) -> List[dict]:
    """Serving rows (load sweep + warm-start + chaos) per device count."""
    rows: List[dict] = []
    for p in device_counts:
        code = (
            _CODE.replace("__N__", str(n))
            .replace("__P__", str(p))
            .replace("__CHAOS__", "True" if chaos else "False")
        )
        out = run_devices_subprocess(code, devices=p)
        for line in out.splitlines():
            if line.startswith("ROW "):
                rows.append(json.loads(line[4:]))
    return rows


def to_csv(rows: List[dict]) -> List[str]:
    out = []
    for r in rows:
        if r.get("row") == "chaos":
            out.append(
                f"serve_sweep/chaos/rate{r['fault_rate']}/p{r['p']},{r['p50_us']},"
                f"p99_us={r['p99_us']};tps={r['tps']};"
                f"failed={r['failed']};retries={r['retries']};"
                f"degraded={r['degraded_dispatches']}"
            )
        elif r.get("row") == "warm_start":
            out.append(
                f"serve_sweep/warm_start/p{r['p']},{r['warm_first_us']},"
                f"cold_first_us={r['cold_first_us']};"
                f"steady_p50_us={r['steady_p50_us']};"
                f"pool_misses={r['warm_pool_misses']}"
            )
        else:
            arm = "coalesce" if r["coalesce"] else "solo"
            out.append(
                f"serve_sweep/{arm}/load{r['load']}/p{r['p']},{r['p50_us']},"
                f"p99_us={r['p99_us']};tps={r['tps']};"
                f"mean_batch={r['mean_batch']}"
            )
    return out


def run(n: int = 64) -> List[str]:
    return to_csv(run_json(n))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument(
        "--chaos", action="store_true",
        help="print only the chaos row (latency under injected faults)",
    )
    cli = ap.parse_args()
    lines = to_csv(run_json(cli.n))
    if cli.chaos:
        lines = [ln for ln in lines if ln.startswith("serve_sweep/chaos")]
    print("\n".join(lines))
