"""MoE dispatch strategy A/B (the paper's technique generalized to the
LM stack): fused gspmd collectives vs the explicit ring (batched + the
paper-faithful interleaved variant) on 4 host devices."""

from __future__ import annotations

from benchmarks.common import run_devices_subprocess

_CODE = r"""
import dataclasses, time
import numpy as np, jax, jax.numpy as jnp
from repro.core.compat import make_mesh
from repro.configs import get_config
from repro.models import moe as moe_lib
import repro.models.moe as M

mesh = make_mesh((1, 4), ("data", "model"))
base = get_config("deepseek-v3-671b", reduced=True)
base = dataclasses.replace(base, d_model=256,
    moe=dataclasses.replace(base.moe, num_experts=16, expert_d_ff=512, top_k=2))
rng = np.random.default_rng(0)
p, _ = moe_lib.init_moe(jax.random.PRNGKey(0), base)
x = jnp.asarray(rng.standard_normal((4, 64, base.d_model)), jnp.bfloat16)

def bench(tag, cfg, interleave=False):
    orig = M._ring_exchange_ffn
    if interleave:
        M._ring_exchange_ffn = lambda *a, **k: orig(*a, **{**k, "interleave": True})
    try:
        fn = jax.jit(lambda p, x: moe_lib.apply_moe(p, x, cfg, mesh=mesh)[0])
        jax.block_until_ready(fn(p, x))
        ts = []
        for _ in range(10):
            t0 = time.perf_counter(); jax.block_until_ready(fn(p, x)); ts.append(time.perf_counter()-t0)
        ts.sort()
        print(f"ROW,{tag},{ts[len(ts)//2]*1e6:.1f}")
    finally:
        M._ring_exchange_ffn = orig

bench("gspmd_fused", dataclasses.replace(base, moe=dataclasses.replace(base.moe, dispatch="einsum")))
bench("ring_batched", dataclasses.replace(base, moe=dataclasses.replace(base.moe, dispatch="ring")))
bench("ring_interleaved", dataclasses.replace(base, moe=dataclasses.replace(base.moe, dispatch="ring")), interleave=True)
"""


def run() -> list[str]:
    out = run_devices_subprocess(_CODE, devices=4)
    rows = []
    for line in out.splitlines():
        if line.startswith("ROW,"):
            _, tag, us = line.split(",")
            rows.append(f"moe_dispatch/{tag},{us},16e_top2_4dev")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
