"""The paper's own workload through the roofline machinery: lower the
2^14 x 2^14 c64 FFT (Figs. 4-5's problem) on the production 16-way axis
and derive the three terms per registered collective backend -- the
dry-run quantification of the paper's all-to-all vs N-scatter comparison.

Run in a subprocess (needs the 512-device host platform):
    PYTHONPATH=src python -m benchmarks.fft_roofline
"""

from __future__ import annotations

from benchmarks.common import run_devices_subprocess

_CODE = r"""
import os, jax, jax.numpy as jnp
from repro.core import backends, comm_model, hlo_analysis, plan_fft
from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh()  # 16x16: FFT shards over the 16-way 'model' axis
n = 16384
p = mesh.shape["model"]
for backend in backends.available():
    if not backends.get(backend).supports(p):
        continue
    cfgs = [(backend, False)]
    if backends.get(backend).supports_chunk_fn and backend == "scatter":
        cfgs.append((backend, True))
    for name, fuse in cfgs:
        plan = plan_fft((n, n), mesh, backend=name, fuse_dft=fuse)
        compiled = plan.lower().compile()  # one compile: analyze it directly
        cost = hlo_analysis.analyze_compiled(compiled, default_group=p)
        roof = comm_model.Roofline(
            flops=cost.flops, hbm_bytes=cost.hbm_bytes,
            coll_bytes=cost.coll_bytes, chips=int(mesh.size),
        )
        ma = compiled.memory_analysis()
        tag = name + ("+fusedft" if fuse else "")
        # useful flops: 5 N^2 log2(N^2) complex-radix2 reference / chips
        useful = 5 * n * n * (2 * 14) / mesh.size / comm_model.PEAK_FLOPS_BF16
        tb = max(roof.t_compute, roof.t_memory, roof.t_collective)
        print(
            f"ROW,{tag},{roof.t_compute*1e3:.2f},{roof.t_memory*1e3:.2f},"
            f"{roof.t_collective*1e3:.2f},{roof.bottleneck},"
            f"{ma.temp_size_in_bytes/2**30:.2f},{useful/tb*100:.1f}"
        )
"""


def run() -> list[str]:
    out = run_devices_subprocess(_CODE, devices=512, timeout=900)
    rows = []
    for line in out.splitlines():
        if line.startswith("ROW,"):
            _, tag, tc, tm, tl, bound, gib, frac = line.split(",")
            rows.append(
                f"fft_roofline_2^14/{tag},{float(tl)*1e3:.0f},"
                f"t_ms=({tc},{tm},{tl});bound={bound};mem_GiB={gib};frac={frac}%"
            )
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
