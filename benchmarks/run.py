"""Benchmark harness -- one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig3_chunk/*     chunk-size scaling of collective strategies (Fig. 3)
  fig45_strong/*   FFT strong scaling per strategy + reference (Figs. 4-5)
  fft_measure/*    measured planner vs alpha-beta model per backend
  pencil_sweep/*   slab vs pencil decomposition per grid shape
  moe_dispatch/*   paper technique on the LM stack (MoE a2a strategies)
  local_fft/*      local FFT impls (XLA vs MXU-matmul vs Pallas)

Run: PYTHONPATH=src python -m benchmarks.run [--only fig3,fig45,moe,kernel,fft,pencil]
     [--json BENCH_fft.json]

``--json PATH`` additionally writes the fft_measure + pencil_sweep rows
(measured + model-predicted per backend / per grid shape) as
machine-readable JSON -- the perf trajectory artifact CI uploads.
"""

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="fig3,fig45,moe,kernel,fft,pencil")
    ap.add_argument(
        "--json",
        default=None,
        metavar="PATH",
        help="write fft_measure rows (+ pencil_sweep rows when that "
        "section is selected) as JSON; implies the fft section only",
    )
    args = ap.parse_args()
    wanted = set(args.only.split(","))
    print("name,us_per_call,derived")
    rows = []
    if "kernel" in wanted:
        from benchmarks import kernel_bench

        rows += kernel_bench.run()
        _flush(rows)
    if "fig3" in wanted:
        from benchmarks import chunk_scaling

        rows += chunk_scaling.run()
        _flush(rows)
    if "fig45" in wanted:
        from benchmarks import strong_scaling

        rows += strong_scaling.run()
        _flush(rows)
    jrows = []
    if "fft" in wanted or args.json:
        from benchmarks import fft_measure

        frows = fft_measure.run_json()
        jrows += frows
        rows += fft_measure.to_csv(frows)
        _flush(rows)
    if "pencil" in wanted:
        from benchmarks import pencil_sweep

        prows = pencil_sweep.run_json()
        jrows += prows
        rows += pencil_sweep.to_csv(prows)
        _flush(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 2, "rows": jrows}, f, indent=2)
        print(f"# wrote {len(jrows)} rows to {args.json}", file=sys.stderr)
    if "moe" in wanted:
        from benchmarks import moe_dispatch

        rows += moe_dispatch.run()
        _flush(rows)


_printed = 0


def _flush(rows):
    global _printed
    for r in rows[_printed:]:
        print(r)
        sys.stdout.flush()
    _printed = len(rows)


if __name__ == "__main__":
    main()
