"""Benchmark harness -- one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig3_chunk/*     chunk-size scaling of collective strategies (Fig. 3)
  fig45_strong/*   FFT strong scaling per strategy + reference (Figs. 4-5)
  fft_measure/*    measured planner vs alpha-beta model per backend
  moe_dispatch/*   paper technique on the LM stack (MoE a2a strategies)
  local_fft/*      local FFT impls (XLA vs MXU-matmul vs Pallas)

Run: PYTHONPATH=src python -m benchmarks.run [--only fig3,fig45,moe,kernel,fft]
     [--json BENCH_fft.json]

``--json PATH`` additionally writes the fft_measure rows (measured +
model-predicted per backend) as machine-readable JSON -- the perf
trajectory artifact CI uploads.
"""

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="fig3,fig45,moe,kernel,fft")
    ap.add_argument(
        "--json",
        default=None,
        metavar="PATH",
        help="write fft_measure rows as JSON (implies the fft section)",
    )
    args = ap.parse_args()
    wanted = set(args.only.split(","))
    print("name,us_per_call,derived")
    rows = []
    if "kernel" in wanted:
        from benchmarks import kernel_bench

        rows += kernel_bench.run()
        _flush(rows)
    if "fig3" in wanted:
        from benchmarks import chunk_scaling

        rows += chunk_scaling.run()
        _flush(rows)
    if "fig45" in wanted:
        from benchmarks import strong_scaling

        rows += strong_scaling.run()
        _flush(rows)
    if "fft" in wanted or args.json:
        from benchmarks import fft_measure

        jrows = fft_measure.run_json()
        rows += fft_measure.to_csv(jrows)
        _flush(rows)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"schema": 1, "rows": jrows}, f, indent=2)
            print(f"# wrote {len(jrows)} rows to {args.json}", file=sys.stderr)
    if "moe" in wanted:
        from benchmarks import moe_dispatch

        rows += moe_dispatch.run()
        _flush(rows)


_printed = 0


def _flush(rows):
    global _printed
    for r in rows[_printed:]:
        print(r)
        sys.stdout.flush()
    _printed = len(rows)


if __name__ == "__main__":
    main()
