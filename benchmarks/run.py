"""Benchmark harness -- one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  overlap/*        fused vs unfused streaming exchanges, n_chunks sweep
                   (the paper's Fig. 3 chunk-size axis, as a runtime knob)
  fig45_strong/*   FFT strong scaling per strategy + reference (Figs. 4-5)
  fft_measure/*    measured planner vs alpha-beta model per backend
  pencil_sweep/*   slab vs pencil decomposition per grid shape
  real_sweep/*     c2c vs r2c (Hermitian payload) per backend per P
  serve_sweep/*    spectral serving: p50/p99 latency + transforms/sec vs
                   offered load, coalescing on vs off, warm plan pool
  moe_dispatch/*   paper technique on the LM stack (MoE a2a strategies)
  local_fft/*      local FFT impls (XLA vs MXU-matmul vs Pallas)

Run: PYTHONPATH=src python -m benchmarks.run
         [--only overlap,fig45,moe,kernel,fft,pencil,real,serve]
     [--json BENCH_fft.json] [--force] [--explain]

``--explain`` first prints each representative plan's stage schedule
(``Plan.describe()``: the declarative pipeline IR with per-stage model
microseconds and wire bytes) followed by its decision provenance
(``Plan.why_text()``: which channel picked the backend, over which
timing table, under which calibration constants); ``--explain --only
''`` prints only the schedules and times nothing.

``--json PATH`` additionally writes the fft_measure + pencil_sweep +
real_sweep + overlap rows (measured + model-predicted per backend / per
grid shape / per transform kind / per pipeline variant) as
machine-readable JSON -- the perf trajectory artifact CI uploads.
Sections that did not run in this invocation keep their rows from an
existing file at PATH (a partial run merges instead of clobbering the
committed baseline); a top-level ``meta`` section (e.g. the planner
accuracy score written by ``benchmarks/planner_score.py --write-meta``)
survives merges the same way. ``--force`` overwrites the file with only
this run's sections. ``fig3`` is accepted as a legacy alias for
``overlap``.

``--trace PATH`` records a Chrome-trace (chrome://tracing / Perfetto)
timeline of the harness: one span per benchmark section, plus -- for the
fft section -- per-stage spans of each subprocess's winning plan
(``Plan.profile`` timelines, one trace process row per device count).
"""

import argparse
import contextlib
import json
import os
import sys

BENCH_SCHEMA = 2


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="overlap,fig45,moe,kernel,fft,pencil,real,serve")
    ap.add_argument(
        "--json",
        default=None,
        metavar="PATH",
        help="write fft_measure rows (+ pencil_sweep/real_sweep rows when "
        "those sections are selected) as JSON, merging into an existing "
        "file; implies the fft section",
    )
    ap.add_argument(
        "--force",
        action="store_true",
        help="with --json: overwrite PATH instead of merging this run's "
        "sections into its existing rows",
    )
    ap.add_argument(
        "--history",
        default=None,
        metavar="PATH",
        help="with --json: the benchmark history ledger to append this "
        "run's snapshot to (default: BENCH_history.jsonl next to the "
        "--json file); see benchmarks/regress.py",
    )
    ap.add_argument(
        "--no-history",
        action="store_true",
        help="with --json: do not append a snapshot to the history ledger "
        "(CI's slow job appends AFTER re-scoring stamps fresh meta)",
    )
    ap.add_argument(
        "--trace",
        default=None,
        metavar="PATH",
        help="write a Chrome-trace JSON timeline of this run (section "
        "spans + per-stage plan profiles from the fft section); load at "
        "ui.perfetto.dev or chrome://tracing",
    )
    ap.add_argument(
        "--explain",
        action="store_true",
        help="before timing anything, print each representative plan's "
        "stage schedule (per-stage model microseconds + wire bytes); "
        "alone (with --only ''), just the schedules",
    )
    args = ap.parse_args()
    rec = None
    if args.trace:
        from repro.obs import TraceRecorder

        rec = TraceRecorder()
        rec.set_process_name(0, "benchmarks.run")
    if args.explain:
        from benchmarks import explain

        print(explain.run(), end="")
        sys.stdout.flush()
    wanted = set(args.only.split(","))
    print("name,us_per_call,derived")
    rows = []
    if "kernel" in wanted:
        from benchmarks import kernel_bench

        with _section(rec, "kernel"):
            rows += kernel_bench.run()
        _flush(rows)
    if "fig45" in wanted:
        from benchmarks import strong_scaling

        with _section(rec, "fig45"):
            rows += strong_scaling.run()
        _flush(rows)
    jrows = []
    if "overlap" in wanted or "fig3" in wanted:
        from benchmarks import chunk_scaling

        with _section(rec, "overlap"):
            orows = chunk_scaling.run_json()
        jrows += orows
        rows += chunk_scaling.to_csv(orows)
        _flush(rows)
    if "fft" in wanted or args.json:
        from benchmarks import fft_measure

        with _section(rec, "fft"):
            frows = fft_measure.run_json(trace=rec)
        jrows += frows
        rows += fft_measure.to_csv(frows)
        _flush(rows)
    if "pencil" in wanted:
        from benchmarks import pencil_sweep

        with _section(rec, "pencil"):
            prows = pencil_sweep.run_json()
        jrows += prows
        rows += pencil_sweep.to_csv(prows)
        _flush(rows)
    if "real" in wanted:
        from benchmarks import real_sweep

        with _section(rec, "real"):
            rrows = real_sweep.run_json()
        jrows += rrows
        rows += real_sweep.to_csv(rrows)
        _flush(rows)
    if "serve" in wanted:
        from benchmarks import serve_sweep

        with _section(rec, "serve"):
            srows = serve_sweep.run_json()
        jrows += srows
        rows += serve_sweep.to_csv(srows)
        _flush(rows)
    if args.json:
        merged, meta = _merge_json(args.json, jrows, force=args.force)
        meta = _stamp_meta(meta, merged)
        doc = {"schema": BENCH_SCHEMA, "meta": meta, "rows": merged}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(
            f"# wrote {len(merged)} rows to {args.json} "
            f"({len(jrows)} from this run)",
            file=sys.stderr,
        )
        if not args.no_history:
            from repro.obs import history as obs_history

            hpath = args.history or os.path.join(
                os.path.dirname(os.path.abspath(args.json)), "BENCH_history.jsonl"
            )
            snap = obs_history.snapshot_from_bench(doc)
            obs_history.append_snapshot(hpath, snap)
            print(
                f"# appended snapshot ({len(snap['metrics'])} metrics, "
                f"commit {snap['commit']}) -> {hpath}",
                file=sys.stderr,
            )
    if "moe" in wanted:
        from benchmarks import moe_dispatch

        with _section(rec, "moe"):
            rows += moe_dispatch.run()
        _flush(rows)
    if rec is not None:
        rec.write_chrome_trace(args.trace)
        n_ev = len(rec.to_chrome_trace()["traceEvents"])
        print(f"# wrote {n_ev} trace events to {args.trace}", file=sys.stderr)


def _section(rec, name: str):
    """Span context for one benchmark section (no-op when untraced)."""
    if rec is None:
        return contextlib.nullcontext()
    return rec.span(f"section:{name}", cat="section")


def _stamp_meta(meta: dict, rows, *, commit=None, now=None) -> dict:
    """Inject run provenance into the baseline's meta section: the git
    ``commit`` this tree is at, the rows' ``device_kind``, and an ISO
    UTC ``timestamp``. Injected at the harness level -- never read
    inside jitted code -- and re-stamped on every ``--json`` write, so a
    merge carries the freshest run's identity while older meta fields
    (planner scores etc.) survive untouched. ``commit``/``now`` are
    injectable for tests."""
    import datetime
    import subprocess

    if commit is None:
        try:
            out = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                capture_output=True,
                text=True,
                cwd=os.path.dirname(os.path.abspath(__file__)),
                timeout=10,
            )
            commit = out.stdout.strip() if out.returncode == 0 else ""
        except (OSError, subprocess.SubprocessError):
            commit = ""
    devs = sorted(
        {
            r["device_kind"]
            for r in rows
            if isinstance(r, dict) and isinstance(r.get("device_kind"), str)
        }
    )
    meta = dict(meta)
    meta["commit"] = commit or "unknown"
    meta["device_kind"] = "+".join(devs) if devs else meta.get("device_kind", "unknown")
    if now is None:
        now = datetime.datetime.now(datetime.timezone.utc).isoformat(timespec="seconds")
    meta["timestamp"] = now
    return meta


def _merge_json(path: str, new_rows, *, force: bool = False):
    """Merge this run's rows into an existing BENCH json: sections (the
    ``bench`` key) produced now replace their old rows; sections that did
    not run survive -- so a partial ``--only`` run cannot clobber the
    committed multi-section baseline. The file's top-level ``meta`` dict
    (planner-accuracy score etc.) is carried over untouched. ``force``
    skips the read. Returns ``(rows, meta)``."""
    if force or not os.path.exists(path):
        return list(new_rows), {}
    try:
        with open(path) as f:
            old = json.load(f)
        old_rows = old.get("rows", []) if isinstance(old, dict) else []
        meta = old.get("meta", {}) if isinstance(old, dict) else {}
        if not isinstance(meta, dict):
            meta = {}
    except (OSError, json.JSONDecodeError) as e:
        print(f"# --json: could not merge existing {path} ({e}); overwriting", file=sys.stderr)
        return list(new_rows), {}
    ran = {r.get("bench") for r in new_rows}
    kept = [r for r in old_rows if isinstance(r, dict) and r.get("bench") not in ran]
    return kept + list(new_rows), meta


_printed = 0


def _flush(rows):
    global _printed
    for r in rows[_printed:]:
        print(r)
        sys.stdout.flush()
    _printed = len(rows)


if __name__ == "__main__":
    main()
