"""Benchmark harness -- one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig3_chunk/*     chunk-size scaling of collective strategies (Fig. 3)
  fig45_strong/*   FFT strong scaling per strategy + reference (Figs. 4-5)
  moe_dispatch/*   paper technique on the LM stack (MoE a2a strategies)
  local_fft/*      local FFT impls (XLA vs MXU-matmul vs Pallas)

Run: PYTHONPATH=src python -m benchmarks.run [--only fig3,fig45,moe,kernel]
"""

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="fig3,fig45,moe,kernel")
    args = ap.parse_args()
    wanted = set(args.only.split(","))
    print("name,us_per_call,derived")
    rows = []
    if "kernel" in wanted:
        from benchmarks import kernel_bench

        rows += kernel_bench.run()
        _flush(rows)
    if "fig3" in wanted:
        from benchmarks import chunk_scaling

        rows += chunk_scaling.run()
        _flush(rows)
    if "fig45" in wanted:
        from benchmarks import strong_scaling

        rows += strong_scaling.run()
        _flush(rows)
    if "moe" in wanted:
        from benchmarks import moe_dispatch

        rows += moe_dispatch.run()
        _flush(rows)


_printed = 0


def _flush(rows):
    global _printed
    for r in rows[_printed:]:
        print(r)
        sys.stdout.flush()
    _printed = len(rows)


if __name__ == "__main__":
    main()
