"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "experiments", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "phi-3-vision-4.2b", "mixtral-8x22b", "deepseek-v3-671b", "qwen2.5-32b",
    "gemma2-9b", "nemotron-4-15b", "phi3-medium-14b", "xlstm-1.3b",
    "hymba-1.5b", "whisper-medium",
]


def load(mesh: str = "single") -> List[Dict]:
    rows = []
    for path in glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json")):
        with open(path) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (ARCH_ORDER.index(r["arch"]), SHAPE_ORDER.index(r["shape"])))
    return rows


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(mesh: str = "single") -> str:
    rows = load(mesh)
    out = [
        "| arch | shape | mem/chip | t_comp | t_mem | t_coll | bound | useful_flops | roofline_frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        ro = r["roofline"]
        t_bound = max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"])
        # roofline fraction: useful model flops time / achievable bound time
        t_useful = r["model_flops_per_chip"] / 197e12
        frac = t_useful / t_bound if t_bound else 0.0
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['memory']['peak_device_bytes']/2**30:.1f}Gi "
            f"| {fmt_s(ro['t_compute_s'])} | {fmt_s(ro['t_memory_s'])} | {fmt_s(ro['t_collective_s'])} "
            f"| {ro['bottleneck']} | {r['useful_flops_frac']*100:.0f}% | {frac*100:.1f}% |"
        )
    return "\n".join(out)


def summary(mesh: str = "single") -> Dict:
    rows = load(mesh)
    worst = min(rows, key=lambda r: _frac(r))
    coll = max(rows, key=lambda r: r["roofline"]["t_collective_s"] / max(_tb(r), 1e-12))
    return {"worst_frac": worst, "most_collective": coll}


def _tb(r):
    ro = r["roofline"]
    return max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"])


def _frac(r):
    return (r["model_flops_per_chip"] / 197e12) / max(_tb(r), 1e-12)


def run() -> List[str]:
    rows = []
    for r in load("single"):
        name = f"roofline/{r['arch']}/{r['shape']}"
        tb = _tb(r)
        rows.append(f"{name},{tb*1e6:.0f},bound={r['roofline']['bottleneck']};frac={_frac(r)*100:.1f}%")
    return rows


if __name__ == "__main__":
    print(roofline_table("single"))
    print()
    print("== multi-pod ==")
    print(roofline_table("multi"))
