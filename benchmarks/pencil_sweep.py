"""Slab-vs-pencil decomposition sweep -- one BENCH row per grid shape.

For a fixed device count P, runs the measured planner for the slab
decomposition (1-D mesh, every backend) and for every non-degenerate
(P_row x P_col) factorization of P (2-D mesh, every per-axis backend
pair), on the same global fft3 problem. Each row carries the measured
median next to the alpha-beta model prediction, so the slab-vs-pencil
crossover (and the per-axis backend split the pencil grid enables) is
visible as data -- the companion case-study's decomposition comparison.

``run_json()`` returns machine-readable rows (merged into
``BENCH_fft.json`` by ``benchmarks/run.py --json``); ``to_csv()``
renders the harness's ``name,us_per_call,derived`` format.
"""

from __future__ import annotations

import json
from typing import Iterable, List

from benchmarks.common import run_devices_subprocess

_CODE = r"""
import json
from repro.core import grid, plan_fft, planner
from repro.core.compat import make_mesh

n, p = __N__, __P__
shape = (n, n, n)
dev = None

def emit(decomp, grid_name, plan):
    for name in sorted(plan.measured):
        # candidates are (backend, n_chunks, fused) variants: model each
        # with its own pipeline resolution
        row = {"bench": "fft3_decomp", "n": n, "p": p, "decomp": decomp,
               "grid": grid_name, "backend": name,
               "measured_us": round(plan.measured[name] * 1e6, 1),
               "model_us": round(planner.predict_candidate(plan, name) * 1e6, 2),
               "picked": plan.backend, "device_kind": dev}
        print("ROW " + json.dumps(row))

mesh1d = make_mesh((p,), ("model",))
dev = planner.device_kind(mesh1d)
plan = plan_fft(shape, mesh1d, ndim=3, planner="measure")
emit("slab", f"{p}x1", plan)

for pr, pc in grid.grid_shapes(p):
    if pr == 1 or pc == 1:
        continue  # degenerate grids are the slab row above
    mesh = make_mesh((pr, pc), ("rows", "cols"))
    plan = plan_fft(shape, mesh, ndim=3, decomp="pencil", planner="measure")
    emit("pencil", f"{pr}x{pc}", plan)
"""


def run_json(n: int = 32, device_counts: Iterable[int] = (4, 8)) -> List[dict]:
    """Slab + every-pencil-grid measured/model rows per device count."""
    rows: List[dict] = []
    for p in device_counts:
        out = run_devices_subprocess(
            _CODE.replace("__N__", str(n)).replace("__P__", str(p)), devices=p
        )
        for line in out.splitlines():
            if line.startswith("ROW "):
                rows.append(json.loads(line[4:]))
    return rows


def to_csv(rows: List[dict]) -> List[str]:
    return [
        f"pencil_sweep/{r['decomp']}/{r['grid']}/{r['backend']},{r['measured_us']},"
        f"model_us={r['model_us']};picked={r['picked']}"
        for r in rows
    ]


def run(n: int = 32) -> List[str]:
    return to_csv(run_json(n))


if __name__ == "__main__":
    print("\n".join(run()))
