"""c2c-vs-r2c sweep -- the wire-byte-halving trajectory rows.

For each device count, runs the measured planner for the complex
transform and for the real (Hermitian-truncated) transform on the same
logical 2-D problem. Each row carries the measured median and the
alpha-beta model prediction per backend, plus the model's per-device
exchange bytes (``Plan.comm_bytes``) and -- for the picked backend --
the compiled HLO's parsed collective bytes, so the "r2c moves ~half the
bytes" claim is visible as data at every P.

``run_json()`` returns machine-readable rows (merged into
``BENCH_fft.json`` by ``benchmarks/run.py --json``); ``to_csv()``
renders the harness's ``name,us_per_call,derived`` format.
"""

from __future__ import annotations

import json
from typing import Iterable, List

from benchmarks.common import run_devices_subprocess

_CODE = r"""
import json
from repro.core import comm_model, plan_fft, planner

from repro.core.compat import make_mesh

n, p = __N__, __P__
mesh = make_mesh((p,), ("model",))
dev = planner.device_kind(mesh)
for real in (False, True):
    plan = plan_fft((n, n), mesh, real=real, planner="measure")
    hlo_bytes = comm_model.parse_collectives(
        plan.lower().compile().as_text(), default_group=p
    ).total_bytes
    for name in sorted(plan.measured):
        # candidates are (backend, n_chunks, fused) variants
        row = {"bench": "real", "n": n, "p": p,
               "transform": "r2c" if real else "c2c", "backend": name,
               "measured_us": round(plan.measured[name] * 1e6, 1),
               "model_us": round(planner.predict_candidate(plan, name) * 1e6, 2),
               "model_bytes": plan.comm_bytes(),
               "picked": plan.backend, "device_kind": dev}
        if name == plan.backend:
            row["hlo_bytes"] = hlo_bytes
        print("ROW " + json.dumps(row))
"""


def run_json(n: int = 256, device_counts: Iterable[int] = (2, 4, 8)) -> List[dict]:
    """Measured + model rows per backend per device count, c2c and r2c."""
    rows: List[dict] = []
    for p in device_counts:
        out = run_devices_subprocess(
            _CODE.replace("__N__", str(n)).replace("__P__", str(p)), devices=p
        )
        for line in out.splitlines():
            if line.startswith("ROW "):
                rows.append(json.loads(line[4:]))
    return rows


def to_csv(rows: List[dict]) -> List[str]:
    return [
        f"real_sweep/{r['transform']}/{r['backend']}/p{r['p']},{r['measured_us']},"
        f"model_us={r['model_us']};model_bytes={r['model_bytes']:.0f};"
        f"picked={r['picked']}"
        for r in rows
    ]


def run(n: int = 256) -> List[str]:
    return to_csv(run_json(n))


if __name__ == "__main__":
    print("\n".join(run()))
