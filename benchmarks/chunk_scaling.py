"""Paper Fig. 3 analogue: chunk-size scaling of the collective backends.

The paper sweeps message sizes between two nodes and shows per-message
overhead separating the parcelports (TCP's latency vs LCI). Here every
registered shard_map backend is swept over local pencil sizes on 2 host
devices: measured wall time shows the dispatch/fusion overheads; the
derived columns give each backend's own alpha-beta v5e model (the
``cost()`` the implementation itself carries), where the
latency-vs-bandwidth crossover actually lives.
"""

from __future__ import annotations

from repro.configs.fft_bench import CHUNK_SWEEP_SIZES
from repro.core import backends

from benchmarks.common import run_devices_subprocess

_CODE = r"""
import time, numpy as np, jax, jax.numpy as jnp
from repro.core import backends, fft2, FFTConfig
from repro.core.compat import make_mesh

mesh = make_mesh((2,), ("model",))
names = [n for n in backends.available()
         if backends.get(n).kind == "shard_map" and backends.get(n).supports(2)]
rng = np.random.default_rng(0)
for n in __SIZES__:
    x = jnp.asarray((rng.standard_normal((n, n)) + 1j*rng.standard_normal((n, n))).astype(np.complex64))
    for strat in names:
        fn = jax.jit(lambda v, s=strat: fft2(v, mesh, "model", FFTConfig(strategy=s)))
        jax.block_until_ready(fn(x))
        ts = []
        for _ in range(10):
            t0 = time.perf_counter(); jax.block_until_ready(fn(x)); ts.append(time.perf_counter()-t0)
        ts.sort()
        print(f"ROW,{n},{strat},{ts[len(ts)//2]*1e6:.1f}")
"""


def run() -> list[str]:
    sizes = CHUNK_SWEEP_SIZES[:4]  # CPU budget
    out = run_devices_subprocess(_CODE.replace("__SIZES__", repr(sizes)), devices=2)
    rows = []
    for line in out.splitlines():
        if not line.startswith("ROW,"):
            continue
        _, n, strat, us = line.split(",")
        n = int(n)
        p = 2
        m_local = n * n * 8 / p
        model = backends.get(strat).cost(m_local, p)
        rows.append(
            f"fig3_chunk/{strat}/n{n},{us},v5e_model_us={model*1e6:.2f};local_MB={m_local/2**20:.2f}"
        )
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
