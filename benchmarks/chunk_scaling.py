"""Paper Fig. 3 analogue: chunk-size scaling of the collective strategies.

The paper sweeps message sizes between two nodes and shows per-message
overhead separating the parcelports (TCP's latency vs LCI). Here the
strategies (fused a2a / scatter ring / bisection) are swept over local
pencil sizes on 2 host devices: measured wall time shows the dispatch/
fusion overheads; the derived columns give the alpha-beta v5e model where
the latency-vs-bandwidth crossover actually lives.
"""

from __future__ import annotations

from repro.configs.fft_bench import CHUNK_SWEEP_SIZES
from repro.core import comm_model

from benchmarks.common import run_devices_subprocess

_CODE = r"""
import time, numpy as np, jax, jax.numpy as jnp
from jax.sharding import AxisType
from repro.core import fft2, FFTConfig

mesh = jax.make_mesh((2,), ("model",), axis_types=(AxisType.Auto,))
rng = np.random.default_rng(0)
for n in __SIZES__:
    x = jnp.asarray((rng.standard_normal((n, n)) + 1j*rng.standard_normal((n, n))).astype(np.complex64))
    for strat in ["alltoall", "scatter", "bisection"]:
        fn = jax.jit(lambda v, s=strat: fft2(v, mesh, "model", FFTConfig(strategy=s)))
        jax.block_until_ready(fn(x))
        ts = []
        for _ in range(10):
            t0 = time.perf_counter(); jax.block_until_ready(fn(x)); ts.append(time.perf_counter()-t0)
        ts.sort()
        print(f"ROW,{n},{strat},{ts[len(ts)//2]*1e6:.1f}")
"""


def run() -> list[str]:
    sizes = CHUNK_SWEEP_SIZES[:4]  # CPU budget
    out = run_devices_subprocess(_CODE.replace("__SIZES__", repr(sizes)), devices=2)
    rows = []
    for line in out.splitlines():
        if not line.startswith("ROW,"):
            continue
        _, n, strat, us = line.split(",")
        n = int(n)
        chunk_bytes = n * n * 8 // 4  # per-chunk payload at P=2: (n/P)*(n/P)... per message
        p = 2
        m_local = n * n * 8 / p
        model = {
            "alltoall": comm_model.t_alltoall(m_local, p),
            "scatter": comm_model.t_scatter_ring(m_local, p),
            "bisection": comm_model.t_bisection(m_local, p),
        }[strat]
        rows.append(
            f"fig3_chunk/{strat}/n{n},{us},v5e_model_us={model*1e6:.2f};local_MB={m_local/2**20:.2f}"
        )
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
