"""Overlap section: fused vs unfused streaming exchanges, n_chunks sweep.

The paper's Fig. 3 probes the chunk-size / per-message-overhead trade
per parcelport; the pipelined overlap executor turns that axis into a
runtime knob (``plan_fft(..., pipeline=)``). This benchmark measures it:
for each configuration (slab fft2 / fft3, pencil fft3, slab r2c) and
each streaming backend, the *same* plan runs

- unfused  (``pipeline=False``: transpose, then the whole-axis FFT),
- fused    (``pipeline="auto"``: the FFT stage streams into the
  exchange's flight time, one chunk per peer), and
- fused with ``n_chunks`` in a sweep (sub-chunked peers: more, smaller
  messages; finer compute grain -- the paper's message-count scaling),

with the plan's own model prediction (``Plan.predict(fused=, n_chunks=)``)
next to each measured row -- the acceptance check is that model and
measurement agree on the *sign* of the fused-vs-unfused win.

``run_json()`` rows land in ``BENCH_fft.json`` under ``bench="overlap"``
via ``benchmarks/run.py --json``; ``to_csv()`` renders the harness's
``name,us_per_call,derived`` format.
"""

from __future__ import annotations

import json
from typing import Iterable, List

from benchmarks.common import run_devices_subprocess

_CODE = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from repro.core import backends, plan_fft, planner
from repro.core.compat import make_mesh

p = __P__
dev = planner.device_kind(make_mesh((p,), ("model",)))


def make_input(plan):
    spec = plan.input_spec()
    rng = np.random.default_rng(0)
    return jax.device_put(
        jnp.asarray(rng.standard_normal(spec.shape).astype(
            np.float32 if plan.real else np.complex64)),
        spec.sharding,
    )


def rows_for(tag, plan_kw, backend, variants, rounds=4, iters=6):
    # Interleave the variants across timing rounds and keep the MINIMUM
    # wall time per variant: host-device CPU timings drift with external
    # load, and interleaving + min cancels that drift where a single
    # median-of-one-block would bake it into whichever variant ran during
    # the spike -- fused-vs-unfused is a paired comparison, so both sides
    # must see the same machine.
    import time
    base = backend if isinstance(backend, str) else "+".join(backend)
    plans = []
    for fused, n_chunks in variants:
        pipeline = (n_chunks or True) if fused else False
        plan = plan_fft(backend=backend, pipeline=pipeline, **plan_kw)
        plans.append((fused, n_chunks, plan, make_input(plan)))
    best = [float("inf")] * len(plans)
    for _ in range(2):  # warmup / compile every variant first
        for _, _, plan, x in plans:
            jax.block_until_ready(plan.execute(x))
    for _ in range(rounds * iters):  # one call per variant per step: max pairing
        for i, (_, _, plan, x) in enumerate(plans):
            t0 = time.perf_counter()
            jax.block_until_ready(plan.execute(x))
            best[i] = min(best[i], time.perf_counter() - t0)
    out = []
    for i, (fused, n_chunks, plan, _) in enumerate(plans):
        model = plan.predict(fused=fused, n_chunks=n_chunks)[base]
        out.append({
            "bench": "overlap", "config": tag, "decomp": plan.decomp,
            "p": p, "backend": base, "fused": bool(plan.fused),
            "n_chunks": plan.n_chunks,
            "measured_us": round(best[i] * 1e6, 1),
            "model_us": round(model * 1e6, 2),
            "device_kind": dev,
        })
    return out


VARIANTS = [(False, None), (True, None), (True, 2 * p), (True, 4 * p)]
mesh = make_mesh((p,), ("model",))
rows = []
for backend in ("scatter", "pairwise_xor"):
    rows += rows_for(f"slab-fft2-n{__N2__}",
                     dict(global_shape=(__N2__, __N2__), mesh=mesh), backend, VARIANTS)
rows += rows_for("slab-fft3-16x16x512",
                 dict(global_shape=(16, 16, 512), mesh=mesh, ndim=3), "scatter", VARIANTS)
rows += rows_for(f"slab-r2c-n{__N2__}",
                 dict(global_shape=(__N2__, __N2__), mesh=mesh, real=True), "scatter", VARIANTS)
# six-step 1-D: the cross-rank stage is a strided length-P FFT, exactly
# what the fused in-flight accumulation replaces -- the structural win
rows += rows_for("slab-fft1d-1M",
                 dict(global_shape=(1 << 20,), mesh=mesh, ndim=1), "scatter", VARIANTS)
if p >= 4:
    pr, pc = (2, p // 2)
    gmesh = make_mesh((pr, pc), ("rows", "cols"))
    rows += rows_for(f"pencil-fft3-{pr}x{pc}",
                     dict(global_shape=(16, 16, 512), mesh=gmesh, ndim=3, decomp="pencil"),
                     ("scatter", "scatter"), VARIANTS)
    rows += rows_for(f"pencil-fft2-{pr}x{pc}",
                     dict(global_shape=(__N2__, __N2__), mesh=gmesh, ndim=2, decomp="pencil"),
                     ("scatter", "scatter"), VARIANTS)
for r in rows:
    print("ROW " + json.dumps(r))
"""


def run_json(n: int = 256, device_counts: Iterable[int] = (8,)) -> List[dict]:
    """Fused-vs-unfused + n_chunks rows per backend per configuration."""
    rows: List[dict] = []
    for p in device_counts:
        out = run_devices_subprocess(
            _CODE.replace("__N2__", str(n)).replace("__P__", str(p)), devices=p
        )
        for line in out.splitlines():
            if line.startswith("ROW "):
                rows.append(json.loads(line[4:]))
    return rows


def to_csv(rows: List[dict]) -> List[str]:
    out = []
    for r in rows:
        variant = (
            f"fused{r['n_chunks']}" if r["fused"] and r["n_chunks"]
            else ("fused" if r["fused"] else "unfused")
        )
        out.append(
            f"overlap/{r['config']}/{r['backend']}/{variant}/p{r['p']},"
            f"{r['measured_us']},model_us={r['model_us']}"
        )
    return out


def run(n: int = 256) -> List[str]:
    return to_csv(run_json(n))


if __name__ == "__main__":
    print("\n".join(run()))
