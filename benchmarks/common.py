"""Benchmark utilities: wall-clock timing + subprocess multi-device runs.

The container has ONE real CPU device; collective-strategy benchmarks run
in subprocesses with --xla_force_host_platform_device_count (host devices
talk over memcpy, so *relative* strategy overheads -- message count,
fusion, per-chunk dispatch -- are visible even without a fabric). The
alpha-beta ICI model (core/comm_model.py) supplies derived v5e columns
next to each measured row.
"""

from __future__ import annotations

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:  # allow running without PYTHONPATH=src
    sys.path.insert(0, SRC)

# One timing implementation for benchmarks AND the measured planner
# (repro.core.planner owns it; the planner cannot import benchmarks/).
from repro.core.planner import time_fn  # noqa: E402,F401


def run_devices_subprocess(code: str, devices: int, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=timeout, cwd=REPO,
    )
    if out.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{out.stderr[-3000:]}")
    return out.stdout


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
