"""Distributed FFT: all strategies vs numpy oracle on 8 host devices.

One consolidated subprocess (jax re-init with forced device count is
per-process), asserting every (transform x strategy x impl) cell.
"""

import pytest

from conftest import run_subprocess

CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import AxisType
from repro.core import fft2, ifft2, fft3, fft1d_large, FFTConfig, make_plan

mesh = jax.make_mesh((8,), ("model",), axis_types=(AxisType.Auto,))
rng = np.random.default_rng(0)
def c64(*s):
    return (rng.standard_normal(s) + 1j * rng.standard_normal(s)).astype(np.complex64)

x = c64(64, 64)
ref = np.fft.fft2(x)
tol = 1e-4 * np.abs(ref).max()
for strat in ["alltoall", "scatter", "bisection", "xla_auto"]:
    impls = ["jnp", "matmul", "pallas"] if strat == "scatter" else ["jnp"]
    for impl in impls:
        y = np.asarray(fft2(jnp.asarray(x), mesh, "model", FFTConfig(strategy=strat, local_impl=impl)))
        assert np.abs(y - ref.T).max() < tol, (strat, impl, np.abs(y - ref.T).max())
print("PASS fft2 strategies")

y = np.asarray(fft2(jnp.asarray(x), mesh, "model", FFTConfig(strategy="scatter", fuse_dft=True)))
assert np.abs(y - ref.T).max() < tol
print("PASS fused scatter-dft")

y = np.asarray(fft2(jnp.asarray(x), mesh, "model", FFTConfig(strategy="scatter", transpose_back=True)))
assert np.abs(y - ref).max() < tol
print("PASS transpose_back")

z = ifft2(fft2(jnp.asarray(x), mesh, "model", FFTConfig(strategy="bisection")), mesh, "model",
          FFTConfig(strategy="bisection"))
assert np.abs(np.asarray(z) - x).max() < 1e-4
print("PASS roundtrip")

xb = c64(3, 32, 64)
refb = np.swapaxes(np.fft.fft2(xb), -1, -2)
y = np.asarray(fft2(jnp.asarray(xb), mesh, "model", FFTConfig(strategy="scatter")))
assert np.abs(y - refb).max() < 1e-4 * np.abs(refb).max()
print("PASS batched")

x3 = c64(16, 8, 8)
r3 = np.fft.fftn(x3, axes=(-3, -2, -1))
for strat in ["alltoall", "scatter", "bisection", "xla_auto"]:
    y = np.asarray(fft3(jnp.asarray(x3), mesh, "model", FFTConfig(strategy=strat)))
    assert np.abs(y - r3).max() < 1e-4 * np.abs(r3).max(), strat
print("PASS fft3")

x1 = c64(4096)
r1 = np.fft.fft(x1)
for strat in ["alltoall", "scatter", "bisection"]:
    y = np.asarray(fft1d_large(jnp.asarray(x1), mesh, "model", FFTConfig(strategy=strat), rows=64))
    assert np.abs(y - r1).max() < 1e-4 * np.abs(r1).max(), strat
print("PASS fft1d_large")

# plan API + abstract lowering
plan = make_plan((128, 64), mesh, strategy="scatter")
y = np.asarray(plan.execute(jnp.asarray(c64(128, 64))))
assert y.shape == (64, 128)
lowered = plan.lower()
assert "main" in lowered.as_text() or lowered is not None
print("PASS plan")
"""


@pytest.mark.slow
def test_distributed_fft_8dev():
    out = run_subprocess(CODE, devices=8)
    assert out.count("PASS") == 8, out
