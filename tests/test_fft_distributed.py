"""Distributed FFT: every registered backend vs numpy oracle on 8 host
devices, plus the plan_fft front-end (auto selection, executable cache).

One consolidated subprocess per device-count regime (jax re-init with a
forced device count is per-process). The strategy sweeps iterate
``repro.core.backends.available()``, so registering a new backend
automatically validates it against the oracle here.
"""

import pytest

from conftest import run_subprocess

CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import backends, fft2, ifft2, fft3, fft1d_large, FFTConfig, plan_fft
from repro.core.compat import make_mesh

mesh = make_mesh((8,), ("model",))
P = 8
rng = np.random.default_rng(0)
def c64(*s):
    return (rng.standard_normal(s) + 1j * rng.standard_normal(s)).astype(np.complex64)

def shard_names():
    return [n for n in backends.available() if backends.get(n).supports(P)]

x = c64(64, 64)
ref = np.fft.fft2(x)
tol = 1e-4 * np.abs(ref).max()
for strat in shard_names():
    impls = ["jnp", "matmul", "pallas"] if strat == "scatter" else ["jnp"]
    for impl in impls:
        y = np.asarray(fft2(jnp.asarray(x), mesh, "model", FFTConfig(strategy=strat, local_impl=impl)))
        assert np.abs(y - ref.T).max() < tol, (strat, impl, np.abs(y - ref.T).max())
print("PASS fft2 strategies")

y = np.asarray(fft2(jnp.asarray(x), mesh, "model", FFTConfig(strategy="scatter", fuse_dft=True)))
assert np.abs(y - ref.T).max() < tol
print("PASS fused scatter-dft")

y = np.asarray(fft2(jnp.asarray(x), mesh, "model", FFTConfig(strategy="scatter", transpose_back=True)))
assert np.abs(y - ref).max() < tol
print("PASS transpose_back")

z = ifft2(fft2(jnp.asarray(x), mesh, "model", FFTConfig(strategy="bisection")), mesh, "model",
          FFTConfig(strategy="bisection"))
assert np.abs(np.asarray(z) - x).max() < 1e-4
print("PASS roundtrip")

xb = c64(3, 32, 64)
refb = np.swapaxes(np.fft.fft2(xb), -1, -2)
y = np.asarray(fft2(jnp.asarray(xb), mesh, "model", FFTConfig(strategy="scatter")))
assert np.abs(y - refb).max() < 1e-4 * np.abs(refb).max()
print("PASS batched")

x3 = c64(16, 8, 8)
r3 = np.fft.fftn(x3, axes=(-3, -2, -1))
for strat in shard_names():
    y = np.asarray(fft3(jnp.asarray(x3), mesh, "model", FFTConfig(strategy=strat)))
    assert np.abs(y - r3).max() < 1e-4 * np.abs(r3).max(), strat
print("PASS fft3")

x1 = c64(4096)
r1 = np.fft.fft(x1)
for strat in shard_names():
    if backends.get(strat).kind != "shard_map":
        continue
    y = np.asarray(fft1d_large(jnp.asarray(x1), mesh, "model", FFTConfig(strategy=strat), rows=64))
    assert np.abs(y - r1).max() < 1e-4 * np.abs(r1).max(), strat
print("PASS fft1d_large")

# plan API: auto backend = cost-model argmin, cached executable, lowering
plan = plan_fft((128, 64), mesh, backend="auto")
pred = plan.predict()
assert abs(pred[plan.backend] - min(pred.values())) < 1e-12, (plan.backend, pred)
xp = jnp.asarray(c64(128, 64))
y1 = plan.execute(xp)
y2 = plan.execute(xp)
assert y1.shape == (64, 128)
assert np.allclose(np.asarray(y1), np.asarray(y2))
assert plan.compiles == 1
assert plan.executable_stats()[("forward", "complex64")] == 1, plan.executable_stats()
lowered = plan.lower()
assert lowered is not None
print("PASS plan")
"""

PLAN_SWEEP_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import backends, plan_fft, reference_fft2
from repro.core.compat import make_mesh

rng = np.random.default_rng(1)
x = (rng.standard_normal((32, 32)) + 1j * rng.standard_normal((32, 32))).astype(np.complex64)
ref = np.asarray(reference_fft2(jnp.asarray(x)))
tol = 1e-4 * np.abs(ref).max()

for p in (1, 2, 4):
    mesh = make_mesh((p,), ("model",))
    for name in backends.available():
        if not backends.get(name).supports(p):
            continue
        plan = plan_fft((32, 32), mesh, backend=name)
        y = np.asarray(plan.execute(jnp.asarray(x)))
        assert np.abs(y - ref.T).max() < tol, (p, name, np.abs(y - ref.T).max())
        z = np.asarray(plan.inverse(jnp.asarray(y)))
        assert np.abs(z.T - x.T).max() < 1e-4, (p, name)
        # repeated execute reuses the one cached jitted executable
        plan.execute(jnp.asarray(x))
        assert plan.executable_stats()[("forward", "complex64")] == 1
    auto = plan_fft((32, 32), mesh, backend="auto")
    pred = auto.predict()
    assert abs(pred[auto.backend] - min(pred.values())) < 1e-12, (p, auto.backend, pred)
    print(f"PASS plan sweep P={p}")
"""


@pytest.mark.slow
def test_distributed_fft_8dev():
    out = run_subprocess(CODE, devices=8)
    assert out.count("PASS") == 8, out


@pytest.mark.slow
def test_plan_all_backends_p124():
    out = run_subprocess(PLAN_SWEEP_CODE, devices=4)
    assert out.count("PASS") == 3, out


# ---------------------------------------------------------------------------
# In-process property test: forward/inverse round trip over the shared
# (odd batch, dtype width, slab/pencil, ndim) field -- the r2c twin lives
# in tests/test_real.py and draws from the same strategies.
# ---------------------------------------------------------------------------

import numpy as np

from roundtrip_common import build_plan, roundtrip_given, transform_shape


@roundtrip_given
def test_c2c_roundtrip_property(batch, decomp, ndim, wide, last_n):
    import jax.numpy as jnp

    shape = transform_shape(batch, ndim, last_n)
    dtype = jnp.complex128 if wide else jnp.complex64
    plan = build_plan(shape, decomp, ndim=ndim, dtype=dtype)
    rng = np.random.default_rng(batch * 100 + ndim * 10 + last_n)
    x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex128 if wide else np.complex64
    )
    z = np.asarray(plan.inverse(plan.execute(jnp.asarray(x))))
    assert z.shape == x.shape
    # x64 may be globally off, so 64-bit draws still settle at c64 tolerance
    assert np.abs(z - x).max() < 1e-4 * max(np.abs(x).max(), 1.0), (
        decomp, ndim, batch, last_n, wide,
    )
