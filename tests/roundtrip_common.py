"""Shared forward/inverse round-trip parametrization (hypothesis).

One strategy set -- odd batch sizes, both dtype widths, slab and pencil
decompositions, 2-D and 3-D -- drawn by the c2c property test
(tests/test_fft_distributed.py) and reused verbatim by the r2c round
trips (tests/test_real.py), so the two transform families are always
exercised over the same field. Runs in-process on the 1-device mesh
(pencil uses a 1x1 grid); the multi-device numerics live in the
subprocess suites.
"""

from __future__ import annotations

from hypothesis import given, settings, strategies as st

#: The single value field both transform families (and the fused-vs-
#: unfused overlap sweep in tests/test_pipeline.py) draw from -- plain
#: tuples so subprocess suites can reuse the exact parametrization.
BATCH_VALUES = (1, 3, 5, 7)  # odd batches: regression territory for chunking bugs
DECOMP_VALUES = ("slab", "pencil")
NDIM_VALUES = (2, 3)
LAST_N_VALUES = (6, 7, 8)  # even and odd Hermitian cases for r2c

BATCHES = st.sampled_from(list(BATCH_VALUES))
DECOMPS = st.sampled_from(list(DECOMP_VALUES))
NDIMS = st.sampled_from(list(NDIM_VALUES))
#: False -> 32-bit pair (complex64 / float32), True -> 64-bit pair
WIDE = st.booleans()
LAST_N = st.sampled_from(list(LAST_N_VALUES))


def roundtrip_given(fn):
    """The shared ``@given`` + ``@settings`` decorator: draws
    (batch, decomp, ndim, wide, last_n)."""
    return settings(max_examples=12, deadline=None)(
        given(batch=BATCHES, decomp=DECOMPS, ndim=NDIMS, wide=WIDE, last_n=LAST_N)(fn)
    )


def transform_shape(batch: int, ndim: int, last_n: int):
    """(batch, ...transform dims) with the drawn trailing length."""
    return (batch,) + (8,) * (ndim - 1) + (last_n,)


def build_plan(shape, decomp: str, **kw):
    """plan_fft on a 1-device mesh matching ``decomp`` (slab: 1-axis
    mesh; pencil: 1x1 ProcessGrid mesh)."""
    from repro.core import plan_fft
    from repro.core.compat import make_mesh

    if decomp == "pencil":
        mesh = make_mesh((1, 1), ("rows", "cols"))
    else:
        mesh = make_mesh((1,), ("model",))
    return plan_fft(shape, mesh, decomp=decomp, **kw)
