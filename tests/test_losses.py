"""Chunked cross-entropy vs unchunked oracle + masking properties."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.models import losses


def _unemb(v, d, rng):
    w = jnp.asarray(rng.standard_normal((d, v)), jnp.float32)
    return lambda x: jnp.einsum("...d,dv->...v", x, w)


@pytest.mark.parametrize("seq_chunk", [4, 7, 16, 64])
def test_chunked_matches_full(rng, seq_chunk):
    b, s, d, v = 2, 33, 8, 50
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    un = _unemb(v, d, rng)
    nll_c, z_c = losses.chunked_xent(x, labels, un, seq_chunk=seq_chunk, z_loss=1e-3)
    nll_f, z_f = losses.full_xent(x, labels, un, z_loss=1e-3)
    np.testing.assert_allclose(float(nll_c), float(nll_f), rtol=1e-5)
    np.testing.assert_allclose(float(z_c), float(z_f), rtol=1e-5)


def test_ignore_labels(rng):
    b, s, d, v = 1, 16, 8, 20
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    masked = labels.at[:, 8:].set(-1)
    un = _unemb(v, d, rng)
    nll_m, _ = losses.chunked_xent(x, masked, un, seq_chunk=4)
    nll_half, _ = losses.chunked_xent(x[:, :8], labels[:, :8], un, seq_chunk=4)
    np.testing.assert_allclose(float(nll_m), float(nll_half), rtol=1e-5)


def test_softcap_applied(rng):
    b, s, d, v = 1, 8, 4, 10
    x = jnp.asarray(rng.standard_normal((b, s, d)) * 10, jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    un = _unemb(v, d, rng)
    a, _ = losses.chunked_xent(x, labels, un, final_softcap=5.0)
    b_, _ = losses.chunked_xent(x, labels, un)
    assert abs(float(a) - float(b_)) > 1e-4


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), s=st.integers(2, 40))
def test_loss_positive_and_bounded(seed, s):
    r = np.random.default_rng(seed)
    v = 30
    x = jnp.asarray(r.standard_normal((1, s, 6)), jnp.float32)
    labels = jnp.asarray(r.integers(0, v, (1, s)), jnp.int32)
    w = jnp.asarray(r.standard_normal((6, v)) * 0.01, jnp.float32)
    nll, _ = losses.chunked_xent(x, labels, lambda h: h @ w, seq_chunk=8)
    assert 0 < float(nll) < 3 * np.log(v)


def test_gradient_matches_full(rng):
    b, s, d, v = 2, 12, 6, 25
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((d, v)), jnp.float32)

    def lc(x, w):
        return losses.chunked_xent(x, labels, lambda h: h @ w, seq_chunk=4)[0]

    def lf(x, w):
        return losses.full_xent(x, labels, lambda h: h @ w)[0]

    gc = jax.grad(lc, argnums=(0, 1))(x, w)
    gf = jax.grad(lf, argnums=(0, 1))(x, w)
    for a, b_ in zip(gc, gf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4, atol=1e-6)
