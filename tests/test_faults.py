"""Fault-tolerant execution: chaos injection on the schedule IR
(FaultPlan modes, determinism, trace spans), per-request isolation and
graceful degradation in serving (batch poison -> solo retry ->
quarantine; circuit breaker -> xla_auto), planner race failure
isolation, recovery primitives (backoff, Resume, FailureInjector,
corrupt-skip checkpoints), and the 8-device elastic remesh-and-replan
acceptance: a P=8 run that loses half its devices resumes at P=4 from
checkpoint bitwise identical to an uninterrupted P=4 run."""

import numpy as np
import pytest

from conftest import run_subprocess

jax = pytest.importorskip("jax")
jnp = jax.numpy

from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.core import backends, plan_fft, planner, schedule as sch  # noqa: E402
from repro.core.compat import make_mesh, make_mesh_1d  # noqa: E402
from repro.obs.trace import TraceRecorder  # noqa: E402
from repro.runtime import (  # noqa: E402
    CircuitBreaker,
    DeviceLossFault,
    FailureInjector,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    Resume,
    RetryPolicy,
    SimulatedFailure,
    backoff_delay,
    elastic_mesh,
    run_with_recovery,
)
from repro.serve import SpectralEngine  # noqa: E402


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


class AutoClock:
    """Advances on every read -- makes wall-clock budgets elapse without
    sleeping."""

    def __init__(self, dt=1.0):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


@pytest.fixture
def mesh1():
    return make_mesh((1,), ("model",))


@pytest.fixture(autouse=True)
def _fresh_wisdom():
    planner.forget_wisdom()
    yield
    planner.forget_wisdom()


def _x(n=16, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    shape = (n, n) if batch is None else (batch, n, n)
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )


def _want(x):
    """Slab fft2 output layout (no transpose_back): transposed spectrum."""
    return np.swapaxes(np.fft.fft2(x), -1, -2)


# ------------------------------------------------------------ FaultPlan
class TestFaultPlan:
    def test_error_fires_records_then_exhausts(self, mesh1):
        plan = plan_fft((16, 16), mesh1, faults=FaultPlan.error(match="Exchange"))
        x = _x()
        with pytest.raises(InjectedFault, match="Exchange"):
            plan.execute(jnp.asarray(x))
        assert plan.faults.injected == 1
        [ev] = plan.faults.events
        assert ev["mode"] == "error" and "Exchange" in ev["stage"]
        # exhausted -> active() False -> back on the fast jitted path,
        # numerics clean
        assert not plan.faults.active()
        np.testing.assert_allclose(
            np.asarray(plan.execute(jnp.asarray(x))), _want(x), rtol=1e-5, atol=1e-6
        )

    def test_stall_uses_injected_sleep_and_still_computes(self, mesh1):
        slept = []
        fp = FaultPlan.stall(0.25, match="Exchange", sleep=slept.append)
        plan = plan_fft((16, 16), mesh1, faults=fp)
        x = _x()
        got = np.asarray(plan.execute(jnp.asarray(x)))
        assert slept == [0.25] and fp.stalled_s == 0.25
        np.testing.assert_allclose(got, _want(x), rtol=1e-5, atol=1e-6)

    def test_device_loss_carries_survivor_count(self, mesh1):
        plan = plan_fft((16, 16), mesh1, faults=FaultPlan.device_loss(4))
        with pytest.raises(DeviceLossFault) as ei:
            plan.execute(jnp.asarray(_x()))
        assert ei.value.alive == 4
        assert isinstance(ei.value, InjectedFault)  # one except-clause catches both

    def test_match_selectivity(self, mesh1):
        fp = FaultPlan.error(match="no-such-stage")
        plan = plan_fft((16, 16), mesh1, faults=fp)
        x = _x()
        np.testing.assert_allclose(
            np.asarray(plan.execute(jnp.asarray(x))), _want(x), rtol=1e-5, atol=1e-6
        )
        assert fp.events == [] and fp.active()  # armed but never matched

    def test_global_backend_label(self, mesh1):
        fp = FaultPlan.error(match="global:")
        plan = plan_fft((16, 16), mesh1, backend="xla_auto", faults=fp)
        with pytest.raises(InjectedFault, match="global:"):
            plan.execute(jnp.asarray(_x()))

    def test_times_caps_consecutive_firings(self):
        fp = FaultPlan((FaultSpec("error", match="Exchange", times=2),))
        fired = []
        for k in range(4):
            try:
                fp.on_stage("Exchange(test)", index=k)
            except InjectedFault:
                fired.append(k)
        assert fired == [0, 1]  # matches 0 and 1 fire, then exhausted
        assert not fp.active()

    def test_at_every_schedule(self):
        fp = FaultPlan((FaultSpec("error", match="", at=1, every=2, times=2),))
        fired = []
        for k in range(6):
            try:
                fp.on_stage("anything", index=k)
            except InjectedFault:
                fired.append(k)
        assert fired == [1, 3]

    def test_rate_is_seed_deterministic(self):
        def pattern(seed):
            fp = FaultPlan.rate(0.5, seed=seed)
            out = []
            for _ in range(32):
                try:
                    fp.on_stage("Exchange(x)")
                    out.append(0)
                except InjectedFault:
                    out.append(1)
            return out

        assert pattern(7) == pattern(7)
        assert 0 < sum(pattern(7)) < 32  # actually probabilistic
        assert pattern(7) != pattern(8)

    def test_reset_replays_identically(self):
        fp = FaultPlan.rate(0.5, seed=3)
        first = [_fires(fp) for _ in range(16)]
        fp.reset()
        assert [_fires(fp) for _ in range(16)] == first
        assert fp.events != []  # reset cleared, replay re-recorded

    def test_recorder_stamps_fault_spans(self, mesh1):
        rec = TraceRecorder()
        fp = FaultPlan.error(match="Exchange", recorder=rec)
        plan = plan_fft((16, 16), mesh1, faults=fp)
        with pytest.raises(InjectedFault):
            plan.execute(jnp.asarray(_x()))
        faults = [s for s in rec.spans if s.cat == "fault"]
        assert len(faults) == 1 and faults[0].name == "fault:error"

    def test_traced_injection_leaves_no_half_open_span(self, mesh1):
        rec = TraceRecorder()
        plan = plan_fft((16, 16), mesh1)
        fp = FaultPlan.error(match="Exchange")
        with pytest.raises(InjectedFault):
            sch.run_schedule(
                jnp.asarray(_x()), plan.schedule(), mesh1, trace=rec, faults=fp
            )
        # the raise happened outside any span context: everything
        # recorded is complete (dur stamped), nothing dangling
        assert all(s.dur >= 0.0 for s in rec.spans)
        assert not any(s.cat == "exchange" for s in rec.spans)

    def test_bad_specs_rejected(self):
        with pytest.raises(ValueError, match="mode"):
            FaultSpec("explode")
        with pytest.raises(ValueError, match="rate"):
            FaultSpec("error", rate=1.5)


def _fires(fp):
    try:
        fp.on_stage("Exchange(x)")
        return 0
    except InjectedFault:
        return 1


# ------------------------------------------- serving: isolation + retry
class TestServeIsolation:
    def test_batch_poison_isolated_siblings_resolve(self, mesh1):
        eng = SpectralEngine(mesh1, max_batch=4, max_wait_s=100.0,
                             retry=RetryPolicy(max_retries=0))
        xs = [_x(seed=i) for i in range(4)]
        # fault #1 poisons the coalesced batch -> split; fault #2
        # poisons the first solo retry -> that one request quarantines
        eng.set_faults(FaultPlan.error(match="Exchange", times=2))
        futs = [eng.submit("fft", x) for x in xs]
        eng.drain()
        failed = [f for f in futs if f.failed()]
        ok = [f for f in futs if not f.failed()]
        assert len(failed) == 1 and len(ok) == 3
        for f in ok:
            np.testing.assert_allclose(
                np.asarray(f.result()),
                _want(np.asarray(f.request.operands[0])),
                rtol=1e-5, atol=1e-6,
            )
        with pytest.raises(InjectedFault):
            failed[0].result()
        with pytest.raises(InjectedFault):
            failed[0].block()
        m = eng.metrics()
        assert m["errors"] == 2 and m["batch_splits"] == 1
        assert m["quarantined"] == 1 and m["failed_requests"] == 1

    def test_retry_heals_transient_fault(self, mesh1):
        eng = SpectralEngine(mesh1, max_batch=1, retry=RetryPolicy(max_retries=1))
        x = _x()
        eng.submit("fft", x).block()  # warm, healthy
        eng.set_faults(FaultPlan.error(match="Exchange", times=1))
        fut = eng.submit("fft", x)
        eng.drain()
        assert not fut.failed()
        np.testing.assert_allclose(np.asarray(fut.result()), _want(x),
                                   rtol=1e-5, atol=1e-6)
        assert eng.retries == 1 and eng.quarantined == 0 and eng.errors == 1

    def test_retry_deadline_abandons(self, mesh1):
        # every clock read advances 1s -> the 0.5s budget is already
        # spent when the retry loop first checks it
        eng = SpectralEngine(
            mesh1, max_batch=1, clock=AutoClock(1.0),
            retry=RetryPolicy(max_retries=10, deadline_s=0.5),
        )
        x = _x()
        eng.submit("fft", x).block()
        eng.set_faults(FaultPlan.error(match="Exchange", times=5))
        fut = eng.submit("fft", x)
        eng.drain()
        assert fut.failed() and eng.retries == 0 and eng.quarantined == 1

    def test_drain_raise_errors_after_siblings(self, mesh1):
        eng = SpectralEngine(mesh1, max_batch=2, max_wait_s=100.0,
                             retry=RetryPolicy(max_retries=0))
        xs = [_x(seed=i) for i in range(2)]
        eng.set_faults(FaultPlan.error(match="Exchange", times=2))
        futs = [eng.submit("fft", x) for x in xs]
        with pytest.raises(InjectedFault):
            eng.drain(raise_errors=True)
        done = [f for f in futs if not f.failed()]
        assert len(done) == 1 and done[0].done()  # sibling still resolved


# --------------------------------------------------- circuit breaker
class TestCircuitBreaker:
    def test_open_after_threshold_consecutive(self):
        clk = FakeClock()
        br = CircuitBreaker(failure_threshold=3, reset_after_s=10.0, clock=clk)
        for _ in range(2):
            br.record_failure("k")
        assert br.state("k") == "closed" and br.allow("k")
        br.record_success("k")  # success resets the consecutive count
        for _ in range(2):
            br.record_failure("k")
        assert br.state("k") == "closed"
        br.record_failure("k")
        assert br.state("k") == "open" and not br.allow("k")
        assert br.stats() == {"open": 1, "half_open": 0, "opened": 1,
                              "reclosed": 0, "probes": 0}

    def test_half_open_probe_recloses(self):
        clk = FakeClock()
        br = CircuitBreaker(failure_threshold=1, reset_after_s=5.0, clock=clk)
        br.record_failure("k")
        assert not br.allow("k")
        clk.advance(5.0)
        assert br.allow("k") and br.state("k") == "half-open"
        assert not br.allow("k")  # exactly one probe admitted
        br.record_success("k")
        assert br.state("k") == "closed" and br.allow("k")
        st = br.stats()
        assert st["probes"] == 1 and st["reclosed"] == 1

    def test_half_open_probe_failure_reopens(self):
        clk = FakeClock()
        br = CircuitBreaker(failure_threshold=1, reset_after_s=5.0, clock=clk)
        br.record_failure("k")
        clk.advance(5.0)
        assert br.allow("k")
        br.record_failure("k")  # probe failed -> re-open, restart timer
        assert br.state("k") == "open" and not br.allow("k")
        clk.advance(4.9)
        assert not br.allow("k")
        clk.advance(0.2)
        assert br.allow("k")
        assert br.stats()["opened"] == 2

    def test_keys_independent_and_reset(self):
        br = CircuitBreaker(failure_threshold=1, clock=FakeClock())
        br.record_failure("a")
        assert not br.allow("a") and br.allow("b")
        br.reset()
        assert br.allow("a") and br.stats()["open"] == 0


class TestServeDegradation:
    def test_breaker_degrades_to_xla_auto_then_reprobes(self, mesh1):
        clk = FakeClock()
        eng = SpectralEngine(
            mesh1, max_batch=1, clock=clk, retry=RetryPolicy(max_retries=0),
            breaker=CircuitBreaker(failure_threshold=2, reset_after_s=5.0, clock=clk),
        )
        x = _x()
        eng.submit("fft", x).block()  # warm, healthy
        eng.set_faults(FaultPlan.error(match="Exchange", times=2))
        assert eng.submit("fft", x) and eng.drain() is None
        f2 = eng.submit("fft", x)
        eng.drain()
        assert f2.failed()
        # breaker open -> third request degrades to the xla_auto
        # reference schedule (its "global:fft" label dodges the
        # Exchange-matched chaos) and still answers correctly
        f3 = eng.submit("fft", x)
        eng.drain()
        assert not f3.failed() and f3.degraded and f3.backend == "xla_auto"
        np.testing.assert_allclose(np.asarray(f3.result()), _want(x),
                                   rtol=1e-5, atol=1e-6)
        m = eng.metrics()
        assert m["degraded_dispatches"] > 0 and m["breaker_open"] == 1
        assert m["breaker_opened"] == 1
        # cool-down elapses, faults are exhausted: the half-open probe
        # runs the primary backend again and re-closes the key
        clk.advance(6.0)
        f4 = eng.submit("fft", x)
        eng.drain()
        assert not f4.failed() and f4.degraded is False
        st = eng.breaker.stats()
        assert st["open"] == 0 and st["reclosed"] == 1 and st["probes"] == 1
        assert eng.stats()["faults"]["breaker"] == st


# ------------------------------------------------- planner race isolation
class TestPlannerRaceIsolation:
    def _timer(self, table, broken):
        def timer(plan):
            if plan.backend in broken:
                raise RuntimeError("backend exploded")
            return table[plan.backend]

        return timer

    def test_failed_candidate_excluded_not_fatal(self):
        mesh = make_mesh_1d(1)
        names = [n for n in backends.available() if backends.get(n).supports(1)]
        broken = sorted(names)[0]
        table = {n: 1.0 + i for i, n in enumerate(sorted(names))}
        plan = plan_fft((32, 32), mesh, planner="measure",
                        timer=self._timer(table, {broken}))
        assert plan.backend != broken
        assert plan.measured[broken] == float("inf")
        assert "exploded" in plan.race_failures[broken]
        why = plan.why()
        assert broken in why["failed"]
        assert broken not in why["timings"]  # inf excluded from argmin set
        assert "failed candidates" in plan.why_text()
        # wisdom remembers the failure note (finite timings only on disk)
        plan2 = plan_fft((32, 32), mesh, planner="measure",
                         timer=self._timer(table, {broken}))
        assert plan2.wisdom_hit and broken in plan2.race_failures

    def test_all_candidates_failing_raises(self):
        mesh = make_mesh_1d(1)

        def timer(plan):
            raise RuntimeError("boom")

        with pytest.raises(RuntimeError, match="every candidate failed"):
            plan_fft((32, 32), mesh, planner="measure", timer=timer)


# --------------------------------------------------- recovery primitives
class TestElasticPrimitives:
    def test_backoff_deterministic_capped(self):
        import random

        a = [backoff_delay(r, 1.0, cap_s=5.0, rng=random.Random(3)) for r in (1, 2, 3, 4)]
        b = [backoff_delay(r, 1.0, cap_s=5.0, rng=random.Random(3)) for r in (1, 2, 3, 4)]
        assert a == b  # seeded jitter is reproducible
        assert all(d <= 5.0 for d in a)
        assert backoff_delay(10, 1.0, cap_s=5.0) == 5.0  # capped, jitterless
        assert backoff_delay(3, 0.0) == 0.0

    def test_run_with_recovery_resume_and_sleep_sequence(self):
        slept, resumes = [], []

        def loop(resume):
            resumes.append(resume)
            if len(resumes) < 3:
                raise SimulatedFailure(f"crash {len(resumes)}")

        restarts = run_with_recovery(
            loop, max_restarts=3, backoff_s=1.0, jitter=0.0, sleep=slept.append
        )
        assert restarts == 2
        assert slept == [1.0, 2.0]  # exponential, deterministic
        assert resumes[0] is None
        assert resumes[1] == Resume(restarts=1, cause="SimulatedFailure: crash 1")
        assert resumes[2].restarts == 2 and resumes[2].step is None

    def test_run_with_recovery_exhausts(self):
        def loop(resume):
            raise SimulatedFailure("always")

        with pytest.raises(SimulatedFailure):
            run_with_recovery(loop, max_restarts=1, sleep=lambda s: None)

    def test_failure_injector_schedule(self):
        inj = FailureInjector(3, every=2, times=2)
        fired = []
        for s in range(10):
            try:
                inj.maybe_fail(s)
            except SimulatedFailure:
                fired.append(s)
        assert fired == [3, 5] and inj.fired_steps == [3, 5] and inj.fired

    def test_failure_injector_default_once(self):
        inj = FailureInjector(2)
        with pytest.raises(SimulatedFailure):
            inj.maybe_fail(2)
        inj.maybe_fail(2)  # repeatable schedule, but times=1 exhausted
        assert not FailureInjector(None).scheduled(0)

    def test_elastic_mesh_rejects_empty_group(self):
        with pytest.raises(ValueError, match="model_parallel"):
            elastic_mesh(("data", "model"), model_parallel=2,
                         devices=jax.devices()[:1])


# ------------------------------------------------- checkpoint corrupt-skip
class TestCheckpointRobustness:
    def _tree(self):
        return {"x": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}

    def test_tmp_dirs_invisible(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self._tree(), blocking=True)
        (tmp_path / "step_0000000009.tmpabc123").mkdir()
        assert mgr.all_steps() == [1]
        assert mgr.latest_step() == 1

    def test_corrupt_manifest_skipped(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        for s in (1, 2, 3):
            mgr.save(s, self._tree(), blocking=True)
        (tmp_path / "step_0000000003" / "manifest.json").write_text("{not json")
        assert mgr.all_steps() == [1, 2, 3]
        assert mgr.valid_steps() == [1, 2] and mgr.latest_step() == 2
        step, restored = mgr.restore_latest(self._tree())
        assert step == 2
        np.testing.assert_array_equal(np.asarray(restored["x"]),
                                      np.asarray(self._tree()["x"]))

    def test_missing_shard_skipped(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        for s in (1, 2):
            mgr.save(s, self._tree(), blocking=True)
        (tmp_path / "step_0000000002" / "proc0.npz").unlink()
        assert mgr.latest_step() == 1

    def test_truncated_npz_falls_back_at_load(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        for s in (1, 2):
            mgr.save(s, self._tree(), blocking=True)
        npz = tmp_path / "step_0000000002" / "proc0.npz"
        npz.write_bytes(npz.read_bytes()[:20])  # valid-looking, unreadable
        assert mgr.latest_step() == 2  # cheap check cannot see inside
        step, restored = mgr.restore_latest(self._tree())
        assert step == 1 and restored is not None

    def test_no_survivor_returns_none(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.restore_latest(self._tree()) == (None, None)

    def test_atomic_unique_staging(self, tmp_path):
        # two managers racing the same step: neither corrupts the other
        a = CheckpointManager(str(tmp_path))
        b = CheckpointManager(str(tmp_path))
        a.save(1, self._tree(), blocking=True)
        b.save(1, {"x": jnp.ones((2, 3), jnp.float32)}, blocking=True)
        step, restored = a.restore_latest(self._tree())
        assert step == 1
        assert not [f for f in tmp_path.iterdir() if ".tmp" in f.name]


# --------------------------------------------------- 8-device subprocess
CHAOS_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import plan_fft, planner
from repro.core.compat import make_mesh
from repro.runtime import (CircuitBreaker, FaultPlan, InjectedFault,
                           RetryPolicy, elastic_mesh)
from repro.serve import SpectralEngine

class FakeClock:
    def __init__(self): self.t = 0.0
    def __call__(self): return self.t
    def advance(self, dt): self.t += dt

mesh = make_mesh((8,), ("model",))
n = 32
rng = np.random.default_rng(0)
xs = [(rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))
      ).astype(np.complex64) for _ in range(4)]
want = [np.swapaxes(np.fft.fft2(x), -1, -2) for x in xs]

# -- batch poison isolation at P=8 -------------------------------------
eng = SpectralEngine(mesh, max_batch=4, max_wait_s=100.0,
                     retry=RetryPolicy(max_retries=0))
hf = [eng.submit("fft", x) for x in xs]
eng.drain()  # warm + healthy baseline
for f, w in zip(hf, want):
    assert np.allclose(np.asarray(f.result()), w, rtol=1e-4, atol=1e-5)
eng.set_faults(FaultPlan.error(match="Exchange", times=2))
futs = [eng.submit("fft", x) for x in xs]
eng.drain()
failed = [i for i, f in enumerate(futs) if f.failed()]
assert len(failed) == 1, failed
for i, f in enumerate(futs):
    if i in failed:
        try:
            f.result(); raise SystemExit("poisoned future did not re-raise")
        except InjectedFault:
            pass
    else:
        assert np.allclose(np.asarray(f.result()), want[i], rtol=1e-4, atol=1e-5)
m = eng.metrics()
assert m["errors"] == 2 and m["batch_splits"] == 1 and m["quarantined"] == 1
print("PASS poison")

# -- breaker degradation at P=8 ----------------------------------------
clk = FakeClock()
deg = SpectralEngine(mesh, max_batch=1, clock=clk,
                     retry=RetryPolicy(max_retries=0),
                     breaker=CircuitBreaker(failure_threshold=2,
                                            reset_after_s=5.0, clock=clk))
deg.submit("fft", xs[0]).block()
deg.set_faults(FaultPlan.error(match="Exchange", times=2))
for _ in range(2):
    deg.submit("fft", xs[0]); deg.drain()
f3 = deg.submit("fft", xs[0]); deg.drain()
assert f3.degraded and f3.backend == "xla_auto"
assert np.allclose(np.asarray(f3.result()), want[0], rtol=1e-4, atol=1e-5)
dm = deg.metrics()
assert dm["degraded_dispatches"] > 0 and dm["breaker_open"] == 1
clk.advance(6.0)
f4 = deg.submit("fft", xs[0]); deg.drain()
assert not f4.failed() and not f4.degraded
assert deg.breaker.stats()["reclosed"] == 1
print("PASS breaker")

# -- elastic remesh: invalidate + re-warm from wisdom at the new P ------
mesh4 = elastic_mesh(("model",), max_devices=4)
assert mesh4.size == 4
planner.forget_wisdom()
plan_fft((1, n, n), mesh4, planner="measure")  # measured race seeds P=4 wisdom
warmed = eng.remesh(mesh4, wisdom=None, compile=True)
assert warmed >= 1, warmed
assert eng.pool.mesh is mesh4 and eng.mesh is mesh4
assert eng.breaker.stats()["open"] == 0
misses = eng.pool.misses
eng.set_faults(None)
rf = eng.submit("fft", xs[1])
eng.drain()
assert rf.pool_hit and eng.pool.misses == misses  # warm at the new P
assert np.allclose(np.asarray(rf.result()), want[1], rtol=1e-4, atol=1e-5)
print("PASS remesh")
"""

ELASTIC_CODE = r"""
import tempfile
import numpy as np, jax, jax.numpy as jnp
from repro.checkpoint import CheckpointManager
from repro.runtime import (FailureInjector, SimulatedFailure, elastic_mesh,
                           run_with_recovery)
from repro.serve import PlanPool

n = 32
STEPS = 6
FAIL_AT = 3
rng = np.random.default_rng(42)
x0 = (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))
      ).astype(np.complex64)
forcing = [(rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))
           ).astype(np.complex64) for _ in range(STEPS)]

# monolithic alltoall, no pipelining: local FFTs + pure data movement,
# so results are bitwise identical at any P (the fused streaming DFT
# decomposes the sum over source ranks and would break parity)
PLAN_KW = dict(decomp="slab", backend="alltoall", pipeline=False)


def run(ckdir, alive, injector=None):
    ckpt = CheckpointManager(ckdir, keep=5)
    out = {}

    def loop(resume):
        mesh = elastic_mesh(("model",), max_devices=alive["n"])
        pool = PlanPool(mesh, plan_kwargs=PLAN_KW)
        plan, _ = pool.get((n, n), 2, jnp.complex64, False)
        state = jnp.asarray(x0)
        start = 0
        latest, restored = ckpt.restore_latest({"x": state})
        if latest is not None:
            state, start = restored["x"], latest
            out.setdefault("resumed_at", (start, mesh.size))
        for step in range(start, STEPS):
            if injector is not None:
                try:
                    injector.maybe_fail(step)
                except SimulatedFailure:
                    alive["n"] = 4  # the crash takes half the ring with it
                    raise
            spec = plan.execute(state + jnp.asarray(forcing[step]))
            state = plan.inverse(spec) * 0.5
            ckpt.save(step + 1, {"x": state}, blocking=True)
        out["x"] = np.asarray(state)

    out["restarts"] = run_with_recovery(loop, max_restarts=2,
                                        sleep=lambda s: None)
    return out


alive = {"n": 8}
inj = FailureInjector(FAIL_AT)
got = run(tempfile.mkdtemp(), alive, inj)
assert inj.fired_steps == [FAIL_AT] and got["restarts"] == 1
assert got["resumed_at"] == (FAIL_AT, 4)  # resumed mid-run on 4 devices
ref = run(tempfile.mkdtemp(), {"n": 4})   # uninterrupted P=4 run
assert ref["restarts"] == 0 and "resumed_at" not in ref
assert np.array_equal(got["x"], ref["x"]), np.max(np.abs(got["x"] - ref["x"]))
ref8 = run(tempfile.mkdtemp(), {"n": 8})  # P=8 parity too: pure movement
assert np.array_equal(got["x"], ref8["x"])
print("PASS elastic")
"""


def test_serve_chaos_8dev():
    out = run_subprocess(CHAOS_CODE, devices=8, timeout=900)
    assert "PASS poison" in out and "PASS breaker" in out and "PASS remesh" in out


def test_elastic_resume_bitwise_parity_8dev():
    out = run_subprocess(ELASTIC_CODE, devices=8, timeout=900)
    assert "PASS elastic" in out
