"""Pencil-grid numerical sweeps on 8 host devices: pencil fft3/fft2 vs
the numpy oracle on non-square grids (2x4 and 4x2), across
(backend_row, backend_col) pairs of shard_map backends, plus the plan
front-end (decomp="auto" on a 2-D mesh, per-axis predicted costs,
measured planner + wisdom).

The fast test keeps both grids but a rotating pair subset (every
backend exercised in both axis roles; the CI fast job runs it under
XLA_FLAGS=--xla_force_host_platform_device_count=8); the slow test
widens to the full pair matrix, c128, odd batch shapes and
forward+inverse round trips.
"""

import pytest

from conftest import run_subprocess

FAST_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import backends, pencil_fft2, pencil_fft3, PencilConfig, plan_fft, planner
from repro.core.grid import make_grid
from repro.core.compat import make_mesh

rng = np.random.default_rng(0)
def cplx(*s):
    return (rng.standard_normal(s) + 1j * rng.standard_normal(s)).astype(np.complex64)

NAMES = backends.available(kind="shard_map")
def rotating_pairs(pr, pc):
    # every backend appears in both axis roles without the full product
    rows = [n for n in NAMES if backends.get(n).supports(pr)]
    cols = [n for n in NAMES if backends.get(n).supports(pc)]
    k = max(len(rows), len(cols))
    return [(rows[i % len(rows)], cols[(i + 1) % len(cols)]) for i in range(k)]

x3 = cplx(16, 8, 8)
ref3 = np.fft.fftn(x3).transpose(2, 1, 0)  # pencil output: reversed axes
tol = 1e-4 * np.abs(ref3).max()
for pr, pc in ((2, 4), (4, 2)):
    g = make_grid((pr, pc))
    for br, bc in rotating_pairs(pr, pc):
        cfg = PencilConfig(backend_row=br, backend_col=bc)
        y = np.asarray(pencil_fft3(jnp.asarray(x3), g, cfg))
        assert np.abs(y - ref3).max() < tol, (pr, pc, br, bc, np.abs(y - ref3).max())
    print(f"PASS pencil fft3 rotating pairs {pr}x{pc}")

# natural layout via transpose_back + inverse round trip (2x4)
g = make_grid((2, 4))
cfg = PencilConfig(backend_row="scatter", backend_col="bisection", transpose_back=True)
y = np.asarray(pencil_fft3(jnp.asarray(x3), g, cfg))
assert np.abs(y - np.fft.fftn(x3)).max() < tol
fwd = pencil_fft3(jnp.asarray(x3), g, PencilConfig("scatter", "bisection"))
z = np.asarray(pencil_fft3(fwd, g, PencilConfig("scatter", "bisection"), inverse=True))
assert np.abs(z - x3).max() < 1e-4, np.abs(z - x3).max()
print("PASS transpose_back + roundtrip")

# pencil fft2: natural-layout output, odd leading batch dim
x2 = cplx(3, 16, 16)
ref2 = np.fft.fft2(x2)
y = np.asarray(pencil_fft2(jnp.asarray(x2), make_grid((4, 2)),
                           PencilConfig("pairwise_xor", "alltoall")))
assert np.abs(y - ref2).max() < 1e-4 * np.abs(ref2).max()
print("PASS pencil fft2")

# plan front-end: decomp="auto" on a 2-D mesh -> pencil, per-axis costs
mesh = make_mesh((2, 4), ("rows", "cols"))
plan = plan_fft((16, 8, 8), mesh, ndim=3, decomp="auto")
assert plan.decomp == "pencil" and plan.grid.shape == (2, 4)
pred = plan.predict()
rowc, colc = plan.predict_axes()
assert abs(pred[plan.backend] - min(pred.values())) < 1e-15, (plan.backend, pred)
br, bc = plan.backend_row, plan.backend_col
assert pred[plan.backend] == rowc[br] + colc[bc]
assert rowc[br] == min(rowc.values()) and colc[bc] == min(colc.values())
y = np.asarray(plan.execute(jnp.asarray(x3)))
assert np.abs(y - ref3).max() < tol
z = np.asarray(plan.inverse(jnp.asarray(y)))
assert np.abs(z - x3).max() < 1e-4
assert plan.compiles == 2
print("PASS plan auto pencil")

# divisibility rejected at plan time, naming the axis and grid dim
try:
    plan_fft((9, 8, 8), mesh, ndim=3, decomp="pencil")
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "axis -3" in str(e) and "P_row=2" in str(e), e
try:
    plan_fft((16, 8, 9), mesh, ndim=3, decomp="pencil")
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "axis -1" in str(e) and "P_col=4" in str(e), e
try:
    plan_fft((18, 16), make_mesh((8,), ("model",)))  # slab error names the mesh axis
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "axis -2" in str(e) and "'model'" in str(e) and "P=8" in str(e), e
# auto falls back to slab when the shape only slab-divides
flat = plan_fft((16, 4, 4), make_mesh((8, 1), ("model", "data")), ndim=3, decomp="auto")
assert flat.decomp == "slab", flat
# ...and when a degenerate (P,1) grid would just double the exchanges
# over the same ring (cost-aware auto, same parallelism either way) --
# regardless of axis names (regression: fft_axis's last-axis fallback
# made the slab trial shard over the size-1 axis and lose on geometry)
for names in (("model", "data"), ("rows", "cols")):
    deg = plan_fft((64, 64), make_mesh((8, 1), names), decomp="auto")
    assert deg.decomp == "slab" and deg.shards == 8, (names, deg.decomp, deg.shards)
# asymmetric shape: the plan's inverse consumes the reversed-axes
# output by swapping the grid roles (no hidden reshard), so round
# trips work whenever the forward plans -- even when the reversed
# shape would not divide the *unswapped* grid (here 2 % P_col=4)
xa = cplx(2, 8, 8)
asym = plan_fft((2, 8, 8), mesh, ndim=3, decomp="pencil", backend=("scatter", "bisection"))
ya = asym.execute(jnp.asarray(xa))
assert ya.shape == (8, 8, 2)
assert np.abs(np.asarray(ya) - np.fft.fftn(xa).transpose(2, 1, 0)).max() < tol
za = np.asarray(asym.inverse(ya))
assert np.abs(za - xa).max() < 1e-4, np.abs(za - xa).max()
lowered = asym.lower(inverse=True)  # opposite-direction dry run, real layout
assert lowered is not None
print("PASS plan-time divisibility")
"""

SLOW_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)  # honest complex128 paths
from repro.core import backends, pencil_fft2, pencil_fft3, PencilConfig, plan_fft, planner
from repro.core.grid import make_grid
from repro.core.compat import make_mesh

rng = np.random.default_rng(7)
def cplx(shape, dtype=np.complex64):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(dtype)

NAMES = backends.available(kind="shard_map")
def pairs(pr, pc):
    return [(r, c) for r in NAMES if backends.get(r).supports(pr)
            for c in NAMES if backends.get(c).supports(pc)]

# full pair matrix, odd batch shape, forward+inverse round trip (2x4)
x = cplx((3, 16, 8, 8))
ref = np.fft.fftn(x, axes=(-3, -2, -1)).transpose(0, 3, 2, 1)
g = make_grid((2, 4))
for br, bc in pairs(2, 4):
    cfg = PencilConfig(backend_row=br, backend_col=bc)
    y = np.asarray(pencil_fft3(jnp.asarray(x), g, cfg))
    assert np.abs(y - ref).max() < 1e-4 * np.abs(ref).max(), ("fft3", br, bc)
    z = np.asarray(pencil_fft3(jnp.asarray(y), g, cfg, inverse=True))
    assert np.abs(z - x).max() < 1e-4 * np.abs(x).max(), ("fft3 inv", br, bc)
print("PASS full matrix fwd+inv 2x4")

# full pair matrix forward on the transposed grid
g42 = make_grid((4, 2))
for br, bc in pairs(4, 2):
    y = np.asarray(pencil_fft3(jnp.asarray(x), g42, PencilConfig(br, bc)))
    assert np.abs(y - ref).max() < 1e-4 * np.abs(ref).max(), ("fft3 4x2", br, bc)
print("PASS full matrix 4x2")

# complex128 at double-precision tolerance, mixed pairs, both grids
xd = cplx((16, 8, 8), np.complex128)
refd = np.fft.fftn(xd).transpose(2, 1, 0)
for grid, prs in ((g, (2, 4)), (g42, (4, 2))):
    for br, bc in (("scatter", "bisection"), ("alltoall", "pairwise_xor")):
        cfg = PencilConfig(backend_row=br, backend_col=bc)
        y = np.asarray(pencil_fft3(jnp.asarray(xd), grid, cfg))
        assert np.abs(y - refd).max() < 1e-10 * np.abs(refd).max(), ("c128", prs, br, bc)
        z = np.asarray(pencil_fft3(jnp.asarray(y), grid, cfg, inverse=True))
        assert np.abs(z - xd).max() < 1e-10, ("c128 inv", prs, br, bc)
print("PASS c128")

# pencil fft2 fwd+inv, c64 + c128, mixed pairs
for dtype, tol in ((np.complex64, 1e-4), (np.complex128, 1e-10)):
    x2 = cplx((5, 16, 16), dtype)
    ref2 = np.fft.fft2(x2)
    for br, bc in (("scatter", "alltoall"), ("bisection", "pairwise_xor")):
        cfg = PencilConfig(backend_row=br, backend_col=bc)
        y2 = np.asarray(pencil_fft2(jnp.asarray(x2), g, cfg))
        assert np.abs(y2 - ref2).max() < tol * np.abs(ref2).max(), ("fft2", dtype, br, bc)
        z2 = np.asarray(pencil_fft2(jnp.asarray(y2), g, cfg, inverse=True))
        assert np.abs(z2 - x2).max() < tol * np.abs(x2).max(), ("fft2 inv", dtype, br, bc)
print("PASS fft2 matrix")

# measured planner over the full pair field on the real mesh + wisdom hit
planner.forget_wisdom()
mesh = make_mesh((2, 4), ("rows", "cols"))
mp = plan_fft((16, 8, 8), mesh, ndim=3, decomp="pencil", planner="measure")
assert mp.backend in mp.measured
assert mp.measured[mp.backend] == min(mp.measured.values())
# candidate field = every plain pair PLUS the unfused (@u) twin of each
# pair with a streaming member (the (backend, n_chunks, fused) triples)
plain = {f"{r}+{c}" for r, c in pairs(2, 4)}
assert plain <= set(mp.measured), sorted(mp.measured)
extras = set(mp.measured) - plain
assert extras and all(k.endswith("@u") and k[:-2] in plain for k in extras), sorted(extras)
mp2 = plan_fft((16, 8, 8), mesh, ndim=3, decomp="pencil", planner="measure")
assert mp2.wisdom_hit and mp2.backend == mp.backend
print("PASS measured pencil")
"""


def test_pencil_fast_8dev():
    """Kept out of the slow marker on purpose: the CI fast job runs this
    under 8 forced host devices so both 2x4 and 4x2 grids are exercised
    in-tree on every push."""
    out = run_subprocess(FAST_CODE, devices=8)
    assert out.count("PASS") == 6, out


@pytest.mark.slow
def test_pencil_full_matrix_8dev():
    out = run_subprocess(SLOW_CODE, devices=8, timeout=1800)
    assert out.count("PASS") == 5, out
