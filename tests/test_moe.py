"""MoE: router, dispatch-path equivalence (dense / gspmd / ring), aux."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import moe as moe_lib
from conftest import run_subprocess


def _cfg(cf=16.0, dispatch="einsum", experts=8):
    cfg = get_config("deepseek-v3-671b", reduced=True)
    return dataclasses.replace(
        cfg,
        dtype="float32",
        moe=dataclasses.replace(
            cfg.moe, num_experts=experts, capacity_factor=cf, dispatch=dispatch
        ),
    )


def test_router_topk_properties(rng):
    x = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    wr = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    w, idx, aux = moe_lib.router_topk(x, wr, 2)
    assert w.shape == (32, 2) and idx.shape == (32, 2)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert (np.asarray(w) >= 0).all()
    assert float(aux) >= 1.0 - 1e-3  # >= 1 with equality at perfect balance


def test_gspmd_matches_dense_high_capacity(rng):
    cfg_e = _cfg(dispatch="einsum")
    cfg_d = _cfg(dispatch="dense")
    p, _ = moe_lib.init_moe(jax.random.PRNGKey(0), cfg_e)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg_e.d_model)), jnp.float32)
    out_e, aux_e = moe_lib.apply_moe(p, x, cfg_e)
    out_d, aux_d = moe_lib.apply_moe(p, x, cfg_d)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_d), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_e), float(aux_d), rtol=1e-4)


def test_capacity_drops_tokens(rng):
    """At tiny capacity, outputs differ from dense (tokens dropped)."""
    cfg_small = _cfg(cf=0.1, dispatch="einsum")
    cfg_dense = _cfg(dispatch="dense")
    p, _ = moe_lib.init_moe(jax.random.PRNGKey(0), cfg_small)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg_small.d_model)), jnp.float32)
    out_s, _ = moe_lib.apply_moe(p, x, cfg_small)
    out_d, _ = moe_lib.apply_moe(p, x, cfg_dense)
    assert float(jnp.abs(out_s - out_d).max()) > 1e-3


def test_dispatch_indices_capacity_order(rng):
    idx = jnp.asarray([[0], [0], [0], [1]], jnp.int32)  # 3 tokens want expert 0
    order, dest, keep = moe_lib._dispatch_indices(idx, e=2, cap=2)
    # first two expert-0 tokens kept, third dropped
    kept_expert0 = [bool(k) for k, d in zip(np.asarray(keep), np.asarray(dest)) if d < 2]
    assert sum(kept_expert0) == 2
    assert int(np.asarray(keep).sum()) == 3  # 2 for e0 + 1 for e1


RING_CODE = r"""
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.core.compat import make_mesh
from repro.configs import get_config
from repro.models import moe as moe_lib

mesh = make_mesh((1, 4), ("data", "model"))
cfg = get_config("deepseek-v3-671b", reduced=True)
cfg = dataclasses.replace(cfg, dtype="float32",
    moe=dataclasses.replace(cfg.moe, num_experts=8, capacity_factor=8.0, dispatch="ring"))
rng = np.random.default_rng(0)
p, _ = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
out_ring, aux_r = jax.jit(lambda p, x: moe_lib.apply_moe(p, x, cfg, mesh=mesh))(p, x)
cfg_e = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, dispatch="einsum"))
out_ein, aux_e = jax.jit(lambda p, x: moe_lib.apply_moe(p, x, cfg_e, mesh=None))(p, x)
assert np.abs(np.asarray(out_ring) - np.asarray(out_ein)).max() < 1e-3
print("PASS ring matches gspmd")

# interleaved (paper-faithful per-arrival FFN) == batched ring
import repro.models.moe as M
orig = M._ring_exchange_ffn
M._ring_exchange_ffn = lambda *a, **k: orig(*a, **{**k, "interleave": True})
out_int, _ = jax.jit(lambda p, x: moe_lib.apply_moe(p, x, cfg, mesh=mesh))(p, x)
assert np.abs(np.asarray(out_ring) - np.asarray(out_int)).max() < 1e-4
print("PASS interleaved matches batched")

# gradient through the ring island
M._ring_exchange_ffn = orig
def loss(p, x):
    out, aux = moe_lib.apply_moe(p, x, cfg, mesh=mesh)
    return (out.astype(jnp.float32) ** 2).mean()
g = jax.jit(jax.grad(loss))(p, x)
flat = jax.tree.leaves(g)
assert all(np.isfinite(np.asarray(t)).all() for t in flat)
assert any(np.abs(np.asarray(t)).max() > 0 for t in flat)
print("PASS ring gradient finite")
"""


@pytest.mark.slow
def test_ring_dispatch_4dev():
    out = run_subprocess(RING_CODE, devices=4)
    assert out.count("PASS") == 3, out
