"""End-to-end behaviour: training actually optimizes, the full driver
runs (with recovery), microbatching matches single-batch updates."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.data import DataConfig, SyntheticLM
from repro.models import Model
from repro.train import init_train_state, make_train_step


@pytest.mark.slow
def test_training_reduces_loss():
    cfg = get_config("qwen2.5-32b", reduced=True)
    model = Model(cfg, attn_impl="chunked")
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=5, total_steps=80)
    ds = SyntheticLM(DataConfig(cfg.vocab_size, 32, 8, seed=0, noise=0.02))
    state, _ = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    step_fn = jax.jit(make_train_step(model, tcfg, None))
    losses = []
    for s in range(80):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert np.isfinite(losses).all()
    assert last < 0.85 * first, f"loss {first:.3f} -> {last:.3f}"


@pytest.mark.slow
def test_training_ssm_arch_steps():
    cfg = get_config("xlstm-1.3b", reduced=True)
    model = Model(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=3, total_steps=20)
    ds = SyntheticLM(DataConfig(cfg.vocab_size, 32, 4, seed=1))
    state, _ = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    step_fn = jax.jit(make_train_step(model, tcfg, None))
    losses = []
    for s in range(20):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


@pytest.mark.slow
def test_microbatch_equivalent_direction():
    """Grad accumulation must match the single-batch step (same data)."""
    cfg = get_config("nemotron-4-15b", reduced=True)
    import dataclasses

    cfg = dataclasses.replace(cfg, dtype="float32")
    model = Model(cfg)
    ds = SyntheticLM(DataConfig(cfg.vocab_size, 16, 8, seed=2))
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}

    t1 = TrainConfig(learning_rate=1e-3, warmup_steps=0, total_steps=10, microbatch=0)
    t2 = TrainConfig(learning_rate=1e-3, warmup_steps=0, total_steps=10, microbatch=4)
    s1, _ = init_train_state(model, jax.random.PRNGKey(0), t1)
    s2, _ = init_train_state(model, jax.random.PRNGKey(0), t2)
    s1b, m1 = jax.jit(make_train_step(model, t1, None))(s1, batch)
    s2b, m2 = jax.jit(make_train_step(model, t2, None))(s2, batch)
    a = jax.tree.leaves(s1b.params)
    b = jax.tree.leaves(s2b.params)
    worst = max(float(jnp.abs(x - y).max()) for x, y in zip(a, b))
    assert worst < 5e-4, worst


@pytest.mark.slow
def test_train_driver_with_injected_failure(tmp_path):
    from repro.launch.train import build_argparser, train

    args = build_argparser().parse_args(
        [
            "--arch", "phi3-medium-14b", "--reduced", "--steps", "12", "--batch", "4",
            "--seq", "16", "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
            "--fail-at", "6",
        ]
    )
    hist = train(args)
    assert hist["restarts"] == 1
    assert len(hist["loss"]) >= 12
    assert np.isfinite(hist["loss"]).all()
