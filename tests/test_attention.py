"""Attention unit tests: chunked/flash vs naive, masks, GQA, MLA, ragged
decode, flash custom-vjp gradients."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import attention as A
from repro.models.attention import AttnSpec


def _qkv(rng, b, s, h, kvh, d, dtype=np.float32):
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, kvh, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, kvh, d)), dtype)
    return q, k, v


SPECS = [
    AttnSpec(causal=True),
    AttnSpec(causal=True, window=16),
    AttnSpec(causal=True, softcap=30.0),
    AttnSpec(causal=True, window=12, prefix=4),
    AttnSpec(causal=False),
]


@pytest.mark.parametrize("spec", SPECS)
def test_chunked_matches_naive(rng, spec):
    q, k, v = _qkv(rng, 2, 48, 4, 2, 16)
    ref = A.attention_naive(q, k, v, spec)
    got = A.attention_chunked(q, k, v, spec, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("spec", SPECS[:4])
def test_flash_matches_naive_fwd_and_grad(rng, spec):
    q, k, v = _qkv(rng, 1, 64, 4, 4, 8)
    ref = A.attention_naive(q, k, v, spec)
    got = A.flash_attention_train(q, k, v, spec, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5)

    def loss_ref(q, k, v):
        return (A.attention_naive(q, k, v, spec) ** 2).sum()

    def loss_fl(q, k, v):
        return (A.flash_attention_train(q, k, v, spec, kv_chunk=16) ** 2).sum()

    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(loss_fl, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


def test_gqa_group_broadcast(rng):
    """GQA with kvh < h must equal MHA with repeated KV heads."""
    b, s, h, kvh, d = 1, 24, 4, 2, 8
    q, k, v = _qkv(rng, b, s, h, kvh, d)
    spec = AttnSpec(causal=True)
    got = A.attention_naive(q, k, v, spec)
    k_rep = jnp.repeat(k, h // kvh, axis=2)
    v_rep = jnp.repeat(v, h // kvh, axis=2)
    # repeat pattern: groups are contiguous per kv head
    qg = q.reshape(b, s, kvh, h // kvh, d).reshape(b, s, h, d)
    exp = A.attention_naive(qg, k_rep, v_rep, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-4, atol=1e-5)


def test_decode_matches_full(rng):
    cfg = get_config("qwen2.5-32b", reduced=True)
    import dataclasses

    cfg = dataclasses.replace(cfg, dtype="float32")
    key = jax.random.PRNGKey(0)
    p, _ = A.init_attention(key, cfg)
    x = jnp.asarray(rng.standard_normal((2, 12, cfg.d_model)), jnp.float32)
    spec = AttnSpec(causal=True)
    full = A.apply_attention(p, x, cfg, spec, impl="chunked")
    cache = A.init_kv_cache(2, 32, cfg.num_kv_heads, cfg.head_dim_, jnp.float32)
    out_pre, cache = A.prefill_attention(p, x[:, :11], cache, cfg, spec)
    np.testing.assert_allclose(np.asarray(out_pre), np.asarray(full[:, :11]), rtol=2e-3, atol=1e-4)
    step_out, cache = A.decode_attention(p, x[:, 11:12], cache, cfg, spec)
    np.testing.assert_allclose(np.asarray(step_out), np.asarray(full[:, 11:12]), rtol=2e-3, atol=2e-4)
    assert int(cache.length[0]) == 12


def test_ragged_decode_rows(rng):
    """Rows at different cache positions decode like their aligned runs."""
    cfg = get_config("qwen2.5-32b", reduced=True)
    import dataclasses

    cfg = dataclasses.replace(cfg, dtype="float32")
    p, _ = A.init_attention(jax.random.PRNGKey(0), cfg)
    spec = AttnSpec(causal=True)
    x_a = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)), jnp.float32)
    x_b = jnp.asarray(rng.standard_normal((1, 5, cfg.d_model)), jnp.float32)
    # per-row reference: each prompt processed alone
    ca = A.init_kv_cache(1, 32, cfg.num_kv_heads, cfg.head_dim_, jnp.float32)
    _, ca = A.prefill_attention(p, x_a, ca, cfg, spec)
    cb = A.init_kv_cache(1, 32, cfg.num_kv_heads, cfg.head_dim_, jnp.float32)
    _, cb = A.prefill_attention(p, x_b, cb, cfg, spec)
    xa_new = jnp.asarray(rng.standard_normal((1, 1, cfg.d_model)), jnp.float32)
    xb_new = jnp.asarray(rng.standard_normal((1, 1, cfg.d_model)), jnp.float32)
    oa, _ = A.decode_attention(p, xa_new, ca, cfg, spec)
    ob, _ = A.decode_attention(p, xb_new, cb, cfg, spec)
    # batched ragged cache: row0 at len 8, row1 at len 5
    batched = A.KVCache(
        k=jnp.concatenate([ca.k, cb.k], axis=0),
        v=jnp.concatenate([ca.v, cb.v], axis=0),
        length=jnp.asarray([8, 5], jnp.int32),
    )
    x_new = jnp.concatenate([xa_new, xb_new], axis=0)
    out, newc = A.decode_attention(p, x_new, batched, cfg, spec)
    np.testing.assert_allclose(np.asarray(out[0:1]), np.asarray(oa), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out[1:2]), np.asarray(ob), rtol=1e-4, atol=1e-5)
    assert newc.length.tolist() == [9, 6]


def test_mla_prefill_decode_exact(rng):
    import dataclasses

    cfg = get_config("deepseek-v3-671b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    p, _ = A.init_mla(jax.random.PRNGKey(0), cfg)
    spec = AttnSpec(causal=True)
    x = jnp.asarray(rng.standard_normal((2, 10, cfg.d_model)), jnp.float32)
    full = A.apply_mla(p, x, cfg, spec, impl="chunked")
    cache = A.init_mla_cache(2, 32, cfg.mla, jnp.float32)
    out9, cache = A.prefill_mla(p, x[:, :9], cache, cfg, spec)
    np.testing.assert_allclose(np.asarray(out9), np.asarray(full[:, :9]), rtol=2e-3, atol=1e-4)
    dec, cache = A.decode_mla(p, x[:, 9:10], cache, cfg, spec)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, 9:10]), rtol=2e-3, atol=2e-4)


def test_softcap_bounds(rng):
    from repro.models.common import softcap

    x = jnp.asarray(rng.standard_normal((100,)) * 1000, jnp.float32)
    capped = softcap(x, 50.0)
    assert float(jnp.abs(capped).max()) <= 50.0
    small = jnp.asarray([0.1, -0.1])
    np.testing.assert_allclose(np.asarray(softcap(small, 50.0)), np.asarray(small), atol=1e-4)


FLASH_DECODE_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.compat import make_mesh, shard_map
from jax.sharding import PartitionSpec as P
from repro.models import attention as A

mesh = make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
B, S, H, D = 2, 64, 4, 16
q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
ref = A.attention_naive(q, k, v, A.AttnSpec(causal=False))

def shard_fn(q, k, v):
    # per-shard partial online softmax over the local KV slice
    import math
    s = jnp.einsum("bqhd,bkhd->bhqk", q / math.sqrt(D), k)[:, :, 0]  # (B,H,Kloc)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    out = jnp.einsum("bhk,bkhd->bhd", p, v)[:, None].swapaxes(1, 1)  # (B,1?,H,D)
    out = out[:, None, :, :] if out.ndim == 3 else out
    return A.flash_decode_combine(out, m, l, "data")

got = jax.jit(shard_map(
    shard_fn, mesh=mesh,
    in_specs=(P(), P(None, "data"), P(None, "data")),
    out_specs=P(), check_vma=False,
))(q, k, v)
err = float(jnp.abs(got - ref).max())
assert err < 1e-4, err
print("PASS flash_decode_combine", err)
"""


@pytest.mark.slow
def test_flash_decode_combine_seqshard():
    """Distributed decode over sequence-sharded KV: per-shard partial
    softmax + the two-psum combine equals single-device attention."""
    from conftest import run_subprocess

    out = run_subprocess(FLASH_DECODE_CODE, devices=4)
    assert "PASS" in out
