"""Per-arch smoke tests: reduced config, one forward/loss on CPU,
asserting output shapes + no NaNs; prefill/decode consistency."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import _MODULES, get_config
from repro.models import Model

ARCHS = list(_MODULES)


def _batch(cfg, rng, b=2, s=32):
    batch = {}
    if cfg.is_encdec:
        batch["enc_embeds"] = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.bfloat16)
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s // 4)), jnp.int32)
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s // 4)), jnp.int32)
    elif cfg.input_kind == "embeddings":
        batch["embeds"] = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.bfloat16)
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(arch, rng):
    cfg = get_config(arch, reduced=True)
    model = Model(cfg, attn_impl="chunked")
    params, specs = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss), metrics
    assert float(loss) > 0
    # specs tree mirrors params
    assert set(specs.keys()) == set(params.keys())


@pytest.mark.parametrize("arch", ARCHS)
def test_hidden_shapes(arch, rng):
    cfg = get_config(arch, reduced=True)
    model = Model(cfg, attn_impl="chunked")
    params, _ = model.init(jax.random.PRNGKey(1))
    b, s = 2, 32
    batch = _batch(cfg, rng, b, s)
    h, aux = jax.jit(model.hidden)(params, batch)
    expect_s = (s // 4) if cfg.is_encdec else s
    assert h.shape == (b, expect_s, cfg.d_model)
    assert jnp.isfinite(h.astype(jnp.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_grads_finite(arch, rng):
    cfg = get_config(arch, reduced=True)
    model = Model(cfg, attn_impl="chunked")
    params, _ = model.init(jax.random.PRNGKey(2))
    batch = _batch(cfg, rng)
    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    flat = jax.tree.leaves(grads)
    assert all(jnp.isfinite(g.astype(jnp.float32)).all() for g in flat)
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, rng):
    cfg = get_config(arch, reduced=True)
    # f32: bf16 legitimately reassociates (absorbed-MLA decode), f32 is exact
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:  # kill capacity-drop artifacts for equivalence
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    model = Model(cfg, attn_impl="chunked")
    params, _ = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    if cfg.input_kind == "embeddings" and not cfg.is_encdec:
        batch = {"embeds": jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)}
    elif cfg.is_encdec:
        batch = {
            "enc_embeds": jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s // 4)), jnp.int32),
        }
    else:
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}

    full = model.logits(params, batch)[:, -1]
    state = model.init_decode_state(b, 64, cache_dtype=jnp.float32)
    state, pl = model.prefill(params, batch, state)
    scale = float(jnp.abs(full).max()) + 1e-9
    assert float(jnp.abs(pl - full).max()) / scale < 2e-2

    nxt = jnp.argmax(pl, -1).astype(jnp.int32)[:, None]
    lg, state = model.decode_step(params, nxt, state)
    if "tokens" in batch:
        ext = dict(batch)
        ext["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
        ref = model.logits(params, ext)[:, -1]
        assert float(jnp.abs(lg - ref).max()) / (float(jnp.abs(ref).max()) + 1e-9) < 3e-2
    assert jnp.isfinite(lg).all()
