"""Checkpointing: atomic roundtrip, keep-N, failure recovery, resume
bit-consistency (the fault-tolerance contract)."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig, get_config
from repro.data import DataConfig, SyntheticLM
from repro.models import Model
from repro.runtime import FailureInjector, SimulatedFailure, run_with_recovery
from repro.train import init_train_state, make_train_step


def _tree(rng):
    return {
        "a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 10, (3,)), jnp.int32)},
    }


def test_roundtrip_bitwise(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree(rng)
    mgr.save(10, t, blocking=True)
    restored = mgr.restore(10, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_n_and_latest(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree(rng)
    for s in (1, 2, 3, 4):
        mgr.save(s, t, blocking=True)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_no_tmp_left_behind(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(rng), blocking=True)
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_shape_mismatch_raises(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.zeros((4,))}, blocking=True)
    with pytest.raises(ValueError):
        mgr.restore(1, {"a": jnp.zeros((5,))})


def _mini_train(tmp_path, fail_at=None, steps=8):
    """Deterministic mini-run with optional injected failure; returns the
    final params and the loss history."""
    cfg = get_config("phi3-medium-14b", reduced=True)
    model = Model(cfg, attn_impl="chunked")
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=steps, checkpoint_every=2)
    ds = SyntheticLM(DataConfig(cfg.vocab_size, 16, 4, seed=0))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    injector = FailureInjector(fail_at)
    losses = {}
    final = {}

    def loop(resume):
        state, _ = init_train_state(model, jax.random.PRNGKey(0), tcfg)
        start = 0
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(latest, state)
            start = latest
        step_fn = jax.jit(make_train_step(model, tcfg, None))
        for s in range(start, steps):
            injector.maybe_fail(s)
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
            state, m = step_fn(state, batch)
            losses[s] = float(m["loss"])
            if (s + 1) % tcfg.checkpoint_every == 0:
                mgr.save(s + 1, state, blocking=True)
        final["params"] = state.params

    restarts = run_with_recovery(loop, max_restarts=2)
    return final["params"], losses, restarts


@pytest.mark.slow
def test_failure_recovery_bit_consistent(tmp_path):
    p_clean, losses_clean, r0 = _mini_train(tmp_path / "clean", fail_at=None)
    p_fail, losses_fail, r1 = _mini_train(tmp_path / "fail", fail_at=5)
    assert r0 == 0 and r1 == 1
    # resumed run must produce identical final params (checkpoint at 4,
    # data = pure fn of step, init deterministic)
    for a, b in zip(jax.tree.leaves(p_clean), jax.tree.leaves(p_fail)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # post-resume losses match the uninterrupted run exactly
    for s in range(4, 8):
        assert abs(losses_clean[s] - losses_fail[s]) < 1e-6


def test_unrecoverable_after_max_restarts(tmp_path):
    injector = FailureInjector(0)

    def loop(resume):
        raise SimulatedFailure("always")

    with pytest.raises(SimulatedFailure):
        run_with_recovery(loop, max_restarts=2)
