"""Collective backend registry: contents, cost-model unification,
auto-selection rule, error behaviour. Single-device/host-process tests
(the multi-device oracle sweeps live in test_fft_distributed.py)."""

import numpy as np
import pytest

from repro.core import backends, comm_model


PAPER_STRATEGIES = {"alltoall", "scatter", "bisection", "xla_auto"}


def test_registry_contains_all_strategies():
    names = set(backends.available())
    assert PAPER_STRATEGIES <= names
    assert "pairwise_xor" in names  # beyond-paper addition
    assert tuple(sorted(names)) == backends.available()  # sorted, stable


def test_unknown_backend_lists_registry():
    with pytest.raises(ValueError) as ei:
        backends.get("lci")
    msg = str(ei.value)
    for name in backends.available():
        assert name in msg


def test_duplicate_registration_rejected():
    class Dup(backends.CollectiveBackend):
        name = "alltoall"

    with pytest.raises(ValueError, match="already registered"):
        backends.register(Dup)


def test_cost_delegates_to_comm_model():
    """The backend cost methods ARE the napkin model -- no drift."""
    m, p = 8 * 2**20, 16
    assert backends.get("alltoall").cost(m, p) == comm_model.t_alltoall(m, p)
    assert backends.get("scatter").cost(m, p) == comm_model.t_scatter_ring(m, p)
    assert backends.get("bisection").cost(m, p) == comm_model.t_bisection(m, p)
    assert backends.get("pairwise_xor").cost(m, p) == comm_model.t_pairwise(m, p)
    assert backends.get("xla_auto").cost(m, p) == comm_model.t_alltoall(m, p)


def test_cheapest_is_cost_argmin():
    """auto selection == argmin over the SAME set predict() ranks (every
    registered backend supporting p)."""
    m, p = 4 * 2**20, 8
    pick = backends.cheapest(m, p)
    costs = {
        n: backends.get(n).cost(m, p)
        for n in backends.available()
        if backends.get(n).supports(p)
    }
    assert costs[pick] == min(costs.values())


def test_pairwise_cost_charges_chunk_compute():
    """Streaming backends must thread chunk compute through their model
    (regression: pairwise ignored exposed per-chunk compute)."""
    m, p = 1 * 2**20, 8
    prm = comm_model.CommParams()
    per_chunk = prm.alpha_s + (m / p) / prm.beta_bytes_s
    heavy = 10 * per_chunk
    assert backends.get("pairwise_xor").cost(m, p, prm, heavy) == backends.get(
        "scatter"
    ).cost(m, p, prm, heavy)
    assert backends.get("pairwise_xor").cost(m, p, prm, heavy) > backends.get(
        "pairwise_xor"
    ).cost(m, p, prm) + heavy
    # same per-chunk units everywhere: monolithic backends serialize all
    # p chunk computes, so the streaming overlap must win under heavy
    # chunk compute -- exactly the paper's motivation for N-scatter
    assert backends.get("scatter").cost(m, p, prm, heavy) < backends.get(
        "alltoall"
    ).cost(m, p, prm, heavy)
    assert backends.cheapest(m, p, prm, chunk_compute_s=heavy) in ("scatter", "pairwise_xor")


def test_pairwise_xor_power_of_two_only():
    b = backends.get("pairwise_xor")
    assert b.supports(1) and b.supports(2) and b.supports(8)
    assert not b.supports(3) and not b.supports(6)
    # non-power-of-two P: excluded from auto selection, not an error
    assert backends.cheapest(1024, 6) in backends.available()


def test_global_backend_has_no_transpose():
    with pytest.raises(NotImplementedError):
        backends.get("xla_auto").transpose(None, "model")


def test_scatter_exposed_compute_charged():
    """Chunk compute beyond per-chunk comm must surface in the model
    (regression: the exposed term was multiplied by zero)."""
    m, p = 1 * 2**20, 8
    prm = comm_model.CommParams()
    per_chunk = prm.alpha_s + (m / p) / prm.beta_bytes_s
    heavy = 10 * per_chunk
    t = comm_model.t_scatter_ring(m, p, prm, chunk_compute_s=heavy)
    base = comm_model.t_scatter_ring(m, p, prm)
    # every step exposes (heavy - per_chunk); the last chunk adds heavy
    expect = base + heavy + (heavy - per_chunk) * (p - 1)
    assert abs(t - expect) < 1e-15
    # fully-hidden regime: only the trailing chunk compute is charged
    light = 0.5 * per_chunk
    assert abs(comm_model.t_scatter_ring(m, p, prm, light) - (base + light)) < 1e-15


def test_pairwise_model_matches_ring_bytes():
    """Pairwise ships the same bytes as the ring (P-1 rounds of M/P)."""
    m, p = 2 * 2**20, 8
    assert comm_model.t_pairwise(m, p) == comm_model.t_scatter_ring(m, p)
    assert comm_model.t_pairwise(m, 1) == 0.0


def test_parse_collectives_permute_counted_point_to_point():
    """collective-permute is point-to-point: full result size, no ring
    factor (regression for the removed unreachable factor branch)."""
    fake = """
HloModule t, is_scheduled=true

ENTRY %main (p: f32[16,4]) -> f32[16,4] {
  %p = f32[16,4]{1,0} parameter(0)
  ROOT %cp = f32[16,4]{1,0} collective-permute(%p), source_target_pairs={{0,1},{1,0}}
}
"""
    stats = comm_model.parse_collectives(fake)
    assert stats.counts["collective-permute"] == 1
    assert stats.bytes_moved["collective-permute"] == 16 * 4 * 4


def test_plan_comm_bytes_dtype_aware():
    import jax.numpy as jnp

    from repro.core import plan_fft
    from repro.core.compat import make_mesh_1d

    mesh = make_mesh_1d(1)
    plan64 = plan_fft((32, 32), mesh, backend="alltoall")
    plan128 = plan_fft((32, 32), mesh, backend="alltoall", dtype=jnp.complex128)
    assert plan128.local_bytes() == 2 * plan64.local_bytes()
    # P=1: nothing crosses the fabric
    assert plan64.comm_bytes() == 0.0
    # the override argument wins over the planned dtype
    assert plan64.local_bytes(jnp.complex128) == plan128.local_bytes()


def test_plan_validates_once_and_rejects():
    from repro.core import plan_fft
    from repro.core.compat import make_mesh_1d

    mesh = make_mesh_1d(1)
    with pytest.raises(ValueError, match="registered backends"):
        plan_fft((32, 32), mesh, backend="tcp")
    with pytest.raises(ValueError, match="ndim"):
        plan_fft((32, 32), mesh, ndim=4)
    with pytest.raises(ValueError, match="direction"):
        plan_fft((32, 32), mesh, direction="sideways")
    with pytest.raises(ValueError, match="fuse_dft"):
        plan_fft((32, 32), mesh, backend="bisection", fuse_dft=True)
    # unexecutable combination must fail at plan time, not first execute
    with pytest.raises(NotImplementedError, match="1-D large inverse"):
        plan_fft((4096,), mesh, ndim=1, direction="inverse")


def test_make_plan_deprecated_but_working():
    import jax.numpy as jnp

    from repro.core import make_plan
    from repro.core.compat import make_mesh_1d

    mesh = make_mesh_1d(1)
    with pytest.warns(DeprecationWarning):
        plan = make_plan((16, 16), mesh, strategy="alltoall")
    x = jnp.asarray(np.random.default_rng(0).standard_normal((16, 16)), jnp.complex64)
    y = np.asarray(plan.execute(x))
    assert np.abs(y - np.fft.fft2(np.asarray(x)).T).max() < 1e-3
    assert plan.comm_bytes() == 0.0  # P=1
