"""Spectral serving engine: coalesced == sequential across decompositions
and transform kinds, LRU plan eviction, admission max-wait with an
injected clock, power-of-two bucket padding, warm start from wisdom.

Queue/pool/admission mechanics run in-process on a P=1 mesh (plan
correctness there is covered by the distributed suites); the
end-to-end numerical equivalences run in an 8-device subprocess."""

import numpy as np
import pytest

from conftest import run_subprocess

jax = pytest.importorskip("jax")
jnp = jax.numpy

from repro.core.compat import make_mesh  # noqa: E402
from repro.serve import (  # noqa: E402
    Admission,
    CoalescingQueue,
    PendingQueue,
    PlanPool,
    SpectralEngine,
    plan_key,
)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture
def mesh1():
    return make_mesh((1,), ("model",))


# ------------------------------------------------------------ queue units
class TestPendingQueue:
    def test_fifo_order(self):
        q = PendingQueue([1, 2])
        q.push(3)
        assert len(q) == 3 and q.peek() == 1
        assert [q.pop(), q.pop(), q.pop()] == [1, 2, 3]
        assert not q

    def test_pop_empty_raises(self):
        with pytest.raises(IndexError):
            PendingQueue().pop()


class TestCoalescingQueue:
    def test_full_batch_ready_immediately(self):
        clk = FakeClock()
        q = CoalescingQueue(Admission(max_batch=2, max_wait_s=10.0), clock=clk)
        q.push("k", "a")
        assert q.ready() == []  # partial, deadline far away
        q.push("k", "b")
        assert q.ready() == [("k", ["a", "b"])]
        assert q.depth() == 0

    def test_partial_flushes_only_after_max_wait(self):
        clk = FakeClock()
        q = CoalescingQueue(Admission(max_batch=4, max_wait_s=1.0), clock=clk)
        q.push("k", "a")
        clk.advance(0.5)
        assert q.ready() == []
        assert q.next_deadline() == pytest.approx(1.0)
        clk.advance(0.5)
        assert q.ready() == [("k", ["a"])]

    def test_keys_do_not_mix(self):
        clk = FakeClock()
        q = CoalescingQueue(Admission(max_batch=2, max_wait_s=0.0), clock=clk)
        q.push("k1", "a")
        q.push("k2", "b")
        assert sorted(q.ready()) == [("k1", ["a"]), ("k2", ["b"])]

    def test_coalesce_off_batches_of_one(self):
        clk = FakeClock()
        q = CoalescingQueue(
            Admission(max_batch=8, max_wait_s=10.0), coalesce=False, clock=clk
        )
        for v in "abc":
            q.push("k", v)
        assert q.ready() == [("k", ["a"]), ("k", ["b"]), ("k", ["c"])]

    def test_flush_chunks_at_max_batch(self):
        q = CoalescingQueue(Admission(max_batch=2, max_wait_s=10.0), clock=FakeClock())
        for v in "abcde":
            q.push("k", v)
        # 4 popped inline as full batches would need ready(); flush pops all
        assert q.flush() == [("k", ["a", "b"]), ("k", ["c", "d"]), ("k", ["e"])]

    def test_bad_admission(self):
        with pytest.raises(ValueError):
            Admission(max_batch=0)
        with pytest.raises(ValueError):
            Admission(max_wait_s=-1.0)


# -------------------------------------------------------------- plan pool
class TestPlanPool:
    def test_lru_eviction(self, mesh1):
        pool = PlanPool(mesh1, capacity=2)
        k16 = pool.key((1, 16, 16), 2, jnp.complex64, False)
        pool.get((1, 16, 16), 2, jnp.complex64, False)
        pool.get((1, 8, 8), 2, jnp.complex64, False)
        pool.get((1, 16, 16), 2, jnp.complex64, False)  # refresh 16 -> MRU
        pool.get((1, 4, 4), 2, jnp.complex64, False)  # evicts the 8x8 plan
        assert pool.evictions == 1
        assert len(pool) == 2
        assert k16 in pool
        assert pool.key((1, 8, 8), 2, jnp.complex64, False) not in pool
        # re-requesting the evicted shape re-plans (a miss, not an error)
        misses = pool.misses
        pool.get((1, 8, 8), 2, jnp.complex64, False)
        assert pool.misses == misses + 1

    def test_hit_vs_miss_counters(self, mesh1):
        pool = PlanPool(mesh1)
        _, hit = pool.get((1, 8, 8), 2, jnp.complex64, False)
        assert not hit and pool.misses == 1 and pool.plan_seconds > 0
        _, hit = pool.get((1, 8, 8), 2, jnp.complex64, False)
        assert hit and pool.hits == 1

    def test_key_separates_real_and_dtype(self, mesh1):
        pool = PlanPool(mesh1)
        a = pool.key((1, 8, 8), 2, jnp.complex64, False)
        b = pool.key((1, 8, 8), 2, jnp.complex64, True)
        c = pool.key((1, 8, 8), 2, jnp.complex128, False)
        assert len({a, b, c}) == 3
        assert plan_key((1, 8, 8), 2, jnp.complex64, 1, "slab", False) == a

    def test_capacity_validates(self, mesh1):
        with pytest.raises(ValueError):
            PlanPool(mesh1, capacity=0)


# ----------------------------------------------------- engine (in-process)
class TestEngineAdmission:
    def test_full_batch_dispatches_inline(self, mesh1):
        eng = SpectralEngine(mesh1, max_batch=2, max_wait_s=100.0, clock=FakeClock())
        x = np.ones((8, 8), np.complex64)
        f1 = eng.submit("fft", x)
        assert not f1.done()  # partial batch queued
        f2 = eng.submit("fft", x)
        assert f1.done() and f2.done()  # completing the batch dispatched it
        assert f1.batch_size == 2 and eng.batches == 1

    def test_max_wait_flush_via_poll(self, mesh1):
        clk = FakeClock()
        eng = SpectralEngine(mesh1, max_batch=8, max_wait_s=1.0, clock=clk)
        fut = eng.submit("fft", np.ones((8, 8), np.complex64))
        assert eng.poll() == 0  # before the deadline: stays queued
        clk.advance(1.5)
        assert eng.poll() == 1
        assert fut.done() and fut.batch_size == 1

    def test_result_forces_dispatch_without_sleeping(self, mesh1):
        clk = FakeClock()
        eng = SpectralEngine(mesh1, max_batch=8, max_wait_s=50.0, clock=clk)
        fut = eng.submit("fft", np.ones((8, 8), np.complex64))
        out = fut.result()  # jumps the clock to the admission deadline
        assert out.shape == (8, 8)
        assert clk.t < 100.0  # no real sleeping involved

    def test_bucket_padding_pow2(self, mesh1):
        eng = SpectralEngine(mesh1, max_batch=8, max_wait_s=100.0, clock=FakeClock())
        x = np.random.default_rng(0).standard_normal((8, 8)).astype(np.complex64)
        futs = [eng.submit("fft", x) for _ in range(3)]
        eng.flush()
        assert all(f.batch_size == 3 for f in futs)
        assert eng.padded == 1  # 3 -> bucket 4
        # the pooled plan is the bucket-4 plan
        assert eng.pool.key((4, 8, 8), 2, jnp.complex64, False) in eng.pool

    def test_coalesce_off_is_solo(self, mesh1):
        eng = SpectralEngine(
            mesh1, max_batch=8, max_wait_s=0.0, coalesce=False, clock=FakeClock()
        )
        x = np.ones((8, 8), np.complex64)
        for _ in range(4):
            eng.submit("fft", x)
        eng.flush()
        s = eng.stats()
        assert s["batches"] == 4 and s["mean_batch"] == 1.0 and s["padded"] == 0

    def test_distinct_shapes_never_coalesce(self, mesh1):
        eng = SpectralEngine(mesh1, max_batch=8, max_wait_s=0.0, clock=FakeClock())
        eng.submit("fft", np.ones((8, 8), np.complex64))
        eng.submit("fft", np.ones((16, 16), np.complex64))
        eng.flush()
        assert eng.batches == 2

    def test_drain_blocks_everything(self, mesh1):
        eng = SpectralEngine(mesh1, max_batch=8, max_wait_s=100.0, clock=FakeClock())
        futs = [eng.submit("fft", np.ones((8, 8), np.complex64)) for _ in range(3)]
        eng.drain()
        assert all(f.done() for f in futs)
        assert eng.stats()["completed"] == 3
        assert not eng._outstanding

    def test_submit_validation(self, mesh1):
        eng = SpectralEngine(mesh1, clock=FakeClock())
        with pytest.raises(ValueError, match="unknown op"):
            eng.submit("dct", np.ones((8, 8), np.complex64))
        with pytest.raises(ValueError, match="real input"):
            eng.submit("rfft", np.ones((8, 8), np.complex64))
        with pytest.raises(ValueError, match="complex"):
            eng.submit("ifft", np.ones((8, 8), np.float32))
        with pytest.raises(ValueError, match="two operands"):
            eng.submit("convolve", np.ones((8, 8), np.float32))
        with pytest.raises(ValueError, match="must match"):
            eng.submit(
                "convolve",
                np.ones((8, 8), np.float32),
                np.ones((4, 4), np.float32),
            )
        with pytest.raises(ValueError, match="ndim"):
            eng.submit("fft", np.ones((8,), np.complex64), ndim=1)

    def test_reset_stats_keeps_pool(self, mesh1):
        eng = SpectralEngine(mesh1, max_batch=2, max_wait_s=0.0, clock=FakeClock())
        eng.submit("fft", np.ones((8, 8), np.complex64))
        eng.drain()
        eng.reset_stats()
        s = eng.stats()
        assert s["requests"] == 0 and s["batches"] == 0
        assert s["pool"]["plans"] == 1  # warm plans survive the reset


class TestWarmStart:
    def test_warm_from_wisdom_in_process(self, mesh1, tmp_path):
        from repro.core import planner
        from repro.core.plan import plan_fft

        planner.forget_wisdom()
        try:
            # measure with a fake timer (no real racing), batched shape
            plan_fft((2, 16, 16), mesh1, planner="measure", timer=lambda p: 1.0)
            path = str(tmp_path / "wisdom.json")
            planner.export_wisdom(path)
            planner.forget_wisdom()
            eng = SpectralEngine(
                mesh1, max_batch=4, max_wait_s=0.0, wisdom=path,
                warm_compile=False, clock=FakeClock(),
            )
            # the (2, n, n) entry warmed the whole bucket ladder 1|2|4
            assert len(eng.pool) == 3
            for b in (1, 2, 4):
                assert eng.pool.key((b, 16, 16), 2, jnp.complex64, False) in eng.pool
            # a request for that shape never plans
            fut = eng.submit("fft", np.ones((16, 16), np.complex64))
            eng.flush()
            assert fut.pool_hit and eng.pool.misses == 0
        finally:
            planner.forget_wisdom()

    def test_foreign_wisdom_skipped(self, mesh1, tmp_path):
        import json

        from repro.core import planner

        path = tmp_path / "wisdom.json"
        path.write_text(json.dumps({"wisdom": {"v1|garbage": {"backend": "x"}}}))
        planner.forget_wisdom()
        try:
            eng = SpectralEngine(mesh1, wisdom=str(path), clock=FakeClock())
            assert len(eng.pool) == 0  # unparseable entries are skipped
        finally:
            planner.forget_wisdom()


# ------------------------------------------------- 8-device end-to-end
FAST_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import plan_fft, planner
from repro.core.compat import make_mesh
from repro.serve import SpectralEngine

mesh = make_mesh((8,), ("model",))
rng = np.random.default_rng(7)
n = 32

def check(tag, got, want, tol=1e-4):
    got = np.asarray(got); want = np.asarray(want)
    assert got.shape == want.shape, (tag, got.shape, want.shape)
    err = np.max(np.abs(got - want)) / max(np.max(np.abs(want)), 1e-30)
    assert err < tol, (tag, err)
    print("PASS", tag)

# -- coalesced == sequential, slab c2c ---------------------------------
xs = [(rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))
       ).astype(np.complex64) for _ in range(5)]
co = SpectralEngine(mesh, max_batch=8, max_wait_s=100.0)
futs = [co.submit("fft", x) for x in xs]
co.flush()
solo = SpectralEngine(mesh, max_batch=8, coalesce=False, max_wait_s=0.0)
sfuts = [solo.submit("fft", x) for x in xs]
solo.flush()
assert all(f.batch_size == 5 for f in futs)
assert all(f.batch_size == 1 for f in sfuts)
for i, (f, s) in enumerate(zip(futs, sfuts)):
    check(f"slab_c2c_{i}", f.block(), s.block())
# against the plan front-end directly
ref = plan_fft((1, n, n), mesh)
for i, f in enumerate(futs):
    want = ref.execute(jnp.asarray(xs[i])[None])[0]
    check(f"slab_vs_plan_{i}", f.result(), want)

# coalesced forward -> coalesced inverse round-trips (spectrum layout)
inv = [co.submit("ifft", f.result()) for f in futs]
co.flush()
for i, fi in enumerate(inv):
    check(f"slab_roundtrip_{i}", fi.block(), xs[i])

# -- r2c (rfft requests, real inputs, Hermitian payload) ---------------
rs = [rng.standard_normal((n, n)).astype(np.float32) for _ in range(3)]
rf = [co.submit("rfft", r) for r in rs]
co.flush()
srf = [solo.submit("rfft", r) for r in rs]
solo.flush()
for i, (f, s) in enumerate(zip(rf, srf)):
    check(f"slab_r2c_{i}", f.block(), s.block())
assert rf[0].batch_size == 3 and srf[0].batch_size == 1

# -- pencil decomposition ----------------------------------------------
pmesh = make_mesh((2, 4), ("rows", "cols"))
ys = [(rng.standard_normal((4, n, n)) + 1j * rng.standard_normal((4, n, n))
      ).astype(np.complex64) for _ in range(3)]
pco = SpectralEngine(pmesh, max_batch=4, max_wait_s=100.0,
                     plan_kwargs={"decomp": "pencil"})
pfuts = [pco.submit("fft", y, ndim=3) for y in ys]
pco.flush()
psolo = SpectralEngine(pmesh, max_batch=4, coalesce=False, max_wait_s=0.0,
                       plan_kwargs={"decomp": "pencil"})
psfuts = [psolo.submit("fft", y, ndim=3) for y in ys]
psolo.flush()
for i, (f, s) in enumerate(zip(pfuts, psfuts)):
    check(f"pencil_c2c_{i}", f.block(), s.block())

# -- mixed ops coalesce per-key, poisson correctness -------------------
from repro.apps import poisson as P
k = 2 * np.pi
xg = np.linspace(0, 1, n, endpoint=False)
f2 = np.sin(k * xg)[:, None] * np.cos(k * xg)[None, :]
rhs = (-2 * k * k * f2).astype(np.float32)
mixed = SpectralEngine(mesh, max_batch=8, max_wait_s=100.0)
pf = mixed.submit("poisson", rhs, lengths=(1.0, 1.0))
gf = mixed.submit("rfft", rs[0])
pf2 = mixed.submit("poisson", rhs, lengths=(1.0, 1.0))
mixed.flush()
assert pf.batch_size == 2 and gf.batch_size == 1  # per-key coalescing
got = np.array(pf.block())  # copy: jax outputs view as read-only
got -= got.mean()
check("poisson", got, f2 - f2.mean(), tol=1e-3)
check("poisson_pair", pf2.block(), pf.result())

# -- async dispatch: submission does not block -------------------------
a = SpectralEngine(mesh, max_batch=1)
t_fut = a.submit("fft", xs[0])
assert t_fut.done()  # max_batch=1: dispatched inline, not blocked on
check("async_value", t_fut.block(), sfuts[0].result())
print("PASS all")
"""

WARM_CODE = r"""
import os, tempfile
import numpy as np, jax.numpy as jnp
from repro.core import plan_fft, planner
from repro.core.compat import make_mesh
from repro.serve import SpectralEngine

mesh = make_mesh((8,), ("model",))
n = 32
x = (np.random.default_rng(7).standard_normal((n, n))
     + 1j * np.random.default_rng(8).standard_normal((n, n))).astype(np.complex64)
want = np.asarray(plan_fft((1, n, n), mesh).execute(jnp.asarray(x)[None])[0])

# measure (real racing at P=8) -> export -> warm a fresh engine
planner.forget_wisdom()
plan_fft((2, n, n), mesh, planner="measure")
wpath = os.path.join(tempfile.mkdtemp(), "w.json")
planner.export_wisdom(wpath)
planner.forget_wisdom()
warm = SpectralEngine(mesh, max_batch=4, max_wait_s=0.0, wisdom=wpath)
assert len(warm.pool) == 3, warm.pool.keys()  # bucket ladder 1|2|4
wf = warm.submit("fft", x)
warm.flush()
assert wf.pool_hit and warm.pool.misses == 0  # no plan_fft in the path
err = np.max(np.abs(np.asarray(wf.block()) - want))
assert err < 1e-4 * np.max(np.abs(want)), err
print("PASS warm")
"""


def test_spectral_serving_8dev():
    out = run_subprocess(FAST_CODE, devices=8, timeout=900)
    assert "PASS all" in out


@pytest.mark.slow
def test_spectral_warm_start_measured_8dev():
    out = run_subprocess(WARM_CODE, devices=8, timeout=900)
    assert "PASS warm" in out
