"""HLO analyzer: loop-aware flops/bytes/collectives vs analytic counts."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import comm_model, hlo_analysis
from conftest import run_subprocess


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    comp = jax.jit(lambda a, b: (a @ b).sum()).lower(a, b).compile()
    c = hlo_analysis.analyze_compiled(comp)
    assert abs(c.flops - 2 * 128 * 256 * 512) / (2 * 128 * 256 * 512) < 0.01


def test_while_trip_count_multiplies():
    L, B, D = 7, 8, 32

    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None

        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
    comp = jax.jit(f).lower(ws, xs).compile()
    c = hlo_analysis.analyze_compiled(comp)
    expect = 2 * B * D * D * L
    assert abs(c.flops - expect) / expect < 0.05
    # XLA's own analysis counts the body once -> must be ~L x smaller
    ca = comp.cost_analysis()
    ca = ca if isinstance(ca, dict) else ca[0]
    assert c.flops > 3 * float(ca.get("flops", 0))


def test_nested_scan():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ ci), None

            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None

        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out.sum()

    xs = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    comp = jax.jit(f).lower(xs).compile()
    c = hlo_analysis.analyze_compiled(comp)
    expect = 2 * 16 * 16 * 16 * 15  # 5*3 nested trips
    assert abs(c.flops - expect) / expect < 0.05


def test_roofline_terms_and_bottleneck():
    r = comm_model.Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=0.0, chips=4)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert r.t_collective == 0.0
    assert r.bottleneck in ("compute", "memory")
    r2 = comm_model.Roofline(flops=1, hbm_bytes=1, coll_bytes=1e12, chips=4)
    assert r2.bottleneck == "collective"


def test_alpha_beta_models():
    p = 16
    m = 8 * 2**20
    t_a2a = comm_model.t_alltoall(m, p)
    t_ring = comm_model.t_scatter_ring(m, p)
    t_bis = comm_model.t_bisection(m, p)
    assert t_a2a > 0 and t_ring > 0 and t_bis > 0
    # small messages: latency dominates -> bisection (log P msgs) wins ring (P-1)
    tiny = 512
    assert comm_model.t_bisection(tiny, p) < comm_model.t_scatter_ring(tiny, p)


def test_collective_parse_text():
    fake = """
HloModule test, is_scheduled=true

ENTRY %main (p: f32[64,64]) -> f32[64,64] {
  %p = f32[64,64]{1,0} parameter(0)
  %ag = f32[64,64]{1,0} all-gather(%p), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %ar = f32[64,64]{1,0} all-reduce(%ag), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    stats = comm_model.parse_collectives(fake)
    assert stats.counts["all-gather"] == 1
    assert stats.counts["all-reduce"] == 1
    sz = 64 * 64 * 4
    assert abs(stats.bytes_moved["all-gather"] - sz * 3 / 4) < 1
    assert abs(stats.bytes_moved["all-reduce"] - sz * 2 * 3 / 4) < 1


ASYNC_CP_HLO = """
HloModule t, is_scheduled=true

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  %cps = (f32[1024]{0}, f32[1024]{0}, u32[], u32[]) collective-permute-start(%p), source_target_pairs={{0,1},{1,0}}
  ROOT %cpd = f32[1024]{0} collective-permute-done(%cps)
}
"""

ASYNC_AG_HLO = """
HloModule t, is_scheduled=true

ENTRY %main (p: f32[8,4]) -> f32[32,4] {
  %p = f32[8,4]{1,0} parameter(0)
  %ags = (f32[8,4]{1,0}, f32[32,4]{1,0}) all-gather-start(%p), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %agd = f32[32,4]{1,0} all-gather-done(%ags)
}
"""


def test_async_collective_permute_both_parsers_agree():
    """Regression: tuple-typed -start results. parse_collectives counted
    0 bytes (empty head before '('); hlo_analysis summed the whole tuple
    (operand alias + u32 contexts = 8200). Both must count exactly the
    4096-byte receive buffer, once, with the -done contributing nothing."""
    stats = comm_model.parse_collectives(ASYNC_CP_HLO)
    cost = hlo_analysis.HloAnalyzer(ASYNC_CP_HLO).entry_cost()
    assert stats.counts["collective-permute"] == 1
    assert stats.bytes_moved["collective-permute"] == 4096
    assert cost.coll_counts == {"collective-permute": 1}
    assert cost.coll_bytes == 4096
    assert stats.total_bytes == cost.coll_bytes


def test_async_all_gather_both_parsers_agree():
    """Same receive-buffer rule for group collectives: the (P-1)/P ring
    factor applies to the gathered result (2nd tuple element), not the
    operand-alias + result sum."""
    expect = 32 * 4 * 4 * 3 / 4  # full result * (P-1)/P, P=4
    stats = comm_model.parse_collectives(ASYNC_AG_HLO)
    cost = hlo_analysis.HloAnalyzer(ASYNC_AG_HLO).entry_cost()
    assert stats.counts["all-gather"] == 1
    assert abs(stats.bytes_moved["all-gather"] - expect) < 1e-9
    assert cost.coll_counts == {"all-gather": 1}
    assert abs(cost.coll_bytes - expect) < 1e-9
    assert abs(stats.total_bytes - cost.coll_bytes) < 1e-9


ASYNC_VARIADIC_AR_HLO = """
HloModule t, is_scheduled=true

ENTRY %main (a: f32[1024], b: f32[1024]) -> (f32[1024], f32[1024]) {
  %a = f32[1024]{0} parameter(0)
  %b = f32[1024]{0} parameter(1)
  %ars = (f32[1024]{0}, f32[1024]{0}) all-reduce-start(%a, %b), replica_groups={{0,1}}, to_apply=%add
  ROOT %ard = (f32[1024]{0}, f32[1024]{0}) all-reduce-done(%ars)
}
"""

RS_A2A_HLO = """
HloModule t, is_scheduled=true

ENTRY %main (p: f32[64,64]) -> f32[16,64] {
  %p = f32[64,64]{1,0} parameter(0)
  %a2a = f32[64,64]{1,0} all-to-all(%p), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %rs = f32[16,64]{1,0} reduce-scatter(%a2a), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add
}
"""


def test_async_variadic_all_reduce_counts_every_result():
    """Regression: all-reduce-start tuples are (result1, result2, ...) --
    results only, no operand alias or context scalars -- so the
    receive-buffer-only rule must NOT apply: a 2-tensor combined async
    all-reduce ships both payloads."""
    expect = 2 * 4096 * 2 * (2 - 1) / 2  # both tensors, 2(P-1)/P at P=2
    stats = comm_model.parse_collectives(ASYNC_VARIADIC_AR_HLO)
    cost = hlo_analysis.HloAnalyzer(ASYNC_VARIADIC_AR_HLO).entry_cost()
    assert stats.counts["all-reduce"] == 1
    assert stats.bytes_moved["all-reduce"] == expect
    assert cost.coll_bytes_by_kind.get("all-reduce") == expect
    assert stats.total_bytes == cost.coll_bytes


def test_reduce_scatter_and_all_to_all_parsers_agree():
    """Pin the shared ring-factor table (collective_scaled_bytes) for the
    kinds the other fixtures don't cover, on both parsers."""
    expect_a2a = 64 * 64 * 4 * 3 / 4  # (P-1)/P, P=4
    expect_rs = 16 * 64 * 4 * 3  # result * (P-1), P=4
    stats = comm_model.parse_collectives(RS_A2A_HLO)
    cost = hlo_analysis.HloAnalyzer(RS_A2A_HLO).entry_cost()
    assert stats.bytes_moved["all-to-all"] == expect_a2a
    assert stats.bytes_moved["reduce-scatter"] == expect_rs
    assert cost.coll_bytes_by_kind.get("all-to-all") == expect_a2a
    assert cost.coll_bytes_by_kind.get("reduce-scatter") == expect_rs
    assert stats.total_bytes == cost.coll_bytes


TILED_HLO = """
HloModule t, is_scheduled=true

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0:T(1024)} parameter(0)
  %cps = (f32[1024]{0:T(1024)}, f32[1024]{0:T(1024)}, u32[]{:T(128)S(1)}, u32[]{:T(128)S(1)}) collective-permute-start(%p), source_target_pairs={{0,1},{1,0}}
  %cpd = f32[1024]{0:T(1024)} collective-permute-done(%cps)
  ROOT %ar = f32[1024]{0:T(1024)} all-reduce(%cpd), replica_groups={{0,1}}, to_apply=%add
}
"""


def test_tpu_layout_annotations_do_not_hide_collectives():
    """Regression: post-layout TPU types carry parenthesized tile /
    memory-space annotations ({0:T(1024)}, S(1)); an eager first-'word('
    op-name search reads 'T(' as the op and drops the line, silently
    zeroing the collective term on the roofline's target platform. Both
    parsers must see through the annotations and still agree."""
    stats = comm_model.parse_collectives(TILED_HLO)
    cost = hlo_analysis.HloAnalyzer(TILED_HLO).entry_cost()
    assert stats.counts["collective-permute"] == 1
    assert stats.bytes_moved["collective-permute"] == 4096
    assert stats.counts["all-reduce"] == 1
    assert stats.bytes_moved["all-reduce"] == 4096  # 2*(P-1)/P, P=2
    assert cost.coll_counts == {"collective-permute": 1, "all-reduce": 1}
    assert cost.coll_bytes == stats.total_bytes == 8192


def test_collective_payload_bytes_rules():
    """The shared tuple-shape helper both parsers delegate to."""
    f = comm_model.collective_payload_bytes
    assert f("f32[64,64]{1,0}") == 64 * 64 * 4
    # async start: receive buffer (2nd element) only
    assert f("(f32[1024]{0}, f32[1024]{0}, u32[], u32[])", is_start=True) == 4096
    # sync variadic collective: every element is payload
    assert f("(f32[16]{0}, bf16[8]{0})") == 16 * 4 + 8 * 2
    # nested tuple receive buffer (variadic async form)
    assert f("((f32[8]{0}, f32[8]{0}), (f32[32]{0}, f32[32]{0}))", is_start=True) == 2 * 32 * 4
    # commas inside dims/layout do not split elements
    assert f("(f32[8,4]{1,0}, f32[32,4]{1,0})", is_start=True) == 32 * 4 * 4


COLLECTIVE_CODE = r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import hlo_analysis
from repro.core.compat import make_mesh

mesh = make_mesh((4,), ("model",))
L, B, D = 5, 8, 64

def f(ws, x):
    def body(c, w):
        return jnp.tanh(c @ w), None
    out, _ = jax.lax.scan(body, x, ws)
    return out.sum()

ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32, sharding=NamedSharding(mesh, P(None, None, "model")))
xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
comp = jax.jit(f).lower(ws, xs).compile()
c = hlo_analysis.analyze_compiled(comp)
# per-iteration all-gather of the (B, D) activations: (P-1)/P * B*D*4 * L
expect = 0.75 * B * D * 4 * L
ag = c.coll_bytes_by_kind.get("all-gather", 0)
assert abs(ag - expect) / expect < 0.1, (ag, expect)
assert c.coll_counts["all-gather"] == L
print("PASS collective loop accounting")
"""


@pytest.mark.slow
def test_collectives_in_loops_counted():
    out = run_subprocess(COLLECTIVE_CODE, devices=4)
    assert "PASS" in out
