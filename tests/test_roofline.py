"""HLO analyzer: loop-aware flops/bytes/collectives vs analytic counts."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import comm_model, hlo_analysis
from conftest import run_subprocess


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    comp = jax.jit(lambda a, b: (a @ b).sum()).lower(a, b).compile()
    c = hlo_analysis.analyze_compiled(comp)
    assert abs(c.flops - 2 * 128 * 256 * 512) / (2 * 128 * 256 * 512) < 0.01


def test_while_trip_count_multiplies():
    L, B, D = 7, 8, 32

    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None

        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
    comp = jax.jit(f).lower(ws, xs).compile()
    c = hlo_analysis.analyze_compiled(comp)
    expect = 2 * B * D * D * L
    assert abs(c.flops - expect) / expect < 0.05
    # XLA's own analysis counts the body once -> must be ~L x smaller
    ca = comp.cost_analysis()
    ca = ca if isinstance(ca, dict) else ca[0]
    assert c.flops > 3 * float(ca.get("flops", 0))


def test_nested_scan():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ ci), None

            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None

        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out.sum()

    xs = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    comp = jax.jit(f).lower(xs).compile()
    c = hlo_analysis.analyze_compiled(comp)
    expect = 2 * 16 * 16 * 16 * 15  # 5*3 nested trips
    assert abs(c.flops - expect) / expect < 0.05


def test_roofline_terms_and_bottleneck():
    r = comm_model.Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=0.0, chips=4)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert r.t_collective == 0.0
    assert r.bottleneck in ("compute", "memory")
    r2 = comm_model.Roofline(flops=1, hbm_bytes=1, coll_bytes=1e12, chips=4)
    assert r2.bottleneck == "collective"


def test_alpha_beta_models():
    p = 16
    m = 8 * 2**20
    t_a2a = comm_model.t_alltoall(m, p)
    t_ring = comm_model.t_scatter_ring(m, p)
    t_bis = comm_model.t_bisection(m, p)
    assert t_a2a > 0 and t_ring > 0 and t_bis > 0
    # small messages: latency dominates -> bisection (log P msgs) wins ring (P-1)
    tiny = 512
    assert comm_model.t_bisection(tiny, p) < comm_model.t_scatter_ring(tiny, p)


def test_collective_parse_text():
    fake = """
HloModule test, is_scheduled=true

ENTRY %main (p: f32[64,64]) -> f32[64,64] {
  %p = f32[64,64]{1,0} parameter(0)
  %ag = f32[64,64]{1,0} all-gather(%p), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %ar = f32[64,64]{1,0} all-reduce(%ag), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    stats = comm_model.parse_collectives(fake)
    assert stats.counts["all-gather"] == 1
    assert stats.counts["all-reduce"] == 1
    sz = 64 * 64 * 4
    assert abs(stats.bytes_moved["all-gather"] - sz * 3 / 4) < 1
    assert abs(stats.bytes_moved["all-reduce"] - sz * 2 * 3 / 4) < 1


COLLECTIVE_CODE = r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import hlo_analysis
from repro.core.compat import make_mesh

mesh = make_mesh((4,), ("model",))
L, B, D = 5, 8, 64

def f(ws, x):
    def body(c, w):
        return jnp.tanh(c @ w), None
    out, _ = jax.lax.scan(body, x, ws)
    return out.sum()

ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32, sharding=NamedSharding(mesh, P(None, None, "model")))
xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
comp = jax.jit(f).lower(ws, xs).compile()
c = hlo_analysis.analyze_compiled(comp)
# per-iteration all-gather of the (B, D) activations: (P-1)/P * B*D*4 * L
expect = 0.75 * B * D * 4 * L
ag = c.coll_bytes_by_kind.get("all-gather", 0)
assert abs(ag - expect) / expect < 0.1, (ag, expect)
assert c.coll_counts["all-gather"] == L
print("PASS collective loop accounting")
"""


@pytest.mark.slow
def test_collectives_in_loops_counted():
    out = run_subprocess(COLLECTIVE_CODE, devices=4)
    assert "PASS" in out
