"""Pencil-grid subsystem, host-process side: ProcessGrid validation,
grid-shape factorizations, per-axis cost-model selection, plan-level
decomp plumbing and its validate-once error surface. Multi-device
numerical sweeps live in tests/test_pencil.py."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import CommParams, backends, comm_model, plan_fft, planner
from repro.core import grid as gridmod
from repro.core.compat import make_mesh, make_mesh_1d
from repro.core.grid import ProcessGrid, auto_grid_shape, grid_from_mesh, grid_shapes, make_grid


@pytest.fixture(autouse=True)
def _fresh_wisdom():
    planner.forget_wisdom()
    yield
    planner.forget_wisdom()


# ---------------------------------------------------------------------------
# grid shapes / factorizations (property-style)
# ---------------------------------------------------------------------------


@given(p=st.integers(min_value=1, max_value=4096))
@settings(max_examples=50)
def test_grid_shapes_are_exact_factorizations(p):
    shapes = grid_shapes(p)
    assert all(pr * pc == p for pr, pc in shapes)
    assert len(set(shapes)) == len(shapes)  # no duplicates
    assert (1, p) in shapes and (p, 1) in shapes
    # complete: every divisor appears as a row count
    assert [pr for pr, _ in shapes] == [d for d in range(1, p + 1) if p % d == 0]


@given(p=st.integers(min_value=1, max_value=4096))
@settings(max_examples=50)
def test_auto_grid_shape_most_square(p):
    pr, pc = auto_grid_shape(p)
    assert pr * pc == p and pr <= pc
    # no other factorization is closer to square
    assert all(max(a, b) >= pc for a, b in grid_shapes(p))


def test_grid_shapes_reject_nonpositive():
    with pytest.raises(ValueError, match="positive"):
        grid_shapes(0)
    with pytest.raises(ValueError, match="positive"):
        auto_grid_shape(-2)


# ---------------------------------------------------------------------------
# ProcessGrid / resolution
# ---------------------------------------------------------------------------


def test_process_grid_validates_axes():
    mesh = make_mesh((1, 1), ("rows", "cols"))
    g = ProcessGrid(mesh)
    assert g.shape == (1, 1) and g.size == 1
    assert g.axis_of("row") == "rows" and g.axis_of("col") == "cols"
    with pytest.raises(ValueError, match="distinct"):
        ProcessGrid(mesh, "rows", "rows")
    with pytest.raises(ValueError, match="not an axis"):
        ProcessGrid(mesh, "rows", "model")
    with pytest.raises(ValueError, match="'row' or 'col'"):
        g.axis_of("diag")


def test_grid_from_mesh_resolution_rules():
    # conventional names win
    g = grid_from_mesh(make_mesh((1, 1), ("rows", "cols")))
    assert (g.row_axis, g.col_axis) == ("rows", "cols")
    # otherwise the last two axes (mirrors fft_axis's fallback)
    g = grid_from_mesh(make_mesh((1, 1), ("data", "model")))
    assert (g.row_axis, g.col_axis) == ("data", "model")
    # explicit names always win
    g = grid_from_mesh(make_mesh((1, 1), ("a", "b")), row_axis="b", col_axis="a")
    assert (g.row_axis, g.col_axis) == ("b", "a")
    with pytest.raises(ValueError, match="both"):
        grid_from_mesh(make_mesh((1, 1), ("a", "b")), row_axis="a")
    with pytest.raises(ValueError, match=">= 2 axes"):
        grid_from_mesh(make_mesh_1d(1))


def test_make_grid_validates():
    g = make_grid((1, 1))
    assert g.shape == (1, 1)
    with pytest.raises(ValueError, match="positive"):
        make_grid((0, 1))
    import jax

    with pytest.raises(ValueError, match="devices"):
        make_grid((1, 2), devices=jax.devices()[:1])


# ---------------------------------------------------------------------------
# per-axis cost model
# ---------------------------------------------------------------------------


def test_available_kind_filter():
    shard = backends.available(kind="shard_map")
    assert "xla_auto" not in shard and "scatter" in shard
    assert set(shard) | {"xla_auto"} == set(backends.available())
    assert backends.available(kind="global") == ("xla_auto",)


def test_cheapest_pair_is_per_axis_argmin():
    m = 4 * 2**20
    row, col = backends.cheapest_pair(m, 8, 2)
    assert row == backends.cheapest(m, 8, names=backends.available(kind="shard_map"))
    assert col == backends.cheapest(m, 2, names=backends.available(kind="shard_map"))
    # global backends never selected per-axis, even when named
    row, col = backends.cheapest_pair(m, 2, 2, names=("alltoall", "xla_auto"))
    assert row == "alltoall" and col == "alltoall"


def test_t_pencil_sums_per_axis_costs():
    m, pr, pc = 2 * 2**20, 4, 2
    prm = CommParams()
    t = comm_model.t_pencil(m, pr, pc, "scatter", "bisection", prm, ndim=3)
    expect = comm_model.t_scatter_ring(m, pr, prm) + comm_model.t_bisection(m, pc, prm)
    assert abs(t - expect) < 1e-18
    # fft2 runs two exchanges per sub-ring
    t2 = comm_model.t_pencil(m, pr, pc, "scatter", "bisection", prm, ndim=2)
    assert abs(t2 - 2 * expect) < 1e-18
    # transpose_back adds one exchange per axis (3-D only)
    tb = comm_model.t_pencil(m, pr, pc, "scatter", "bisection", prm, ndim=3, transpose_back=True)
    assert abs(tb - 2 * expect) < 1e-18
    with pytest.raises(ValueError, match="ndim 2 or 3"):
        comm_model.t_pencil(m, pr, pc, "scatter", "scatter", ndim=1)


def test_pencil_sub_axis_ring_sizes_separate_backends():
    """The point of the extension: at the same total P, the per-axis
    ranking differs between a long and a short sub-ring (the alpha/beta
    regimes the paper separates by parcelport)."""
    prm = CommParams(alpha_s=1.0, beta_bytes_s=1e12)  # alpha-dominated
    m = 2**20
    # alpha-dominated: message count decides -- alltoall (1) wins both
    # axes; but a streaming backend's cost grows with the sub-ring size,
    # so the *gap* is wider on the longer axis
    s8 = backends.get("scatter").cost(m, 8, prm)
    s2 = backends.get("scatter").cost(m, 2, prm)
    assert s8 > s2  # sub-ring size reached the model
    row, col = backends.cheapest_pair(m, 8, 2, prm)
    assert row == "alltoall"


# ---------------------------------------------------------------------------
# plan-level decomp plumbing (1x1 grid executes on the single real device)
# ---------------------------------------------------------------------------


def test_pencil_plan_predict_decomposes_per_axis():
    mesh = make_mesh((1, 1), ("rows", "cols"))
    plan = plan_fft((8, 8, 8), mesh, ndim=3, decomp="pencil")
    assert plan.decomp == "pencil" and plan.grid.shape == (1, 1)
    pred = plan.predict()
    rowc, colc = plan.predict_axes()
    for r in rowc:
        for c in colc:
            assert pred[f"{r}+{c}"] == rowc[r] + colc[c]
    # pair count = shard_map backends squared (all support P=1)
    n = len(backends.available(kind="shard_map"))
    assert len(pred) == n * n
    assert plan.backend == f"{plan.backend_row}+{plan.backend_col}"


def test_pencil_plan_executes_and_roundtrips_1x1():
    import jax.numpy as jnp

    mesh = make_mesh((1, 1), ("rows", "cols"))
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((8, 4, 4)) + 1j * rng.standard_normal((8, 4, 4))).astype(
        np.complex64
    )
    plan = plan_fft((8, 4, 4), mesh, ndim=3, decomp="pencil", backend=("scatter", "bisection"))
    assert (plan.backend_row, plan.backend_col) == ("scatter", "bisection")
    y = np.asarray(plan.execute(jnp.asarray(x)))
    ref = np.fft.fftn(x).transpose(2, 1, 0)
    assert np.abs(y - ref).max() < 1e-4 * np.abs(ref).max()
    z = np.asarray(plan.inverse(jnp.asarray(y)))
    assert np.abs(z - x).max() < 1e-4
    # executable caching applies to pencil plans too
    plan.execute(jnp.asarray(x))
    assert plan.compiles == 2  # forward + inverse wrappers only


def test_pencil_fft2_natural_layout_1x1():
    import jax.numpy as jnp

    mesh = make_mesh((1, 1), ("rows", "cols"))
    rng = np.random.default_rng(1)
    x = (rng.standard_normal((8, 8)) + 1j * rng.standard_normal((8, 8))).astype(np.complex64)
    plan = plan_fft((8, 8), mesh, ndim=2, decomp="pencil")
    y = np.asarray(plan.execute(jnp.asarray(x)))
    ref = np.fft.fft2(x)  # natural layout, NOT transposed like slab
    assert np.abs(y - ref).max() < 1e-4 * np.abs(ref).max()


def test_decomp_auto_picks_pencil_on_2d_mesh_slab_on_1d():
    mesh2 = make_mesh((1, 1), ("rows", "cols"))
    auto2 = plan_fft((8, 8, 8), mesh2, ndim=3, decomp="auto")
    assert auto2.decomp == "pencil" and auto2.grid is not None
    auto1 = plan_fft((8, 8), make_mesh_1d(1), decomp="auto")
    assert auto1.decomp == "slab" and auto1.grid is None
    # 1-D transforms are slab-only, even on a 2-D mesh
    auto1d = plan_fft((4096,), mesh2, ndim=1, decomp="auto")
    assert auto1d.decomp == "slab"


def test_decomp_auto_steered_by_pinned_backend():
    """A pinned backend that only works under one decomposition steers
    auto instead of erroring (regression: whole-transform backends raised
    under auto on a 2-D mesh even though slab handles them)."""
    mesh2 = make_mesh((1, 1), ("rows", "cols"))
    p = plan_fft((8, 8, 8), mesh2, ndim=3, decomp="auto", backend="xla_auto")
    assert p.decomp == "slab" and p.backend == "xla_auto"
    # the same steering through the measured planner
    mp = plan_fft(
        (8, 8, 8), mesh2, ndim=3, decomp="auto", backend="xla_auto",
        planner="measure", timer=lambda plan: 1.0,
    )
    assert mp.decomp == "slab" and mp.measured == {"xla_auto": 1.0}
    # a pinned pair steers toward pencil
    p2 = plan_fft((8, 8, 8), mesh2, ndim=3, decomp="auto", backend=("scatter", "bisection"))
    assert p2.decomp == "pencil" and p2.backend == "scatter+bisection"
    # neither decomposition fits: the error reports both reasons
    with pytest.raises(ValueError, match=r"neither decomposition.*pencil:.*slab:"):
        plan_fft((8, 8), mesh2, decomp="auto", backend=("xla_auto", "xla_auto"))


def test_decomp_validation_errors():
    mesh2 = make_mesh((1, 1), ("rows", "cols"))
    mesh1 = make_mesh_1d(1)
    with pytest.raises(ValueError, match="decomp"):
        plan_fft((8, 8), mesh2, decomp="brick")
    with pytest.raises(ValueError, match="ndim 2 or 3"):
        plan_fft((4096,), mesh2, ndim=1, decomp="pencil")
    with pytest.raises(ValueError, match="slab scatter-only"):
        plan_fft((8, 8), mesh2, decomp="pencil", fuse_dft=True, backend="scatter")
    with pytest.raises(ValueError, match="natural layout"):
        plan_fft((8, 8), mesh2, decomp="pencil", transpose_back=True)
    with pytest.raises(ValueError, match=">= 2 axes"):
        plan_fft((8, 8), mesh1, decomp="pencil")
    with pytest.raises(ValueError, match="decomp='pencil'"):
        plan_fft((8, 8), mesh2, decomp="slab", row_axis="rows", col_axis="cols")
    with pytest.raises(ValueError, match="one backend name"):
        plan_fft((8, 8), mesh1, backend="scatter+bisection")
    with pytest.raises(ValueError, match="whole-transform"):
        plan_fft((8, 8), mesh2, decomp="pencil", backend="xla_auto")
    with pytest.raises(ValueError, match="registered backends"):
        plan_fft((8, 8), mesh2, decomp="pencil", backend=("scatter", "lci"))
    with pytest.raises(ValueError, match="2 entries"):
        plan_fft((8, 8), mesh2, decomp="pencil", backend=("a", "b", "c"))


def test_auto_does_not_swallow_axis_argument_errors():
    """decomp='auto' falls back to slab on *infeasibility*, never on a
    bad user argument (regression: row_axis without col_axis, or a
    nonexistent axis name, silently produced a slab plan)."""
    mesh2 = make_mesh((1, 1), ("rows", "cols"))
    with pytest.raises(ValueError, match="both row_axis and col_axis"):
        plan_fft((8, 8), mesh2, decomp="auto", row_axis="rows")
    with pytest.raises(ValueError, match="not an axis"):
        plan_fft((8, 8), mesh2, decomp="auto", row_axis="rows", col_axis="model")
    # well-formed explicit axes still auto-resolve to pencil
    p = plan_fft((8, 8), mesh2, decomp="auto", row_axis="cols", col_axis="rows")
    assert p.decomp == "pencil" and p.grid.row_axis == "cols"


def test_pencil_divisibility_errors_name_axis_and_grid_dim():
    """The satellite contract: a bad shape fails naming the data axis
    and grid dimension, not deep inside transpose chunking. (The duck-
    typed grid stands in for the 2x4 mesh a 1-device host can't build;
    the plan_fft-level path is exercised in tests/test_pencil.py.)"""
    from repro.core.pencil import check_divisible

    class FakeGrid:
        p_rows, p_cols = 2, 4
        row_axis, col_axis = "rows", "cols"

    with pytest.raises(ValueError, match=r"axis -3 .*P_row=2"):
        check_divisible((9, 8, 8), FakeGrid(), 3)
    with pytest.raises(ValueError, match=r"axis -2 .*P_col=4"):
        check_divisible((8, 9, 8), FakeGrid(), 3)
    with pytest.raises(ValueError, match=r"axis -1 .*P_col=4"):
        check_divisible((8, 8, 9), FakeGrid(), 3)
    # D1 is re-sharded by BOTH exchanges: divisible by P_col but not P_row
    class LopsidedGrid(FakeGrid):
        p_rows, p_cols = 3, 4

    with pytest.raises(ValueError, match=r"axis -2 .*P_row=3.*re-shards"):
        check_divisible((9, 4, 8), LopsidedGrid(), 3)
    with pytest.raises(ValueError, match=r"axis -2 .*P_row\*P_col=8"):
        check_divisible((9, 8), FakeGrid(), 2)
    with pytest.raises(ValueError, match="ndim 2 or 3"):
        check_divisible((8, 8), FakeGrid(), 1)


# ---------------------------------------------------------------------------
# measured planner + wisdom with pencil key fields
# ---------------------------------------------------------------------------


def test_measured_pencil_plan_and_wisdom_roundtrip(tmp_path):
    """Acceptance: wisdom round-trips the new key fields (decomp, grid
    shape, per-axis backend pair)."""
    import json

    mesh = make_mesh((1, 1), ("rows", "cols"))
    pairs = planner.candidate_pairs(1, 1)
    assert all("+" in k for k in pairs)
    table = {k: float(i + 2) for i, k in enumerate(pairs)}
    table["scatter+bisection"] = 0.5
    calls = []

    def timer(plan):
        calls.append(plan.backend)
        return table[plan.backend]

    p1 = plan_fft((8, 8, 8), mesh, ndim=3, decomp="pencil", planner="measure", timer=timer)
    assert p1.backend == "scatter+bisection"
    assert (p1.backend_row, p1.backend_col) == ("scatter", "bisection")
    assert p1.measured == table and not p1.wisdom_hit
    assert set(calls) == set(pairs)

    # key carries the new fields
    (key,) = json.loads(planner.export_wisdom())["entries"]
    assert "decomp=pencil" in key and "grid=1x1" in key and "axes=rows+cols" in key

    # wisdom hit: no re-measure, same pair
    n = len(calls)
    p2 = plan_fft((8, 8, 8), mesh, ndim=3, decomp="pencil", planner="measure", timer=timer)
    assert p2.wisdom_hit and len(calls) == n
    assert p2.backend == p1.backend and p2.measured == table

    # disk round-trip restores the pencil entry
    path = tmp_path / "wisdom.json"
    planner.export_wisdom(str(path))
    planner.forget_wisdom()
    assert planner.import_wisdom(str(path)) == 1
    p3 = plan_fft((8, 8, 8), mesh, ndim=3, decomp="pencil", planner="measure", timer=timer)
    assert p3.wisdom_hit and p3.backend == p1.backend and len(calls) == n


def test_slab_and_pencil_wisdom_never_alias():
    """Same shape, same total P, different decomposition -> separate
    wisdom entries (a slab winner says nothing about a pencil grid)."""
    mesh2 = make_mesh((1, 1), ("rows", "cols"))
    calls = []

    def timer(plan):
        calls.append(plan.backend)
        return 1.0

    plan_fft((8, 8, 8), mesh2, ndim=3, decomp="pencil", planner="measure", timer=timer)
    n = len(calls)
    slab = plan_fft((8, 8, 8), mesh2, ndim=3, decomp="slab", planner="measure", timer=timer)
    assert not slab.wisdom_hit  # measured fresh, no aliasing
    assert len(calls) > n
    assert planner.wisdom_size() == 2


def test_measured_pinned_pair_times_only_that_pair():
    mesh = make_mesh((1, 1), ("rows", "cols"))
    calls = []

    def timer(plan):
        calls.append(plan.backend)
        return 1.0

    plan = plan_fft(
        (8, 8, 8),
        mesh,
        ndim=3,
        decomp="pencil",
        backend=("scatter", "bisection"),
        planner="measure",
        timer=timer,
    )
    assert plan.backend == "scatter+bisection"
    assert calls == ["scatter+bisection"]


def test_pencil_comm_bytes_accounts_both_axes():
    mesh = make_mesh((1, 1), ("rows", "cols"))
    plan = plan_fft((8, 8, 8), mesh, ndim=3, decomp="pencil")
    assert plan.comm_bytes() == 0.0  # 1x1 grid: nothing crosses a fabric
    assert plan.local_bytes() == 8 * 8 * 8 * 8  # c64 itemsize, 1 shard
