"""Stage-schedule IR tests: golden snapshots over the full pipeline
grid, the one shard-divisibility validator's exact messages, rewrite
semantics, and the cost/byte invariants (per-stage contributions sum to
the whole-plan prediction; model bytes match both HLO parsers).

Regenerate the golden file after an INTENTIONAL pipeline change with:

    PYTHONPATH=src python tests/test_schedule.py --regen
"""

import json
import os
import re

import pytest

import repro.core.schedule as sch

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden_schedules.json")


# ---------------------------------------------------------------------------
# The snapshot grid: every (decomp x real x direction x fused) pipeline,
# built purely (shapes + names + ring sizes in -- no mesh, no devices)
# ---------------------------------------------------------------------------


def snapshot_cases():
    """key -> build_schedule kwargs for every supported combination."""
    cases = {}
    for ndim, shape in ((2, (16, 16)), (3, (8, 8, 8))):
        for real in (False, True):
            for inverse in (False, True):
                for fused in (False, True):
                    tbs = (False, True) if ndim == 2 else (False,)
                    for tb in tbs:
                        key = (
                            f"slab/ndim{ndim}/{'r2c' if real else 'c2c'}/"
                            f"{'inv' if inverse else 'fwd'}/"
                            f"{'fused' if fused else 'unfused'}"
                            + ("/tb" if tb else "")
                        )
                        cases[key] = dict(
                            global_shape=shape, ndim=ndim, inverse=inverse,
                            real=real, decomp="slab", axis_name="x", p=4,
                            backend="scatter", fused=fused, transpose_back=tb,
                        )
    for fused in (False, True):
        key = f"slab/ndim1/c2c/fwd/{'fused' if fused else 'unfused'}"
        cases[key] = dict(
            global_shape=(64,), ndim=1, inverse=False, decomp="slab",
            axis_name="x", p=4, backend="scatter", fused=fused,
        )
    for ndim, shape in ((2, (16, 16)), (3, (8, 8, 8))):
        for real in (False, True):
            for inverse in (False, True):
                for fused in (False, True):
                    tbs = (False, True) if ndim == 3 else (False,)
                    for tb in tbs:
                        key = (
                            f"pencil/ndim{ndim}/{'r2c' if real else 'c2c'}/"
                            f"{'inv' if inverse else 'fwd'}/"
                            f"{'fused' if fused else 'unfused'}"
                            + ("/tb" if tb else "")
                        )
                        cases[key] = dict(
                            global_shape=shape, ndim=ndim, inverse=inverse,
                            real=real, decomp="pencil",
                            row_axis="rows", col_axis="cols",
                            p_rows=2, p_cols=2,
                            backend_row="scatter", backend_col="alltoall",
                            fused=fused, transpose_back=tb,
                        )
    # the GSPMD whole-transform reference route (empty abstract exchanges
    # still carry cost structure; execution goes through _xla_reference)
    cases["slab/ndim2/c2c/fwd/xla_auto"] = dict(
        global_shape=(16, 16), ndim=2, inverse=False, decomp="slab",
        axis_name="x", p=4, backend="xla_auto",
    )
    cases["slab/ndim2/r2c/fwd/xla_auto"] = dict(
        global_shape=(16, 16), ndim=2, inverse=False, real=True,
        decomp="slab", axis_name="x", p=4, backend="xla_auto",
    )
    return cases


def build_snapshots():
    return {k: sch.build_schedule(**kw).canonical() for k, kw in sorted(snapshot_cases().items())}


def test_golden_schedules_drift():
    """Every pipeline's lowered stage schedule is byte-identical to the
    committed snapshot -- any change to what executes (stage order,
    exchange payloads, ring sizes, conj/scale) must be intentional and
    show up in review as a golden-file diff."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    built = build_snapshots()
    assert set(built) == set(golden), (
        f"pipeline grid changed: new={sorted(set(built) - set(golden))} "
        f"gone={sorted(set(golden) - set(built))} -- regenerate "
        f"tests/golden_schedules.json if intentional"
    )
    for key in sorted(built):
        assert built[key] == golden[key], (
            f"schedule drift in {key}:\n--- golden ---\n{golden[key]}\n"
            f"--- built ---\n{built[key]}"
        )


def test_schedule_hash_tracks_content():
    a = sch.build_schedule((16, 16), ndim=2, decomp="slab", axis_name="x",
                           p=4, backend="scatter")
    same = sch.build_schedule((16, 16), ndim=2, decomp="slab", axis_name="x",
                              p=4, backend="scatter")
    other = sch.build_schedule((16, 16), ndim=2, decomp="slab", axis_name="x",
                               p=4, backend="alltoall")
    assert a.schedule_hash() == same.schedule_hash()
    assert a.schedule_hash() != other.schedule_hash()
    assert re.fullmatch(r"[0-9a-f]{12}", a.schedule_hash())


# ---------------------------------------------------------------------------
# Rewrites
# ---------------------------------------------------------------------------


def test_with_pipeline_rewrites_every_exchange():
    s = sch.build_schedule((8, 8, 8), ndim=3, decomp="slab", axis_name="x",
                           p=4, backend="scatter", fused=True)
    u = sch.with_pipeline(s, False, None)
    assert all(not ex.fused and ex.n_chunks is None for ex in u.exchanges())
    f = sch.with_pipeline(s, True, 16)
    assert all(ex.fused and ex.n_chunks == 16 for ex in f.exchanges())
    # non-Exchange stages and the header survive untouched
    assert u.global_shape == s.global_shape and len(u.stages) == len(s.stages)


def test_with_backends_by_role():
    s = sch.build_schedule((8, 8, 8), ndim=3, decomp="pencil",
                           row_axis="r", col_axis="c", p_rows=2, p_cols=2,
                           backend_row="alltoall", backend_col="alltoall")
    rw = sch.with_backends(s, row="scatter")
    assert all(ex.backend == "scatter" for ex in rw.exchanges("row"))
    assert all(ex.backend == "alltoall" for ex in rw.exchanges("col"))


def test_apply_variant_matches_manual_rewrite():
    s = sch.build_schedule((16, 16), ndim=2, decomp="slab", axis_name="x",
                           p=4, backend="alltoall")
    v = sch.apply_variant(s, "scatter@f8")
    assert all(ex.backend == "scatter" and ex.fused and ex.n_chunks == 8
               for ex in v.exchanges())
    u = sch.apply_variant(s, "scatter@u")
    assert all(ex.backend == "scatter" and not ex.fused for ex in u.exchanges())
    p = sch.build_schedule((8, 8, 8), ndim=3, decomp="pencil",
                           row_axis="r", col_axis="c", p_rows=2, p_cols=2,
                           backend_row="alltoall", backend_col="alltoall")
    pv = sch.apply_variant(p, "scatter+bisection@u")
    assert all(ex.backend == "scatter" for ex in pv.exchanges("row"))
    assert all(ex.backend == "bisection" for ex in pv.exchanges("col"))
    assert all(not ex.fused for ex in pv.exchanges())


# ---------------------------------------------------------------------------
# The one validator: exact legacy messages (regression-pinned)
# ---------------------------------------------------------------------------


def test_validator_slab_c2c_messages():
    with pytest.raises(ValueError, match=re.escape(
            "slab fft2: data axis -2 (global size 10) is not divisible by "
            "mesh axis 'x' (P=4) -- shape (10, 16)")):
        sch.check_divisible((10, 16), 2, p=4, axis_name="x")
    with pytest.raises(ValueError, match=re.escape(
            "slab fft2: data axis -1 (global size 10)")):
        sch.check_divisible((16, 10), 2, p=4, axis_name="x")
    with pytest.raises(ValueError, match=re.escape(
            "slab fft3: data axis -3 (global size 10)")):
        sch.check_divisible((10, 8, 8), 3, p=4, axis_name="x")
    with pytest.raises(ValueError, match=re.escape(
            "slab fft3: flattened axes (-2,-1) (size 5*2=10)")):
        sch.check_divisible((8, 5, 2), 3, p=4, axis_name="x")
    with pytest.raises(ValueError, match=re.escape(
            "fft1d_large: data axis -1 (size 24) must be divisible by P^2=16")):
        sch.check_divisible((24,), 1, p=4, axis_name="x")


def test_validator_pencil_c2c_messages():
    with pytest.raises(ValueError, match=re.escape(
            "pencil fft3: data axis -3 (global size 9) is not divisible by "
            "P_row=2 ('rows')")):
        sch.check_divisible((9, 8, 8), 3, p_rows=2, p_cols=2,
                            row_axis="rows", col_axis="cols")
    with pytest.raises(ValueError, match=re.escape("P_col=2 ('cols')")):
        sch.check_divisible((8, 9, 8), 3, p_rows=2, p_cols=2,
                            row_axis="rows", col_axis="cols")
    with pytest.raises(ValueError, match=re.escape(
            "P_row*P_col=4 (both sub-rings re-shard it)")):
        sch.check_divisible((10, 8), 2, p_rows=2, p_cols=2,
                            row_axis="rows", col_axis="cols")
    with pytest.raises(ValueError, match=re.escape(
            "pencil decomposition supports ndim 2 or 3, got 1")):
        sch.check_divisible((16,), 1, p_rows=2, p_cols=2,
                            row_axis="rows", col_axis="cols")


def test_validator_real_messages():
    # slab r2c: the rows axis must divide P; the Hermitian axis must
    # divide (or pad) -- messages name the data axis and the mesh axis
    with pytest.raises(ValueError, match=re.escape(
            "real slab rfft2: data axis -2 (global size 10) is not divisible "
            "by mesh axis 'x' (P=4)")):
        sch.check_divisible((10, 16), 2, p=4, axis_name="x", real=True)
    with pytest.raises(ValueError, match=re.escape(
            "real slab rfft2: Hermitian axis -1 (N=10 -> N//2+1=6)")):
        sch.check_divisible((16, 10), 2, p=4, axis_name="x", real=True, pad=False)
    with pytest.raises(NotImplementedError, match="real transforms support ndim 2 or 3"):
        sch.check_divisible((64,), 1, p=4, axis_name="x", real=True)
    # pencil r2c: (8,8,8) on a 2x2 grid has h = 8//2+1 = 5, not divisible
    with pytest.raises(ValueError, match=re.escape(
            "real pencil rfft3: Hermitian axis -1 (N=8 -> N//2+1=5)")):
        sch.check_divisible((8, 8, 8), 3, p_rows=2, p_cols=2,
                            row_axis="rows", col_axis="cols", real=True, pad=False)
    with pytest.raises(NotImplementedError, match="real pencil transforms support ndim 2 or 3"):
        sch.check_divisible((64,), 1, p_rows=2, p_cols=2,
                            row_axis="rows", col_axis="cols", real=True)
    # padding resolves the Hermitian axis: returns (h, hp)
    h, hp = sch.check_divisible((16, 16), 2, p=4, axis_name="x", real=True)
    assert (h, hp) == (9, 12)


def test_validator_is_the_single_source():
    """The legacy validator spellings all delegate here -- same checks,
    same messages (the dedup satellite)."""
    from repro.core import pencil as pencil_mod
    from repro.core import real as real_mod

    with pytest.raises(ValueError, match="Hermitian axis -1"):
        real_mod.check_divisible_slab((16, 10), 4, 2, "x", pad=False)
    with pytest.raises(ValueError, match="real pencil rfft3"):
        real_mod.check_divisible_pencil((8, 8, 8), type(
            "G", (), dict(p_rows=2, p_cols=2, row_axis="r", col_axis="c"))(), 3,
            pad=False)

    class FakeGrid:
        p_rows, p_cols = 2, 2
        row_axis, col_axis = "rows", "cols"

    with pytest.raises(ValueError, match="P_row=2"):
        pencil_mod.check_divisible((9, 8, 8), FakeGrid(), 3)


# ---------------------------------------------------------------------------
# Cost/byte invariants (pure walks; the executed-vs-modeled cross-check
# against both HLO parsers runs on 8 devices below)
# ---------------------------------------------------------------------------


def test_stage_walk_sums_to_whole_schedule():
    from repro.core import comm_model as cm

    prm = cm.CommParams()
    s = sch.build_schedule((8, 8, 8), ndim=3, decomp="pencil",
                           row_axis="r", col_axis="c", p_rows=2, p_cols=2,
                           backend_row="scatter", backend_col="alltoall",
                           fused=True)
    total = sch.predict_seconds(s, prm, 1e-6, 8, 8)
    per_stage = sum(sch.stage_seconds(ex, prm, 1e-6, 8, 8) for ex in s.exchanges())
    assert total == per_stage
    assert (sch.predict_seconds(s, prm, 1e-6, 8, 8, "row")
            + sch.predict_seconds(s, prm, 1e-6, 8, 8, "col")) == total
    bytes_total = sch.schedule_comm_bytes(s, 8, 8)
    assert bytes_total == sum(sch.exchange_wire_bytes(ex, 8, 8) for ex in s.exchanges())
    assert bytes_total > 0


def test_describe_renders_stage_table():
    s = sch.build_schedule((16, 16), ndim=2, decomp="slab", axis_name="x",
                           p=4, backend="scatter", fused=True)
    text = s.describe()
    assert s.schedule_hash() in text
    assert "LocalFFT" in text and "Exchange" in text
    assert "wire bytes" in text and "total modeled exchange time" in text


def test_plan_level_invariants_8dev():
    """Per-stage predict() contributions sum to the whole-plan
    prediction, per-stage model bytes sum to comm_bytes, and (alltoall
    pipelines) both HLO parsers count exactly those bytes."""
    from conftest import run_subprocess

    code = r"""
from repro.core import plan_fft, comm_model, hlo_analysis
from repro.core.compat import make_mesh

mesh = make_mesh((8,), ("x",))
gmesh = make_mesh((4, 2), ("rows", "cols"))
cases = [
    dict(shape=(32, 32), mesh=mesh, ndim=2, backend="scatter"),
    dict(shape=(32, 32), mesh=mesh, ndim=2, backend="alltoall"),
    dict(shape=(16, 16, 16), mesh=mesh, ndim=3, backend="alltoall"),
    dict(shape=(64 * 8,), mesh=mesh, ndim=1, backend="scatter"),
    dict(shape=(32, 32), mesh=mesh, ndim=2, backend="alltoall", real=True),
    dict(shape=(32, 32), mesh=mesh, ndim=2, backend="alltoall", real=True,
         direction="inverse"),
    dict(shape=(16, 16, 16), mesh=gmesh, ndim=3, decomp="pencil",
         backend=("alltoall", "alltoall")),
    dict(shape=(16, 16, 16), mesh=gmesh, ndim=3, decomp="pencil",
         backend=("scatter", "bisection")),
    dict(shape=(16, 16, 16), mesh=gmesh, ndim=3, decomp="pencil", real=True,
         backend=("alltoall", "alltoall")),
    dict(shape=(32, 32), mesh=gmesh, ndim=2, decomp="pencil", real=True,
         backend=("alltoall", "alltoall")),
]
for kw in cases:
    shape, m = kw.pop("shape"), kw.pop("mesh")
    plan = plan_fft(shape, m, **kw)
    stages = plan.predict_stages()
    secs = sum(s for _, s, _ in stages)
    byts = sum(b for _, _, b in stages)
    whole = plan.predict()[plan.backend]
    assert abs(secs - whole) <= 1e-15 + 1e-9 * whole, (plan, secs, whole)
    assert abs(byts - plan.comm_bytes()) <= 1e-6, (plan, byts, plan.comm_bytes())
    all_a2a = all(kw_b == "alltoall" for kw_b in (
        [plan.backend] if plan.decomp == "slab"
        else [plan.backend_row, plan.backend_col]))
    if all_a2a and plan.shards > 1:
        comp = plan.lower().compile()
        group = plan.shards
        parsed = comm_model.parse_collectives(comp.as_text(), default_group=group).total_bytes
        hlo = hlo_analysis.analyze_compiled(comp, default_group=group).coll_bytes
        assert abs(parsed - byts) <= 1e-6 * max(byts, 1.0), (plan, parsed, byts)
        assert abs(hlo - byts) <= 1e-6 * max(byts, 1.0), (plan, hlo, byts)
    print("PASS", plan)
print("PASS all invariants")
"""
    out = run_subprocess(code, devices=8)
    assert "PASS all invariants" in out


def test_plan_schedule_identity_and_hash_8dev():
    """Plan.schedule() is the executed object: fused and unfused plans
    hash differently, forward/inverse round-trip through genuinely
    reversed real chains, and the serve pool records the hash."""
    from conftest import run_subprocess

    code = r"""
import numpy as np
import jax.numpy as jnp
from repro.core import plan_fft
from repro.core.compat import make_mesh
from repro.serve.spectral import PlanPool

mesh = make_mesh((8,), ("x",))
pf = plan_fft((32, 32), mesh, backend="scatter")
pu = plan_fft((32, 32), mesh, backend="scatter", pipeline=False)
assert pf.schedule_hash() != pu.schedule_hash()
assert pf.schedule_hash() == plan_fft((32, 32), mesh, backend="scatter").schedule_hash()
assert pf.schedule_hash(inverse=True) != pf.schedule_hash(inverse=False)

pr = plan_fft((32, 32), mesh, backend="scatter", real=True)
fwd, inv = pr.schedule(False), pr.schedule(True)
assert fwd.stages != inv.stages  # real inverse is a reversed chain, not a conj-wrap
assert fwd.kind == "rfft2" and inv.kind == "irfft2"

pool = PlanPool(mesh, capacity=4)
plan, hit = pool.get((32, 32), 2, jnp.complex64, False)
key = pool.key((32, 32), 2, jnp.complex64, False)
assert not hit and pool.schedule_hash(key) == plan.schedule_hash()
assert pool.stats()["distinct_schedules"] == 1
assert key.startswith("shape=32x32|ndim=2|")  # pool key format is frozen
print("PASS schedule identity")
"""
    out = run_subprocess(code, devices=8)
    assert "PASS schedule identity" in out


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        snaps = build_snapshots()
        with open(GOLDEN_PATH, "w") as f:
            json.dump(snaps, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {len(snaps)} schedules to {GOLDEN_PATH}")
    else:
        print(__doc__)
