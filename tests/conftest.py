"""Shared fixtures. NOTE: no XLA_FLAGS here -- smoke tests and benches
must see the 1 real device; multi-device tests spawn subprocesses that
set --xla_force_host_platform_device_count themselves.

When ``hypothesis`` is not installed, a tiny deterministic fallback shim
is registered in its place (conftest loads before test-module collection)
so the property tests still run -- each ``@given`` draws ``max_examples``
seeded-random samples instead of shrinking counterexamples."""

import functools
import inspect
import os
import random
import subprocess
import sys
import types

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample  # sample(rng) -> value

    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    def _booleans():
        return _Strategy(lambda rng: bool(rng.randrange(2)))

    def _floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_hyp_max_examples", 10)
                rng = random.Random(f"{fn.__module__}.{fn.__name__}")
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # pytest must only see the non-strategy params (fixtures), not
            # the drawn ones -- and must not unwrap to the original fn.
            del wrapper.__wrapped__
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[p for name, p in sig.parameters.items() if name not in strategies]
            )
            return wrapper

        return deco

    def _settings(max_examples=10, **_):
        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn

        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.floats = _floats
    _hyp.strategies = _st
    _hyp.__version__ = "0.0.0-shim"
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# The measured planner auto-runs a ppermute calibration sweep once per
# device kind on a fresh race (default-on in production). Under test it
# would add real-fabric timing noise to every measure-planner test and
# couple test outcomes to suite order, so the suite pins it off --
# subprocesses spawned by run_subprocess inherit the env and stay
# deterministic too. Calibration-specific tests inject timers or call
# planner.ensure_calibrated explicitly.
os.environ.setdefault("REPRO_AUTO_CALIBRATE", "0")


def run_subprocess(code: str, *, devices: int = 8, timeout: int = 600) -> str:
    """Run a python snippet with N host-platform devices; returns stdout.
    The snippet should print 'PASS' lines / raise on failure."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
        cwd=REPO,
    )
    if out.returncode != 0:
        raise AssertionError(f"subprocess failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}")
    return out.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
