"""Shared fixtures. NOTE: no XLA_FLAGS here -- smoke tests and benches
must see the 1 real device; multi-device tests spawn subprocesses that
set --xla_force_host_platform_device_count themselves."""

import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(code: str, *, devices: int = 8, timeout: int = 600) -> str:
    """Run a python snippet with N host-platform devices; returns stdout.
    The snippet should print 'PASS' lines / raise on failure."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
        cwd=REPO,
    )
    if out.returncode != 0:
        raise AssertionError(f"subprocess failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}")
    return out.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
