"""Spectral application layer (repro.apps) against analytic / numpy
oracles: Poisson solve, spectral gradient/laplacian, FFT convolution and
correlation -- each through the plan front-end so every combination of
decomposition (slab / pencil), transform family (c2c / r2c) and backend
flows through the same app code. In-process tests run on the 1-device
mesh; the 8-host-device subprocess re-runs the solvers on real multi-
shard layouts (the CI fast job executes it under forced 8 devices).
"""

import numpy as np
import pytest

from conftest import run_subprocess

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.apps import (  # noqa: E402
    fft_convolve,
    fft_correlate,
    gradient,
    laplacian,
    solve_poisson,
    wavenumbers,
)
from repro.core import plan_fft  # noqa: E402
from repro.core.compat import make_mesh  # noqa: E402


def _grid2(n):
    xs = np.arange(n) * 2 * np.pi / n
    return np.meshgrid(xs, xs, indexing="ij")


def _plans_2d(n):
    mesh = make_mesh((1,), ("model",))
    gmesh = make_mesh((1, 1), ("rows", "cols"))
    return {
        "slab-c2c": plan_fft((n, n), mesh),
        "slab-r2c": plan_fft((n, n), mesh, real=True),
        "slab-r2c-tb": plan_fft((n, n), mesh, real=True, transpose_back=True),
        "pencil-c2c": plan_fft((n, n), gmesh, decomp="pencil"),
        "pencil-r2c": plan_fft((n, n), gmesh, decomp="pencil", real=True),
    }


def _cast(a, plan):
    return jnp.asarray(a if plan.real else a.astype(np.complex64))


def test_poisson_2d_all_layouts():
    n = 32
    X, Y = _grid2(n)
    u0 = np.sin(X) * np.cos(2 * Y)  # zero mean
    f = -(1 + 4) * u0
    for name, plan in _plans_2d(n).items():
        u = np.real(np.asarray(solve_poisson(_cast(f, plan), plan)))
        assert np.abs(u - u0).max() < 1e-4, name


def test_poisson_nonunit_lengths():
    n = 64
    L = (4.0, 8.0)
    xs = np.arange(n) * L[0] / n
    ys = np.arange(n) * L[1] / n
    X, _ = np.meshgrid(xs, ys, indexing="ij")
    k0 = 2 * np.pi / L[0]
    u0 = np.sin(2 * k0 * X)
    f = -((2 * k0) ** 2) * u0
    plan = plan_fft((n, n), make_mesh((1,), ("model",)), real=True)
    u = np.asarray(solve_poisson(jnp.asarray(f.astype(np.float32)), plan, lengths=L))
    assert np.abs(u - u0).max() < 1e-3


def test_gradient_laplacian():
    n = 32
    X, Y = _grid2(n)
    u = np.sin(X) * np.cos(3 * Y)
    dux = np.cos(X) * np.cos(3 * Y)
    duy = -3 * np.sin(X) * np.sin(3 * Y)
    lap = -(1 + 9) * u
    for name, plan in _plans_2d(n).items():
        gx, gy = gradient(_cast(u, plan), plan)
        assert np.abs(np.real(np.asarray(gx)) - dux).max() < 1e-4, name
        assert np.abs(np.real(np.asarray(gy)) - duy).max() < 1e-4, name
        lp = laplacian(_cast(u, plan), plan)
        assert np.abs(np.real(np.asarray(lp)) - lap).max() < 1e-3, name


def test_convolve_correlate_vs_numpy():
    n = 16
    rng = np.random.default_rng(3)
    a = rng.standard_normal((n, n)).astype(np.float32)
    b = rng.standard_normal((n, n)).astype(np.float32)
    ref_cv = np.real(np.fft.ifft2(np.fft.fft2(a) * np.fft.fft2(b)))
    ref_cr = np.real(np.fft.ifft2(np.fft.fft2(a) * np.conj(np.fft.fft2(b))))
    for name, plan in _plans_2d(n).items():
        cv = np.real(np.asarray(fft_convolve(_cast(a, plan), _cast(b, plan), plan)))
        cr = np.real(np.asarray(fft_correlate(_cast(a, plan), _cast(b, plan), plan)))
        assert np.abs(cv - ref_cv).max() < 1e-3 * np.abs(ref_cv).max(), name
        assert np.abs(cr - ref_cr).max() < 1e-3 * np.abs(ref_cr).max(), name
    plan = _plans_2d(n)["slab-r2c"]
    with pytest.raises(ValueError, match="share a shape"):
        fft_convolve(jnp.zeros((n, n)), jnp.zeros((n, 2 * n)), plan)


def test_wavenumbers_layouts():
    """k-grids land at the right output positions in transposed,
    reversed and Hermitian-padded layouts."""
    mesh = make_mesh((1,), ("model",))
    plan = plan_fft((8, 10), mesh, real=True)  # spectrum (6, 8): (half C, R)
    kx, ky = wavenumbers(plan)
    assert kx.shape == (1, 8) and ky.shape == (6, 1)  # kx = orig axis -2 (R)
    assert float(ky[-1, 0]) == 5.0  # rfftfreq top mode of n=10
    np.testing.assert_allclose(
        np.asarray(kx).ravel(), np.fft.fftfreq(8) * 8, atol=1e-6
    )
    gmesh = make_mesh((1, 1), ("rows", "cols"))
    plan3 = plan_fft((4, 6, 8), gmesh, ndim=3, decomp="pencil", real=True)
    k0, k1, k2 = wavenumbers(plan3)  # ordered by original axis
    assert k0.shape == (1, 1, 4) and k1.shape == (1, 6, 1) and k2.shape == (5, 1, 1)
    with pytest.raises(ValueError, match="lengths"):
        wavenumbers(plan3, lengths=(1.0, 2.0))


APPS_8DEV_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import plan_fft
from repro.core.compat import make_mesh
from repro.apps import fft_convolve, gradient, solve_poisson

n = 32
xs = np.arange(n) * 2 * np.pi / n
X, Y, Z = np.meshgrid(xs, xs, xs, indexing="ij")
u0 = np.sin(X) * np.cos(Y) * np.sin(2 * Z)
f = -(1 + 1 + 4) * u0

mesh = make_mesh((8,), ("model",))
gmesh = make_mesh((2, 4), ("rows", "cols"))
plans = {
    "slab r2c": plan_fft((n,) * 3, mesh, ndim=3, real=True),
    "pencil r2c": plan_fft((n,) * 3, gmesh, ndim=3, decomp="pencil", real=True),
    "pencil c2c": plan_fft((n,) * 3, gmesh, ndim=3, decomp="pencil"),
}
for name, plan in plans.items():
    fin = jnp.asarray(f.astype(np.float32) if plan.real else f.astype(np.complex64))
    u = np.real(np.asarray(solve_poisson(fin, plan)))
    assert np.abs(u - u0).max() < 1e-4, (name, np.abs(u - u0).max())
print("PASS poisson 3d multi-shard")

# gradient through the sharded r2c pencil plan
uin = jnp.asarray((np.sin(X)).astype(np.float32))
gx, gy, gz = gradient(uin, plans["pencil r2c"])
assert np.abs(np.asarray(gx) - np.cos(X)).max() < 1e-4
assert np.abs(np.asarray(gy)).max() < 1e-4 and np.abs(np.asarray(gz)).max() < 1e-4
print("PASS gradient multi-shard")

# distributed real convolution on a 2-D slab plan
rng = np.random.default_rng(5)
a = rng.standard_normal((64, 64)).astype(np.float32)
b = rng.standard_normal((64, 64)).astype(np.float32)
ref = np.real(np.fft.ifft2(np.fft.fft2(a) * np.fft.fft2(b)))
plan2 = plan_fft((64, 64), mesh, real=True)
cv = np.asarray(fft_convolve(jnp.asarray(a), jnp.asarray(b), plan2))
assert np.abs(cv - ref).max() < 1e-2 * np.abs(ref).max()
print("PASS convolve multi-shard")
"""


def test_apps_8dev():
    out = run_subprocess(APPS_8DEV_CODE, devices=8)
    assert out.count("PASS") == 3, out
