"""Pallas kernels vs pure-jnp oracles: shape/dtype sweep in interpret mode."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _planar(rng, shape):
    return (
        rng.standard_normal(shape).astype(np.float32),
        rng.standard_normal(shape).astype(np.float32),
    )


@pytest.mark.parametrize(
    "b,m,k,n",
    [(1, 128, 128, 128), (2, 256, 64, 128), (3, 128, 512, 256), (1, 384, 128, 384)],
)
def test_stage_left_matches_ref(rng, b, m, k, n):
    w = _planar(rng, (m, k))
    a = _planar(rng, (b, k, n))
    t = _planar(rng, (m, n))
    got = ops.stage_left(w, a, t)
    exp = ref.stage_left_ref(w, a, t)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("b,m,k,n", [(1, 128, 128, 128), (2, 128, 256, 128)])
def test_stage_right_matches_ref(rng, b, m, k, n):
    a = _planar(rng, (b, m, k))
    w = _planar(rng, (n, k))
    got = ops.stage_right(a, w)
    exp = ref.stage_right_ref(a, w)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), rtol=2e-4, atol=2e-3)


def test_stage_left_block_sweep(rng):
    """BlockSpec tiling must not change results."""
    w = _planar(rng, (256, 128))
    a = _planar(rng, (1, 128, 256))
    t = _planar(rng, (256, 256))
    base = ops.stage_left(w, a, t, bm=256, bn=256)
    for bm in (64, 128):
        for bn in (64, 128, 256):
            got = ops.stage_left(w, a, t, bm=bm, bn=bn)
            for g, e in zip(got, base):
                np.testing.assert_allclose(np.asarray(g), np.asarray(e), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("lead,rows,c,p", [((3,), 4, 6, 8), ((2, 5), 8, 4, 2), ((1,), 1, 3, 4)])
def test_chunk_twiddle_pack_matches_jnp(rng, lead, rows, c, p):
    """The pipelined overlap executor's one-launch per-chunk callback:
    relayout + W_P-column x twiddle multiply == the two-op jnp path."""
    from repro.kernels import fft_stage

    chunk = (rng.standard_normal(lead + (rows, c)) + 1j * rng.standard_normal(
        lead + (rows, c))).astype(np.complex64)
    m = (rng.standard_normal((p, rows)) + 1j * rng.standard_normal((p, rows))).astype(
        np.complex64)
    got = np.asarray(fft_stage.chunk_twiddle_pack_c64(jnp.asarray(chunk), jnp.asarray(m)))
    ct = np.swapaxes(chunk, -1, -2)  # (..., c, rows)
    exp = ct[..., None, :] * m  # (..., c, p, rows)
    assert got.shape == lead + (c, p, rows)
    assert got.dtype == np.complex64
    np.testing.assert_allclose(got, exp, rtol=1e-5, atol=1e-5)


def test_chunk_twiddle_pack_rejects_wrong_dtype_and_shape(rng):
    from repro.kernels import fft_stage

    chunk = jnp.zeros((2, 4, 6), jnp.complex64)
    # a non-c64 chunk (x64 may be disabled, so use the real dtype)
    with pytest.raises(ValueError, match="planar-f32"):
        fft_stage.chunk_twiddle_pack_c64(jnp.zeros((2, 4, 6), jnp.float32),
                                         jnp.zeros((8, 4), jnp.complex64))
    with pytest.raises(ValueError, match=r"\(p, rows\)"):
        fft_stage.chunk_twiddle_pack_c64(chunk, jnp.zeros((8, 5), jnp.complex64))


@pytest.mark.parametrize("n", [1024, 4096, 16384])
@pytest.mark.parametrize("inverse", [False, True])
def test_fft_last_axis_vs_oracle(rng, n, inverse):
    x = (rng.standard_normal((2, n)) + 1j * rng.standard_normal((2, n))).astype(np.complex64)
    got = np.asarray(ops.fft_last_axis(jnp.asarray(x), inverse=inverse))
    exp = np.asarray(ref.fft_last_axis_ref(jnp.asarray(x), inverse=inverse))
    scale = np.abs(exp).max() + 1e-9
    assert np.abs(got - exp).max() / scale < 2e-5


def test_fft_last_axis_fallback_odd_size(rng):
    # 1021 prime: wrapper falls back to the matmul path transparently
    x = (rng.standard_normal((1021,)) + 1j * rng.standard_normal((1021,))).astype(np.complex64)
    got = np.asarray(ops.fft_last_axis(jnp.asarray(x)))
    exp = np.fft.fft(x)
    assert np.abs(got - exp).max() / np.abs(exp).max() < 1e-4


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 4),
    n=st.sampled_from([1024, 2048, 4096]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fft_kernel_property_sweep(b, n, seed):
    r = np.random.default_rng(seed)
    x = (r.standard_normal((b, n)) + 1j * r.standard_normal((b, n))).astype(np.complex64)
    got = np.asarray(ops.fft_last_axis(jnp.asarray(x)))
    exp = np.fft.fft(x, axis=-1)
    assert np.abs(got - exp).max() / (np.abs(exp).max() + 1e-9) < 2e-5
