"""Pallas kernels vs pure-jnp oracles: shape/dtype sweep in interpret mode."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _planar(rng, shape):
    return (
        rng.standard_normal(shape).astype(np.float32),
        rng.standard_normal(shape).astype(np.float32),
    )


@pytest.mark.parametrize(
    "b,m,k,n",
    [(1, 128, 128, 128), (2, 256, 64, 128), (3, 128, 512, 256), (1, 384, 128, 384)],
)
def test_stage_left_matches_ref(rng, b, m, k, n):
    w = _planar(rng, (m, k))
    a = _planar(rng, (b, k, n))
    t = _planar(rng, (m, n))
    got = ops.stage_left(w, a, t)
    exp = ref.stage_left_ref(w, a, t)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("b,m,k,n", [(1, 128, 128, 128), (2, 128, 256, 128)])
def test_stage_right_matches_ref(rng, b, m, k, n):
    a = _planar(rng, (b, m, k))
    w = _planar(rng, (n, k))
    got = ops.stage_right(a, w)
    exp = ref.stage_right_ref(a, w)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), rtol=2e-4, atol=2e-3)


def test_stage_left_block_sweep(rng):
    """BlockSpec tiling must not change results."""
    w = _planar(rng, (256, 128))
    a = _planar(rng, (1, 128, 256))
    t = _planar(rng, (256, 256))
    base = ops.stage_left(w, a, t, bm=256, bn=256)
    for bm in (64, 128):
        for bn in (64, 128, 256):
            got = ops.stage_left(w, a, t, bm=bm, bn=bn)
            for g, e in zip(got, base):
                np.testing.assert_allclose(np.asarray(g), np.asarray(e), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("n", [1024, 4096, 16384])
@pytest.mark.parametrize("inverse", [False, True])
def test_fft_last_axis_vs_oracle(rng, n, inverse):
    x = (rng.standard_normal((2, n)) + 1j * rng.standard_normal((2, n))).astype(np.complex64)
    got = np.asarray(ops.fft_last_axis(jnp.asarray(x), inverse=inverse))
    exp = np.asarray(ref.fft_last_axis_ref(jnp.asarray(x), inverse=inverse))
    scale = np.abs(exp).max() + 1e-9
    assert np.abs(got - exp).max() / scale < 2e-5


def test_fft_last_axis_fallback_odd_size(rng):
    # 1021 prime: wrapper falls back to the matmul path transparently
    x = (rng.standard_normal((1021,)) + 1j * rng.standard_normal((1021,))).astype(np.complex64)
    got = np.asarray(ops.fft_last_axis(jnp.asarray(x)))
    exp = np.fft.fft(x)
    assert np.abs(got - exp).max() / np.abs(exp).max() < 1e-4


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 4),
    n=st.sampled_from([1024, 2048, 4096]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fft_kernel_property_sweep(b, n, seed):
    r = np.random.default_rng(seed)
    x = (r.standard_normal((b, n)) + 1j * r.standard_normal((b, n))).astype(np.complex64)
    got = np.asarray(ops.fft_last_axis(jnp.asarray(x)))
    exp = np.fft.fft(x, axis=-1)
    assert np.abs(got - exp).max() / (np.abs(exp).max() + 1e-9) < 2e-5
