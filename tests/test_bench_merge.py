"""benchmarks/run.py --json merge semantics: a partial run must merge
its sections into an existing BENCH_fft.json instead of clobbering the
committed multi-section baseline (and --force must overwrite). The
top-level ``meta`` section (planner-accuracy score) must survive row
merges untouched, and every write re-stamps the run-provenance fields
(commit / device_kind / timestamp) the history ledger snapshots."""

import json
import sys

from conftest import REPO

if REPO not in sys.path:
    sys.path.insert(0, REPO)

from benchmarks.run import _merge_json, _stamp_meta  # noqa: E402


def _write(path, rows, meta=None):
    doc = {"schema": 2, "rows": rows}
    if meta is not None:
        doc["meta"] = meta
    path.write_text(json.dumps(doc))


def test_partial_run_keeps_other_sections(tmp_path):
    path = tmp_path / "BENCH_fft.json"
    baseline = [
        {"bench": "fft2", "p": 8, "backend": "scatter", "measured_us": 1.0},
        {"bench": "fft3_decomp", "p": 8, "grid": "2x4", "measured_us": 2.0},
        {"bench": "real", "p": 8, "transform": "r2c", "measured_us": 3.0},
        {"bench": "overlap", "p": 8, "backend": "scatter", "fused": True,
         "n_chunks": 16, "measured_us": 4.0},
    ]
    _write(path, baseline)
    new = [{"bench": "fft2", "p": 8, "backend": "scatter", "measured_us": 9.0}]
    merged, _ = _merge_json(str(path), new)
    benches = sorted(r["bench"] for r in merged)
    assert benches == ["fft2", "fft3_decomp", "overlap", "real"]
    (fft2_row,) = [r for r in merged if r["bench"] == "fft2"]
    assert fft2_row["measured_us"] == 9.0  # ran section replaced...
    assert any(r["bench"] == "real" and r["measured_us"] == 3.0 for r in merged)
    # ...and the overlap section survives a run that did not select it
    assert any(r["bench"] == "overlap" and r["n_chunks"] == 16 for r in merged)


def test_overlap_section_replaced_as_a_unit(tmp_path):
    """An overlap re-run replaces every old overlap row (fused and
    unfused variants alike) while other sections survive."""
    path = tmp_path / "b.json"
    _write(path, [
        {"bench": "overlap", "p": 8, "fused": False, "measured_us": 5.0},
        {"bench": "overlap", "p": 8, "fused": True, "measured_us": 4.0},
        {"bench": "real", "p": 8, "measured_us": 3.0},
    ])
    merged, _ = _merge_json(str(path), [
        {"bench": "overlap", "p": 8, "fused": True, "n_chunks": 32, "measured_us": 2.0},
    ])
    overlap = [r for r in merged if r["bench"] == "overlap"]
    assert overlap == [
        {"bench": "overlap", "p": 8, "fused": True, "n_chunks": 32, "measured_us": 2.0}
    ]
    assert any(r["bench"] == "real" for r in merged)


def test_ran_section_fully_replaced_not_appended(tmp_path):
    path = tmp_path / "b.json"
    _write(path, [{"bench": "real", "p": 2}, {"bench": "real", "p": 4}])
    merged, _ = _merge_json(str(path), [{"bench": "real", "p": 8}])
    assert merged == [{"bench": "real", "p": 8}]


def test_serve_section_merges_like_the_rest(tmp_path):
    """A --only serve re-run replaces the serve rows (both the load-sweep
    and warm_start kinds) and leaves the transform sections alone."""
    path = tmp_path / "BENCH_fft.json"
    _write(path, [
        {"bench": "fft2", "p": 8, "backend": "scatter", "measured_us": 1.0},
        {"bench": "serve", "row": "load_sweep", "p": 8, "coalesce": True,
         "load": 16, "tps": 100.0},
        {"bench": "serve", "row": "warm_start", "p": 8, "cold_first_us": 9e4},
    ])
    merged, _ = _merge_json(str(path), [
        {"bench": "serve", "row": "load_sweep", "p": 8, "coalesce": True,
         "load": 16, "tps": 250.0},
        {"bench": "serve", "row": "load_sweep", "p": 8, "coalesce": False,
         "load": 16, "tps": 150.0},
        {"bench": "serve", "row": "warm_start", "p": 8, "cold_first_us": 8e4,
         "warm_first_us": 7e3},
    ])
    serve = [r for r in merged if r["bench"] == "serve"]
    assert len(serve) == 3
    assert all(r.get("tps") != 100.0 for r in serve)  # old rows replaced
    assert any(r.get("warm_first_us") == 7e3 for r in serve)
    assert any(r["bench"] == "fft2" and r["measured_us"] == 1.0 for r in merged)


def test_force_overwrites(tmp_path):
    path = tmp_path / "b.json"
    _write(path, [{"bench": "fft3_decomp", "p": 8}])
    merged, meta = _merge_json(str(path), [{"bench": "fft2", "p": 8}], force=True)
    assert merged == [{"bench": "fft2", "p": 8}]
    assert meta == {}


def test_missing_or_corrupt_file_is_fresh_start(tmp_path):
    assert _merge_json(str(tmp_path / "nope.json"), [{"bench": "fft2"}]) == (
        [{"bench": "fft2"}],
        {},
    )
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert _merge_json(str(bad), [{"bench": "fft2"}]) == ([{"bench": "fft2"}], {})


def test_meta_survives_row_merge(tmp_path):
    """planner_score --write-meta stamps meta; a later --json bench run
    must carry it over unchanged while replacing its own rows."""
    path = tmp_path / "BENCH_fft.json"
    score = {"planner_score": {"picked_hit_rate": 1.0, "groups": 15}}
    _write(path, [{"bench": "fft2", "p": 8, "measured_us": 1.0}], meta=score)
    merged, meta = _merge_json(str(path), [{"bench": "fft2", "p": 8, "measured_us": 2.0}])
    assert meta == score
    assert merged == [{"bench": "fft2", "p": 8, "measured_us": 2.0}]


def test_stamp_meta_injects_provenance_and_keeps_scores():
    rows = [
        {"bench": "fft2", "p": 8, "device_kind": "cpu"},
        {"bench": "real", "p": 4, "device_kind": "cpu"},
    ]
    meta = {"planner_score": {"groups": 14}}
    out = _stamp_meta(meta, rows, commit="abc1234", now="2026-08-08T00:00:00+00:00")
    assert out["commit"] == "abc1234"
    assert out["device_kind"] == "cpu"
    assert out["timestamp"] == "2026-08-08T00:00:00+00:00"
    assert out["planner_score"] == {"groups": 14}  # older meta survives
    assert "commit" not in meta  # input not mutated


def test_stamp_meta_device_kind_union_and_fallback():
    rows = [
        {"bench": "fft2", "device_kind": "tpu"},
        {"bench": "fft2", "device_kind": "cpu"},
        {"bench": "overlap"},  # rows without device_kind don't crash it
    ]
    out = _stamp_meta({}, rows, commit="c", now="t")
    assert out["device_kind"] == "cpu+tpu"
    # no rows carry a kind: the previous stamp survives, else "unknown"
    assert _stamp_meta({"device_kind": "gpu"}, [], commit="c", now="t")["device_kind"] == "gpu"
    assert _stamp_meta({}, [], commit="c", now="t")["device_kind"] == "unknown"


def test_stamp_meta_roundtrips_through_merge(tmp_path):
    """The full --json write cycle: stamp, write, merge a later partial
    run, re-stamp -- scores survive, provenance reflects the new run."""
    path = tmp_path / "BENCH_fft.json"
    rows = [{"bench": "fft2", "p": 8, "measured_us": 1.0, "device_kind": "cpu"}]
    meta = _stamp_meta(
        {"planner_score": {"groups": 1}}, rows, commit="old1234", now="2026-01-01T00:00:00+00:00"
    )
    _write(path, rows, meta=meta)
    new = [{"bench": "fft2", "p": 8, "measured_us": 2.0, "device_kind": "cpu"}]
    merged, meta2 = _merge_json(str(path), new)
    meta2 = _stamp_meta(meta2, merged, commit="new5678", now="2026-02-02T00:00:00+00:00")
    assert meta2["commit"] == "new5678"
    assert meta2["timestamp"] == "2026-02-02T00:00:00+00:00"
    assert meta2["planner_score"] == {"groups": 1}
    assert merged == new


def test_stamp_meta_real_git_fallbacks():
    """Without injected commit/now the stamp must still produce strings
    (a short hash or 'unknown'; an ISO timestamp) -- never raise."""
    out = _stamp_meta({}, [{"bench": "fft2", "device_kind": "cpu"}])
    assert isinstance(out["commit"], str) and out["commit"]
    assert "T" in out["timestamp"]


def test_malformed_meta_dropped_not_crashed(tmp_path):
    path = tmp_path / "b.json"
    doc = {"schema": 2, "rows": [{"bench": "real", "p": 2}], "meta": ["not", "a", "dict"]}
    path.write_text(json.dumps(doc))
    merged, meta = _merge_json(str(path), [{"bench": "fft2", "p": 8}])
    assert meta == {}
    assert any(r["bench"] == "real" for r in merged)
