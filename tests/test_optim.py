"""Optimizer: AdamW correctness, clipping, schedules, int8 compression."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.configs.base import TrainConfig
from repro.core.compat import shard_map
from repro.optim import adamw, compress, schedule


def test_adamw_converges_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init(params)
    lr = 0.1
    for step in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw of w^2
        if step == 150:
            lr = 0.01  # decay to kill the constant-lr oscillation band
        params, state = adamw.update(grads, state, params, lr=jnp.asarray(lr), cfg=tcfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_bf16_state_close_to_f32():
    tcfg = TrainConfig(weight_decay=0.01)
    params = {"w": jnp.ones((64,))}
    s32 = adamw.init(params, "float32")
    s16 = adamw.init(params, "bfloat16")
    g = {"w": jnp.linspace(-1, 1, 64)}
    p32, _ = adamw.update(g, s32, params, lr=jnp.asarray(1e-2), cfg=tcfg)
    p16, _ = adamw.update(g, s16, params, lr=jnp.asarray(1e-2), cfg=tcfg)
    np.testing.assert_allclose(np.asarray(p32["w"]), np.asarray(p16["w"]), atol=1e-3)
    assert s16.mu["w"].dtype == jnp.bfloat16


def test_global_norm_clip(rng):
    g = {"a": jnp.asarray(rng.standard_normal(16), jnp.float32) * 100}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1.0
    small = {"a": jnp.asarray([1e-3])}
    same, _ = adamw.clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(small["a"]))


def test_warmup_cosine_shape():
    lr = [float(schedule.warmup_cosine(s, peak=1.0, warmup=10, total=100)) for s in range(100)]
    assert lr[0] == 0.0
    assert abs(lr[10] - 1.0) < 0.1
    assert lr[99] < lr[50] < lr[10] + 1e-6
    assert lr[99] >= 0.1 - 1e-6  # floor


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_error_bounded(seed):
    r = np.random.default_rng(seed)
    g = jnp.asarray(r.standard_normal(128) * r.uniform(0.1, 100), jnp.float32)
    q, scale = compress.quantize_int8(g)
    back = compress.dequantize_int8(q, scale)
    assert float(jnp.abs(back - g).max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_preserves_signal():
    """With error feedback, the *accumulated* quantized stream converges to
    the accumulated true gradient (bias-free compression)."""
    g = jnp.asarray(np.linspace(-1e-3, 1e-3, 32), jnp.float32)
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        with_fb = g + err
        q, s = compress.quantize_int8(with_fb)
        deq = compress.dequantize_int8(q, s)
        err = with_fb - deq
        total = total + deq
    np.testing.assert_allclose(np.asarray(total), np.asarray(50 * g), atol=float(s) * 1.5)


def test_compressed_psum_single_device():
    import jax
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    g = jnp.asarray(np.linspace(-1, 1, 16), jnp.float32)
    err = jnp.zeros_like(g)

    def f(g, err):
        return compress.compressed_psum(g, "data", err)

    out, new_err = jax.jit(
        shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False)
    )(g, err)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=1e-2)
