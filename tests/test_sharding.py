"""Logical sharding rules: resolution, shape-awareness, sanitization."""

import numpy as np
import pytest
import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import sharding as sh


def _mesh2(d=1, m=1):
    devs = np.asarray(jax.devices()[:1] * (d * m)).reshape(d, m)
    return Mesh(devs, ("data", "model"))


class FakeMesh:
    """Shape-only stand-in (rules never touch devices)."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.size = int(np.prod(list(axes.values()))) if axes else 1


def test_resolve_basic():
    m = FakeMesh(data=16, model=16)
    assert sh.resolve(m, "batch", None) == P("data", None)
    assert sh.resolve(m, "fsdp", "mlp") == P("data", "model")
    assert sh.resolve(m, "experts", "fsdp", "mlp") == P("model", "data", None)


def test_resolve_multipod():
    m = FakeMesh(pod=2, data=16, model=16)
    assert sh.resolve(m, "batch", None) == P(("pod", "data"), None)


def test_resolve_missing_axes_replicate():
    m = FakeMesh()
    assert sh.resolve(m, "batch", "mlp") == P(None, None)


def test_shape_aware_skips_nondivisible():
    m = FakeMesh(data=16, model=16)
    # 8 experts can't take the 16-way model axis; d_ff 16384 can
    spec = sh.resolve(m, "experts", "fsdp", "mlp", shape=(8, 6144, 16384))
    assert spec == P(None, "data", "model")
    # 256 experts claim it; d_ff then replicates
    spec2 = sh.resolve(m, "experts", "fsdp", "mlp", shape=(256, 7168, 2048))
    assert spec2 == P("model", "data", None)


def test_shape_aware_batch_prefix():
    m = FakeMesh(pod=2, data=16, model=16)
    # batch 2: only the pod axis (prefix) divides
    assert sh.resolve(m, "batch", shape=(2,)) == P("pod")
    assert sh.resolve(m, "batch", shape=(64,)) == P(("pod", "data"))
    assert sh.resolve(m, "batch", shape=(1,)) == P(None)


def test_sanitize_spec():
    m = FakeMesh(data=16, model=16)
    assert sh.sanitize_spec(m, P("model", None), (40, 8)) == P(None, None)
    assert sh.sanitize_spec(m, P("model", None), (48, 8)) == P("model", None)
    # missing mesh axes are skipped (but divisible present ones are kept)
    assert sh.sanitize_spec(m, P(("pod", "data"), None), (32, 4)) == P("data", None)
    m2 = FakeMesh(pod=2, data=16, model=16)
    assert sh.sanitize_spec(m2, P(("pod", "data"), None), (2, 4)) == P("pod", None)


def test_constrain_noop_single_device():
    import jax.numpy as jnp

    m = _mesh2(1, 1)
    x = jnp.zeros((4, 4))
    y = sh.constrain(x, m, "batch", None)
    assert y.shape == x.shape


def test_fft_axis():
    assert sh.fft_axis(FakeMesh(data=16, model=16)) == "model"
    assert sh.fft_axis(FakeMesh(rows=4)) == "rows"
