"""Local FFT: MXU matmul formulation vs XLA's FFT, + hypothesis props."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import fftmath


def _rand_c64(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)


@pytest.mark.parametrize("n", [1, 2, 8, 64, 100, 128, 384, 512, 1024, 4096, 12288])
def test_fft_matmul_matches_fft(rng, n):
    x = _rand_c64(rng, (3, n))
    got = np.asarray(fftmath.fft_matmul(jnp.asarray(x)))
    exp = np.fft.fft(x, axis=-1)
    scale = np.abs(exp).max() + 1e-9
    assert np.abs(got - exp).max() / scale < 2e-5


@pytest.mark.parametrize("n", [64, 300, 1024])
def test_ifft_roundtrip(rng, n):
    x = _rand_c64(rng, (2, n))
    y = fftmath.fft_matmul(jnp.asarray(x))
    z = np.asarray(fftmath.fft_matmul(y, inverse=True))
    assert np.abs(z - x).max() < 1e-4


def test_prime_fallback_direct_dft(rng):
    # 1021 is prime > MAX_DFT -> direct O(n^2) DFT fallback
    x = _rand_c64(rng, (1021,))
    got = np.asarray(fftmath.fft_matmul(jnp.asarray(x)))
    exp = np.fft.fft(x)
    assert np.abs(got - exp).max() / (np.abs(exp).max() + 1e-9) < 2e-5


def test_split_factor():
    assert fftmath.split_factor(512) == 512
    assert fftmath.split_factor(1024) in (2, 4, 8, 16, 32, 64, 128, 256, 512)
    assert 1024 % fftmath.split_factor(1024) == 0
    assert fftmath.split_factor(1021) == 0  # prime beyond limit
    n1 = fftmath.split_factor(16384)
    assert n1 <= 512 and 16384 % n1 == 0


def test_axis_argument(rng):
    x = _rand_c64(rng, (4, 8, 16))
    got = np.asarray(fftmath.local_fft(jnp.asarray(x), axis=1, impl="matmul"))
    exp = np.fft.fft(x, axis=1)
    assert np.abs(got - exp).max() / np.abs(exp).max() < 1e-5


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([16, 64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linearity(n, seed):
    r = np.random.default_rng(seed)
    x, y = _rand_c64(r, (n,)), _rand_c64(r, (n,))
    a = complex(r.standard_normal(), r.standard_normal())
    lhs = np.asarray(fftmath.fft_matmul(jnp.asarray(a * x + y)))
    rhs = a * np.asarray(fftmath.fft_matmul(jnp.asarray(x))) + np.asarray(
        fftmath.fft_matmul(jnp.asarray(y))
    )
    assert np.abs(lhs - rhs).max() / (np.abs(rhs).max() + 1e-9) < 1e-4


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([16, 64, 256]), seed=st.integers(0, 2**31 - 1))
def test_parseval(n, seed):
    r = np.random.default_rng(seed)
    x = _rand_c64(r, (n,))
    f = np.asarray(fftmath.fft_matmul(jnp.asarray(x)))
    lhs = np.sum(np.abs(x) ** 2)
    rhs = np.sum(np.abs(f) ** 2) / n
    assert abs(lhs - rhs) / (abs(lhs) + 1e-9) < 1e-4
